"""§Perf hillclimbing driver: re-lower a dry-run cell under candidate
changes and report the roofline deltas.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell dbrx-132b/train_4k \
        --out benchmarks/perf_log.json

Each experiment is (name, knobs); knobs:
  cfg:<field>=<value>      ModelConfig patch (attn_chunk, remat, ...)
  rules:<axis>=a,b|none    sharding-rule override for a logical axis
  seq_shard                shard token sequence over 'model' (SP)
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional, Tuple

from repro.launch.dryrun import dryrun_cell
from repro.launch.mesh import make_production_mesh

#: candidate ladders per chosen cell: (label, hypothesis, kwargs)
EXPERIMENTS: Dict[str, List[Tuple[str, str, dict]]] = {
    # most collective-bound cell: MoE EP traffic dominates
    "dbrx-132b/train_4k": [
        ("baseline", "paper-faithful: EP over model, ZeRO-1, remat", {}),
        ("cap_1.0",
         "capacity factor 1.25->1.0 cuts dispatch/combine and expert "
         "matmul bytes ~20% with bounded drop risk",
         {"cfg_overrides": {"capacity_factor": 1.0}}),
        ("experts_replicated",
         "replicating experts kills the EP all-to-all but multiplies "
         "param/opt bytes by 16 — expect memory to explode (refutation "
         "probe for 'collectives are the problem')",
         {"sharding_overrides": {"experts": []}}),
        ("seq_shard",
         "sequence-sharded activations shrink per-dev layer I/O and the "
         "gather sizes feeding the router",
         {"seq_shard_inputs": True}),
        ("cap1.0+seq_shard",
         "compose the two confirmed wins",
         {"cfg_overrides": {"capacity_factor": 1.0},
          "seq_shard_inputs": True}),
    ],
    # worst memory/compute ratio: long-context prefill
    "llama3.2-3b/prefill_32k": [
        ("baseline", "paper-faithful: chunked attention, chunk=2048", {}),
        ("chunk_4096",
         "bigger kv chunks halve the number of passes over q/acc "
         "(bytes-accessed ~ nck * q_bytes), VMEM-feasible at 4k",
         {"cfg_overrides": {"attn_chunk": 4096}}),
        ("chunk_8192",
         "same direction, 4x fewer passes than baseline",
         {"cfg_overrides": {"attn_chunk": 8192}}),
        ("seq_shard",
         "shard the 32k sequence over 'model': per-dev activation bytes "
         "drop 16x; attention must all-gather kv once per layer — net "
         "win predicted on the memory term",
         {"seq_shard_inputs": True}),
        ("chunk_8192+seq_shard",
         "compose",
         {"cfg_overrides": {"attn_chunk": 8192}, "seq_shard_inputs": True}),
    ],
    # the paper's-technique representative: dense train step
    "starcoder2-15b/train_4k": [
        ("baseline", "paper-faithful: TP over model, ZeRO-1, remat", {}),
        ("no_remat",
         "remat trades 4/3x flops for activation memory; with 16GB/chip "
         "headroom the recompute is pure waste — expect compute term "
         "down 25%",
         {"cfg_overrides": {"remat": False}}),
        ("seq_shard",
         "SP on layer boundaries cuts per-dev activation traffic",
         {"seq_shard_inputs": True}),
        ("attn_chunk_4096",
         "single-chunk attention at 4k seq: one pass, fewer "
         "rescale-corrections",
         {"cfg_overrides": {"attn_chunk": 4096}}),
        ("no_remat+seq_shard",
         "compose the confirmed wins",
         {"cfg_overrides": {"remat": False}, "seq_shard_inputs": True}),
    ],
}


def run_cell(cell: str, out_path: str, experiments=None):
    arch, shape = cell.split("/")
    mesh = make_production_mesh(multi_pod=False)
    if os.path.exists(out_path):
        with open(out_path) as f:
            prev = json.load(f)
        if isinstance(prev, dict) and prev.get("cells"):
            log_all = prev
        else:
            log_all = {"cells": {}}
    else:
        log_all = {"cells": {}}
    # append to an existing cell ladder instead of replacing it
    log = log_all["cells"].get(cell, {"cell": cell, "runs": []})

    for label, hypothesis, kw in (experiments or EXPERIMENTS[cell]):
        t0 = time.time()
        rec = dryrun_cell(arch, shape, mesh, **kw)
        entry = {
            "label": label,
            "hypothesis": hypothesis,
            "knobs": {k: str(v) for k, v in kw.items()},
            "ok": rec.get("ok"),
            "error": rec.get("error"),
            "roofline": rec.get("roofline"),
            "collectives": rec.get("collectives"),
            "memory_analysis": rec.get("memory_analysis"),
            "param_bytes_per_dev": rec.get("param_bytes_per_dev"),
            "wall_s": time.time() - t0,
        }
        log["runs"].append(entry)
        rl = entry["roofline"] or {}
        print(f"[hillclimb] {cell} :: {label}: "
              f"ok={entry['ok']} "
              f"c={rl.get('t_compute_s', 0):.3f}s "
              f"m={rl.get('t_memory_s', 0):.3f}s "
              f"x={rl.get('t_collective_s', 0):.3f}s "
              f"bound={rl.get('bound_s', 0):.3f}s ({rl.get('bottleneck')})",
              flush=True)
        log_all["cells"][cell] = log
        with open(out_path, "w") as f:
            json.dump(log_all, f, indent=1)
    return log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all")
    ap.add_argument("--out", default="benchmarks/perf_log.json")
    args = ap.parse_args()
    cells = list(EXPERIMENTS) if args.cell == "all" else [args.cell]
    for cell in cells:
        run_cell(cell, args.out)


if __name__ == "__main__":
    main()
