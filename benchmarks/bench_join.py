"""Hash-join ablation: generic jnp lowering vs. the two-kernel hash plan.

A fact-to-dimension (m:1) join — the Spark SQL workload the paper's
§6 port leans on — timed three ways over the SAME fused Weld program,
plus left/anti/multi-key variants that must each take exactly ONE
horizontally fused probe launch (all output columns share one
membership kernel), plus m:n fan-out configs (fanout 1/4/32, duplicate
build keys) that must each take exactly ONE ``group_build`` and ONE
``group_probe`` launch (the groupbuilder expansion route):

* ``kernelize="off"``   — generic lowering (vectorized binary-search
  probe + sort-based dictmerger build);
* ``kernelize="auto"``  — the default: the roofline cost gate decides
  per matched loop (build -> ``dict_hash_build``, probes ->
  ``hash_probe``) whether the kernel route can win;
* ``kernelize="always"``— every match routed unconditionally.

Every configuration is validated against a NumPy oracle before timing,
and ``--smoke`` (run from tools/ci.sh) asserts the expected routing
decisions: at the large config BOTH the open-addressing hash build and
the one-hot probe kernels must be selected under auto, while the tiny
config must be cost-gated back to the jnp lowering — so a routing
regression fails CI instead of landing silently.

On this CPU container the kernels resolve to their ref (pure-jnp) paths;
the TPU target flips ``kops.DEFAULT_IMPL`` to "pallas" and the same plan
drives the real kernels.
"""
from __future__ import annotations

import numpy as np

from repro.frames import weldrel

from .common import RowCollector, Suite, merge_routing, time_fn, \
    write_results


def make_join_data(n: int, k: int, seed: int = 3):
    rng = np.random.RandomState(seed)
    lcols = {
        "key": rng.randint(0, 2 * k, n).astype(np.int64),  # ~50% match
        "qty": rng.rand(n) * 40.0,
        "price": rng.rand(n) * 100.0,
    }
    rcols = {
        "key": np.arange(k, dtype=np.int64),
        "rate": rng.rand(k),
    }
    return lcols, rcols


def make_mn_data(n: int, k: int, fanout: int, seed: int = 7):
    """An m:n config: every build key appears `fanout` times.  At
    fanout=1 one key row is duplicated so the m:n (groupbuilder) path
    still engages — an all-unique build side takes the m:1 route."""
    rng = np.random.RandomState(seed)
    rkey = np.repeat(np.arange(k, dtype=np.int64), fanout)
    if fanout == 1:
        rkey = np.concatenate([rkey, rkey[:1]])
    rcols = {"key": rkey, "rate": rng.rand(rkey.size)}
    lcols = {
        "key": rng.randint(0, 2 * k, n).astype(np.int64),  # ~50% match
        "qty": rng.rand(n) * 40.0,
        "price": rng.rand(n) * 100.0,
    }
    return lcols, rcols


def np_join_revenue(lcols, rcols):
    """Oracle: join on key, revenue = sum(price * rate over matches)."""
    sel = np.isin(lcols["key"], rcols["key"])
    idx = np.searchsorted(rcols["key"], lcols["key"][sel])
    return (lcols["price"][sel] * rcols["rate"][idx]).sum(), int(sel.sum())


def weld_join(lcols, rcols, kernelize, how="inner", on="key",
              collect_stats=None):
    t = weldrel.Table(lcols, eager=False)
    r = weldrel.Table(rcols, eager=False)
    return weldrel.Query(t).join(r, on=on, how=how, kernelize=kernelize,
                                 collect_stats=collect_stats)


def _validate(lcols, rcols, kernelize):
    out = weld_join(lcols, rcols, kernelize)
    want_rev, want_rows = np_join_revenue(lcols, rcols)
    price = weldrel._host(out.cols["price"])
    rate = weldrel._host(out.cols["rate"])
    assert price.shape[0] == want_rows, (price.shape, want_rows)
    got = float((price * rate).sum())
    assert abs(got - want_rev) < 1e-6 * max(abs(want_rev), 1), \
        (got, want_rev, kernelize)


def run(emit, n=1_000_000, smoke=False, tol=0.35, routing=None):
    s = Suite(emit)
    k = max(n // 20, 64)
    routing = routing if routing is not None else {}

    # -- large config: both kernels must route under auto ------------------
    lcols, rcols = make_join_data(n, k)
    st: dict = {}
    weld_join(lcols, rcols, "auto", collect_stats=st)
    merge_routing(routing, st)
    if smoke:
        routed = st.get("kernelplan", {}).get("routed", {})
        assert st.get("kernelize.dict_hash_build", 0) >= 1, \
            f"auto must route the hash build at n={n}: {routed}"
        # the 4 output columns (key, qty, price, rate) share ONE
        # horizontally fused probe launch — N probes would be a
        # fusion regression
        assert st.get("kernelize.hash_probe", 0) == 1, \
            f"auto must route ONE fused probe at n={n}: {routed}"
    for kz in ("off", "auto", "always"):
        _validate(lcols, rcols, kz)

    us_off = time_fn(lambda: weld_join(lcols, rcols, "off"))
    s.record("join/inner_jnp", us_off, baseline_of="kj")
    us_auto = time_fn(lambda: weld_join(lcols, rcols, "auto"))
    s.record("join/inner_auto", us_auto, vs="kj")
    us_always = time_fn(lambda: weld_join(lcols, rcols, "always"))
    s.record("join/inner_kernelized", us_always, vs="kj")

    # -- left / anti / multi-key: one fused probe each, oracle-checked -----
    sel = np.isin(lcols["key"], rcols["key"])
    for how, want_rows in (("left", lcols["key"].shape[0]),
                           ("anti", int((~sel).sum()))):
        sth: dict = {}
        out = weld_join(lcols, rcols, "always", how=how, collect_stats=sth)
        merge_routing(routing, sth)
        rows = weldrel._host(out.cols["key"]).shape[0]
        assert rows == want_rows, (how, rows, want_rows)
        if how == "left":
            rate = weldrel._host(out.cols["rate"])
            assert int(np.isnan(rate).sum()) == int((~sel).sum()), how
        if smoke:
            assert sth.get("kernelize.hash_probe", 0) == 1, \
                f"{how} join must take ONE fused probe: {sth.get('kernelplan')}"
        us_h = time_fn(lambda: weld_join(lcols, rcols, "always", how=how))
        s.record(f"join/{how}_kernelized", us_h, vs="kj")

    mlcols = {"key": lcols["key"] % 1000, "key2": lcols["key"] % 7,
              "price": lcols["price"]}
    mrcols = {"key": np.arange(min(k, 1000), dtype=np.int64) ,
              "key2": (np.arange(min(k, 1000)) % 7).astype(np.int64),
              "rate": rcols["rate"][:min(k, 1000)]}
    stm: dict = {}
    outm = weld_join(mlcols, mrcols, "always", on=["key", "key2"],
                     collect_stats=stm)
    merge_routing(routing, stm)
    if smoke:
        assert stm.get("kernelize.dict_hash_build", 0) == 1, \
            f"multi-key build must route: {stm.get('kernelplan')}"
        assert stm.get("kernelize.hash_probe", 0) == 1, \
            f"multi-key join must take ONE fused probe: {stm.get('kernelplan')}"
    # multi-key oracle: packed tuples
    lt = set(zip(mrcols["key"].tolist(), mrcols["key2"].tolist()))
    wantm = sum(1 for a, b in zip(mlcols["key"].tolist(),
                                  mlcols["key2"].tolist()) if (a, b) in lt)
    rowsm = weldrel._host(outm.cols["price"]).shape[0]
    assert rowsm == wantm, (rowsm, wantm)
    s.record("join/multikey_kernelized",
             time_fn(lambda: weld_join(mlcols, mrcols, "always",
                                       on=["key", "key2"])))

    # -- m:n fan-out configs: groupbuilder expansion, ONE group_probe ------
    n_mn = min(n, 200_000)
    for fanout in (1, 4, 32):
        kmn = max(min(k, 2048) // max(fanout, 1), 8)
        ml, mr = make_mn_data(n_mn, kmn, fanout)
        stg: dict = {}
        outg = weld_join(ml, mr, "always", collect_stats=stg)
        merge_routing(routing, stg)
        # expansion-size oracle: sum of per-probe-row build match counts
        uniq, cnts = np.unique(mr["key"], return_counts=True)
        cnt_map = np.zeros(2 * kmn, np.int64)
        cnt_map[uniq] = cnts
        want_rows = int(cnt_map[ml["key"]].sum())
        rows = weldrel._host(outg.cols["price"]).shape[0]
        assert rows == want_rows, (fanout, rows, want_rows)
        rows0 = weldrel._host(
            weld_join(ml, mr, "off").cols["price"]).shape[0]
        assert rows0 == want_rows, (fanout, rows0, want_rows)
        if smoke:
            # exactly ONE group build + ONE fan-out probe per m:n join,
            # whatever the output width (N launches = a fusion regression)
            assert stg.get("kernelize.group_build", 0) == 1, \
                f"m:n fanout={fanout} build: {stg.get('kernelplan')}"
            assert stg.get("kernelize.group_probe", 0) == 1, \
                f"m:n fanout={fanout} probe: {stg.get('kernelplan')}"
        s.record(f"join/mn_fanout{fanout}_jnp",
                 time_fn(lambda: weld_join(ml, mr, "off")),
                 baseline_of=f"mn{fanout}")
        s.record(f"join/mn_fanout{fanout}_kernelized",
                 time_fn(lambda: weld_join(ml, mr, "always")),
                 vs=f"mn{fanout}")

    # -- tiny config: the cost gate must keep the jnp lowering -------------
    tl, tr = make_join_data(256, 32, seed=5)
    st2: dict = {}
    weld_join(tl, tr, "auto", collect_stats=st2)
    merge_routing(routing, st2)
    if smoke:
        assert st2.get("kernelize.matched", 0) == 0, \
            f"auto must gate the tiny join: {st2.get('kernelplan')}"
    for kz in ("off", "auto"):
        _validate(tl, tr, kz)
    s.record("join/tiny_auto_gated", time_fn(lambda: weld_join(tl, tr, "auto")))

    if smoke and us_auto > us_off * (1.0 + tol):
        # re-measure once so shared-CI timing jitter can't fail the gate
        us_auto2 = time_fn(lambda: weld_join(lcols, rcols, "auto"))
        us_off2 = time_fn(lambda: weld_join(lcols, rcols, "off"))
        assert min(us_auto / us_off, us_auto2 / us_off2) <= 1.0 + tol, (
            f"auto-mode join slower than jnp beyond tol={tol}: "
            f"{us_auto / us_off:.2f}x (re-measured "
            f"{us_auto2 / us_off2:.2f}x)"
        )


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced size + routing assertions (CI gate)")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--tol", type=float, default=0.35,
                    help="max allowed auto/jnp slowdown in --smoke")
    args = ap.parse_args()
    n = args.n or (300_000 if args.smoke else 1_000_000)
    print("name,us_per_call,derived")
    emit = RowCollector(lambda line: print(line, flush=True))
    routing: dict = {}
    run(emit, n=n, smoke=args.smoke, tol=args.tol, routing=routing)
    write_results("join_hash", emit.rows,
                  config={"n": n, "smoke": args.smoke, "tol": args.tol},
                  routing=routing)
    if args.smoke:
        print("# join smoke ablation OK")


if __name__ == "__main__":
    main()
