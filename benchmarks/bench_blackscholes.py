"""Fig. 5a — NumPy Black-Scholes: native eager NumPy (8 operator calls,
materialized intermediates) vs the Weld-integrated weldnp (one fused
program; vectorized erf/exp/log)."""
from __future__ import annotations

import numpy as np

from .common import Suite, time_fn
from .workloads import (black_scholes_native, black_scholes_weld,
                        make_bs_data)


def run(emit, n=2_000_000):
    s = Suite(emit)
    d = make_bs_data(n)
    want = black_scholes_native(d)
    got = black_scholes_weld(d)
    assert abs(got - want) < 1e-4 * abs(want), (got, want)

    us = time_fn(lambda: black_scholes_native(d))
    s.record("fig5a/native_numpy", us, baseline_of="bs")
    us = time_fn(lambda: black_scholes_weld(d))
    s.record("fig5a/weld", us, vs="bs")
