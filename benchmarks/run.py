"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,fig8] [--scale 0.5]

Prints ``name,us_per_call,derived`` CSV.  Every benchmark validates its
Weld result against the native baseline before timing.
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    ("fig3_motivating", "benchmarks.bench_motivating"),
    ("fig5a_blackscholes", "benchmarks.bench_blackscholes"),
    ("fig5b_pandas_clean", "benchmarks.bench_pandas_clean"),
    ("fig5d_logreg", "benchmarks.bench_logreg"),
    ("fig6_crosslib", "benchmarks.bench_crosslib"),
    ("fig7_incremental", "benchmarks.bench_incremental"),
    ("fig8_tpch", "benchmarks.bench_tpch"),
    ("fig8e_pagerank", "benchmarks.bench_pagerank"),
    ("fig10_ablations", "benchmarks.bench_ablations"),
    ("kernelplan_ablation", "benchmarks.bench_kernelplan"),
    ("join_hash", "benchmarks.bench_join"),
    ("fig11_vecmerger", "benchmarks.bench_vecmerger"),
    ("compile_times", "benchmarks.bench_compile_times"),
    ("fused_adamw", "benchmarks.bench_fused_adamw"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filter on module names")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="scale default dataset sizes")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    from .common import RowCollector, write_results

    print("name,us_per_call,derived")
    t0 = time.time()
    failures = []
    for name, modpath in MODULES:
        if only and not any(o in name for o in only):
            continue
        print(f"# {name}", file=sys.stderr, flush=True)
        mod = __import__(modpath, fromlist=["run"])
        emit = RowCollector(lambda line: print(line, flush=True))
        err = None
        kw = {}
        try:
            import inspect
            sig = inspect.signature(mod.run)
            if "n" in sig.parameters and args.scale != 1.0:
                default_n = sig.parameters["n"].default
                kw["n"] = max(int(default_n * args.scale), 1000)
            mod.run(emit, **kw)
        except Exception as e:  # noqa: BLE001
            err = repr(e)
            failures.append((name, err))
            print(f"{name},NaN,ERROR:{e!r}", flush=True)
        write_results(name, emit.rows,
                      config={"module": modpath, "scale": args.scale, **kw},
                      error=err)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
