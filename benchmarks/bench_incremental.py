"""Fig. 7 — incremental integration: Black-Scholes with k of its 8
operators ported to Weld (most-expensive-first, as the paper measured);
un-ported operators run in native NumPy with materialization at every
library boundary."""
from __future__ import annotations

import numpy as np

from repro.frames import weldnp

from .common import Suite, time_fn
from .workloads import (INV_SQRT2, RISKFREE, VOL, _cnd_np, _erf_np,
                        black_scholes_native, make_bs_data)


def bs_partial(d, ported: int):
    """ported = how many of the 8 ops run in Weld (expensive-first:
    erf(d1), erf(d2), final combine, d1, log, d2, sqrt, sig_t)."""
    s_np, k_np, t_np = d["price"], d["strike"], d["t"]

    def W(x):
        return weldnp.array(x)

    def N(x):
        return x.to_numpy() if isinstance(x, weldnp.ndarray) else x

    # ops in cost order with their implementations
    sqrt_t = np.sqrt(t_np) if ported < 7 else weldnp.sqrt(W(t_np))
    log_sk = np.log(s_np / k_np) if ported < 5 else weldnp.log(
        W(s_np) / W(k_np))
    sig_t = VOL * N(sqrt_t) if ported < 8 else sqrt_t * VOL

    if ported < 4:
        d1 = (N(log_sk) + (RISKFREE + 0.5 * VOL * VOL) * t_np) / N(sig_t)
    else:
        d1 = ((W(N(log_sk)) if not isinstance(log_sk, weldnp.ndarray)
               else log_sk)
              + W(t_np) * (RISKFREE + 0.5 * VOL * VOL)) / \
            (W(N(sig_t)) if not isinstance(sig_t, weldnp.ndarray) else sig_t)
    if ported < 6:
        d2 = N(d1) - N(sig_t)
    else:
        d2 = (d1 if isinstance(d1, weldnp.ndarray) else W(d1)) - \
            (sig_t if isinstance(sig_t, weldnp.ndarray) else W(N(sig_t)))

    if ported < 1:
        cnd1 = _cnd_np(N(d1))
    else:
        x = d1 if isinstance(d1, weldnp.ndarray) else W(N(d1))
        cnd1 = (weldnp.erf(x * INV_SQRT2) + 1.0) * 0.5
    if ported < 2:
        cnd2 = _cnd_np(N(d2))
    else:
        x = d2 if isinstance(d2, weldnp.ndarray) else W(N(d2))
        cnd2 = (weldnp.erf(x * INV_SQRT2) + 1.0) * 0.5

    if ported < 3:
        call = s_np * N(cnd1) - k_np * np.exp(-RISKFREE * t_np) * N(cnd2)
        return call.sum()
    c1 = cnd1 if isinstance(cnd1, weldnp.ndarray) else W(N(cnd1))
    c2 = cnd2 if isinstance(cnd2, weldnp.ndarray) else W(N(cnd2))
    call = W(s_np) * c1 - W(k_np) * weldnp.exp(W(t_np) * (-RISKFREE)) * c2
    return call.sum().item()


def run(emit, n=500_000):
    s = Suite(emit)
    d = make_bs_data(n)
    want = black_scholes_native(d)
    base = time_fn(lambda: bs_partial(d, 0))
    s.record("fig7/ported_0", base, baseline_of="inc")
    for k in (1, 2, 4, 6, 8):
        got = bs_partial(d, k)
        assert abs(got - want) < 1e-3 * abs(want), (k, got, want)
        us = time_fn(lambda k=k: bs_partial(d, k))
        s.record(f"fig7/ported_{k}", us, vs="inc")
