"""Beyond-paper: the framework's optimizer as a Weld workload.

AdamW is ~10 elementwise passes per parameter.  As separate eager NumPy
ops (how a standalone optimizer library behaves) it is memory-bound on
materialized intermediates; the Weld-fused form runs ONE pass producing
three outputs (Listing 3 at production scale); `jax_fused` is the
XLA-jitted chain (the in-trainer path); the Pallas kernel is the
explicit-VMEM TPU form (interpret-timed on CPU — indicative only).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as kref
from repro.optim.adamw import adamw_update_weld

from .common import Suite, time_fn


def adamw_numpy(p, g, m, v, lr, t, b1=0.9, b2=0.999, eps=1e-8, wd=0.01):
    m_new = b1 * m + (1 - b1) * g                    # pass 1+2
    v_new = b2 * v + (1 - b2) * g * g                # pass 3+4
    m_hat = m_new / (1 - b1 ** t)                    # pass 5
    v_hat = v_new / (1 - b2 ** t)                    # pass 6
    upd = m_hat / (np.sqrt(v_hat) + eps) + wd * p    # pass 7+8
    return p - lr * upd, m_new, v_new                # pass 9


def run(emit, n=2_000_000):
    s = Suite(emit)
    rng = np.random.RandomState(7)
    p = rng.randn(n)
    g = rng.randn(n) * 0.1
    m = np.zeros(n)
    v = np.zeros(n)

    want = adamw_numpy(p, g, m, v, 1e-3, 1.0)
    got = adamw_update_weld(p, g, m, v, 1e-3, 1.0)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), b, rtol=1e-9)

    us = time_fn(lambda: adamw_numpy(p, g, m, v, 1e-3, 1.0))
    s.record("adamw/native_numpy", us, baseline_of="aw")
    us = time_fn(lambda: adamw_update_weld(p, g, m, v, 1e-3, 1.0))
    s.record("adamw/weld_fused", us, vs="aw")

    jj = [jnp.asarray(x) for x in (p, g, m, v)]
    jf = jax.jit(lambda p, g, m, v: kref.adamw_update(p, g, m, v, 1e-3, 1.0))
    jax.block_until_ready(jf(*jj))
    us = time_fn(lambda: jax.block_until_ready(jf(*jj)))
    s.record("adamw/jax_fused", us, vs="aw")
