"""Shared benchmark utilities: robust timing + CSV rows.

Every benchmark emits ``name,us_per_call,derived`` rows where `derived`
carries the figure-relevant ratio (e.g. speedup vs the native baseline).
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional

import numpy as np


def time_fn(fn: Callable, *, warmup: int = 2, iters: int = 5,
            min_time_s: float = 0.05) -> float:
    """Median wall time per call, in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        n = 0
        while True:
            fn()
            n += 1
            dt = time.perf_counter() - t0
            if dt >= min_time_s:
                break
        times.append(dt / n)
    return float(np.median(times) * 1e6)


def row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"


class Suite:
    def __init__(self, emit):
        self.emit = emit
        self.baselines = {}

    def record(self, name: str, us: float, baseline_of: Optional[str] = None,
               vs: Optional[str] = None):
        derived = ""
        if baseline_of is not None:
            self.baselines[baseline_of] = us
        if vs is not None and vs in self.baselines:
            derived = f"speedup_vs_{vs}={self.baselines[vs] / us:.2f}x"
        self.emit(row(name, us, derived))
        return us
