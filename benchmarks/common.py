"""Shared benchmark utilities: robust timing + CSV rows + JSON results.

Every benchmark emits ``name,us_per_call,derived`` rows where `derived`
carries the figure-relevant ratio (e.g. speedup vs the native baseline).
Harness entry points additionally persist each bench's rows as
machine-readable ``BENCH_<name>.json`` (config, timings, routing counts,
git rev) under ``benchmarks/results/`` — the perf-trajectory dataset;
override the directory with ``$WELD_BENCH_RESULTS``.
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Callable, List, Optional

import numpy as np


def time_fn(fn: Callable, *, warmup: int = 2, iters: int = 5,
            min_time_s: float = 0.05) -> float:
    """Median wall time per call, in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        n = 0
        while True:
            fn()
            n += 1
            dt = time.perf_counter() - t0
            if dt >= min_time_s:
                break
        times.append(dt / n)
    return float(np.median(times) * 1e6)


def row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"


class Suite:
    def __init__(self, emit):
        self.emit = emit
        self.baselines = {}

    def record(self, name: str, us: float, baseline_of: Optional[str] = None,
               vs: Optional[str] = None):
        derived = ""
        if baseline_of is not None:
            self.baselines[baseline_of] = us
        if vs is not None and vs in self.baselines:
            derived = f"speedup_vs_{vs}={self.baselines[vs] / us:.2f}x"
        self.emit(row(name, us, derived))
        return us


# ---------------------------------------------------------------------------
# Machine-readable results (BENCH_<name>.json)
# ---------------------------------------------------------------------------

ENV_RESULTS = "WELD_BENCH_RESULTS"


class RowCollector:
    """Wraps an emit callback, parsing every CSV row into a dict so the
    harness can persist structured results next to the printed CSV."""

    def __init__(self, emit: Callable[[str], None]):
        self._emit = emit
        self.rows: List[dict] = []

    def __call__(self, line: str) -> None:
        parts = line.split(",", 2)
        if len(parts) >= 2 and not line.startswith("#"):
            try:
                us = float(parts[1])
            except ValueError:
                us = None
            self.rows.append({
                "name": parts[0],
                "us_per_call": us,
                "derived": parts[2] if len(parts) > 2 else "",
            })
        self._emit(line)


def git_rev() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            stderr=subprocess.DEVNULL, text=True,
        ).strip()
    except Exception:
        return "unknown"


def merge_routing(dst: dict, stats: dict) -> dict:
    """Accumulate ``kernelize.*`` routing counts from one evaluation's
    collect_stats dict into a bench-level routing summary."""
    for k, v in stats.items():
        if k.startswith("kernelize.") and isinstance(v, int):
            dst[k] = dst.get(k, 0) + v
    return dst


def results_dir() -> str:
    return os.environ.get(ENV_RESULTS) or os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "results"
    )


def write_results(name: str, rows: List[dict], config: Optional[dict] = None,
                  routing: Optional[dict] = None,
                  error: Optional[str] = None) -> Optional[str]:
    """Persist one bench's results as ``BENCH_<name>.json``.  Best-effort:
    an unwritable results directory never fails the bench."""
    payload = {
        "bench": name,
        "git_rev": git_rev(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "config": config or {},
        "routing": routing or {},
        "rows": rows,
    }
    if error is not None:
        payload["error"] = error
    out = os.path.join(results_dir(), f"BENCH_{name}.json")
    try:
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            json.dump(payload, f, indent=1)
    except OSError:
        return None
    return out
