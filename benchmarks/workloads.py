"""The paper's workloads, shared across benchmark modules.

Each workload has a `native` (eager NumPy — the paper's "optimized C
operators composed through the function-call interface") and a `weld`
variant; both return a comparable scalar for validation.
"""
from __future__ import annotations

import numpy as np

from repro.frames import welddf, weldnp

N_DEFAULT = 2_000_000


def make_crime_data(n=N_DEFAULT, seed=0):
    rng = np.random.RandomState(seed)
    return {
        "population": rng.randint(0, 1_000_000, n).astype(np.float64),
        "crime": rng.rand(n),
        "state": rng.randint(0, 50, n).astype(np.int64),
    }


def crime_index_native(d):
    """Fig 3 / 6b: filter + linear model + aggregate, eager NumPy."""
    m = d["population"] > 500_000          # pass 1
    pop = d["population"][m]               # pass 2 (materialize)
    crime = d["crime"][m]                  # pass 3
    a = pop * 0.1                          # pass 4
    b = crime * 2.0                        # pass 5
    idx = a + b                            # pass 6
    return idx.sum()                       # pass 7


def crime_index_weld(d, collect_stats=None):
    df = welddf.DataFrame({"population": d["population"],
                           "crime": d["crime"]})
    big = df[df["population"] > 500_000]
    index = big["population"] * 0.1 + big["crime"] * 2.0
    total = index.sum()
    if collect_stats is not None:
        from repro.core.lazy import Evaluate
        return Evaluate(total.obj, collect_stats=collect_stats).value
    return total.item()


# -- Black-Scholes (Fig 5a) ----------------------------------------------------

_A1, _A2, _A3, _A4, _A5, _P = (
    0.254829592, -0.284496736, 1.421413741, -1.453152027, 1.061405429,
    0.3275911,
)


def _erf_np(x):
    """Vectorized Abramowitz–Stegun erf — the 'optimized C' analogue the
    native baseline would ship (numpy has no erf; scipy absent here)."""
    s = np.sign(x)
    x = np.abs(x)
    t = 1.0 / (1.0 + _P * x)
    y = 1.0 - (((((_A5 * t + _A4) * t) + _A3) * t + _A2) * t + _A1) * t \
        * np.exp(-x * x)
    return s * y


def make_bs_data(n=N_DEFAULT, seed=1):
    rng = np.random.RandomState(seed)
    return {
        "price": rng.uniform(10, 200, n),
        "strike": rng.uniform(10, 200, n),
        "t": rng.uniform(0.1, 2.0, n),
    }


RISKFREE, VOL = 0.02, 0.30
INV_SQRT2 = 1.0 / np.sqrt(2.0)


def _cnd_np(x):
    return 0.5 * (1.0 + _erf_np(x * INV_SQRT2))


def black_scholes_native(d):
    """Eight eager NumPy operator calls, intermediates materialized."""
    s, k, t = d["price"], d["strike"], d["t"]
    sqrt_t = np.sqrt(t)                                   # op 1
    log_sk = np.log(s / k)                                # op 2 (+div)
    sig_t = VOL * sqrt_t                                  # op 3
    d1 = (log_sk + (RISKFREE + 0.5 * VOL * VOL) * t) / sig_t   # op 4
    d2 = d1 - sig_t                                       # op 5
    cnd1 = _cnd_np(d1)                                    # op 6 (erf)
    cnd2 = _cnd_np(d2)                                    # op 7 (erf)
    call = s * cnd1 - k * np.exp(-RISKFREE * t) * cnd2    # op 8
    return call.sum()


def _cnd_w(x):
    return (weldnp.erf(x * INV_SQRT2) + 1.0) * 0.5


def black_scholes_weld_expr(d):
    s = weldnp.array(d["price"])
    k = weldnp.array(d["strike"])
    t = weldnp.array(d["t"])
    sqrt_t = weldnp.sqrt(t)
    log_sk = weldnp.log(s / k)
    sig_t = sqrt_t * VOL
    d1 = (log_sk + t * (RISKFREE + 0.5 * VOL * VOL)) / sig_t
    d2 = d1 - sig_t
    call = s * _cnd_w(d1) - k * weldnp.exp(t * (-RISKFREE)) * _cnd_w(d2)
    return call.sum()


def black_scholes_weld(d):
    return black_scholes_weld_expr(d).item()


# -- Pandas zipcode cleaning (Fig 5b) -------------------------------------------


def make_zip_data(n=N_DEFAULT, seed=2):
    rng = np.random.RandomState(seed)
    return {"zip": rng.randint(1, 100_000_000, n).astype(np.int64),
            "value": rng.rand(n)}


def pandas_clean_native(d):
    z = d["zip"]
    width = np.where(z > 0, np.floor(np.log10(np.maximum(z, 1))) + 1, 1)
    drop = np.maximum(width - 5, 0).astype(np.int64)
    z5 = (z // np.power(10, drop)).astype(np.int64)        # slice to 5
    valid = (z5 >= 501) & (z5 <= 99_950)                   # drop nonexistent
    zv = z5[valid]
    return np.unique(zv).shape[0]


def pandas_clean_weld(d):
    df = welddf.DataFrame({"zip": d["zip"], "value": d["value"]})
    z5 = df.slice_code("zip", 5)
    df2 = welddf.DataFrame({"zip5": z5})
    fdf = df2[(df2["zip5"] >= 501) & (df2["zip5"] <= 99_950)]
    return fdf.unique("zip5", capacity=1 << 17).shape[0]
