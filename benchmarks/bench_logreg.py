"""Fig. 5d — logistic-regression scoring through weldflow:

    native  per-op jit dispatch + materialization (TF-without-XLA)
    xla     whole graph in one jax.jit (TF-with-XLA — literally XLA)
    weld    graph transformer -> WeldOp -> Weld optimizer

The paper's claim: Weld ≈ XLA on this workload despite Weld's generality
(both ≫ native).  Mirrored here exactly since our "xla" IS XLA.
"""
from __future__ import annotations

import numpy as np

from repro.frames import weldflow

from .common import Suite, time_fn


def _graph(m, w, b):
    x = weldflow.placeholder()
    logits = weldflow.matvec(x, weldflow.constant(w)) + b
    probs = weldflow.sigmoid(logits)
    return x, weldflow.reduce_mean(weldflow.log(probs))


def run(emit, n=500_000, d=64):
    s = Suite(emit)
    rng = np.random.RandomState(4)
    m = rng.rand(n, d)
    w = rng.rand(d)
    x, loss = _graph(m, w, 0.25)
    feed = {x: m}

    sessions = {k: weldflow.Session(k) for k in ("native", "xla", "weld")}
    vals = {k: float(sessions[k].run(loss, feed)) for k in sessions}
    assert abs(vals["weld"] - vals["native"]) < 1e-9
    assert abs(vals["xla"] - vals["native"]) < 1e-9

    us = time_fn(lambda: sessions["native"].run(loss, feed))
    s.record("fig5d/native_per_op", us, baseline_of="lr")
    us = time_fn(lambda: sessions["xla"].run(loss, feed))
    s.record("fig5d/xla", us, vs="lr", baseline_of="xla")
    us = time_fn(lambda: sessions["weld"].run(loss, feed))
    s.record("fig5d/weld", us, vs="lr")
    s.record("fig5d/weld_vs_xla", us, vs="xla")
