"""Fig. 11 — builder implementation strategies for the vecmerger
(count occurrences of each key), swept over the number of distinct keys.

The paper's point: the best strategy is platform-specific, and the
builder abstraction lets the backend choose.  Strategies here:

    native        NumPy np.add.at (the library a user would call)
    scatter       XLA scatter-add (jnp .at[].add) — "global, atomic-free"
    onehot_mxu    one-hot matmul accumulation — the TPU MXU strategy of
                  kernels/segment_reduce.py (timed via its jnp form)
    sort_segment  sort + segment-sum — the dictmerger lowering
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import Suite, time_fn


def run(emit, n=1_000_000):
    s = Suite(emit)
    rng = np.random.RandomState(6)

    for k in (16, 256, 4096, 65_536):
        keys = rng.randint(0, k, n).astype(np.int32)
        ones = np.ones(n, np.float32)
        kj = jnp.asarray(keys)
        oj = jnp.asarray(ones)

        def native():
            out = np.zeros(k, np.float32)
            np.add.at(out, keys, ones)
            return out

        scatter = jax.jit(
            lambda kk, vv: jnp.zeros(k, jnp.float32).at[kk].add(vv))
        onehot = jax.jit(
            lambda kk, vv: jnp.einsum(
                "nk,n->k",
                jax.nn.one_hot(kk, k, dtype=jnp.float32), vv))
        sortseg = jax.jit(
            lambda kk, vv: jax.ops.segment_sum(
                vv[jnp.argsort(kk)], jnp.sort(kk), num_segments=k))

        strategies = [("scatter", scatter), ("sort_segment", sortseg)]
        if k <= 4096:  # one-hot blows up past the VMEM-tile regime
            strategies.insert(1, ("onehot_mxu", onehot))

        want = native()
        for name, fn in strategies:
            got = np.asarray(fn(kj, oj))
            np.testing.assert_allclose(got, want, rtol=1e-5)

        base = time_fn(native)
        s.record(f"fig11/k{k}/native", base, baseline_of=f"vm{k}")
        for name, fn in strategies:
            us = time_fn(lambda fn=fn: jax.block_until_ready(fn(kj, oj)))
            s.record(f"fig11/k{k}/{name}", us, vs=f"vm{k}")
