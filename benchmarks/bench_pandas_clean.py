"""Fig. 5b — Pandas zipcode cleaning: slice to 5 digits, drop
nonexistent codes, count distinct.  Native = eager NumPy column ops;
Weld = welddf fused program (numeric-code adaptation per DESIGN.md §2)."""
from __future__ import annotations

from .common import Suite, time_fn
from .workloads import make_zip_data, pandas_clean_native, pandas_clean_weld


def run(emit, n=1_000_000):
    s = Suite(emit)
    d = make_zip_data(n)
    want = pandas_clean_native(d)
    got = pandas_clean_weld(d)
    assert got == want, (got, want)

    us = time_fn(lambda: pandas_clean_native(d))
    s.record("fig5b/native_pandas", us, baseline_of="pd")
    us = time_fn(lambda: pandas_clean_weld(d))
    s.record("fig5b/weld", us, vs="pd")
