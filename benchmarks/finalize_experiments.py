"""Fill EXPERIMENTS.md placeholders from dryrun_results.json,
perf_log.json and bench_output.txt.

    PYTHONPATH=src python -m benchmarks.finalize_experiments
"""
from __future__ import annotations

import json
import os
import re
import sys

sys.path.insert(0, "src")

from repro.roofline.report import (  # noqa: E402
    dryrun_table, roofline_table, skips_table,
)


def bench_summary(path="bench_output.txt") -> str:
    if not os.path.exists(path):
        return "_bench_output.txt not yet generated_"
    rows = ["| benchmark | us/call | derived |", "|---|---|---|"]
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(("name,", "#")):
                continue
            parts = line.split(",", 2)
            if len(parts) == 3:
                rows.append(f"| {parts[0]} | {parts[1]} | {parts[2]} |")
    return "\n".join(rows)


def perf_log(path="benchmarks/perf_log.json") -> str:
    if not os.path.exists(path):
        return "_perf_log.json not yet generated_"
    with open(path) as f:
        data = json.load(f)
    out = []
    for cell, log in data.get("cells", {}).items():
        out.append(f"### {cell}\n")
        out.append("| change | hypothesis | t_compute | t_memory | "
                   "t_collective | bound | Δbound vs baseline | verdict |")
        out.append("|---|---|---|---|---|---|---|---|")
        base = None
        for r in log["runs"]:
            rl = r.get("roofline") or {}
            bound = rl.get("bound_s")
            if r["label"] == "baseline":
                base = bound
            if not r.get("ok"):
                out.append(f"| {r['label']} | {r['hypothesis'][:60]} | - | "
                           f"- | - | FAIL | - | {r.get('error', '')[:40]} |")
                continue
            delta = ""
            verdict = ""
            if base and bound:
                pct = (base - bound) / base * 100
                delta = f"{pct:+.1f}%"
                verdict = ("confirmed" if pct > 2 else
                           "refuted" if pct < -2 else "neutral")
            out.append(
                f"| {r['label']} | {r['hypothesis'][:60]} | "
                f"{rl.get('t_compute_s', 0):.2f}s | "
                f"{rl.get('t_memory_s', 0):.2f}s | "
                f"{rl.get('t_collective_s', 0):.2f}s | "
                f"{bound:.2f}s | {delta} | {verdict} |"
            )
        out.append("")
    return "\n".join(out)


def main():
    with open("benchmarks/dryrun_results.json") as f:
        results = json.load(f)

    with open("EXPERIMENTS.md") as f:
        doc = f.read()

    n_ok = sum(1 for r in results.values()
               if r.get("ok") and "skipped" not in r)
    n_skip = sum(1 for r in results.values() if "skipped" in r)
    n_fail = sum(1 for r in results.values() if not r.get("ok"))

    dry = (
        f"Cells: {len(results)} — compiled OK: **{n_ok}**, skipped per "
        f"assignment rules: **{n_skip}**, failed: **{n_fail}**.\n\n"
        + dryrun_table(results)
        + "\n\n### Skipped cells (assignment rules)\n\n"
        + skips_table(results)
    )
    roof = roofline_table(results)

    doc = re.sub(r"<!-- BENCH_SUMMARY -->", lambda m: bench_summary(), doc)
    doc = re.sub(r"<!-- DRYRUN_TABLE -->", lambda m: dry, doc)
    doc = re.sub(r"<!-- ROOFLINE_TABLE -->", lambda m: roof, doc)
    doc = re.sub(r"<!-- PERF_LOG -->", lambda m: perf_log(), doc)

    with open("EXPERIMENTS.md", "w") as f:
        f.write(doc)
    print("EXPERIMENTS.md updated "
          f"({n_ok} ok / {n_skip} skip / {n_fail} fail)")


if __name__ == "__main__":
    main()
