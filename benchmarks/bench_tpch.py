"""Fig. 5c / Fig. 8 — TPC-H Q1 and Q6 on a synthetic lineitem table.

    native      eager NumPy relational operators (materialized masks)
    weld        weldrel operators fused by Weld (one pass per query)
    handcoded   a hand-fused jax.jit kernel (the paper's "C baseline")
    weld_pallas Q6 through the filter_reduce kernel (TPU target form,
                interpret-validated; CPU timing is indicative only)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.frames import weldrel
from repro.kernels import ops as kops

from .common import Suite, time_fn


def make_lineitem(n=2_000_000, seed=3):
    rng = np.random.RandomState(seed)
    return {
        "ship": rng.randint(0, 2557, n).astype(np.int64),
        "disc": rng.uniform(0, 0.1, n),
        "qty": rng.uniform(1, 50, n),
        "price": rng.uniform(100, 10_000, n),
        "tax": rng.uniform(0, 0.08, n),
        "rf": rng.randint(0, 3, n).astype(np.int64),
        "ls": rng.randint(0, 2, n).astype(np.int64),
    }


# -- Q6 -------------------------------------------------------------------------


def q6_native(c):
    m = (c["ship"] >= 365) & (c["ship"] < 730)
    m &= (c["disc"] >= 0.05) & (c["disc"] <= 0.07)
    m &= c["qty"] < 24.0
    return (c["price"][m] * c["disc"][m]).sum()


def q6_weld(c):
    t = weldrel.Table(c)
    q = weldrel.Query(t).filter(
        (t.col("ship") >= 365) & (t.col("ship") < 730)
        & (t.col("disc") >= 0.05) & (t.col("disc") <= 0.07)
        & (t.col("qty") < 24.0)
    )
    return q.agg({"rev": (t.col("price") * t.col("disc"), "+")})["rev"]


def _q6_hand(ship, disc, qty, price):
    m = (ship >= 365) & (ship < 730) & (disc >= 0.05) & (disc <= 0.07) \
        & (qty < 24.0)
    return jnp.sum(jnp.where(m, price * disc, 0.0))


# -- Q1 -------------------------------------------------------------------------


def q1_native(c):
    m = c["ship"] <= 2000
    out = {}
    rf, ls = c["rf"][m], c["ls"][m]
    qty, price = c["qty"][m], c["price"][m]
    disc, tax = c["disc"][m], c["tax"][m]
    dp = price * (1 - disc)
    ch = dp * (1 + tax)
    for r in range(3):
        for l in range(2):
            g = (rf == r) & (ls == l)
            out[(r, l)] = (qty[g].sum(), price[g].sum(), dp[g].sum(),
                           ch[g].sum(), int(g.sum()))
    return out


def q1_weld(c):
    t = weldrel.Table(c)
    dp = t.col("price") * (1.0 - t.col("disc"))
    ch = dp * (1.0 + t.col("tax"))
    q = weldrel.Query(t).filter(t.col("ship") <= 2000)
    return q.group_agg(
        [t.col("rf"), t.col("ls")],
        {"sq": (t.col("qty"), "+"), "sb": (t.col("price"), "+"),
         "sdp": (dp, "+"), "sch": (ch, "+")},
        capacity=16,
    )


def run(emit, n=1_000_000):
    s = Suite(emit)
    c = make_lineitem(n)

    want = q6_native(c)
    got = q6_weld(c)
    assert abs(got - want) < 1e-6 * max(abs(want), 1)
    us = time_fn(lambda: q6_native(c))
    s.record("fig8/q6_native", us, baseline_of="q6")
    us = time_fn(lambda: q6_weld(c))
    s.record("fig8/q6_weld", us, vs="q6")

    hand = jax.jit(_q6_hand)
    args = [jnp.asarray(c[k]) for k in ("ship", "disc", "qty", "price")]
    hand(*args).block_until_ready()
    us = time_fn(lambda: hand(*args).block_until_ready())
    s.record("fig8/q6_handcoded", us, vs="q6")

    cols = jnp.stack([jnp.asarray(c["ship"], jnp.float64),
                      jnp.asarray(c["disc"]), jnp.asarray(c["qty"])])
    lo = jnp.asarray([365.0, 0.05, 0.0])
    hi = jnp.asarray([730.0, 0.07 + 1e-12, 24.0])
    val = jnp.asarray(c["price"] * 1.0) * 0 + jnp.asarray(c["price"])
    val = jnp.asarray(c["price"] * c["disc"])
    got = kops.filter_reduce_q6(cols, lo, hi, val, impl="ref")
    assert abs(float(got) - want) < 1e-6 * max(abs(want), 1)
    us = time_fn(lambda: jax.block_until_ready(
        kops.filter_reduce_q6(cols, lo, hi, val, impl="ref")))
    s.record("fig8/q6_kernel_ref", us, vs="q6")

    w1 = q1_native(c)
    g1 = q1_weld(c)
    for k in w1:
        assert abs(g1[k][0] - w1[k][0]) < 1e-6 * max(w1[k][0], 1)
        assert g1[k][4] == w1[k][4]
    us = time_fn(lambda: q1_native(c))
    s.record("fig8/q1_native", us, baseline_of="q1")
    us = time_fn(lambda: q1_weld(c))
    s.record("fig8/q1_weld", us, vs="q1")
