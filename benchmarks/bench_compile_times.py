"""§7.8 — Weld compile times (IR optimization + XLA codegen) across the
suite's programs; the paper reports 62–257 ms (mean 126 ms)."""
from __future__ import annotations

import numpy as np

from repro.core import runtime
from repro.core.lazy import Evaluate

from .common import Suite, row
from .workloads import (black_scholes_weld_expr, make_bs_data,
                        make_crime_data)
from .bench_motivating import _weld_total


def run(emit, n=100_000):
    s = Suite(emit)
    times = []

    progs = {
        "crimeindex": lambda: _weld_total(make_crime_data(n)).obj,
        "blackscholes": lambda: black_scholes_weld_expr(make_bs_data(n)).obj,
    }
    for name, fn in progs.items():
        runtime.clear_cache()
        res = Evaluate(fn())
        times.append(res.compile_ms)
        emit(row(f"compile/{name}", res.compile_ms * 1e3,
                 f"compile_ms={res.compile_ms:.0f}"))
        # second evaluation hits the cache
        res2 = Evaluate(fn())
        assert res2.from_cache
        emit(row(f"compile/{name}_cached", res2.compile_ms * 1e3,
                 "cached=true"))
    emit(row("compile/mean", float(np.mean(times)) * 1e3,
             f"mean_ms={np.mean(times):.0f},median_ms={np.median(times):.0f}"))
