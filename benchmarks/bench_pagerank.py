"""Fig. 8e — PageRank, edge-list (pull) formulation.

The per-vertex irregular loop is un-nested to a flat edge scan
(DESIGN.md §8.2): contribution gather (Lookup) + vecmerger scatter —
one fused Weld pass per iteration.  Native = NumPy with np.add.at.
"""
from __future__ import annotations

import numpy as np

from repro.core import ir, macros as M, wtypes as wt
from repro.core.lazy import Evaluate, NewWeldObject

from .common import Suite, time_fn

DAMP = 0.85


def make_graph(n_vertices=100_000, n_edges=1_000_000, seed=5):
    rng = np.random.RandomState(seed)
    src = rng.randint(0, n_vertices, n_edges).astype(np.int64)
    dst = rng.randint(0, n_vertices, n_edges).astype(np.int64)
    deg = np.bincount(src, minlength=n_vertices).astype(np.float64)
    deg = np.maximum(deg, 1.0)
    return src, dst, deg, n_vertices


def pagerank_native_iter(rank, src, dst, deg, n):
    contrib = rank[src] / deg[src]
    out = np.zeros(n)
    np.add.at(out, dst, contrib)
    return (1 - DAMP) / n + DAMP * out


def weld_pagerank_iter(rank_np, src_o, dst_o, invdeg_o, n,
                       kernelize=None, collect_stats=None):
    """One iteration as a single fused Weld program."""
    r = NewWeldObject(rank_np, None)
    rid = ir.Ident(r.obj_id, r.weld_type())
    sid = ir.Ident(src_o.obj_id, src_o.weld_type())
    did = ir.Ident(dst_o.obj_id, dst_o.weld_type())
    iid = ir.Ident(invdeg_o.obj_id, invdeg_o.weld_type())

    # contrib[e] = rank[src[e]] * invdeg[src[e]]  (two gathers), then
    # vecmerger scatter into dst[e] — ONE loop over the edge list.
    bt = wt.VecMerger(wt.F64, "+")
    b = ir.Ident(ir.fresh("b"), bt)
    i = ir.Ident(ir.fresh("i"), wt.I64)
    x = ir.Ident(ir.fresh("x"), wt.Struct((wt.I64, wt.I64)))
    gathered = ir.BinOp(
        "*",
        ir.Lookup(rid, ir.GetField(x, 0)),
        ir.Lookup(iid, ir.GetField(x, 0)),
    )
    body = ir.Merge(b, ir.MakeStruct((ir.GetField(x, 1), gathered)))
    base = NewWeldObject(np.zeros(n), None)
    bid = ir.Ident(base.obj_id, base.weld_type())
    loop = ir.Result(ir.For(
        (ir.Iter(sid), ir.Iter(did)),
        ir.NewBuilder(bt, arg=bid),
        ir.Lambda((b, i, x), body),
    ))
    # rank' = (1-d)/n + d * scatter
    out = M.map_(
        loop,
        lambda v: ir.BinOp(
            "+", ir.Literal((1 - DAMP) / n, wt.F64),
            ir.BinOp("*", ir.Literal(DAMP, wt.F64), v)),
    )
    obj = NewWeldObject([r, src_o, dst_o, invdeg_o, base], out)
    return np.asarray(Evaluate(obj, kernelize=kernelize,
                               collect_stats=collect_stats).value)


def run(emit, n_vertices=100_000, n_edges=500_000):
    s = Suite(emit)
    src, dst, deg, n = make_graph(n_vertices, n_edges)
    rank0 = np.full(n, 1.0 / n)

    want = pagerank_native_iter(rank0, src, dst, deg, n)
    src_o = NewWeldObject(src, None)
    dst_o = NewWeldObject(dst, None)
    invdeg_o = NewWeldObject(1.0 / deg, None)
    got = weld_pagerank_iter(rank0, src_o, dst_o, invdeg_o, n)
    np.testing.assert_allclose(got, want, rtol=1e-10)

    us = time_fn(lambda: pagerank_native_iter(rank0, src, dst, deg, n))
    s.record("fig8e/pagerank_native", us, baseline_of="pr")
    us = time_fn(lambda: weld_pagerank_iter(rank0, src_o, dst_o, invdeg_o, n))
    s.record("fig8e/pagerank_weld", us, vs="pr")
