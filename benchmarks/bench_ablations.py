"""Fig. 10 — effects of individual optimizations on Black-Scholes
(compute-bound) and the crime-index workload (data-movement-bound).

Matches the paper's finding: fusion dominates for the data-intensive
workload, while the compute-bound workload is insensitive to it.
Pass toggles reuse the optimizer's `passes` parameter.
"""
from __future__ import annotations

from repro.core.lazy import Evaluate

from .common import Suite, time_fn
from .workloads import (black_scholes_weld_expr, make_bs_data,
                        make_crime_data)
from .bench_motivating import _weld_total

ALL = ["inline", "fusion", "size", "tiling", "predication", "cse"]


def _variants():
    return {
        "all": ALL,
        "no_fusion": [p for p in ALL if p != "fusion"],
        "no_predication": [p for p in ALL if p != "predication"],
        "no_cse": [p for p in ALL if p != "cse"],
        "none": [],
    }


def run(emit, n=1_000_000):
    s = Suite(emit)
    bs = make_bs_data(n)
    cr = make_crime_data(n)

    for wname, obj_fn in (
        ("blackscholes", lambda: black_scholes_weld_expr(bs).obj),
        ("crimeindex", lambda: _weld_total(cr).obj),
    ):
        ref = None
        for vname, passes in _variants().items():
            def go(passes=passes):
                return Evaluate(obj_fn(), passes=passes).value

            val = go()
            if ref is None:
                ref = val
            assert abs(val - ref) < 1e-6 * max(abs(ref), 1), (wname, vname)
            us = time_fn(go)
            tag = f"fig10/{wname}/{vname}"
            if vname == "all":
                s.record(tag, us, baseline_of=wname)
            else:
                s.record(tag, us, vs=wname)
