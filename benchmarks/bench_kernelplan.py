"""Backend ablation: generic jnp lowering vs. kernel-planned lowering.

For each workload the SAME fused Weld program is compiled twice — once
with the plain vector emitter (``kernelize=False``, the jnp-only
backend) and once with the kernel planner routing matched loops onto the
``repro.kernels.ops`` entries (``kernelize=True``).  Every kernelized
result is validated against the jnp-only result before timing, and the
planner's per-kernel match counts are asserted so a silent fallback
can't masquerade as a win.

On this CPU container the kernels resolve to their ref (pure-jnp) paths,
so timings measure planner + dispatch overhead and XLA's view of the
restructured program; the TPU target flips ``kops.DEFAULT_IMPL`` to
"pallas" and the same plan drives the real kernels.
"""
from __future__ import annotations

import numpy as np

from repro.core.lazy import NewWeldObject
from repro.frames import welddf, weldrel

from .bench_pagerank import make_graph, pagerank_native_iter, \
    weld_pagerank_iter
from .bench_tpch import make_lineitem, q6_native
from .common import Suite, time_fn
from .workloads import black_scholes_native, black_scholes_weld_expr, \
    make_bs_data


def _q6(c, kernelize, collect_stats=None):
    t = weldrel.Table(c)
    q = weldrel.Query(t).filter(
        (t.col("ship") >= 365) & (t.col("ship") < 730)
        & (t.col("disc") >= 0.05) & (t.col("disc") <= 0.07)
        & (t.col("qty") < 24.0)
    )
    return q.agg({"rev": (t.col("price") * t.col("disc"), "+")},
                 kernelize=kernelize, collect_stats=collect_stats)["rev"]


def run(emit, n=1_000_000):
    s = Suite(emit)

    # -- TPC-H Q6: fused filter+reduce ------------------------------------
    c = make_lineitem(n)
    want = q6_native(c)
    st: dict = {}
    got = _q6(c, True, st)
    assert st.get("kernelize.filter_reduce_sum", 0) >= 1, st
    assert abs(got - want) < 1e-6 * max(abs(want), 1)
    us = time_fn(lambda: _q6(c, False))
    s.record("kernelplan/q6_jnp", us, baseline_of="kq6")
    us = time_fn(lambda: _q6(c, True))
    s.record("kernelplan/q6_kernelized", us, vs="kq6")

    # -- PageRank: vecmerger scatter -> segment_sum ------------------------
    src, dst, deg, nv = make_graph(n_vertices=max(n // 10, 1000),
                                   n_edges=max(n // 2, 10_000))
    rank0 = np.full(nv, 1.0 / nv)
    src_o = NewWeldObject(src, None)
    dst_o = NewWeldObject(dst, None)
    invdeg_o = NewWeldObject(1.0 / deg, None)
    want = pagerank_native_iter(rank0, src, dst, deg, nv)
    st = {}
    got = weld_pagerank_iter(rank0, src_o, dst_o, invdeg_o, nv,
                             kernelize=True, collect_stats=st)
    assert st.get("kernelize.vecmerger_segment_sum", 0) >= 1, st
    np.testing.assert_allclose(got, want, rtol=1e-10)
    us = time_fn(lambda: weld_pagerank_iter(rank0, src_o, dst_o, invdeg_o,
                                            nv, kernelize=False))
    s.record("kernelplan/pagerank_jnp", us, baseline_of="kpr")
    us = time_fn(lambda: weld_pagerank_iter(rank0, src_o, dst_o, invdeg_o,
                                            nv, kernelize=True))
    s.record("kernelplan/pagerank_kernelized", us, vs="kpr")

    # -- group-by: dictmerger -> dense segment_sum -------------------------
    rng = np.random.RandomState(11)
    state = rng.randint(0, 50, n).astype(np.int64)
    crime = rng.rand(n)
    df = welddf.DataFrame({"state": state, "crime": crime})
    st = {}
    d1 = df.groupby_sum("state", "crime", capacity=64, kernelize=True,
                        collect_stats=st)
    assert st.get("kernelize.dict_group_sum", 0) >= 1, st
    d0 = df.groupby_sum("state", "crime", capacity=64, kernelize=False)
    assert set(d1) == set(d0)
    for k in d0:
        assert abs(d1[k] - d0[k]) < 1e-6 * max(abs(d0[k]), 1)
    us = time_fn(lambda: df.groupby_sum("state", "crime", capacity=64,
                                        kernelize=False))
    s.record("kernelplan/groupby_jnp", us, baseline_of="kgb")
    us = time_fn(lambda: df.groupby_sum("state", "crime", capacity=64,
                                        kernelize=True))
    s.record("kernelplan/groupby_kernelized", us, vs="kgb")

    # -- Black-Scholes: map chain + unfiltered reduce ----------------------
    d = make_bs_data(n)
    want = black_scholes_native(d)
    expr = black_scholes_weld_expr(d)
    st = {}
    got = expr.evaluate(kernelize=True, collect_stats=st)
    assert st.get("kernelize.filter_reduce_sum", 0) >= 1, st
    assert abs(float(got) - want) < 1e-4 * abs(want)
    us = time_fn(lambda: expr.evaluate(kernelize=False))
    s.record("kernelplan/blackscholes_jnp", us, baseline_of="kbs")
    us = time_fn(lambda: expr.evaluate(kernelize=True))
    s.record("kernelplan/blackscholes_kernelized", us, vs="kbs")
