"""Backend ablation: generic jnp lowering vs. kernel-planned lowering.

For each workload the SAME fused Weld program is compiled three ways —

* ``kernelize="off"``  — the plain vector emitter (jnp-only backend);
* ``kernelize="auto"`` — the default: the roofline cost gate decides
  per matched loop whether the Pallas route can win;
* ``kernelize="always"`` — every match routed unconditionally (the
  PR-1 behavior; shows what the gate saves us from on losing routes).

Every kernelized result is validated against the jnp-only result before
timing, and the planner's routing decisions are asserted so a silent
fallback (or a silent route) can't masquerade as a win: Q6, group-by
and Black-Scholes must ROUTE under auto, while the large-key PageRank
vecmerger scatter must be COST-GATED back to the jnp lowering.

``--smoke`` (used by tools/ci.sh) runs a reduced size and *fails* if
any auto-mode workload is slower than the jnp baseline by more than
``--tol`` — a cost-gate regression breaks CI instead of landing
silently.

On this CPU container the kernels resolve to their ref (pure-jnp) paths,
so timings measure planner + dispatch overhead and XLA's view of the
restructured program; the TPU target flips ``kops.DEFAULT_IMPL`` to
"pallas" and the same plan drives the real kernels.
"""
from __future__ import annotations

import numpy as np

from repro.core.lazy import NewWeldObject
from repro.frames import welddf, weldrel

from .bench_pagerank import make_graph, pagerank_native_iter, \
    weld_pagerank_iter
from .bench_tpch import make_lineitem, q6_native
from .common import RowCollector, Suite, merge_routing, time_fn, write_results
from .workloads import black_scholes_native, black_scholes_weld_expr, \
    make_bs_data


def _q6(c, kernelize, collect_stats=None):
    t = weldrel.Table(c)
    q = weldrel.Query(t).filter(
        (t.col("ship") >= 365) & (t.col("ship") < 730)
        & (t.col("disc") >= 0.05) & (t.col("disc") <= 0.07)
        & (t.col("qty") < 24.0)
    )
    return q.agg({"rev": (t.col("price") * t.col("disc"), "+")},
                 kernelize=kernelize, collect_stats=collect_stats)["rev"]


def run(emit, n=1_000_000, smoke=False, tol=0.35, routing=None):
    s = Suite(emit)
    routing = routing if routing is not None else {}
    ratios = []  # (workload, auto_us/jnp_us, closure) for the smoke gate

    def triple(tag, key, fn):
        """Time kernelize=off / auto / always for one workload closure."""
        us_off = time_fn(lambda: fn("off"))
        s.record(f"kernelplan/{tag}_jnp", us_off, baseline_of=key)
        us_auto = time_fn(lambda: fn("auto"))
        s.record(f"kernelplan/{tag}_auto", us_auto, vs=key)
        us_always = time_fn(lambda: fn("always"))
        s.record(f"kernelplan/{tag}_kernelized", us_always, vs=key)
        ratios.append((tag, us_auto / us_off, fn))
        return us_off, us_auto, us_always

    def auto_vs_jnp(fn):
        return time_fn(lambda: fn("auto")) / time_fn(lambda: fn("off"))

    # Routing asserts encode the expected cost-gate decisions, which are
    # size-dependent: below the crossover the gate correctly rejects, so
    # only assert "must route" at sizes safely above it.
    big = n >= 100_000

    # -- TPC-H Q6: fused filter+reduce (multi-agg kernel) ------------------
    c = make_lineitem(n)
    want = q6_native(c)
    st: dict = {}
    got = _q6(c, "auto", st)
    merge_routing(routing, st)
    if big:
        assert st.get("kernelize.filter_reduce_sum", 0) >= 1, \
            f"auto must route Q6 at n={n}: {st.get('kernelplan')}"
    assert abs(got - want) < 1e-6 * max(abs(want), 1)
    got_always = _q6(c, "always")  # validate the forced kernel route too
    assert abs(got_always - want) < 1e-6 * max(abs(want), 1)
    triple("q6", "kq6", lambda kz: _q6(c, kz))

    # -- PageRank: vecmerger scatter — the gate must REJECT (large K) ------
    src, dst, deg, nv = make_graph(n_vertices=max(n // 10, 1000),
                                   n_edges=max(n // 2, 10_000))
    rank0 = np.full(nv, 1.0 / nv)
    src_o = NewWeldObject(src, None)
    dst_o = NewWeldObject(dst, None)
    invdeg_o = NewWeldObject(1.0 / deg, None)
    want = pagerank_native_iter(rank0, src, dst, deg, nv)
    st = {}
    got = weld_pagerank_iter(rank0, src_o, dst_o, invdeg_o, nv,
                             kernelize="auto", collect_stats=st)
    merge_routing(routing, st)
    if nv > 4096:  # beyond the VMEM tile bound the route can never win
        assert st.get("kernelize.vecmerger_segment_sum", 0) == 0, \
            f"auto must gate the large-K vecmerger: {st.get('kernelplan')}"
        assert st["kernelplan"]["rejected"].get(
            "vecmerger_segment_sum", 0) >= 1
    np.testing.assert_allclose(got, want, rtol=1e-10)
    # the forced route is the one that times the kernel — validate it too
    st_always: dict = {}
    got_always = weld_pagerank_iter(rank0, src_o, dst_o, invdeg_o, nv,
                                    kernelize="always",
                                    collect_stats=st_always)
    assert st_always.get("kernelize.vecmerger_segment_sum", 0) >= 1, \
        st_always.get("kernelplan")
    np.testing.assert_allclose(got_always, want, rtol=1e-10)
    triple("pagerank", "kpr",
           lambda kz: weld_pagerank_iter(rank0, src_o, dst_o, invdeg_o, nv,
                                         kernelize=kz))

    # -- group-by: dictmerger -> dense segment_sum -------------------------
    rng = np.random.RandomState(11)
    state = rng.randint(0, 50, n).astype(np.int64)
    crime = rng.rand(n)
    df = welddf.DataFrame({"state": state, "crime": crime})
    st = {}
    d1 = df.groupby_sum("state", "crime", capacity=64, kernelize="auto",
                        collect_stats=st)
    merge_routing(routing, st)
    gb_routed = st.get("kernelize.dict_group_sum", 0) >= 1
    if big:
        assert gb_routed, \
            f"auto must route the group-by at n={n}: {st.get('kernelplan')}"
    d0 = df.groupby_sum("state", "crime", capacity=64, kernelize="off")
    assert set(d1) == set(d0)
    for k in d0:
        assert abs(d1[k] - d0[k]) < 1e-6 * max(abs(d0[k]), 1)
    gb_fn = lambda kz: df.groupby_sum("state", "crime", capacity=64,  # noqa: E731
                                      kernelize=kz)
    gb_off, gb_auto, _ = triple("groupby", "kgb", gb_fn)
    if smoke and gb_routed:
        win = gb_off / gb_auto
        if win < 1.5:  # re-measure once before blaming the code
            win = max(win, 1.0 / auto_vs_jnp(gb_fn))
        assert win >= 1.5, (
            f"group-by kernel route regressed: {win:.2f}x "
            f"(expected >= 1.5x; >= 2x at full size)"
        )

    # -- Black-Scholes: map chain + unfiltered reduce ----------------------
    d = make_bs_data(n)
    want = black_scholes_native(d)
    expr = black_scholes_weld_expr(d)
    st = {}
    got = expr.evaluate(kernelize="auto", collect_stats=st)
    merge_routing(routing, st)
    if big:
        assert st.get("kernelize.filter_reduce_sum", 0) >= 1, \
            f"auto must route Black-Scholes at n={n}: {st.get('kernelplan')}"
    assert abs(float(got) - want) < 1e-4 * abs(want)
    got_always = expr.evaluate(kernelize="always")
    assert abs(float(got_always) - want) < 1e-4 * abs(want)
    triple("blackscholes", "kbs", lambda kz: expr.evaluate(kernelize=kz))

    if smoke:
        # Wall-clock ratios on shared CI hardware are noisy (the same
        # executable can measure ±30% across runs); the routing-decision
        # asserts above are the primary gate, and this timing backstop
        # re-measures before declaring a regression so jitter alone
        # can't fail CI.
        still_bad = []
        for t, r, fn in ratios:
            if r <= 1.0 + tol:
                continue
            r2 = auto_vs_jnp(fn)
            if min(r, r2) > 1.0 + tol:
                still_bad.append((t, min(r, r2)))
        assert not still_bad, (
            f"auto-mode routes slower than jnp beyond tol={tol} "
            f"(reproduced on re-measure): "
            + ", ".join(f"{t}={r:.2f}x" for t, r in still_bad)
        )


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced size + hard assertions (CI gate)")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--tol", type=float, default=0.35,
                    help="max allowed auto/jnp slowdown in --smoke")
    args = ap.parse_args()
    n = args.n or (300_000 if args.smoke else 1_000_000)
    print("name,us_per_call,derived")
    emit = RowCollector(lambda line: print(line, flush=True))
    routing: dict = {}
    run(emit, n=n, smoke=args.smoke, tol=args.tol, routing=routing)
    write_results("kernelplan_ablation", emit.rows,
                  config={"n": n, "smoke": args.smoke, "tol": args.tol},
                  routing=routing)
    if args.smoke:
        print("# kernelplan smoke ablation OK")


if __name__ == "__main__":
    main()
