"""Fig. 6a/6b — cross-library workloads (Pandas + NumPy):

  6b  crime index: filter -> linear model -> total (Fig 3's workload)
  6a  softmax-model variant: filter -> per-class linear scores ->
      per-state aggregation of the best class score (groupby)
"""
from __future__ import annotations

import numpy as np

from repro.frames import welddf, weldnp

from .common import Suite, time_fn
from .workloads import crime_index_native, crime_index_weld, make_crime_data


def softmax_state_native(d, n_classes=4):
    m = d["population"] > 500_000
    pop = d["population"][m]
    crime = d["crime"][m]
    state = d["state"][m]
    best = None
    for k in range(n_classes):
        score = pop * (0.1 + 0.01 * k) + crime * (2.0 - 0.1 * k)
        best = score if best is None else np.maximum(best, score)
    out = np.zeros(50)
    np.add.at(out, state, best)
    return out


def softmax_state_weld(d, n_classes=4):
    df = welddf.DataFrame({
        "population": d["population"], "crime": d["crime"],
        "state": d["state"],
    })
    big = df[df["population"] > 500_000]
    pop = big["population"]
    crime = big["crime"]
    best = None
    for k in range(n_classes):
        score = pop * (0.1 + 0.01 * k) + crime * (2.0 - 0.1 * k)
        best = score if best is None else weldnp.maximum(best, score)
    # per-state aggregation via the fused dictmerger
    fdf = welddf.DataFrame({"state": big["state"], "best": best})
    return fdf.groupby_sum("state", "best", capacity=64)


def run(emit, n=4_000_000):
    s = Suite(emit)
    d = make_crime_data(n)

    want = crime_index_native(d)
    got = crime_index_weld(d)
    assert abs(got - want) < 1e-6 * abs(want)
    us = time_fn(lambda: crime_index_native(d))
    s.record("fig6b/native", us, baseline_of="6b")
    us = time_fn(lambda: crime_index_weld(d))
    s.record("fig6b/weld", us, vs="6b")

    w = softmax_state_native(d)
    g = softmax_state_weld(d)
    for k in range(50):
        if abs(w[k]) > 1:
            assert abs(g.get(float(k), g.get(k, 0.0)) - w[k]) < 1e-6 * abs(w[k])
    us = time_fn(lambda: softmax_state_native(d))
    s.record("fig6a/native", us, baseline_of="6a")
    us = time_fn(lambda: softmax_state_weld(d))
    s.record("fig6a/weld", us, vs="6a")
