"""Fig. 3 — the motivating data-science workflow (Pandas + NumPy crime
index) under optimization toggles:

    native          eager NumPy, per-op materialization
    weld_nofusion   Weld codegen, loop fusion disabled
    weld_nocrosslib fusion within each library only (evaluation forced at
                    the library boundary)
    weld            all optimizations across both libraries
"""
from __future__ import annotations

import numpy as np

from repro.core import runtime
from repro.core.lazy import Evaluate
from repro.frames import welddf

from .common import Suite, time_fn
from .workloads import crime_index_native, crime_index_weld, make_crime_data


def _weld_total(d, passes=None):
    df = welddf.DataFrame({"population": d["population"],
                           "crime": d["crime"]})
    big = df[df["population"] > 500_000]
    index = big["population"] * 0.1 + big["crime"] * 2.0
    return index.sum()


def _weld_crosslib_cut(d):
    """Force evaluation at the Pandas/NumPy boundary: filtered columns
    materialize, then the arithmetic fuses only within weldnp."""
    df = welddf.DataFrame({"population": d["population"],
                           "crime": d["crime"]})
    big = df[df["population"] > 500_000]
    import numpy as _np

    from repro.frames import weldnp
    pop = weldnp.array(_np.asarray(big["population"].evaluate()))
    crime = weldnp.array(_np.asarray(big["crime"].evaluate()))
    return (pop * 0.1 + crime * 2.0).sum().item()


def run(emit, n=4_000_000):
    s = Suite(emit)
    d = make_crime_data(n)
    want = crime_index_native(d)

    us = time_fn(lambda: crime_index_native(d))
    s.record("fig3/native", us, baseline_of="fig3")

    def nofusion():
        obj = _weld_total(d).obj
        return Evaluate(obj, passes=None, optimize=False).value

    # warm the caches first so timing excludes compilation (paper reports
    # runtime; §7.8 reports compile separately)
    from repro.core.runtime import compile_and_run  # noqa: F401
    got = nofusion()
    assert abs(got - want) < 1e-6 * abs(want)
    us = time_fn(nofusion)
    s.record("fig3/weld_nofusion", us, vs="fig3")

    got = _weld_crosslib_cut(d)
    assert abs(got - want) < 1e-6 * abs(want)
    us = time_fn(lambda: _weld_crosslib_cut(d))
    s.record("fig3/weld_nocrosslib", us, vs="fig3")

    got = crime_index_weld(d)
    assert abs(got - want) < 1e-6 * abs(want)
    us = time_fn(lambda: crime_index_weld(d))
    s.record("fig3/weld", us, vs="fig3")
