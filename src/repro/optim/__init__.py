"""Optimizer substrate: AdamW (plain / weld-fused / pallas), schedules,
gradient clipping + accumulation, int8 error-feedback compression."""
from .adamw import adamw_init, adamw_update_tree, clip_by_global_norm  # noqa: F401
from .schedule import cosine_warmup  # noqa: F401
