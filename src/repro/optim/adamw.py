"""AdamW over parameter pytrees.

Three implementations of the same update (see benchmarks/bench_fused_adamw):
  * "jax"    — jnp elementwise chain (inside the jitted train step XLA
               fuses it; this is the production path)
  * "pallas" — the explicit fused VMEM kernel (kernels/fused_adamw.py)
  * "weld"   — the update chain expressed as Weld IR and fused by the
               paper's optimizer; demonstrates the paper's "within one
               library" speedup when the optimizer runs as a separate
               eager library (benchmarks only).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kops


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(grads)
    ))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
    ), gn


def _leaf_update_jax(p, g, m, v, lr, t, b1, b2, eps, wd):
    gf = g.astype(jnp.float32)
    pf = p.astype(jnp.float32)
    m_new = b1 * m + (1 - b1) * gf
    v_new = b2 * v + (1 - b2) * gf * gf
    c1 = 1 - b1 ** t
    c2 = 1 - b2 ** t
    upd = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps) + wd * pf
    return (pf - lr * upd).astype(p.dtype), m_new, v_new


def _leaf_update_pallas(p, g, m, v, lr, t, b1, b2, eps, wd):
    shp, dt = p.shape, p.dtype
    flat = lambda a: a.reshape(-1).astype(jnp.float32)
    pn, mn, vn = kops.adamw_update(
        flat(p), flat(g), flat(m), flat(v), lr, t,
        b1=b1, b2=b2, eps=eps, wd=wd, impl="interpret",
    )
    return pn.reshape(shp).astype(dt), mn.reshape(shp), vn.reshape(shp)


def adamw_update_tree(params, grads, state, lr, *, b1=0.9, b2=0.999,
                      eps=1e-8, wd=0.01, impl: str = "jax"):
    """Returns (new_params, new_state)."""
    t = (state["step"] + 1).astype(jnp.float32)
    leaf = _leaf_update_pallas if impl == "pallas" else _leaf_update_jax
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        pn, mn, vn = leaf(p, g, m, v, lr, t, b1, b2, eps, wd)
        new_p.append(pn)
        new_m.append(mn)
        new_v.append(vn)
    unf = functools.partial(jax.tree_util.tree_unflatten, treedef)
    return unf(new_p), {
        "m": unf(new_m), "v": unf(new_v), "step": state["step"] + 1,
    }


# ---------------------------------------------------------------------------
# Weld-expressed AdamW (the paper-native form; benchmarks only)
# ---------------------------------------------------------------------------


def adamw_update_weld(p, g, m, v, lr: float, t: float, b1=0.9, b2=0.999,
                      eps=1e-8, wd=0.01):
    """One flat-leaf AdamW step as a single fused Weld program.

    Eight logical elementwise passes fuse to ONE loop producing three
    outputs through a struct of builders (Listing 3's pattern at
    production scale)."""
    import numpy as np

    from ..core import ir, macros as M, wtypes as wt
    from ..core.lazy import Evaluate, NewWeldObject

    po = NewWeldObject(np.asarray(p, np.float64), None)
    go = NewWeldObject(np.asarray(g, np.float64), None)
    mo = NewWeldObject(np.asarray(m, np.float64), None)
    vo = NewWeldObject(np.asarray(v, np.float64), None)
    ids = {o.obj_id: ir.Ident(o.obj_id, o.weld_type())
           for o in (po, go, mo, vo)}
    pi, gi, mi, vi = ids.values()

    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t
    f = lambda x: ir.Literal(float(x), wt.F64)

    def body(pp, gg, mm, vv):
        m_new = ir.BinOp("+", ir.BinOp("*", f(b1), mm),
                         ir.BinOp("*", f(1 - b1), gg))
        v_new = ir.BinOp("+", ir.BinOp("*", f(b2), vv),
                         ir.BinOp("*", f(1 - b2), ir.BinOp("*", gg, gg)))
        mlet = ir.Ident(ir.fresh("mn"), wt.F64)
        vlet = ir.Ident(ir.fresh("vn"), wt.F64)
        upd = ir.BinOp(
            "+",
            ir.BinOp("/", ir.BinOp("/", mlet, f(c1)),
                     ir.BinOp("+", ir.UnaryOp(
                         "sqrt", ir.BinOp("/", vlet, f(c2))), f(eps))),
            ir.BinOp("*", f(wd), pp),
        )
        p_new = ir.BinOp("-", pp, ir.BinOp("*", f(lr), upd))
        return ir.Let(mlet.name, m_new, ir.Let(
            vlet.name, v_new,
            ir.MakeStruct((p_new, mlet, vlet))))

    st = wt.Struct((wt.F64, wt.F64, wt.F64, wt.F64))
    bt = wt.StructBuilder((
        wt.VecBuilder(wt.F64), wt.VecBuilder(wt.F64), wt.VecBuilder(wt.F64)))
    b = ir.Ident(ir.fresh("b"), bt)
    i = ir.Ident(ir.fresh("i"), wt.I64)
    x = ir.Ident(ir.fresh("x"), st)
    res = body(*[ir.GetField(x, k) for k in range(4)])
    out = ir.Ident(ir.fresh("o"), wt.Struct((wt.F64, wt.F64, wt.F64)))
    lam_body = ir.Let(
        out.name, res,
        ir.MakeStruct((
            ir.Merge(ir.GetField(b, 0), ir.GetField(out, 0)),
            ir.Merge(ir.GetField(b, 1), ir.GetField(out, 1)),
            ir.Merge(ir.GetField(b, 2), ir.GetField(out, 2)),
        )),
    )
    loop = ir.Result(ir.For(
        (ir.Iter(pi), ir.Iter(gi), ir.Iter(mi), ir.Iter(vi)),
        ir.MakeStruct((ir.NewBuilder(wt.VecBuilder(wt.F64)),) * 3),
        ir.Lambda((b, i, x), lam_body),
    ))
    obj = NewWeldObject([po, go, mo, vo], loop)
    out_p, out_m, out_v = Evaluate(obj).value
    return out_p, out_m, out_v
