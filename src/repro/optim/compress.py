"""Int8 error-feedback gradient compression for the cross-pod hop.

At 512+ chips the pod-interconnect (DCI) all-reduce is the scarcest
bandwidth; 4× compression with error feedback keeps convergence while
quartering the cross-pod bytes (DESIGN.md §5).  The within-pod reduction
stays full precision.

Usage (inside shard_map over the 'pod' axis):

    g_sync, err = compressed_psum(g_local, err, axis_name="pod")

`err` is carried in the optimizer state; the quantization residual is
re-added next step, so the compression bias telescopes instead of
accumulating.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jax.Array, err: jax.Array, axis_name: str):
    """Error-feedback int8 all-reduce over `axis_name`.
    Returns (mean-reduced gradient, new error buffer)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    sent = dequantize_int8(q, scale)
    new_err = corrected - sent
    # int8 payload on the wire (the all-gather moves int8, 4x fewer
    # bytes); each shard is dequantized with ITS OWN scale, so the
    # reduction is exact up to per-shard quantization error
    qs = jax.lax.all_gather(q, axis_name)                # (P, ...) int8
    scales = jax.lax.all_gather(scale, axis_name)        # (P,)
    n = qs.shape[0]
    bshape = (n,) + (1,) * (qs.ndim - 1)
    mean = jnp.sum(
        qs.astype(jnp.float32) * scales.reshape(bshape), axis=0
    ) / n
    return mean, new_err


def tree_compressed_psum(grads, errs, axis_name: str):
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(errs)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        m, ne = compressed_psum(g, e, axis_name)
        out_g.append(m.astype(g.dtype))
        out_e.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_e))


def init_error_buffers(grads):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)
