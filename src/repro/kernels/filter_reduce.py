"""Predicated filter+reduce kernel — the fused form of Listing 10.

    result(for(v, merger[+,0], (b,i,x) => if(p(x)) merge(b,x) else b))

TPU adaptation: the branch becomes a VPU select (predication is mandatory
on SPMD hardware), and the reduction happens block-wise in VMEM with a
running scalar accumulator across grid steps.  The predicate is supplied
as precomputed comparison bounds so one kernel serves Q6-style multi-column
conjunctions: keep = all(lo_k <= col_k < hi_k).

Block size: 8×1024 f32 = 32 KiB per column tile — several columns fit VMEM
(~16 MiB) with room for double buffering; the lane dim (1024) is a multiple
of the 128-wide VPU registers.  ``BLOCK`` is the default; the planner's
autotuner sweeps ``BLOCK_CANDIDATES`` per (kernel, dtype, size-bucket)
and bakes the winner into the plan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 1024
#: autotune grid — all 1024-lane multiples so every candidate stays
#: VPU-register aligned; small end bounds padding waste on short columns.
BLOCK_CANDIDATES = (1024, 8 * 1024, 32 * 1024)


def _kernel(x_ref, pred_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    keep = pred_ref[...]
    contrib = jnp.sum(jnp.where(keep, x, jnp.zeros_like(x)))
    o_ref[...] += contrib[None, None]


def filter_reduce_sum(x: jax.Array, pred: jax.Array, *,
                      block: int = BLOCK, interpret: bool = True) -> jax.Array:
    """sum(x[pred]) in one pass.  x: (n,) float; pred: (n,) bool.
    n is padded to a block multiple with pred=False."""
    n = x.shape[0]
    if n == 0:
        return jnp.zeros((), x.dtype)
    npad = (block - n % block) % block
    if npad:
        x = jnp.pad(x, (0, npad))
        pred = jnp.pad(pred, (0, npad))
    grid = (x.shape[0] // block,)
    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((1, 1), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        interpret=interpret,
    )(x, pred)
    return out[0, 0]


def _kernel_multi(vals_ref, pred_ref, o_ref):
    """Multi-aggregate form: A value rows share ONE predicate mask and
    one grid pass — the struct-of-mergers (weldrel ``agg``) case fused
    into a single launch instead of one kernel call per aggregate."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    vals = vals_ref[...]                      # (A, B)
    keep = pred_ref[...]                      # (B,)
    contrib = jnp.sum(
        jnp.where(keep[None, :], vals, jnp.zeros_like(vals)), axis=1
    )
    o_ref[...] += contrib[None, :]


def filter_reduce_sum_multi(vals: jax.Array, pred: jax.Array, *,
                            block: int = BLOCK,
                            interpret: bool = True) -> jax.Array:
    """Row-wise predicated sums: vals (A, n), pred (n,) -> (A,) where
    out[a] = sum(vals[a][pred]).  One pass; the predicate and the column
    tiles are loaded once for all A aggregates."""
    a, n = vals.shape
    if n == 0:
        return jnp.zeros((a,), vals.dtype)
    npad = (block - n % block) % block
    if npad:
        vals = jnp.pad(vals, ((0, 0), (0, npad)))
        pred = jnp.pad(pred, (0, npad))
    grid = (vals.shape[1] // block,)
    out = pl.pallas_call(
        _kernel_multi,
        out_shape=jax.ShapeDtypeStruct((1, a), vals.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((a, block), lambda i: (0, i)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, a), lambda i: (0, 0)),
        interpret=interpret,
    )(vals, pred)
    return out[0]


def _kernel_fused_pred(cols_ref, lo_ref, hi_ref, val_ref, o_ref):
    """Q6 shape: keep = AND_k(lo_k <= col_k < hi_k); sum val where keep."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    cols = cols_ref[...]          # (K, B)
    lo = lo_ref[...]              # (K, 1)
    hi = hi_ref[...]              # (K, 1)
    keep = jnp.all((cols >= lo) & (cols < hi), axis=0)   # (B,)
    v = val_ref[...]
    o_ref[...] += jnp.sum(jnp.where(keep, v, jnp.zeros_like(v)))[None, None]


def filter_reduce_q6(cols: jax.Array, lo: jax.Array, hi: jax.Array,
                     val: jax.Array, *, block: int = BLOCK,
                     interpret: bool = True) -> jax.Array:
    """cols: (K, n) predicate columns; lo/hi: (K,) bounds; val: (n,).
    Computes sum(val[all(lo<=cols<hi)]) in a single fused pass."""
    k, n = cols.shape
    npad = (block - n % block) % block
    if npad:
        cols = jnp.pad(cols, ((0, 0), (0, npad)), constant_values=jnp.inf)
        val = jnp.pad(val, (0, npad))
    grid = (cols.shape[1] // block,)
    out = pl.pallas_call(
        _kernel_fused_pred,
        out_shape=jax.ShapeDtypeStruct((1, 1), val.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, block), lambda i: (0, i)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        interpret=interpret,
    )(cols, lo.reshape(k, 1), hi.reshape(k, 1), val)
    return out[0, 0]
