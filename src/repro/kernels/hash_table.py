"""Open-addressing hash-to-slot kernel — dictmerger builds with sparse keys.

The dense group-by route (``segment_reduce``) requires int keys in
``[0, capacity)``; this kernel lifts that restriction.  It assigns every
input key a *slot* in a VMEM-resident open-addressing table (linear
probing, Fibonacci hashing), so rows with equal keys share a slot and
distinct keys get distinct slots.  Downstream value accumulation is then
an ordinary segment reduction over the slot ids — the existing one-hot
MXU ``segment_sum`` kernels — followed by a sort-based compaction into
the backend's sorted-front-packed dict layout.

TPU adaptation: inserts are inherently serial (a later row must observe
an earlier row's insert), so the kernel walks each row block with a
``fori_loop`` while the grid streams blocks sequentially — the table
lives in the output ref and persists across grid steps, exactly like the
running accumulator in ``filter_reduce``.  The slot id per input row is
emitted block-wise so the (parallel) segment reduction can consume it.

Slot numbering is implementation-defined: the Pallas kernel yields hash
positions, the jnp oracle (``ref.hash_to_slot``) yields ascending-key
compact ids.  Callers must only rely on the slots/table contract below,
which is what ``kernelplan.registry`` normalizes into a sorted dict.

Contract (shared with ``ref.hash_to_slot``):

* ``keys`` are i64 (packed key space; see jaxgen ``_pack_keys``); rows
  equal to ``EMPTY`` are padding/masked and get slot ``cap_table``;
* returns ``(slots, table_keys, used)`` with ``slots[i]`` in
  ``[0, cap_table]`` (``cap_table`` = parked), ``table_keys[slot]`` the
  key occupying a slot (``EMPTY`` when free), and ``used`` the number of
  distinct keys inserted.  A full table drops rows but then
  ``used == cap_table``, which callers size (``cap_table >= 2*capacity``)
  so overflow is always detectable as ``used > capacity``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

#: sentinel for "no key": reserved, never a valid packed key in practice
#: (single-column int keys keep their full value; multi-column keys pack
#: 32 bits per column, so hitting INT64_MIN needs a -2^31 leading key —
#: the build adapter detects the clash and poisons the dict rather than
#: conflate).
EMPTY = int(np.iinfo(np.int64).min)

#: largest dict capacity the hash route serves; the table itself is
#: 2*capacity rounded up to a power of two (load factor <= 0.5), so the
#: VMEM key tile tops out at 2^17 * 8 B = 1 MiB.
MAX_CAP = 65536

#: Fibonacci multiplicative hashing constant (golden-ratio reciprocal).
_GOLD = np.uint64(0x9E3779B97F4A7C15)

BLOCK_N = 256
#: autotune grid for the row block: bigger blocks amortize grid steps,
#: smaller ones bound the per-step serial insert chain.
BLOCK_CANDIDATES = (128, 256, 512, 1024)


def table_size(capacity: int) -> int:
    """Power-of-two open-addressing table for `capacity` distinct keys."""
    c = 16
    while c < 2 * capacity:
        c <<= 1
    return c


def _hash0(k, cap_table: int):
    """Initial probe position: high bits of the Fibonacci product."""
    lg = int(cap_table).bit_length() - 1
    ku = k.astype(jnp.uint64) * _GOLD
    return (ku >> jnp.uint64(64 - lg)).astype(jnp.int32)


def _kernel(keys_ref, slots_ref, table_ref, used_ref, *, cap_table: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        table_ref[...] = jnp.full_like(table_ref, EMPTY)
        used_ref[...] = jnp.zeros_like(used_ref)

    keys = keys_ref[...]
    block = keys.shape[0]
    mask = jnp.int32(cap_table - 1)

    def insert(j, used):
        k = keys[j]
        valid = k != EMPTY
        h0 = _hash0(k, cap_table)

        def probe_cond(s):
            t, slot, done = s
            return jnp.logical_not(done) & (t < cap_table)

        def probe_body(s):
            t, slot, done = s
            cur = pl.load(table_ref, (pl.ds(slot, 1),))[0]
            hit = (cur == k) | (cur == EMPTY)
            nxt = jnp.where(hit, slot, (slot + 1) & mask)
            return t + 1, nxt, hit

        _, slot, done = jax.lax.while_loop(
            probe_cond, probe_body, (jnp.int32(0), h0, ~valid)
        )
        cur = pl.load(table_ref, (pl.ds(slot, 1),))[0]
        do_store = valid & done & (cur == EMPTY)
        pl.store(table_ref, (pl.ds(slot, 1),),
                 jnp.where(do_store, k, cur)[None])
        final = jnp.where(valid & done, slot, jnp.int32(cap_table))
        pl.store(slots_ref, (pl.ds(j, 1),), final[None])
        return used + jnp.where(do_store, jnp.int32(1), jnp.int32(0))

    used = jax.lax.fori_loop(0, block, insert, jnp.int32(0))
    used_ref[...] += used[None, None]


def hash_to_slot(keys: jax.Array, cap_table: int, *, block: int = BLOCK_N,
                 interpret: bool = True):
    """Assign an open-addressing slot to every key; see module contract."""
    assert cap_table & (cap_table - 1) == 0, "table size must be pow2"
    n = keys.shape[0]
    if n == 0:
        return (jnp.zeros((0,), jnp.int32),
                jnp.full((cap_table,), EMPTY, jnp.int64),
                jnp.zeros((), jnp.int32))
    npad = (block - n % block) % block
    if npad:
        keys = jnp.pad(keys, (0, npad), constant_values=EMPTY)
    grid = (keys.shape[0] // block,)
    slots, table, used = pl.pallas_call(
        functools.partial(_kernel, cap_table=cap_table),
        out_shape=(
            jax.ShapeDtypeStruct((keys.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((cap_table,), jnp.int64),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ),
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=(
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((cap_table,), lambda i: (0,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ),
        interpret=interpret,
    )(keys.astype(jnp.int64))
    return slots[:n], table, used[0, 0]
