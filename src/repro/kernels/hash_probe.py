"""Dictionary probe kernel — the gather side of the hash-join plan.

Given a dict in the backend's sorted-front-packed column layout
(``WDict``: keys ascending for the first ``count`` slots), find each
query key's slot and whether it exists.  The TPU-native strategy mirrors
``segment_reduce``: instead of a divergent binary search per lane, each
query block builds a **one-hot membership matrix** against the whole
VMEM-resident key tile

    hits[B, C] = (queries[:, None] == table[None, :]) & (iota_C < count)

and reduces it on the VPU — ``found = any(hits, axis=1)``,
``pos = argmax(hits, axis=1)`` (keys are unique, so at most one lane
matches).  C is bounded by the dict capacity (<= ``hash_table.MAX_CAP``)
so the comparison tile fits VMEM alongside the query block.

The value gather itself happens outside the kernel (``vals[pos]``): the
positions serve any value dtype/struct without specializing the kernel.
That split is what lets weldrel's horizontally fused join probe reuse
ONE launch for every output column — inner joins front-pack by the
found mask, left joins keep every row and select per-dtype fills where
``found`` is false, anti joins front-pack by its negation — all from
the same ``(pos, found)`` pair (``kernelplan.registry``,
``_exec_hash_probe_fused``).  Multi-column keys arrive pre-packed (32
bits per column) in the same i64 key space the build side uses.

Contract (shared with ``ref.dict_probe``): queries and table keys live
in the packed key space; returns ``(pos, found)`` with ``pos`` int32,
zeroed where not found.

``group_probe`` is the m:n-join variant: the SAME hits tile also
one-hot-gathers each matching group's fan-out (CSR ``offsets`` diffs),
so membership, slot positions, and the expansion's match-count pass
are one launch; the expansion itself (exclusive scan + repeat/gather)
runs outside, shared by every output column
(``kernelplan.registry._exec_group_probe``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 512
#: autotune grid for the query block: the hits tile is block x capacity,
#: so small blocks keep large-capacity dicts inside VMEM.
BLOCK_CANDIDATES = (128, 256, 512, 1024)


def _kernel(q_ref, keys_ref, cnt_ref, pos_ref, found_ref, *, cap: int):
    q = q_ref[...]                               # (B,)
    keys = keys_ref[...]                         # (C,)
    cnt = cnt_ref[0, 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], cap), 1)
    hits = (q[:, None] == keys[None, :]) & (iota < cnt)
    found = jnp.any(hits, axis=1)
    pos = jnp.argmax(hits, axis=1).astype(jnp.int32)
    found_ref[...] = found
    pos_ref[...] = jnp.where(found, pos, jnp.int32(0))


def _group_kernel(q_ref, keys_ref, sizes_ref, cnt_ref, pos_ref, found_ref,
                  size_ref, *, cap: int):
    q = q_ref[...]                               # (B,)
    keys = keys_ref[...]                         # (C,)
    sizes = sizes_ref[...]                       # (C,) group fan-outs
    cnt = cnt_ref[0, 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], cap), 1)
    hits = (q[:, None] == keys[None, :]) & (iota < cnt)
    found = jnp.any(hits, axis=1)
    pos = jnp.argmax(hits, axis=1).astype(jnp.int32)
    # one-hot gather of the matching group's size on the VPU: the SAME
    # hits tile serves membership, position, and the match-count pass of
    # the m:n expansion — one launch, three outputs
    size = jnp.sum(jnp.where(hits, sizes[None, :], jnp.int32(0)), axis=1)
    found_ref[...] = found
    pos_ref[...] = jnp.where(found, pos, jnp.int32(0))
    size_ref[...] = jnp.where(found, size.astype(jnp.int32), jnp.int32(0))


def group_probe(table_keys: jax.Array, offsets: jax.Array, count,
                queries: jax.Array, *, block: int = BLOCK_N,
                interpret: bool = True):
    """(pos, found, sizes) per query against a groupbuilder's sorted
    key column + CSR offsets — the membership AND match-count pass of
    the m:n join expansion in ONE launch (``sizes`` is 0 on a miss).
    Contract shared with ``ref.group_probe``."""
    cap = table_keys.shape[0]
    n = queries.shape[0]
    if n == 0 or cap == 0:
        z = jnp.zeros((n,), jnp.int32)
        return z, jnp.zeros((n,), bool), z
    sizes = (offsets[1:] - offsets[:-1]).astype(jnp.int32)
    npad = (block - n % block) % block
    if npad:
        queries = jnp.pad(queries, (0, npad))
    grid = (queries.shape[0] // block,)
    cnt = jnp.asarray(count, jnp.int32).reshape(1, 1)
    pos, found, size = pl.pallas_call(
        functools.partial(_group_kernel, cap=cap),
        out_shape=(
            jax.ShapeDtypeStruct((queries.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((queries.shape[0],), jnp.bool_),
            jax.ShapeDtypeStruct((queries.shape[0],), jnp.int32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((cap,), lambda i: (0,)),
            pl.BlockSpec((cap,), lambda i: (0,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ),
        interpret=interpret,
    )(queries.astype(jnp.int64), table_keys.astype(jnp.int64), sizes, cnt)
    return pos[:n], found[:n], size[:n]


def dict_probe(table_keys: jax.Array, count, queries: jax.Array, *,
               block: int = BLOCK_N, interpret: bool = True):
    """pos/found per query against sorted-front-packed dict keys."""
    cap = table_keys.shape[0]
    n = queries.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.int32), jnp.zeros((0,), bool)
    npad = (block - n % block) % block
    if npad:
        queries = jnp.pad(queries, (0, npad))
    grid = (queries.shape[0] // block,)
    cnt = jnp.asarray(count, jnp.int32).reshape(1, 1)
    pos, found = pl.pallas_call(
        functools.partial(_kernel, cap=cap),
        out_shape=(
            jax.ShapeDtypeStruct((queries.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((queries.shape[0],), jnp.bool_),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((cap,), lambda i: (0,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ),
        interpret=interpret,
    )(queries.astype(jnp.int64), table_keys.astype(jnp.int64), cnt)
    return pos[:n], found[:n]
