"""Loop tiling (paper Table 3) as explicit BlockSpec VMEM tiling.

The paper tiles nested loops so a block of the inner vector stays in
cache across outer iterations (its Listing 4 example: reuse blocks of x
across rows of v).  On TPU the cache is software-managed VMEM and the
compute unit is the 128×128 MXU, so the tiled form is a blocked matmul:

    C[i,j] = sum_k A[i,k] @ B[k,j]

with (bm, bk) × (bk, bn) tiles resident in VMEM and a (bm, bn) f32
accumulator carried across the k grid dimension.  Tile sizes default to
MXU-aligned 256/512 multiples; (256×512 + 512×256 + 256×256) f32 tiles =
1.25 MiB in flight, leaving VMEM headroom for double-buffered prefetch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


#: autotune grids per tile dim — MXU-aligned (multiples of 128); the
#: planner's autotuner sweeps the cross product and bakes the winner.
BM_CANDIDATES = (128, 256)
BN_CANDIDATES = (128, 256)
BK_CANDIDATES = (256, 512)


def _kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def tiled_matmul(a: jax.Array, b: jax.Array, *, bm: int = 256, bn: int = 256,
                 bk: int = 512, interpret: bool = True) -> jax.Array:
    """C = A @ B with explicit VMEM tiling.  Shapes padded to tiles."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    pm, pk, pn = (-m) % bm, (-k) % bk, (-n) % bn
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    gm, gn, gk = a.shape[0] // bm, b.shape[1] // bn, a.shape[1] // bk
    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((a.shape[0], b.shape[1]), a.dtype),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]
