"""Fused AdamW update — the framework's weld-fused optimizer hot-spot.

Plain AdamW is ~10 elementwise ops per parameter: executed per-op (the
function-call interface) that is 10 HBM round-trips per step.  Expressed
as one Weld loop it fuses to a single pass; this kernel is that fused
pass as an explicit Pallas kernel: reads (p, g, m, v) tiles into VMEM
once, performs the whole update chain on the VPU, writes (p, m, v) once —
4 reads + 3 writes instead of ~20 accesses, i.e. ~3x less HBM traffic for
a purely memory-bound step.

Block: 4 arrays × 64 KiB f32 tiles (16384 lanes) = 512 KiB VMEM in-flight.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 16 * 1024
#: autotune grid — 4 arrays in flight per block, so the top end (64 KiB
#: lanes = 1 MiB f32 in-flight) still leaves VMEM double-buffer headroom.
BLOCK_CANDIDATES = (4 * 1024, 16 * 1024, 64 * 1024)


def _kernel(p_ref, g_ref, m_ref, v_ref, lr_ref, t_ref,
            po_ref, mo_ref, vo_ref, *,
            b1: float, b2: float, eps: float, wd: float):
    p = p_ref[...]
    g = g_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    lr = lr_ref[0]
    t = t_ref[0]

    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    # bias correction
    c1 = 1.0 - jnp.power(jnp.float32(b1), t)
    c2 = 1.0 - jnp.power(jnp.float32(b2), t)
    m_hat = m_new / c1
    v_hat = v_new / c2
    update = m_hat / (jnp.sqrt(v_hat) + eps) + wd * p
    po_ref[...] = p - lr * update
    mo_ref[...] = m_new
    vo_ref[...] = v_new


def adamw_update(p: jax.Array, g: jax.Array, m: jax.Array, v: jax.Array,
                 lr, step, *, b1: float = 0.9, b2: float = 0.999,
                 eps: float = 1e-8, wd: float = 0.01,
                 block: int = BLOCK, interpret: bool = True):
    """One fused AdamW step over a flat f32 parameter shard.
    Returns (p_new, m_new, v_new)."""
    n = p.shape[0]
    npad = (block - n % block) % block
    if npad:
        p, g, m, v = (jnp.pad(a, (0, npad)) for a in (p, g, m, v))
    grid = (p.shape[0] // block,)
    lr = jnp.asarray(lr, jnp.float32).reshape(1)
    t = jnp.asarray(step, jnp.float32).reshape(1)
    shp = jax.ShapeDtypeStruct(p.shape, p.dtype)
    po, mo, vo = pl.pallas_call(
        functools.partial(_kernel, b1=b1, b2=b2, eps=eps, wd=wd),
        out_shape=(shp, shp, shp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ),
        interpret=interpret,
    )(p, g, m, v, lr, t)
    if npad:
        po, mo, vo = po[:n], mo[:n], vo[:n]
    return po, mo, vo
