"""Group (CSR) build kernel — groupbuilder with sparse keys.

The m:n hash-join build side stores *every* build row under its key
(key -> growing vector of row ids), not one accumulated value.  The
TPU-native layout is CSR: one ``offsets`` array over ascending-key
compact slots plus the row payloads sorted by slot — variable-length
groups with no pointer chasing, and the probe side can fetch a group's
fan-out as ``offsets[s+1] - offsets[s]``.

The build composes three steps:

1. **hash-to-slot** (reused from :mod:`.hash_table`): the open-addressing
   Pallas kernel assigns every row a table slot, so rows with equal
   packed keys share a slot;
2. **rank compaction** (jnp glue, same as the dictmerger hash route):
   table slots are renumbered into ascending-key compact ids, matching
   the backend's sorted-front-packed dict layout;
3. **slot histogram** (the Pallas kernel in this module): per-slot row
   counts accumulated in a VMEM-resident table, then an exclusive scan
   into the CSR ``offsets``.

Like the insert chain, the histogram is inherently random-access, so the
kernel walks each row block with a ``fori_loop`` while the grid streams
blocks sequentially and the counts tile persists in the output ref —
the same serial-grid pattern as ``hash_table``.

Contract (shared with ``ref.group_build``):

* ``keys`` are i64 (packed key space); rows equal to ``EMPTY`` are
  padding/masked and park at slot ``capacity``;
* returns ``(cslots, offsets, used)``: ``cslots[i]`` in ``[0, capacity]``
  is row ``i``'s ascending-key compact slot (``capacity`` = parked),
  ``offsets`` is the ``(capacity+1,)`` int32 CSR boundary array over
  the first ``used`` slots, and ``used`` counts distinct keys inserted.
  ``used > capacity`` signals overflow; callers must poison then (which
  keys survive into the truncated slots is implementation-defined —
  the ref oracle keeps the smallest, the hash table whatever fit).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .hash_table import EMPTY, hash_to_slot, table_size

BLOCK_N = 256
#: autotune grid for the row block (shared shape with hash_table: the
#: serial insert/count chains bound the per-step latency).
BLOCK_CANDIDATES = (128, 256, 512, 1024)


def _hist_kernel(slots_ref, cnt_ref, *, nslots: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    slots = slots_ref[...]

    def bump(j, _):
        s = slots[j]
        cur = pl.load(cnt_ref, (pl.ds(s, 1),))[0]
        pl.store(cnt_ref, (pl.ds(s, 1),), (cur + 1)[None])
        return 0

    jax.lax.fori_loop(0, slots.shape[0], bump, 0)


def slot_hist(slots: jax.Array, num_slots: int, *, block: int = BLOCK_N,
              interpret: bool = True) -> jax.Array:
    """Per-slot row counts: ``out[s] = sum(slots == s)``; slots int32 in
    ``[0, num_slots)``.  Serial accumulation in a VMEM counts tile."""
    n = slots.shape[0]
    if n == 0:
        return jnp.zeros((num_slots,), jnp.int32)
    npad = (block - n % block) % block
    if npad:
        # padding parks in the last slot, which group_build never reads
        slots = jnp.pad(slots, (0, npad), constant_values=num_slots - 1)
    grid = (slots.shape[0] // block,)
    return pl.pallas_call(
        functools.partial(_hist_kernel, nslots=num_slots),
        out_shape=jax.ShapeDtypeStruct((num_slots,), jnp.int32),
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((num_slots,), lambda i: (0,)),
        interpret=interpret,
    )(slots.astype(jnp.int32))


def group_build(keys: jax.Array, capacity: int, *, block: int = BLOCK_N,
                interpret: bool = True):
    """CSR group build over packed i64 keys; see the module contract."""
    cap = int(capacity)
    ctab = table_size(cap)
    n = keys.shape[0]
    slots, table, used = hash_to_slot(keys, ctab, block=block,
                                      interpret=interpret)
    if n == 0:
        return (jnp.zeros((0,), jnp.int32),
                jnp.zeros((cap + 1,), jnp.int32),
                used)
    # table slot -> ascending-key compact id (identical renumbering to
    # the dictmerger hash route, so probes see the sorted layout)
    big = jnp.iinfo(jnp.int64).max
    tsort = jnp.where(table == EMPTY, big, table)
    order = jnp.argsort(tsort)
    rank = jnp.zeros((ctab,), jnp.int32).at[order].set(
        jnp.arange(ctab, dtype=jnp.int32))
    cslots = jnp.where(slots < ctab, rank[jnp.clip(slots, 0, ctab - 1)],
                       jnp.int32(cap))
    cslots = jnp.where(cslots < cap, cslots, jnp.int32(cap))
    counts = slot_hist(cslots, cap + 1, block=block,
                       interpret=interpret)[:cap]
    offsets = jnp.concatenate([
        jnp.zeros((1,), jnp.int32),
        jnp.cumsum(counts).astype(jnp.int32),
    ])
    return cslots, offsets, used
