"""Public jit'd wrappers for the Pallas kernels.

``impl`` selects the execution path:
  * "pallas"    — the Pallas kernel compiled for the accelerator
  * "interpret" — the Pallas kernel body interpreted on CPU (validation)
  * "ref"       — the pure-jnp oracle (CPU benchmarks, dry-run lowering)
Default on this CPU container is "ref"; on TPU the launcher flips the
default to "pallas".  Resolution happens OUTSIDE jit so flipping the
default always takes effect (impl is a static argument of the inner jit).
"""
from __future__ import annotations

import functools
from typing import Literal, Optional

import jax

from . import filter_reduce as _fr
from . import flash_attention as _fa
from . import fused_adamw as _aw
from . import map_chain as _mc
from . import ref as _ref
from . import segment_reduce as _sr
from . import tiled_matmul as _tm

Impl = Literal["pallas", "interpret", "ref"]

DEFAULT_IMPL: Impl = "ref"


def set_default_impl(impl: Impl) -> None:
    global DEFAULT_IMPL
    DEFAULT_IMPL = impl


def _resolve(impl: Optional[str]) -> str:
    return DEFAULT_IMPL if impl is None else impl


# -- filter+reduce -------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("impl",))
def _frs(x, pred, impl):
    if impl == "ref":
        return _ref.filter_reduce_sum(x, pred)
    return _fr.filter_reduce_sum(x, pred, interpret=(impl == "interpret"))


def filter_reduce_sum(x, pred, impl: Optional[Impl] = None):
    return _frs(x, pred, impl=_resolve(impl))


@functools.partial(jax.jit, static_argnames=("impl",))
def _frq6(cols, lo, hi, val, impl):
    if impl == "ref":
        return _ref.filter_reduce_q6(cols, lo, hi, val)
    return _fr.filter_reduce_q6(cols, lo, hi, val,
                                interpret=(impl == "interpret"))


def filter_reduce_q6(cols, lo, hi, val, impl: Optional[Impl] = None):
    return _frq6(cols, lo, hi, val, impl=_resolve(impl))


# -- segment reduce -------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("num_segments", "impl"))
def _ss(seg_ids, vals, num_segments, impl):
    if impl == "ref":
        return _ref.segment_sum(seg_ids, vals, num_segments)
    return _sr.segment_sum(seg_ids, vals, num_segments,
                           interpret=(impl == "interpret"))


def segment_sum(seg_ids, vals, num_segments: int,
                impl: Optional[Impl] = None):
    impl = _resolve(impl)
    if num_segments > _sr.MAX_K:
        impl = "ref"
    return _ss(seg_ids, vals, num_segments=num_segments, impl=impl)


@functools.partial(jax.jit, static_argnames=("num_segments", "impl"))
def _ssv(seg_ids, vals, num_segments, impl):
    if impl == "ref":
        return _ref.segment_sum_vectors(seg_ids, vals, num_segments)
    return _sr.segment_sum_vectors(seg_ids, vals, num_segments,
                                   interpret=(impl == "interpret"))


def segment_sum_vectors(seg_ids, vals, num_segments: int,
                        impl: Optional[Impl] = None):
    impl = _resolve(impl)
    if num_segments > _sr.MAX_K:
        impl = "ref"
    return _ssv(seg_ids, vals, num_segments=num_segments, impl=impl)


# -- fused adamw ----------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps", "wd", "impl"))
def _adamw(p, g, m, v, lr, step, b1, b2, eps, wd, impl):
    kw = dict(b1=b1, b2=b2, eps=eps, wd=wd)
    if impl == "ref":
        return _ref.adamw_update(p, g, m, v, lr, step, **kw)
    return _aw.adamw_update(p, g, m, v, lr, step,
                            interpret=(impl == "interpret"), **kw)


def adamw_update(p, g, m, v, lr, step, b1=0.9, b2=0.999, eps=1e-8, wd=0.01,
                 impl: Optional[Impl] = None):
    return _adamw(p, g, m, v, lr, step, b1=b1, b2=b2, eps=eps, wd=wd,
                  impl=_resolve(impl))


# -- tiled matmul -----------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("impl",))
def _mm(a, b, impl):
    if impl == "ref":
        return _ref.tiled_matmul(a, b)
    return _tm.tiled_matmul(a, b, interpret=(impl == "interpret"))


def matmul(a, b, impl: Optional[Impl] = None):
    return _mm(a, b, impl=_resolve(impl))


# -- fused elementwise map chain --------------------------------------------------


def map_elementwise(fn, arrays, impl: Optional[Impl] = None):
    """Apply a staged elementwise body to 1-D columns in one fused pass.

    ``fn`` is a jnp-traceable callable (built by the kernel planner from
    IR), so there is no outer jit here — the caller is always inside the
    program's jit and the kernel inlines into its trace.
    """
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.map_elementwise(fn, arrays)
    return _mc.map_elementwise(fn, arrays, interpret=(impl == "interpret"))


# -- attention --------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("causal", "group", "scale", "impl", "chunk", "unroll"),
)
def _attn(q, k, v, causal, group, scale, chunk, unroll, impl):
    if impl == "ref":
        return _ref.chunked_attention(q, k, v, causal=causal, group=group,
                                      scale=scale, chunk=chunk,
                                      unroll=unroll)
    return _fa.flash_attention(q, k, v, causal=causal, group=group,
                               scale=scale, interpret=(impl == "interpret"))


def attention(q, k, v, causal: bool = True, group: int = 1, scale=None,
              chunk: int = 1024, unroll: bool = False,
              impl: Optional[Impl] = None):
    return _attn(q, k, v, causal=causal, group=group, scale=scale,
                 chunk=chunk, unroll=unroll, impl=_resolve(impl))
