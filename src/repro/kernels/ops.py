"""Public jit'd wrappers for the Pallas kernels.

``impl`` selects the execution path:
  * "pallas"    — the Pallas kernel compiled for the accelerator
  * "interpret" — the Pallas kernel body interpreted on CPU (validation)
  * "ref"       — the pure-jnp oracle (CPU benchmarks, dry-run lowering)
Default on this CPU container is "ref"; on TPU the launcher flips the
default to "pallas".  Resolution happens OUTSIDE jit so flipping the
default always takes effect (impl is a static argument of the inner jit).

Block sizes are tunable: every entry takes an optional block override
(``block=``, or ``bm``/``bn``/``bk`` for the matmul) resolved to the
kernel module's default when omitted.  The kernel planner's autotuner
(``repro.core.kernelplan.autotune``) sweeps each module's
``*_CANDIDATES`` grid and passes the per-(dtype, size-bucket) winner
through these knobs; the ref oracle ignores them by construction.
"""
from __future__ import annotations

import functools
from typing import Literal, Optional

import jax

from . import filter_reduce as _fr
from . import flash_attention as _fa
from . import fused_adamw as _aw
from . import group_build as _gb
from . import hash_probe as _hp
from . import hash_table as _ht
from . import map_chain as _mc
from . import ref as _ref
from . import segment_reduce as _sr
from . import tiled_matmul as _tm

Impl = Literal["pallas", "interpret", "ref"]

DEFAULT_IMPL: Impl = "ref"


def set_default_impl(impl: Impl) -> None:
    global DEFAULT_IMPL
    DEFAULT_IMPL = impl


def _resolve(impl: Optional[str]) -> str:
    return DEFAULT_IMPL if impl is None else impl


# -- filter+reduce -------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("impl", "block"))
def _frs(x, pred, impl, block):
    if impl == "ref":
        return _ref.filter_reduce_sum(x, pred)
    return _fr.filter_reduce_sum(x, pred, block=block,
                                 interpret=(impl == "interpret"))


def filter_reduce_sum(x, pred, impl: Optional[Impl] = None,
                      block: Optional[int] = None):
    return _frs(x, pred, impl=_resolve(impl), block=block or _fr.BLOCK)


@functools.partial(jax.jit, static_argnames=("impl", "block"))
def _frsm(vals, pred, impl, block):
    if impl == "ref":
        return _ref.filter_reduce_sum_multi(vals, pred)
    return _fr.filter_reduce_sum_multi(vals, pred, block=block,
                                       interpret=(impl == "interpret"))


def filter_reduce_sum_multi(vals, pred, impl: Optional[Impl] = None,
                            block: Optional[int] = None):
    """Predicated row sums: vals (A, n) + pred (n,) -> (A,) in ONE pass
    (the multi-aggregate fusion of filter_reduce_sum)."""
    return _frsm(vals, pred, impl=_resolve(impl), block=block or _fr.BLOCK)


@functools.partial(jax.jit, static_argnames=("impl", "block"))
def _frq6(cols, lo, hi, val, impl, block):
    if impl == "ref":
        return _ref.filter_reduce_q6(cols, lo, hi, val)
    return _fr.filter_reduce_q6(cols, lo, hi, val, block=block,
                                interpret=(impl == "interpret"))


def filter_reduce_q6(cols, lo, hi, val, impl: Optional[Impl] = None,
                     block: Optional[int] = None):
    return _frq6(cols, lo, hi, val, impl=_resolve(impl),
                 block=block or _fr.BLOCK)


# -- segment reduce -------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("num_segments", "impl", "block"))
def _ss(seg_ids, vals, num_segments, impl, block):
    if impl == "ref":
        return _ref.segment_sum(seg_ids, vals, num_segments)
    return _sr.segment_sum(seg_ids, vals, num_segments, block=block,
                           interpret=(impl == "interpret"))


def segment_sum(seg_ids, vals, num_segments: int,
                impl: Optional[Impl] = None, block: Optional[int] = None):
    impl = _resolve(impl)
    if num_segments > _sr.MAX_K:
        impl = "ref"
    return _ss(seg_ids, vals, num_segments=num_segments, impl=impl,
               block=block or _sr.BLOCK_N)


@functools.partial(jax.jit, static_argnames=("num_segments", "impl", "block"))
def _ssv(seg_ids, vals, num_segments, impl, block):
    if impl == "ref":
        return _ref.segment_sum_vectors(seg_ids, vals, num_segments)
    return _sr.segment_sum_vectors(seg_ids, vals, num_segments, block=block,
                                   interpret=(impl == "interpret"))


def segment_sum_vectors(seg_ids, vals, num_segments: int,
                        impl: Optional[Impl] = None,
                        block: Optional[int] = None):
    impl = _resolve(impl)
    if num_segments > _sr.MAX_K:
        impl = "ref"
    return _ssv(seg_ids, vals, num_segments=num_segments, impl=impl,
                block=block or 256)


# -- dict build / probe (hash-join route) -----------------------------------------


@functools.partial(jax.jit, static_argnames=("cap_table", "impl", "block"))
def _hts(keys, cap_table, impl, block):
    if impl == "ref":
        return _ref.hash_to_slot(keys, cap_table)
    return _ht.hash_to_slot(keys, cap_table, block=block,
                            interpret=(impl == "interpret"))


def hash_to_slot(keys, cap_table: int, impl: Optional[Impl] = None,
                 block: Optional[int] = None):
    """Open-addressing slot assignment for i64 (packed) keys; rows equal
    to ``hash_table.EMPTY`` park at slot ``cap_table``.  Returns
    ``(slots, table_keys, used)`` — see kernels/hash_table.py."""
    return _hts(keys, cap_table=cap_table, impl=_resolve(impl),
                block=block or _ht.BLOCK_N)


@functools.partial(jax.jit, static_argnames=("impl", "block"))
def _dp(table_keys, count, queries, impl, block):
    if impl == "ref":
        return _ref.dict_probe(table_keys, count, queries)
    return _hp.dict_probe(table_keys, count, queries, block=block,
                          interpret=(impl == "interpret"))


def dict_probe(table_keys, count, queries, impl: Optional[Impl] = None,
               block: Optional[int] = None):
    """(pos, found) per query against a sorted-front-packed dict key
    column; ``pos`` is zeroed where not found."""
    return _dp(table_keys, count, queries, impl=_resolve(impl),
               block=block or _hp.BLOCK_N)


# -- group build / probe (m:n hash-join route) ------------------------------------


@functools.partial(jax.jit, static_argnames=("capacity", "impl", "block"))
def _gbd(keys, capacity, impl, block):
    if impl == "ref":
        return _ref.group_build(keys, capacity)
    return _gb.group_build(keys, capacity, block=block,
                           interpret=(impl == "interpret"))


def group_build(keys, capacity: int, impl: Optional[Impl] = None,
                block: Optional[int] = None):
    """CSR group build over i64 (packed) keys: rows with equal keys share
    an ascending-key compact slot.  Returns ``(cslots, offsets, used)``
    — see kernels/group_build.py for the contract."""
    return _gbd(keys, capacity=capacity, impl=_resolve(impl),
                block=block or _gb.BLOCK_N)


@functools.partial(jax.jit, static_argnames=("impl", "block"))
def _gpr(table_keys, offsets, count, queries, impl, block):
    if impl == "ref":
        return _ref.group_probe(table_keys, offsets, count, queries)
    return _hp.group_probe(table_keys, offsets, count, queries, block=block,
                           interpret=(impl == "interpret"))


def group_probe(table_keys, offsets, count, queries,
                impl: Optional[Impl] = None, block: Optional[int] = None):
    """(pos, found, sizes) per query against a groupbuilder's sorted key
    column + CSR offsets — membership and the m:n expansion's
    match-count pass in one launch; ``sizes`` is 0 where not found."""
    return _gpr(table_keys, offsets, count, queries, impl=_resolve(impl),
                block=block or _hp.BLOCK_N)


# -- fused adamw ----------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("b1", "b2", "eps", "wd", "impl", "block"))
def _adamw(p, g, m, v, lr, step, b1, b2, eps, wd, impl, block):
    kw = dict(b1=b1, b2=b2, eps=eps, wd=wd)
    if impl == "ref":
        return _ref.adamw_update(p, g, m, v, lr, step, **kw)
    return _aw.adamw_update(p, g, m, v, lr, step, block=block,
                            interpret=(impl == "interpret"), **kw)


def adamw_update(p, g, m, v, lr, step, b1=0.9, b2=0.999, eps=1e-8, wd=0.01,
                 impl: Optional[Impl] = None, block: Optional[int] = None):
    return _adamw(p, g, m, v, lr, step, b1=b1, b2=b2, eps=eps, wd=wd,
                  impl=_resolve(impl), block=block or _aw.BLOCK)


# -- tiled matmul -----------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("impl", "bm", "bn", "bk"))
def _mm(a, b, impl, bm, bn, bk):
    if impl == "ref":
        return _ref.tiled_matmul(a, b)
    return _tm.tiled_matmul(a, b, bm=bm, bn=bn, bk=bk,
                            interpret=(impl == "interpret"))


def matmul(a, b, impl: Optional[Impl] = None, bm: Optional[int] = None,
           bn: Optional[int] = None, bk: Optional[int] = None):
    return _mm(a, b, impl=_resolve(impl), bm=bm or 256, bn=bn or 256,
               bk=bk or 512)


# -- fused elementwise map chain --------------------------------------------------


def map_elementwise(fn, arrays, impl: Optional[Impl] = None,
                    block: Optional[int] = None):
    """Apply a staged elementwise body to 1-D columns in one fused pass.

    ``fn`` is a jnp-traceable callable (built by the kernel planner from
    IR), so there is no outer jit here — the caller is always inside the
    program's jit and the kernel inlines into its trace.
    """
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.map_elementwise(fn, arrays)
    return _mc.map_elementwise(fn, arrays, block=block or _mc.BLOCK,
                               interpret=(impl == "interpret"))


# -- attention --------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("causal", "group", "scale", "impl", "chunk", "unroll"),
)
def _attn(q, k, v, causal, group, scale, chunk, unroll, impl):
    if impl == "ref":
        return _ref.chunked_attention(q, k, v, causal=causal, group=group,
                                      scale=scale, chunk=chunk,
                                      unroll=unroll)
    return _fa.flash_attention(q, k, v, causal=causal, group=group,
                               scale=scale, interpret=(impl == "interpret"))


def attention(q, k, v, causal: bool = True, group: int = 1, scale=None,
              chunk: int = 1024, unroll: bool = False,
              impl: Optional[Impl] = None):
    return _attn(q, k, v, causal=causal, group=group, scale=scale,
                 chunk=chunk, unroll=unroll, impl=_resolve(impl))
