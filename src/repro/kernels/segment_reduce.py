"""vecmerger / dictmerger kernel: keyed aggregation without atomics.

The paper (§7.7) shows the optimal vecmerger strategy is
platform-specific: thread-local copies on CPU, aggregation trees on GPU.
The TPU-native strategy implemented here is different again — and only
expressible because builders are declarative: each block builds a one-hot
matrix of its segment ids and feeds the **MXU** with

    out[K] += onehot(seg_block, K)^T @ vals_block

turning scatter-accumulation into dense systolic matmuls (no atomics, no
divergence; deterministic).  K (number of segments / vecmerger width) must
fit a VMEM-resident accumulator tile: K ≤ 4096 covers MoE expert counts
and the benchmark's key-count workload; larger K falls back to the ref
path (sort + segment-sum).

Block: 512 rows × K=1024 f32 one-hot = 2 MiB VMEM — MXU-aligned on both
dims (multiples of 128).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 512
MAX_K = 4096
#: autotune grid for the row-block dim: MXU-aligned multiples of 128.
#: Small blocks shrink the per-step one-hot tile (B × K) when K is large;
#: big blocks amortize grid steps when K is small.
BLOCK_CANDIDATES = (128, 256, 512, 1024)


def _kernel(seg_ref, val_ref, o_ref, *, k: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    seg = seg_ref[...]                       # (B,) int32
    vals = val_ref[...]                      # (B,)
    iota = jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], k), 1)
    onehot = (iota == seg[:, None]).astype(vals.dtype)   # (B, K)
    # MXU: (K, B) @ (B,) -> accumulate into the K-wide VMEM tile
    o_ref[...] += jnp.dot(onehot.T, vals,
                          preferred_element_type=o_ref.dtype)[None, :]


def segment_sum(seg_ids: jax.Array, vals: jax.Array, num_segments: int, *,
                block: int = BLOCK_N, interpret: bool = True) -> jax.Array:
    """out[s] = sum(vals[seg_ids == s]).  seg_ids int32 in [0, K)."""
    assert num_segments <= MAX_K, "K too large for VMEM tile; use ref path"
    n = vals.shape[0]
    if n == 0:
        return jnp.zeros((num_segments,), vals.dtype)
    npad = (block - n % block) % block
    if npad:
        # park padding in a segment that we never read back
        seg_ids = jnp.pad(seg_ids, (0, npad), constant_values=0)
        vals = jnp.pad(vals, (0, npad))
    grid = (vals.shape[0] // block,)
    import functools

    out = pl.pallas_call(
        functools.partial(_kernel, k=num_segments),
        out_shape=jax.ShapeDtypeStruct((1, num_segments), vals.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, num_segments), lambda i: (0, 0)),
        interpret=interpret,
    )(seg_ids.astype(jnp.int32), vals)
    return out[0]


def _kernel_matrix(seg_ref, val_ref, o_ref, *, k: int):
    """Segment-sum of row-vectors: out[K, D] += onehot^T @ vals (B, D).
    This is exactly MoE combine / expert-bucket accumulation."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    seg = seg_ref[...]
    vals = val_ref[...]                       # (B, D)
    iota = jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], k), 1)
    onehot = (iota == seg[:, None]).astype(vals.dtype)
    o_ref[...] += jax.lax.dot_general(
        onehot, vals, (((0,), (0,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )


def segment_sum_vectors(seg_ids: jax.Array, vals: jax.Array,
                        num_segments: int, *, block: int = 256,
                        interpret: bool = True) -> jax.Array:
    """vals: (n, d) rows merged into out: (K, d) by segment id."""
    assert num_segments <= MAX_K
    n, d = vals.shape
    if n == 0:
        return jnp.zeros((num_segments, d), vals.dtype)
    npad = (block - n % block) % block
    if npad:
        seg_ids = jnp.pad(seg_ids, (0, npad), constant_values=0)
        vals = jnp.pad(vals, ((0, npad), (0, 0)))
    grid = (vals.shape[0] // block,)
    import functools

    return pl.pallas_call(
        functools.partial(_kernel_matrix, k=num_segments),
        out_shape=jax.ShapeDtypeStruct((num_segments, d), vals.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, d), lambda i: (0, 0)),
        interpret=interpret,
    )(seg_ids.astype(jnp.int32), vals)
