"""Flash attention (chunked online-softmax) with explicit VMEM tiling.

The LM stack's memory hot-spot: naive attention materializes an (Sq, Skv)
score matrix per head in HBM; at 32k context that is 4 GiB/head — the
memory-roofline killer the dry-run exposes.  The tiled form keeps one
(bq, bk) score tile in VMEM, carrying the online-softmax state (running
max m, normalizer l, accumulator acc) across the kv grid dimension.

This is the paper's loop-tiling insight applied to the attention loop
nest: tile the kv loop so q/acc tiles are reused across kv blocks.

GQA is handled in the BlockSpec index_map (kv head = q head // group) —
grouped heads never materialize repeated K/V.

Block sizes: bq=bk=512, d≤256 → q/k/v/acc tiles ≈ 4×512×256×4B = 2 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, offset: int,
            skv: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                          # (bq, d)
    k = k_ref[0]                          # (bk, d)
    v = v_ref[0]                          # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                             # (bq, bk)

    kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    s = jnp.where(kj < skv, s, NEG_INF)  # mask kv padding
    if causal:
        qi = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 0) + offset
        s = jnp.where(kj <= qi, s, NEG_INF)

    m_prev = m_ref[0]                     # (bq,)
    l_prev = l_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc = acc_ref[0] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[0] = m_new
    l_ref[0] = l_new
    acc_ref[0] = acc

    @pl.when(j == pl.num_programs(2) - 1)
    def _fin():
        o_ref[0] = (acc_ref[0] / jnp.maximum(l_ref[0], 1e-30)[:, None]).astype(
            o_ref.dtype
        )


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, group: int = 1, scale=None,
                    bq: int = 512, bk: int = 512,
                    interpret: bool = True) -> jax.Array:
    """q: (H, Sq, D); k/v: (H//group, Skv, D).  Returns (H, Sq, D).

    Causal alignment assumes q positions are the LAST Sq positions of the
    kv sequence (standard prefill/decode layout)."""
    h, sq, d = q.shape
    hk, skv, _ = k.shape
    assert h == hk * group
    scale = float(scale if scale is not None else d ** -0.5)
    bq_ = min(bq, sq)
    bk_ = min(bk, skv)
    pq, pk_ = (-sq) % bq_, (-skv) % bk_
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk_:
        k = jnp.pad(k, ((0, 0), (0, pk_), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk_), (0, 0)))
    gq, gkv = q.shape[1] // bq_, k.shape[1] // bk_
    offset = skv - sq  # causal alignment

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, bq=bq_, bk=bk_, offset=offset,
        skv=skv,
    )
    out, _, _, _ = pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((h, q.shape[1]), jnp.float32),
            jax.ShapeDtypeStruct((h, q.shape[1]), jnp.float32),
            jax.ShapeDtypeStruct(q.shape, jnp.float32),
        ),
        grid=(h, gq, gkv),
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk_, d), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, bq_, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq_), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq_), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq_, d), lambda b, i, j: (b, i, 0)),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq, :]
