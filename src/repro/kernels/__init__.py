"""Pallas TPU kernels for the compute hot-spots Weld optimizes.

Each kernel <name>.py carries a pl.pallas_call with explicit BlockSpec
VMEM tiling; ops.py holds the jit'd public wrappers; ref.py the pure-jnp
oracles.  All kernels validate in interpret=True mode on CPU (the dry-run
and CPU benchmarks use the ref path; the kernels are the TPU target).

Kernel inventory and the Weld construct each one lowers:
  * filter_reduce   — predicated single-pass merger (Listing 10 / TPC-H Q6)
  * segment_reduce  — vecmerger/dictmerger via one-hot MXU matmul
                      (atomic-free "global" builder strategy, §7.7)
  * fused_adamw     — the framework's weld-fused optimizer elementwise chain
  * tiled_matmul    — loop tiling (paper Table 3) as BlockSpec VMEM tiling
  * flash_attention — chunked online-softmax attention (VMEM-resident tiles)
"""
