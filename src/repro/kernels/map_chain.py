"""Fused elementwise map-chain kernel.

The Weld optimizer collapses a chain of library map operators into ONE
loop; the planner routes that loop here so the whole chain executes as a
single Pallas pass:

    result(for(v1..vk, vecbuilder, (b,i,x) => merge(b, f(x))))

The body ``f`` arrives as a jnp-traceable callable staged from the IR, so
one kernel serves every elementwise chain (Black-Scholes, dataframe
column math, normalization...).  Each grid step loads one VMEM-resident
block per input column, applies the fused body on the VPU, and writes one
output block — intermediates never touch HBM, which is the paper's fusion
argument restated at the kernel level.

Block size: 8×1024 lanes per column (f32: 32 KiB/column) — matches the
filter_reduce tile so several columns plus the output stay well inside
VMEM with double-buffering headroom.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 1024
#: autotune grid (matches filter_reduce: these kernels share tile math).
BLOCK_CANDIDATES = (1024, 8 * 1024, 32 * 1024)


def map_elementwise(fn: Callable, arrays: Sequence[jax.Array], *,
                    block: int = BLOCK, interpret: bool = True) -> jax.Array:
    """out[i] = fn(a1[i], ..., ak[i]) for equal-length 1-D arrays.

    Inputs are padded to a block multiple; ``fn`` must be total on the
    padded zeros (padding rows are sliced off before returning).
    """
    arrays = [jnp.asarray(a) for a in arrays]
    n = arrays[0].shape[0]
    out_sd = jax.eval_shape(
        fn, *[jax.ShapeDtypeStruct((), a.dtype) for a in arrays]
    )
    if n == 0:
        return jnp.zeros((0,), out_sd.dtype)
    npad = (block - n % block) % block
    if npad:
        arrays = [jnp.pad(a, (0, npad)) for a in arrays]
    total = arrays[0].shape[0]

    def _kernel(*refs):
        o_ref = refs[-1]
        val = fn(*[r[...] for r in refs[:-1]])
        o_ref[...] = jnp.broadcast_to(val, o_ref.shape).astype(o_ref.dtype)

    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((total,), out_sd.dtype),
        grid=(total // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)) for _ in arrays],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=interpret,
    )(*arrays)
    return out[:n]
