"""Pure-jnp oracles for every Pallas kernel.

These are the reference semantics kernels are validated against
(interpret=True allclose sweeps in tests/test_kernels.py), AND the
execution path used on CPU (benchmarks) and in the dry-run lowering
(kernels are the TPU target; HLO cost analysis uses these — conservative,
since the Pallas forms strictly reduce HBM traffic)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def filter_reduce_sum(x, pred):
    return jnp.sum(jnp.where(pred, x, jnp.zeros_like(x)))


def filter_reduce_sum_multi(vals, pred):
    """vals (A, n), pred (n,) -> (A,) predicated row sums."""
    return jnp.sum(jnp.where(pred[None, :], vals, jnp.zeros_like(vals)),
                   axis=1)


def filter_reduce_q6(cols, lo, hi, val):
    keep = jnp.all((cols >= lo[:, None]) & (cols < hi[:, None]), axis=0)
    return jnp.sum(jnp.where(keep, val, jnp.zeros_like(val)))


def segment_sum(seg_ids, vals, num_segments):
    return jax.ops.segment_sum(vals, seg_ids, num_segments=num_segments)


def hash_to_slot(keys, cap_table):
    """Sort-based oracle for the open-addressing slot assignment: rows
    with equal keys share a slot, distinct keys get distinct slots.
    Slot numbering is ascending-key compact ids (the Pallas kernel uses
    hash positions instead — only the slots/table CONTRACT is shared,
    see kernels/hash_table.py)."""
    from .hash_table import EMPTY

    n = keys.shape[0]
    if n == 0:
        return (jnp.zeros((0,), jnp.int32),
                jnp.full((cap_table,), EMPTY, jnp.int64),
                jnp.zeros((), jnp.int32))
    keys = keys.astype(jnp.int64)
    valid = keys != EMPTY
    big = jnp.iinfo(jnp.int64).max
    pk = jnp.where(valid, keys, big)
    order = jnp.argsort(pk, stable=True)
    sk = pk[order]
    sval = valid[order]
    is_new = jnp.concatenate([sval[:1], (sk[1:] != sk[:-1]) & sval[1:]])
    seg = jnp.cumsum(is_new.astype(jnp.int32)) - 1
    seg = jnp.where(sval & (seg < cap_table), seg, cap_table)
    slots = jnp.zeros((n,), jnp.int32).at[order].set(seg)
    used = is_new.sum().astype(jnp.int32)
    table = jnp.full((cap_table,), EMPTY, jnp.int64).at[
        jnp.where(is_new, seg, cap_table)
    ].set(jnp.where(is_new, sk, EMPTY), mode="drop")
    return slots, table, used


def dict_probe(table_keys, count, queries):
    """Binary-search oracle for the one-hot membership probe: table keys
    are sorted ascending for the first `count` slots (parked slots are
    neutralized here so a stale tail cannot break the search)."""
    cap = table_keys.shape[0]
    n = queries.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.int32), jnp.zeros((0,), bool)
    big = jnp.iinfo(jnp.int64).max
    cnt = jnp.asarray(count, jnp.int32)
    neut = jnp.where(jnp.arange(cap) < cnt, table_keys.astype(jnp.int64), big)
    q = queries.astype(jnp.int64)
    pos = jnp.searchsorted(neut, q).astype(jnp.int32)
    posc = jnp.clip(pos, 0, cap - 1)
    found = (neut[posc] == q) & (posc < cnt)
    return jnp.where(found, posc, jnp.int32(0)), found


def group_build(keys, capacity):
    """Sort-based oracle for the CSR group build: rows with equal keys
    share an ascending-key compact slot; ``offsets`` are the CSR group
    boundaries over those slots; ``used`` counts distinct valid keys
    (``used > capacity`` = overflow, callers poison — the contract
    shared with kernels/group_build.py)."""
    from .hash_table import EMPTY

    cap = int(capacity)
    n = keys.shape[0]
    if n == 0:
        return (jnp.zeros((0,), jnp.int32),
                jnp.zeros((cap + 1,), jnp.int32),
                jnp.zeros((), jnp.int32))
    keys = keys.astype(jnp.int64)
    valid = keys != EMPTY
    big = jnp.iinfo(jnp.int64).max
    pk = jnp.where(valid, keys, big)
    order = jnp.argsort(pk, stable=True)
    sk = pk[order]
    sval = valid[order]
    is_new = jnp.concatenate([sval[:1], (sk[1:] != sk[:-1]) & sval[1:]])
    seg = jnp.cumsum(is_new.astype(jnp.int32)) - 1
    seg = jnp.where(sval & (seg < cap), seg, cap)
    cslots = jnp.zeros((n,), jnp.int32).at[order].set(seg)
    used = is_new.sum().astype(jnp.int32)
    counts = jax.ops.segment_sum(
        jnp.where(seg < cap, 1, 0), seg, num_segments=cap + 1
    )[:cap]
    offsets = jnp.concatenate([
        jnp.zeros((1,), jnp.int32),
        jnp.cumsum(counts).astype(jnp.int32),
    ])
    return cslots, offsets, used


def group_probe(table_keys, offsets, count, queries):
    """Binary-search oracle for the fused membership + match-count probe
    of the m:n expansion: ``(pos, found, sizes)`` per query, ``sizes``
    read off the CSR offsets (0 on a miss)."""
    cap = table_keys.shape[0]
    n = queries.shape[0]
    if n == 0 or cap == 0:
        z = jnp.zeros((n,), jnp.int32)
        return z, jnp.zeros((n,), bool), z
    big = jnp.iinfo(jnp.int64).max
    cnt = jnp.asarray(count, jnp.int32)
    neut = jnp.where(jnp.arange(cap) < cnt, table_keys.astype(jnp.int64), big)
    q = queries.astype(jnp.int64)
    pos = jnp.searchsorted(neut, q).astype(jnp.int32)
    posc = jnp.clip(pos, 0, cap - 1)
    found = (neut[posc] == q) & (posc < cnt)
    sizes = (offsets[1:] - offsets[:-1]).astype(jnp.int32)[posc]
    return (jnp.where(found, posc, jnp.int32(0)), found,
            jnp.where(found, sizes, jnp.int32(0)))


def segment_sum_vectors(seg_ids, vals, num_segments):
    return jax.ops.segment_sum(vals, seg_ids, num_segments=num_segments)


def adamw_update(p, g, m, v, lr, step, *, b1=0.9, b2=0.999, eps=1e-8,
                 wd=0.01):
    lr = jnp.asarray(lr, p.dtype)
    t = jnp.asarray(step, p.dtype)
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    m_hat = m_new / (1.0 - jnp.power(jnp.asarray(b1, p.dtype), t))
    v_hat = v_new / (1.0 - jnp.power(jnp.asarray(b2, p.dtype), t))
    p_new = p - lr * (m_hat / (jnp.sqrt(v_hat) + eps) + wd * p)
    return p_new, m_new, v_new


def tiled_matmul(a, b):
    return jnp.dot(a, b, preferred_element_type=a.dtype)


def map_elementwise(fn, arrays):
    out = fn(*[jnp.asarray(a) for a in arrays])
    return jnp.broadcast_to(out, jnp.asarray(arrays[0]).shape)


def attention(q, k, v, *, causal=True, group=1, scale=None):
    """q: (H, Sq, D); k/v: (H//group, Skv, D) — dense reference."""
    h, sq, d = q.shape
    scale = float(scale if scale is not None else d ** -0.5)
    if group > 1:
        k = jnp.repeat(k, group, axis=0)
        v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    skv = k.shape[1]
    if causal:
        offset = skv - sq
        qi = jnp.arange(sq)[:, None] + offset
        kj = jnp.arange(skv)[None, :]
        s = jnp.where(kj <= qi, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)).astype(q.dtype)


def chunked_attention(q, k, v, *, causal=True, group=1, scale=None,
                      chunk=1024, unroll=False):
    """Memory-bounded jnp attention (lax.scan over kv chunks with online
    softmax) — the production ref path for long sequences; equals
    `attention` but with O(Sq*chunk) live score memory."""
    h, sq, d = q.shape
    hk, skv, _ = k.shape
    scale = float(scale if scale is not None else d ** -0.5)
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    nck = k.shape[1] // chunk
    kc = k.reshape(hk, nck, chunk, d).transpose(1, 0, 2, 3)
    vc = v.reshape(hk, nck, chunk, d).transpose(1, 0, 2, 3)
    offset = skv - sq
    qf = q.astype(jnp.float32)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        jc, kb, vb = inp
        if group > 1:
            kb = jnp.repeat(kb, group, axis=0)
            vb = jnp.repeat(vb, group, axis=0)
        s = jnp.einsum("hqd,hkd->hqk", qf, kb.astype(jnp.float32)) * scale
        kj = jc * chunk + jnp.arange(chunk)[None, :]
        s = jnp.where(kj[None] < skv, s, -1e30)
        if causal:
            qi = jnp.arange(sq)[:, None] + offset
            s = jnp.where(kj[None] <= qi[None], s, -1e30)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "hqk,hkd->hqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc), None

    init = (
        jnp.full((h, sq), -1e30, jnp.float32),
        jnp.zeros((h, sq), jnp.float32),
        jnp.zeros((h, sq, d), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        step, init, (jnp.arange(nck), kc, vc), unroll=bool(unroll)
    )
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
