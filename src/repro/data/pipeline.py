"""Deterministic synthetic token pipeline.

Properties a 1000-node fleet needs, all tested:
  * **shard-aware** — batch(step, shard k of n) is a disjoint, stable
    slice of the global batch; re-sharding to a different n yields the
    same global stream (elastic restarts don't skew data);
  * **stateful & checkpointable** — `state()`/`restore()` round-trip the
    cursor, so preempt/resume is bitwise identical;
  * **fused preprocessing** — the shift/mask/mixture transforms run as
    one Weld program per batch (`preprocess_weld`), the paper's pipeline
    integration.

Tokens are a fixed mixture of synthetic "documents" (Zipf-ish ids keyed
by a counter hash), so losses are reproducible across runs and hosts
without any dataset download.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np


def _keyed_bits(seed: int, lo: int, n: int) -> np.ndarray:
    """Deterministic uint32 stream independent of shard layout: value at
    global index i depends only on (seed, i)."""
    out = np.empty(n, np.uint64)
    # counter-mode hashing in blocks of 8192 for speed
    idx = np.arange(lo, lo + n, dtype=np.uint64)
    x = idx * np.uint64(0x9E3779B97F4A7C15) ^ np.uint64(seed)
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    out[:] = x
    return out


@dataclass
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard: int = 0
    num_shards: int = 1
    step: int = 0

    def __post_init__(self):
        assert self.global_batch % self.num_shards == 0, (
            "global batch must divide across data shards"
        )

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.num_shards

    # -- state (checkpointed) ---------------------------------------------------

    def state(self) -> Dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: Dict) -> None:
        self.step = int(state["step"])
        self.seed = int(state["seed"])

    # -- batches -----------------------------------------------------------------

    def _tokens_for(self, step: int, row: int) -> np.ndarray:
        """Global row `row` of global step `step` (shard-independent)."""
        base = (step * self.global_batch + row) * (self.seq_len + 1)
        bits = _keyed_bits(self.seed, base, self.seq_len + 1)
        # Zipf-ish skew: square a uniform, keeps a learnable bigram bias
        u = (bits % np.uint64(1 << 30)).astype(np.float64) / float(1 << 30)
        toks = (u * u * (self.vocab - 1)).astype(np.int64)
        # inject structure so the LM has something to learn: tok[i+1]
        # sometimes repeats tok[i]
        rep = bits % np.uint64(4) == 0
        toks[1:] = np.where(rep[1:], toks[:-1], toks[1:])
        return toks

    def next_batch(self) -> Dict[str, np.ndarray]:
        rows = range(self.shard * self.local_batch,
                     (self.shard + 1) * self.local_batch)
        seqs = np.stack([self._tokens_for(self.step, r) for r in rows])
        self.step += 1
        return {
            "tokens": seqs[:, :-1].astype(np.int32),
            "labels": seqs[:, 1:].astype(np.int32),
        }

    # -- Weld-fused preprocessing -------------------------------------------------

    def preprocess_weld(self, raw: np.ndarray,
                        pad_id: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        """Shift + pad-mask in ONE fused pass (two outputs, one loop) —
        the paper's Listing 3 pattern on the data path."""
        from ..core import ir, macros as M, wtypes as wt
        from ..core.lazy import Evaluate, NewWeldObject

        flat = raw.astype(np.int64).reshape(-1)
        d = NewWeldObject(flat, None)
        did = ir.Ident(d.obj_id, d.weld_type())
        bt = wt.StructBuilder((wt.VecBuilder(wt.I64), wt.VecBuilder(wt.I64)))
        b = ir.Ident(ir.fresh("b"), bt)
        i = ir.Ident(ir.fresh("i"), wt.I64)
        x = ir.Ident(ir.fresh("x"), wt.I64)
        body = ir.MakeStruct((
            ir.Merge(ir.GetField(b, 0), x),
            ir.Merge(
                ir.GetField(b, 1),
                ir.Select(ir.BinOp("==", x, M.lit(pad_id)),
                          M.lit(0), M.lit(1)),
            ),
        ))
        loop = ir.Result(ir.For(
            (ir.Iter(did),),
            ir.MakeStruct((ir.NewBuilder(wt.VecBuilder(wt.I64)),
                           ir.NewBuilder(wt.VecBuilder(wt.I64)))),
            ir.Lambda((b, i, x), body),
        ))
        toks, mask = Evaluate(NewWeldObject([d], loop)).value
        return (np.asarray(toks).reshape(raw.shape),
                np.asarray(mask).reshape(raw.shape))
