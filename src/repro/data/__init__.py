"""Data substrate: deterministic, shard-aware, checkpointable pipeline."""
from .pipeline import TokenPipeline  # noqa: F401
