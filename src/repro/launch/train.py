"""Training driver: sharded train step (DP/TP/ZeRO-1 via logical rules),
gradient accumulation, clipping, cosine schedule, async checkpointing
with preempt/resume, straggler monitoring, optional cross-pod int8
gradient compression.

CPU-runnable end to end (examples/train_lm.py drives a ~10M-param model
for a few hundred steps); identical code lowers onto the production mesh
in the dry-run.
"""
from __future__ import annotations

import argparse
import functools
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer
from ..configs import get_config
from ..data import TokenPipeline
from ..distributed.sharding import (
    replicated, tree_shardings, zero1_moment_shardings,
)
from ..distributed.straggler import StepMonitor
from ..models import build_model
from ..optim import adamw_init, adamw_update_tree, clip_by_global_norm
from ..optim.schedule import cosine_warmup
from .mesh import make_local_mesh


def build_train_step(model, mesh, *, accum: int = 1, peak_lr: float = 3e-4,
                     warmup: int = 50, total_steps: int = 1000,
                     max_grad_norm: float = 1.0, rules=None):
    """Returns (jitted step fn, state shardings).  State = (params, opt)."""
    cfg = model.cfg

    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = model.param_specs()
    psh = tree_shardings(pspecs, pshapes, mesh, rules)
    oshapes = jax.eval_shape(adamw_init, pshapes)
    osh = {
        "m": zero1_moment_shardings(pspecs, pshapes, mesh, rules),
        "v": zero1_moment_shardings(pspecs, pshapes, mesh, rules),
        "step": replicated(mesh),
    }

    def lr_fn(step):
        return cosine_warmup(step, peak_lr=peak_lr, warmup=warmup,
                             total=total_steps)

    def loss_microbatch(params, mb):
        return model.loss_fn(params, mb)

    def train_step(params, opt, batch):
        if accum > 1:
            b = batch["tokens"].shape[0]
            mb_size = b // accum

            def micro(carry, idx):
                gacc, lacc = carry
                mb = jax.tree_util.tree_map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, idx * mb_size, mb_size, axis=0),
                    batch)
                l, g = jax.value_and_grad(loss_microbatch)(params, mb)
                gacc = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l), None

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                micro, (zero, 0.0), jnp.arange(accum))
            grads = jax.tree_util.tree_map(lambda g: g / accum, gsum)
            loss = lsum / accum
        else:
            loss, grads = jax.value_and_grad(loss_microbatch)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = lr_fn(opt["step"])
        params, opt = adamw_update_tree(params, grads, opt, lr)
        metrics = {"loss": loss.astype(jnp.float32), "gnorm": gnorm,
                   "lr": lr}
        return params, opt, metrics

    # batch shardings are inferred by GSPMD from the pinned param/opt
    # shardings; the dry-run pins them explicitly (launch/dryrun.py).
    step = jax.jit(
        train_step,
        in_shardings=(psh, osh, None),
        out_shardings=(psh, osh, None),
        donate_argnums=(0, 1),
    )
    return step, (psh, osh)


def train(arch: str, *, smoke: bool = True, steps: int = 50,
          global_batch: int = 8, seq_len: int = 64, accum: int = 1,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 20,
          resume: bool = False, dp: Optional[int] = None, tp: int = 1,
          peak_lr: float = 1e-3, log_every: int = 10,
          seed: int = 0, verbose: bool = True) -> Dict:
    """Run a real training loop; returns final metrics + loss history."""
    cfg = get_config(arch, smoke=smoke)
    model = build_model(cfg)
    mesh = make_local_mesh(dp=dp, tp=tp)

    step_fn, (psh, osh) = build_train_step(
        model, mesh, accum=accum, peak_lr=peak_lr, total_steps=steps)

    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=seq_len,
                         global_batch=global_batch, seed=seed)
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None

    start = 0
    if resume and ckpt is not None and ckpt.latest_step() is not None:
        tstep = ckpt.latest_step()
        template = {
            "params": jax.eval_shape(model.init, jax.random.PRNGKey(seed)),
            "opt": jax.eval_shape(
                adamw_init,
                jax.eval_shape(model.init, jax.random.PRNGKey(seed))),
        }
        state, extra = ckpt.restore(
            tstep, template, shardings={"params": psh, "opt": osh})
        params, opt = state["params"], state["opt"]
        pipe.restore(extra["pipeline"])
        start = extra["step"]
        if verbose:
            print(f"[train] resumed from step {start}")
    else:
        with jax.default_device(jax.devices()[0]):
            params = model.init(jax.random.PRNGKey(seed))
        params = jax.device_put(params, psh)
        opt = jax.device_put(adamw_init(params), osh)

    monitor = StepMonitor()
    losses = []
    for s in range(start, steps):
        batch_np = pipe.next_batch()
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        monitor.start()
        params, opt, metrics = step_fn(params, opt, batch)
        metrics = jax.device_get(metrics)
        monitor.stop()
        losses.append(float(metrics["loss"]))
        if verbose and (s % log_every == 0 or s == steps - 1):
            print(f"[train] step {s:5d} loss {metrics['loss']:.4f} "
                  f"gnorm {metrics['gnorm']:.3f} lr {metrics['lr']:.2e}")
        if ckpt is not None and (s + 1) % ckpt_every == 0:
            ckpt.save(s + 1, {"params": params, "opt": opt},
                      extra={"pipeline": pipe.state(), "step": s + 1})
    if ckpt is not None:
        ckpt.save(steps, {"params": params, "opt": opt},
                  extra={"pipeline": pipe.state(), "step": steps},
                  blocking=True)
    return {
        "losses": losses,
        "final_loss": losses[-1] if losses else None,
        "params": params,
        "opt": opt,
        "straggler": monitor.summary(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--full", action="store_true",
                    help="full (published) config instead of smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--dp", type=int, default=None)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    train(args.arch, smoke=not args.full, steps=args.steps,
          global_batch=args.batch, seq_len=args.seq, accum=args.accum,
          ckpt_dir=args.ckpt_dir, resume=args.resume, dp=args.dp,
          tp=args.tp, peak_lr=args.lr)


if __name__ == "__main__":
    main()
