"""Serving driver: batched prefill + greedy decode with a static KV/state
cache.  CPU-runnable on the smoke configs (examples/serve_lm.py); the
decode_32k / long_500k dry-run cells lower exactly this `decode_step`.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import build_model


def _pad_cache_to(cache, full_cache):
    """Place prefill kv into a max_seq-sized decode cache (attention
    caches are seq-padded; recurrent states are copied through)."""

    def place(small, big):
        if small.shape == big.shape:
            return small
        # pad along the one differing (sequence) axis
        idx = [i for i, (a, b) in enumerate(zip(small.shape, big.shape))
               if a != b]
        assert len(idx) == 1, (small.shape, big.shape)
        ax = idx[0]
        pad = [(0, 0)] * small.ndim
        pad[ax] = (0, big.shape[ax] - small.shape[ax])
        return jnp.pad(small, pad)

    return jax.tree_util.tree_map(place, cache, full_cache)


def serve(arch: str, *, smoke: bool = True, batch: int = 2,
          prompt_len: int = 16, gen_len: int = 16, seed: int = 0,
          verbose: bool = True) -> Dict:
    cfg = get_config(arch, smoke=smoke)
    model = build_model(cfg)
    rng = np.random.RandomState(seed)
    params = model.init(jax.random.PRNGKey(seed))

    max_seq = prompt_len + gen_len
    batch_in = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab, (batch, prompt_len)), jnp.int32)}
    if cfg.family == "encdec":
        batch_in["frames"] = jnp.asarray(
            rng.randn(batch, cfg.n_frames, cfg.d_model), cfg.act_dtype)
    if cfg.family == "vlm":
        batch_in["images"] = jnp.asarray(
            rng.randn(batch, cfg.n_image_tokens, cfg.d_vision),
            cfg.act_dtype)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch_in)
    cache = _pad_cache_to(cache, model.cache_init(batch, max_seq))
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out_tokens = [tok]
    t0 = time.perf_counter()
    for step in range(gen_len - 1):
        pos = jnp.int32(prompt_len + step)
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    tput = batch * (gen_len - 1) / max(t_decode, 1e-9)
    if verbose:
        print(f"[serve] {arch}: prefill {t_prefill*1e3:.1f} ms, "
              f"decode {tput:.1f} tok/s, sample row: {gen[0][:8]}")
    return {"tokens": gen, "prefill_s": t_prefill, "decode_s": t_decode,
            "tok_per_s": tput}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    serve(args.arch, smoke=not args.full, batch=args.batch,
          prompt_len=args.prompt_len, gen_len=args.gen_len)


if __name__ == "__main__":
    main()
