import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: jax locks the device count on first
#   init.  setdefault so test harnesses (8 fake devices) keep their own.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) cell, lower + compile the real
step function (`train_step` for train_4k, `prefill` for prefill_32k,
`decode_step` for decode_32k/long_500k) against ShapeDtypeStruct inputs
(no allocation) on the production mesh — 16×16 single pod and 2×16×16
multi-pod — then record memory analysis, cost analysis and the HLO
collective schedule for the roofline report.

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch all --shapes all --mesh both --out benchmarks/dryrun_results.json
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import SHAPES, get_config, list_configs
from ..configs.base import ShapeConfig
from ..distributed.sharding import (
    replicated, tree_shardings, zero1_moment_shardings,
)
from ..models import build_model
from ..optim import adamw_init, adamw_update_tree, clip_by_global_norm
from ..roofline.analysis import (
    HW_V5E, collective_bytes_from_hlo, extract_cost, roofline_terms,
)
from .mesh import make_production_mesh


def _shard_bytes(shapes, shardings) -> int:
    """Exact per-device bytes for a tree of ShapeDtypeStructs under the
    given shardings (analytic memory-fit check, DESIGN.md §6)."""
    total = 0
    for leaf, sh in zip(jax.tree_util.tree_leaves(shapes),
                        jax.tree_util.tree_leaves(
                            shardings, is_leaf=lambda x: hasattr(x, "spec"))):
        local = sh.shard_shape(tuple(leaf.shape))
        total += int(np.prod(local)) * jnp.dtype(leaf.dtype).itemsize
    return total


# ---------------------------------------------------------------------------
# Cost extrapolation (exact roofline despite scanned layers)
#
# XLA's cost analysis counts while-loop bodies ONCE, so the compact
# (scanned) lowering under-reports flops/bytes/collectives by ~n_layers×.
# Layers within a stack are homogeneous by construction, so we lower
# reduced-depth UNROLLED variants (1 unit and 2 units per layer stack) and
# extrapolate:  cost(L) = cost(1u) + (L-1) · (cost(2u) - cost(1u)).
# The compact lowering still provides the compile-success proof and the
# memory analysis (its while loops reuse buffers, like the real run).
# ---------------------------------------------------------------------------


def _cost_stacks(cfg):
    """[(stack_name, full_units, cfg_builder(units_dict))] per family."""
    fam = cfg.family

    def with_layers(**kw):
        return dataclasses.replace(cfg, **kw)

    if fam == "dense":
        return ([("layers", cfg.n_layers)],
                lambda u: with_layers(n_layers=u["layers"]))
    if fam == "moe":
        fk = cfg.first_k_dense
        return ([("moe", cfg.n_layers - fk)],
                lambda u: with_layers(n_layers=fk + u["moe"]))
    if fam == "vlm":
        k = cfg.cross_attn_every
        return ([("super", cfg.n_layers // k)],
                lambda u: with_layers(n_layers=k * u["super"]))
    if fam == "encdec":
        return ([("enc", cfg.n_enc_layers), ("dec", cfg.n_layers)],
                lambda u: with_layers(n_enc_layers=u["enc"],
                                      n_layers=u["dec"]))
    if fam == "hybrid":
        k = cfg.attn_every
        return ([("group", cfg.n_layers / k)],
                lambda u: with_layers(n_layers=k * u["group"]))
    if fam == "ssm":
        k = cfg.slstm_every
        return ([("unit", cfg.n_layers / k)],
                lambda u: with_layers(n_layers=k * u["unit"]))
    raise ValueError(fam)


def _lower_cost_variant(cfg, shape, mesh, rules, seq_shard_inputs=False):
    """Lower + compile one reduced-depth unrolled variant; return
    (flops, bytes, coll_total) per device."""
    model = build_model(cfg)
    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    psh = tree_shardings(model.param_specs(), pshapes, mesh, rules)
    in_specs = model.input_specs(shape, shape.kind)
    in_axes = model.input_axes(shape.kind)
    if seq_shard_inputs and shape.kind in ("train", "prefill"):
        in_axes = dict(in_axes)
        for k in ("tokens", "labels"):
            if k in in_axes:
                in_axes[k] = ("batch", "seq")

    if shape.kind == "train":
        oshapes = jax.eval_shape(adamw_init, pshapes)
        osh = {
            "m": zero1_moment_shardings(model.param_specs(), pshapes, mesh,
                                        rules),
            "v": zero1_moment_shardings(model.param_specs(), pshapes, mesh,
                                        rules),
            "step": replicated(mesh),
        }
        bsh = tree_shardings(in_axes, in_specs, mesh, rules)

        def train_step(params, opt, batch):
            loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
            grads, gnorm = clip_by_global_norm(grads, 1.0)
            params, opt = adamw_update_tree(params, grads, opt, 3e-4)
            return params, opt, loss

        with mesh:  # binds P-spec sharding constraints (e.g. MoE EP pins)
            lowered = jax.jit(train_step, in_shardings=(psh, osh, bsh),
                              out_shardings=(psh, osh, None),
                              donate_argnums=(0, 1)).lower(
                pshapes, oshapes, in_specs)
    elif shape.kind == "prefill":
        bsh = tree_shardings(in_axes, in_specs, mesh, rules)
        with mesh:
            lowered = jax.jit(model.prefill, in_shardings=(psh, bsh)).lower(
                pshapes, in_specs)
    else:
        cache_spec = in_specs["cache"]
        csh = tree_shardings(in_axes["cache"], cache_spec, mesh, rules)
        tsh = tree_shardings(in_axes["tokens"], in_specs["tokens"], mesh,
                             rules)
        with mesh:
            lowered = jax.jit(
                model.decode_step,
                in_shardings=(psh, csh, tsh, replicated(mesh)),
                donate_argnums=(1,),
            ).lower(pshapes, cache_spec, in_specs["tokens"], in_specs["pos"])

    compiled = lowered.compile()
    c = extract_cost(compiled.cost_analysis())
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {"flops": c["flops"], "bytes": c["bytes"],
            "coll": dict(coll)}


def extrapolated_cost(cfg, shape, mesh, rules, *, attn_chunk=None,
                      seq_shard_inputs=False) -> Dict:
    """Exact-per-layer roofline inputs via 1-unit/2-unit unrolled variants."""
    stacks, builder = _cost_stacks(cfg)
    unroll_cfg = dict(
        scan_unroll=True,
        attn_chunk=(attn_chunk if attn_chunk is not None
                    else max(1024, min(2048, shape.seq_len))),
        ssm_chunk=max(cfg.ssm_chunk,
                      min(1024, max(shape.seq_len // 32, 128))),
        remat=False,  # reduced variants measure algorithmic cost; the
        # remat multiplier is applied analytically below for train cells
    )
    # MoE needs >=2 units in the base: GSPMD sharding decisions differ
    # between 1-expert-layer and multi-layer modules, which would corrupt
    # the per-layer delta (observed as negative extrapolated flops)
    u0 = 2 if cfg.family == "moe" else 1
    base_units = {name: u0 for name, _ in stacks}
    base_cfg = dataclasses.replace(builder(base_units), **unroll_cfg)
    base = _lower_cost_variant(base_cfg, shape, mesh, rules,
                               seq_shard_inputs)

    flops = base["flops"]
    nbytes = base["bytes"]
    coll = dict(base["coll"])
    variants = 1
    for name, full in stacks:
        u2 = dict(base_units)
        u2[name] = u0 + 1
        v_cfg = dataclasses.replace(builder(u2), **unroll_cfg)
        v = _lower_cost_variant(v_cfg, shape, mesh, rules,
                                seq_shard_inputs)
        variants += 1
        scale = full - u0
        d_flops = max(v["flops"] - base["flops"], 0.0)
        d_bytes = max(v["bytes"] - base["bytes"], 0.0)
        flops += scale * d_flops
        nbytes += scale * d_bytes
        for k in coll:
            coll[k] += scale * max(v["coll"][k] - base["coll"][k], 0)
    # remat recompute: one extra forward pass through the blocks (~1/3 of
    # the fwd+bwd flops) when training with full activation checkpointing
    remat_mult = 4.0 / 3.0 if (shape.kind == "train" and cfg.remat) else 1.0
    return {
        "flops_per_dev": flops * remat_mult,
        "bytes_per_dev": nbytes * remat_mult,
        "coll_per_dev": {k: int(v) for k, v in coll.items()},
        "remat_multiplier": remat_mult,
        "n_cost_lowerings": variants,
    }


def dryrun_cell(arch: str, shape_name: str, mesh, *, smoke: bool = False,
                batch_override: Optional[int] = None,
                seq_override: Optional[int] = None,
                sharding_overrides: Optional[dict] = None,
                cfg_overrides: Optional[dict] = None,
                seq_shard_inputs: bool = False,
                with_cost: bool = True,
                keep_hlo: bool = False) -> Dict:
    """Lower+compile one cell; returns a JSON-safe record.

    Hillclimb knobs: `sharding_overrides` replaces logical-axis rules;
    `cfg_overrides` patches ModelConfig fields (attn_chunk, remat, ...);
    `seq_shard_inputs` shards the token sequence axis over 'model'
    (sequence parallelism at the data boundary)."""
    t_start = time.perf_counter()
    cfg = get_config(arch, smoke=smoke)
    if not smoke and cfg.family in ("hybrid", "ssm"):
        # TPU-native SSD/mLSTM chunking: larger chunks feed the MXU
        # 512-wide and keep the recurrent while-nest shallow (the CPU
        # SPMD compiler also chokes on deeply nested tiny loops)
        cfg = dataclasses.replace(
            cfg, ssm_chunk=max(cfg.ssm_chunk, 512))
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    if batch_override or seq_override:
        shape = ShapeConfig(
            shape.name,
            seq_override or shape.seq_len,
            batch_override or shape.global_batch,
            shape.kind,
        )
    rec: Dict = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "mesh": dict(mesh.shape), "ok": False,
    }
    supported, why = cfg.shape_supported(shape)
    if not supported:
        rec.update(ok=True, skipped=why)
        return rec

    try:
        model = build_model(cfg)
        rules = sharding_overrides
        pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pspecs = model.param_specs()
        psh = tree_shardings(pspecs, pshapes, mesh, rules)
        n_params = sum(int(np.prod(l.shape))
                       for l in jax.tree_util.tree_leaves(pshapes))
        rec["n_params"] = n_params
        rec["param_bytes_per_dev"] = _shard_bytes(pshapes, psh)

        in_specs = model.input_specs(shape, shape.kind)
        in_axes = model.input_axes(shape.kind)
        if seq_shard_inputs and shape.kind in ("train", "prefill"):
            in_axes = dict(in_axes)
            for k in ("tokens", "labels"):
                if k in in_axes:
                    in_axes[k] = ("batch", "seq")

        if shape.kind == "train":
            oshapes = jax.eval_shape(adamw_init, pshapes)
            osh = {
                "m": zero1_moment_shardings(pspecs, pshapes, mesh, rules),
                "v": zero1_moment_shardings(pspecs, pshapes, mesh, rules),
                "step": replicated(mesh),
            }
            rec["opt_bytes_per_dev"] = _shard_bytes(
                oshapes["m"], osh["m"]) + _shard_bytes(oshapes["v"], osh["v"])
            bsh = tree_shardings(in_axes, in_specs, mesh, rules)

            def train_step(params, opt, batch):
                loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
                grads, gnorm = clip_by_global_norm(grads, 1.0)
                params, opt = adamw_update_tree(params, grads, opt, 3e-4)
                return params, opt, {"loss": loss, "gnorm": gnorm}

            with mesh:
                lowered = jax.jit(
                    train_step,
                    in_shardings=(psh, osh, bsh),
                    out_shardings=(psh, osh, None),
                    donate_argnums=(0, 1),
                ).lower(pshapes, oshapes, in_specs)
            tokens = shape.global_batch * shape.seq_len

        elif shape.kind == "prefill":
            bsh = tree_shardings(in_axes, in_specs, mesh, rules)
            with mesh:
                lowered = jax.jit(
                    model.prefill,
                    in_shardings=(psh, bsh),
                ).lower(pshapes, in_specs)
            tokens = shape.global_batch * shape.seq_len

        else:  # decode: serve_step = one new token over a seq_len cache
            cache_spec = in_specs["cache"]
            csh = tree_shardings(in_axes["cache"], cache_spec, mesh, rules)
            tsh = tree_shardings(
                in_axes["tokens"], in_specs["tokens"], mesh, rules)
            rec["cache_bytes_per_dev"] = _shard_bytes(cache_spec, csh)

            def serve_step(params, cache, tokens, pos):
                return model.decode_step(params, cache, tokens, pos)

            with mesh:
                lowered = jax.jit(
                    serve_step,
                    in_shardings=(psh, csh, tsh, replicated(mesh)),
                    donate_argnums=(1,),
                ).lower(pshapes, cache_spec, in_specs["tokens"],
                        in_specs["pos"])
            tokens = shape.global_batch  # one token per sequence

        t_lower = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter()

        mem = compiled.memory_analysis()
        rec["memory_analysis"] = _mem_to_dict(mem)
        # the compact (scanned) module's own analysis — body counted once;
        # kept for reference, superseded by the extrapolated cost below
        rec["cost_compact"] = extract_cost(compiled.cost_analysis())

        hlo = compiled.as_text()
        rec["collectives_compact"] = collective_bytes_from_hlo(hlo)
        rec["hlo_bytes_len"] = len(hlo)
        if keep_hlo:
            rec["hlo"] = hlo

        n_chips = int(np.prod(list(mesh.shape.values())))
        rec["n_chips"] = n_chips
        rec["lower_s"] = t_lower - t_start
        rec["compile_s"] = t_compile - t_lower

        if with_cost and not smoke:
            xc = extrapolated_cost(
                cfg, shape, mesh, rules,
                attn_chunk=(cfg_overrides or {}).get("attn_chunk"),
                seq_shard_inputs=seq_shard_inputs)
            rec["cost"] = {"flops": xc["flops_per_dev"],
                           "bytes": xc["bytes_per_dev"]}
            rec["collectives"] = xc["coll_per_dev"]
            rec["remat_multiplier"] = xc["remat_multiplier"]
            rl = roofline_terms(rec["cost"], xc["coll_per_dev"]["total"])
        else:
            rec["cost"] = rec["cost_compact"]
            rec["collectives"] = rec["collectives_compact"]
            rl = roofline_terms(rec["cost"], rec["collectives"]["total"])
        rec["roofline"] = rl

        # MODEL_FLOPS: useful-math floor (6·N_active·D train, 2·N·D fwd)
        n_active = model.active_param_count() if hasattr(
            model, "active_param_count") else n_params
        mult = 6.0 if shape.kind == "train" else 2.0
        rec["model_flops_global"] = mult * n_active * tokens
        hlo_flops_global = rl["hlo_flops_per_dev"] * n_chips
        rec["useful_flops_ratio"] = (
            rec["model_flops_global"] / hlo_flops_global
            if hlo_flops_global else None
        )
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 - report, don't crash the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def _mem_to_dict(mem) -> Dict:
    if mem is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shapes", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/dryrun_results.json")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    archs = ([a for a in list_configs() if a != "weld-bench"]
             if args.arch == "all" else args.arch.split(","))
    shapes = list(SHAPES) if args.shapes == "all" else args.shapes.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    # resume-able sweep: merge into existing results
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "2x16x16" if multi else "16x16"
        for arch in archs:
            for shape in shapes:
                key = f"{arch}|{shape}|{mesh_name}"
                if results.get(key, {}).get("ok"):
                    print(f"[dryrun] skip cached {key}")
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                # roofline table is single-pod; multi-pod pass proves the
                # 'pod' axis shards (compile success + memory analysis)
                rec = dryrun_cell(arch, shape, mesh, smoke=args.smoke,
                                  with_cost=not multi)
                rec["mesh_name"] = mesh_name
                results[key] = rec
                status = ("SKIP: " + rec["skipped"] if "skipped" in rec
                          else "OK" if rec["ok"]
                          else "FAIL: " + rec.get("error", "?"))
                if rec.get("ok") and "roofline" in rec:
                    rl = rec["roofline"]
                    status += (
                        f"  [{rl['bottleneck']}-bound; "
                        f"c={rl['t_compute_s']*1e3:.2f}ms "
                        f"m={rl['t_memory_s']*1e3:.2f}ms "
                        f"x={rl['t_collective_s']*1e3:.2f}ms; "
                        f"compile {rec['compile_s']:.1f}s]"
                    )
                print(f"[dryrun] {key} -> {status}", flush=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                if rec.get("memory_analysis"):
                    print("   memory:", rec["memory_analysis"], flush=True)

    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"[dryrun] {n_ok}/{len(results)} cells ok -> {args.out}")


if __name__ == "__main__":
    main()
