"""Device meshes.

`make_production_mesh` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — required because
the dry-run must set XLA_FLAGS before any device query.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production mesh: one pod = 16×16 = 256 chips
    (data × model); multi-pod = 2 pods = 512 chips with a leading
    'pod' axis (used for hierarchical data parallelism / optional PP)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(dp: Optional[int] = None, tp: int = 1):
    """Small mesh over whatever devices exist (tests / CPU training)."""
    n = len(jax.devices())
    if dp is None:
        dp = n // tp
    assert dp * tp <= n, f"need {dp * tp} devices, have {n}"
    return jax.make_mesh((dp, tp), ("data", "model"))


def mesh_axis_size(mesh, name) -> int:
    if isinstance(name, (tuple, list)):
        out = 1
        for n in name:
            out *= mesh_axis_size(mesh, n)
        return out
    return mesh.shape[name] if name in mesh.shape else 1
