"""Render the dry-run results JSON into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.roofline.report benchmarks/dryrun_results.json
"""
from __future__ import annotations

import json
import sys
from typing import Dict

from .analysis import HW_V5E


def _fmt_bytes(n) -> str:
    if n is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def _fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.0f}us"


def dryrun_table(results: Dict) -> str:
    rows = [
        "| arch | shape | mesh | status | params | param B/dev | "
        "cache B/dev | compile | HLO temp B/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(results):
        r = results[key]
        mesh = r.get("mesh_name", "?")
        status = ("SKIP" if "skipped" in r else
                  "OK" if r.get("ok") else "FAIL")
        mem = r.get("memory_analysis", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | {status} | "
            f"{r.get('n_params', 0) / 1e9:.1f}B | "
            f"{_fmt_bytes(r.get('param_bytes_per_dev'))} | "
            f"{_fmt_bytes(r.get('cache_bytes_per_dev'))} | "
            f"{r.get('compile_s', 0):.1f}s | "
            f"{_fmt_bytes(mem.get('temp_size_in_bytes'))} |"
        )
    return "\n".join(rows)


def roofline_table(results: Dict) -> str:
    rows = [
        "| arch | shape | bottleneck | t_compute | t_memory | t_collective "
        "| bound | MODEL/HLO flops | step tokens/s bound |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(results):
        r = results[key]
        if r.get("mesh_name") != "16x16" or not r.get("ok") \
                or "skipped" in r or "roofline" not in r:
            continue
        rl = r["roofline"]
        tokens = (r["global_batch"] * r["seq_len"]
                  if r["kind"] in ("train", "prefill") else r["global_batch"])
        tput = tokens / rl["bound_s"] if rl["bound_s"] else 0
        rows.append(
            f"| {r['arch']} | {r['shape']} | **{rl['bottleneck']}** | "
            f"{_fmt_s(rl['t_compute_s'])} | {_fmt_s(rl['t_memory_s'])} | "
            f"{_fmt_s(rl['t_collective_s'])} | {_fmt_s(rl['bound_s'])} | "
            f"{(r.get('useful_flops_ratio') or 0):.2f} | "
            f"{tput:,.0f} |"
        )
    return "\n".join(rows)


def skips_table(results: Dict) -> str:
    rows = ["| arch | shape | reason |", "|---|---|---|"]
    seen = set()
    for key in sorted(results):
        r = results[key]
        if "skipped" in r and (r["arch"], r["shape"]) not in seen:
            seen.add((r["arch"], r["shape"]))
            rows.append(f"| {r['arch']} | {r['shape']} | {r['skipped']} |")
    return "\n".join(rows)


def summarize(results: Dict) -> str:
    n_ok = sum(1 for r in results.values()
               if r.get("ok") and "skipped" not in r)
    n_skip = sum(1 for r in results.values() if "skipped" in r)
    n_fail = sum(1 for r in results.values() if not r.get("ok"))
    out = [
        f"cells: {len(results)} — compiled OK: {n_ok}, "
        f"skipped (per assignment rules): {n_skip}, failed: {n_fail}",
        "",
        "## Dry-run (both meshes)",
        "",
        dryrun_table(results),
        "",
        "## Skipped cells",
        "",
        skips_table(results),
        "",
        "## Roofline (single pod, 16x16 = 256 chips; "
        f"{HW_V5E['peak_flops_bf16'] / 1e12:.0f} TFLOP/s bf16, "
        f"{HW_V5E['hbm_bw'] / 1e9:.0f} GB/s HBM, "
        f"{HW_V5E['ici_bw'] / 1e9:.0f} GB/s ICI per chip)",
        "",
        roofline_table(results),
    ]
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else \
        "benchmarks/dryrun_results.json"
    with open(path) as f:
        results = json.load(f)
    print(summarize(results))


if __name__ == "__main__":
    main()
