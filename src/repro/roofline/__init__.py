"""Roofline analysis from compiled dry-run artifacts."""
from .analysis import HW_V5E, collective_bytes_from_hlo, roofline_terms  # noqa: F401
