"""Three-term roofline from the compiled dry-run (no wall clock on CPU):

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

`compiled.cost_analysis()` runs on the SPMD-*partitioned* module, so its
flops/bytes are per-chip; dividing per-chip quantities by per-chip peaks
is algebraically identical to the global form above.  Collective bytes
are not in cost_analysis: we parse the partitioned HLO and sum operand
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (async `-start` forms counted once, `-done` skipped).
"""
from __future__ import annotations

import re
from typing import Dict, Optional

#: TPU v5e hardware constants (per chip)
HW_V5E = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16 * 1024 ** 3,
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_RESULT_RE = re.compile(r"=\s+\(?([a-z0-9]+)\[([0-9,]*)\]")


def _participants(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))  # [n_groups, group_size]<=[N]
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind OPERAND bytes summed over the module (per device).

    The optimized-HLO printer omits operand types, so operand bytes are
    derived from the result shape: all-reduce / all-to-all /
    collective-permute have operand == result; all-gather's operand is
    result / participants; reduce-scatter's operand is result ×
    participants.  Async `-start` forms counted once, `-done` skipped.
    """
    out = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        for op in _COLL_OPS:
            if f" {op}(" not in line and f" {op}-start(" not in line:
                continue
            rm = _RESULT_RE.search(line)
            if rm is None:
                continue
            result = _shape_bytes(rm.group(1), rm.group(2))
            if result == 0:
                # tuple results (e.g. fused all-reduce of several tensors):
                # sum every shape on the left of the op name
                lhs = line.split(f" {op}", 1)[0]
                result = sum(_shape_bytes(dt, dims)
                             for dt, dims in _SHAPE_RE.findall(lhs))
            p = _participants(line)
            if op == "all-gather":
                operand = result // max(p, 1)
            elif op == "reduce-scatter":
                operand = result * p
            else:
                operand = result
            out[op] += operand
            break
    out["total"] = sum(out[k] for k in _COLL_OPS)
    return out


def extract_cost(cost: Optional[dict]) -> Dict[str, float]:
    """Normalize compiled.cost_analysis() output across backends."""
    c = cost or {}
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    if "bytes" in c:  # already normalized
        return {"flops": float(c.get("flops", 0.0)),
                "bytes": float(c["bytes"])}
    flops = float(c.get("flops", 0.0))
    bytes_accessed = float(c.get("bytes accessed", 0.0))
    if bytes_accessed == 0.0:
        bytes_accessed = sum(
            float(v) for k, v in c.items()
            if isinstance(k, str) and k.startswith("bytes accessed")
        )
    return {"flops": flops, "bytes": bytes_accessed}


def roofline_terms(cost: dict, coll_bytes_per_dev: int, *,
                   hw: dict = HW_V5E) -> Dict[str, float]:
    """All terms in SECONDS (per-chip quantities over per-chip peaks)."""
    c = extract_cost(cost)
    t_compute = c["flops"] / hw["peak_flops_bf16"]
    t_memory = c["bytes"] / hw["hbm_bw"]
    t_coll = coll_bytes_per_dev / hw["ici_bw"]
    dom = max(
        ("compute", t_compute), ("memory", t_memory),
        ("collective", t_coll), key=lambda kv: kv[1],
    )[0]
    total = max(t_compute, t_memory, t_coll)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": dom,
        "bound_s": total,
        "hlo_flops_per_dev": c["flops"],
        "hlo_bytes_per_dev": c["bytes"],
        "coll_bytes_per_dev": float(coll_bytes_per_dev),
    }


def model_flops(cfg, n_params_active: int, tokens: int,
                kind: str = "train") -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference forward)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens
