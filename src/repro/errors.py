"""Top-level alias for :mod:`repro.core.errors` (the typed exception
hierarchy): ``from repro import errors; errors.CapacityError``."""
from .core.errors import (  # noqa: F401
    CapacityError,
    InjectedFault,
    KernelCompileError,
    ResourceError,
    WeldError,
    WeldVerifyError,
)

__all__ = [
    "WeldError", "CapacityError", "ResourceError",
    "KernelCompileError", "InjectedFault", "WeldVerifyError",
]
