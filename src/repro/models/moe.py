"""Mixture-of-Experts layer (deepseek-moe / dbrx) with capacity-bounded
sort-based dispatch.

The dispatch/combine pattern is exactly Weld's groupbuilder/vecmerger
(DESIGN.md §3): group tokens by expert id, scatter-add weighted expert
outputs back to token slots.  `examples/moe_weld_routing.py` shows the
same routing written in Weld IR; here it is implemented directly with the
static-shape lowering the Weld backend uses (sort + segment ops), so the
same algorithm serves both the paper demo and the production layer.

EP sharding: expert-stacked weights carry the EXPERTS logical axis, which
the mesh rules map to the `model` axis.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import layers as L


def _expert_ffn_init(key, cfg, n: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    shape_i = (n, cfg.d_model, d_ff)
    shape_o = (n, d_ff, cfg.d_model)
    return {
        "wi": L.he_init(k1, shape_i, cfg.param_dtype, fan_in=cfg.d_model),
        "wg": L.he_init(k2, shape_i, cfg.param_dtype, fan_in=cfg.d_model),
        "wo": L.he_init(k3, shape_o, cfg.param_dtype, fan_in=d_ff),
    }


def _expert_ffn_specs():
    return {
        "wi": (L.EXPERTS, L.EMBED, L.MLP),
        "wg": (L.EXPERTS, L.EMBED, L.MLP),
        "wo": (L.EXPERTS, L.MLP, L.EMBED),
    }


def moe_init(key, cfg):
    kr, ke, ks = jax.random.split(key, 3)
    p = {
        "router": L.he_init(kr, (cfg.d_model, cfg.n_experts), jnp.float32),
        "experts": _expert_ffn_init(ke, cfg, cfg.n_experts, cfg.expert_d_ff),
    }
    if cfg.n_shared_experts:
        p["shared"] = _expert_ffn_init(
            ks, cfg, cfg.n_shared_experts, cfg.expert_d_ff)
    return p


def moe_specs(cfg):
    s = {
        "router": (L.EMBED, L.EXPERTS),
        "experts": _expert_ffn_specs(),
    }
    if cfg.n_shared_experts:
        s["shared"] = _expert_ffn_specs()
    return s


def _maybe_constrain(x, *spec):
    """Pin intermediate sharding when a mesh context is active (the
    dry-run / production path); no-op in mesh-less unit tests.  Pinning
    the expert axis stops GSPMD from replicating expert compute."""
    try:
        from jax.sharding import PartitionSpec as P

        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def _ffn_batched(w, x, cfg):
    """x: (E, C, d) bucketed tokens; SwiGLU expert FFN."""
    h = jnp.einsum("ecd,edf->ecf", x, w["wi"].astype(x.dtype))
    g = jnp.einsum("ecd,edf->ecf", x, w["wg"].astype(x.dtype))
    h = jax.nn.silu(g) * h
    return jnp.einsum("ecf,efd->ecd", h, w["wo"].astype(x.dtype))


def moe_apply(p, x, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, d).  Returns (out, load_balance_aux_loss)."""
    b, t, d = x.shape
    n_tok = b * t
    e, k = cfg.n_experts, cfg.top_k
    cap = int(cfg.capacity_factor * n_tok * k / e + 0.5)
    cap = max(cap, 4)

    xt = x.reshape(n_tok, d)
    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)                   # (N, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # -- load-balance aux (switch-style) --
    me = probs.mean(axis=0)                                # (E,)
    onehot_top1 = jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32)
    ce = onehot_top1.mean(axis=0)
    aux = e * jnp.sum(me * ce)

    # -- dispatch: sort token-slots by expert, bounded by capacity --
    flat_ids = ids.reshape(-1)                             # (N*k,) int32
    order = jnp.argsort(flat_ids, stable=True)             # token slots by expert
    sorted_ids = flat_ids[order]
    # rank within expert bucket
    seg_starts = jnp.searchsorted(sorted_ids, jnp.arange(e), side="left")
    rank = jnp.arange(n_tok * k) - seg_starts[sorted_ids]
    keep = rank < cap
    bucket_idx = sorted_ids * cap + jnp.where(keep, rank, 0)

    tok_idx = order // k                                   # source token per slot
    gathered = xt[tok_idx]                                 # (N*k, d)
    buckets = jnp.zeros((e * cap, d), x.dtype)
    buckets = buckets.at[bucket_idx].add(
        jnp.where(keep[:, None], gathered, 0).astype(x.dtype)
    )
    buckets = buckets.reshape(e, cap, d)
    if e % 8 == 0:  # EP: experts over the 'model' axis (all-to-all here)
        buckets = _maybe_constrain(buckets, "model", None, None)

    # -- expert compute (EP-sharded einsum over the experts axis) --
    outs = _ffn_batched(p["experts"], buckets, cfg)
    if e % 8 == 0:
        outs = _maybe_constrain(outs, "model", None, None)
    outs = outs.reshape(e * cap, d)

    # -- combine: weighted scatter-add back to tokens (vecmerger) --
    slot_gate = gates.reshape(-1)[order]                   # (N*k,)
    contrib = outs[bucket_idx] * jnp.where(keep, slot_gate, 0.0)[
        :, None].astype(x.dtype)
    combined = jnp.zeros((n_tok, d), x.dtype).at[tok_idx].add(contrib)

    out = combined.reshape(b, t, d)
    if cfg.n_shared_experts:
        sh = _ffn_batched(
            p["shared"],
            jnp.broadcast_to(xt, (cfg.n_shared_experts,) + xt.shape),
            cfg,
        ).sum(0)
        out = out + sh.reshape(b, t, d)
    return out, aux
