"""Shared building blocks: norms, projections, embeddings, RoPE, GQA
attention (train / prefill / decode), MLP variants.

Conventions:
  * params are dict pytrees of jnp arrays; every init has a matching
    `*_specs` returning the same structure with tuples of logical axis
    names (None = replicated axis).
  * activations: (batch, seq, d_model); attention heads kept as a
    separate axis only inside the attention op.
  * dtype policy: params in cfg.param_dtype, math in cfg.dtype with f32
    for softmax/norm accumulation.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kops

# logical axis names (mapped to mesh axes by distributed/sharding.py)
EMBED, MLP, HEADS, KV_HEADS, HEAD_DIM, VOCAB, LAYERS, EXPERTS, STATE = (
    "embed", "mlp", "heads", "kv_heads", "head_dim", "vocab", "layers",
    "experts", "state",
)


def _norm_init(key, shape, dtype):
    return jnp.ones(shape, dtype)


def he_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(cfg):
    return {"scale": jnp.ones((cfg.d_model,), cfg.param_dtype)}


def rmsnorm_specs():
    return {"scale": (EMBED,)}


def rmsnorm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(cfg):
    return {
        "scale": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "bias": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }


def layernorm_specs():
    return {"scale": (EMBED,), "bias": (EMBED,)}


def layernorm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(
        jnp.float32
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embedding_init(key, cfg):
    return {
        "table": he_init(key, (cfg.vocab, cfg.d_model), cfg.param_dtype,
                         fan_in=cfg.d_model),
    }


def embedding_specs():
    return {"table": (VOCAB, EMBED)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x):
    # tied unembedding: logits in f32 for a stable softmax/loss
    return jnp.einsum(
        "btd,vd->btv", x.astype(jnp.float32),
        params["table"].astype(jnp.float32),
    )


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, d/2)
    cos = jnp.cos(ang)[..., None, :]                   # (..., seq, 1, d/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def attention_init(key, cfg):
    ks = jax.random.split(key, 4)
    hd = cfg.head_dim
    p = {
        "wq": he_init(ks[0], (cfg.d_model, cfg.n_heads, hd), cfg.param_dtype),
        "wk": he_init(ks[1], (cfg.d_model, cfg.n_kv_heads, hd), cfg.param_dtype),
        "wv": he_init(ks[2], (cfg.d_model, cfg.n_kv_heads, hd), cfg.param_dtype),
        "wo": he_init(ks[3], (cfg.n_heads, hd, cfg.d_model), cfg.param_dtype,
                      fan_in=cfg.n_heads * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), cfg.param_dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), cfg.param_dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), cfg.param_dtype)
    return p


def attention_specs(cfg):
    s = {
        "wq": (EMBED, HEADS, HEAD_DIM),
        "wk": (EMBED, KV_HEADS, HEAD_DIM),
        "wv": (EMBED, KV_HEADS, HEAD_DIM),
        "wo": (HEADS, HEAD_DIM, EMBED),
    }
    if cfg.qkv_bias:
        s["bq"] = (HEADS, HEAD_DIM)
        s["bk"] = (KV_HEADS, HEAD_DIM)
        s["bv"] = (KV_HEADS, HEAD_DIM)
    return s


def _qkv(params, x, cfg, positions, rope: bool = True):
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_apply(params, x, cfg, positions=None, causal: bool = True,
                    rope: bool = True, kv_override=None):
    """Full-sequence attention (train/prefill).  Returns (out, (k, v)).

    kv_override: (k, v) from another sequence => cross-attention."""
    b, t, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    if kv_override is None:
        q, k, v = _qkv(params, x, cfg, positions, rope)
    else:
        q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(x.dtype))
        if cfg.qkv_bias:
            q = q + params["bq"].astype(x.dtype)
        if rope:
            q = apply_rope(q, positions, cfg.rope_theta)
        k, v = kv_override
    group = cfg.n_heads // cfg.n_kv_heads
    # (B,T,H,D) -> (B,H,T,D) for the kernel
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    chunk = min(cfg.attn_chunk, kh.shape[2])
    out = jax.vmap(
        lambda qq, kk, vv: kops.attention(
            qq, kk, vv, causal=causal, group=group, chunk=chunk,
            unroll=cfg.scan_unroll,
        )
    )(qh, kh, vh)
    out = out.transpose(0, 2, 1, 3)  # (B,T,H,D)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"].astype(x.dtype))
    return y, (k, v)


def attention_decode(params, x, cfg, cache, pos, rope: bool = True,
                     cross: bool = False):
    """Single-token decode.  x: (B, 1, d); cache: {"k","v"}: (B, S, Hkv, D);
    pos: scalar current position.  Returns (out, new_cache)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    if cross:
        q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(x.dtype))
        if cfg.qkv_bias:
            q = q + params["bq"].astype(x.dtype)
        if rope:
            q = apply_rope(q, positions, cfg.rope_theta)
        k_all, v_all = cache["k"], cache["v"]
        valid = jnp.ones((k_all.shape[1],), bool)
        new_cache = cache
    else:
        q, k, v = _qkv(params, x, cfg, positions, rope)
        k_all = jax.lax.dynamic_update_slice_in_dim(cache["k"],
                                                    k.astype(cache["k"].dtype),
                                                    pos, axis=1)
        v_all = jax.lax.dynamic_update_slice_in_dim(cache["v"],
                                                    v.astype(cache["v"].dtype),
                                                    pos, axis=1)
        valid = jnp.arange(k_all.shape[1]) <= pos
        new_cache = {"k": k_all, "v": v_all}

    group = cfg.n_heads // cfg.n_kv_heads
    qg = q[:, 0].reshape(b, cfg.n_kv_heads, group, cfg.head_dim)
    scores = jnp.einsum(
        "bhgk,bshk->bhgs", qg.astype(jnp.float32),
        k_all.astype(jnp.float32),
    ) * (cfg.head_dim ** -0.5)
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhgs,bshk->bhgk", probs, v_all.astype(jnp.float32))
    ctx = ctx.reshape(b, 1, cfg.n_heads, cfg.head_dim).astype(x.dtype)
    y = jnp.einsum("bthk,hkd->btd", ctx, params["wo"].astype(x.dtype))
    return y, new_cache


def attention_cache_spec(cfg, batch: int, max_seq: int, dtype):
    shp = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shp, dtype),
            "v": jax.ShapeDtypeStruct(shp, dtype)}


def attention_cache_init(cfg, batch: int, max_seq: int, dtype):
    shp = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_variant == "swiglu":
        return {
            "wi": he_init(k1, (cfg.d_model, cfg.d_ff), cfg.param_dtype),
            "wg": he_init(k2, (cfg.d_model, cfg.d_ff), cfg.param_dtype),
            "wo": he_init(k3, (cfg.d_ff, cfg.d_model), cfg.param_dtype,
                          fan_in=cfg.d_ff),
        }
    return {
        "wi": he_init(k1, (cfg.d_model, cfg.d_ff), cfg.param_dtype),
        "wo": he_init(k2, (cfg.d_ff, cfg.d_model), cfg.param_dtype,
                      fan_in=cfg.d_ff),
    }


def mlp_specs(cfg):
    if cfg.mlp_variant == "swiglu":
        return {"wi": (EMBED, MLP), "wg": (EMBED, MLP), "wo": (MLP, EMBED)}
    return {"wi": (EMBED, MLP), "wo": (MLP, EMBED)}


def mlp_apply(params, x, cfg):
    h = jnp.einsum("btd,df->btf", x, params["wi"].astype(x.dtype))
    if cfg.mlp_variant == "swiglu":
        g = jnp.einsum("btd,df->btf", x, params["wg"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    elif cfg.mlp_variant == "relu2":   # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("btf,fd->btd", h, params["wo"].astype(x.dtype))
