"""Uniform model interface used by the launcher, dry-run and tests.

    model = build_model(cfg)
    params = model.init(key)
    loss   = model.loss_fn(params, batch)
    logits, cache = model.prefill(params, batch)
    logits, cache = model.decode_step(params, cache, tokens, pos)

`input_specs(shape, kind)` returns ShapeDtypeStruct stand-ins for every
input (no allocation) — the dry-run lowers against these.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from .encdec import EncDecLM
from .ssm import Zamba2LM
from .transformer import DenseLM
from .vlm import VisionLM
from .xlstm import XLSTMLM


def build_model(cfg: ModelConfig):
    fam = cfg.family
    if fam in ("dense", "moe"):
        return Model(cfg, DenseLM(cfg))
    if fam == "hybrid":
        return Model(cfg, Zamba2LM(cfg))
    if fam == "ssm":
        return Model(cfg, XLSTMLM(cfg))
    if fam == "encdec":
        return Model(cfg, EncDecLM(cfg))
    if fam == "vlm":
        return Model(cfg, VisionLM(cfg))
    raise ValueError(f"unknown family {fam}")


class Model:
    def __init__(self, cfg: ModelConfig, impl):
        self.cfg = cfg
        self.impl = impl

    # -- delegation -------------------------------------------------------------

    def init(self, key):
        return self.impl.init(key)

    def param_specs(self):
        return self.impl.param_specs()

    def loss_fn(self, params, batch):
        return self.impl.loss_fn(params, batch)

    def prefill(self, params, batch):
        return self.impl.prefill(params, batch)

    def decode_step(self, params, cache, tokens, pos):
        return self.impl.decode_step(params, cache, tokens, pos)

    def cache_spec(self, batch: int, max_seq: int):
        return self.impl.cache_spec(batch, max_seq)

    def cache_init(self, batch: int, max_seq: int):
        return self.impl.cache_init(batch, max_seq)

    def cache_axes(self):
        return self.impl.cache_axes()

    # -- shape stand-ins -----------------------------------------------------------

    def input_specs(self, shape: ShapeConfig, kind: str = None) -> Dict:
        """ShapeDtypeStructs for the batch dict of `kind`
        ("train" | "prefill" | "decode")."""
        cfg = self.cfg
        kind = kind or shape.kind
        b, t = shape.global_batch, shape.seq_len
        tok = jnp.int32

        if kind in ("train", "prefill"):
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, t), tok),
            }
            if kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((b, t), tok)
            if cfg.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_frames, cfg.d_model), cfg.act_dtype)
            if cfg.family == "vlm":
                specs["images"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_image_tokens, cfg.d_vision), cfg.act_dtype)
            return specs

        if kind == "decode":
            return {
                "tokens": jax.ShapeDtypeStruct((b, 1), tok),
                "pos": jax.ShapeDtypeStruct((), jnp.int32),
                "cache": self.cache_spec(b, t),
            }
        raise ValueError(kind)

    def input_axes(self, kind: str) -> Dict:
        """Logical axes for each input (batch axis sharded over data)."""
        cfg = self.cfg
        if kind in ("train", "prefill"):
            axes = {"tokens": ("batch", None)}
            if kind == "train":
                axes["labels"] = ("batch", None)
            if cfg.family == "encdec":
                axes["frames"] = ("batch", None, None)
            if cfg.family == "vlm":
                axes["images"] = ("batch", None, None)
            return axes
        if kind == "decode":
            return {
                "tokens": ("batch", None),
                "pos": (),
                "cache": self.cache_axes(),
            }
        raise ValueError(kind)

    def param_count(self, params=None) -> int:
        import math

        if params is None:
            shapes = jax.eval_shape(self.init, jax.random.PRNGKey(0))
            return sum(
                math.prod(l.shape)
                for l in jax.tree_util.tree_leaves(shapes)
            )
        return sum(x.size for x in jax.tree_util.tree_leaves(params))

    def active_param_count(self) -> int:
        """For MoE: params touched per token (6·N_active·D roofline)."""
        cfg = self.cfg
        total = self.param_count()
        if cfg.family != "moe":
            return total
        # subtract the inactive routed-expert fraction
        per_expert = 3 * cfg.d_model * cfg.expert_d_ff
        n_moe_layers = cfg.n_layers - cfg.first_k_dense
        inactive = n_moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
        return total - inactive
