"""xLSTM blocks: mLSTM (matrix memory, chunk-parallelizable) and sLSTM
(scalar memory with hidden-to-hidden recurrence — inherently sequential,
lowered to `lax.scan`; DESIGN.md §8.5).

Simplification (documented): we use sigmoid input/forget gates for mLSTM
instead of the paper's exponential-gate + max-stabilizer, which keeps the
chunked form identical to SSD with per-head decays; the normalizer state
is folded in as an extra value column (v' = [v, 1]), so h = num/den comes
out of one matrix recurrence.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .transformer import norm_fns, stacked_init, stacked_specs, xent_loss


def _dims(cfg):
    nh = cfg.n_heads
    hd = cfg.d_model // nh  # qk and v head dim
    return nh, hd


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg):
    nh, hd = _dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "norm": {"scale": jnp.ones((d,), cfg.param_dtype)},
        "wqkv": L.he_init(k1, (d, 3 * d), cfg.param_dtype),
        "wif": L.he_init(k2, (d, 2 * nh), cfg.param_dtype),
        "if_bias": jnp.concatenate(
            [jnp.zeros((nh,), jnp.float32),
             jnp.full((nh,), 2.0, jnp.float32)]),  # forget bias -> remember
        "wo_gate": L.he_init(k3, (d, d), cfg.param_dtype),
        "out_proj": L.he_init(k4, (d, d), cfg.param_dtype),
    }


def mlstm_specs(cfg):
    return {
        "norm": {"scale": (L.EMBED,)},
        "wqkv": (L.EMBED, L.MLP),
        "wif": (L.EMBED, None),
        "if_bias": (None,),
        "wo_gate": (L.EMBED, L.MLP),
        "out_proj": (L.MLP, L.EMBED),
    }


def _mlstm_gates(p, xn, nh):
    raw = jnp.einsum("btd,dg->btg", xn, p["wif"].astype(xn.dtype)) \
        .astype(jnp.float32) + p["if_bias"]
    i_g = jax.nn.sigmoid(raw[..., :nh])       # (B,T,H)
    f_g = jax.nn.sigmoid(raw[..., nh:])
    return i_g, f_g


def mlstm_apply(p, x, cfg, return_cache: bool = False):
    b, t, d = x.shape
    nh, hd = _dims(cfg)
    xn = L.rmsnorm(p["norm"], x)
    qkv = jnp.einsum("btd,de->bte", xn, p["wqkv"].astype(xn.dtype))
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, t, nh, hd)
    k = k.reshape(b, t, nh, hd) / (hd ** 0.5)
    v = v.reshape(b, t, nh, hd)
    i_g, f_g = _mlstm_gates(p, xn, nh)
    # fold normalizer: v' = [v, 1]
    v1 = jnp.concatenate(
        [v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], axis=-1)

    c = min(cfg.ssm_chunk, t)
    assert t % c == 0
    nc = t // c
    qf = q.reshape(b, nc, c, nh, hd).astype(jnp.float32)
    kf = k.reshape(b, nc, c, nh, hd).astype(jnp.float32)
    vf = v1.reshape(b, nc, c, nh, hd + 1).astype(jnp.float32)
    dac = jnp.log(f_g + 1e-8).reshape(b, nc, c, nh)
    dtc = i_g.reshape(b, nc, c, nh)

    def chunk_step(state, inp):
        qb, kb, vb, dtb, dab = inp
        cum = jnp.cumsum(dab, axis=1)
        total = cum[:, -1:, :]
        wij = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])
        mask = jnp.tril(jnp.ones((c, c), bool))
        wij = jnp.where(mask[None, :, :, None], wij, 0.0)
        qk = jnp.einsum("bihn,bjhn->bijh", qb, kb)
        dtv = vb * dtb[..., None]
        y_intra = jnp.einsum("bijh,bijh,bjhp->bihp", qk, wij, dtv)
        y_inter = jnp.einsum("bihn,bnhp,bih->bihp", qb, state, jnp.exp(cum))
        wlast = jnp.exp(total - cum)
        s_new = jnp.einsum("bjhn,bjh,bjhp->bnhp", kb, wlast, dtv)
        state = jnp.exp(total[:, 0])[:, None, :, None] * state + s_new
        return state, y_intra + y_inter

    init = jnp.zeros((b, hd, nh, hd + 1), jnp.float32)
    xs_t = jax.tree_util.tree_map(
        lambda a: jnp.moveaxis(a, 1, 0), (qf, kf, vf, dtc, dac))
    final_state, ys = jax.lax.scan(chunk_step, init, xs_t,
                                   unroll=bool(cfg.scan_unroll))
    yv = jnp.moveaxis(ys, 0, 1).reshape(b, t, nh, hd + 1)
    num, den = yv[..., :hd], yv[..., hd:]
    h = num / jnp.maximum(jnp.abs(den), 1.0)
    h = h.reshape(b, t, d).astype(x.dtype)
    og = jax.nn.sigmoid(
        jnp.einsum("btd,de->bte", xn, p["wo_gate"].astype(xn.dtype)))
    out = jnp.einsum("bte,ed->btd", h * og, p["out_proj"].astype(x.dtype))
    if return_cache:
        return x + out, {"state": final_state}
    return x + out


def mlstm_decode(p, x, cfg, cache, pos):
    b, _, d = x.shape
    nh, hd = _dims(cfg)
    xn = L.rmsnorm(p["norm"], x)
    qkv = jnp.einsum("btd,de->bte", xn, p["wqkv"].astype(xn.dtype))[:, 0]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, nh, hd).astype(jnp.float32)
    k = (k.reshape(b, nh, hd) / (hd ** 0.5)).astype(jnp.float32)
    v = v.reshape(b, nh, hd).astype(jnp.float32)
    v1 = jnp.concatenate([v, jnp.ones((b, nh, 1), jnp.float32)], axis=-1)
    i_g, f_g = _mlstm_gates(p, xn, nh)
    i1, f1 = i_g[:, 0], f_g[:, 0]             # (B,H)
    state = cache["state"]                    # (B, hd, H, hd+1)
    state = f1[:, None, :, None] * state + jnp.einsum(
        "bhn,bhp->bnhp", k, v1 * i1[..., None])
    yv = jnp.einsum("bhn,bnhp->bhp", q, state)
    num, den = yv[..., :hd], yv[..., hd:]
    h = (num / jnp.maximum(jnp.abs(den), 1.0)).reshape(b, 1, d).astype(x.dtype)
    og = jax.nn.sigmoid(
        jnp.einsum("btd,de->bte", xn, p["wo_gate"].astype(xn.dtype)))
    out = jnp.einsum("bte,ed->btd", h * og, p["out_proj"].astype(x.dtype))
    return x + out, {"state": state}


def mlstm_cache_spec(cfg, batch):
    nh, hd = _dims(cfg)
    return {"state": jax.ShapeDtypeStruct((batch, hd, nh, hd + 1),
                                          jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM block (sequential scan)
# ---------------------------------------------------------------------------


def slstm_init(key, cfg):
    nh, hd = _dims(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "norm": {"scale": jnp.ones((d,), cfg.param_dtype)},
        "wx": L.he_init(k1, (d, 4 * d), cfg.param_dtype),       # z i f o
        "rh": L.he_init(k2, (nh, hd, 4 * hd), cfg.param_dtype,
                        fan_in=hd),                              # block-diag
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "out_proj": L.he_init(k3, (d, d), cfg.param_dtype),
    }


def slstm_specs(cfg):
    return {
        "norm": {"scale": (L.EMBED,)},
        "wx": (L.EMBED, L.MLP),
        "rh": (L.HEADS, None, None),
        "bias": (None,),
        "out_proj": (L.MLP, L.EMBED),
    }


def _slstm_cell(p, xt, state, cfg):
    """One sLSTM step.  xt: (B, 4d) precomputed Wx; state: (c,n,h)."""
    nh, hd = _dims(cfg)
    c_prev, n_prev, h_prev = state
    b = xt.shape[0]
    hh = h_prev.reshape(b, nh, hd)
    rec = jnp.einsum("bhk,hkg->bhg", hh, p["rh"].astype(h_prev.dtype))
    rec = rec.reshape(b, 4 * nh * hd)
    pre = (xt + rec).astype(jnp.float32) + p["bias"]
    z, i, f, o = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z)
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f)
    o = jax.nn.sigmoid(o)
    c_new = f * c_prev + i * z
    n_new = f * n_prev + i
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new.astype(h_prev.dtype))


def slstm_apply(p, x, cfg, return_cache: bool = False):
    b, t, d = x.shape
    xn = L.rmsnorm(p["norm"], x)
    wx = jnp.einsum("btd,dg->btg", xn, p["wx"].astype(xn.dtype))

    def step(state, xt):
        new = _slstm_cell(p, xt, state, cfg)
        return new, new[2]

    init = (jnp.zeros((b, d), jnp.float32), jnp.zeros((b, d), jnp.float32),
            jnp.zeros((b, d), x.dtype))
    state, hs = jax.lax.scan(step, init, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    out = jnp.einsum("btd,de->bte", h, p["out_proj"].astype(x.dtype))
    if return_cache:
        return x + out, {"c": state[0], "n": state[1], "h": state[2]}
    return x + out


def slstm_decode(p, x, cfg, cache, pos):
    xn = L.rmsnorm(p["norm"], x)
    wx = jnp.einsum("btd,dg->btg", xn, p["wx"].astype(xn.dtype))[:, 0]
    state = (cache["c"], cache["n"], cache["h"])
    c, n, h = _slstm_cell(p, wx, state, cfg)
    out = jnp.einsum("bd,de->be", h.astype(x.dtype),
                     p["out_proj"].astype(x.dtype))[:, None, :]
    return x + out, {"c": c, "n": n, "h": h}


def slstm_cache_spec(cfg, batch):
    d = cfg.d_model
    return {"c": jax.ShapeDtypeStruct((batch, d), jnp.float32),
            "n": jax.ShapeDtypeStruct((batch, d), jnp.float32),
            "h": jax.ShapeDtypeStruct((batch, d), jnp.dtype(cfg.dtype))}


# ---------------------------------------------------------------------------
# Full model: mLSTM stack with sLSTM every `slstm_every` positions
# ---------------------------------------------------------------------------


class XLSTMLM:
    def __init__(self, cfg):
        self.cfg = cfg
        k = cfg.slstm_every
        self.slstm_idx = [i for i in range(cfg.n_layers)
                          if k and (i % k == k - 1)]
        self.mlstm_idx = [i for i in range(cfg.n_layers)
                          if i not in self.slstm_idx]

    def init(self, key):
        cfg = self.cfg
        km, ks, ke = jax.random.split(key, 3)
        return {
            "embed": L.embedding_init(ke, cfg),
            "mlstm_layers": stacked_init(
                lambda k: mlstm_init(k, cfg), km, len(self.mlstm_idx)),
            "slstm_layers": stacked_init(
                lambda k: slstm_init(k, cfg), ks, max(len(self.slstm_idx), 1)),
            "final_norm": {"scale": jnp.ones((cfg.d_model,), cfg.param_dtype)},
        }

    def param_specs(self):
        cfg = self.cfg
        return {
            "embed": L.embedding_specs(),
            "mlstm_layers": stacked_specs(mlstm_specs(cfg)),
            "slstm_layers": stacked_specs(slstm_specs(cfg)),
            "final_norm": {"scale": (L.EMBED,)},
        }

    def _forward(self, p, x, collect=False):
        """Python-unrolled interleave of the two scans: contiguous mLSTM
        runs are scanned; sLSTM layers interleave between runs."""
        cfg = self.cfg
        caches_m, caches_s = [], []
        mi = si = 0
        i = 0
        while i < cfg.n_layers:
            run = 0
            while (i + run) < cfg.n_layers and (i + run) in self.mlstm_idx:
                run += 1
            if run:
                grp = jax.tree_util.tree_map(
                    lambda a: a[mi: mi + run], p["mlstm_layers"])

                def body(h, lp):
                    out, c = mlstm_apply(lp, h, cfg, return_cache=True)
                    return out, c

                body_fn = jax.checkpoint(body) if cfg.remat else body
                x, cs = jax.lax.scan(body_fn, x, grp,
                                     unroll=bool(cfg.scan_unroll))
                caches_m.append(cs)
                mi += run
                i += run
            if i < cfg.n_layers:  # an sLSTM layer
                lp = jax.tree_util.tree_map(
                    lambda a: a[si], p["slstm_layers"])
                x, c = slstm_apply(lp, x, cfg, return_cache=True)
                caches_s.append(c)
                si += 1
                i += 1
        cm = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs), *caches_m) if caches_m else None
        csc = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *caches_s) if caches_s else None
        return x, cm, csc

    def loss_fn(self, p, batch):
        cfg = self.cfg
        x = L.embed(p["embed"], batch["tokens"]).astype(cfg.act_dtype)
        x, _, _ = self._forward(p, x)
        x = L.rmsnorm(p["final_norm"], x)
        return xent_loss(L.unembed(p["embed"], x), batch["labels"])

    def prefill(self, p, batch):
        cfg = self.cfg
        x = L.embed(p["embed"], batch["tokens"]).astype(cfg.act_dtype)
        x, cm, cs = self._forward(p, x)
        x = L.rmsnorm(p["final_norm"], x)
        logits = L.unembed(p["embed"], x[:, -1:, :])
        cache = {"mlstm": cm}
        if cs is not None:
            cache["slstm"] = cs
        return logits, cache

    def decode_step(self, p, cache, tokens, pos):
        cfg = self.cfg
        x = L.embed(p["embed"], tokens).astype(cfg.act_dtype)
        new_m, new_s = [], []
        mi = si = 0
        i = 0
        while i < cfg.n_layers:
            run = 0
            while (i + run) < cfg.n_layers and (i + run) in self.mlstm_idx:
                run += 1
            if run:
                grp = jax.tree_util.tree_map(
                    lambda a: a[mi: mi + run], p["mlstm_layers"])
                gc = jax.tree_util.tree_map(
                    lambda a: a[mi: mi + run], cache["mlstm"])

                def body(h, lp_c):
                    lp, c = lp_c
                    out, nc = mlstm_decode(lp, h, cfg, c, pos)
                    return out, nc

                x, nc = jax.lax.scan(body, x, (grp, gc),
                                     unroll=bool(cfg.scan_unroll))
                new_m.append(nc)
                mi += run
                i += run
            if i < cfg.n_layers:
                lp = jax.tree_util.tree_map(lambda a: a[si], p["slstm_layers"])
                sc = jax.tree_util.tree_map(lambda a: a[si], cache["slstm"])
                x, nc = slstm_decode(lp, x, cfg, sc, pos)
                new_s.append(nc)
                si += 1
                i += 1
        x = L.rmsnorm(p["final_norm"], x)
        logits = L.unembed(p["embed"], x)
        new_cache = {"mlstm": jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs), *new_m)}
        if new_s:
            new_cache["slstm"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_s)
        return logits, new_cache

    def cache_spec(self, batch, max_seq):
        cfg = self.cfg
        m_one = mlstm_cache_spec(cfg, batch)
        out = {"mlstm": jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(
                (len(self.mlstm_idx),) + s.shape, s.dtype), m_one)}
        if self.slstm_idx:
            s_one = slstm_cache_spec(cfg, batch)
            out["slstm"] = jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(
                    (len(self.slstm_idx),) + s.shape, s.dtype), s_one)
        return out

    def cache_init(self, batch, max_seq):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_spec(batch, max_seq))

    def cache_axes(self):
        out = {"mlstm": {"state": (None, "batch", None, L.HEADS, None)}}
        if self.slstm_idx:
            out["slstm"] = {"c": (None, "batch", L.EMBED),
                            "n": (None, "batch", L.EMBED),
                            "h": (None, "batch", L.EMBED)}
        return out
