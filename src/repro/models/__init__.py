"""Model zoo: the assigned architectures as composable JAX modules.

Every module is a pair of pure functions (init/apply) over dict pytrees,
with a parallel `specs` tree of *logical axis names* per parameter leaf —
the distribution layer maps logical axes onto the device mesh
(DESIGN.md §5), so architectures declare sharding without mentioning it.
"""
from .api import build_model  # noqa: F401
