"""Llama-3.2-Vision-style VLM backbone: a dense GQA decoder with
cross-attention layers to image patch embeddings every
`cross_attn_every`-th layer.

The vision tower is a STUB per the assignment: `input_specs()` supplies
precomputed patch embeddings (B, n_image_tokens, d_vision), projected to
d_model by a learned matrix.  Layers are organized as scanned
"super-blocks" of (cross_attn_every - 1) self layers + 1 cross layer, so
HLO stays O(1) in depth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .transformer import (block_apply, block_decode, block_init,
                          block_prefill, block_specs, norm_fns, stacked_init,
                          stacked_specs, xent_loss)


def cross_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "norm": {"scale": jnp.ones((cfg.d_model,), cfg.param_dtype)},
        "attn": L.attention_init(k1, cfg),
        "mlp_norm": {"scale": jnp.ones((cfg.d_model,), cfg.param_dtype)},
        "mlp": L.mlp_init(k2, cfg),
        "gate": jnp.zeros((1,), jnp.float32),  # gated cross-attn (llama3.2)
    }


def cross_block_specs(cfg):
    return {
        "norm": {"scale": (L.EMBED,)},
        "attn": L.attention_specs(cfg),
        "mlp_norm": {"scale": (L.EMBED,)},
        "mlp": L.mlp_specs(cfg),
        "gate": (None,),
    }


class VisionLM:
    def __init__(self, cfg):
        self.cfg = cfg
        k = cfg.cross_attn_every
        assert k > 1
        assert cfg.n_layers % k == 0, "n_layers must divide into super-blocks"
        self.n_super = cfg.n_layers // k
        self.self_per_super = k - 1

    # -- params -----------------------------------------------------------------

    def init(self, key):
        cfg = self.cfg
        ke, ks, kc, kp = jax.random.split(key, 4)

        def super_self(k):
            return stacked_init(
                lambda kk: block_init(kk, cfg, moe=False), k,
                self.self_per_super)

        return {
            "embed": L.embedding_init(ke, cfg),
            "img_proj": L.he_init(kp, (cfg.d_vision, cfg.d_model),
                                  cfg.param_dtype, fan_in=cfg.d_vision),
            "self_layers": stacked_init(super_self, ks, self.n_super),
            "cross_layers": stacked_init(
                lambda k: cross_block_init(k, cfg), kc, self.n_super),
            "final_norm": {"scale": jnp.ones((cfg.d_model,),
                                             cfg.param_dtype)},
        }

    def param_specs(self):
        cfg = self.cfg
        return {
            "embed": L.embedding_specs(),
            "img_proj": (None, L.EMBED),
            "self_layers": stacked_specs(
                stacked_specs(block_specs(cfg, moe=False))),
            "cross_layers": stacked_specs(cross_block_specs(cfg)),
            "final_norm": {"scale": (L.EMBED,)},
        }

    # -- blocks -----------------------------------------------------------------

    def _img_tokens(self, p, images):
        return jnp.einsum(
            "bnv,vd->bnd", images.astype(self.cfg.act_dtype),
            p["img_proj"].astype(self.cfg.act_dtype))

    def _cross_apply(self, lp, x, img, cfg):
        xq = L.rmsnorm(lp["norm"], x)
        kc = jnp.einsum("bnd,dhk->bnhk", img,
                        lp["attn"]["wk"].astype(img.dtype))
        vc = jnp.einsum("bnd,dhk->bnhk", img,
                        lp["attn"]["wv"].astype(img.dtype))
        c, _ = L.attention_apply(lp["attn"], xq, cfg, causal=False,
                                 rope=False, kv_override=(kc, vc))
        x = x + jnp.tanh(lp["gate"]).astype(x.dtype) * c
        m = L.mlp_apply(lp["mlp"], L.rmsnorm(lp["mlp_norm"], x), cfg)
        return x + m, (kc, vc)

    # -- entry points --------------------------------------------------------------

    def loss_fn(self, p, batch):
        cfg = self.cfg
        x = L.embed(p["embed"], batch["tokens"]).astype(cfg.act_dtype)
        img = self._img_tokens(p, batch["images"])

        def super_body(h, lp):
            selfs, cross = lp

            def self_body(hh, slp):
                out, _ = block_apply(slp, hh, cfg, moe=False)
                return out, None

            sb = jax.checkpoint(self_body) if cfg.remat else self_body
            h, _ = jax.lax.scan(sb, h, selfs,
                                unroll=bool(cfg.scan_unroll))
            h, _ = self._cross_apply(cross, h, img, cfg)
            return h, None

        body = jax.checkpoint(super_body) if cfg.remat else super_body
        x, _ = jax.lax.scan(body, x, (p["self_layers"], p["cross_layers"]),
                            unroll=bool(cfg.scan_unroll))
        x = L.rmsnorm(p["final_norm"], x)
        return xent_loss(L.unembed(p["embed"], x), batch["labels"])

    def prefill(self, p, batch):
        cfg = self.cfg
        x = L.embed(p["embed"], batch["tokens"]).astype(cfg.act_dtype)
        img = self._img_tokens(p, batch["images"])

        def super_body(h, lp):
            selfs, cross = lp

            def self_body(hh, slp):
                out, kv = block_prefill(slp, hh, cfg, moe=False)
                return out, {"k": kv[0].astype(cfg.act_dtype),
                             "v": kv[1].astype(cfg.act_dtype)}

            h, skv = jax.lax.scan(self_body, h, selfs,
                                  unroll=bool(cfg.scan_unroll))
            h, (kc, vc) = self._cross_apply(cross, h, img, cfg)
            return h, {"self": skv,
                       "cross": {"k": kc.astype(cfg.act_dtype),
                                 "v": vc.astype(cfg.act_dtype)}}

        x, cache = jax.lax.scan(super_body, x,
                                (p["self_layers"], p["cross_layers"]),
                                unroll=bool(cfg.scan_unroll))
        x = L.rmsnorm(p["final_norm"], x)
        logits = L.unembed(p["embed"], x[:, -1:, :])
        return logits, cache

    def decode_step(self, p, cache, tokens, pos):
        cfg = self.cfg
        x = L.embed(p["embed"], tokens).astype(cfg.act_dtype)

        def super_body(h, lp):
            selfs, cross, c = lp

            def self_body(hh, slp_c):
                slp, sc = slp_c
                out, nsc = block_decode(slp, hh, cfg, sc, pos, moe=False)
                return out, nsc

            h, nself = jax.lax.scan(self_body, h, (selfs, c["self"]),
                                    unroll=bool(cfg.scan_unroll))
            xq = L.rmsnorm(cross["norm"], h)
            cr, _ = L.attention_decode(cross["attn"], xq, cfg, c["cross"],
                                       pos, rope=False, cross=True)
            h = h + jnp.tanh(cross["gate"]).astype(h.dtype) * cr
            m = L.mlp_apply(cross["mlp"],
                            L.rmsnorm(cross["mlp_norm"], h), cfg)
            h = h + m
            return h, {"self": nself, "cross": c["cross"]}

        x, new_cache = jax.lax.scan(
            super_body, x, (p["self_layers"], p["cross_layers"], cache),
            unroll=bool(cfg.scan_unroll))
        x = L.rmsnorm(p["final_norm"], x)
        return L.unembed(p["embed"], x), new_cache

    # -- cache -----------------------------------------------------------------

    def cache_spec(self, batch, max_seq):
        cfg = self.cfg
        dt = cfg.act_dtype
        self_shp = (self.n_super, self.self_per_super, batch, max_seq,
                    cfg.n_kv_heads, cfg.head_dim)
        cross_shp = (self.n_super, batch, cfg.n_image_tokens,
                     cfg.n_kv_heads, cfg.head_dim)
        return {
            "self": {"k": jax.ShapeDtypeStruct(self_shp, dt),
                     "v": jax.ShapeDtypeStruct(self_shp, dt)},
            "cross": {"k": jax.ShapeDtypeStruct(cross_shp, dt),
                      "v": jax.ShapeDtypeStruct(cross_shp, dt)},
        }

    def cache_init(self, batch, max_seq):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_spec(batch, max_seq))

    def cache_axes(self):
        s = (None, None, "batch", None, L.KV_HEADS, L.HEAD_DIM)
        c = (None, "batch", None, L.KV_HEADS, L.HEAD_DIM)
        return {"self": {"k": s, "v": s}, "cross": {"k": c, "v": c}}
