"""Whisper-style encoder-decoder backbone.

Per the assignment the conv/audio frontend is a STUB: `input_specs()`
provides precomputed frame embeddings (B, n_frames, d_model); the
encoder is the transformer stack over those frames (non-causal), the
decoder is causal self-attn + cross-attn.  LayerNorm + GELU + learned
decoder positions (whisper's canonical decoder context is 448; the
decode_32k cell is a stress configuration of the same backbone — noted
in DESIGN.md §7)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .transformer import norm_fns, stacked_init, stacked_specs, xent_loss


def _sinusoid(t: int, d: int):
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def enc_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": L.layernorm_init(cfg),
        "attn": L.attention_init(k1, cfg),
        "mlp_norm": L.layernorm_init(cfg),
        "mlp": L.mlp_init(k2, cfg),
    }


def enc_block_specs(cfg):
    return {
        "attn_norm": L.layernorm_specs(),
        "attn": L.attention_specs(cfg),
        "mlp_norm": L.layernorm_specs(),
        "mlp": L.mlp_specs(cfg),
    }


def dec_block_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_norm": L.layernorm_init(cfg),
        "self_attn": L.attention_init(k1, cfg),
        "cross_norm": L.layernorm_init(cfg),
        "cross_attn": L.attention_init(k2, cfg),
        "mlp_norm": L.layernorm_init(cfg),
        "mlp": L.mlp_init(k3, cfg),
    }


def dec_block_specs(cfg):
    return {
        "self_norm": L.layernorm_specs(),
        "self_attn": L.attention_specs(cfg),
        "cross_norm": L.layernorm_specs(),
        "cross_attn": L.attention_specs(cfg),
        "mlp_norm": L.layernorm_specs(),
        "mlp": L.mlp_specs(cfg),
    }


class EncDecLM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.n_enc = cfg.n_enc_layers or cfg.n_layers
        self.n_dec = cfg.n_layers

    def init(self, key):
        cfg = self.cfg
        ke, kd, kt, kp = jax.random.split(key, 4)
        return {
            "embed": L.embedding_init(kt, cfg),
            "pos": L.he_init(kp, (cfg.max_position, cfg.d_model),
                             cfg.param_dtype, fan_in=cfg.d_model),
            "enc_layers": stacked_init(
                lambda k: enc_block_init(k, cfg), ke, self.n_enc),
            "enc_norm": L.layernorm_init(cfg),
            "dec_layers": stacked_init(
                lambda k: dec_block_init(k, cfg), kd, self.n_dec),
            "dec_norm": L.layernorm_init(cfg),
        }

    def param_specs(self):
        cfg = self.cfg
        return {
            "embed": L.embedding_specs(),
            "pos": (None, L.EMBED),
            "enc_layers": stacked_specs(enc_block_specs(cfg)),
            "enc_norm": L.layernorm_specs(),
            "dec_layers": stacked_specs(dec_block_specs(cfg)),
            "dec_norm": L.layernorm_specs(),
        }

    # -- encoder ---------------------------------------------------------------

    def encode(self, p, frames):
        cfg = self.cfg
        x = frames.astype(cfg.act_dtype)
        x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]

        def body(h, lp):
            a, _ = L.attention_apply(
                lp["attn"], L.layernorm(lp["attn_norm"], h), cfg,
                causal=False, rope=False)
            h = h + a
            m = L.mlp_apply(lp["mlp"], L.layernorm(lp["mlp_norm"], h), cfg)
            return h + m, None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, p["enc_layers"],
                            unroll=bool(cfg.scan_unroll))
        return L.layernorm(p["enc_norm"], x)

    # -- decoder ---------------------------------------------------------------

    def _dec_embed(self, p, tokens, pos0=0):
        cfg = self.cfg
        x = L.embed(p["embed"], tokens).astype(cfg.act_dtype)
        t = tokens.shape[1]
        pos = jax.lax.dynamic_slice_in_dim(
            p["pos"], pos0, t, axis=0) if not isinstance(pos0, int) else \
            p["pos"][pos0: pos0 + t]
        return x + pos.astype(x.dtype)[None]

    def _dec_block(self, lp, x, enc, cfg):
        a, self_kv = L.attention_apply(
            lp["self_attn"], L.layernorm(lp["self_norm"], x), cfg,
            causal=True, rope=False)
        x = x + a
        xq = L.layernorm(lp["cross_norm"], x)
        kc = jnp.einsum("btd,dhk->bthk", enc,
                        lp["cross_attn"]["wk"].astype(enc.dtype))
        vc = jnp.einsum("btd,dhk->bthk", enc,
                        lp["cross_attn"]["wv"].astype(enc.dtype))
        c, _ = L.attention_apply(lp["cross_attn"], xq, cfg, causal=False,
                                 rope=False, kv_override=(kc, vc))
        x = x + c
        m = L.mlp_apply(lp["mlp"], L.layernorm(lp["mlp_norm"], x), cfg)
        return x + m, self_kv, (kc, vc)

    def loss_fn(self, p, batch):
        cfg = self.cfg
        enc = self.encode(p, batch["frames"])
        x = self._dec_embed(p, batch["tokens"])

        def body(h, lp):
            out, _, _ = self._dec_block(lp, h, enc, cfg)
            return out, None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, p["dec_layers"],
                            unroll=bool(cfg.scan_unroll))
        x = L.layernorm(p["dec_norm"], x)
        return xent_loss(L.unembed(p["embed"], x), batch["labels"])

    def prefill(self, p, batch):
        cfg = self.cfg
        enc = self.encode(p, batch["frames"])
        x = self._dec_embed(p, batch["tokens"])

        def body(h, lp):
            out, skv, ckv = self._dec_block(lp, h, enc, cfg)
            return out, {
                "self_k": skv[0].astype(cfg.act_dtype),
                "self_v": skv[1].astype(cfg.act_dtype),
                "cross_k": ckv[0].astype(cfg.act_dtype),
                "cross_v": ckv[1].astype(cfg.act_dtype),
            }

        x, cache = jax.lax.scan(body, x, p["dec_layers"],
                                unroll=bool(cfg.scan_unroll))
        x = L.layernorm(p["dec_norm"], x)
        logits = L.unembed(p["embed"], x[:, -1:, :])
        return logits, cache

    def decode_step(self, p, cache, tokens, pos):
        cfg = self.cfg
        x = self._dec_embed(p, tokens, pos0=pos)

        def body(h, lp_c):
            lp, c = lp_c
            a, nsc = L.attention_decode(
                lp["self_attn"], L.layernorm(lp["self_norm"], h), cfg,
                {"k": c["self_k"], "v": c["self_v"]}, pos, rope=False)
            h = h + a
            xq = L.layernorm(lp["cross_norm"], h)
            cr, _ = L.attention_decode(
                lp["cross_attn"], xq, cfg,
                {"k": c["cross_k"], "v": c["cross_v"]}, pos, rope=False,
                cross=True)
            h = h + cr
            m = L.mlp_apply(lp["mlp"], L.layernorm(lp["mlp_norm"], h), cfg)
            return h + m, {
                "self_k": nsc["k"], "self_v": nsc["v"],
                "cross_k": c["cross_k"], "cross_v": c["cross_v"],
            }

        x, new_cache = jax.lax.scan(body, x, (p["dec_layers"], cache),
                                    unroll=bool(cfg.scan_unroll))
        x = L.layernorm(p["dec_norm"], x)
        return L.unembed(p["embed"], x), new_cache

    def cache_spec(self, batch, max_seq):
        cfg = self.cfg
        hkv = cfg.n_kv_heads
        self_shp = (self.n_dec, batch, max_seq, hkv, cfg.head_dim)
        cross_shp = (self.n_dec, batch, cfg.n_frames, hkv, cfg.head_dim)
        dt = cfg.act_dtype
        return {
            "self_k": jax.ShapeDtypeStruct(self_shp, dt),
            "self_v": jax.ShapeDtypeStruct(self_shp, dt),
            "cross_k": jax.ShapeDtypeStruct(cross_shp, dt),
            "cross_v": jax.ShapeDtypeStruct(cross_shp, dt),
        }

    def cache_init(self, batch, max_seq):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_spec(batch, max_seq))

    def cache_axes(self):
        spec = (None, "batch", None, L.KV_HEADS, L.HEAD_DIM)
        return {k: spec for k in ("self_k", "self_v", "cross_k", "cross_v")}
