"""Dense decoder-only LM (starcoder2 / nemotron / llama / qwen families)
with scan-over-layers (O(1) HLO in depth), remat, GQA + RoPE, and the
three entry points the launcher lowers: loss, prefill, decode_step.

Also hosts the shared scan/stack utilities used by every family.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .moe import moe_apply, moe_init, moe_specs


# ---------------------------------------------------------------------------
# Shared utilities
# ---------------------------------------------------------------------------


def stacked_init(init_fn: Callable, key, n: int):
    """Stack per-layer params on a leading axis via vmap'd init."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def stacked_specs(spec_tree, n_prefix=(None,)):
    """Prepend the layer-stack axis (replicated) to every leaf spec."""
    return jax.tree_util.tree_map(
        lambda s: tuple(n_prefix) + tuple(s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def xent_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy.  logits (B,T,V) f32; labels (B,T)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - ll)


def norm_fns(cfg):
    if cfg.norm == "layernorm":
        return L.layernorm_init, L.layernorm_specs, L.layernorm
    return L.rmsnorm_init, L.rmsnorm_specs, L.rmsnorm


# ---------------------------------------------------------------------------
# Dense / MoE decoder block
# ---------------------------------------------------------------------------


def block_init(key, cfg, moe: bool = False):
    kn1, ka, kn2, km = jax.random.split(key, 4)
    ninit, _, _ = norm_fns(cfg)
    return {
        "attn_norm": ninit(cfg),
        "attn": L.attention_init(ka, cfg),
        "mlp_norm": ninit(cfg),
        "mlp": moe_init(km, cfg) if moe else L.mlp_init(km, cfg),
    }


def block_specs(cfg, moe: bool = False):
    _, nspecs, _ = norm_fns(cfg)
    return {
        "attn_norm": nspecs(),
        "attn": L.attention_specs(cfg),
        "mlp_norm": nspecs(),
        "mlp": moe_specs(cfg) if moe else L.mlp_specs(cfg),
    }


def block_apply(p, x, cfg, moe: bool = False, positions=None):
    _, _, norm = norm_fns(cfg)
    h, _ = L.attention_apply(p["attn"], norm(p["attn_norm"], x), cfg,
                             positions=positions, causal=True,
                             rope=cfg.rope_theta > 0)
    x = x + h
    z = norm(p["mlp_norm"], x)
    if moe:
        h2, aux = moe_apply(p["mlp"], z, cfg)
    else:
        h2, aux = L.mlp_apply(p["mlp"], z, cfg), 0.0
    return x + h2, aux


def block_prefill(p, x, cfg, moe: bool = False):
    _, _, norm = norm_fns(cfg)
    h, kv = L.attention_apply(p["attn"], norm(p["attn_norm"], x), cfg,
                              causal=True, rope=cfg.rope_theta > 0)
    x = x + h
    z = norm(p["mlp_norm"], x)
    h2 = moe_apply(p["mlp"], z, cfg)[0] if moe else L.mlp_apply(z_params := p["mlp"], z, cfg)
    return x + h2, kv


def block_decode(p, x, cfg, cache, pos, moe: bool = False):
    _, _, norm = norm_fns(cfg)
    h, new_cache = L.attention_decode(p["attn"], norm(p["attn_norm"], x),
                                      cfg, cache, pos,
                                      rope=cfg.rope_theta > 0)
    x = x + h
    z = norm(p["mlp_norm"], x)
    h2 = moe_apply(p["mlp"], z, cfg)[0] if moe else L.mlp_apply(p["mlp"], z, cfg)
    return x + h2, new_cache


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


class DenseLM:
    """Also serves MoE LMs (family == "moe"): the first `first_k_dense`
    layers are dense, the rest MoE."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.is_moe = cfg.family == "moe"
        self.n_dense = cfg.first_k_dense if self.is_moe else cfg.n_layers
        self.n_moe = cfg.n_layers - self.n_dense

    # -- params ---------------------------------------------------------------

    def init(self, key):
        cfg = self.cfg
        ke, kd, km, kf = jax.random.split(key, 4)
        ninit, _, _ = norm_fns(cfg)
        p = {"embed": L.embedding_init(ke, cfg), "final_norm": ninit(cfg)}
        if self.n_dense:
            p["dense_layers"] = stacked_init(
                lambda k: block_init(k, cfg, moe=False), kd, self.n_dense)
        if self.n_moe:
            p["moe_layers"] = stacked_init(
                lambda k: block_init(k, cfg, moe=True), km, self.n_moe)
        return p

    def param_specs(self):
        cfg = self.cfg
        _, nspecs, _ = norm_fns(cfg)
        s = {"embed": L.embedding_specs(), "final_norm": nspecs()}
        if self.n_dense:
            s["dense_layers"] = stacked_specs(block_specs(cfg, moe=False))
        if self.n_moe:
            s["moe_layers"] = stacked_specs(block_specs(cfg, moe=True))
        return s

    # -- scan helpers -----------------------------------------------------------

    def _scan_blocks(self, params_key, p, x, moe: bool):
        cfg = self.cfg

        def body(carry, lp):
            h, aux = carry
            out, a = block_apply(lp, h, cfg, moe=moe)
            return (out, aux + a), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = jax.lax.scan(body_fn, (x, 0.0), p[params_key],
                                   unroll=bool(cfg.scan_unroll))
        return x, aux

    # -- entry points -------------------------------------------------------------

    def loss_fn(self, p, batch):
        cfg = self.cfg
        x = L.embed(p["embed"], batch["tokens"]).astype(cfg.act_dtype)
        aux = 0.0
        if self.n_dense:
            x, a = self._scan_blocks("dense_layers", p, x, moe=False)
            aux += a
        if self.n_moe:
            x, a = self._scan_blocks("moe_layers", p, x, moe=True)
            aux += a
        _, _, norm = norm_fns(cfg)
        x = norm(p["final_norm"], x)
        logits = L.unembed(p["embed"], x)
        loss = xent_loss(logits, batch["labels"])
        if self.is_moe:
            loss = loss + 0.01 * aux / cfg.n_layers
        return loss

    def prefill(self, p, batch):
        cfg = self.cfg
        x = L.embed(p["embed"], batch["tokens"]).astype(cfg.act_dtype)
        caches = {}

        def mk_body(moe):
            def body(h, lp):
                out, kv = block_prefill(lp, h, cfg, moe=moe)
                return out, {"k": kv[0].astype(cfg.act_dtype),
                             "v": kv[1].astype(cfg.act_dtype)}
            return jax.checkpoint(body) if cfg.remat else body

        u = bool(cfg.scan_unroll)
        if self.n_dense:
            x, caches["dense"] = jax.lax.scan(
                mk_body(False), x, p["dense_layers"], unroll=u)
        if self.n_moe:
            x, caches["moe"] = jax.lax.scan(mk_body(True), x,
                                            p["moe_layers"], unroll=u)
        _, _, norm = norm_fns(cfg)
        x = norm(p["final_norm"], x)
        logits = L.unembed(p["embed"], x[:, -1:, :])
        return logits, caches

    def decode_step(self, p, cache, tokens, pos):
        """tokens: (B, 1) current token; pos: scalar position index."""
        cfg = self.cfg
        x = L.embed(p["embed"], tokens).astype(cfg.act_dtype)

        def mk_body(moe):
            def body(h, lp_and_cache):
                lp, c = lp_and_cache
                out, nc = block_decode(lp, h, cfg, c, pos, moe=moe)
                return out, nc
            return body

        new_cache = {}
        u = bool(cfg.scan_unroll)
        if self.n_dense:
            x, new_cache["dense"] = jax.lax.scan(
                mk_body(False), x, (p["dense_layers"], cache["dense"]),
                unroll=u)
        if self.n_moe:
            x, new_cache["moe"] = jax.lax.scan(
                mk_body(True), x, (p["moe_layers"], cache["moe"]), unroll=u)
        _, _, norm = norm_fns(cfg)
        x = norm(p["final_norm"], x)
        logits = L.unembed(p["embed"], x)
        return logits, new_cache

    # -- spec helpers ------------------------------------------------------------

    def cache_spec(self, batch: int, max_seq: int):
        cfg = self.cfg
        one = L.attention_cache_spec(cfg, batch, max_seq, cfg.act_dtype)

        def stack(spec, n):
            return jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), spec)

        out = {}
        if self.n_dense:
            out["dense"] = stack(one, self.n_dense)
        if self.n_moe:
            out["moe"] = stack(one, self.n_moe)
        return out

    def cache_init(self, batch: int, max_seq: int):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_spec(batch, max_seq))

    def cache_axes(self):
        """Logical axes for cache leaves: (layers, batch, seq, kv_heads, hd)."""
        spec = (None, "batch", None, L.KV_HEADS, L.HEAD_DIM)
        out = {}
        if self.n_dense:
            out["dense"] = {"k": spec, "v": spec}
        if self.n_moe:
            out["moe"] = {"k": spec, "v": spec}
        return out
