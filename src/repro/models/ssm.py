"""Mamba2 (SSD) blocks and the zamba2 hybrid model.

TPU adaptation (DESIGN.md §8.5): the SSD recurrence is computed in the
*chunked* form — intra-chunk terms are dense matmuls on the MXU, the
inter-chunk state is a `lax.scan` carry — the TPU-native split between
parallel and sequential work.  Chunks are scanned (not materialized all
at once) so live memory is O(B · c² · H) per step, not O(B · T · c · H).

Decode is the O(1) recurrent step on the carried (H, N, P) state — this
is what makes the hybrid/ssm archs eligible for the long_500k cell.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .transformer import norm_fns, stacked_init, stacked_specs, xent_loss


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def _dims(cfg):
    d_inner = 2 * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    return d_inner, nheads, cfg.ssm_head_dim, cfg.ssm_state


def mamba_init(key, cfg):
    d_inner, nh, p_, n = _dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * n + nh
    return {
        "norm": {"scale": jnp.ones((cfg.d_model,), cfg.param_dtype)},
        "in_proj": L.he_init(k1, (cfg.d_model, proj_out), cfg.param_dtype),
        "conv": L.he_init(k2, (4, d_inner + 2 * n), cfg.param_dtype,
                          fan_in=4),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "out_proj": L.he_init(k3, (d_inner, cfg.d_model), cfg.param_dtype,
                              fan_in=d_inner),
    }


def mamba_specs(cfg):
    return {
        "norm": {"scale": (L.EMBED,)},
        "in_proj": (L.EMBED, L.MLP),
        "conv": (None, L.MLP),
        "A_log": (None,),
        "dt_bias": (None,),
        "D": (None,),
        "out_proj": (L.MLP, L.EMBED),
    }


def _split_proj(proj, cfg):
    d_inner, nh, p_, n = _dims(cfg)
    z, xs, bmat, cmat, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n],
        axis=-1,
    )
    return z, xs, bmat, cmat, dt


def _causal_conv(x, w):
    """Depthwise causal conv, width 4.  x: (B,T,C); w: (4,C)."""
    pad = jnp.pad(x, ((0, 0), (3, 0), (0, 0)))
    out = sum(pad[:, i: i + x.shape[1], :] * w[i][None, None, :]
              for i in range(4))
    return out


def _gates(dt_raw, p):
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    da = -jnp.exp(p["A_log"]) * dt          # log decay (negative)
    return dt, da


def mamba_apply(p, x, cfg, return_cache: bool = False):
    """Full-sequence chunked SSD.  x: (B,T,d) -> (B,T,d)
    (or (out, cache) with the final recurrent state when return_cache)."""
    b, t, _ = x.shape
    d_inner, nh, hp, n = _dims(cfg)
    xn = L.rmsnorm(p["norm"], x)
    proj = jnp.einsum("btd,de->bte", xn, p["in_proj"].astype(xn.dtype))
    z, xs, bmat, cmat, dt_raw = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv"].astype(xn.dtype)))
    xs, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
    dt, da = _gates(dt_raw, p)               # (B,T,H)

    c = min(cfg.ssm_chunk, t)
    assert t % c == 0, "seq_len must be a multiple of ssm_chunk"
    nc = t // c
    xh = xs.reshape(b, nc, c, nh, hp).astype(jnp.float32)
    bh = bmat.reshape(b, nc, c, n).astype(jnp.float32)
    ch = cmat.reshape(b, nc, c, n).astype(jnp.float32)
    dtc = dt.reshape(b, nc, c, nh)
    dac = da.reshape(b, nc, c, nh)

    def chunk_step(state, inp):
        xb, bb, cb, dtb, dab = inp            # (b,c,...) one chunk
        cum = jnp.cumsum(dab, axis=1)         # (b,c,H) inclusive
        total = cum[:, -1:, :]                # (b,1,H)
        # intra-chunk: W[i,j,h] = exp(cum_i - cum_j) [j<=i]
        wij = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])
        mask = jnp.tril(jnp.ones((c, c), bool))
        wij = jnp.where(mask[None, :, :, None], wij, 0.0)
        cbij = jnp.einsum("bin,bjn->bij", cb, bb)
        dtx = xb * dtb[..., None]             # (b,c,H,P)
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", cbij, wij, dtx)
        # inter-chunk: y_inter[i] = exp(cum_i) * C_i . S_prev
        y_inter = jnp.einsum("bin,bnhp,bih->bihp",
                             cb, state, jnp.exp(cum))
        # new chunk state: S += sum_j exp(total - cum_j) dt_j B_j (x) x_j
        wlast = jnp.exp(total - cum)          # (b,c,H)
        s_new = jnp.einsum("bjn,bjh,bjhp->bnhp", bb, wlast, dtx)
        state = jnp.exp(total[:, 0])[:, None, :, None] * state + s_new
        return state, y_intra + y_inter

    init = jnp.zeros((b, n, nh, hp), jnp.float32)
    xs_t = jax.tree_util.tree_map(
        lambda a: jnp.moveaxis(a, 1, 0), (xh, bh, ch, dtc, dac))
    final_state, ys = jax.lax.scan(chunk_step, init, xs_t,
                                   unroll=bool(cfg.scan_unroll))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, nh, hp)
    y = y + p["D"][None, None, :, None] * xh.reshape(b, t, nh, hp)
    y = y.reshape(b, t, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(x.dtype))
    if return_cache:
        cache = {"state": final_state,
                 "conv": conv_in[:, -3:, :].astype(x.dtype)}
        return x + out, cache
    return x + out


def mamba_decode(p, x, cfg, cache, pos):
    """Single-token recurrent step.  cache: {"state": (B,N,H,P),
    "conv": (B,3,C)} rolling conv window."""
    b = x.shape[0]
    d_inner, nh, hp, n = _dims(cfg)
    xn = L.rmsnorm(p["norm"], x)
    proj = jnp.einsum("btd,de->bte", xn, p["in_proj"].astype(xn.dtype))
    z, xs, bmat, cmat, dt_raw = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)[:, 0]   # (B,C)
    window = jnp.concatenate([cache["conv"], conv_in[:, None]], axis=1)
    w = p["conv"].astype(xn.dtype)
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, w))
    xs1, b1, c1 = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
    dt, da = _gates(dt_raw[:, 0], p)          # (B,H)

    xhp = xs1.reshape(b, nh, hp).astype(jnp.float32)
    state = cache["state"]
    decay = jnp.exp(da)[:, None, :, None]     # (B,1,H,1)
    upd = jnp.einsum("bn,bhp->bnhp", b1.astype(jnp.float32),
                     xhp * dt[..., None])
    state = decay * state + upd
    y = jnp.einsum("bn,bnhp->bhp", c1.astype(jnp.float32), state)
    y = y + p["D"][None, :, None] * xhp
    y = y.reshape(b, 1, d_inner).astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(x.dtype))
    new_cache = {"state": state, "conv": window[:, 1:]}
    return x + out, new_cache


def mamba_cache_spec(cfg, batch, dtype):
    d_inner, nh, hp, n = _dims(cfg)
    return {
        "state": jax.ShapeDtypeStruct((batch, n, nh, hp), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, 3, d_inner + 2 * n), dtype),
    }


# ---------------------------------------------------------------------------
# zamba2 hybrid: mamba backbone + one SHARED attention block every k layers
# ---------------------------------------------------------------------------


class Zamba2LM:
    """`attn_every` mamba blocks per group, one shared-parameter attention
    block applied between groups (zamba2's parameter-efficient design: the
    attention weights are reused at every invocation; we omit the
    per-invocation LoRA deltas — noted in the config docstring)."""

    def __init__(self, cfg):
        self.cfg = cfg
        assert cfg.attn_every > 0
        self.n_groups = (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every

    def init(self, key):
        cfg = self.cfg
        km, ka, ke, kn = jax.random.split(key, 4)
        return {
            "embed": L.embedding_init(ke, cfg),
            "mamba_layers": stacked_init(
                lambda k: mamba_init(k, cfg), km, cfg.n_layers),
            "shared_attn": {
                "norm": {"scale": jnp.ones((cfg.d_model,), cfg.param_dtype)},
                "attn": L.attention_init(ka, cfg),
                "mlp_norm": {"scale": jnp.ones((cfg.d_model,),
                                               cfg.param_dtype)},
                "mlp": L.mlp_init(kn, cfg),
            },
            "final_norm": {"scale": jnp.ones((cfg.d_model,), cfg.param_dtype)},
        }

    def param_specs(self):
        cfg = self.cfg
        return {
            "embed": L.embedding_specs(),
            "mamba_layers": stacked_specs(mamba_specs(cfg)),
            "shared_attn": {
                "norm": {"scale": (L.EMBED,)},
                "attn": L.attention_specs(cfg),
                "mlp_norm": {"scale": (L.EMBED,)},
                "mlp": L.mlp_specs(cfg),
            },
            "final_norm": {"scale": (L.EMBED,)},
        }

    def _groups(self):
        cfg = self.cfg
        sizes = []
        left = cfg.n_layers
        while left > 0:
            sizes.append(min(cfg.attn_every, left))
            left -= cfg.attn_every
        return sizes

    def _take(self, stacked, lo, hi):
        return jax.tree_util.tree_map(lambda a: a[lo:hi], stacked)

    def loss_fn(self, p, batch):
        cfg = self.cfg
        x = L.embed(p["embed"], batch["tokens"]).astype(cfg.act_dtype)
        lo = 0
        for size in self._groups():
            grp = self._take(p["mamba_layers"], lo, lo + size)
            lo += size

            def body(h, lp):
                return mamba_apply(lp, h, cfg), None

            body_fn = jax.checkpoint(body) if cfg.remat else body
            x, _ = jax.lax.scan(body_fn, x, grp,
                                unroll=bool(cfg.scan_unroll))
            sa = p["shared_attn"]
            h, _ = L.attention_apply(
                sa["attn"], L.rmsnorm(sa["norm"], x), cfg, causal=True,
                rope=True)
            x = x + h
            x = x + L.mlp_apply(sa["mlp"], L.rmsnorm(sa["mlp_norm"], x), cfg)
        x = L.rmsnorm(p["final_norm"], x)
        logits = L.unembed(p["embed"], x)
        return xent_loss(logits, batch["labels"])

    def prefill(self, p, batch):
        cfg = self.cfg
        x = L.embed(p["embed"], batch["tokens"]).astype(cfg.act_dtype)
        ssm_caches, attn_caches = [], []
        lo = 0
        for size in self._groups():
            grp = self._take(p["mamba_layers"], lo, lo + size)
            lo += size

            # harvest final recurrent state per layer for decode handoff
            def body(h, lp):
                out, c = mamba_apply(lp, h, cfg, return_cache=True)
                return out, c

            x, states = jax.lax.scan(body, x, grp,
                                     unroll=bool(cfg.scan_unroll))
            ssm_caches.append(states)
            sa = p["shared_attn"]
            h, kv = L.attention_apply(
                sa["attn"], L.rmsnorm(sa["norm"], x), cfg, causal=True,
                rope=True)
            x = x + h
            x = x + L.mlp_apply(sa["mlp"], L.rmsnorm(sa["mlp_norm"], x), cfg)
            attn_caches.append({"k": kv[0].astype(cfg.act_dtype),
                                "v": kv[1].astype(cfg.act_dtype)})
        x = L.rmsnorm(p["final_norm"], x)
        logits = L.unembed(p["embed"], x[:, -1:, :])
        cache = {
            "ssm": jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs), *ssm_caches),
            "attn": jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *attn_caches),
        }
        return logits, cache

    def decode_step(self, p, cache, tokens, pos):
        cfg = self.cfg
        x = L.embed(p["embed"], tokens).astype(cfg.act_dtype)
        new_ssm, new_attn = [], []
        lo = 0
        gi = 0
        for size in self._groups():
            grp = self._take(p["mamba_layers"], lo, lo + size)
            grp_cache = jax.tree_util.tree_map(
                lambda a: a[lo: lo + size], cache["ssm"])
            lo += size

            def body(h, lp_c):
                lp, c = lp_c
                out, nc = mamba_decode(lp, h, cfg, c, pos)
                return out, nc

            x, nc = jax.lax.scan(body, x, (grp, grp_cache),
                                 unroll=bool(cfg.scan_unroll))
            new_ssm.append(nc)
            sa = p["shared_attn"]
            a_cache = jax.tree_util.tree_map(lambda a: a[gi], cache["attn"])
            h, na = L.attention_decode(
                sa["attn"], L.rmsnorm(sa["norm"], x), cfg, a_cache, pos,
                rope=True)
            x = x + h
            x = x + L.mlp_apply(sa["mlp"], L.rmsnorm(sa["mlp_norm"], x), cfg)
            new_attn.append(na)
            gi += 1
        x = L.rmsnorm(p["final_norm"], x)
        logits = L.unembed(p["embed"], x)
        new_cache = {
            "ssm": jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs), *new_ssm),
            "attn": jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_attn),
        }
        return logits, new_cache

    def cache_spec(self, batch, max_seq):
        cfg = self.cfg
        one = mamba_cache_spec(cfg, batch, cfg.act_dtype)
        ssm = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype),
            one)
        attn_one = L.attention_cache_spec(cfg, batch, max_seq, cfg.act_dtype)
        attn = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((self.n_groups,) + s.shape, s.dtype),
            attn_one)
        return {"ssm": ssm, "attn": attn}

    def cache_init(self, batch, max_seq):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_spec(batch, max_seq))

    def cache_axes(self):
        return {
            "ssm": {"state": (None, "batch", None, None, None),
                    "conv": (None, "batch", None, L.MLP)},
            "attn": {"k": (None, "batch", None, L.KV_HEADS, L.HEAD_DIM),
                     "v": (None, "batch", None, L.KV_HEADS, L.HEAD_DIM)},
        }
