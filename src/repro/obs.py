"""Top-level alias so ``repro.obs.enable()`` works as documented.

The implementation lives in :mod:`repro.core.obs`.
"""
from .core.obs import *  # noqa: F401,F403
from .core.obs import __all__, ledger  # noqa: F401
