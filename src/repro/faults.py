"""Top-level alias for :mod:`repro.core.faults` (deterministic fault
injection): ``from repro import faults; faults.inject(...)``."""
from .core.faults import (  # noqa: F401
    ENV_FAULTS,
    armed,
    capacity_override,
    clear,
    fingerprint,
    fired,
    inject,
    maybe_raise,
    poisoned,
)

__all__ = [
    "ENV_FAULTS", "inject", "clear", "armed", "fired", "fingerprint",
    "maybe_raise", "poisoned", "capacity_override",
]
