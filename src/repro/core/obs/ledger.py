"""Persistent predicted-vs-measured cost ledger.

Every kernelized execution with tracing enabled appends one JSONL record
per kernel launch::

    {"kernel": "group_probe", "dtype": "float64", "n": 262144,
     "bucket": 262144, "predicted_ns": 181000, "measured_ns": 240917,
     "impl": "ref", "params": {"block": 1024}, "ts": 1754600000.0}

The file lives next to the autotune cache (default
``~/.cache/weld-repro/cost_ledger.jsonl``) and is overridable via
``$WELD_COST_LEDGER``.  ``tools/cost_report.py`` summarizes calibration
error per ``(kernel, dtype, size-bucket)`` group — the dataset the
ROADMAP's measured-cost serving gate will train on.

This module deliberately avoids importing the kernelplan/jax stack so
the report CLI can read ledgers from a bare Python interpreter; the
path and bucketing logic mirror ``kernelplan.autotune`` (``ENV_CACHE``,
``MIN_BUCKET``) and must be kept in sync with it.
"""
from __future__ import annotations

import json
import math
import os
import time
import warnings
from typing import Any, Dict, List, Optional

__all__ = [
    "ledger_path",
    "record",
    "read",
    "summarize",
    "format_report",
]

ENV_LEDGER = "WELD_COST_LEDGER"
_ENV_AUTOTUNE_CACHE = "WELD_AUTOTUNE_CACHE"  # autotune.ENV_CACHE
_MIN_BUCKET = 1024  # autotune.MIN_BUCKET


def ledger_path() -> str:
    override = os.environ.get(ENV_LEDGER)
    if override:
        return override
    # default: sit next to the autotune cache so both calibration
    # artifacts live (and get wiped) together
    at = os.environ.get(_ENV_AUTOTUNE_CACHE)
    # abspath first: a bare-filename WELD_AUTOTUNE_CACHE has dirname ""
    # which would silently drop the ledger into whatever cwd is
    base = os.path.dirname(os.path.abspath(at)) if at else os.path.join(
        os.path.expanduser("~"), ".cache", "weld-repro"
    )
    return os.path.join(base, "cost_ledger.jsonl")


def size_bucket(n: int) -> int:
    """Next power of two ≥ n, floored at 1024 (mirrors autotune)."""
    b = _MIN_BUCKET
    while b < n:
        b <<= 1
    return b


def record(kernel: str, dtype: str, n: int, predicted_ns: Optional[int],
           measured_ns: int, impl: Optional[str] = None,
           params: Optional[Dict[str, Any]] = None,
           path: Optional[str] = None) -> Optional[dict]:
    """Append one launch record.  Best-effort: IO failures are swallowed
    so observability can never break an execution."""
    rec = {
        "kernel": kernel,
        "dtype": str(dtype),
        "n": int(n),
        "bucket": size_bucket(int(n)) if n and n > 0 else 0,
        "predicted_ns": int(predicted_ns) if predicted_ns else None,
        "measured_ns": int(measured_ns),
        "impl": impl,
        "params": dict(params) if params else {},
        "ts": time.time(),
    }
    p = path or ledger_path()
    try:
        # the io.ledger failpoint proves the best-effort contract: an
        # injected OSError must drop the record, never the execution
        from .. import faults

        faults.maybe_raise("io.ledger", exc=OSError)
        d = os.path.dirname(p)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(p, "a") as f:
            f.write(json.dumps(rec) + "\n")
    except OSError:
        return None
    return rec


def read(path: Optional[str] = None) -> List[dict]:
    """Load all records, skipping corrupt lines (a crashed writer can
    leave a truncated tail — a torn write must never crash the reader).

    Malformed lines raise ONE RuntimeWarning naming the file and the
    first bad line number (mirroring the autotune corrupt-cache idiom)
    so the torn tail is visible instead of silently shrinking the
    calibration dataset."""
    p = path or ledger_path()
    out: List[dict] = []
    bad = 0
    first_bad = None
    first_err = None
    try:
        with open(p) as f:
            for lineno, line in enumerate(f, start=1):
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except ValueError as e:
                    bad += 1
                    if first_bad is None:
                        first_bad, first_err = lineno, e
                    continue
                if isinstance(rec, dict) and "kernel" in rec:
                    out.append(rec)
    except OSError:
        pass
    if bad:
        warnings.warn(
            f"cost ledger {p} has {bad} malformed line"
            f"{'s' if bad != 1 else ''} (first at line {first_bad}: "
            f"{first_err}); skipping them — likely a writer killed "
            "mid-append; truncate or delete the file to silence this "
            "warning",
            RuntimeWarning, stacklevel=2,
        )
    return out


def _median(xs: List[float]) -> float:
    s = sorted(xs)
    m = len(s) // 2
    return s[m] if len(s) % 2 else (s[m - 1] + s[m]) / 2


def summarize(records: List[dict]) -> List[dict]:
    """Group by (kernel, dtype, bucket); report median predicted/measured
    times, their ratio, and the mean |log2 ratio| calibration error."""
    groups: Dict[tuple, List[dict]] = {}
    for r in records:
        key = (r.get("kernel"), r.get("dtype"), r.get("bucket"))
        groups.setdefault(key, []).append(r)
    rows = []
    for (kernel, dtype, bucket), rs in sorted(groups.items(),
                                              key=lambda kv: str(kv[0])):
        meas = [r["measured_ns"] for r in rs if r.get("measured_ns")]
        pred = [r["predicted_ns"] for r in rs if r.get("predicted_ns")]
        both = [(r["predicted_ns"], r["measured_ns"]) for r in rs
                if r.get("predicted_ns") and r.get("measured_ns")]
        ratios = [m / p for p, m in both if p > 0]
        log2err = [abs(math.log2(x)) for x in ratios if x > 0]
        rows.append({
            "kernel": kernel,
            "dtype": dtype,
            "bucket": bucket,
            "calls": len(rs),
            "predicted_us": round(_median(pred) / 1e3, 2) if pred else None,
            "measured_us": round(_median(meas) / 1e3, 2) if meas else None,
            "ratio": round(_median(ratios), 3) if ratios else None,
            "log2_err": round(sum(log2err) / len(log2err), 3)
            if log2err else None,
        })
    return rows


def format_report(rows: List[dict]) -> str:
    """Fixed-width table of :func:`summarize` rows.  ``ratio`` is
    measured/predicted (>1 ⇒ the roofline is optimistic); ``log2_err``
    is the mean absolute log2 of that ratio (0 = perfectly calibrated,
    1 = off by 2x on average)."""
    hdr = (f"{'kernel':<24} {'dtype':<10} {'bucket':>10} {'calls':>6} "
           f"{'pred_us':>10} {'meas_us':>10} {'ratio':>8} {'log2_err':>9}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        def fmt(v, spec):
            return format(v, spec) if v is not None else "-"
        lines.append(
            f"{r['kernel']:<24} {r['dtype']:<10} {r['bucket']:>10} "
            f"{r['calls']:>6} {fmt(r['predicted_us'], '>10.2f'):>10} "
            f"{fmt(r['measured_us'], '>10.2f'):>10} "
            f"{fmt(r['ratio'], '>8.3f'):>8} {fmt(r['log2_err'], '>9.3f'):>9}"
        )
    return "\n".join(lines)
