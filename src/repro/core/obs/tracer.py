"""weldtrace: a zero-dependency span tracer for the evaluation pipeline.

Spans are nested wall-clock intervals with free-form tags and counters.
Tracing is OFF by default; when disabled, ``span()`` hands back a shared
no-op object so instrumented code pays one flag check per call site.
Enable with ``repro.obs.enable()`` or ``WELD_TRACE=1`` in the
environment.

Finished spans accumulate in a process-global list (pre-order: a span is
registered when it *opens*, its duration is filled in when it closes) and
can be exported as Chrome-trace/Perfetto JSON (``to_chrome``) or a
human-readable tree (``format_tree``).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = [
    "Span",
    "enable",
    "disable",
    "enabled",
    "clear",
    "span",
    "event",
    "mark",
    "spans",
    "spans_since",
    "to_chrome",
    "dump_chrome",
    "format_tree",
]

ENV_TRACE = "WELD_TRACE"


def _env_enabled(env: Optional[dict] = None) -> bool:
    v = (env if env is not None else os.environ).get(ENV_TRACE, "")
    return str(v).strip().lower() not in ("", "0", "false", "no", "off")


class Span:
    """One timed interval.  ``dur_ns`` is None while the span is open."""

    __slots__ = ("name", "tags", "counters", "start_ns", "dur_ns",
                 "depth", "tid")

    def __init__(self, name: str, tags: Optional[Dict[str, Any]] = None,
                 depth: int = 0, tid: int = 0):
        self.name = name
        self.tags: Dict[str, Any] = dict(tags) if tags else {}
        self.counters: Dict[str, float] = {}
        self.start_ns = time.perf_counter_ns()
        self.dur_ns: Optional[int] = None
        self.depth = depth
        self.tid = tid

    def set(self, key: str, value: Any) -> "Span":
        self.tags[key] = value
        return self

    def count(self, key: str, delta: float = 1) -> "Span":
        self.counters[key] = self.counters.get(key, 0) + delta
        return self

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> None:
        _close(self)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        dur = "open" if self.dur_ns is None else f"{self.dur_ns / 1e3:.1f}us"
        return f"Span({self.name!r}, {dur}, tags={self.tags})"


class _NoopSpan:
    """Shared do-nothing span returned while tracing is disabled."""

    __slots__ = ()

    def set(self, key: str, value: Any) -> "_NoopSpan":
        return self

    def count(self, key: str, delta: float = 1) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass

    # mirror Span's readable attrs so callers can poke them unconditionally
    name = ""
    tags: Dict[str, Any] = {}
    counters: Dict[str, float] = {}
    start_ns = 0
    dur_ns = 0
    depth = 0
    tid = 0


NOOP = _NoopSpan()

_enabled = _env_enabled()
_lock = threading.Lock()
_spans: List[Span] = []
_tls = threading.local()


def enable() -> None:
    """Turn tracing on for the whole process."""
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def enabled() -> bool:
    return _enabled


def clear() -> None:
    """Drop all recorded spans (open stacks on other threads survive)."""
    with _lock:
        _spans.clear()


def _stack() -> List[Span]:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def span(name: str, **tags):
    """Open a span.  Use as a context manager::

        with obs.span("optimize", passes=6) as sp:
            ...
            sp.set("iterations", 3)

    Returns the shared no-op span when tracing is disabled.
    """
    if not _enabled:
        return NOOP
    st = _stack()
    sp = Span(name, tags, depth=len(st), tid=threading.get_ident())
    st.append(sp)
    with _lock:
        _spans.append(sp)
    return sp


def _close(sp: Span) -> None:
    sp.dur_ns = time.perf_counter_ns() - sp.start_ns
    st = _stack()
    # tolerate out-of-order exits (exceptions unwind the whole stack)
    while st and st[-1] is not sp:
        st.pop()
    if st:
        st.pop()


def event(name: str, **tags):
    """Record an instantaneous (zero-duration) span."""
    if not _enabled:
        return NOOP
    sp = span(name, **tags)
    sp.dur_ns = 0
    st = _stack()
    if st and st[-1] is sp:
        st.pop()
    return sp


def mark() -> int:
    """A position in the span log; pair with :func:`spans_since`."""
    with _lock:
        return len(_spans)


def spans() -> List[Span]:
    with _lock:
        return list(_spans)


def spans_since(pos: int) -> List[Span]:
    with _lock:
        return list(_spans[pos:])


# ---------------------------------------------------------------- exports

def _args_of(sp: Span) -> Dict[str, Any]:
    args = {}
    for k, v in sp.tags.items():
        try:
            json.dumps(v)
            args[k] = v
        except (TypeError, ValueError):
            args[k] = repr(v)
    for k, v in sp.counters.items():
        args[f"count.{k}"] = v
    return args


def to_chrome(span_list: Optional[List[Span]] = None) -> dict:
    """Chrome-trace ("trace event") JSON object.  Load the dumped file at
    ``chrome://tracing`` or https://ui.perfetto.dev."""
    sl = spans() if span_list is None else span_list
    events = []
    for sp in sl:
        events.append({
            "name": sp.name,
            "ph": "X",
            "ts": sp.start_ns / 1e3,          # Chrome wants microseconds
            "dur": (sp.dur_ns or 0) / 1e3,
            "pid": os.getpid(),
            "tid": sp.tid,
            "args": _args_of(sp),
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def dump_chrome(path: str, span_list: Optional[List[Span]] = None) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome(span_list), f)
    return path


def format_tree(span_list: Optional[List[Span]] = None,
                min_ns: int = 0) -> str:
    """Human-readable indented tree of the recorded spans."""
    sl = spans() if span_list is None else span_list
    lines = []
    base = min((sp.depth for sp in sl), default=0)
    for sp in sl:
        if sp.dur_ns is not None and sp.dur_ns < min_ns and sp.dur_ns > 0:
            continue
        pad = "  " * (sp.depth - base)
        dur = "..." if sp.dur_ns is None else f"{sp.dur_ns / 1e6:10.3f} ms"
        bits = [f"{k}={v}" for k, v in sp.tags.items()]
        bits += [f"{k}={v:g}" for k, v in sp.counters.items()]
        tagtxt = (" [" + ", ".join(bits) + "]") if bits else ""
        lines.append(f"{pad}{sp.name:<{max(1, 40 - len(pad))}} {dur}{tagtxt}")
    return "\n".join(lines)
