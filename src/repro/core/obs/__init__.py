"""weldtrace: spans, Chrome-trace export, and the cost ledger.

Usage::

    from repro.core import obs   # (or: from repro import obs)

    obs.enable()                 # or WELD_TRACE=1 in the environment
    ... run queries ...
    print(obs.format_tree())
    obs.dump_chrome("trace.json")   # load in Perfetto / chrome://tracing

See ``tracer`` for the span API and ``ledger`` for the on-disk
predicted-vs-measured record format.
"""
from . import ledger  # noqa: F401
from .tracer import (  # noqa: F401
    NOOP,
    Span,
    clear,
    disable,
    dump_chrome,
    enable,
    enabled,
    event,
    format_tree,
    mark,
    span,
    spans,
    spans_since,
    to_chrome,
)

__all__ = [
    "NOOP", "Span", "clear", "disable", "dump_chrome", "enable", "enabled",
    "event", "format_tree", "ledger", "mark", "span", "spans",
    "spans_since", "to_chrome",
]
