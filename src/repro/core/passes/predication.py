"""Predication (paper Table 3): transform branches inside loop bodies into
unconditional select instructions.

    if (c) merge(b, v) else b   ==>   merge(b, select(c, v, identity))

Valid for mergers (identity exists for every commutative MERGE_OP) and for
vecmergers (merge identity at a clamped index is a no-op).  Dict-family
builders are NOT predicated: merging a sentinel key would insert it.

On TPU this transform is load-bearing rather than cosmetic: SPMD lanes have
no divergent control flow, so a non-predicated conditional merge would
otherwise force a serial loop.
"""
from __future__ import annotations

from typing import Dict, Optional

from .. import ir
from .. import wtypes as wt


def _identity_expr(ty: wt.WeldType, op: str) -> Optional[ir.Expr]:
    if isinstance(ty, wt.Scalar):
        return ir.Literal(wt.merge_identity(op, ty), ty)
    if isinstance(ty, wt.Struct):
        items = []
        for f in ty.fields:
            it = _identity_expr(f, op)
            if it is None:
                return None
            items.append(it)
        return ir.MakeStruct(tuple(items))
    return None


def _builder_ty_of(e: ir.Expr) -> Optional[wt.BuilderType]:
    try:
        t = ir.typeof(e)
    except Exception:
        return None
    return t if isinstance(t, wt.BuilderType) else None


def predicate(e: ir.Expr, stats: Dict[str, int]) -> ir.Expr:
    def rec(x: ir.Expr) -> ir.Expr:
        x = x.map_children(rec)
        if not isinstance(x, ir.If):
            return x
        t, f = x.on_true, x.on_false
        # normalize: if(c, b, merge(..)) -> if(!c, merge(..), b)
        if isinstance(f, ir.Merge) and not isinstance(t, ir.Merge):
            t, f = f, t
            cond: ir.Expr = ir.UnaryOp("not", x.cond)
        else:
            cond = x.cond
        if not isinstance(t, ir.Merge):
            return x
        if ir.canon_key(f) != ir.canon_key(t.builder):
            return x  # else-branch must be the un-merged builder
        bty = _builder_ty_of(t.builder)
        if isinstance(bty, wt.Merger):
            ident = _identity_expr(bty.elem, bty.op)
            if ident is None:
                return x
            stats["predication"] = stats.get("predication", 0) + 1
            return ir.Merge(t.builder, ir.Select(cond, t.value, ident))
        if isinstance(bty, wt.VecMerger):
            ident = _identity_expr(bty.elem, bty.op)
            if ident is None:
                return x
            stats["predication"] = stats.get("predication", 0) + 1
            val = t.value  # {index, v}
            idx = ir.GetField(val, 0)
            v = ir.GetField(val, 1)
            safe = ir.MakeStruct(
                (
                    ir.Select(cond, idx, ir.Literal(0, wt.I64)),
                    ir.Select(cond, v, ident),
                )
            )
            return ir.Merge(t.builder, safe)
        return x

    return rec(e)
