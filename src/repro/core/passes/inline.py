"""Let-inlining: exposes producer→consumer loop chains to the fusion pass.

A binding `let n = v; body` is inlined when
  * `v` is trivial (Ident/Literal), or
  * `n` is used exactly once in `body` and that use is not under a Lambda
    (inlining into a loop body would re-evaluate `v` every iteration).
"""
from __future__ import annotations

from typing import Dict

from .. import ir


def _count_uses(e: ir.Expr, name: str, in_lambda: bool = False):
    """Returns (total_uses, uses_under_lambda)."""
    total = lam = 0
    stack = [(e, in_lambda)]
    while stack:
        x, under = stack.pop()
        if isinstance(x, ir.Ident):
            if x.name == name:
                total += 1
                lam += 1 if under else 0
            continue
        if isinstance(x, ir.Let) and x.name == name:
            stack.append((x.value, under))
            continue  # shadowed in body
        if isinstance(x, ir.Lambda):
            if any(p.name == name for p in x.params):
                continue
            stack.append((x.body, True))
            continue
        for c in x.children():
            stack.append((c, under))
    return total, lam


def _is_loop_result(e: ir.Expr) -> bool:
    return isinstance(e, ir.Result) and isinstance(e.builder, ir.For)


def _sole_use_is_iter_data(body: ir.Expr, name: str) -> bool:
    """True if the only use of `name` is as the data of a For's Iter —
    the position vertical fusion consumes."""
    hits = []

    def rec(x: ir.Expr):
        if isinstance(x, ir.For):
            for it in x.iters:
                if isinstance(it.data, ir.Ident) and it.data.name == name:
                    hits.append("iter")
                else:
                    rec(it)
            rec(x.builder)
            rec(x.func)
            return
        if isinstance(x, ir.Ident) and x.name == name:
            hits.append("other")
            return
        if isinstance(x, ir.Let) and x.name == name:
            rec(x.value)
            return
        if isinstance(x, ir.Lambda) and any(p.name == name for p in x.params):
            return
        for c in x.children():
            rec(c)

    rec(body)
    return hits == ["iter"]


def inline_lets(e: ir.Expr, stats: Dict[str, int]) -> ir.Expr:
    def rec(x: ir.Expr) -> ir.Expr:
        x = x.map_children(rec)
        if isinstance(x, ir.Let):
            trivial = isinstance(x.value, (ir.Ident, ir.Literal))
            total, under_lam = _count_uses(x.body, x.name)
            if total == 0 and not trivial:
                # dead binding (value is pure in Weld IR) — drop it
                stats["inline.dead"] = stats.get("inline.dead", 0) + 1
                return x.body
            inlinable = trivial or (total == 1 and under_lam == 0)
            if inlinable and _is_loop_result(x.value) and not trivial:
                # keep loops at let-level (horizontal fusion matches the
                # chain) unless the single use is a consumer loop's input,
                # where inlining enables vertical fusion.
                inlinable = _sole_use_is_iter_data(x.body, x.name)
            if inlinable:
                stats["inline.lets"] = stats.get("inline.lets", 0) + 1
                return ir.substitute(x.body, {x.name: x.value})
        return x

    return rec(e)
