"""Loop fusion (paper Table 3).

Two rules, mirroring the paper:

* **Vertical** — `for(result(for(V, vecbuilder, F1)), B, F2)` where the
  consumer iterates over the materialized output of a producer loop: the
  producer's `merge(b1, e)` sites are rewritten to run the consumer body on
  `e` directly, eliminating the intermediate vector entirely.

* **Horizontal** — multiple loops over the *same* iteration space with
  independent builders are combined into one loop over a struct of
  builders (Listing 3), so a single pass over the data produces all
  results ("fuses multiple passes over the same vector").
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .. import ir
from .. import wtypes as wt


# ---------------------------------------------------------------------------
# Vertical fusion
# ---------------------------------------------------------------------------


def _merge_sites(body: ir.Expr, bname: str) -> Optional[List[ir.Merge]]:
    """Collect Merge sites into builder `bname` if the body has the simple
    'builder-flow' shape: an expression over {Ident(b), Merge, If, Let}
    where the builder flows linearly.  Returns None if the body is too
    complex to fuse (nested loops over the builder, builder in Select...)."""
    sites: List[ir.Merge] = []

    def rec(x: ir.Expr) -> bool:
        # returns True if x is a builder-typed expression in the flow
        if isinstance(x, ir.Ident):
            return x.name == bname
        if isinstance(x, ir.Merge):
            if rec(x.builder):
                sites.append(x)
                return True
            return False
        if isinstance(x, ir.If):
            t = rec(x.on_true)
            f = rec(x.on_false)
            return t and f
        if isinstance(x, ir.Let):
            # allow lets of pure values around the flow
            if _uses(x.value, bname):
                return False
            return rec(x.body)
        return False

    ok = rec(body)
    return sites if ok else None


def _uses(e: ir.Expr, name: str) -> bool:
    return any(isinstance(n, ir.Ident) and n.name == name for n in ir.walk(e))


def _merges_unconditionally_once(body: ir.Expr, bname: str) -> bool:
    """True if every control path merges exactly once (map-like)."""

    def rec(x: ir.Expr) -> Optional[int]:
        if isinstance(x, ir.Ident) and x.name == bname:
            return 0
        if isinstance(x, ir.Merge):
            inner = rec(x.builder)
            return None if inner is None else inner + 1
        if isinstance(x, ir.If):
            t, f = rec(x.on_true), rec(x.on_false)
            if t is None or f is None or t != f:
                return None
            return t
        if isinstance(x, ir.Let):
            return rec(x.body)
        return None

    return rec(body) == 1


def try_vertical_fuse(consumer: ir.For, stats: Dict[str, int]) -> Optional[ir.Expr]:
    if len(consumer.iters) != 1 or not consumer.iters[0].is_plain:
        return None
    src = consumer.iters[0].data
    if not isinstance(src, ir.Result):
        return None
    prod = src.builder
    if not isinstance(prod, ir.For):
        return None
    if not isinstance(prod.builder, ir.NewBuilder) or not isinstance(
        prod.builder.ty, wt.VecBuilder
    ):
        return None

    pb, pi, px = prod.func.params
    cb, ci, cx = consumer.func.params
    if _merge_sites(prod.func.body, pb.name) is None:
        return None
    map_like = _merges_unconditionally_once(prod.func.body, pb.name)
    consumer_uses_index = _uses(consumer.func.body, ci.name)
    if consumer_uses_index and not map_like:
        return None  # indices would not align across a filter

    nb = ir.Ident(ir.fresh("b"), ir.typeof(consumer.builder, _builder_env(consumer)))

    def xf(x: ir.Expr) -> ir.Expr:
        """Rewrite the producer body: builder refs become the consumer's
        builder; each merge site becomes an inlined consumer body."""
        if isinstance(x, ir.Ident) and x.name == pb.name:
            return nb
        if isinstance(x, ir.Merge):
            inner = xf(x.builder)
            cbody = ir.rename_binders(
                ir.Lambda((cb, ci, cx), consumer.func.body)
            )
            cb2, ci2, cx2 = cbody.params
            sub = {
                cb2.name: inner,
                cx2.name: x.value,
                ci2.name: pi if map_like else ir.Literal(0, wt.I64),
            }
            return ir.substitute(cbody.body, sub)
        if isinstance(x, ir.If):
            return ir.If(x.cond, xf(x.on_true), xf(x.on_false))
        if isinstance(x, ir.Let):
            return ir.Let(x.name, x.value, xf(x.body))
        raise AssertionError("unreachable: _merge_sites validated the shape")

    new_body = xf(prod.func.body)
    stats["fusion.vertical"] = stats.get("fusion.vertical", 0) + 1
    return ir.For(
        prod.iters,
        consumer.builder,
        ir.Lambda((nb, pi, px), new_body),
    )


def _builder_env(loop: ir.For) -> Dict[str, wt.WeldType]:
    # builder exprs inside loops may reference enclosing params; fall back
    # to Ident-carried types (typeof resolves unknown names from Ident.ty)
    return {}


# ---------------------------------------------------------------------------
# Zip fusion: a consumer iterating MULTIPLE producers (the paper's
# single-pass dataframe traversal: zip of filtered/mapped columns).
# ---------------------------------------------------------------------------


def _classify_producer(it: ir.Iter):
    """Classify one consumer iter: ('raw', iter) | ('map', ...) |
    ('filter', ...).  Producers must be simple vecbuilder loops with a
    single (possibly conditional) merge and no lets."""
    if not it.is_plain:
        return None
    src = it.data
    if not (isinstance(src, ir.Result) and isinstance(src.builder, ir.For)):
        return ("raw", it, None, None, None)
    loop = src.builder
    nb = loop.builder
    if not (isinstance(nb, ir.NewBuilder) and isinstance(nb.ty, wt.VecBuilder)):
        return None
    if not all(i.is_plain for i in loop.iters):
        return None
    pb, pi, px = loop.func.params
    body = loop.func.body
    if _uses(body, pi.name):
        return None
    if isinstance(body, ir.Merge):
        if not (isinstance(body.builder, ir.Ident)
                and body.builder.name == pb.name):
            return None
        return ("map", it, loop, None, body.value)
    if isinstance(body, ir.If) and isinstance(body.on_true, ir.Merge) \
            and isinstance(body.on_false, ir.Ident) \
            and body.on_false.name == pb.name:
        m = body.on_true
        if not (isinstance(m.builder, ir.Ident)
                and m.builder.name == pb.name):
            return None
        return ("filter", it, loop, body.cond, m.value)
    return None


def _normalized_cond_key(cond: ir.Expr, loop: ir.For) -> str:
    """Canonical key of a producer's condition with element-field
    references rewritten to the canonical keys of their SOURCE vectors —
    two producers with equal keys filter in lockstep."""
    px = loop.func.params[2]
    sources = [ir.canon_key(i.data) for i in loop.iters]

    def rewrite(x: ir.Expr) -> ir.Expr:
        if isinstance(x, ir.GetField) and isinstance(x.expr, ir.Ident) \
                and x.expr.name == px.name:
            return ir.Ident(f"<src:{sources[x.index]}>", None)
        if isinstance(x, ir.Ident) and x.name == px.name:
            return ir.Ident(f"<src:{sources[0]}>", None)
        return x.map_children(rewrite)

    return ir.canon_key(rewrite(cond))


def try_zip_fuse(consumer: ir.For, input_shapes,
                 stats: Dict[str, int]) -> Optional[ir.Expr]:
    """Fuse a multi-iter consumer with its (aligned) producers."""
    if len(consumer.iters) < 1:
        return None
    infos = [_classify_producer(it) for it in consumer.iters]
    if any(i is None for i in infos):
        return None
    kinds = {i[0] for i in infos}
    if kinds == {"raw"}:
        return None  # nothing to fuse
    cb, ci, cx = consumer.func.params
    uses_index = _uses(consumer.func.body, ci.name)
    if "filter" in kinds:
        # every stream must be an identically-conditioned filter
        if kinds != {"filter"} or uses_index:
            return None
        keys = {_normalized_cond_key(i[3], i[2]) for i in infos}
        if len(keys) != 1:
            return None
    # all underlying sources must have statically equal lengths (or the
    # consumer has a single producer, where alignment is intrinsic)
    all_src_iters: List[ir.Iter] = []
    for kind, it, loop, cond, val in infos:
        all_src_iters.extend(loop.iters if loop is not None else [it])
    lens = {_static_len(i, input_shapes) for i in all_src_iters}
    if len(infos) > 1 or len(all_src_iters) > 1:
        if None in lens or len(lens) != 1:
            return None

    # union of source iters
    union: List[ir.Iter] = []
    union_keys: List[str] = []

    def upos(it: ir.Iter) -> int:
        key = ir.canon_key(it)
        if key in union_keys:
            return union_keys.index(key)
        union_keys.append(key)
        union.append(it)
        return len(union) - 1

    elem_tys: List[wt.WeldType] = []

    def _ety(it: ir.Iter):
        t = ir.typeof(it.data)
        return t.elem

    # rewritten per-stream value + (single) condition on the union elem
    fx_tys: List[wt.WeldType] = []
    vals: List[ir.Expr] = []
    cond_u: Optional[ir.Expr] = None
    fi = ir.Ident(ir.fresh("i"), wt.I64)

    # placeholder for the union elem (typed after union is complete)
    fx_name = ir.fresh("x")

    def rewrite_stream(expr: ir.Expr, loop: Optional[ir.For],
                       it: ir.Iter) -> ir.Expr:
        if loop is None:  # raw stream: value is the element itself
            p = upos(it)
            return ir.GetField(ir.Ident(fx_name, None), p)
        px = loop.func.params[2]
        pi = loop.func.params[1]
        positions = [upos(i) for i in loop.iters]

        def rec(x: ir.Expr) -> ir.Expr:
            if isinstance(x, ir.GetField) and isinstance(x.expr, ir.Ident) \
                    and x.expr.name == px.name:
                return ir.GetField(ir.Ident(fx_name, None),
                                   positions[x.index])
            if isinstance(x, ir.Ident) and x.name == px.name:
                return ir.GetField(ir.Ident(fx_name, None), positions[0])
            if isinstance(x, ir.Ident) and x.name == pi.name:
                return fi
            return x.map_children(rec)

        return rec(ir.rename_binders(ir.Lambda((), expr)).body)

    for kind, it, loop, cond, val in infos:
        if kind == "raw":
            vals.append(rewrite_stream(None, None, it))
        else:
            vals.append(rewrite_stream(val, loop, it))
            if kind == "filter" and cond_u is None:
                cond_u = rewrite_stream(cond, loop, it)

    if len(union) < 1:
        return None
    if len(union) == 1:
        # single-source union: the loop elem IS the element (no struct)
        union_elem = _ety(union[0])

        def strip(x: ir.Expr) -> ir.Expr:
            if isinstance(x, ir.GetField) and isinstance(x.expr, ir.Ident) \
                    and x.expr.name == fx_name:
                return ir.Ident(fx_name, union_elem)
            return x.map_children(strip)

        vals = [strip(v) for v in vals]
        cond_u = strip(cond_u) if cond_u is not None else None
    else:
        union_elem = wt.Struct(tuple(_ety(i) for i in union))
    fx = ir.Ident(fx_name, union_elem)

    nb = ir.Ident(ir.fresh("b"),
                  ir.typeof(consumer.builder, _builder_env(consumer)))
    celem = vals[0] if len(vals) == 1 else ir.MakeStruct(tuple(vals))
    cbody = ir.rename_binders(ir.Lambda((cb, ci, cx), consumer.func.body))
    cb2, ci2, cx2 = cbody.params
    sub = {cb2.name: nb, cx2.name: celem,
           ci2.name: fi if not uses_index else fi}
    new_body = ir.substitute(cbody.body, sub)
    if cond_u is not None:
        new_body = ir.If(cond_u, new_body, nb)

    # retype the placeholder element refs now that union_elem is known
    def retype(x: ir.Expr) -> ir.Expr:
        if isinstance(x, ir.Ident) and x.name == fx_name:
            return fx
        return x.map_children(retype)

    new_body = retype(new_body)
    stats["fusion.zip"] = stats.get("fusion.zip", 0) + 1
    return ir.For(
        tuple(union),
        consumer.builder,
        ir.Lambda((nb, fi, fx), new_body),
    )


# ---------------------------------------------------------------------------
# Horizontal fusion
# ---------------------------------------------------------------------------


def _same_iters(a: Tuple[ir.Iter, ...], b: Tuple[ir.Iter, ...]) -> bool:
    if len(a) != len(b):
        return False
    return all(ir.canon_key(x) == ir.canon_key(y) for x, y in zip(a, b))


def _fusable_loop(e: ir.Expr) -> Optional[ir.For]:
    """Result(For(..., NewBuilder-or-MakeStruct(NewBuilders), f))"""
    if not isinstance(e, ir.Result):
        return None
    loop = e.builder
    if not isinstance(loop, ir.For):
        return None
    b = loop.builder
    if isinstance(b, ir.NewBuilder):
        return loop
    if isinstance(b, ir.MakeStruct) and all(
        isinstance(i, ir.NewBuilder) for i in b.items
    ):
        return loop
    return None


def _builder_parts(loop: ir.For) -> List[ir.NewBuilder]:
    b = loop.builder
    return list(b.items) if isinstance(b, ir.MakeStruct) else [b]


def _static_len(it: ir.Iter, input_shapes) -> Optional[int]:
    """Statically-known iteration length, if resolvable."""
    if not it.is_plain:
        return None
    d = it.data
    if isinstance(d, ir.Ident) and input_shapes and d.name in input_shapes:
        shp = input_shapes[d.name]
        return int(shp[0]) if len(shp) >= 1 else None
    if isinstance(d, ir.MakeVec):
        return len(d.items)
    return None


def _loops_compatible(a: ir.For, b: ir.For, input_shapes) -> bool:
    """Same iteration space: identical iters, or all iters of both loops
    have statically-equal lengths (sound union fusion)."""
    if _same_iters(a.iters, b.iters):
        return True
    lens = [_static_len(it, input_shapes) for it in a.iters + b.iters]
    return all(l is not None for l in lens) and len(set(lens)) == 1


def try_horizontal_fuse(
    loops: List[Tuple[str, ir.For]],
) -> Optional[Tuple[ir.For, List[Tuple[str, int, int]]]]:
    """Fuse Result(For)s over a compatible iteration space.  `loops` is a
    list of (bound_name, loop).  The fused loop iterates the UNION of the
    input loops' iter sources (deduplicated structurally); each body's
    element accesses are remapped into the union struct.  Returns the
    fused loop and, per input, (name, field_offset, width) to rebuild its
    result."""
    if len(loops) < 2:
        return None

    # union of iter sources (dedup by structure)
    union: List[ir.Iter] = []
    union_keys: List[str] = []
    pos_of: List[List[int]] = []  # per loop: union position per its iter
    for _, loop in loops:
        positions = []
        for it in loop.iters:
            key = ir.canon_key(it)
            if key in union_keys:
                positions.append(union_keys.index(key))
            else:
                union_keys.append(key)
                union.append(it)
                positions.append(len(union) - 1)
        pos_of.append(positions)

    all_builders: List[ir.NewBuilder] = []
    layout: List[Tuple[str, int, int]] = []
    for name, loop in loops:
        parts = _builder_parts(loop)
        layout.append((name, len(all_builders), len(parts)))
        all_builders.extend(parts)

    elem_tys = []
    for it in union:
        try:
            t = ir.typeof(it.data)
        except Exception:
            return None
        if not isinstance(t, wt.Vec):
            return None
        elem_tys.append(t.elem)
    union_elem_ty = (
        elem_tys[0] if len(union) == 1 else wt.Struct(tuple(elem_tys))
    )

    fused_bt = wt.StructBuilder(tuple(nb.ty for nb in all_builders))
    fb = ir.Ident(ir.fresh("b"), fused_bt)
    fi = ir.Ident(ir.fresh("i"), wt.I64)
    fx = ir.Ident(ir.fresh("x"), union_elem_ty)

    def elem_for(positions: List[int]) -> ir.Expr:
        def field(p: int) -> ir.Expr:
            return fx if len(union) == 1 else ir.GetField(fx, p)

        if len(positions) == 1:
            return field(positions[0])
        return ir.MakeStruct(tuple(field(p) for p in positions))

    # Chain the bodies: each consumes its slice of the struct and produces
    # the full updated struct; thread the struct through a let-chain.
    cur: ir.Expr = fb
    bindings: List[Tuple[str, ir.Expr]] = []
    for (name, loop), (_, off, width), positions in zip(loops, layout,
                                                        pos_of):
        f = ir.rename_binders(loop.func)
        b_p, i_p, x_p = f.params
        body = ir.substitute(
            f.body, {i_p.name: fi, x_p.name: elem_for(positions)})
        body = _retarget_into_struct(body, b_p.name, cur, off, width,
                                     len(all_builders))
        nxt = ir.Ident(ir.fresh("bs"), fused_bt)
        bindings.append((nxt.name, body))
        cur = nxt
    fused_body: ir.Expr = cur
    for bname, bval in reversed(bindings):
        fused_body = ir.Let(bname, bval, fused_body)

    fused = ir.For(
        tuple(union),
        ir.MakeStruct(tuple(all_builders)),
        ir.Lambda((fb, fi, fx), fused_body),
    )
    return fused, layout


def _retarget_into_struct(body: ir.Expr, bname: str, struct_expr: ir.Expr,
                          off: int, width: int, total: int) -> ir.Expr:
    """Make `body` (which returns this loop's builder, possibly a struct of
    `width` builders) return the FULL struct of `total` builders instead."""
    # First rewrite builder references to components of struct_expr.
    # Bind struct_expr once to keep linearity.
    s_in = ir.Ident(ir.fresh("sin"), _struct_ty(struct_expr, total))

    def sub_refs(x: ir.Expr) -> ir.Expr:
        if isinstance(x, ir.Ident) and x.name == bname:
            if width == 1:
                return ir.GetField(s_in, off)
            return ir.MakeStruct(
                tuple(ir.GetField(s_in, off + k) for k in range(width))
            )
        if isinstance(x, ir.GetField) and isinstance(x.expr, ir.Ident) \
                and x.expr.name == bname:
            return ir.GetField(s_in, off + x.index)
        if isinstance(x, ir.Lambda):
            if any(p.name == bname for p in x.params):
                return x
            return ir.Lambda(x.params, sub_refs(x.body))
        if isinstance(x, ir.Let):
            return ir.Let(x.name, sub_refs(x.value), sub_refs(x.body))
        return x.map_children(sub_refs)

    new_body = sub_refs(body)
    # result of new_body: builder (width==1) or struct of width builders.
    out = ir.Ident(ir.fresh("out"), None)
    rebuilt_items: List[ir.Expr] = []
    for k in range(total):
        if off <= k < off + width:
            if width == 1:
                rebuilt_items.append(ir.Ident(out.name, None))
            else:
                rebuilt_items.append(ir.GetField(ir.Ident(out.name, None), k - off))
        else:
            rebuilt_items.append(ir.GetField(s_in, k))
    rebuilt = ir.MakeStruct(tuple(rebuilt_items))
    return ir.Let(
        s_in.name, struct_expr, ir.Let(out.name, new_body, rebuilt)
    )


def _struct_ty(e: ir.Expr, total: int):
    try:
        return ir.typeof(e)
    except Exception:
        return None


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def _propagate_lengths(e: ir.Expr, input_shapes) -> Dict[str, tuple]:
    """Extend input shapes with lengths of let-bound map-like loop results
    (a mask column has its source's length, etc.)."""
    known = dict(input_shapes or {})
    cur = e
    while isinstance(cur, ir.Let):
        v = cur.value
        loop = _fusable_loop(v) if isinstance(v, ir.Result) else None
        if loop is not None and isinstance(loop.builder, ir.NewBuilder) \
                and isinstance(loop.builder.ty, wt.VecBuilder):
            pb = loop.func.params[0]
            if _merges_unconditionally_once(loop.func.body, pb.name):
                lens = {_static_len(it, known) for it in loop.iters}
                if None not in lens and len(lens) == 1:
                    known[cur.name] = (lens.pop(),)
        cur = cur.body
    return known


def fuse_loops(e: ir.Expr, stats: Dict[str, int],
               input_shapes=None) -> ir.Expr:
    known = _propagate_lengths(e, input_shapes)
    e = _vertical(e, stats, known)
    e = _horizontal(e, stats, known)
    return e


def _vertical(e: ir.Expr, stats: Dict[str, int],
              input_shapes=None) -> ir.Expr:
    def rec(x: ir.Expr) -> ir.Expr:
        x = x.map_children(rec)
        if isinstance(x, ir.For):
            fused = try_vertical_fuse(x, stats)
            if fused is not None:
                return rec(fused)
            fused = try_zip_fuse(x, input_shapes, stats)
            if fused is not None:
                return rec(fused)
        if isinstance(x, ir.Len):
            # len(result(for(V, vb, map-like))) == len(V)
            inner = x.expr
            if isinstance(inner, ir.Result) and isinstance(inner.builder, ir.For):
                loop = inner.builder
                if isinstance(loop.builder, ir.NewBuilder) and isinstance(
                    loop.builder.ty, wt.VecBuilder
                ):
                    pb = loop.func.params[0]
                    if _merges_unconditionally_once(loop.func.body, pb.name):
                        stats["fusion.len"] = stats.get("fusion.len", 0) + 1
                        return _iter_len(loop.iters[0])
        return x

    return rec(e)


def _iter_len(it: ir.Iter) -> ir.Expr:
    if it.is_plain:
        return ir.Len(it.data)
    start = it.start or ir.Literal(0, wt.I64)
    end = it.end or ir.Len(it.data)
    stride = it.stride or ir.Literal(1, wt.I64)
    span = ir.BinOp("-", end, start)
    # ceil-div
    num = ir.BinOp("+", span, ir.BinOp("-", stride, ir.Literal(1, wt.I64)))
    return ir.BinOp("/", num, stride)


def _horizontal(e: ir.Expr, stats: Dict[str, int],
                input_shapes=None) -> ir.Expr:
    """Find runs of let-bound fusable loops over compatible iteration
    spaces and combine them (classic shape after DAG stitching: one let
    per library operator)."""

    def rec(x: ir.Expr) -> ir.Expr:
        x = x.map_children(rec)
        if not isinstance(x, ir.Let):
            return x
        # collect a maximal run of let-bound fusable loops
        run: List[Tuple[str, ir.For]] = []
        cursor: ir.Expr = x
        while isinstance(cursor, ir.Let):
            loop = _fusable_loop(cursor.value)
            if loop is None:
                break
            # later loops must not depend on earlier results in the run
            if any(_uses(cursor.value, nm) for nm, _ in run):
                break
            run.append((cursor.name, loop))
            cursor = cursor.body
        if len(run) < 2:
            return x
        # group by iteration-space compatibility, preserving order
        groups: List[List[Tuple[str, ir.For]]] = []
        for name, loop in run:
            placed = False
            for g in groups:
                if _loops_compatible(g[0][1], loop, input_shapes):
                    g.append((name, loop))
                    placed = True
                    break
            if not placed:
                groups.append([(name, loop)])
        if all(len(g) < 2 for g in groups):
            return x
        body = cursor
        # rebuild: fused groups first, then leftover singles (order-safe:
        # loops in the run are mutually independent)
        for g in groups:
            if len(g) >= 2:
                fused = try_horizontal_fuse(g)
                if fused is None:
                    continue
                floop, layout = fused
                stats["fusion.horizontal"] = stats.get(
                    "fusion.horizontal", 0
                ) + (len(g) - 1)
                tmp = ir.fresh("hf")
                tmp_ty = ir.typeof(floop).result_type()
                inner = body
                for name, off, width in reversed(layout):
                    if width == 1:
                        val: ir.Expr = ir.GetField(
                            ir.Ident(tmp, tmp_ty), off
                        )
                    else:
                        val = ir.MakeStruct(
                            tuple(
                                ir.GetField(ir.Ident(tmp, tmp_ty), off + k)
                                for k in range(width)
                            )
                        )
                    inner = ir.Let(name, val, inner)
                body = ir.Let(tmp, ir.Result(floop), inner)
            else:
                name, loop = g[0]
                body = ir.Let(name, ir.Result(loop), body)
        return body

    return rec(e)
