"""Common subexpression elimination (paper Table 3).

After DAG stitching, every library operator is a top-level let binding.
Two libraries that independently built the same computation produce two
let-bound values with identical (alpha-invariant) structure; CSE aliases
the later binding to the earlier one, so the computation runs once.  The
shared loop is then further combinable by horizontal fusion.

Builder linearity is preserved: only *completed* values (e.g.
``result(for(...))`` with its own fresh builders) are shared, never open
builder flow.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from .. import ir


def cse(e: ir.Expr, stats: Dict[str, int]) -> ir.Expr:
    def rec(x: ir.Expr, seen: Dict[str, Tuple[str, object]]) -> ir.Expr:
        if isinstance(x, ir.Let):
            value = rec(x.value, seen)
            key = ir.canon_key(value)
            if key in seen and not isinstance(value, (ir.Ident, ir.Literal)):
                prev_name, prev_ty = seen[key]
                stats["cse.hits"] = stats.get("cse.hits", 0) + 1
                alias = ir.Ident(prev_name, prev_ty)
                return rec(
                    ir.substitute(x.body, {x.name: alias}), seen
                )
            try:
                ty = ir.typeof(value)
            except Exception:
                ty = None
            seen2 = dict(seen)
            seen2[key] = (x.name, ty)
            return ir.Let(x.name, value, rec(x.body, seen2))
        if isinstance(x, ir.Lambda):
            # loop bodies are evaluated per-iteration; their duplicates are
            # local and handled by the backend's jaxpr-level sharing.
            return x
        return x.map_children(lambda c: rec(c, seen))

    return rec(e, {})
