"""Weld optimizer (paper §5, Table 3).

Passes are pattern-matching rewrites over the AST, applied in the paper's
static order — loop fusion first, then size analysis, then loop tiling,
then vectorization/predication, finally CSE — with each level's rules
applied repeatedly until the AST no longer changes.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from .. import ir
from .inline import inline_lets
from .fusion import fuse_loops
from .size import size_analysis
from .tiling import raise_tiled_ops
from .predication import predicate
from .cse import cse

#: paper order (vectorization itself happens in the backend; predication is
#: its IR-level enabling transform).
DEFAULT_PASSES = (
    "inline",
    "fusion",
    "size",
    "tiling",
    "predication",
    "cse",
)

_PASS_FNS = {
    "inline": inline_lets,
    "fusion": fuse_loops,
    "size": size_analysis,
    "tiling": raise_tiled_ops,
    "predication": predicate,
    "cse": cse,
}

MAX_FIXPOINT_ITERS = 6


def optimize(
    e: ir.Expr,
    passes: Optional[Sequence[str]] = None,
    stats: Optional[Dict[str, int]] = None,
    input_shapes: Optional[Dict[str, tuple]] = None,
) -> ir.Expr:
    """Run the optimizer; `passes` selects/disables passes (for ablations).

    `input_shapes` (name -> shape), when available, lets horizontal
    fusion soundly merge loops over *different equal-length* vectors
    (the paper's single-pass dataframe traversal)."""
    names = list(passes if passes is not None else DEFAULT_PASSES)
    stats = stats if stats is not None else {}
    from . import fusion as _fusion

    from .. import check, obs

    verifying = check.enabled()
    for it in range(MAX_FIXPOINT_ITERS):
        before = ir.canon_key(e)
        for name in names:
            with obs.span(f"pass.{name}", iteration=it):
                if name == "fusion":
                    e = _fusion.fuse_loops(e, stats,
                                           input_shapes=input_shapes)
                else:
                    e = _PASS_FNS[name](e, stats)
            if verifying:
                check.checkpoint(f"pass.{name}", e, stats=stats,
                                 shapes=input_shapes)
        stats["iterations"] = it + 1
        if ir.canon_key(e) == before:
            break
    return e


def loop_count(e: ir.Expr) -> int:
    """Number of For loops (== passes over data) — the fusion metric."""
    return ir.count_nodes(e, lambda n: isinstance(n, ir.For))
