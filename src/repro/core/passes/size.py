"""Size analysis (paper Table 3): infer output vector sizes statically.

Map-like loops (exactly one unconditional merge per iteration) produce
exactly `len(iter)` elements; their vecbuilders get a `size_hint`, letting
the backend preallocate dense storage (and, on TPU, lower to whole-array
ops with no append machinery at all).
"""
from __future__ import annotations

from typing import Dict

from .. import ir
from .. import wtypes as wt
from .fusion import _merges_unconditionally_once, _iter_len


def size_analysis(e: ir.Expr, stats: Dict[str, int]) -> ir.Expr:
    def rec(x: ir.Expr) -> ir.Expr:
        x = x.map_children(rec)
        if not isinstance(x, ir.For):
            return x
        nb = x.builder
        if not (
            isinstance(nb, ir.NewBuilder)
            and isinstance(nb.ty, wt.VecBuilder)
            and nb.size_hint is None
        ):
            return x
        pb = x.func.params[0]
        if not _merges_unconditionally_once(x.func.body, pb.name):
            return x
        hint = _iter_len(x.iters[0])
        # hints are metadata (preallocation / memory-limit estimation): a
        # hint must be cheap — never duplicate a loop into it
        if any(isinstance(n, ir.For) for n in ir.walk(hint)):
            return x
        stats["size.hints"] = stats.get("size.hints", 0) + 1
        return ir.For(
            x.iters,
            ir.NewBuilder(nb.ty, arg=nb.arg, size_hint=hint),
            x.func,
        )

    return rec(e)
