"""Loop tiling (paper Table 3), adapted for TPU.

On x86 the paper blocks nested loops so a tile of the inner data stays in
cache.  On TPU the equivalent is to (a) *raise* recognized dot-shaped
nested loops onto the MXU (a matmul feeds the systolic array from VMEM in
hardware-managed tiles), and (b) tile explicitly in the Pallas kernels via
BlockSpec, where the kernel author controls VMEM residency.

This pass performs (a): it recognizes

    for(M : vec[vec[T]], vecbuilder,
        (b,i,row) => merge(b, result(for([row, w], merger[+],
                                         (b2,_,xy) => merge(b2, x*y)))))

— the shape Listing 4's ``itertools.map(vecs, v -> numpy.dot(v, x))``
reaches after vertical fusion — and raises it to an internal ``matvec``
node.  The backend lowers raised nodes to ``jnp.dot`` (MXU) or the Pallas
``tiled_matmul`` kernel; without this pass they run as per-row VPU
reductions (the un-tiled form), which benchmarks show is several times
slower for large widths.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from .. import ir
from .. import wtypes as wt


def _match_dot(e: ir.Expr) -> Optional[Tuple[ir.Expr, ir.Expr, wt.Scalar]]:
    """Match result(for([a, b], merger[+], (bb,i,xy) => merge(bb, x*y)))."""
    if not isinstance(e, ir.Result):
        return None
    loop = e.builder
    if not isinstance(loop, ir.For) or len(loop.iters) != 2:
        return None
    if not all(it.is_plain for it in loop.iters):
        return None
    nb = loop.builder
    if not (
        isinstance(nb, ir.NewBuilder)
        and isinstance(nb.ty, wt.Merger)
        and nb.ty.op == "+"
        and nb.arg is None
    ):
        return None
    bb, ii, xy = loop.func.params
    body = loop.func.body
    if not (isinstance(body, ir.Merge) and isinstance(body.builder, ir.Ident)
            and body.builder.name == bb.name):
        return None
    v = body.value
    if not (isinstance(v, ir.BinOp) and v.op == "*"):
        return None
    def _is_field(x, k):
        return (
            isinstance(x, ir.GetField)
            and x.index == k
            and isinstance(x.expr, ir.Ident)
            and x.expr.name == xy.name
        )
    if not (
        (_is_field(v.left, 0) and _is_field(v.right, 1))
        or (_is_field(v.left, 1) and _is_field(v.right, 0))
    ):
        return None
    elem = nb.ty.elem
    if not isinstance(elem, wt.Scalar):
        return None
    return loop.iters[0].data, loop.iters[1].data, elem


def raise_tiled_ops(e: ir.Expr, stats: Dict[str, int]) -> ir.Expr:
    def rec(x: ir.Expr) -> ir.Expr:
        x = x.map_children(rec)
        # vec . vec  ->  dot   (whole Result(For) replaced by a value node)
        m = _match_dot(x)
        if m is not None:
            a, b, elem = m
            stats["tiling.dot"] = stats.get("tiling.dot", 0) + 1
            return ir.CUDF("linalg.dot", (a, b), elem)
        # row-wise dot over a matrix -> matvec (the tiled/MXU form)
        if isinstance(x, ir.Result) and isinstance(x.builder, ir.For):
            mv = _match_matvec(x.builder)
            if mv is not None:
                mat, vec, elem = mv
                stats["tiling.matvec"] = stats.get("tiling.matvec", 0) + 1
                return _matvec(mat, vec, elem)
        return x

    return rec(e)


def _match_matvec(loop: ir.For) -> Optional[Tuple[ir.Expr, ir.Expr, wt.WeldType]]:
    if len(loop.iters) != 1 or not loop.iters[0].is_plain:
        return None
    nb = loop.builder
    if not (isinstance(nb, ir.NewBuilder) and isinstance(nb.ty, wt.VecBuilder)):
        return None
    pb, pi, row = loop.func.params
    body = loop.func.body
    if not (
        isinstance(body, ir.Merge)
        and isinstance(body.builder, ir.Ident)
        and body.builder.name == pb.name
    ):
        return None
    val = body.value
    if not (isinstance(val, ir.CUDF) and val.name == "linalg.dot"):
        return None
    a, b = val.args
    if not (isinstance(a, ir.Ident) and a.name == row.name):
        a, b = b, a
    if not (isinstance(a, ir.Ident) and a.name == row.name):
        return None
    if any(isinstance(n, ir.Ident) and n.name == row.name for n in ir.walk(b)):
        return None
    mat = loop.iters[0].data
    try:
        mt = ir.typeof(mat)
    except Exception:
        return None
    if isinstance(mt, wt.Vec) and isinstance(mt.elem, wt.Vec):
        return mat, b, val.ret_ty
    return None


def _matvec(mat: ir.Expr, vec: ir.Expr, elem: wt.WeldType) -> ir.Expr:
    return ir.CUDF("linalg.matvec", (mat, vec), wt.Vec(elem))
