"""Typed exception hierarchy for the Weld runtime.

Every runtime failure the recovery layer (``core.recovery``) can act on
carries a type, not just a message string:

* :class:`WeldError` — base class for all runtime-raised errors.
* :class:`CapacityError` — a builder/dict capacity was exceeded: the
  negative-count poison convention observed at decode time, or a
  host-side capacity guard (``weldrel.Query.join``).  Subclasses BOTH
  ``RuntimeError`` and ``ValueError`` so the pre-existing catch sites
  (poison decode raised ``RuntimeError``, the join guard raised
  ``ValueError``) keep working unchanged.
* :class:`ResourceError` — an estimated resource budget was breached
  before execution (``memory_limit`` accounting in the backend).
* :class:`KernelCompileError` — a planned accelerator kernel failed to
  stage/compile/launch.  Carries the quarantine key
  ``(kernel, impl, dtype, n)`` so ``kernelplan.quarantine`` can record
  the offender and the recovery layer can fall back to the generic
  lowering.
* :class:`InjectedFault` — raised by an armed ``core.faults`` failpoint
  (deterministic fault injection for tests/CI).
* :class:`WeldVerifyError` — the static verifier (``core.check``) found
  an ill-formed program after an optimizer pass, the kernel planner, or
  a recovery rewrite.  Carries the offending phase and the structured
  diagnostics so callers can pinpoint the pass that broke the IR.

The module is dependency-free on purpose: anything in the runtime may
import it without cycles.  Re-exported at top level as ``repro.errors``.
"""
from __future__ import annotations

from typing import Optional

__all__ = [
    "WeldError",
    "CapacityError",
    "ResourceError",
    "KernelCompileError",
    "InjectedFault",
    "WeldVerifyError",
]


class WeldError(RuntimeError):
    """Base class for all typed Weld runtime errors."""


class CapacityError(WeldError, ValueError):
    """A dictmerger/groupbuilder/vecbuilder capacity was exceeded.

    Raised when decode observes the negative-count poison convention, or
    by host-side capacity guards.  The adaptive recovery ladder
    (``core.recovery``) treats this as retryable: re-stamp capacities
    with geometric growth, then degrade to the generic lowering.
    """


class ResourceError(WeldError):
    """An estimated resource budget (``memory_limit``) would be breached."""


class KernelCompileError(WeldError):
    """A planned kernel failed to stage, compile, or launch.

    ``kernel``/``impl``/``dtype``/``n`` identify the offender for the
    quarantine health file; any may be None when unknown.
    """

    def __init__(self, message: str, *, kernel: Optional[str] = None,
                 impl: Optional[str] = None, dtype: Optional[str] = None,
                 n: Optional[int] = None):
        super().__init__(message)
        self.kernel = kernel
        self.impl = impl
        self.dtype = dtype
        self.n = n


class InjectedFault(WeldError):
    """Raised by an armed deterministic failpoint (``core.faults``)."""


class WeldVerifyError(WeldError):
    """The static verifier rejected a program.

    ``phase`` names the pipeline stage whose output failed (``"input"``,
    ``"pass.fusion"``, ``"kernelplan"``, ``"recovery.regrow"``, ...);
    ``diagnostics`` is the list of :class:`repro.core.check.Diagnostic`
    objects that survived, each naming a code and the offending
    subexpression.
    """

    def __init__(self, message: str, *, phase: Optional[str] = None,
                 diagnostics=None):
        super().__init__(message)
        self.phase = phase
        self.diagnostics = list(diagnostics or [])

    @property
    def codes(self):
        return [d.code for d in self.diagnostics]
