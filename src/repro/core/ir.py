"""Weld intermediate representation (paper §3).

A small, functional, expression-oriented IR: arithmetic, let-bindings,
conditionals, collection lookups, external C-function calls, plus the two
parallel constructs — the `For` loop and builders.

Nodes are frozen dataclasses (hashable, structurally comparable) so the
optimizer can pattern-match and hash-cons subtrees.  All binders introduce
globally-unique names (see `fresh`), which keeps substitution capture-free.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, fields, replace
from typing import Callable, Dict, Optional, Tuple

from . import wtypes as wt
from .wtypes import WeldType, WeldTypeError


_counter = itertools.count()


def fresh(prefix: str = "t") -> str:
    """Globally-unique identifier name."""
    return f"{prefix}%{next(_counter)}"


class Expr:
    """Base class for IR expressions."""

    def children(self) -> Tuple["Expr", ...]:
        out = []
        for f in fields(self):
            v = getattr(self, f.name)
            if isinstance(v, Expr):
                out.append(v)
            elif isinstance(v, tuple):
                out.extend(c for c in v if isinstance(c, Expr))
        return tuple(out)

    def map_children(self, fn: Callable[["Expr"], "Expr"]) -> "Expr":
        changes = {}
        for f in fields(self):
            v = getattr(self, f.name)
            if isinstance(v, Expr):
                nv = fn(v)
                if nv is not v:
                    changes[f.name] = nv
            elif isinstance(v, tuple) and any(isinstance(c, Expr) for c in v):
                nv = tuple(fn(c) if isinstance(c, Expr) else c for c in v)
                if any(a is not b for a, b in zip(nv, v)):
                    changes[f.name] = nv
        return replace(self, **changes) if changes else self

    def __str__(self) -> str:
        from .pretty import pretty

        return pretty(self)


# ---------------------------------------------------------------------------
# Leaf / scalar expressions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Literal(Expr):
    value: object
    ty: wt.Scalar


@dataclass(frozen=True)
class Ident(Expr):
    name: str
    ty: WeldType


@dataclass(frozen=True)
class Let(Expr):
    name: str
    value: Expr
    body: Expr


BINOPS = {
    "+", "-", "*", "/", "%", "min", "max", "pow",
    "==", "!=", "<", "<=", ">", ">=", "&&", "||",
}
CMP_OPS = {"==", "!=", "<", "<=", ">", ">="}

UNARYOPS = {
    "neg", "not", "exp", "log", "sqrt", "erf", "sin", "cos",
    "tanh", "abs", "sigmoid", "floor", "rsqrt",
}


@dataclass(frozen=True)
class BinOp(Expr):
    op: str
    left: Expr
    right: Expr

    def __post_init__(self):
        if self.op not in BINOPS:
            raise WeldTypeError(f"unknown binop {self.op}")


@dataclass(frozen=True)
class UnaryOp(Expr):
    op: str
    expr: Expr

    def __post_init__(self):
        if self.op not in UNARYOPS:
            raise WeldTypeError(f"unknown unaryop {self.op}")


@dataclass(frozen=True)
class Cast(Expr):
    expr: Expr
    ty: wt.Scalar


@dataclass(frozen=True)
class If(Expr):
    """Control-flow conditional (may produce builders)."""

    cond: Expr
    on_true: Expr
    on_false: Expr


@dataclass(frozen=True)
class Select(Expr):
    """Data conditional: both sides evaluated (predication target)."""

    cond: Expr
    on_true: Expr
    on_false: Expr


# ---------------------------------------------------------------------------
# Structs, vectors, dictionaries
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MakeStruct(Expr):
    items: Tuple[Expr, ...]


@dataclass(frozen=True)
class GetField(Expr):
    expr: Expr
    index: int


@dataclass(frozen=True)
class MakeVec(Expr):
    items: Tuple[Expr, ...]
    elem_ty: WeldType


@dataclass(frozen=True)
class Len(Expr):
    expr: Expr


@dataclass(frozen=True)
class Lookup(Expr):
    """vec[i] or dict[k].

    Dict lookups may carry a miss ``default``: ``lookup(d, k, v)`` yields
    the stored value when ``k`` exists and ``v`` otherwise — the
    single-probe form of ``if(keyexists(d,k), lookup(d,k), v)`` that
    left joins lower through (one hash probe, no second pass)."""

    expr: Expr
    index: Expr
    default: Optional[Expr] = None


@dataclass(frozen=True)
class KeyExists(Expr):
    expr: Expr
    key: Expr


@dataclass(frozen=True)
class GroupLookup(Expr):
    """``grouplookup(d, k)``: the group vector for key ``k`` in a
    groupbuilder result (``dict[K, vec[V]]``).  A missing key yields the
    EMPTY vector — the single-pass probe form m:n hash joins iterate
    (a probe row with no build-side match simply expands to zero rows,
    no separate ``keyexists`` pass needed)."""

    expr: Expr
    key: Expr


@dataclass(frozen=True)
class CUDF(Expr):
    """Call to an external (C in the paper; host-registered here) function."""

    name: str
    args: Tuple[Expr, ...]
    ret_ty: WeldType


# ---------------------------------------------------------------------------
# Parallel constructs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Lambda(Expr):
    params: Tuple[Ident, ...]
    body: Expr


@dataclass(frozen=True)
class NewBuilder(Expr):
    ty: wt.BuilderType
    #: optional argument: merger initial value, vecmerger base vector,
    #: dictmerger/groupbuilder capacity literal.
    arg: Optional[Expr] = None
    #: filled by size analysis for vecbuilders with statically-known length.
    size_hint: Optional[Expr] = None


@dataclass(frozen=True)
class Merge(Expr):
    builder: Expr
    value: Expr


@dataclass(frozen=True)
class Result(Expr):
    builder: Expr


@dataclass(frozen=True)
class Iter(Expr):
    """Iteration descriptor: strided view over a vector."""

    data: Expr
    start: Optional[Expr] = None
    end: Optional[Expr] = None
    stride: Optional[Expr] = None

    @property
    def is_plain(self) -> bool:
        return self.start is None and self.end is None and self.stride is None


@dataclass(frozen=True)
class For(Expr):
    """for(iters, builder, (b, i, x) => ...) -> builder"""

    iters: Tuple[Iter, ...]
    builder: Expr
    func: Lambda


# ---------------------------------------------------------------------------
# Kernel calls (planner output)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class KernelCall(Expr):
    """A matched IR subtree lowered onto a registered accelerator kernel.

    Produced only by the kernel planner (``repro.core.kernelplan``) after
    optimization; never built by frames.  ``args`` are ordinary IR
    expressions evaluated by the backend before the kernel runs; ``fns``
    are per-element lambdas (over the loop's ``(i, x)`` params) the
    backend stages into jnp-traceable callables; ``params`` are static
    kwargs baked into the call (hashable, part of the compile-cache key).
    """

    kernel: str
    args: Tuple[Expr, ...]
    ret_ty: WeldType
    params: Tuple[Tuple[str, object], ...] = ()
    fns: Tuple[Lambda, ...] = ()


# ---------------------------------------------------------------------------
# Traversal utilities
# ---------------------------------------------------------------------------


def postorder_map(e: Expr, fn: Callable[[Expr], Expr]) -> Expr:
    """Apply `fn` bottom-up over the tree."""

    def rec(x: Expr) -> Expr:
        return fn(x.map_children(rec))

    return rec(e)


def walk(e: Expr):
    yield e
    for c in e.children():
        yield from walk(c)


def count_nodes(e: Expr, pred=None) -> int:
    return sum(1 for n in walk(e) if pred is None or pred(n))


def free_vars(e: Expr) -> Dict[str, WeldType]:
    out: Dict[str, WeldType] = {}

    def rec(x: Expr, bound: frozenset):
        if isinstance(x, Ident):
            if x.name not in bound:
                out.setdefault(x.name, x.ty)
            return
        if isinstance(x, Let):
            rec(x.value, bound)
            rec(x.body, bound | {x.name})
            return
        if isinstance(x, Lambda):
            inner = bound | {p.name for p in x.params}
            rec(x.body, inner)
            return
        for c in x.children():
            rec(c, bound)

    rec(e, frozenset())
    return out


def substitute(e: Expr, mapping: Dict[str, Expr]) -> Expr:
    """Capture-free substitution (binder names are globally unique)."""
    if not mapping:
        return e

    def rec(x: Expr, mapping: Dict[str, Expr]) -> Expr:
        if isinstance(x, Ident):
            return mapping.get(x.name, x)
        if isinstance(x, Let):
            m2 = {k: v for k, v in mapping.items() if k != x.name}
            return Let(x.name, rec(x.value, mapping), rec(x.body, m2))
        if isinstance(x, Lambda):
            names = {p.name for p in x.params}
            m2 = {k: v for k, v in mapping.items() if k not in names}
            return Lambda(x.params, rec(x.body, m2))
        return x.map_children(lambda c: rec(c, mapping))

    return rec(e, dict(mapping))


def rename_binders(e: Expr) -> Expr:
    """Alpha-rename every binder to a fresh name (used when duplicating
    subtrees, e.g. during fusion, to preserve global binder uniqueness)."""

    def rec(x: Expr, env: Dict[str, str]) -> Expr:
        if isinstance(x, Ident):
            if x.name in env:
                return Ident(env[x.name], x.ty)
            return x
        if isinstance(x, Let):
            nn = fresh(x.name.split("%")[0])
            return Let(nn, rec(x.value, env), rec(x.body, {**env, x.name: nn}))
        if isinstance(x, Lambda):
            new_params = []
            env2 = dict(env)
            for p in x.params:
                nn = fresh(p.name.split("%")[0])
                env2[p.name] = nn
                new_params.append(Ident(nn, p.ty))
            return Lambda(tuple(new_params), rec(x.body, env2))
        return x.map_children(lambda c: rec(c, env))

    return rec(e, {})


# ---------------------------------------------------------------------------
# Alpha-invariant canonical key (CSE, compile cache)
# ---------------------------------------------------------------------------


def canon_key(e: Expr, name_map: Optional[Dict[str, object]] = None) -> str:
    """Structural key, invariant under renaming of bound variables (de
    Bruijn-style).  Free variables keep their names unless `name_map`
    supplies a positional alias (the compile cache passes input positions
    so two rebuilds of the same workflow share one executable)."""
    parts: list = []
    name_map = name_map or {}

    def rec(x: Expr, depth: Dict[str, int], level: int):
        if isinstance(x, Ident):
            if x.name in depth:
                parts.append(f"@{level - depth[x.name]}")
            else:
                parts.append(f"${name_map.get(x.name, x.name)}")
            return
        if isinstance(x, Literal):
            parts.append(f"L{x.value!r}:{x.ty}")
            return
        if isinstance(x, Let):
            parts.append("(let")
            rec(x.value, depth, level)
            rec(x.body, {**depth, x.name: level + 1}, level + 1)
            parts.append(")")
            return
        if isinstance(x, Lambda):
            parts.append(f"(lam{len(x.params)}")
            d2 = dict(depth)
            lvl = level
            for p in x.params:
                lvl += 1
                d2[p.name] = lvl
            rec(x.body, d2, lvl)
            parts.append(")")
            return
        tag = type(x).__name__
        parts.append(f"({tag}")
        for f in fields(x):
            v = getattr(x, f.name)
            if isinstance(v, Expr):
                rec(v, depth, level)
            elif isinstance(v, tuple) and any(isinstance(c, Expr) for c in v):
                parts.append(f"[{len(v)}")
                for c in v:
                    if isinstance(c, Expr):
                        rec(c, depth, level)
                    else:
                        parts.append(f"|{c}")
                parts.append("]")
            else:
                parts.append(f"|{v}")
        parts.append(")")

    rec(e, {}, 0)
    return "".join(parts)


# ---------------------------------------------------------------------------
# Type checking
# ---------------------------------------------------------------------------


def _binop_type(op: str, lt: WeldType, rt: WeldType) -> WeldType:
    if lt != rt:
        raise WeldTypeError(f"binop {op} on mismatched types {lt} vs {rt}")
    if op in CMP_OPS:
        return wt.Bool
    if op in ("&&", "||"):
        if lt != wt.Bool:
            raise WeldTypeError(f"{op} requires bool, got {lt}")
        return wt.Bool
    if not isinstance(lt, wt.Scalar):
        raise WeldTypeError(f"binop {op} on non-scalar {lt}")
    return lt


def typeof(e: Expr, env: Optional[Dict[str, WeldType]] = None) -> WeldType:
    """Whole-program type inference, closed over ``Let``/``Lambda``/``For``
    environments.  On failure the raised :class:`WeldTypeError` carries the
    pretty-printed offending subexpression and the innermost enclosing
    binder name (``err.node`` / ``err.binder`` hold them structurally)."""
    env = dict(env or {})

    def rec(x: Expr, env: Dict[str, WeldType],
            binder: Optional[str] = None) -> WeldType:
        try:
            return _typeof_node(x, env, binder, rec)
        except WeldTypeError as err:
            if getattr(err, "node", None) is None:
                from .pretty import short

                err.node = x
                err.binder = binder
                where = f" [in {binder}]" if binder else ""
                err.args = (f"{err.args[0]}{where} at: {short(x)}",)
            raise

    return rec(e, env)


def _typeof_node(x: Expr, env: Dict[str, WeldType],
                 binder: Optional[str], rec0) -> WeldType:
    def rec(y: Expr, env2, b=None) -> WeldType:
        return rec0(y, env2, b if b is not None else binder)

    if True:
        if isinstance(x, Literal):
            return x.ty
        if isinstance(x, Ident):
            ty = env.get(x.name, x.ty)
            if ty is None:
                raise WeldTypeError(
                    f"identifier {x.name} carries no type and is not "
                    f"bound in the environment"
                )
            return ty
        if isinstance(x, Let):
            vt = rec(x.value, env, x.name)
            return rec(x.body, {**env, x.name: vt}, x.name)
        if isinstance(x, BinOp):
            return _binop_type(x.op, rec(x.left, env), rec(x.right, env))
        if isinstance(x, UnaryOp):
            t = rec(x.expr, env)
            if x.op == "not":
                if t != wt.Bool:
                    raise WeldTypeError(f"not requires bool, got {t}")
                return wt.Bool
            if not isinstance(t, wt.Scalar):
                raise WeldTypeError(f"unary {x.op} on non-scalar {t}")
            return t
        if isinstance(x, Cast):
            rec(x.expr, env)
            return x.ty
        if isinstance(x, (If, Select)):
            ct = rec(x.cond, env)
            if ct != wt.Bool:
                raise WeldTypeError(f"condition must be bool, got {ct}")
            tt = rec(x.on_true, env)
            ft = rec(x.on_false, env)
            if tt != ft:
                raise WeldTypeError(f"branch types differ: {tt} vs {ft}")
            return tt
        if isinstance(x, MakeStruct):
            tys = tuple(rec(i, env) for i in x.items)
            if any(isinstance(t, wt.BuilderType) for t in tys):
                if not all(isinstance(t, wt.BuilderType) for t in tys):
                    raise WeldTypeError("cannot mix builders and values in struct")
                return wt.StructBuilder(tys)  # Listing 3: {merge(bs.0,..), ..}
            return wt.Struct(tys)
        if isinstance(x, GetField):
            st = rec(x.expr, env)
            if isinstance(st, (wt.Struct, wt.StructBuilder)):
                flds = st.fields if isinstance(st, wt.Struct) else st.builders
                if not (0 <= x.index < len(flds)):
                    raise WeldTypeError(
                        f"getfield index {x.index} out of range for {st}"
                    )
                return flds[x.index]
            raise WeldTypeError(f"getfield on non-struct {st}")
        if isinstance(x, MakeVec):
            for i in x.items:
                it = rec(i, env)
                if it != x.elem_ty:
                    raise WeldTypeError(f"makevec elem {it} != {x.elem_ty}")
            return wt.Vec(x.elem_ty)
        if isinstance(x, Len):
            vt = rec(x.expr, env)
            if not isinstance(vt, wt.Vec):
                raise WeldTypeError(f"len of non-vec {vt}")
            return wt.I64
        if isinstance(x, Lookup):
            ct = rec(x.expr, env)
            it = rec(x.index, env)
            if isinstance(ct, wt.Vec):
                if x.default is not None:
                    raise WeldTypeError("vec lookup takes no default")
                if not (isinstance(it, wt.Scalar) and it.is_int):
                    raise WeldTypeError("vec lookup index must be int")
                return ct.elem
            if isinstance(ct, wt.DictType):
                if it != ct.key:
                    raise WeldTypeError("dict lookup key type mismatch")
                if x.default is not None:
                    dt = rec(x.default, env)
                    if dt != ct.val:
                        raise WeldTypeError(
                            f"dict lookup default {dt} != value type {ct.val}"
                        )
                return ct.val
            raise WeldTypeError(f"lookup on {ct}")
        if isinstance(x, KeyExists):
            ct = rec(x.expr, env)
            if not isinstance(ct, wt.DictType):
                raise WeldTypeError("keyexists on non-dict")
            rec(x.key, env)
            return wt.Bool
        if isinstance(x, GroupLookup):
            ct = rec(x.expr, env)
            if not (isinstance(ct, wt.DictType)
                    and isinstance(ct.val, wt.Vec)):
                raise WeldTypeError(
                    f"grouplookup requires dict[K, vec[V]], got {ct}"
                )
            kt = rec(x.key, env)
            if kt != ct.key:
                raise WeldTypeError(
                    f"grouplookup key type {kt} != dict key {ct.key}"
                )
            return ct.val
        if isinstance(x, CUDF):
            for a in x.args:
                rec(a, env)
            return x.ret_ty
        if isinstance(x, Lambda):
            env2 = dict(env)
            for p in x.params:
                env2[p.name] = p.ty
            return wt.Fn(tuple(p.ty for p in x.params), rec(x.body, env2))
        if isinstance(x, NewBuilder):
            if x.arg is not None:
                rec(x.arg, env)
            return x.ty
        if isinstance(x, Merge):
            bt = rec(x.builder, env)
            if not isinstance(bt, wt.BuilderType):
                raise WeldTypeError(f"merge into non-builder {bt}")
            vt = rec(x.value, env)
            expect = merge_arg_type(bt)
            if vt != expect:
                raise WeldTypeError(f"merge type {vt}, builder wants {expect}")
            return bt
        if isinstance(x, Result):
            bt = rec(x.builder, env)
            if not isinstance(bt, wt.BuilderType):
                raise WeldTypeError(f"result of non-builder {bt}")
            return bt.result_type()
        if isinstance(x, Iter):
            dt = rec(x.data, env)
            if not isinstance(dt, wt.Vec):
                raise WeldTypeError(f"iter over non-vec {dt}")
            return dt
        if isinstance(x, KernelCall):
            for a in x.args:
                rec(a, env)
            return x.ret_ty
        if isinstance(x, For):
            bt = rec(x.builder, env)
            if not isinstance(bt, wt.BuilderType):
                raise WeldTypeError("for-loop builder arg is not a builder")
            elem_tys = []
            for it in x.iters:
                vt = rec(it, env)
                elem_tys.append(vt.elem)
            elem = elem_tys[0] if len(elem_tys) == 1 else wt.Struct(tuple(elem_tys))
            ft = rec(x.func, env)
            want = (bt, wt.I64, elem)
            if tuple(ft.params) != want:
                raise WeldTypeError(
                    f"for func params {tuple(map(str, ft.params))} != "
                    f"{tuple(map(str, want))}"
                )
            if ft.ret != bt:
                raise WeldTypeError(f"for func returns {ft.ret}, builder is {bt}")
            return bt
        raise WeldTypeError(f"cannot type {type(x).__name__}")


def merge_arg_type(bt: wt.BuilderType) -> WeldType:
    if isinstance(bt, wt.VecBuilder):
        return bt.elem
    if isinstance(bt, wt.Merger):
        return bt.elem
    if isinstance(bt, (wt.DictMerger, wt.VecMerger, wt.GroupBuilder)):
        return bt.merge_type()
    if isinstance(bt, wt.StructBuilder):
        raise WeldTypeError("cannot merge directly into a struct of builders")
    raise WeldTypeError(f"unknown builder {bt}")


# ---------------------------------------------------------------------------
# Linearity check (paper §3.2): each builder consumed exactly once per path.
# Best-effort structural check used in tests and on frames-generated IR.
# ---------------------------------------------------------------------------


def check_linearity(e: Expr) -> None:
    """Raises WeldTypeError if a builder-typed let/param is consumed more
    than once along a control path (conservative, syntactic)."""

    def uses(x: Expr, name: str) -> int:
        if isinstance(x, Ident):
            return 1 if x.name == name else 0
        if isinstance(x, If):
            # one consumption per control path: max over branches
            return uses(x.cond, name) + max(
                uses(x.on_true, name), uses(x.on_false, name)
            )
        if isinstance(x, Let) and x.name == name:
            return uses(x.value, name)
        if isinstance(x, Lambda) and any(p.name == name for p in x.params):
            return 0
        return sum(uses(c, name) for c in x.children())

    def rec(x: Expr, env: Dict[str, WeldType]):
        if isinstance(x, Let):
            rec(x.value, env)
            try:
                vt = typeof(x.value, env)
            except WeldTypeError:
                vt = None
            if vt is not None and wt.is_builder(vt):
                n = uses(x.body, x.name)
                if n != 1:
                    raise WeldTypeError(
                        f"builder {x.name} consumed {n} times (must be 1)"
                    )
            rec(x.body, {**env, x.name: vt} if vt is not None else env)
            return
        if isinstance(x, Lambda):
            env2 = dict(env)
            for p in x.params:
                env2[p.name] = p.ty
                if wt.is_builder(p.ty):
                    n = uses(x.body, p.name)
                    if n != 1:
                        raise WeldTypeError(
                            f"builder param {p.name} consumed {n} times"
                        )
            rec(x.body, env2)
            return
        for c in x.children():
            rec(c, env)

    rec(e, {})
