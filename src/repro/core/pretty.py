"""Pretty-printer for Weld IR (debugging / test goldens)."""
from __future__ import annotations

from . import ir


def pretty(e: "ir.Expr", indent: int = 0) -> str:
    pad = "  " * indent

    def p(x):
        return pretty(x, indent)

    if isinstance(e, ir.Literal):
        return f"{e.value}{'' if e.ty.kind in ('i64',) else ':' + e.ty.kind}"
    if isinstance(e, ir.Ident):
        return e.name
    if isinstance(e, ir.Let):
        return f"(let {e.name} = {p(e.value)};\n{pad} {pretty(e.body, indent)})"
    if isinstance(e, ir.BinOp):
        return f"({p(e.left)} {e.op} {p(e.right)})"
    if isinstance(e, ir.UnaryOp):
        return f"{e.op}({p(e.expr)})"
    if isinstance(e, ir.Cast):
        return f"{e.ty}({p(e.expr)})"
    if isinstance(e, ir.If):
        return f"if({p(e.cond)}, {p(e.on_true)}, {p(e.on_false)})"
    if isinstance(e, ir.Select):
        return f"select({p(e.cond)}, {p(e.on_true)}, {p(e.on_false)})"
    if isinstance(e, ir.MakeStruct):
        return "{" + ", ".join(p(i) for i in e.items) + "}"
    if isinstance(e, ir.GetField):
        return f"{p(e.expr)}.${e.index}"
    if isinstance(e, ir.MakeVec):
        return "[" + ", ".join(p(i) for i in e.items) + "]"
    if isinstance(e, ir.Len):
        return f"len({p(e.expr)})"
    if isinstance(e, ir.Lookup):
        if e.default is not None:
            return f"lookup({p(e.expr)}, {p(e.index)}, {p(e.default)})"
        return f"lookup({p(e.expr)}, {p(e.index)})"
    if isinstance(e, ir.KeyExists):
        return f"keyexists({p(e.expr)}, {p(e.key)})"
    if isinstance(e, ir.GroupLookup):
        return f"grouplookup({p(e.expr)}, {p(e.key)})"
    if isinstance(e, ir.CUDF):
        return f"cudf[{e.name}](" + ", ".join(p(a) for a in e.args) + ")"
    if isinstance(e, ir.KernelCall):
        # tuned tile parameters surface next to the kernel name so a plan
        # dump shows the block shape the autotuner chose for each call
        blocks = [(k, v) for k, v in e.params
                  if k in ("block", "bm", "bn", "bk")]
        rest = [(k, v) for k, v in e.params
                if k not in ("block", "bm", "bn", "bk")]
        tag = f"kernel[{e.kernel}]"
        if blocks:
            tag += "@{" + ",".join(f"{k}={v}" for k, v in blocks) + "}"
        parts = [p(a) for a in e.args]
        parts += [f"{k}={v}" for k, v in rest]
        parts += [p(f) for f in e.fns]
        return tag + "(" + ", ".join(parts) + ")"
    if isinstance(e, ir.Lambda):
        params = ",".join(f"{q.name}:{q.ty}" for q in e.params)
        return f"|{params}| {pretty(e.body, indent + 1)}"
    if isinstance(e, ir.NewBuilder):
        arg = f"({p(e.arg)})" if e.arg is not None else ""
        hint = f"@size={p(e.size_hint)}" if e.size_hint is not None else ""
        return f"{e.ty}{arg}{hint}"
    if isinstance(e, ir.Merge):
        return f"merge({p(e.builder)}, {p(e.value)})"
    if isinstance(e, ir.Result):
        return f"result({p(e.builder)})"
    if isinstance(e, ir.Iter):
        if e.is_plain:
            return p(e.data)
        parts = [p(e.data)]
        for x in (e.start, e.end, e.stride):
            parts.append(p(x) if x is not None else "_")
        return f"iter({', '.join(parts)})"
    if isinstance(e, ir.For):
        its = ", ".join(p(i) for i in e.iters)
        return (
            f"for([{its}],\n{pad}    {pretty(e.builder, indent + 1)},"
            f"\n{pad}    {pretty(e.func, indent + 1)})"
        )
    return f"<{type(e).__name__}>"
