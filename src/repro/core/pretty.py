"""Pretty-printer for Weld IR (debugging / test goldens / diagnostics).

Every node has a **stable anchor** — ``#n<k>`` where ``k`` is the node's
preorder position in the tree — so a diagnostic can name the exact
subexpression it is about instead of dumping the whole program:

* ``pretty(e, anchors=True)`` prefixes structural nodes (lets, loops,
  builders, merges, kernel calls, ...) with their anchor;
* ``pretty(e, highlight=node)`` wraps that one subexpression (matched by
  identity) in ``>>> ... <<<`` markers;
* ``anchor_of(root, node)`` returns the anchor string for any node.

With neither argument the output is byte-identical to the historical
format (tests keep their goldens).
"""
from __future__ import annotations

from typing import Optional

from . import ir

#: node kinds that carry a visible anchor under ``anchors=True`` — the
#: "statement-shaped" nodes a diagnostic is most likely to point at.
_ANCHORED = None  # initialised lazily to avoid import-order issues


def _anchored_types():
    global _ANCHORED
    if _ANCHORED is None:
        _ANCHORED = (
            ir.Let, ir.For, ir.NewBuilder, ir.Merge, ir.Result,
            ir.KernelCall, ir.If, ir.Select, ir.Lookup, ir.GroupLookup,
            ir.KeyExists, ir.CUDF,
        )
    return _ANCHORED


def _number(root: "ir.Expr") -> dict:
    """id(node) -> preorder index (first occurrence wins, so anchors are
    stable across prints even when hash-consed subtrees are shared)."""
    idx: dict = {}
    for i, n in enumerate(ir.walk(root)):
        idx.setdefault(id(n), i)
    return idx


def anchor_of(root: "ir.Expr", node: "ir.Expr") -> Optional[str]:
    """Stable anchor (``#n17``) of ``node`` within ``root``, or None."""
    if node is None:
        return None
    i = _number(root).get(id(node))
    return None if i is None else f"#n{i}"


def short(e: "ir.Expr", limit: int = 120) -> str:
    """One-line pretty form, truncated — for error messages."""
    s = " ".join(pretty(e).split())
    return s if len(s) <= limit else s[: limit - 3] + "..."


def pretty(
    e: "ir.Expr",
    indent: int = 0,
    anchors: bool = False,
    highlight: Optional["ir.Expr"] = None,
) -> str:
    idx = _number(e) if (anchors or highlight is not None) else None
    hi_id = id(highlight) if highlight is not None else None

    def deco(x, s: str) -> str:
        if idx is not None and anchors and isinstance(x, _anchored_types()):
            i = idx.get(id(x))
            if i is not None:
                s = f"#n{i}:{s}"
        if hi_id is not None and id(x) == hi_id:
            s = f">>> {s} <<<"
        return s

    def go(x, ind: int) -> str:
        pad = "  " * ind

        def p(y):
            return go(y, ind)

        if isinstance(x, ir.Literal):
            out = f"{x.value}{'' if x.ty.kind in ('i64',) else ':' + x.ty.kind}"
        elif isinstance(x, ir.Ident):
            out = x.name
        elif isinstance(x, ir.Let):
            out = f"(let {x.name} = {p(x.value)};\n{pad} {go(x.body, ind)})"
        elif isinstance(x, ir.BinOp):
            out = f"({p(x.left)} {x.op} {p(x.right)})"
        elif isinstance(x, ir.UnaryOp):
            out = f"{x.op}({p(x.expr)})"
        elif isinstance(x, ir.Cast):
            out = f"{x.ty}({p(x.expr)})"
        elif isinstance(x, ir.If):
            out = f"if({p(x.cond)}, {p(x.on_true)}, {p(x.on_false)})"
        elif isinstance(x, ir.Select):
            out = f"select({p(x.cond)}, {p(x.on_true)}, {p(x.on_false)})"
        elif isinstance(x, ir.MakeStruct):
            out = "{" + ", ".join(p(i) for i in x.items) + "}"
        elif isinstance(x, ir.GetField):
            out = f"{p(x.expr)}.${x.index}"
        elif isinstance(x, ir.MakeVec):
            out = "[" + ", ".join(p(i) for i in x.items) + "]"
        elif isinstance(x, ir.Len):
            out = f"len({p(x.expr)})"
        elif isinstance(x, ir.Lookup):
            if x.default is not None:
                out = f"lookup({p(x.expr)}, {p(x.index)}, {p(x.default)})"
            else:
                out = f"lookup({p(x.expr)}, {p(x.index)})"
        elif isinstance(x, ir.KeyExists):
            out = f"keyexists({p(x.expr)}, {p(x.key)})"
        elif isinstance(x, ir.GroupLookup):
            out = f"grouplookup({p(x.expr)}, {p(x.key)})"
        elif isinstance(x, ir.CUDF):
            out = f"cudf[{x.name}](" + ", ".join(p(a) for a in x.args) + ")"
        elif isinstance(x, ir.KernelCall):
            # tuned tile parameters surface next to the kernel name so a
            # plan dump shows the block shape the autotuner chose per call
            blocks = [(k, v) for k, v in x.params
                      if k in ("block", "bm", "bn", "bk")]
            rest = [(k, v) for k, v in x.params
                    if k not in ("block", "bm", "bn", "bk")]
            tag = f"kernel[{x.kernel}]"
            if blocks:
                tag += "@{" + ",".join(f"{k}={v}" for k, v in blocks) + "}"
            parts = [p(a) for a in x.args]
            parts += [f"{k}={v}" for k, v in rest]
            parts += [p(f) for f in x.fns]
            out = tag + "(" + ", ".join(parts) + ")"
        elif isinstance(x, ir.Lambda):
            params = ",".join(f"{q.name}:{q.ty}" for q in x.params)
            out = f"|{params}| {go(x.body, ind + 1)}"
        elif isinstance(x, ir.NewBuilder):
            arg = f"({p(x.arg)})" if x.arg is not None else ""
            hint = f"@size={p(x.size_hint)}" if x.size_hint is not None else ""
            out = f"{x.ty}{arg}{hint}"
        elif isinstance(x, ir.Merge):
            out = f"merge({p(x.builder)}, {p(x.value)})"
        elif isinstance(x, ir.Result):
            out = f"result({p(x.builder)})"
        elif isinstance(x, ir.Iter):
            if x.is_plain:
                out = p(x.data)
            else:
                parts = [p(x.data)]
                for y in (x.start, x.end, x.stride):
                    parts.append(p(y) if y is not None else "_")
                out = f"iter({', '.join(parts)})"
        elif isinstance(x, ir.For):
            its = ", ".join(p(i) for i in x.iters)
            out = (
                f"for([{its}],\n{pad}    {go(x.builder, ind + 1)},"
                f"\n{pad}    {go(x.func, ind + 1)})"
            )
        else:
            out = f"<{type(x).__name__}>"
        return deco(x, out)

    return go(e, indent)
