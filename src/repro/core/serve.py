"""weldserve: concurrent queries against AOT-compiled cached plans.

The paper's §7.8 economics — compile once, evaluate many times — only
pays off if something can actually *hold* the compiled plans and push
concurrent traffic through them.  :class:`QueryServer` is that driver:

* requests enter from N worker threads (a ``ThreadPoolExecutor``);
* same-plan-same-shape requests coalesce onto ONE executable through
  the runtime's bounded single-flight compile cache (one thread
  compiles a key, peers wait on the in-flight slot — never a duplicate
  compile);
* each request is admitted or shed BEFORE any compile is spent: the
  runtime's weldbound admission gate evaluates the plan's symbolic
  peak-memory certificate against the request's bound shapes at the
  end of the optimize stage — before anything is traced, jitted, or
  launched — and a provably over-budget query raises a typed
  :class:`~repro.core.errors.ResourceError`, which the server accounts
  under the ``shed`` counter (a shed plan is never cached);
* executions of cached plans run concurrently — only compiles
  serialize (on the runtime's compile lock).

Requests are duck-typed: a ``weldrel`` ``StagedQuery`` (anything with
``program()`` + ``finalize``), a raw :class:`~repro.core.lazy.Program`,
or a ``WeldObject``.  This module deliberately does not import the
frames layer.

    with QueryServer(workers=8, memory_limit=1 << 30) as srv:
        futs = [srv.submit(Query(t).stage().join(r, on="k"))
                for _ in range(32)]
        tables = [f.result() for f in futs]
        print(srv.stats())   # requests/completed/shed + cache.* counters

The certificate is priced on the *planned* program (builder size hints
from the optimizer plus kernel scratch footprints from the planner —
an unoptimized program carries neither), so admission necessarily sits
inside the compile pipeline; it still precedes every expensive step.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, List, Optional, Tuple

from . import obs
from .errors import ResourceError
from .lazy import Program, build_program

__all__ = ["QueryServer"]


def _identity(v):
    return v


class QueryServer:
    """Thread-pooled serving driver over the AOT compile pipeline.

    ``memory_limit`` / ``kernelize`` / ``kernel_impl`` are server-wide
    defaults; a staged query's own settings (when not None) win.  Use as
    a context manager or call :meth:`close`."""

    def __init__(self, workers: int = 8,
                 memory_limit: Optional[int] = None,
                 kernelize=None, kernel_impl: Optional[str] = None):
        if workers < 1:
            raise ValueError("QueryServer needs at least one worker")
        self.workers = workers
        self.memory_limit = memory_limit
        self.kernelize = kernelize
        self.kernel_impl = kernel_impl
        self._pool = ThreadPoolExecutor(
            max_workers=workers, thread_name_prefix="weldserve")
        self._lock = threading.Lock()
        self._counters = {
            "serve.requests": 0,
            "serve.completed": 0,
            "serve.shed": 0,
            "serve.errors": 0,
        }
        self._closed = False

    # -- request intake ------------------------------------------------------

    def submit(self, query) -> Future:
        """Enqueue one query; returns a ``concurrent.futures.Future``
        resolving to the query's natural result (a finalized weldrel
        value for staged queries, the decoded value otherwise).  A shed
        request fails the future with :class:`ResourceError`."""
        if self._closed:
            raise RuntimeError("QueryServer is closed")
        with self._lock:
            self._counters["serve.requests"] += 1
        return self._pool.submit(self._serve_one, query)

    def run(self, query):
        """Synchronous :meth:`submit`."""
        return self.submit(query).result()

    def map(self, queries) -> List[object]:
        """Submit every query, gather results in order (first error
        propagates after all futures settle)."""
        futs = [self.submit(q) for q in queries]
        out, first_err = [], None
        for f in futs:
            try:
                out.append(f.result())
            except BaseException as e:  # noqa: BLE001 - re-raised below
                out.append(None)
                first_err = first_err or e
        if first_err is not None:
            raise first_err
        return out

    # -- lifecycle -----------------------------------------------------------

    def stats(self) -> dict:
        """Server counters merged with the runtime's ``cache.*``
        counters (hits/misses/evictions/waits/size)."""
        from . import runtime

        with self._lock:
            out = dict(self._counters)
        out.update(runtime.cache_stats())
        return out

    def close(self, wait: bool = True) -> None:
        self._closed = True
        self._pool.shutdown(wait=wait)

    def __enter__(self) -> "QueryServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- the per-request pipeline -------------------------------------------

    def _serve_one(self, query):
        prog, finalize, op, limit, kz, ki = self._normalize(query)
        with obs.span("serve.request", op=op):
            try:
                from . import runtime

                handle = runtime.compile_program(
                    prog, memory_limit=limit, kernelize=kz, kernel_impl=ki)
                value = handle.run()
                result = finalize(value)
            except ResourceError as e:
                obs.event("serve.shed", op=op, reason=str(e))
                with self._lock:
                    self._counters["serve.shed"] += 1
                raise
            except BaseException:
                with self._lock:
                    self._counters["serve.errors"] += 1
                raise
            with self._lock:
                self._counters["serve.completed"] += 1
            return result

    def _normalize(self, query) -> Tuple[Program, Callable, str,
                                         Optional[int], object,
                                         Optional[str]]:
        """(program, finalize, op, memory_limit, kernelize, kernel_impl)
        for any accepted request shape."""
        prog_fn = getattr(query, "program", None)
        if callable(prog_fn) and hasattr(query, "finalize"):
            # weldrel StagedQuery (duck-typed: no frames import here)
            q_limit = getattr(query, "memory_limit", None)
            q_kz = getattr(query, "kernelize", None)
            q_ki = getattr(query, "kernel_impl", None)
            return (
                prog_fn(),
                query.finalize,
                getattr(query, "op", "staged"),
                q_limit if q_limit is not None else self.memory_limit,
                q_kz if q_kz is not None else self.kernelize,
                q_ki if q_ki is not None else self.kernel_impl,
            )
        if isinstance(query, Program):
            return (query, _identity, "program", self.memory_limit,
                    self.kernelize, self.kernel_impl)
        if hasattr(query, "obj_id") and hasattr(query, "expr"):
            # a lazy WeldObject DAG root
            return (build_program(query), _identity, "weldobject",
                    self.memory_limit, self.kernelize, self.kernel_impl)
        raise TypeError(
            f"QueryServer cannot serve {type(query).__name__}: expected "
            "a weldrel StagedQuery, a core.lazy.Program, or a WeldObject")
