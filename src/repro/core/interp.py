"""Pure-Python reference interpreter for Weld IR.

This is the *semantic oracle*: it executes the IR directly on Python
lists/scalars/dicts with no optimization and no JAX.  Property tests check
that the optimizer + JAX backend agree with this interpreter on random
programs.  It is deliberately simple and slow.
"""
from __future__ import annotations

import math
from typing import Dict, List

import numpy as np

from . import ir
from . import wtypes as wt
from .cudf import lookup_cudf_host


class _VecBuilderState:
    def __init__(self, bt):
        self.bt, self.items = bt, []

    def merge(self, v):
        self.items.append(v)

    def result(self):
        return list(self.items)


def _apply_op(op, a, b):
    if op == "+":
        return a + b
    if op == "*":
        return a * b
    if op == "min":
        return min(a, b)
    if op == "max":
        return max(a, b)
    raise ValueError(op)


class _MergerState:
    def __init__(self, bt, init=None):
        self.bt = bt
        self.acc = init if init is not None else _default_acc(bt)

    def merge(self, v):
        self.acc = _merge_val(self.bt.elem, self.bt.op, self.acc, v)

    def result(self):
        return self.acc


def _default_acc(bt):
    return _identity_of(bt.elem, bt.op)


def _identity_of(ty, op):
    if isinstance(ty, wt.Struct):
        return tuple(_identity_of(f, op) for f in ty.fields)
    return wt.merge_identity(op, ty)


def _merge_val(ty, op, a, b):
    if isinstance(ty, wt.Struct):
        return tuple(
            _merge_val(f, op, x, y) for f, x, y in zip(ty.fields, a, b)
        )
    return _apply_op(op, a, b)


class _DictMergerState:
    def __init__(self, bt):
        self.bt, self.d = bt, {}

    def merge(self, kv):
        k, v = kv
        k = _hashable(k)
        if k in self.d:
            self.d[k] = _merge_val(self.bt.val, self.bt.op, self.d[k], v)
        else:
            self.d[k] = v

    def result(self):
        return dict(self.d)


class _GroupBuilderState:
    def __init__(self, bt):
        self.bt, self.d = bt, {}

    def merge(self, kv):
        k, v = kv
        k = _hashable(k)
        self.d.setdefault(k, []).append(v)

    def result(self):
        return {k: list(v) for k, v in self.d.items()}


class _VecMergerState:
    def __init__(self, bt, base):
        self.bt = bt
        self.vec = list(base)

    def merge(self, iv):
        i, v = iv
        self.vec[int(i)] = _apply_op(self.bt.op, self.vec[int(i)], v)

    def result(self):
        return list(self.vec)


def _hashable(k):
    return tuple(k) if isinstance(k, (list, tuple)) else k


class _Closure:
    def __init__(self, lam: ir.Lambda, env: Dict[str, object]):
        self.lam, self.env = lam, env


_UNARY_FNS = {
    "neg": lambda x: -x,
    "not": lambda x: not x,
    "exp": math.exp,
    "log": math.log,
    "sqrt": math.sqrt,
    "erf": math.erf,
    "sin": math.sin,
    "cos": math.cos,
    "tanh": math.tanh,
    "abs": abs,
    "sigmoid": lambda x: 1.0 / (1.0 + math.exp(-x)),
    "floor": math.floor,
    "rsqrt": lambda x: 1.0 / math.sqrt(x),
}


def _new_builder_state(bt, arg):
    if isinstance(bt, wt.VecBuilder):
        return _VecBuilderState(bt)
    if isinstance(bt, wt.Merger):
        return _MergerState(bt, init=arg)
    if isinstance(bt, wt.DictMerger):
        return _DictMergerState(bt)
    if isinstance(bt, wt.GroupBuilder):
        return _GroupBuilderState(bt)
    if isinstance(bt, wt.VecMerger):
        if arg is None:
            raise ValueError("vecmerger needs a base vector")
        return _VecMergerState(bt, arg)
    if isinstance(bt, wt.StructBuilder):
        raise ValueError("struct builders are created via MakeStruct")
    raise ValueError(f"unknown builder {bt}")


def interpret(e: ir.Expr, env: Dict[str, object] | None = None):
    """Evaluate `e` in `env`; vectors are Python lists, dicts are dicts,
    structs are tuples, builders are internal state objects."""
    env = dict(env or {})

    def rec(x: ir.Expr, env):
        if isinstance(x, ir.Literal):
            return x.value
        if isinstance(x, ir.Ident):
            if x.name not in env:
                raise NameError(f"unbound {x.name}")
            return env[x.name]
        if isinstance(x, ir.Let):
            v = rec(x.value, env)
            return rec(x.body, {**env, x.name: v})
        if isinstance(x, ir.BinOp):
            a, b = rec(x.left, env), rec(x.right, env)
            if x.op == "&&":
                return bool(a) and bool(b)
            if x.op == "||":
                return bool(a) or bool(b)
            if x.op in ir.CMP_OPS:
                return {
                    "==": a == b, "!=": a != b, "<": a < b,
                    "<=": a <= b, ">": a > b, ">=": a >= b,
                }[x.op]
            if x.op == "/":
                if isinstance(a, int) and isinstance(b, int):
                    # C-style truncating integer division
                    return int(a / b) if b != 0 else 0
                return a / b
            if x.op == "%":
                return a % b
            if x.op == "pow":
                return a ** b
            return _apply_op(x.op, a, b) if x.op in ("min", "max") else {
                "+": a + b, "-": a - b, "*": a * b,
            }[x.op]
        if isinstance(x, ir.UnaryOp):
            return _UNARY_FNS[x.op](rec(x.expr, env))
        if isinstance(x, ir.Cast):
            v = rec(x.expr, env)
            return x.ty.np_dtype(v).item()
        if isinstance(x, (ir.If, ir.Select)):
            if isinstance(x, ir.Select):
                t = rec(x.on_true, env)
                f = rec(x.on_false, env)
                return t if rec(x.cond, env) else f
            return rec(x.on_true if rec(x.cond, env) else x.on_false, env)
        if isinstance(x, ir.MakeStruct):
            return tuple(rec(i, env) for i in x.items)
        if isinstance(x, ir.GetField):
            return rec(x.expr, env)[x.index]
        if isinstance(x, ir.MakeVec):
            return [rec(i, env) for i in x.items]
        if isinstance(x, ir.Len):
            return len(rec(x.expr, env))
        if isinstance(x, ir.Lookup):
            c = rec(x.expr, env)
            i = rec(x.index, env)
            if isinstance(c, dict):
                k = _hashable(i)
                if x.default is not None and k not in c:
                    return rec(x.default, env)
                return c[k]
            return c[int(i)]
        if isinstance(x, ir.KeyExists):
            return _hashable(rec(x.key, env)) in rec(x.expr, env)
        if isinstance(x, ir.GroupLookup):
            d = rec(x.expr, env)
            k = _hashable(rec(x.key, env))
            return list(d.get(k, []))  # miss -> EMPTY vector
        if isinstance(x, ir.CUDF):
            fn = lookup_cudf_host(x.name)
            return fn(*[rec(a, env) for a in x.args])
        if isinstance(x, ir.Lambda):
            return _Closure(x, dict(env))
        if isinstance(x, ir.NewBuilder):
            arg = rec(x.arg, env) if x.arg is not None else None
            if isinstance(x.ty, (wt.DictMerger, wt.GroupBuilder)):
                arg = None  # capacity hint: irrelevant to reference semantics
            return _new_builder_state(x.ty, arg)
        if isinstance(x, ir.Merge):
            b = rec(x.builder, env)
            b.merge(rec(x.value, env))
            return b
        if isinstance(x, ir.Result):
            b = rec(x.builder, env)
            if isinstance(b, tuple):  # struct of builders
                return tuple(s.result() for s in b)
            return b.result()
        if isinstance(x, ir.Iter):
            data = rec(x.data, env)
            start = int(rec(x.start, env)) if x.start is not None else 0
            end = int(rec(x.end, env)) if x.end is not None else len(data)
            stride = int(rec(x.stride, env)) if x.stride is not None else 1
            return data[start:end:stride]
        if isinstance(x, ir.For):
            seqs = [rec(it, env) for it in x.iters]
            n = min(len(s) for s in seqs)
            b = rec(x.builder, env)
            clo = rec(x.func, env)
            for i in range(n):
                elem = seqs[0][i] if len(seqs) == 1 else tuple(s[i] for s in seqs)
                b = _call(clo, [b, i, elem])
            return b
        raise ValueError(f"cannot interpret {type(x).__name__}")

    def _call(clo: _Closure, args: List[object]):
        env2 = dict(clo.env)
        for p, a in zip(clo.lam.params, args):
            env2[p.name] = a
        return rec(clo.lam.body, env2)

    return rec(e, env)


def _guess_ty(v):
    if isinstance(v, bool):
        return wt.Bool
    if isinstance(v, int):
        return wt.I64
    if isinstance(v, float):
        return wt.F64
    return wt.F64


def to_python(value, ty: wt.WeldType):
    """Convert a backend (numpy/jax) value into interpreter-land types."""
    if isinstance(ty, wt.Scalar):
        return np.asarray(value).item()
    if isinstance(ty, wt.Vec):
        return [to_python(v, ty.elem) for v in np.asarray(value).tolist()] \
            if isinstance(ty.elem, wt.Struct) else np.asarray(value).tolist()
    if isinstance(ty, wt.Struct):
        return tuple(to_python(v, f) for v, f in zip(value, ty.fields))
    raise ValueError(f"cannot convert {ty}")
