"""Higher-level operators (paper §3.3) — map/filter/reduce etc. as macros
that expand to `for` loops and builders.  Library integrations build their
IR almost exclusively through these.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

from . import ir
from . import wtypes as wt


def _lam3(bt: wt.BuilderType, elem_ty: wt.WeldType, body_fn) -> ir.Lambda:
    b = ir.Ident(ir.fresh("b"), bt)
    i = ir.Ident(ir.fresh("i"), wt.I64)
    x = ir.Ident(ir.fresh("x"), elem_ty)
    return ir.Lambda((b, i, x), body_fn(b, i, x))


def elem_type(vec_expr: ir.Expr) -> wt.WeldType:
    ty = ir.typeof(vec_expr)
    if not isinstance(ty, wt.Vec):
        raise wt.WeldTypeError(f"expected vec, got {ty}")
    return ty.elem


def map_(vec: ir.Expr, fn: Callable[[ir.Expr], ir.Expr],
         out_ty: Optional[wt.WeldType] = None) -> ir.Expr:
    """map(v, f): for(v, vecbuilder, (b,i,x)=>merge(b, f(x)))"""
    et = elem_type(vec)
    probe = ir.Ident(ir.fresh("probe"), et)
    if out_ty is None:
        out_ty = ir.typeof(fn(probe), {probe.name: et})
    bt = wt.VecBuilder(out_ty)
    lam = _lam3(bt, et, lambda b, i, x: ir.Merge(b, fn(x)))
    return ir.Result(ir.For((ir.Iter(vec),), ir.NewBuilder(bt), lam))


def zip_map(vecs: Sequence[ir.Expr], fn, out_ty: Optional[wt.WeldType] = None) -> ir.Expr:
    """Elementwise map over multiple equal-length vectors.

    `fn` receives one expression per vector.
    """
    etys = [elem_type(v) for v in vecs]
    struct_ty = wt.Struct(tuple(etys)) if len(vecs) > 1 else etys[0]
    probe = ir.Ident(ir.fresh("probe"), struct_ty)
    if len(vecs) == 1:
        body = lambda x: fn(x)
    else:
        body = lambda x: fn(*[ir.GetField(x, k) for k in range(len(vecs))])
    if out_ty is None:
        out_ty = ir.typeof(body(probe), {probe.name: struct_ty})
    bt = wt.VecBuilder(out_ty)
    lam = _lam3(bt, struct_ty, lambda b, i, x: ir.Merge(b, body(x)))
    return ir.Result(
        ir.For(tuple(ir.Iter(v) for v in vecs), ir.NewBuilder(bt), lam)
    )


def filter_(vec: ir.Expr, pred: Callable[[ir.Expr], ir.Expr]) -> ir.Expr:
    """filter(v, p): conditional merge into a vecbuilder."""
    et = elem_type(vec)
    bt = wt.VecBuilder(et)
    lam = _lam3(
        bt, et,
        lambda b, i, x: ir.If(pred(x), ir.Merge(b, x), b),
    )
    return ir.Result(ir.For((ir.Iter(vec),), ir.NewBuilder(bt), lam))


def reduce_(vec: ir.Expr, op: str = "+",
            fn: Optional[Callable[[ir.Expr], ir.Expr]] = None,
            init: Optional[ir.Expr] = None) -> ir.Expr:
    """reduce(v, op): merger over (optionally mapped) elements."""
    et = elem_type(vec)
    probe = ir.Ident(ir.fresh("probe"), et)
    vt = et if fn is None else ir.typeof(fn(probe), {probe.name: et})
    bt = wt.Merger(vt, op)
    lam = _lam3(
        bt, et,
        lambda b, i, x: ir.Merge(b, fn(x) if fn is not None else x),
    )
    return ir.Result(ir.For((ir.Iter(vec),), ir.NewBuilder(bt, arg=init), lam))


def filter_reduce(vec: ir.Expr, pred, op: str = "+", fn=None) -> ir.Expr:
    """Fused filter+reduce (Listing 10): produced directly by some frames,
    also the result of fusing filter_ into reduce_."""
    et = elem_type(vec)
    probe = ir.Ident(ir.fresh("probe"), et)
    vt = et if fn is None else ir.typeof(fn(probe), {probe.name: et})
    bt = wt.Merger(vt, op)
    lam = _lam3(
        bt, et,
        lambda b, i, x: ir.If(
            pred(x), ir.Merge(b, fn(x) if fn is not None else x), b
        ),
    )
    return ir.Result(ir.For((ir.Iter(vec),), ir.NewBuilder(bt), lam))


def scatter_add(base: ir.Expr, idx: ir.Expr, vals: ir.Expr, op: str = "+") -> ir.Expr:
    """vecmerger: merge vals[i] into base[idx[i]]."""
    et = elem_type(vals)
    bt = wt.VecMerger(et, op)
    struct_ty = wt.Struct((elem_type(idx), et))
    lam = _lam3(
        bt, struct_ty,
        lambda b, i, x: ir.Merge(
            b,
            ir.MakeStruct((_as_i64(ir.GetField(x, 0)), ir.GetField(x, 1))),
        ),
    )
    return ir.Result(
        ir.For(
            (ir.Iter(idx), ir.Iter(vals)),
            ir.NewBuilder(bt, arg=base),
            lam,
        )
    )


def groupby_agg(keys: ir.Expr, vals: ir.Expr, op: str = "+",
                capacity: int = 1024) -> ir.Expr:
    """dictmerger: aggregate vals by key → dict[key, val]."""
    kt, vt = elem_type(keys), elem_type(vals)
    bt = wt.DictMerger(kt, vt, op)
    struct_ty = wt.Struct((kt, vt))
    lam = _lam3(bt, struct_ty, lambda b, i, x: ir.Merge(b, x))
    cap = ir.Literal(capacity, wt.I64)
    return ir.Result(
        ir.For((ir.Iter(keys), ir.Iter(vals)), ir.NewBuilder(bt, arg=cap), lam)
    )


def group_vals(keys: ir.Expr, vals: ir.Expr, capacity: int = 1024) -> ir.Expr:
    """groupbuilder: dict[key, vec[val]]."""
    kt, vt = elem_type(keys), elem_type(vals)
    bt = wt.GroupBuilder(kt, vt)
    struct_ty = wt.Struct((kt, vt))
    lam = _lam3(bt, struct_ty, lambda b, i, x: ir.Merge(b, x))
    cap = ir.Literal(capacity, wt.I64)
    return ir.Result(
        ir.For((ir.Iter(keys), ir.Iter(vals)), ir.NewBuilder(bt, arg=cap), lam)
    )


def dot(a: ir.Expr, b: ir.Expr) -> ir.Expr:
    """Inner product via a merger (the tiling pass raises this to matmul)."""
    return reduce_(
        zip_map([a, b], lambda x, y: ir.BinOp("*", x, y)), "+"
    )


def lit(v, ty: Optional[wt.Scalar] = None) -> ir.Literal:
    if ty is None:
        if isinstance(v, bool):
            ty = wt.Bool
        elif isinstance(v, int):
            ty = wt.I64
        else:
            ty = wt.F64
    return ir.Literal(v, ty)


def _as_i64(e: ir.Expr) -> ir.Expr:
    t = ir.typeof(e)
    return e if t == wt.I64 else ir.Cast(e, wt.I64)
