"""Weld type system (paper §3.1, Table 1).

Scalars, variable-length vectors, structs and dictionaries, plus the five
builder types. Types are immutable, hashable dataclasses so they can key
compile caches and be embedded in IR nodes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple

import numpy as np


class WeldType:
    """Base class for all Weld types."""

    def __str__(self) -> str:  # pragma: no cover - overridden
        return self.__class__.__name__


class WeldTypeError(TypeError):
    pass


# ---------------------------------------------------------------------------
# Value types
# ---------------------------------------------------------------------------

_SCALAR_KINDS = ("bool", "i8", "i32", "i64", "f32", "f64")

_NUMPY_DTYPES = {
    "bool": np.bool_,
    "i8": np.int8,
    "i32": np.int32,
    "i64": np.int64,
    "f32": np.float32,
    "f64": np.float64,
}


@dataclass(frozen=True)
class Scalar(WeldType):
    kind: str

    def __post_init__(self):
        if self.kind not in _SCALAR_KINDS:
            raise WeldTypeError(f"unknown scalar kind {self.kind!r}")

    @property
    def is_float(self) -> bool:
        return self.kind in ("f32", "f64")

    @property
    def is_int(self) -> bool:
        return self.kind in ("i8", "i32", "i64")

    @property
    def np_dtype(self):
        return _NUMPY_DTYPES[self.kind]

    def __str__(self) -> str:
        return self.kind


Bool = Scalar("bool")
I8 = Scalar("i8")
I32 = Scalar("i32")
I64 = Scalar("i64")
F32 = Scalar("f32")
F64 = Scalar("f64")


@dataclass(frozen=True)
class Vec(WeldType):
    elem: WeldType

    def __str__(self) -> str:
        return f"vec[{self.elem}]"


@dataclass(frozen=True)
class Struct(WeldType):
    fields: Tuple[WeldType, ...]

    def __str__(self) -> str:
        return "{" + ",".join(str(f) for f in self.fields) + "}"


@dataclass(frozen=True)
class DictType(WeldType):
    key: WeldType
    val: WeldType

    def __str__(self) -> str:
        return f"dict[{self.key},{self.val}]"


@dataclass(frozen=True)
class Fn(WeldType):
    params: Tuple[WeldType, ...]
    ret: WeldType

    def __str__(self) -> str:
        return "(" + ",".join(str(p) for p in self.params) + f")=>{self.ret}"


# ---------------------------------------------------------------------------
# Builder types (Table 1).  Builders are linear: consumed exactly once per
# control path.  `result_type()` gives the type produced by result(b).
# ---------------------------------------------------------------------------

#: Commutative merge functions supported by merger-family builders.
MERGE_OPS = ("+", "*", "min", "max")


class BuilderType(WeldType):
    def result_type(self) -> WeldType:  # pragma: no cover - abstract
        raise NotImplementedError


@dataclass(frozen=True)
class VecBuilder(BuilderType):
    """Builds vec[elem] by appending merged values."""

    elem: WeldType

    def result_type(self) -> WeldType:
        return Vec(self.elem)

    def __str__(self) -> str:
        return f"vecbuilder[{self.elem}]"


@dataclass(frozen=True)
class Merger(BuilderType):
    """Builds a scalar/struct of type `elem` by commutative `op`."""

    elem: WeldType
    op: str = "+"

    def __post_init__(self):
        if self.op not in MERGE_OPS:
            raise WeldTypeError(f"merger op {self.op!r} not commutative")

    def result_type(self) -> WeldType:
        return self.elem

    def __str__(self) -> str:
        return f"merger[{self.elem},{self.op}]"


@dataclass(frozen=True)
class DictMerger(BuilderType):
    """Builds dict[key,val] merging {k,v} pairs with commutative `op`."""

    key: WeldType
    val: WeldType
    op: str = "+"

    def __post_init__(self):
        if self.op not in MERGE_OPS:
            raise WeldTypeError(f"dictmerger op {self.op!r} not commutative")

    def merge_type(self) -> WeldType:
        return Struct((self.key, self.val))

    def result_type(self) -> WeldType:
        return DictType(self.key, self.val)

    def __str__(self) -> str:
        return f"dictmerger[{self.key},{self.val},{self.op}]"


@dataclass(frozen=True)
class VecMerger(BuilderType):
    """Builds vec[elem] by merging {index, elem} into existing cells."""

    elem: WeldType
    op: str = "+"

    def __post_init__(self):
        if self.op not in MERGE_OPS:
            raise WeldTypeError(f"vecmerger op {self.op!r} not commutative")

    def merge_type(self) -> WeldType:
        return Struct((I64, self.elem))

    def result_type(self) -> WeldType:
        return Vec(self.elem)

    def __str__(self) -> str:
        return f"vecmerger[{self.elem},{self.op}]"


@dataclass(frozen=True)
class GroupBuilder(BuilderType):
    """Builds dict[key, vec[val]] grouping {k,v} pairs by key."""

    key: WeldType
    val: WeldType

    def merge_type(self) -> WeldType:
        return Struct((self.key, self.val))

    def result_type(self) -> WeldType:
        return DictType(self.key, Vec(self.val))

    def __str__(self) -> str:
        return f"groupbuilder[{self.key},{self.val}]"


@dataclass(frozen=True)
class StructBuilder(BuilderType):
    """A struct of builders: a single for-loop can merge into several."""

    builders: Tuple[BuilderType, ...]

    def result_type(self) -> WeldType:
        return Struct(tuple(b.result_type() for b in self.builders))

    def __str__(self) -> str:
        return "{" + ",".join(str(b) for b in self.builders) + "}"


def is_builder(ty: WeldType) -> bool:
    return isinstance(ty, BuilderType)


def elem_bytes(ty: WeldType) -> int:
    """Widest scalar element width (bytes) reachable in a value type —
    the byte-per-element figure the kernel planner's cost model and the
    emitter's memory accounting both price traffic with."""
    if isinstance(ty, Struct):
        return max((elem_bytes(f) for f in ty.fields), default=8)
    if isinstance(ty, Vec):
        return elem_bytes(ty.elem)
    if isinstance(ty, DictType):
        return elem_bytes(ty.val)
    if isinstance(ty, Scalar):
        return int(np.dtype(ty.np_dtype).itemsize)
    return 8


def merge_identity(op: str, ty: Scalar):
    """Identity element of a commutative merge op, as a python scalar."""
    if op == "+":
        return False if ty.kind == "bool" else ty.np_dtype(0).item()
    if op == "*":
        return True if ty.kind == "bool" else ty.np_dtype(1).item()
    info = (np.finfo if ty.is_float else np.iinfo)(ty.np_dtype)
    if op == "min":
        return float(info.max) if ty.is_float else int(info.max)
    if op == "max":
        return float(info.min) if ty.is_float else int(info.min)
    raise WeldTypeError(f"no identity for op {op}")


def dtype_to_weld(dt) -> Scalar:
    dt = np.dtype(dt)
    table = {
        np.dtype(np.bool_): Bool,
        np.dtype(np.int8): I8,
        np.dtype(np.int32): I32,
        np.dtype(np.int64): I64,
        np.dtype(np.float32): F32,
        np.dtype(np.float64): F64,
    }
    if dt in table:
        return table[dt]
    # bf16 arrives from jax; treat as f32 at the IR level.
    if dt.name == "bfloat16":
        return F32
    if dt == np.dtype(np.float16):
        return F32
    if dt in (np.dtype(np.uint8),):
        return I32
    raise WeldTypeError(f"unsupported dtype {dt}")
