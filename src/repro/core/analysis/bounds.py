"""weldbound: interval abstract interpretation + peak-memory certificates.

Two artifacts come out of one pass over a (planned or generic) program:

* **per-builder size intervals** — for every vecbuilder, dictmerger /
  groupbuilder, and kernel expansion buffer, a bound ``[lo, hi]``
  symbolic in the input lengths: filter ⇒ ``[0, n]``, map ⇒ ``[n, n]``,
  dict/group build ⇒ ``[0, min(n, capacity)]``, grouplookup expansion
  (the m:n join CSR fan-out) ⇒ ``[0, n_probe * n_build]`` (``lo =
  n_probe`` for an unfiltered left join, where every probe row emits at
  least its miss row);
* **a whole-plan peak-memory certificate** — the symbolic byte
  expression the backend's emitter would charge against
  ``memory_limit`` at trace time (hinted vecbuilder buffers + kernel
  scratch footprints), mirrored term-for-term so evaluating the
  certificate at bind time and tracing the program agree exactly.

Consumers: the runtime's admission check (reject before compiling),
the planner (static capacities on the host-count-free replay path and
interval-midpoint costing), the recovery ladder (clamp capacity regrow
at the proven need), and the WV5xx weldcheck lints.

Soundness contract: every observed runtime size must land inside its
derived interval — enforced differentially by the join fuzzer's bounds
profile, not by trust.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .. import ir
from .. import wtypes as wt
from . import domain as d
from .domain import INF, Interval, Shapes, Sym

ENV_BOUNDS = "WELD_BOUNDS"
_override: Optional[bool] = None


def enabled() -> bool:
    """Bounds analysis on/off — ``WELD_BOUNDS`` env knob, default ON."""
    if _override is not None:
        return _override
    return os.environ.get(ENV_BOUNDS, "1").lower() not in (
        "0", "off", "false", "no")


def set_enabled(v: Optional[bool]) -> None:
    """Force on/off from code (None restores the env default)."""
    global _override
    _override = v


# -- IR expr -> Sym (mirror of the emitter's _static_eval) ----------------


def sym_of(e: Optional[ir.Expr]) -> Optional[Sym]:
    """Symbolic form of a size expression, exactly the fragment the
    backend can statically resolve: literals, ``len(input)``, and
    ``+ - * / min max`` over those.  None = the emitter would bail too."""
    if e is None:
        return None
    if isinstance(e, ir.Literal):
        try:
            return d.const(int(e.value))
        except (TypeError, ValueError):
            return None
    if isinstance(e, ir.Len) and isinstance(e.expr, ir.Ident):
        return d.length(e.expr.name)
    if isinstance(e, ir.BinOp) and e.op in ("+", "-", "*", "/",
                                            "min", "max"):
        a = sym_of(e.left)
        b = sym_of(e.right)
        if a is None or b is None:
            return None
        return {"+": d.add, "-": d.sub, "*": d.mul, "/": d.div,
                "min": d.smin, "max": d.smax}[e.op](a, b)
    return None


def static_size(e: Optional[ir.Expr], shapes: Optional[Shapes]) -> Optional[int]:
    """Resolve a size expression to a concrete int against input shapes
    (None entries tolerated).  The planner's replacement for its old
    Literal-only capacity checks."""
    s = sym_of(e)
    if s is None:
        return None
    shp = {k: tuple(v) for k, v in (shapes or {}).items() if v}
    v = d.evaluate(s, shp)
    if v is None or v == INF:
        return None
    return int(v)


# -- abstract values ------------------------------------------------------


@dataclass
class AVec:
    """A vector whose length lies in ``n``."""

    n: Interval


@dataclass
class ADict:
    """A dict/group result: ``size`` distinct keys, ``total`` merged
    rows (the CSR fan-out mass for groupbuilders), ``cap`` the declared
    slot budget."""

    size: Interval
    total: Interval
    cap: Optional[Sym]
    group: bool = False


@dataclass
class AStruct:
    items: Tuple[object, ...]


@dataclass
class BuilderBound:
    """One sized allocation site and what the analysis proved about it."""

    node: ir.Expr
    kind: str  # vecbuilder[ty] | dictmerger | groupbuilder | group_probe
    #: derived need (rows to be merged / emitted), UNclamped by declared
    rows: Interval
    #: the declared size (vecbuilder hint / dict capacity / probe out_cap)
    declared: Optional[Sym]
    role: str  # "hint" | "cap" | "out_cap"


class _Unknown(Exception):
    """A merge whose target builder can't be identified — poison the
    enclosing loop's bounds rather than under-count."""


# -- certificate terms (mirror of the emitter's charge sites) -------------


def _charge_terms(e: ir.Expr) -> List[Tuple[str, Sym]]:
    """One term per emitter charge: hinted scalar vecbuilders (the
    generic lowerings and the m:n group-probe buffers both charge
    ``hint * itemsize``) and kernel footprint hooks.  Unresolvable
    terms evaluate to nothing — exactly what the emitter charges when
    it can't statically size an allocation."""
    terms: List[Tuple[str, Sym]] = []
    for node in ir.walk(e):
        if (isinstance(node, ir.NewBuilder)
                and isinstance(node.ty, wt.VecBuilder)
                and node.size_hint is not None
                and isinstance(node.ty.elem, wt.Scalar)):
            hs = sym_of(node.size_hint)
            if hs is not None:
                itemsize = int(np.dtype(node.ty.elem.np_dtype).itemsize)
                terms.append((f"vecbuilder[{node.ty.elem}]",
                              d.mul(hs, d.const(itemsize))))
        elif isinstance(node, ir.KernelCall):
            t = _kernel_term(node)
            if t is not None:
                terms.append((node.kernel, t))
    return terms


def _kernel_term(x: ir.KernelCall) -> Optional[Sym]:
    try:
        from ..kernelplan import registry as kreg
        spec = kreg.get(x.kernel)
    except Exception:
        return None
    fp = getattr(spec, "footprint", None)
    if fp is None:
        return None
    params = dict(x.params)
    itemsize = wt.elem_bytes(x.ret_ty)
    getters: List[Tuple[str, object]] = []
    for a in x.args:
        if isinstance(a, ir.Ident):
            getters.append(("name", a.name))
        elif isinstance(a, ir.MakeVec):
            getters.append(("const", (len(a.items),)))
        else:
            getters.append(("opaque", None))

    def ev(shapes: Shapes) -> int:
        arg_shapes = []
        for kind, v in getters:
            if kind == "name":
                shp = shapes.get(v)
                arg_shapes.append(tuple(shp) if shp else ())
            elif kind == "const":
                arg_shapes.append(v)
            else:
                arg_shapes.append(())
        try:
            return int(fp(arg_shapes, itemsize, params))
        except Exception:
            return 0

    # display the driving length (probe kernels iterate args[1:])
    n_arg = None
    pick = 1 if x.kernel in ("hash_probe", "group_probe") else 0
    if pick < len(x.args) and isinstance(x.args[pick], ir.Ident):
        n_arg = d.length(x.args[pick].name)
    return d.SCall(x.kernel, ev, n_arg)


# -- the abstract interpreter ---------------------------------------------


class _Analyzer:
    def __init__(self):
        self.builders: List[BuilderBound] = []
        self.name_rows: Dict[str, Interval] = {}

    # .. value evaluation ..................................................

    def eval(self, e: ir.Expr, env: Dict[str, object]):
        if isinstance(e, ir.Ident):
            return env.get(e.name)
        if isinstance(e, ir.Let):
            v = self.eval(e.value, env)
            if isinstance(v, AVec):
                self.name_rows[e.name] = v.n
            env2 = dict(env)
            env2[e.name] = v
            return self.eval(e.body, env2)
        if isinstance(e, (ir.If, ir.Select)):
            self.eval(e.cond, env)
            return self._join(self.eval(e.on_true, env),
                              self.eval(e.on_false, env))
        if isinstance(e, ir.MakeStruct):
            return AStruct(tuple(self.eval(i, env) for i in e.items))
        if isinstance(e, ir.GetField):
            v = self.eval(e.expr, env)
            if isinstance(v, AStruct) and e.index < len(v.items):
                return v.items[e.index]
            return None
        if isinstance(e, ir.MakeVec):
            return AVec(d.point(d.const(len(e.items))))
        if isinstance(e, ir.Result):
            if isinstance(e.builder, ir.For):
                return self._ev_for(e.builder, env)
            return self.eval(e.builder, env)
        if isinstance(e, ir.For):
            return self._ev_for(e, env)
        if isinstance(e, ir.GroupLookup):
            dv = self.eval(e.expr, env)
            self.eval(e.key, env)
            hi = dv.total.hi if isinstance(dv, ADict) else d.const(INF)
            return AVec(Interval(d.const(0), hi))
        if isinstance(e, ir.KernelCall):
            return self._ev_kernelcall(e, env)
        # leaves and nodes with no size meaning: still traverse children
        # so nested Lets/loops get analyzed
        for c in e.children():
            self.eval(c, env)
        return None

    def _join(self, a, b):
        if isinstance(a, AVec) and isinstance(b, AVec):
            return AVec(a.n.join(b.n))
        if isinstance(a, AStruct) and isinstance(b, AStruct) \
                and len(a.items) == len(b.items):
            return AStruct(tuple(self._join(x, y)
                                 for x, y in zip(a.items, b.items)))
        if isinstance(a, ADict) and isinstance(b, ADict):
            return ADict(a.size.join(b.size), a.total.join(b.total),
                         a.cap if a.cap == b.cap else None,
                         a.group and b.group)
        return None

    # .. loops .............................................................

    def _vec_interval(self, data: ir.Expr, env, guards) -> Interval:
        if isinstance(data, ir.GroupLookup) \
                and isinstance(data.expr, ir.Ident):
            dv = env.get(data.expr.name)
            hi = dv.total.hi if isinstance(dv, ADict) else d.const(INF)
            lo = d.const(0)
            try:
                if (data.expr.name, ir.canon_key(data.key)) in guards:
                    lo = d.const(1)  # key proven present: >= 1 group row
            except Exception:
                pass
            return Interval(lo, hi)
        v = self.eval(data, env)
        if isinstance(v, AVec):
            return v.n
        return d.top()

    def _iter_interval(self, iters: Sequence[ir.Iter], env,
                       guards) -> Interval:
        out: Optional[Interval] = None
        for it in iters:
            if not it.is_plain:
                return d.top()  # strided views: length not yet modeled
            iv = self._vec_interval(it.data, env, guards)
            out = iv if out is None else Interval(
                d.smin(out.lo, iv.lo), d.smin(out.hi, iv.hi))
        return out if out is not None else d.ZERO

    def _ev_for(self, loop: ir.For, env):
        try:
            return self._ev_for_inner(loop, env)
        except _Unknown:
            return None  # unanalyzable body: no bounds recorded

    def _ev_for_inner(self, loop: ir.For, env):
        n_it = self._iter_interval(loop.iters, env, frozenset())
        if len(loop.func.params) != 3:
            raise _Unknown
        b_name = loop.func.params[0].name
        counts = self._count_merges(loop.func.body, env, frozenset())

        def tot(idx) -> Interval:
            per = counts.get((b_name, idx), d.ZERO)
            return per.mul(n_it)

        init = loop.builder
        if isinstance(init, ir.NewBuilder):
            return self._builder_result(init, tot(None), env)
        if isinstance(init, ir.MakeStruct):
            items = []
            for k, nb in enumerate(init.items):
                if isinstance(nb, ir.NewBuilder):
                    items.append(self._builder_result(nb, tot(k), env))
                else:
                    items.append(None)
            return AStruct(tuple(items))
        if isinstance(init, ir.Ident):
            return env.get(init.name)
        return None

    def _count_merges(self, e: ir.Expr, env, guards
                      ) -> Dict[Tuple[str, Optional[int]], Interval]:
        """Per-iteration merge counts into each named builder slot."""
        if isinstance(e, ir.Merge):
            counts = self._count_merges(e.value, env, guards)
            tgt = e.builder
            if isinstance(tgt, ir.Merge):
                counts = _sum(counts, self._count_merges(tgt, env, guards))
            slot = _root_slot(tgt)
            if slot is None:
                raise _Unknown  # can't attribute this merge: poison
            return _sum(counts, {slot: d.ONE})
        if isinstance(e, ir.If):
            g2 = guards
            if isinstance(e.cond, ir.KeyExists) \
                    and isinstance(e.cond.expr, ir.Ident):
                try:
                    g2 = guards | {(e.cond.expr.name,
                                    ir.canon_key(e.cond.key))}
                except Exception:
                    pass
            c = self._count_merges(e.cond, env, guards)
            t = self._count_merges(e.on_true, env, g2)
            f = self._count_merges(e.on_false, env, guards)
            return _sum(c, _join_counts(t, f))
        if isinstance(e, ir.For):
            if len(e.func.params) != 3:
                raise _Unknown
            fan = self._iter_interval(e.iters, env, guards)
            inner = self._count_merges(e.func.body, env, guards)
            bp = e.func.params[0].name
            out: Dict[Tuple[str, Optional[int]], Interval] = {}
            for (nm, idx), cnt in inner.items():
                key = (nm, idx)
                if nm == bp:
                    # rename the inner loop's builder param to the outer
                    # target it initializes from
                    tgt = e.builder
                    if isinstance(tgt, ir.Ident):
                        key = (tgt.name, idx)
                    elif (isinstance(tgt, ir.GetField)
                          and isinstance(tgt.expr, ir.Ident)
                          and idx is None):
                        key = (tgt.expr.name, tgt.index)
                    else:
                        raise _Unknown
                out = _sum(out, {key: cnt.mul(fan)})
            # the nested loop's own init builders get their bounds too
            self._ev_for(e, env)
            return out
        if isinstance(e, ir.Lambda):
            return {}  # kernel fns / non-loop lambdas: no outer merges
        if isinstance(e, (ir.Ident, ir.Literal)):
            return {}
        out = {}
        for c in e.children():
            out = _sum(out, self._count_merges(c, env, guards))
        return out

    def _builder_result(self, nb: ir.NewBuilder, tot: Interval, env):
        bt = nb.ty
        if isinstance(bt, wt.VecBuilder):
            hint = sym_of(nb.size_hint) if nb.size_hint is not None else None
            self.builders.append(BuilderBound(
                nb, f"vecbuilder[{bt.elem}]", tot, hint, "hint"))
            return AVec(tot)
        if isinstance(bt, (wt.DictMerger, wt.GroupBuilder)):
            cap = sym_of(nb.arg) if nb.arg is not None else d.const(1024)
            kind = ("groupbuilder" if isinstance(bt, wt.GroupBuilder)
                    else "dictmerger")
            self.builders.append(BuilderBound(nb, kind, tot, cap, "cap"))
            hi = tot.hi if cap is None else d.smin(tot.hi, cap)
            return ADict(size=Interval(d.const(0), hi), total=tot,
                         cap=cap, group=isinstance(bt, wt.GroupBuilder))
        if isinstance(bt, wt.VecMerger):
            base = self.eval(nb.arg, env) if nb.arg is not None else None
            return base if isinstance(base, AVec) else None
        return None  # merger: scalar result, no size

    # .. kernel transfer functions .........................................

    def _ev_kernelcall(self, x: ir.KernelCall, env):
        for a in x.args:
            self.eval(a, env)
        params = dict(x.params)
        k = x.kernel

        def args_interval(exprs) -> Interval:
            out: Optional[Interval] = None
            for a in exprs:
                iv = self._vec_interval(a, env, frozenset())
                out = iv if out is None else Interval(
                    d.smin(out.lo, iv.lo), d.smin(out.hi, iv.hi))
            return out if out is not None else d.ZERO

        if k == "map_elementwise":
            return AVec(args_interval(x.args))
        if k == "vecmerger_segment_sum":
            base = self.eval(x.args[0], env)
            return base if isinstance(base, AVec) else None
        if k in ("dict_hash_build", "dict_group_sum", "group_build"):
            n_b = args_interval(x.args)
            cap = params.get("capacity")
            cap_s = d.const(int(cap)) if cap is not None else None
            lo = d.const(0)
            total = Interval(
                lo if params.get("has_pred") else n_b.lo, n_b.hi)
            hi = n_b.hi if cap_s is None else d.smin(n_b.hi, cap_s)
            return ADict(size=Interval(d.const(0), hi), total=total,
                         cap=cap_s, group=(k == "group_build"))
        if k == "hash_probe":
            n_pr = args_interval(x.args[1:])
            how = params.get("how", "inner")
            lo = (n_pr.lo if how == "left" and not params.get("has_pred")
                  else d.const(0))
            rows = Interval(lo, n_pr.hi)
            return self._probe_struct(x, rows)
        if k == "group_probe":
            n_iters = int(params.get("n_iters", 1))
            n_pr = args_interval(x.args[1:1 + n_iters])
            dv = self.eval(x.args[0], env) if x.args else None
            fan_hi = dv.total.hi if isinstance(dv, ADict) else d.const(INF)
            how = params.get("how", "inner")
            if how == "left":
                exp_hi = d.mul(n_pr.hi, d.smax(fan_hi, d.const(1)))
                lo = (n_pr.lo if not params.get("has_pred")
                      else d.const(0))
            else:
                exp_hi = d.mul(n_pr.hi, fan_hi)
                lo = d.const(0)
            derived = Interval(lo, exp_hi)
            out_cap = params.get("out_cap")
            decl = d.const(int(out_cap)) if out_cap is not None else None
            self.builders.append(BuilderBound(
                x, "group_probe", derived, decl, "out_cap"))
            hi = exp_hi if decl is None else d.smin(decl, exp_hi)
            return self._probe_struct(x, Interval(lo, hi))
        return None  # matmul/matvec/filter_reduce: no row-count meaning

    def _probe_struct(self, x: ir.KernelCall, rows: Interval):
        ret = x.ret_ty
        if isinstance(ret, wt.Struct):
            return AStruct(tuple(AVec(rows) for _ in ret.fields))
        return AVec(rows)


def _root_slot(tgt: ir.Expr):
    while isinstance(tgt, ir.Merge):
        tgt = tgt.builder
    if isinstance(tgt, ir.GetField) and isinstance(tgt.expr, ir.Ident):
        return (tgt.expr.name, tgt.index)
    if isinstance(tgt, ir.Ident):
        return (tgt.name, None)
    return None


def _sum(a: Dict, b: Dict) -> Dict:
    out = dict(a)
    for k, v in b.items():
        out[k] = out[k].add(v) if k in out else v
    return out


def _join_counts(t: Dict, f: Dict) -> Dict:
    out = {}
    for k in set(t) | set(f):
        out[k] = t.get(k, d.ZERO).join(f.get(k, d.ZERO))
    return out


# -- report ---------------------------------------------------------------


@dataclass
class BoundsReport:
    expr: ir.Expr
    inputs: List[str]
    rename: Dict[str, str]
    builders: List[BuilderBound] = field(default_factory=list)
    terms: List[Tuple[str, Sym]] = field(default_factory=list)
    result: object = None
    name_rows: Dict[str, Interval] = field(default_factory=dict)

    def certificate(self) -> str:
        """The symbolic peak-memory expression, human-readable."""
        if not self.terms:
            return "0"
        return " + ".join(d.render(t, self.rename) for _, t in self.terms)

    def peak(self, shapes: Optional[Shapes]) -> int:
        """Certificate evaluated at concrete shapes (bytes).  Terms the
        emitter couldn't resolve either charge 0 there too."""
        shp = {k: tuple(v) for k, v in (shapes or {}).items() if v}
        total = 0
        for _, t in self.terms:
            v = d.evaluate(t, shp)
            if v is None or v == INF:
                continue
            total += int(v)
        return total

    def result_interval(self) -> Optional[Interval]:
        v = self.result
        if isinstance(v, AStruct):
            for item in v.items:
                if isinstance(item, AVec):
                    return item.n
            return None
        if isinstance(v, AVec):
            return v.n
        if isinstance(v, ADict):
            return v.size
        return None

    def result_rows(self, shapes: Optional[Shapes]
                    ) -> Optional[Tuple[int, Optional[int]]]:
        iv = self.result_interval()
        if iv is None:
            return None
        shp = {k: tuple(v) for k, v in (shapes or {}).items() if v}
        hi = iv.hi_val(shp)
        return (iv.lo_val(shp), None if hi == INF else int(hi))

    def name_bounds(self, shapes: Optional[Shapes]
                    ) -> Dict[str, Tuple[int, Optional[int]]]:
        """Concrete ``[lo, hi]`` per let-bound vector — the planner's
        interval-midpoint cost inputs."""
        shp = {k: tuple(v) for k, v in (shapes or {}).items() if v}
        out = {}
        for name, iv in self.name_rows.items():
            hi = iv.hi_val(shp)
            out[name] = (iv.lo_val(shp), None if hi == INF else int(hi))
        return out

    def capacity_bounds(self, shapes: Optional[Shapes]
                        ) -> Dict[int, Tuple[int, Optional[int]]]:
        """``id(NewBuilder) -> (lb, ub)`` for dict/group capacity sites
        — the recovery ladder's clamp.  ``lb`` is a lower bound on the
        SLOTS needed (distinct keys: >=1 whenever anything merges), ub
        an upper bound (total merged rows)."""
        shp = {k: tuple(v) for k, v in (shapes or {}).items() if v}
        out = {}
        for bb in self.builders:
            if bb.role != "cap":
                continue
            lb = 1 if bb.rows.lo_val(shp) >= 1 else 0
            hi = bb.rows.hi_val(shp)
            out[id(bb.node)] = (lb, None if hi == INF else int(hi))
        return out

    def builder_lines(self, shapes: Optional[Shapes]) -> List[str]:
        shp = {k: tuple(v) for k, v in (shapes or {}).items() if v}
        lines = []
        for bb in self.builders:
            hi = bb.rows.hi_val(shp)
            hi_s = "inf" if hi == INF else str(int(hi))
            decl = ""
            if bb.declared is not None:
                dv = d.evaluate(bb.declared, shp)
                shown = (d.render(bb.declared, self.rename)
                         if dv is None else str(int(dv)))
                decl = f" {bb.role}={shown}"
            lines.append(
                f"{bb.kind:<22} rows={bb.rows.render(self.rename)}"
                f" = [{bb.rows.lo_val(shp)}, {hi_s}]{decl}")
        return lines


def analyze(e: ir.Expr, env=None) -> BoundsReport:
    """Run the interval interpreter + certificate walk over a program.
    ``env`` (name -> WeldType) is accepted for checkpoint-API symmetry;
    input types come from the program's free variables."""
    fv = ir.free_vars(e)
    inputs = sorted(fv)
    rename = {n: f"in{i}" for i, n in enumerate(inputs)}
    a = _Analyzer()
    env0: Dict[str, object] = {}
    for name, ty in fv.items():
        if isinstance(ty, wt.Vec):
            n = d.length(name)
            env0[name] = AVec(d.point(n))
    result = a.eval(e, env0)
    return BoundsReport(expr=e, inputs=inputs, rename=rename,
                        builders=a.builders, terms=_charge_terms(e),
                        result=result, name_rows=a.name_rows)
