"""weldbound: static size & memory-bounds analysis over the Weld IR.

``domain`` carries the symbolic-arithmetic and interval lattice;
``bounds`` is the abstract interpreter that derives per-builder size
intervals and the whole-plan peak-memory certificate the runtime's
admission check, the planner, and the recovery ladder consume.
"""
from . import bounds, domain  # noqa: F401
