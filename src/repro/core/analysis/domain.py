"""Symbolic sizes and the interval lattice for weldbound.

A ``Sym`` is a tiny symbolic integer expression over input lengths
(``len(in_k)``), constants, and the arithmetic the IR's static size
evaluator understands (``+ - * / min max``).  ``evaluate`` mirrors the
backend's ``_static_eval`` exactly — same operator set, same truncating
division, same "unresolvable -> None" contract — so a certificate
evaluated at bind time charges byte-for-byte what the emitter would
charge at trace time.

``Interval`` is the nonnegative-size abstract domain ``[lo, hi]`` the
bounds interpreter computes in: ``lo`` is a proven lower bound (unknown
degrades to 0), ``hi`` a proven upper bound (unknown degrades to +inf).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple, Union

#: sentinel for "unbounded" — compares/propagates like IEEE infinity.
INF = math.inf

Shapes = Dict[str, Tuple[int, ...]]


class Sym:
    """Base class for symbolic size expressions."""

    __slots__ = ()


@dataclass(frozen=True)
class SConst(Sym):
    value: float  # int or INF


@dataclass(frozen=True)
class SLen(Sym):
    """``len(name)`` — leading dimension of the input bound to ``name``."""

    name: str


@dataclass(frozen=True)
class SOp(Sym):
    op: str  # + - * / min max
    left: Sym
    right: Sym


class SCall(Sym):
    """An opaque kernel-footprint term: a closure over the registry's
    footprint hook, resolved only when concrete shapes are bound.  Kept
    out of the dataclass family on purpose — equality is identity (two
    calls to the same kernel are distinct charges)."""

    __slots__ = ("kernel", "fn", "display")

    def __init__(self, kernel: str, fn: Callable[[Shapes], int],
                 display: Optional[Sym] = None):
        self.kernel = kernel
        self.fn = fn
        self.display = display


# -- folding constructors -------------------------------------------------


def const(v: Union[int, float]) -> SConst:
    return SConst(INF if v == INF else int(v))


def length(name: str) -> SLen:
    return SLen(name)


def _is_const(s: Sym, v: Optional[float] = None) -> bool:
    return isinstance(s, SConst) and (v is None or s.value == v)


def add(a: Sym, b: Sym) -> Sym:
    if isinstance(a, SConst) and isinstance(b, SConst):
        return const(a.value + b.value)
    if _is_const(a, 0):
        return b
    if _is_const(b, 0):
        return a
    return SOp("+", a, b)


def sub(a: Sym, b: Sym) -> Sym:
    if isinstance(a, SConst) and isinstance(b, SConst):
        return const(a.value - b.value)
    if _is_const(b, 0):
        return a
    return SOp("-", a, b)


def mul(a: Sym, b: Sym) -> Sym:
    if _is_const(a, 0) or _is_const(b, 0):
        return const(0)
    if isinstance(a, SConst) and isinstance(b, SConst):
        return const(a.value * b.value)
    if _is_const(a, 1):
        return b
    if _is_const(b, 1):
        return a
    return SOp("*", a, b)


def div(a: Sym, b: Sym) -> Sym:
    if isinstance(a, SConst) and isinstance(b, SConst):
        return const(_apply("/", a.value, b.value))
    return SOp("/", a, b)


def smin(a: Sym, b: Sym) -> Sym:
    if a == b:
        return a
    if isinstance(a, SConst) and isinstance(b, SConst):
        return const(min(a.value, b.value))
    if _is_const(a, INF):
        return b
    if _is_const(b, INF):
        return a
    return SOp("min", a, b)


def smax(a: Sym, b: Sym) -> Sym:
    if a == b:
        return a
    if isinstance(a, SConst) and isinstance(b, SConst):
        return const(max(a.value, b.value))
    if _is_const(a, INF) or _is_const(b, INF):
        return const(INF)
    # sizes are nonnegative, so max(x, 0) = x
    if _is_const(a, 0):
        return b
    if _is_const(b, 0):
        return a
    return SOp("max", a, b)


# -- evaluation (mirrors jaxgen._static_eval) -----------------------------


def _apply(op: str, a: float, b: float) -> float:
    if op == "+":
        return a + b
    if op == "-":
        return a - b
    if op == "*":
        # interval arithmetic can pair 0 with INF (zero iterations of an
        # unbounded body): the product of sizes is still 0
        if a == 0 or b == 0:
            return 0
        v = a * b
        return v if v == INF or v == -INF else int(v)
    if op == "/":
        if b == 0:
            return 0  # mirror: the emitter's static eval yields 0 on /0
        if a == INF:
            return INF
        if b == INF:
            return 0
        return int(a / b)
    if op == "min":
        return min(a, b)
    if op == "max":
        return max(a, b)
    raise ValueError(f"unknown sym op {op}")


def evaluate(s: Sym, shapes: Shapes) -> Optional[float]:
    """Resolve ``s`` against concrete input shapes.  Returns an int (or
    ``INF`` for unbounded constants), or None when a referenced input is
    absent from ``shapes`` — the same "can't resolve" answer the
    emitter's ``_static_eval`` gives, under which it charges nothing."""
    if isinstance(s, SConst):
        return s.value
    if isinstance(s, SLen):
        shp = shapes.get(s.name)
        if shp is None or not len(shp):
            return None
        return int(shp[0])
    if isinstance(s, SOp):
        a = evaluate(s.left, shapes)
        b = evaluate(s.right, shapes)
        if a is None or b is None:
            return None
        return _apply(s.op, a, b)
    if isinstance(s, SCall):
        try:
            return int(s.fn(shapes))
        except Exception:
            return 0  # mirror: the emitter swallows footprint errors as 0
    return None


def render(s: Sym, rename: Optional[Dict[str, str]] = None) -> str:
    """Human-readable form: ``len(in0)*len(in1)`` / ``min(a, b)`` /
    ``fp[hash_probe](len(in0))``."""
    rename = rename or {}
    if isinstance(s, SConst):
        return "inf" if s.value == INF else str(int(s.value))
    if isinstance(s, SLen):
        return f"len({rename.get(s.name, s.name)})"
    if isinstance(s, SOp):
        a, b = render(s.left, rename), render(s.right, rename)
        if s.op in ("min", "max"):
            return f"{s.op}({a}, {b})"
        if isinstance(s.left, SOp) and s.left.op not in ("min", "max"):
            a = f"({a})"
        if isinstance(s.right, SOp) and s.right.op not in ("min", "max"):
            b = f"({b})"
        return f"{a}{s.op}{b}"
    if isinstance(s, SCall):
        inner = render(s.display, rename) if s.display is not None else "..."
        return f"fp[{s.kernel}]({inner})"
    return "?"


# -- the interval domain --------------------------------------------------


@dataclass(frozen=True)
class Interval:
    """``[lo, hi]`` over nonnegative sizes, both bounds symbolic."""

    lo: Sym
    hi: Sym

    def add(self, other: "Interval") -> "Interval":
        return Interval(add(self.lo, other.lo), add(self.hi, other.hi))

    def mul(self, other: "Interval") -> "Interval":
        # both operands nonnegative: lo*lo / hi*hi are the extremes
        return Interval(mul(self.lo, other.lo), mul(self.hi, other.hi))

    def join(self, other: "Interval") -> "Interval":
        return Interval(smin(self.lo, other.lo), smax(self.hi, other.hi))

    def lo_val(self, shapes: Shapes) -> int:
        """Concrete sound lower bound (unknown degrades to 0)."""
        v = evaluate(self.lo, shapes)
        if v is None or v == INF:
            return 0
        return max(0, int(v))

    def hi_val(self, shapes: Shapes) -> float:
        """Concrete sound upper bound (unknown degrades to +inf)."""
        v = evaluate(self.hi, shapes)
        if v is None:
            return INF
        return v if v == INF else max(0, int(v))

    def render(self, rename: Optional[Dict[str, str]] = None) -> str:
        return f"[{render(self.lo, rename)}, {render(self.hi, rename)}]"


def point(s: Sym) -> Interval:
    return Interval(s, s)


def top() -> Interval:
    return Interval(const(0), const(INF))


ZERO = point(const(0))
ONE = point(const(1))
