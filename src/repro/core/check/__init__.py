"""weldcheck: a static IR verifier + race/linearity linter.

Four analyses over a Weld program, run from one shared non-throwing type
annotation pass (so a checkpoint costs one O(n) walk plus three linear
lints, never repeated inference):

1. **types** (``verify_types.annotate``) — whole-program type/shape
   re-verification closing over ``Let``/``Lambda``/``For`` environments,
   including planner ``KernelCall`` output types (WV1xx);
2. **linearity** (``linear.lint_linearity``) — every builder consumed
   exactly once per control path (WV2xx);
3. **races** (``races.lint_races``) — non-commutative merges, reads of a
   builder mid-construction, aliasing scatters (WV3xx);
4. **capacity** (``capacity.lint_capacity``) — capacity/poison
   soundness, plus the differential ``verify_rewrite`` used by
   recovery's regrow (WV4xx);
5. **bounds** (``bounds_lint.lint_bounds``) — declared sizes vs. the
   weldbound interval analysis (hint below the derived lower bound,
   capacity above the proven upper bound, peak-memory certificate
   contradicting ``memory_limit``) (WV5xx).

The pipeline calls :func:`checkpoint` after every optimizer pass, after
kernel planning, and after every recovery rewrite.  Checkpoints are
no-ops unless ``WELD_VERIFY=1`` (tests/CI default it on); a violation
raises :class:`~repro.core.errors.WeldVerifyError` naming the pass, the
diagnostic code, and the pretty-printed offending subexpression.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Optional, Sequence

from .. import ir
from .. import obs
from .. import wtypes as wt
from ..errors import WeldVerifyError
from .bounds_lint import lint_bounds
from .capacity import check_regrow_monotone, lint_capacity
from .diagnostics import CODES, Diagnostic
from .linear import lint_linearity
from .races import lint_races
from .verify_types import annotate

__all__ = [
    "CODES",
    "Diagnostic",
    "WeldVerifyError",
    "ENV_VERIFY",
    "enabled",
    "set_enabled",
    "annotate",
    "verify",
    "checkpoint",
    "verify_rewrite",
]

ENV_VERIFY = "WELD_VERIFY"

#: analysis name -> lint entrypoint (all take (expr, types) -> [Diagnostic];
#: "bounds" additionally receives shapes/memory_limit keywords)
ANALYSES = {
    "linearity": lint_linearity,
    "races": lint_races,
    "capacity": lint_capacity,
    "bounds": lint_bounds,
}

_override: Optional[bool] = None


def enabled() -> bool:
    """True when checkpoints should run (``WELD_VERIFY=1`` or a
    programmatic override).  Read dynamically so tests can flip it."""
    if _override is not None:
        return _override
    v = os.environ.get(ENV_VERIFY, "")
    return str(v).strip().lower() not in ("", "0", "false", "no", "off")


def set_enabled(value: Optional[bool]) -> None:
    """Force verification on/off regardless of the environment;
    ``None`` restores environment control."""
    global _override
    _override = value


def verify(
    e: ir.Expr,
    env: Optional[Dict[str, wt.WeldType]] = None,
    analyses: Optional[Sequence[str]] = None,
    shapes: Optional[dict] = None,
    memory_limit: Optional[int] = None,
) -> List[Diagnostic]:
    """Run the verifier over ``e`` and return every diagnostic found.

    ``env`` types the program's free identifiers; when omitted it is
    recovered from the idents' own annotations (sufficient for
    post-frontend IR, where frames stamp input types on the roots).
    ``shapes`` (input name -> shape) lets the bounds lint resolve
    symbolic sizes; ``memory_limit`` additionally arms the WV503
    certificate-contradiction check (checkpoints never pass it — the
    admission path owns that rejection with a typed ResourceError).
    """
    if env is None:
        env = {k: t for k, t in ir.free_vars(e).items() if t is not None}
    types, diags = annotate(e, env)
    root_ty = types.get(id(e))
    if isinstance(root_ty, wt.BuilderType):
        diags.append(Diagnostic(
            "WV201",
            f"program evaluates to an unconsumed builder ({root_ty}) — "
            f"missing result()",
            e, analysis="linearity"))
    for name in (analyses if analyses is not None else ANALYSES):
        if name == "bounds":
            diags.extend(ANALYSES[name](e, types, shapes=shapes,
                                        memory_limit=memory_limit))
        else:
            diags.extend(ANALYSES[name](e, types))
    return diags


def checkpoint(
    phase: str,
    e: ir.Expr,
    env: Optional[Dict[str, wt.WeldType]] = None,
    stats: Optional[dict] = None,
    shapes: Optional[dict] = None,
) -> None:
    """Verify ``e`` at a named pipeline point; raise on violations.

    No-op when verification is disabled.  Timing and outcome land in
    ``stats["verify.*"]`` and a weldtrace ``verify`` span.
    """
    if not enabled():
        return
    t0 = time.perf_counter()
    with obs.span("verify", phase=phase) as sp:
        diags = verify(e, env=env, shapes=shapes)
        sp.set("diagnostics", len(diags))
    ms = (time.perf_counter() - t0) * 1e3
    if stats is not None:
        stats["verify.runs"] = stats.get("verify.runs", 0) + 1
        stats["verify.ms"] = stats.get("verify.ms", 0.0) + ms
        stats.setdefault("verify.phases", []).append((phase, round(ms, 3)))
    if diags:
        _raise(phase, e, diags)


def verify_rewrite(
    phase: str,
    before: ir.Expr,
    after: ir.Expr,
    stats: Optional[dict] = None,
) -> None:
    """Differential checkpoint for capacity rewrites: ``after`` must
    verify clean *and* every capacity must dominate its counterpart in
    ``before`` (WV404)."""
    if not enabled():
        return
    t0 = time.perf_counter()
    with obs.span("verify", phase=phase, differential=True) as sp:
        diags = check_regrow_monotone(before, after)
        diags.extend(verify(after))
        sp.set("diagnostics", len(diags))
    ms = (time.perf_counter() - t0) * 1e3
    if stats is not None:
        stats["verify.runs"] = stats.get("verify.runs", 0) + 1
        stats["verify.ms"] = stats.get("verify.ms", 0.0) + ms
        stats.setdefault("verify.phases", []).append((phase, round(ms, 3)))
    if diags:
        _raise(phase, after, diags)


def _raise(phase: str, root: ir.Expr, diags: List[Diagnostic]) -> None:
    from ..pretty import pretty

    lines = [f"weldcheck failed after {phase!r} "
             f"({len(diags)} diagnostic{'s' if len(diags) != 1 else ''}):"]
    lines += [f"  {d.render(root)}" for d in diags]
    first = next((d.node for d in diags if d.node is not None), None)
    if first is not None:
        lines.append("program (offender highlighted):")
        lines.append(pretty(root, anchors=True, highlight=first))
    raise WeldVerifyError("\n".join(lines), phase=phase, diagnostics=diags)
