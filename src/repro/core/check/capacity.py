"""Analysis 4: capacity / poison soundness.

Dict and group builders carry static capacity literals; the runtime's
poison convention (negative counts on overflow) plus the recovery
ladder's geometric regrow depend on those literals being well-formed and
mutually consistent:

* **WV401** — a dict/group ``NewBuilder`` capacity literal must be a
  positive integer: zero/negative capacities poison unconditionally and
  regrowing them (``cap * factor``) is not monotone.
* **WV402** — a ``KernelCall``'s capacity-like params (``capacity``,
  ``k``, ``out_cap``) must be positive, and a probe call's segment width
  must agree with the static capacity of the let-bound dict it probes —
  a shrunk build capacity with a stale probe plan scans the wrong tile.
* **WV403** — a vecbuilder ``size_hint`` must not be negative and must
  not duplicate a loop (hints are metadata; the backend may evaluate
  them for preallocation).
* **WV404** — differential: a capacity rewrite (recovery's
  ``regrow_capacities``) must be monotone — every capacity in the new
  program ≥ its counterpart in the old one (checked by
  :func:`check_regrow_monotone`).
"""
from __future__ import annotations

from typing import Dict, List, Optional

from .. import ir
from .. import wtypes as wt
from .diagnostics import Diagnostic

#: kernels whose first arg is a probed dict and whose segment width must
#: match that dict's build capacity
_PROBE_KERNELS = ("hash_probe", "group_probe")
#: kernels that build a dict and carry its capacity as a param
_BUILD_KERNELS = ("dict_hash_build", "group_build", "dict_group_sum")


def lint_capacity(
    e: ir.Expr,
    types: Dict[int, Optional[wt.WeldType]],
) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    #: let-bound name -> static capacity of the dict it holds
    dict_caps: Dict[str, int] = {}

    def note_binding(name: str, v: ir.Expr) -> None:
        cap = _static_dict_cap(v)
        if cap is not None:
            dict_caps[name] = cap

    def rec(x: ir.Expr) -> None:
        if isinstance(x, ir.Let):
            rec(x.value)
            note_binding(x.name, x.value)
            rec(x.body)
            return
        if isinstance(x, ir.NewBuilder):
            _lint_newbuilder(x, diags)
        if isinstance(x, ir.KernelCall):
            _lint_kernelcall(x, dict_caps, diags)
        for c in x.children():
            rec(c)

    rec(e)
    return diags


def _static_dict_cap(v: ir.Expr) -> Optional[int]:
    """Static capacity of a let-bound dict value (kernelized or not) —
    mirrors the planner's ``_dict_cap_of``."""
    if isinstance(v, ir.KernelCall) and v.kernel in _BUILD_KERNELS:
        cap = dict(v.params).get("capacity")
        return int(cap) if cap is not None else None
    if isinstance(v, ir.Result) and isinstance(v.builder, ir.For):
        nb = v.builder.builder
        if isinstance(nb, ir.NewBuilder) \
                and isinstance(nb.ty, (wt.DictMerger, wt.GroupBuilder)) \
                and isinstance(nb.arg, ir.Literal):
            return int(nb.arg.value)
    return None


def _lint_newbuilder(nb: ir.NewBuilder, diags: List[Diagnostic]) -> None:
    if isinstance(nb.ty, (wt.DictMerger, wt.GroupBuilder)) \
            and isinstance(nb.arg, ir.Literal):
        v = nb.arg.value
        ok_kind = isinstance(nb.arg.ty, wt.Scalar) and nb.arg.ty.is_int
        if not ok_kind or not isinstance(v, (int,)) or v <= 0:
            diags.append(Diagnostic(
                "WV401",
                f"dict/group capacity must be a positive int literal, "
                f"got {v!r}:{nb.arg.ty}",
                nb, analysis="capacity", data={"capacity": v}))
    if nb.size_hint is not None:
        if isinstance(nb.size_hint, ir.Literal) \
                and isinstance(nb.size_hint.value, int) \
                and nb.size_hint.value < 0:
            diags.append(Diagnostic(
                "WV403",
                f"negative size hint {nb.size_hint.value}",
                nb, analysis="capacity",
                data={"hint": nb.size_hint.value}))
        elif any(isinstance(n, ir.For) for n in ir.walk(nb.size_hint)):
            diags.append(Diagnostic(
                "WV403",
                "size hint duplicates a loop — hints must be cheap "
                "metadata, never recomputation",
                nb, analysis="capacity"))


def _lint_kernelcall(kc: ir.KernelCall, dict_caps: Dict[str, int],
                     diags: List[Diagnostic]) -> None:
    params = dict(kc.params)
    for key in ("capacity", "k", "out_cap"):
        v = params.get(key)
        if v is None:
            continue
        # out_cap is an *output* size bound: 0 is legal (empty probe side)
        floor = 0 if key == "out_cap" else 1
        if not isinstance(v, int) or v < floor:
            diags.append(Diagnostic(
                "WV402",
                f"kernel {kc.kernel!r} param {key}={v!r} must be an int "
                f">= {floor}",
                kc, analysis="capacity", data={key: v}))
    if kc.kernel in _PROBE_KERNELS and kc.args:
        d = kc.args[0]
        seg = params.get("k", params.get("capacity"))
        if isinstance(d, ir.Ident) and d.name in dict_caps \
                and isinstance(seg, int):
            built = dict_caps[d.name]
            if seg != built:
                diags.append(Diagnostic(
                    "WV402",
                    f"probe kernel {kc.kernel!r} scans segment width "
                    f"{seg} but dict {d.name} was built with capacity "
                    f"{built}",
                    kc, analysis="capacity",
                    data={"segment": seg, "built": built}))


def check_regrow_monotone(
    before: ir.Expr, after: ir.Expr,
) -> List[Diagnostic]:
    """WV404: every dict/group capacity literal in ``after`` must
    dominate its positional counterpart in ``before`` — the recovery
    regrow rewrite preserves structure, so capacities align by preorder
    position."""

    def caps(e: ir.Expr):
        out = []
        for n in ir.walk(e):
            if isinstance(n, ir.NewBuilder) \
                    and isinstance(n.ty, (wt.DictMerger, wt.GroupBuilder)) \
                    and isinstance(n.arg, ir.Literal):
                out.append((n, n.arg.value))
        return out

    b, a = caps(before), caps(after)
    diags: List[Diagnostic] = []
    if len(b) != len(a):
        diags.append(Diagnostic(
            "WV404",
            f"capacity rewrite changed builder count "
            f"({len(b)} -> {len(a)})",
            after, analysis="capacity"))
        return diags
    for (_, old), (node, new) in zip(b, a):
        if new < old:
            diags.append(Diagnostic(
                "WV404",
                f"capacity rewrite shrank a capacity ({old} -> {new}); "
                f"regrow must be monotone",
                node, analysis="capacity",
                data={"old": old, "new": new}))
    return diags
