"""Analysis 1: whole-program type/shape re-verification.

A non-throwing re-implementation of ``ir.typeof`` that closes over
``Let``/``Lambda``/``For`` environments from the program roots, annotates
every node with its inferred type (``id(node) -> WeldType``), and records
:class:`Diagnostic` objects instead of raising — so one broken
subexpression doesn't hide the rest, and so the later analyses
(linearity, races, capacity) can reuse the type map without re-running
inference per binding.

Unknowns propagate as ``None``: a node whose operand failed to type
yields no *cascading* diagnostics, only the root cause is reported.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .. import ir
from .. import wtypes as wt
from .diagnostics import Diagnostic

MAX_DIAGS = 25

_INT_KINDS = ("i8", "i32", "i64")


def annotate(
    e: ir.Expr,
    env: Optional[Dict[str, wt.WeldType]] = None,
) -> Tuple[Dict[int, Optional[wt.WeldType]], List[Diagnostic]]:
    """Returns ``(types, diagnostics)`` — the per-node type map (by
    ``id``) and every type violation found, root causes only."""
    types: Dict[int, Optional[wt.WeldType]] = {}
    diags: List[Diagnostic] = []

    def bad(code: str, msg: str, node: ir.Expr, **data) -> None:
        if len(diags) < MAX_DIAGS:
            diags.append(Diagnostic(code, msg, node, analysis="types",
                                    data=data))

    def rec(x: ir.Expr, env: Dict[str, Optional[wt.WeldType]],
            binder: Optional[str]) -> Optional[wt.WeldType]:
        t = _infer(x, env, binder, rec, bad)
        types[id(x)] = t
        return t

    rec(e, dict(env or {}), None)
    return types, diags


def _infer(x, env, binder, rec, bad) -> Optional[wt.WeldType]:
    if isinstance(x, ir.Literal):
        return x.ty
    if isinstance(x, ir.Ident):
        if x.name in env:
            t = env[x.name]
            if t is not None and x.ty is not None and x.ty != t:
                bad("WV102",
                    f"identifier {x.name} annotated {x.ty} but bound as {t}"
                    + (f" (in {binder})" if binder else ""),
                    x, annotated=str(x.ty), bound=str(t))
            return t if t is not None else x.ty
        if x.ty is None:
            bad("WV101",
                f"identifier {x.name} carries no type and is not bound",
                x)
        return x.ty
    if isinstance(x, ir.Let):
        vt = rec(x.value, env, x.name)
        return rec(x.body, {**env, x.name: vt}, x.name)
    if isinstance(x, ir.BinOp):
        lt = rec(x.left, env, binder)
        rt = rec(x.right, env, binder)
        if lt is None or rt is None:
            return None
        if lt != rt:
            bad("WV101", f"binop {x.op} on mismatched types {lt} vs {rt}", x)
            return None
        if x.op in ir.CMP_OPS:
            return wt.Bool
        if x.op in ("&&", "||"):
            if lt != wt.Bool:
                bad("WV101", f"{x.op} requires bool, got {lt}", x)
            return wt.Bool
        if not isinstance(lt, wt.Scalar):
            bad("WV101", f"binop {x.op} on non-scalar {lt}", x)
            return None
        return lt
    if isinstance(x, ir.UnaryOp):
        t = rec(x.expr, env, binder)
        if t is None:
            return None
        if x.op == "not":
            if t != wt.Bool:
                bad("WV101", f"not requires bool, got {t}", x)
            return wt.Bool
        if not isinstance(t, wt.Scalar):
            bad("WV101", f"unary {x.op} on non-scalar {t}", x)
            return None
        return t
    if isinstance(x, ir.Cast):
        rec(x.expr, env, binder)
        return x.ty
    if isinstance(x, (ir.If, ir.Select)):
        ct = rec(x.cond, env, binder)
        if ct is not None and ct != wt.Bool:
            bad("WV101", f"condition must be bool, got {ct}", x.cond)
        tt = rec(x.on_true, env, binder)
        ft = rec(x.on_false, env, binder)
        if tt is not None and ft is not None and tt != ft:
            bad("WV101", f"branch types differ: {tt} vs {ft}", x)
            return None
        return tt if tt is not None else ft
    if isinstance(x, ir.MakeStruct):
        tys = tuple(rec(i, env, binder) for i in x.items)
        if any(t is None for t in tys):
            return None
        if any(isinstance(t, wt.BuilderType) for t in tys):
            if not all(isinstance(t, wt.BuilderType) for t in tys):
                bad("WV101", "cannot mix builders and values in struct", x)
                return None
            return wt.StructBuilder(tys)
        return wt.Struct(tys)
    if isinstance(x, ir.GetField):
        st = rec(x.expr, env, binder)
        if st is None:
            return None
        if isinstance(st, (wt.Struct, wt.StructBuilder)):
            flds = st.fields if isinstance(st, wt.Struct) else st.builders
            if not (0 <= x.index < len(flds)):
                bad("WV101",
                    f"getfield index {x.index} out of range for {st}", x)
                return None
            return flds[x.index]
        bad("WV101", f"getfield on non-struct {st}", x)
        return None
    if isinstance(x, ir.MakeVec):
        for i in x.items:
            it = rec(i, env, binder)
            if it is not None and it != x.elem_ty:
                bad("WV101", f"makevec elem {it} != {x.elem_ty}", i)
        return wt.Vec(x.elem_ty)
    if isinstance(x, ir.Len):
        vt = rec(x.expr, env, binder)
        if vt is not None and not isinstance(vt, wt.Vec):
            bad("WV101", f"len of non-vec {vt}", x)
        return wt.I64
    if isinstance(x, ir.Lookup):
        ct = rec(x.expr, env, binder)
        it = rec(x.index, env, binder)
        if ct is None:
            if x.default is not None:
                rec(x.default, env, binder)
            return None
        if isinstance(ct, wt.Vec):
            if x.default is not None:
                bad("WV101", "vec lookup takes no default", x)
            if it is not None and not (isinstance(it, wt.Scalar)
                                       and it.is_int):
                bad("WV101", f"vec lookup index must be int, got {it}", x)
            return ct.elem
        if isinstance(ct, wt.DictType):
            if it is not None and it != ct.key:
                bad("WV101",
                    f"dict lookup key type {it} != dict key {ct.key}", x)
            if x.default is not None:
                dt = rec(x.default, env, binder)
                if dt is not None and dt != ct.val:
                    bad("WV101",
                        f"dict lookup default {dt} != value type {ct.val}",
                        x)
            return ct.val
        bad("WV101", f"lookup on {ct}", x)
        return None
    if isinstance(x, ir.KeyExists):
        ct = rec(x.expr, env, binder)
        if ct is not None and not isinstance(ct, wt.DictType):
            bad("WV101", f"keyexists on non-dict {ct}", x)
        rec(x.key, env, binder)
        return wt.Bool
    if isinstance(x, ir.GroupLookup):
        ct = rec(x.expr, env, binder)
        kt = rec(x.key, env, binder)
        if ct is None:
            return None
        if not (isinstance(ct, wt.DictType) and isinstance(ct.val, wt.Vec)):
            bad("WV101", f"grouplookup requires dict[K, vec[V]], got {ct}", x)
            return None
        if kt is not None and kt != ct.key:
            bad("WV101",
                f"grouplookup key type {kt} != dict key {ct.key}", x)
        return ct.val
    if isinstance(x, ir.CUDF):
        for a in x.args:
            rec(a, env, binder)
        return x.ret_ty
    if isinstance(x, ir.Lambda):
        env2 = dict(env)
        for p in x.params:
            env2[p.name] = p.ty
        bt = rec(x.body, env2, binder)
        if bt is None or any(p.ty is None for p in x.params):
            return None
        return wt.Fn(tuple(p.ty for p in x.params), bt)
    if isinstance(x, ir.NewBuilder):
        if x.arg is not None:
            at = rec(x.arg, env, binder)
            _check_builder_arg(x, at, bad)
        if x.size_hint is not None:
            ht = rec(x.size_hint, env, binder)
            if ht is not None and not (isinstance(ht, wt.Scalar)
                                       and ht.is_int):
                bad("WV104",
                    f"size hint must be an int scalar, got {ht}",
                    x.size_hint)
        return x.ty
    if isinstance(x, ir.Merge):
        bt = rec(x.builder, env, binder)
        vt = rec(x.value, env, binder)
        if bt is None:
            return None
        if not isinstance(bt, wt.BuilderType):
            bad("WV101", f"merge into non-builder {bt}", x)
            return None
        try:
            expect = ir.merge_arg_type(bt)
        except wt.WeldTypeError as err:
            bad("WV101", str(err), x)
            return bt
        if vt is not None and vt != expect:
            bad("WV101",
                f"merge type {vt}, builder wants {expect}", x)
        return bt
    if isinstance(x, ir.Result):
        bt = rec(x.builder, env, binder)
        if bt is None:
            return None
        if not isinstance(bt, wt.BuilderType):
            bad("WV101", f"result of non-builder {bt}", x)
            return None
        return bt.result_type()
    if isinstance(x, ir.Iter):
        dt = rec(x.data, env, binder)
        for bound in (x.start, x.end, x.stride):
            if bound is not None:
                bt = rec(bound, env, binder)
                if bt is not None and not (isinstance(bt, wt.Scalar)
                                           and bt.is_int):
                    bad("WV101",
                        f"iter bound must be an int scalar, got {bt}",
                        bound)
        if dt is None:
            return None
        if not isinstance(dt, wt.Vec):
            bad("WV101", f"iter over non-vec {dt}", x)
            return None
        return dt
    if isinstance(x, ir.KernelCall):
        for a in x.args:
            rec(a, env, binder)
        for f in x.fns:
            rec(f, env, binder)
        _check_kernel_known(x, bad)
        return x.ret_ty
    if isinstance(x, ir.For):
        bt = rec(x.builder, env, binder)
        elem_tys = []
        for it in x.iters:
            vt = rec(it, env, binder)
            elem_tys.append(vt.elem if isinstance(vt, wt.Vec) else None)
        ft = rec(x.func, env, binder)
        if bt is not None and not isinstance(bt, wt.BuilderType):
            bad("WV101", f"for-loop builder arg is not a builder: {bt}", x)
            return None
        if ft is None or bt is None or any(t is None for t in elem_tys):
            return bt
        elem = (elem_tys[0] if len(elem_tys) == 1
                else wt.Struct(tuple(elem_tys)))
        want = (bt, wt.I64, elem)
        if not isinstance(ft, wt.Fn):
            bad("WV101", f"for func is not a function: {ft}", x.func)
            return bt
        if tuple(ft.params) != want:
            bad("WV101",
                f"for func params {tuple(map(str, ft.params))} != "
                f"{tuple(map(str, want))}", x.func)
        elif ft.ret != bt:
            bad("WV101",
                f"for func returns {ft.ret}, builder is {bt}", x.func)
        return bt
    bad("WV101", f"cannot type {type(x).__name__}", x)
    return None


def _check_builder_arg(nb: ir.NewBuilder, at, bad) -> None:
    """WV104: the optional NewBuilder argument must fit the builder —
    merger initial value, vecmerger base vector, dict/group capacity."""
    if at is None:
        return
    bt = nb.ty
    if isinstance(bt, wt.Merger):
        if at != bt.elem:
            bad("WV104",
                f"merger init {at} != element type {bt.elem}", nb)
    elif isinstance(bt, wt.VecMerger):
        if at != wt.Vec(bt.elem):
            bad("WV104",
                f"vecmerger base {at} != vec[{bt.elem}]", nb)
    elif isinstance(bt, (wt.DictMerger, wt.GroupBuilder)):
        if not (isinstance(at, wt.Scalar) and at.is_int):
            bad("WV104",
                f"dict/group capacity must be an int scalar, got {at}", nb)


def _check_kernel_known(kc: ir.KernelCall, bad) -> None:
    """WV103: a planned kernel must exist in the registry."""
    try:
        from ..kernelplan import registry as reg
    except Exception:  # pragma: no cover - kernels lib unavailable
        return
    if reg.available(kc.kernel) is None:
        bad("WV103", f"kernel {kc.kernel!r} is not registered", kc)
