"""Diagnostic objects and the weldcheck code registry.

Codes are grouped by analysis family:

* ``WV1xx`` — whole-program type/shape re-verification
* ``WV2xx`` — builder linearity (consumed exactly once per path)
* ``WV3xx`` — merge-race lint (parallel-loop soundness)
* ``WV4xx`` — capacity / poison soundness
* ``WV5xx`` — weldbound size/memory-bounds contradictions

Every diagnostic carries the offending IR node so callers (the
``WeldVerifyError`` message, ``tools/weldlint.py``) can point at the
exact subexpression via ``pretty.anchor_of`` / ``highlight=``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .. import ir

#: code -> (slug, one-line description)
CODES = {
    # -- types ------------------------------------------------------------
    "WV101": ("type-error",
              "expression fails whole-program type re-verification"),
    "WV102": ("stale-ident-type",
              "identifier annotation disagrees with its binding's type"),
    "WV103": ("unknown-kernel",
              "KernelCall names a kernel absent from the registry"),
    "WV104": ("builder-arg-type",
              "NewBuilder argument has the wrong type for the builder"),
    # -- linearity --------------------------------------------------------
    "WV201": ("builder-unused",
              "builder bound but never consumed on any path"),
    "WV202": ("builder-reused",
              "builder consumed more than once along a control path"),
    "WV203": ("merge-after-result",
              "builder used again after result() consumed it"),
    "WV204": ("builder-captured-by-loop",
              "free builder captured by a loop body (consumed per iteration)"),
    "WV205": ("builder-branch-imbalance",
              "builder consumed on some control paths but not others"),
    "WV206": ("builder-valued-select",
              "select() over builders: both sides evaluate, breaking "
              "linearity"),
    # -- races ------------------------------------------------------------
    "WV301": ("noncommutative-merge",
              "merger-family builder carries a non-commutative merge op"),
    "WV302": ("read-during-build",
              "loop body reads a builder that is still being built"),
    "WV303": ("aliasing-scatter",
              "vecmerger scatter index can alias under a non-commutative "
              "combine"),
    # -- capacity ---------------------------------------------------------
    "WV401": ("bad-capacity",
              "dict/group builder capacity literal is not a positive int"),
    "WV402": ("kernel-capacity-mismatch",
              "KernelCall capacity param invalid or disagrees with the "
              "builder it lowers"),
    "WV403": ("unsound-size-hint",
              "size hint is negative or duplicates a loop"),
    "WV404": ("regrow-not-monotone",
              "capacity rewrite shrank a capacity (regrow must grow)"),
    # -- bounds (weldbound interval analysis) -----------------------------
    "WV501": ("size-below-lower-bound",
              "declared size is below the derived lower bound (buffer "
              "provably truncates)"),
    "WV502": ("size-above-upper-bound",
              "declared size exceeds the derived upper bound (allocation "
              "provably wastes budget)"),
    "WV503": ("certificate-exceeds-limit",
              "peak-memory certificate exceeds the plan's memory_limit"),
}


@dataclass
class Diagnostic:
    code: str
    message: str
    node: Optional[ir.Expr] = None
    #: analysis that produced it ("types", "linearity", "races", "capacity")
    analysis: str = ""
    #: extra structured context (binder name, counts, ...)
    data: dict = field(default_factory=dict)

    @property
    def slug(self) -> str:
        return CODES.get(self.code, ("?", ""))[0]

    def render(self, root: Optional[ir.Expr] = None) -> str:
        from ..pretty import anchor_of, short

        loc = ""
        if self.node is not None:
            anchor = anchor_of(root, self.node) if root is not None else None
            at = f"{anchor} " if anchor else ""
            loc = f" at {at}`{short(self.node)}`"
        return f"[{self.code} {self.slug}] {self.message}{loc}"

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        return self.render()
