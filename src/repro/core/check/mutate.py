"""Mutation harness: seeded IR sabotage to measure verifier recall.

Each mutator takes a well-typed program and returns ``(mutant,
expected_codes, target)`` — a broken variant, the diagnostic codes that
would legitimately catch it, and the node (or its replacement) the
verifier should name.  ``run_mutations`` applies every applicable
mutator at every applicable site (or a seeded sample) and scores the
verifier: a *catch* requires at least one diagnostic with an expected
code anchored at the mutated node (or any node for whole-type
corruptions, where the offender is a type embedded at many sites).

The mutators deliberately bypass the IR/type constructors
(``object.__setattr__`` on frozen dataclasses) — that is the point:
weldcheck guards against *passes* corrupting programs in ways the
constructors would have rejected.
"""
from __future__ import annotations

import copy
import random
from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from .. import ir
from .. import wtypes as wt
from . import verify
from .diagnostics import Diagnostic


@dataclass
class Mutation:
    """One applied sabotage."""
    name: str
    mutant: ir.Expr
    expected: Set[str]
    #: the node whose replacement carries the defect (None = anywhere)
    target: Optional[ir.Expr]


@dataclass
class Score:
    applied: int = 0
    caught: int = 0
    misses: List[Tuple[str, List[str]]] = None  # (mutator, codes seen)

    def __post_init__(self):
        if self.misses is None:
            self.misses = []

    @property
    def rate(self) -> float:
        return self.caught / self.applied if self.applied else 1.0


def _replace_node(root: ir.Expr, old: ir.Expr,
                  new: ir.Expr) -> ir.Expr:
    """Rebuild ``root`` with the single occurrence of ``old`` (by
    identity) swapped for ``new``."""

    def rec(x: ir.Expr) -> ir.Expr:
        if x is old:
            return new
        return x.map_children(rec)

    return rec(root)


def _sites(e: ir.Expr, pred: Callable[[ir.Expr], bool]) -> List[ir.Expr]:
    return [n for n in ir.walk(e) if pred(n)]


# -- mutators ------------------------------------------------------------


def drop_result(e: ir.Expr, rng: random.Random) -> Optional[Mutation]:
    """Delete a Result(For(...)) wrapper: the loop's builder escapes
    unconsumed / the program's type changes."""
    sites = _sites(e, lambda n: isinstance(n, ir.Result))
    if not sites:
        return None
    r = rng.choice(sites)
    # a dropped result shows up as a type break at the use sites
    # (WV101/WV102), an unconsumed or loop-captured builder
    # (WV201/WV204/WV205), or a builder-typed program root (WV201)
    return Mutation("drop_result", _replace_node(e, r, r.builder),
                    {"WV101", "WV102", "WV201", "WV204", "WV205"},
                    r.builder)


def swap_merge_op(e: ir.Expr, rng: random.Random) -> Optional[Mutation]:
    """Corrupt a merger-family op to non-commutative '-' in place
    (bypassing the constructor's commutativity guard)."""

    def has_merger(n):
        return isinstance(n, ir.NewBuilder) and isinstance(
            n.ty, (wt.Merger, wt.DictMerger, wt.VecMerger))

    sites = _sites(e, has_merger)
    if not sites:
        return None
    nb = rng.choice(sites)
    bad_ty = copy.copy(nb.ty)
    object.__setattr__(bad_ty, "op", "-")
    bad = replace(nb, ty=bad_ty)
    return Mutation("swap_merge_op", _replace_node(e, nb, bad),
                    {"WV301"}, bad)


def shrink_capacity(e: ir.Expr, rng: random.Random) -> Optional[Mutation]:
    """Zero out a dict/group capacity literal."""

    def is_cap(n):
        return (isinstance(n, ir.NewBuilder)
                and isinstance(n.ty, (wt.DictMerger, wt.GroupBuilder))
                and isinstance(n.arg, ir.Literal))

    sites = _sites(e, is_cap)
    if not sites:
        return None
    nb = rng.choice(sites)
    bad = replace(nb, arg=ir.Literal(0, nb.arg.ty))
    return Mutation("shrink_capacity", _replace_node(e, nb, bad),
                    {"WV401"}, bad)


def retype_param(e: ir.Expr, rng: random.Random) -> Optional[Mutation]:
    """Flip a scalar lambda parameter's type (i64 <-> f64): the loop
    signature check and every arithmetic use goes inconsistent."""

    def scalar_param(n):
        return isinstance(n, ir.Lambda) and any(
            isinstance(p.ty, wt.Scalar) for p in n.params)

    sites = _sites(e, scalar_param)
    if not sites:
        return None
    lam = rng.choice(sites)
    idx = rng.choice([i for i, p in enumerate(lam.params)
                      if isinstance(p.ty, wt.Scalar)])
    old_p = lam.params[idx]
    new_ty = wt.F64 if old_p.ty != wt.F64 else wt.I64
    new_p = ir.Ident(old_p.name, new_ty)
    bad = replace(lam, params=tuple(
        new_p if i == idx else p for i, p in enumerate(lam.params)))
    return Mutation("retype_param", _replace_node(e, lam, bad),
                    {"WV101", "WV102"}, None)


def getfield_oob(e: ir.Expr, rng: random.Random) -> Optional[Mutation]:
    """Push a GetField index out of range."""
    sites = _sites(e, lambda n: isinstance(n, ir.GetField))
    if not sites:
        return None
    gf = rng.choice(sites)
    bad = replace(gf, index=gf.index + 64)
    return Mutation("getfield_oob", _replace_node(e, gf, bad),
                    {"WV101"}, bad)


def dup_builder(e: ir.Expr, rng: random.Random) -> Optional[Mutation]:
    """Alias a builder-typed loop and merge into both names — the
    classic linearity violation."""

    def builder_for(n):
        return isinstance(n, ir.For) and isinstance(
            n.builder, ir.NewBuilder)

    sites = _sites(e, builder_for)
    if not sites:
        return None
    loop = rng.choice(sites)
    name = ir.fresh("dup")
    # let dup = newbuilder in for(iters, dup, fn(... uses of dup twice))
    alias = ir.Ident(name, loop.builder.ty)
    bad_for = replace(loop, builder=alias)
    two = ir.Let(name, loop.builder,
                 ir.MakeStruct((bad_for, alias)))
    return Mutation("dup_builder", _replace_node(e, loop, two),
                    {"WV202", "WV101", "WV201", "WV205"}, None)


def _probe_out_caps(e: ir.Expr, left_only: bool = False
                    ) -> List[ir.KernelCall]:
    """Planned ``group_probe`` calls carrying an ``out_cap`` param (the
    post-kernelplan spelling of an expansion-buffer size)."""
    out = []
    for n in ir.walk(e):
        if not (isinstance(n, ir.KernelCall)
                and n.kernel == "group_probe"):
            continue
        params = dict(n.params)
        if "out_cap" not in params:
            continue
        if left_only and not (params.get("how") == "left"
                              and not params.get("has_pred")):
            continue
        out.append(n)
    return out


def _with_out_cap(kc: ir.KernelCall, value: int) -> ir.KernelCall:
    return replace(kc, params=tuple(
        (k, value if k == "out_cap" else v) for k, v in kc.params))


def inflate_size_hint(e: ir.Expr, rng: random.Random) -> Optional[Mutation]:
    """Blow a vecbuilder size hint (or a planned group_probe out_cap)
    far past anything the inputs could produce — the weldbound interval
    analysis proves the declared size exceeds the derived upper bound
    (WV502: budget provably wasted / certificate inflated)."""

    def is_hinted(n):
        return (isinstance(n, ir.NewBuilder)
                and isinstance(n.ty, wt.VecBuilder)
                and isinstance(n.size_hint, ir.Literal))

    sites = _sites(e, is_hinted) + _probe_out_caps(e)
    if not sites:
        return None
    s = rng.choice(sites)
    if isinstance(s, ir.NewBuilder):
        huge = int(s.size_hint.value) * 1000 + 10 ** 7
        bad: ir.Expr = replace(
            s, size_hint=ir.Literal(huge, s.size_hint.ty))
    else:
        huge = int(dict(s.params)["out_cap"]) * 1000 + 10 ** 7
        bad = _with_out_cap(s, huge)
    return Mutation("inflate_size_hint", _replace_node(e, s, bad),
                    {"WV502"}, bad)


def undersize_hint(e: ir.Expr, rng: random.Random) -> Optional[Mutation]:
    """Shrink an expansion-buffer size below the weldbound-derived lower
    bound (WV501: provable truncation).  Only left-join expansion sites
    carry a nonzero derived lower bound (every probe row emits at least
    one output row), so the sites are hinted vecbuilders initializing a
    loop whose body is guarded by KeyExists — the left m:n shape — or a
    planned left group_probe."""
    sites: List[ir.Expr] = []
    for n in ir.walk(e):
        if not (isinstance(n, ir.For) and isinstance(n.func, ir.Lambda)):
            continue
        body = n.func.body
        if not (isinstance(body, ir.If)
                and isinstance(body.cond, ir.KeyExists)):
            continue
        init = n.builder
        items = init.items if isinstance(init, ir.MakeStruct) else (init,)
        for item in items:
            if (isinstance(item, ir.NewBuilder)
                    and isinstance(item.ty, wt.VecBuilder)
                    and isinstance(item.size_hint, ir.Literal)):
                sites.append(item)
    sites += _probe_out_caps(e, left_only=True)
    if not sites:
        return None
    s = rng.choice(sites)
    if isinstance(s, ir.NewBuilder):
        bad: ir.Expr = replace(s, size_hint=ir.Literal(
            1, s.size_hint.ty))
    else:
        bad = _with_out_cap(s, 1)
    return Mutation("undersize_hint", _replace_node(e, s, bad),
                    {"WV501"}, bad)


MUTATORS: Dict[str, Callable] = {
    "drop_result": drop_result,
    "swap_merge_op": swap_merge_op,
    "shrink_capacity": shrink_capacity,
    "retype_param": retype_param,
    "getfield_oob": getfield_oob,
    "dup_builder": dup_builder,
    "inflate_size_hint": inflate_size_hint,
    "undersize_hint": undersize_hint,
}


def run_mutations(
    programs: Sequence[ir.Expr],
    seed: int = 0,
    rounds: int = 3,
    mutators: Optional[Sequence[str]] = None,
    shapes: Optional[Sequence[Optional[dict]]] = None,
) -> Score:
    """Apply each mutator ``rounds`` times per program (seeded) and
    score how many mutants the verifier catches with an expected code.

    ``shapes`` (one input-shapes dict per program, or None) lets the
    bounds lint resolve symbolic sizes — the WV501/WV502 mutators are
    only catchable when the derived bounds evaluate to numbers.
    """
    rng = random.Random(seed)
    score = Score()
    names = list(mutators if mutators is not None else MUTATORS)
    for pi, prog in enumerate(programs):
        shp = shapes[pi] if shapes is not None else None
        for mname in names:
            for _ in range(rounds):
                m = MUTATORS[mname](prog, rng)
                if m is None:
                    continue
                score.applied += 1
                diags = verify(m.mutant, shapes=shp)
                if _caught(m, diags):
                    score.caught += 1
                else:
                    score.misses.append(
                        (mname, sorted({d.code for d in diags})))
    return score


def _caught(m: Mutation, diags: List[Diagnostic]) -> bool:
    hits = [d for d in diags if d.code in m.expected]
    if not hits:
        return False
    if m.target is None:
        return True
    # the verifier must name the mutated node, a node inside it, or an
    # enclosing node (a deletion is correctly blamed on the binding that
    # now holds the broken value)
    inside = {id(n) for n in ir.walk(m.target)}
    for d in hits:
        if d.node is None:
            continue
        if id(d.node) in inside:
            return True
        if any(n is m.target for n in ir.walk(d.node)):
            return True
    return False
