"""Analysis 2: builder linearity (paper §3.2), as a dataflow pass.

Every builder-typed binding (a ``Let`` name or a ``Lambda`` parameter)
must be consumed **exactly once along every control path**:

* ``If`` branches are alternative paths — each path's total must be 1
  (e.g. ``if(p, merge(b, x), b)`` is linear);
* ``Select`` evaluates *both* sides — builder uses sum (WV206/WV202);
* a builder captured free inside a loop body is consumed once per
  iteration (WV204);
* a struct-of-builders binding is tracked per field: ``b.$k`` consumes
  field ``k``, a bare ``b`` consumes every field — so the fused
  "merge into each slot, rebuild the struct" idiom checks exactly.

The pass reuses the type map produced by ``verify_types.annotate`` so
it never re-runs inference.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .. import ir
from .. import wtypes as wt
from .diagnostics import Diagnostic

#: sentinel count for "many" (captured by a per-iteration lambda)
MANY = 1 << 20


def lint_linearity(
    e: ir.Expr,
    types: Dict[int, Optional[wt.WeldType]],
) -> List[Diagnostic]:
    diags: List[Diagnostic] = []

    def check_binding(name: str, bty: wt.BuilderType, scope: ir.Expr,
                      binding_node: ir.Expr) -> None:
        if isinstance(bty, wt.StructBuilder):
            width = len(bty.builders)
            counts = [_count(scope, name, field=k) for k in range(width)]
        else:
            width = 0
            counts = [_count(scope, name, field=None)]
        for k, (lo, hi, consumers) in enumerate(counts):
            label = f"{name}.${k}" if width else name
            if lo == 1 and hi == 1:
                continue
            if hi >= MANY or "lambda" in consumers:
                diags.append(Diagnostic(
                    "WV204",
                    f"builder {label} captured free by a loop body — "
                    f"consumed once per iteration, not once",
                    binding_node, analysis="linearity",
                    data={"name": name}))
            elif hi == 0:
                diags.append(Diagnostic(
                    "WV201",
                    f"builder {label} is never consumed",
                    binding_node, analysis="linearity",
                    data={"name": name}))
            elif lo != hi and hi <= 1:
                diags.append(Diagnostic(
                    "WV205",
                    f"builder {label} consumed on some paths only "
                    f"(min {lo}, max {hi} uses)",
                    binding_node, analysis="linearity",
                    data={"name": name, "min": lo, "max": hi}))
            elif "result" in consumers and (
                    "merge" in consumers or "select" in consumers):
                diags.append(Diagnostic(
                    "WV203",
                    f"builder {label} used after result() consumed it "
                    f"({hi} uses on a path)",
                    binding_node, analysis="linearity",
                    data={"name": name, "max": hi}))
            elif "select" in consumers:
                diags.append(Diagnostic(
                    "WV206",
                    f"builder {label} duplicated across select() arms — "
                    f"both sides evaluate ({hi} uses)",
                    binding_node, analysis="linearity",
                    data={"name": name, "max": hi}))
            else:
                diags.append(Diagnostic(
                    "WV202",
                    f"builder {label} consumed {hi} times along a path "
                    f"(must be exactly 1)",
                    binding_node, analysis="linearity",
                    data={"name": name, "min": lo, "max": hi}))

    def rec(x: ir.Expr) -> None:
        if isinstance(x, ir.Let):
            rec(x.value)
            vt = types.get(id(x.value))
            if isinstance(vt, wt.BuilderType):
                check_binding(x.name, vt, x.body, x)
            rec(x.body)
            return
        if isinstance(x, ir.Lambda):
            for p in x.params:
                if isinstance(p.ty, wt.BuilderType):
                    check_binding(p.name, p.ty, x.body, x)
            rec(x.body)
            return
        for c in x.children():
            rec(c)

    rec(e)
    return diags


def _count(
    x: ir.Expr,
    name: str,
    field: Optional[int],
    parent_kind: str = "other",
) -> Tuple[int, int, set]:
    """(min, max, consumer-kinds) of uses of ``name`` (restricted to
    struct field ``field`` when given) along control paths through
    ``x``.  ``parent_kind`` tags how a hit is being consumed."""

    def is_hit(n: ir.Expr) -> bool:
        return isinstance(n, ir.Ident) and n.name == name

    consumers: set = set()

    def go(n: ir.Expr, kind: str) -> Tuple[int, int]:
        if isinstance(n, ir.Ident):
            if n.name != name:
                return (0, 0)
            consumers.add(kind)
            return (1, 1)
        if field is not None and isinstance(n, ir.GetField) \
                and is_hit(n.expr):
            # b.$k consumes only field k of a struct-of-builders binding
            if n.index == field:
                consumers.add(kind)
                return (1, 1)
            return (0, 0)
        if isinstance(n, ir.Let):
            if n.name == name:  # shadowed in body
                return go(n.value, "alias")
            v = go(n.value, "alias" if is_hit(n.value) else "other")
            b = go(n.body, "other")
            return (v[0] + b[0], v[1] + b[1])
        if isinstance(n, ir.Lambda):
            if any(p.name == name for p in n.params):
                return (0, 0)
            lo, hi = go(n.body, "lambda")
            if hi > 0:
                # the body runs per iteration: any use is a many-use
                consumers.add("lambda")
                return (lo, MANY)
            return (0, 0)
        if isinstance(n, ir.If):
            c = go(n.cond, "other")
            t = go(n.on_true, "other")
            f = go(n.on_false, "other")
            return (c[0] + min(t[0], f[0]), c[1] + max(t[1], f[1]))
        if isinstance(n, ir.Select):
            c = go(n.cond, "other")
            t = go(n.on_true, "select")
            f = go(n.on_false, "select")
            both = t[1] + f[1]
            if both > 1:
                consumers.add("select")
            return (c[0] + t[0] + f[0], c[1] + both)
        if isinstance(n, ir.Merge):
            b = go(n.builder, "merge")
            v = go(n.value, "other")
            return (b[0] + v[0], b[1] + v[1])
        if isinstance(n, ir.Result):
            return go(n.builder, "result")
        if isinstance(n, ir.For):
            b = go(n.builder, "for")
            lo, hi = b
            for it in n.iters:
                l2, h2 = go(it, "other")
                lo, hi = lo + l2, hi + h2
            l3, h3 = go(n.func, "other")
            return (lo + l3, hi + h3)
        lo = hi = 0
        for c in n.children():
            l2, h2 = go(c, kind if kind != "other" else "other")
            lo, hi = lo + l2, hi + h2
        return (lo, hi)

    lo, hi = go(x, parent_kind)
    return (lo, hi, consumers)
