"""Analysis 3: merge-race lint for parallel loops.

Weld ``for`` loops are parallel: iterations may interleave or reorder
arbitrarily, so a loop is only sound when its merges commute and nothing
reads a builder mid-construction.  Three lints:

* **WV301** — a merger-family builder (merger / dictmerger / vecmerger)
  carries a merge op outside the commutative set.  The type constructors
  reject these, so a hit means a pass (or a mutation) corrupted the type
  in place.
* **WV302** — the loop body *reads* a value derived from the loop's own
  builder (``result``/``lookup``/``grouplookup``/``keyexists``/``len``
  of it): observing a builder still being built races with the merges.
* **WV303** — a vecmerger scatter whose index expression can alias
  across iterations (it is not the bare loop index) combined with a
  non-commutative op: reordered iterations hitting one slot disagree.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set

from .. import ir
from .. import wtypes as wt
from .diagnostics import Diagnostic

_MERGER_FAMILY = (wt.Merger, wt.DictMerger, wt.VecMerger)

#: read operations that observe a collection's contents
_READS = (ir.Result, ir.Lookup, ir.GroupLookup, ir.KeyExists, ir.Len)


def _bad_op_types(ty) -> List[wt.WeldType]:
    """Merger-family types reachable inside ``ty`` whose op is not
    commutative (recurses into struct builders)."""
    out = []
    if isinstance(ty, _MERGER_FAMILY) and ty.op not in wt.MERGE_OPS:
        out.append(ty)
    if isinstance(ty, wt.StructBuilder):
        for b in ty.builders:
            out.extend(_bad_op_types(b))
    return out


def lint_races(
    e: ir.Expr,
    types: Dict[int, Optional[wt.WeldType]],
) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    flagged: Set[int] = set()

    # -- WV301: corrupted merge ops, wherever the type is embedded -------
    for node in ir.walk(e):
        ty = None
        if isinstance(node, ir.NewBuilder):
            ty = node.ty
        elif isinstance(node, ir.Ident):
            ty = node.ty
        for bad in _bad_op_types(ty) if ty is not None else ():
            if id(node) in flagged:
                continue
            flagged.add(id(node))
            diags.append(Diagnostic(
                "WV301",
                f"non-commutative merge op {bad.op!r} on {bad} — parallel "
                f"merges reorder freely, result is nondeterministic",
                node, analysis="races", data={"op": bad.op}))

    # -- WV302/WV303: per-loop body analysis -----------------------------
    for node in ir.walk(e):
        if isinstance(node, ir.For):
            _lint_loop(node, types, diags)
    return diags


def _lint_loop(loop: ir.For, types, diags: List[Diagnostic]) -> None:
    if not loop.func.params:
        return
    bparam = loop.func.params[0]
    iparam = loop.func.params[1] if len(loop.func.params) > 1 else None
    body = loop.func.body

    # names whose value derives from the loop's builder param
    derived: Set[str] = {bparam.name}

    def mentions_derived(x: ir.Expr) -> bool:
        return any(
            isinstance(n, ir.Ident) and n.name in derived
            for n in ir.walk(x)
        )

    def rec(x: ir.Expr) -> None:
        if isinstance(x, ir.Let):
            rec(x.value)
            if mentions_derived(x.value):
                derived.add(x.name)
            rec(x.body)
            return
        if isinstance(x, _READS):
            target = x.builder if isinstance(x, ir.Result) else x.expr
            if mentions_derived(target):
                diags.append(Diagnostic(
                    "WV302",
                    f"loop body reads builder {bparam.name} while it is "
                    f"still being built ({type(x).__name__.lower()})",
                    x, analysis="races", data={"builder": bparam.name}))
        if isinstance(x, ir.Merge):
            _lint_scatter(x, iparam, types, diags)
        for c in x.children():
            rec(c)

    rec(body)


def _lint_scatter(m: ir.Merge, iparam: Optional[ir.Ident], types,
                  diags: List[Diagnostic]) -> None:
    """WV303: vecmerger {index, value} merge with an alias-capable index
    under a non-commutative combine."""
    bt = types.get(id(m.builder))
    if bt is None and isinstance(m.builder, ir.Ident):
        bt = m.builder.ty
    if not isinstance(bt, wt.VecMerger):
        return
    if bt.op in wt.MERGE_OPS:
        return  # commutative combines tolerate aliasing by construction
    idx = (m.value.items[0]
           if isinstance(m.value, ir.MakeStruct) and len(m.value.items) == 2
           else None)
    if idx is None or _index_injective(idx, iparam):
        return
    diags.append(Diagnostic(
        "WV303",
        f"vecmerger scatter index can alias across iterations and the "
        f"combine op {bt.op!r} is not commutative",
        m, analysis="races", data={"op": bt.op}))


def _index_injective(idx: ir.Expr, iparam: Optional[ir.Ident]) -> bool:
    """Conservatively true only for the bare loop index (optionally
    shifted by a constant) — anything data-dependent can alias."""
    if iparam is None:
        return False
    if isinstance(idx, ir.Ident):
        return idx.name == iparam.name
    if isinstance(idx, ir.Cast):
        return _index_injective(idx.expr, iparam)
    if isinstance(idx, ir.BinOp) and idx.op in ("+", "-"):
        l_i = isinstance(idx.left, ir.Ident) and idx.left.name == iparam.name
        r_i = (isinstance(idx.right, ir.Ident)
               and idx.right.name == iparam.name)
        l_c = isinstance(idx.left, ir.Literal)
        r_c = isinstance(idx.right, ir.Literal)
        return (l_i and r_c) or (r_i and l_c)
    return False
