"""WV5xx: bounds-analysis lints (the weldbound family).

Cross-checks every declared size (vecbuilder hint, group-probe
``out_cap``) against the interval the weldbound abstract interpreter
derives for it, and — when a ``memory_limit`` is supplied — the
whole-plan peak-memory certificate against that limit:

* **WV501** — a declared size below the derived *lower* bound: the
  buffer provably truncates (a size-analysis or planner bug);
* **WV502** — a declared size above the derived *upper* bound: the
  allocation provably wastes budget (and inflates the certificate);
* **WV503** — the certificate itself exceeds ``memory_limit``: the
  plan would be rejected at admission, so a cached executable carrying
  it is a contradiction.

Dict/group *capacities* are deliberately not compared here: a capacity
legitimately exceeds the derived key-count bound (group-by defaults a
generous table), and the runtime's regrow ladder owns undersized ones.
Both comparisons fire only when BOTH sides resolve (symbolic sizes
need ``shapes``); an unresolvable side is not a diagnostic.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from .. import ir
from .. import wtypes as wt
from ..analysis import bounds as _bounds
from ..analysis import domain as _dom
from .diagnostics import Diagnostic


def lint_bounds(
    e: ir.Expr,
    types: Dict[int, wt.WeldType],
    shapes: Optional[dict] = None,
    memory_limit: Optional[int] = None,
) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    try:
        rep = _bounds.analyze(e)
    except Exception:
        return diags  # mutants may be arbitrarily broken: never crash
    shp = {k: tuple(v) for k, v in (shapes or {}).items() if v}
    for bb in rep.builders:
        if bb.role not in ("hint", "out_cap") or bb.declared is None:
            continue
        declared = _dom.evaluate(bb.declared, shp)
        if declared is None or declared == _dom.INF:
            continue
        declared = int(declared)
        lo = bb.rows.lo_val(shp)
        hi = bb.rows.hi_val(shp)
        if declared < lo:
            diags.append(Diagnostic(
                "WV501",
                f"{bb.kind} declares {bb.role}={declared} but the derived "
                f"lower bound is {lo} rows "
                f"(interval {bb.rows.render(rep.rename)}) — the buffer "
                f"provably truncates",
                bb.node, analysis="bounds",
                data={"declared": declared, "lo": lo}))
        elif hi != _dom.INF and declared > int(hi):
            diags.append(Diagnostic(
                "WV502",
                f"{bb.kind} declares {bb.role}={declared} but the derived "
                f"upper bound is {int(hi)} rows "
                f"(interval {bb.rows.render(rep.rename)}) — the allocation "
                f"provably wastes budget",
                bb.node, analysis="bounds",
                data={"declared": declared, "hi": int(hi)}))
    if memory_limit is not None:
        peak = rep.peak(shp)
        if peak > int(memory_limit):
            diags.append(Diagnostic(
                "WV503",
                f"peak-memory certificate {rep.certificate()} = {peak} "
                f"bytes exceeds memory_limit={int(memory_limit)} — the "
                f"plan contradicts its admission limit",
                e, analysis="bounds",
                data={"peak": peak, "limit": int(memory_limit)}))
    return diags
