"""Evaluation driver: lower → optimize → compile → execute → decode.

One `Evaluate` call == one fused XLA executable (the paper's evaluation
point).  Compiled programs are cached by alpha-invariant structure +
input signature, mirroring the paper's §7.8 observation that compile cost
amortizes across repeated evaluations.

The pipeline is split into explicit AOT stages (JaCe's
``Wrapped/Lowered/Compiled`` staging is the exemplar) so a serving tier
can hold a compiled plan and re-bind same-shape inputs without paying a
recompile:

* :func:`lower` → :class:`LoweredProgram` — inputs encoded, the
  compile-cache key formed (nothing optimized yet);
* ``LoweredProgram.optimize()`` → :class:`OptimizedProgram` — optimizer
  passes, kernel planning, autotuning, weldbound admission;
* ``OptimizedProgram.compile()`` / ``LoweredProgram.compile()`` /
  :func:`compile_program` → :class:`CompiledProgram` — the reusable AOT
  handle with ``.stats`` and ``.run(arrays)``.

The compile cache is a bounded, locked, single-flight LRU
(``$WELD_COMPILE_CACHE_MAX``, default 256): one thread compiles a given
key while peers wait on the in-flight slot, eviction is
least-recently-used, and hit/miss/evict/wait counters surface in every
result's ``stats["cache.*"]``.  ``compile_and_run`` (what `Evaluate`
calls, under the recovery ladder) drives the same stages end-to-end.
"""
from __future__ import annotations

import os
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax

# The Weld IR's i64/f64 scalars require x64; the LM stack specifies its
# dtypes explicitly everywhere so this global is benign for it.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from . import check  # noqa: E402
from . import faults  # noqa: E402
from . import ir  # noqa: E402
from . import obs  # noqa: E402
from . import wtypes as wt  # noqa: E402
from .analysis import bounds as _bounds  # noqa: E402
from .backend.jaxgen import emit_program  # noqa: E402
from .backend.values import WDict, WGroup, WVec  # noqa: E402
from .errors import CapacityError, ResourceError  # noqa: E402
from .lazy import Program  # noqa: E402
from .passes import loop_count, optimize as run_passes  # noqa: E402

ENV_CACHE_MAX = "WELD_COMPILE_CACHE_MAX"
DEFAULT_CACHE_MAX = 256

#: Serializes the optimize→plan→autotune→trace compile body.  The
#: optimizer, planner, autotune cache and jax tracing all touch
#: process-global state; executions of already-compiled programs run
#: WITHOUT this lock, so concurrent serving only serializes on compiles.
_compile_lock = threading.RLock()


def cache_max() -> int:
    """Bound on cached executables (``$WELD_COMPILE_CACHE_MAX``, ≥1)."""
    try:
        return max(1, int(os.environ.get(ENV_CACHE_MAX, DEFAULT_CACHE_MAX)))
    except ValueError:
        return DEFAULT_CACHE_MAX


class _Flight:
    """In-flight compile slot: the leader resolves it, waiters block on
    the event and take the entry from the flight itself (NOT a cache
    lookup — the entry may have been filed under a refreshed-fingerprint
    key, or already evicted under pressure)."""

    __slots__ = ("event", "entry", "error")

    def __init__(self) -> None:
        self.event = threading.Event()
        self.entry: Optional[Tuple[object, dict]] = None
        self.error: Optional[BaseException] = None


class _CompileCache:
    """Bounded, locked, single-flight LRU of compiled executables."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._entries: "OrderedDict[str, Tuple[object, dict]]" = OrderedDict()
        self._flights: Dict[str, _Flight] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.waits = 0

    def lookup_or_begin(self, key: str):
        """('hit', entry) | ('wait', flight) | ('lead', flight)."""
        with self._lock:
            ent = self._entries.get(key)
            if ent is not None:
                self._entries.move_to_end(key)
                self.hits += 1
                return "hit", ent
            fl = self._flights.get(key)
            if fl is not None:
                self.waits += 1
                return "wait", fl
            fl = _Flight()
            self._flights[key] = fl
            self.misses += 1
            return "lead", fl

    def fill(self, key: str, entry: Tuple[object, dict],
             store_key: Optional[str] = None) -> None:
        """Store the compiled entry and resolve any waiters.

        The entry is stored ONLY under ``store_key`` (defaults to
        ``key``).  When first-encounter tuning refreshed the autotune
        fingerprint mid-compile, ``store_key`` is the refreshed key and
        the pre-tuning ``key`` is deliberately NOT filed: its fingerprint
        can never match a future lookup, so filing it would leak one
        forever-unreachable entry per first-encounter tuning."""
        store = store_key if store_key is not None else key
        with self._lock:
            self._entries[store] = entry
            self._entries.move_to_end(store)
            limit = cache_max()
            while len(self._entries) > limit:
                self._entries.popitem(last=False)
                self.evictions += 1
            fl = self._flights.pop(key, None)
        if fl is not None:
            fl.entry = entry
            fl.event.set()

    def abandon(self, key: str, error: BaseException) -> None:
        """Leader failed: release the flight so waiters can retry (and
        surface the same typed error if they fail the same way)."""
        with self._lock:
            fl = self._flights.pop(key, None)
        if fl is not None:
            fl.error = error
            fl.event.set()

    def clear(self) -> None:
        # in-flight compiles are left to resolve their own flights; only
        # the stored entries and the counters reset
        with self._lock:
            self._entries.clear()
            self.hits = self.misses = self.evictions = self.waits = 0

    def size(self) -> int:
        with self._lock:
            return len(self._entries)

    def counters(self) -> dict:
        with self._lock:
            return {
                "cache.hits": self.hits,
                "cache.misses": self.misses,
                "cache.evictions": self.evictions,
                "cache.waits": self.waits,
                "cache.size": len(self._entries),
                "cache.max": cache_max(),
            }


_cache = _CompileCache()


def _copy_stats(v):
    """Recursively copy the stats containers (dicts/lists) while keeping
    leaf values (numbers, strings, IR exprs) by reference.  Callers get
    an isolated tree: mutating it cannot poison the cached entry."""
    if isinstance(v, dict):
        return {k: _copy_stats(x) for k, x in v.items()}
    if isinstance(v, list):
        return [_copy_stats(x) for x in v]
    if isinstance(v, tuple):
        return tuple(_copy_stats(x) for x in v)
    return v


def clear_cache() -> None:
    _cache.clear()


def cache_size() -> int:
    return _cache.size()


def cache_stats() -> dict:
    """Global ``cache.*`` counters (also injected into every result's
    stats): hits, misses, evictions, single-flight waits, size, max."""
    return _cache.counters()


def _export_stats(stats: dict, from_cache: bool) -> dict:
    out = _copy_stats(stats)
    out.update(_cache.counters())
    out["cache.hit"] = from_cache
    return out


# ---------------------------------------------------------------------------
# staged AOT pipeline
# ---------------------------------------------------------------------------


@dataclass
class LoweredProgram:
    """Stage 1: inputs encoded, compile-cache key formed.

    ``opt``/``memory_limit``/``passes``/``mode``/``kernel_impl`` are the
    resolved compile options; ``arrays`` are the encoded (device-ready)
    inputs in ``input_names`` order — the positional binding every
    same-key execution re-binds against."""

    prog: Program
    opt: bool
    memory_limit: Optional[int]
    passes: Optional[tuple]
    mode: str
    kernel_impl: Optional[str]
    input_names: List[str] = field(default_factory=list)
    arrays: list = field(default_factory=list)
    shapes: Dict[str, tuple] = field(default_factory=dict)
    types: Dict[str, wt.WeldType] = field(default_factory=dict)
    sig: str = ""
    kreg: str = ""
    key: str = ""

    @property
    def kernelize_on(self) -> bool:
        return self.mode != "off"

    def refresh_kreg(self) -> str:
        return _kreg_fingerprint() if self.kernelize_on else ""

    def cache_key(self, kreg_now: Optional[str] = None) -> str:
        # positional input aliasing: rebuilt workflows (fresh obj ids)
        # share one compiled executable as long as structure matches.
        # Armed faults join the key too (empty when none — the common
        # path): an injected fault must never be defeated by a cached
        # executable, and a consumed fault must never serve the poisoned
        # executable it produced
        name_map = {n: f"in{i}" for i, n in enumerate(self.input_names)}
        kreg_now = self.kreg if kreg_now is None else kreg_now
        return (
            ir.canon_key(self.prog.expr, name_map)
            + f"|opt={self.opt}|mem={self.memory_limit}|passes={self.passes}"
            + f"|kz={self.mode}|kimpl={self.kernel_impl}|kreg={kreg_now}"
            + f"|flt={faults.fingerprint()}|{self.sig}"
        )

    def optimize(self) -> "OptimizedProgram":
        """Stage 2: optimizer passes + kernel planning + autotuning +
        weldbound admission.  Uncached — callers wanting the shared
        cache go through :meth:`compile` / :func:`compile_program`."""
        with _compile_lock:
            return _optimize_stage(self)

    def compile(self) -> "CompiledProgram":
        """Stages 2+3 through the shared single-flight cache."""
        jitted, stats, from_cache = _compile_handle(self)
        return CompiledProgram(self, jitted, stats, from_cache)


def _kreg_fingerprint() -> str:
    from .kernelplan import autotune, fingerprint, quarantine

    return (fingerprint() + "/" + autotune.fingerprint()
            + "/" + quarantine.fingerprint())


def lower(
    prog: Program,
    optimize: bool = True,
    memory_limit: Optional[int] = None,
    passes=None,
    kernelize=None,
    kernel_impl: Optional[str] = None,
) -> LoweredProgram:
    """Public stage-1 entry: resolve options, encode inputs, form the key."""
    from .kernelplan import normalize_kernelize

    mode = normalize_kernelize(kernelize)
    if mode != "off" and kernel_impl is None:
        # resolve the kernel library's default NOW so it lands in the
        # compile-cache key — kops promises set_default_impl() always
        # takes effect, which a cached executable would otherwise defeat
        from ..kernels import ops as _kops

        kernel_impl = _kops.DEFAULT_IMPL
    return _lower(prog, optimize, memory_limit, passes, mode, kernel_impl)


def _lower(prog, optimize, memory_limit, passes, mode,
           kernel_impl) -> LoweredProgram:
    low = LoweredProgram(prog=prog, opt=optimize, memory_limit=memory_limit,
                         passes=passes, mode=mode, kernel_impl=kernel_impl)
    low.input_names = sorted(prog.inputs)
    with obs.span("encode", inputs=len(low.input_names)):
        for name in low.input_names:
            ty, enc, data = prog.inputs[name]
            arr = jnp.asarray(enc.encode(data))
            low.arrays.append(arr)
            low.shapes[name] = tuple(arr.shape)
            low.types[name] = ty
    low.sig = ",".join(f"{a.dtype}:{a.shape}" for a in low.arrays)
    if low.kernelize_on:
        # register/unregister, new tunings AND quarantine changes must
        # invalidate the cache: a stale executable must never serve a
        # newly tuned plan or a newly quarantined kernel route
        low.kreg = _kreg_fingerprint()
    low.key = low.cache_key()
    return low


@dataclass
class OptimizedProgram:
    """Stage 2 result: the planned IR + stats, ready to jit."""

    lowered: LoweredProgram
    expr: ir.Expr
    stats: dict
    optimize_ms: float = 0.0

    def compile(self) -> "CompiledProgram":
        """Stage 3: emit + jit + AOT-compile, then file the executable in
        the shared cache (under the refreshed autotune-fingerprint key
        when first-encounter tuning bumped it — the stale pre-tuning key
        is never stored, so it cannot leak)."""
        low = self.lowered
        with _compile_lock:
            jitted = _jit_stage(low, self.expr, self.stats,
                                self.optimize_ms)
        store_key = low.key
        if low.kernelize_on:
            kreg_now = low.refresh_kreg()
            if kreg_now != low.kreg:
                store_key = low.cache_key(kreg_now)
        _cache.fill(low.key, (jitted, self.stats), store_key=store_key)
        return CompiledProgram(low, jitted, self.stats, from_cache=False)


def _optimize_stage(low: LoweredProgram) -> OptimizedProgram:
    t0 = time.perf_counter()
    expr = low.prog.expr
    stats: dict = {}
    stats["loops.before"] = loop_count(expr)
    # verify the frontend's program before any rewrite touches it: a
    # pre-existing violation must be blamed on the input, not on
    # whichever pass happens to run first
    check.checkpoint("input", expr, env=low.types, stats=stats,
                     shapes=low.shapes)
    if low.opt:
        with obs.span("optimize") as sp:
            expr = run_passes(expr, passes=low.passes, stats=stats,
                              input_shapes=low.shapes)
            sp.set("iterations", stats.get("iterations"))
    stats["loops.after"] = loop_count(expr)
    if low.kernelize_on:
        from .kernelplan import autotune, plan_kernels

        with obs.span("kernelplan", mode=low.mode) as sp:
            expr = plan_kernels(expr, input_shapes=low.shapes, stats=stats,
                                mode=low.mode, impl=low.kernel_impl)
            sp.set("matched", stats.get("kernelize.matched", 0))
        if stats.get("kernelize.matched"):
            with obs.span("autotune"):
                expr = autotune.tune_plan(expr, impl=low.kernel_impl,
                                          stats=stats)
            check.checkpoint("autotune", expr, stats=stats,
                             shapes=low.shapes)
    # the planned IR is part of the stats so explain()/the measured
    # replay can reach the program that actually ran (cache hits
    # included — the expr rides along in the cached stats entry).
    # plan.inputs pins the COMPILE-time input binding: a later hit
    # from a rebuilt workflow has fresh obj ids, but its arrays map
    # positionally onto these names (the cache key aliases inputs
    # positionally), so the replay re-binds them the same way
    stats["plan.ir"] = expr
    stats["plan.inputs"] = (list(low.input_names), dict(low.types),
                            dict(low.shapes))
    _admit(low, expr, stats)
    return OptimizedProgram(lowered=low, expr=expr, stats=stats,
                            optimize_ms=(time.perf_counter() - t0) * 1e3)


def _admit(low: LoweredProgram, expr: ir.Expr, stats: dict) -> None:
    """Weldbound admission: evaluate the plan's symbolic peak-memory
    certificate against the bound inputs and reject BEFORE tracing — a
    rejected plan costs zero kernel launches and is never cached.
    Analysis OR certificate-evaluation failures only disable admission
    (the emitter's own trace-time charging still guards execution)."""
    if not _bounds.enabled():
        return
    tb0 = time.perf_counter()
    admitted = True
    brep = None
    with obs.span("bounds") as sp:
        try:
            brep = _bounds.analyze(expr)
        except Exception:
            brep = None
        if brep is not None:
            try:
                peak = brep.peak(low.shapes)
                certificate = brep.certificate()
                builders = brep.builder_lines(low.shapes)
                out_rows = brep.result_rows(low.shapes)
            except Exception as e:
                # the certificate itself failed to evaluate at these
                # shapes — same contract as an analysis failure: degrade
                # to trace-time charging, never kill the compile
                brep = None
                stats.pop("bounds.certificate", None)
                stats["bounds.degraded"] = f"{type(e).__name__}: {e}"
                sp.set("degraded", stats["bounds.degraded"])
        if brep is not None:
            admitted = (low.memory_limit is None
                        or peak <= int(low.memory_limit))
            stats["bounds.certificate"] = certificate
            stats["bounds.peak_bytes"] = peak
            stats["bounds.builders"] = builders
            stats["bounds.out_rows"] = out_rows
            stats["bounds.admitted"] = admitted
            sp.set("peak_bytes", peak)
            sp.set("admitted", admitted)
    stats["bounds.ms"] = round((time.perf_counter() - tb0) * 1e3, 3)
    if brep is not None and not admitted:
        raise ResourceError(
            f"plan rejected at admission: peak-memory certificate "
            f"{stats['bounds.certificate']} = "
            f"{stats['bounds.peak_bytes']} bytes exceeds "
            f"memory_limit={int(low.memory_limit)} (builder size "
            f"hints + kernel scratch footprints provably do not "
            f"fit; nothing was traced or launched)")


def _jit_stage(low: LoweredProgram, expr: ir.Expr, stats: dict,
               optimize_ms: float) -> object:
    t0 = time.perf_counter()
    with obs.span("jit_compile"):
        fn = emit_program(expr, low.input_names, low.types, low.shapes,
                          low.memory_limit, kernel_impl=low.kernel_impl)
        jitted = jax.jit(fn)
        # trigger tracing+compilation now so compile_ms is honest
        _ = jitted.lower(*low.arrays).compile()
    stats["compile_ms"] = optimize_ms + (time.perf_counter() - t0) * 1e3
    return jitted


def _compile_handle(low: LoweredProgram) -> Tuple[object, dict, bool]:
    """The cached, single-flight compile driver: one thread compiles a
    key, peers wait on the flight and receive the entry from it."""
    while True:
        with obs.span("cache.lookup") as sp:
            kind, payload = _cache.lookup_or_begin(low.key)
            sp.set("hit", kind == "hit")
        if kind == "hit":
            jitted, stats = payload
            return jitted, stats, True
        if kind == "wait":
            with obs.span("cache.wait"):
                payload.event.wait()
            if payload.entry is not None:
                jitted, stats = payload.entry
                return jitted, stats, True
            # leader failed: loop — this thread may become the next
            # leader and surface the same typed error itself
            continue
        try:
            opt = low.optimize()
            handle = opt.compile()  # fills the cache + resolves the flight
        except BaseException as e:
            _cache.abandon(low.key, e)
            raise
        return handle._jitted, handle._cached_stats, False


class CompiledProgram:
    """Stage-3 AOT handle: one compiled (plan, shape-signature)
    executable plus its compile-time stats.  ``run()`` re-binds
    same-shape inputs with zero recompiles; data-dependent capacity
    poison at decode still climbs the full recovery ladder."""

    def __init__(self, lowered: LoweredProgram, jitted, stats: dict,
                 from_cache: bool) -> None:
        self._low = lowered
        self._jitted = jitted
        self._cached_stats = stats
        self.from_cache = from_cache

    @property
    def key(self) -> str:
        return self._low.key

    @property
    def out_ty(self) -> wt.WeldType:
        return self._low.prog.out_ty

    @property
    def stats(self) -> dict:
        return _export_stats(self._cached_stats, self.from_cache)

    def signature(self) -> str:
        """dtype:shape signature the executable was compiled against."""
        return self._low.sig

    def run(self, arrays=None, *, recover: bool = True):
        """Execute against ``arrays`` (encoded, positional; None = the
        inputs the handle was lowered with) and decode the result.

        Same shapes+dtypes are the caller's contract (checked against
        the compiled signature).  On capacity poison — re-bound data
        overflowing the plan's baked builder capacities — the full
        recovery ladder re-runs the program with regrown capacities."""
        low = self._low
        if arrays is None:
            arrays = low.arrays
        else:
            arrays = [jnp.asarray(a) for a in arrays]
            sig = ",".join(f"{a.dtype}:{a.shape}" for a in arrays)
            if sig != low.sig:
                raise ValueError(
                    f"CompiledProgram.run: bound inputs {sig} do not "
                    f"match the compiled signature {low.sig}; re-lower "
                    "and compile for new shapes/dtypes")
        stats = self._cached_stats
        with obs.span("weld.run", from_cache=self.from_cache):
            with obs.span("execute"):
                out = self._jitted(*arrays)
                out = jax.block_until_ready(out)
            if (obs.enabled() and stats.get("kernelize.matched")
                    and stats.get("plan.ir") is not None
                    and stats.get("plan.inputs") is not None):
                pnames, ptypes, pshapes = stats["plan.inputs"]
                _measured_replay(stats["plan.ir"], pnames, ptypes, pshapes,
                                 low.memory_limit, low.kernel_impl, arrays)
            with obs.span("decode"):
                try:
                    faults.maybe_raise("decode")
                    if faults.poisoned("decode"):
                        raise CapacityError(
                            "fault injected at decode: result poisoned")
                    return decode_value(out, low.prog.out_ty)
                except CapacityError:
                    from . import recovery

                    if not recover or not recovery.enabled():
                        raise
        # capacity poison under recovery: rebuild a Program bound to
        # THESE arrays and climb the full ladder (regrow → fallback)
        prog2 = Program(
            expr=low.prog.expr,
            inputs={name: (low.types[name], low.prog.inputs[name][1],
                           arrays[i])
                    for i, name in enumerate(low.input_names)},
            out_ty=low.prog.out_ty,
        )
        value, _, _, _ = compile_and_run(
            prog2, optimize=low.opt, memory_limit=low.memory_limit,
            passes=low.passes, kernelize=low.mode,
            kernel_impl=low.kernel_impl)
        return value


def compile_program(
    prog: Program,
    optimize: bool = True,
    memory_limit: Optional[int] = None,
    passes=None,
    kernelize=None,
    kernel_impl: Optional[str] = None,
) -> CompiledProgram:
    """AOT entry: lower → (cached, single-flight) optimize + compile.
    Nothing is executed; the returned handle's ``run()`` re-binds
    same-shape inputs against the cached executable."""
    low = lower(prog, optimize=optimize, memory_limit=memory_limit,
                passes=passes, kernelize=kernelize, kernel_impl=kernel_impl)
    with obs.span("weld.compile", kernelize=low.mode,
                  impl=low.kernel_impl) as sp:
        jitted, stats, from_cache = _compile_handle(low)
        sp.set("from_cache", from_cache)
    return CompiledProgram(low, jitted, stats, from_cache)


# ---------------------------------------------------------------------------
# end-to-end driver (Evaluate path)
# ---------------------------------------------------------------------------


def compile_and_run(
    prog: Program,
    optimize: bool = True,
    memory_limit: Optional[int] = None,
    passes=None,
    kernelize=None,
    kernel_impl: Optional[str] = None,
):
    """Returns (value, compile_ms, from_cache, stats).

    ``kernelize`` selects the kernel-planner mode — ``"auto"`` (the
    process default: roofline-cost-gated routing), ``"always"``
    (``True``: route every match), or ``"off"`` (``False``).  The
    planner runs after optimization so matched loops dispatch to the
    Pallas kernel library; the block-size autotuner then bakes tuned
    tile parameters into the plan.  ``kernel_impl`` selects
    ref / interpret / pallas for those calls (None = the kernel
    library's own default).
    """
    # kernelplan (and the Pallas kernel library behind it) is imported
    # lazily so kernelize="off" evaluations never pay its import cost
    from .kernelplan import normalize_kernelize
    from .recovery import run_with_recovery

    mode = normalize_kernelize(kernelize)
    kernelize_on = mode != "off"
    if kernelize_on and kernel_impl is None:
        from ..kernels import ops as _kops

        kernel_impl = _kops.DEFAULT_IMPL
    with obs.span("weld.evaluate", kernelize=mode, impl=kernel_impl) as root:
        # the recovery ladder owns retries: capacity poison regrows
        # builder capacities then degrades to the generic lowering;
        # kernel stage/compile failures quarantine the offender and
        # degrade immediately (see core/recovery.py)
        return run_with_recovery(
            _compile_and_run, prog, optimize=optimize,
            memory_limit=memory_limit, passes=passes, mode=mode,
            kernel_impl=kernel_impl, root=root,
        )


def _compile_and_run(prog, optimize, memory_limit, passes, mode,
                     kernelize_on, kernel_impl, root):
    del kernelize_on  # carried by mode
    low = _lower(prog, optimize, memory_limit, passes, mode, kernel_impl)
    jitted, stats, from_cache = _compile_handle(low)
    compile_ms = 0.0 if from_cache else stats.get("compile_ms", 0.0)
    root.set("from_cache", from_cache)
    with obs.span("execute"):
        out = jitted(*low.arrays)
        out = jax.block_until_ready(out)
    if (obs.enabled() and stats.get("kernelize.matched")
            and stats.get("plan.ir") is not None
            and stats.get("plan.inputs") is not None):
        pnames, ptypes, pshapes = stats["plan.inputs"]
        _measured_replay(stats["plan.ir"], pnames, ptypes, pshapes,
                         memory_limit, kernel_impl, low.arrays)
    with obs.span("decode"):
        faults.maybe_raise("decode")
        if faults.poisoned("decode"):
            raise CapacityError("fault injected at decode: result poisoned")
        value = decode_value(out, prog.out_ty)
    return value, compile_ms, from_cache, _export_stats(stats, from_cache)


def _measured_replay(expr, input_names, types, shapes, memory_limit,
                     kernel_impl, arrays) -> None:
    """Re-run the planned program eagerly (unjitted) with per-kernel
    timing enabled, so each ``KernelCall`` gets its own measured span and
    a cost-ledger record.  The fused jitted executable gives no per-call
    boundaries, so when tracing is on we pay one extra eager pass to get
    honest per-kernel wall times (adapter overhead included — the same
    thing the roofline model prices).  Best-effort: a replay failure is
    recorded on the span, never raised.  Serialized under the compile
    lock: the eager pass runs through the same global emitter state a
    concurrent compile would be mutating."""
    with obs.span("measure.replay") as sp:
        try:
            faults.maybe_raise("measure.replay")
            with _compile_lock:
                fn = emit_program(expr, input_names, types, shapes,
                                  memory_limit, kernel_impl=kernel_impl,
                                  measure=True)
                out = fn(*arrays)
            jax.block_until_ready(out)
        except Exception as e:  # pragma: no cover - defensive
            sp.set("error", f"{type(e).__name__}: {e}")


def decode_value(v, ty: wt.WeldType):
    """Backend value -> natural host value (numpy arrays / dicts / tuples)."""
    if isinstance(v, WVec):
        data = v.to_numpy()
        return data
    if isinstance(v, WDict):
        return v.to_numpy()
    if isinstance(v, WGroup):
        return v.to_numpy()
    if isinstance(v, tuple):
        if isinstance(ty, wt.Struct):
            return tuple(
                decode_value(x, f) for x, f in zip(v, ty.fields)
            )
        return tuple(decode_value(x, None) for x in v)
    if hasattr(v, "shape") and getattr(v, "shape", None) == ():
        return np.asarray(v).item()
    return np.asarray(v)
