"""Evaluation driver: optimize → lower → jit → execute → decode.

One `Evaluate` call == one fused XLA executable (the paper's evaluation
point).  Compiled programs are cached by alpha-invariant structure +
input signature, mirroring the paper's §7.8 observation that compile cost
amortizes across repeated evaluations.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax

# The Weld IR's i64/f64 scalars require x64; the LM stack specifies its
# dtypes explicitly everywhere so this global is benign for it.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from . import ir  # noqa: E402
from . import wtypes as wt  # noqa: E402
from .backend.jaxgen import emit_program  # noqa: E402
from .backend.values import WDict, WGroup, WVec  # noqa: E402
from .lazy import Program  # noqa: E402
from .passes import loop_count, optimize as run_passes  # noqa: E402

_compile_cache: Dict[str, Tuple[object, dict]] = {}


def clear_cache() -> None:
    _compile_cache.clear()


def cache_size() -> int:
    return len(_compile_cache)


def compile_and_run(
    prog: Program,
    optimize: bool = True,
    memory_limit: Optional[int] = None,
    passes=None,
    kernelize: Optional[bool] = None,
    kernel_impl: Optional[str] = None,
):
    """Returns (value, compile_ms, from_cache, stats).

    ``kernelize`` (None = the kernelplan process default, False until
    parity is proven) runs the kernel planner after optimization so
    matched loops dispatch to the Pallas kernel library; ``kernel_impl``
    selects ref / interpret / pallas for those calls (None = the kernel
    library's own default).
    """
    # kernelplan (and the Pallas kernel library behind it) is imported
    # lazily so the default jnp-only path doesn't pay its import cost
    if kernelize is None:
        from .kernelplan import DEFAULT_KERNELIZE

        kernelize = DEFAULT_KERNELIZE
    kernelize = bool(kernelize)
    if kernelize and kernel_impl is None:
        # resolve the kernel library's default NOW so it lands in the
        # compile-cache key — kops promises set_default_impl() always
        # takes effect, which a cached executable would otherwise defeat
        from ..kernels import ops as _kops

        kernel_impl = _kops.DEFAULT_IMPL
    input_names = sorted(prog.inputs)
    arrays = []
    shapes: Dict[str, tuple] = {}
    types: Dict[str, wt.WeldType] = {}
    for name in input_names:
        ty, enc, data = prog.inputs[name]
        arr = enc.encode(data)
        arr = jnp.asarray(arr)
        arrays.append(arr)
        shapes[name] = tuple(arr.shape)
        types[name] = ty

    # positional input aliasing: rebuilt workflows (fresh obj ids) share
    # one compiled executable as long as their structure matches
    name_map = {n: f"in{i}" for i, n in enumerate(input_names)}
    sig = ",".join(f"{a.dtype}:{a.shape}" for a in arrays)
    kreg = ""
    if kernelize:
        from .kernelplan import fingerprint

        kreg = fingerprint()  # register/unregister must invalidate the cache
    key = (
        ir.canon_key(prog.expr, name_map)
        + f"|opt={optimize}|mem={memory_limit}|passes={passes}"
        + f"|kz={kernelize}|kimpl={kernel_impl}|kreg={kreg}|{sig}"
    )

    stats: dict = {}
    if key in _compile_cache:
        jitted, stats = _compile_cache[key]
        from_cache = True
        compile_ms = 0.0
    else:
        from_cache = False
        t0 = time.perf_counter()
        expr = prog.expr
        stats["loops.before"] = loop_count(expr)
        if optimize:
            expr = run_passes(expr, passes=passes, stats=stats,
                              input_shapes=shapes)
        stats["loops.after"] = loop_count(expr)
        if kernelize:
            from .kernelplan import plan_kernels

            expr = plan_kernels(expr, input_shapes=shapes, stats=stats)
        fn = emit_program(expr, input_names, types, shapes, memory_limit,
                          kernel_impl=kernel_impl)
        jitted = jax.jit(fn)
        # trigger tracing+compilation now so compile_ms is honest
        _ = jitted.lower(*arrays).compile()
        compile_ms = (time.perf_counter() - t0) * 1e3
        stats["compile_ms"] = compile_ms
        _compile_cache[key] = (jitted, stats)

    out = jitted(*arrays)
    out = jax.block_until_ready(out)
    value = decode_value(out, prog.out_ty)
    return value, compile_ms, from_cache, dict(stats)


def decode_value(v, ty: wt.WeldType):
    """Backend value -> natural host value (numpy arrays / dicts / tuples)."""
    if isinstance(v, WVec):
        data = v.to_numpy()
        return data
    if isinstance(v, WDict):
        return v.to_numpy()
    if isinstance(v, WGroup):
        return v.to_numpy()
    if isinstance(v, tuple):
        if isinstance(ty, wt.Struct):
            return tuple(
                decode_value(x, f) for x, f in zip(v, ty.fields)
            )
        return tuple(decode_value(x, None) for x in v)
    if hasattr(v, "shape") and getattr(v, "shape", None) == ():
        return np.asarray(v).item()
    return np.asarray(v)
