"""Evaluation driver: optimize → lower → jit → execute → decode.

One `Evaluate` call == one fused XLA executable (the paper's evaluation
point).  Compiled programs are cached by alpha-invariant structure +
input signature, mirroring the paper's §7.8 observation that compile cost
amortizes across repeated evaluations.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax

# The Weld IR's i64/f64 scalars require x64; the LM stack specifies its
# dtypes explicitly everywhere so this global is benign for it.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from . import check  # noqa: E402
from . import faults  # noqa: E402
from . import ir  # noqa: E402
from . import obs  # noqa: E402
from . import wtypes as wt  # noqa: E402
from .analysis import bounds as _bounds  # noqa: E402
from .backend.jaxgen import emit_program  # noqa: E402
from .backend.values import WDict, WGroup, WVec  # noqa: E402
from .errors import CapacityError, ResourceError  # noqa: E402
from .lazy import Program  # noqa: E402
from .passes import loop_count, optimize as run_passes  # noqa: E402

_compile_cache: Dict[str, Tuple[object, dict]] = {}


def _copy_stats(v):
    """Recursively copy the stats containers (dicts/lists) while keeping
    leaf values (numbers, strings, IR exprs) by reference.  Callers get
    an isolated tree: mutating it cannot poison the cached entry."""
    if isinstance(v, dict):
        return {k: _copy_stats(x) for k, x in v.items()}
    if isinstance(v, list):
        return [_copy_stats(x) for x in v]
    if isinstance(v, tuple):
        return tuple(_copy_stats(x) for x in v)
    return v


def clear_cache() -> None:
    _compile_cache.clear()


def cache_size() -> int:
    return len(_compile_cache)


def compile_and_run(
    prog: Program,
    optimize: bool = True,
    memory_limit: Optional[int] = None,
    passes=None,
    kernelize=None,
    kernel_impl: Optional[str] = None,
):
    """Returns (value, compile_ms, from_cache, stats).

    ``kernelize`` selects the kernel-planner mode — ``"auto"`` (the
    process default: roofline-cost-gated routing), ``"always"``
    (``True``: route every match), or ``"off"`` (``False``).  The
    planner runs after optimization so matched loops dispatch to the
    Pallas kernel library; the block-size autotuner then bakes tuned
    tile parameters into the plan.  ``kernel_impl`` selects
    ref / interpret / pallas for those calls (None = the kernel
    library's own default).
    """
    # kernelplan (and the Pallas kernel library behind it) is imported
    # lazily so kernelize="off" evaluations never pay its import cost
    from .kernelplan import normalize_kernelize
    from .recovery import run_with_recovery

    mode = normalize_kernelize(kernelize)
    kernelize_on = mode != "off"
    if kernelize_on and kernel_impl is None:
        # resolve the kernel library's default NOW so it lands in the
        # compile-cache key — kops promises set_default_impl() always
        # takes effect, which a cached executable would otherwise defeat
        from ..kernels import ops as _kops

        kernel_impl = _kops.DEFAULT_IMPL
    with obs.span("weld.evaluate", kernelize=mode, impl=kernel_impl) as root:
        # the recovery ladder owns retries: capacity poison regrows
        # builder capacities then degrades to the generic lowering;
        # kernel stage/compile failures quarantine the offender and
        # degrade immediately (see core/recovery.py)
        return run_with_recovery(
            _compile_and_run, prog, optimize=optimize,
            memory_limit=memory_limit, passes=passes, mode=mode,
            kernel_impl=kernel_impl, root=root,
        )


def _compile_and_run(prog, optimize, memory_limit, passes, mode,
                     kernelize_on, kernel_impl, root):
    input_names = sorted(prog.inputs)
    arrays = []
    shapes: Dict[str, tuple] = {}
    types: Dict[str, wt.WeldType] = {}
    with obs.span("encode", inputs=len(input_names)):
        for name in input_names:
            ty, enc, data = prog.inputs[name]
            arr = enc.encode(data)
            arr = jnp.asarray(arr)
            arrays.append(arr)
            shapes[name] = tuple(arr.shape)
            types[name] = ty

    # positional input aliasing: rebuilt workflows (fresh obj ids) share
    # one compiled executable as long as their structure matches
    name_map = {n: f"in{i}" for i, n in enumerate(input_names)}
    sig = ",".join(f"{a.dtype}:{a.shape}" for a in arrays)
    kreg = ""

    def _kreg() -> str:
        from .kernelplan import autotune, fingerprint, quarantine

        return (fingerprint() + "/" + autotune.fingerprint()
                + "/" + quarantine.fingerprint())

    if kernelize_on:
        # register/unregister, new tunings AND quarantine changes must
        # invalidate the cache: a stale executable must never serve a
        # newly tuned plan or a newly quarantined kernel route
        kreg = _kreg()

    def _mk_key(kreg_now: str) -> str:
        # armed faults join the key too (empty when none — the common
        # path): an injected fault must never be defeated by a cached
        # executable, and a consumed fault must never serve the
        # poisoned executable it produced
        return (
            ir.canon_key(prog.expr, name_map)
            + f"|opt={optimize}|mem={memory_limit}|passes={passes}"
            + f"|kz={mode}|kimpl={kernel_impl}|kreg={kreg_now}"
            + f"|flt={faults.fingerprint()}|{sig}"
        )

    key = _mk_key(kreg)

    stats: dict = {}
    with obs.span("cache.lookup") as sp:
        hit = key in _compile_cache
        sp.set("hit", hit)
    if hit:
        jitted, stats = _compile_cache[key]
        from_cache = True
        compile_ms = 0.0
    else:
        from_cache = False
        t0 = time.perf_counter()
        expr = prog.expr
        stats["loops.before"] = loop_count(expr)
        # verify the frontend's program before any rewrite touches it:
        # a pre-existing violation must be blamed on the input, not on
        # whichever pass happens to run first
        check.checkpoint("input", expr, env=types, stats=stats,
                         shapes=shapes)
        if optimize:
            with obs.span("optimize") as sp:
                expr = run_passes(expr, passes=passes, stats=stats,
                                  input_shapes=shapes)
                sp.set("iterations", stats.get("iterations"))
        stats["loops.after"] = loop_count(expr)
        if kernelize_on:
            from .kernelplan import autotune, plan_kernels

            with obs.span("kernelplan", mode=mode) as sp:
                expr = plan_kernels(expr, input_shapes=shapes, stats=stats,
                                    mode=mode, impl=kernel_impl)
                sp.set("matched", stats.get("kernelize.matched", 0))
            if stats.get("kernelize.matched"):
                with obs.span("autotune"):
                    expr = autotune.tune_plan(expr, impl=kernel_impl,
                                              stats=stats)
                check.checkpoint("autotune", expr, stats=stats,
                                 shapes=shapes)
        # the planned IR is part of the stats so explain()/the measured
        # replay can reach the program that actually ran (cache hits
        # included — the expr rides along in the cached stats entry).
        # plan.inputs pins the COMPILE-time input binding: a later hit
        # from a rebuilt workflow has fresh obj ids, but its arrays map
        # positionally onto these names (the cache key aliases inputs
        # positionally), so the replay re-binds them the same way
        stats["plan.ir"] = expr
        stats["plan.inputs"] = (list(input_names), dict(types),
                                dict(shapes))
        # weldbound admission: evaluate the plan's symbolic peak-memory
        # certificate against the bound inputs and reject BEFORE tracing
        # — a rejected plan costs zero kernel launches and is never
        # cached.  Analysis failures only disable admission (the
        # emitter's own trace-time charging still guards execution).
        if _bounds.enabled():
            tb0 = time.perf_counter()
            with obs.span("bounds") as sp:
                try:
                    brep = _bounds.analyze(expr)
                except Exception:
                    brep = None
                if brep is not None:
                    peak = brep.peak(shapes)
                    admitted = (memory_limit is None
                                or peak <= int(memory_limit))
                    stats["bounds.certificate"] = brep.certificate()
                    stats["bounds.peak_bytes"] = peak
                    stats["bounds.builders"] = brep.builder_lines(shapes)
                    stats["bounds.out_rows"] = brep.result_rows(shapes)
                    stats["bounds.admitted"] = admitted
                    sp.set("peak_bytes", peak)
                    sp.set("admitted", admitted)
            stats["bounds.ms"] = round(
                (time.perf_counter() - tb0) * 1e3, 3)
            if brep is not None and not stats["bounds.admitted"]:
                raise ResourceError(
                    f"plan rejected at admission: peak-memory certificate "
                    f"{stats['bounds.certificate']} = "
                    f"{stats['bounds.peak_bytes']} bytes exceeds "
                    f"memory_limit={int(memory_limit)} (builder size "
                    f"hints + kernel scratch footprints provably do not "
                    f"fit; nothing was traced or launched)")
        with obs.span("jit_compile"):
            fn = emit_program(expr, input_names, types, shapes, memory_limit,
                              kernel_impl=kernel_impl)
            jitted = jax.jit(fn)
            # trigger tracing+compilation now so compile_ms is honest
            _ = jitted.lower(*arrays).compile()
        compile_ms = (time.perf_counter() - t0) * 1e3
        stats["compile_ms"] = compile_ms
        _compile_cache[key] = (jitted, stats)
        if kernelize_on:
            # first-encounter tuning bumps the autotune fingerprint AFTER
            # the key was formed; the executable was built WITH those
            # tunings, so file it under the refreshed key too — the next
            # identical call hits instead of recompiling the same plan
            kreg_now = _kreg()
            if kreg_now != kreg:
                _compile_cache[_mk_key(kreg_now)] = (jitted, stats)

    root.set("from_cache", from_cache)
    with obs.span("execute"):
        out = jitted(*arrays)
        out = jax.block_until_ready(out)
    if (obs.enabled() and stats.get("kernelize.matched")
            and stats.get("plan.ir") is not None
            and stats.get("plan.inputs") is not None):
        pnames, ptypes, pshapes = stats["plan.inputs"]
        _measured_replay(stats["plan.ir"], pnames, ptypes, pshapes,
                         memory_limit, kernel_impl, arrays)
    with obs.span("decode"):
        faults.maybe_raise("decode")
        if faults.poisoned("decode"):
            raise CapacityError("fault injected at decode: result poisoned")
        value = decode_value(out, prog.out_ty)
    return value, compile_ms, from_cache, _copy_stats(stats)


def _measured_replay(expr, input_names, types, shapes, memory_limit,
                     kernel_impl, arrays) -> None:
    """Re-run the planned program eagerly (unjitted) with per-kernel
    timing enabled, so each ``KernelCall`` gets its own measured span and
    a cost-ledger record.  The fused jitted executable gives no per-call
    boundaries, so when tracing is on we pay one extra eager pass to get
    honest per-kernel wall times (adapter overhead included — the same
    thing the roofline model prices).  Best-effort: a replay failure is
    recorded on the span, never raised."""
    with obs.span("measure.replay") as sp:
        try:
            faults.maybe_raise("measure.replay")
            fn = emit_program(expr, input_names, types, shapes,
                              memory_limit, kernel_impl=kernel_impl,
                              measure=True)
            out = fn(*arrays)
            jax.block_until_ready(out)
        except Exception as e:  # pragma: no cover - defensive
            sp.set("error", f"{type(e).__name__}: {e}")


def decode_value(v, ty: wt.WeldType):
    """Backend value -> natural host value (numpy arrays / dicts / tuples)."""
    if isinstance(v, WVec):
        data = v.to_numpy()
        return data
    if isinstance(v, WDict):
        return v.to_numpy()
    if isinstance(v, WGroup):
        return v.to_numpy()
    if isinstance(v, tuple):
        if isinstance(ty, wt.Struct):
            return tuple(
                decode_value(x, f) for x, f in zip(v, ty.fields)
            )
        return tuple(decode_value(x, None) for x in v)
    if hasattr(v, "shape") and getattr(v, "shape", None) == ():
        return np.asarray(v).item()
    return np.asarray(v)
