"""Deterministic fault injection for the Weld runtime.

Every degradation path in the recovery runtime (poison-triggered retry,
kernel quarantine, best-effort IO) is unreachable on healthy inputs —
this module makes them reachable on demand so tests and CI can prove
them.  A *failpoint* is a named site in the runtime; arming one makes
the next N evaluations of that site fire an action:

* ``raise`` — raise :class:`~repro.core.errors.InjectedFault` (or a
  caller-chosen exception class at IO sites).
* ``poison`` — flip the site's overflow/poison flag (builder finalizes,
  kernel adapters) so the negative-count convention propagates exactly
  as a real capacity overflow would.
* ``cap=<int>`` — override a capacity the site is about to use
  (e.g. ``join.capacity``), simulating a mis-estimated build size.

Arming is either programmatic::

    from repro import faults
    faults.inject("kernel.hash_probe", "raise", times=1)

or via the environment, parsed once at first use::

    WELD_FAULTS="kernel.hash_probe:raise@1,dict.build:poison@2"

``site:action@N`` fires the action for the next N evaluations of the
site (``@N`` optional, default 1), then disarms.  Known sites include
``kernel.<name>`` (every planned kernel launch, via
``kernelplan.registry.execute_spec``), ``dict.build`` / ``group.build``
(the generic keyed finalize), ``join.capacity`` (weldrel's host-side
capacity choice), ``decode`` (poison/raise at result decode),
``measure.replay`` (the traced eager replay), ``autotune.time`` (the
tuner's candidate timer), and ``io.autotune_cache`` / ``io.ledger``
(best-effort cache/ledger writes).

Fired failpoints emit ``fault.fired`` obs events; :func:`fingerprint`
participates in the runtime's compile-cache key whenever anything is
armed, so an armed fault can never be defeated by a cached executable.
Everything here is deterministic — no randomness, no timing.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, List, Optional

from .errors import InjectedFault

__all__ = [
    "inject", "clear", "armed", "fired", "fingerprint",
    "maybe_raise", "poisoned", "capacity_override",
]

ENV_FAULTS = "WELD_FAULTS"

_ACTIONS = ("raise", "poison", "cap")

_lock = threading.RLock()
_armed: Optional[Dict[str, List[dict]]] = None  # site -> [entry, ...]
_fired: List[dict] = []
_generation = 0


def _parse_spec(spec: str) -> dict:
    """``raise`` | ``poison`` | ``cap=<int>``, with optional ``@N``."""
    times = 1
    if "@" in spec:
        spec, _, t = spec.rpartition("@")
        times = int(t)
    value = None
    if "=" in spec:
        spec, _, v = spec.partition("=")
        value = int(v)
    if spec not in _ACTIONS:
        raise ValueError(
            f"unknown fault action {spec!r} (expected one of {_ACTIONS})"
        )
    if spec == "cap" and value is None:
        raise ValueError("fault action 'cap' needs a value: cap=<int>")
    return {"action": spec, "value": value, "remaining": max(int(times), 0)}


def _load() -> Dict[str, List[dict]]:
    """Armed table, seeding from $WELD_FAULTS on first use."""
    global _armed, _generation
    with _lock:
        if _armed is None:
            _armed = {}
            env = os.environ.get(ENV_FAULTS, "").strip()
            for part in filter(None, (p.strip() for p in env.split(","))):
                site, sep, spec = part.partition(":")
                if not sep:
                    raise ValueError(
                        f"bad {ENV_FAULTS} entry {part!r} "
                        "(expected site:action[@N])"
                    )
                _armed.setdefault(site, []).append(_parse_spec(spec))
            if _armed:
                _generation += 1
        return _armed


def inject(site: str, action: str = "raise", times: int = 1,
           value: Optional[int] = None) -> None:
    """Arm ``site`` to fire ``action`` for the next ``times`` hits."""
    global _generation
    spec = action if value is None else f"{action}={value}"
    with _lock:
        _load().setdefault(site, []).append(
            dict(_parse_spec(spec), remaining=max(int(times), 0))
        )
        _generation += 1


def clear() -> None:
    """Disarm every failpoint and forget the fired log ($WELD_FAULTS is
    NOT re-read; use it for one-shot process-level arming)."""
    global _armed, _generation
    with _lock:
        _armed = {}
        _fired.clear()
        _generation += 1


def armed() -> Dict[str, List[dict]]:
    """Copy of the currently armed table (introspection/tests)."""
    with _lock:
        return {s: [dict(e) for e in v] for s, v in _load().items() if v}


def fired() -> List[dict]:
    """Log of every failpoint that fired since the last :func:`clear`."""
    with _lock:
        return [dict(e) for e in _fired]


def fingerprint() -> str:
    """Cache-key token: empty when nothing is armed (the common path),
    else a digest of the armed table INCLUDING remaining counts — a
    consumed fault changes the key, so a poisoned executable compiled
    under an armed fault is never served once the fault is spent."""
    with _lock:
        t = _load()
        live = sorted(
            f"{s}:{e['action']}@{e['remaining']}"
            for s, v in t.items() for e in v if e["remaining"] > 0
        )
        return ",".join(live)


def _fire(site: str, action: str) -> Optional[dict]:
    """Consume one armed hit of ``action`` at ``site``; None if unarmed."""
    with _lock:
        for entry in _load().get(site, ()):
            if entry["action"] == action and entry["remaining"] > 0:
                entry["remaining"] -= 1
                rec = {"site": site, "action": action,
                       "value": entry["value"]}
                _fired.append(rec)
                break
        else:
            return None
    from . import obs  # deferred: obs.ledger imports this module

    obs.event("fault.fired", site=site, action=action)
    return rec


def maybe_raise(site: str, exc: Optional[type] = None) -> None:
    """Raise if ``site`` is armed with a ``raise`` action.  ``exc``
    substitutes the exception class at sites whose callers only swallow
    specific types (e.g. ``OSError`` for best-effort IO)."""
    if _fire(site, "raise") is not None:
        cls = exc or InjectedFault
        raise cls(f"fault injected at {site}")


def poisoned(site: str) -> bool:
    """True (consuming one hit) if ``site`` is armed with ``poison``."""
    return _fire(site, "poison") is not None


def capacity_override(site: str) -> Optional[int]:
    """The injected capacity for ``site`` (consuming one hit), or None."""
    rec = _fire(site, "cap")
    return None if rec is None else int(rec["value"])
