"""Adaptive recovery around ``compile_and_run``: retry, regrow, degrade.

Weld's premise is one runtime safely owning execution for many
libraries — so the runtime must not delegate failure back to the user.
Two failure families are retryable, and this module owns the ladder:

* **Capacity poison** (:class:`~repro.core.errors.CapacityError`): a
  dictmerger/groupbuilder overflowed its static capacity and flagged the
  result with the negative-count convention, detected at decode.  The
  ladder re-stamps every dict/group capacity literal in the program with
  geometric growth (×2, up to :data:`MAX_REGROW` attempts) and re-runs;
  if growth alone cannot fix it (e.g. a kernel route that cannot
  represent the keys), the last rung degrades to the generic
  ``kernelize="off"`` lowering — the unmodified-library safety net Split
  Annotations keeps around, which our jnp lowering exactly is.
* **Kernel failure** (:class:`~repro.core.errors.KernelCompileError`): a
  planned Pallas kernel failed to stage/compile/launch.  The offender is
  recorded in the quarantine health file (``kernelplan.quarantine`` —
  the cost gate rejects it up front next time) and the same program
  re-runs on the generic lowering.

Every step emits a ``RuntimeWarning``, an obs event + ``recovery.retry``
span (visible in ``Query.explain(analyze=True)``), and lands in the
``recovery.*`` stats namespace of the attempt that finally succeeded.

Disable with ``WELD_RECOVERY=0`` (or :func:`set_enabled` /
:func:`disabled`): failures then surface as their typed exceptions.
"""
from __future__ import annotations

import contextlib
import os
import warnings
from typing import Optional

from . import ir
from . import obs
from . import wtypes as wt
from .errors import CapacityError, KernelCompileError

ENV_RECOVERY = "WELD_RECOVERY"

#: capacity-regrow rungs before degrading to the generic lowering:
#: factors ×2, ×4, ×8 over the originally planned capacities.
MAX_REGROW = 3
GROWTH = 2

_enabled_override: Optional[bool] = None


def enabled() -> bool:
    if _enabled_override is not None:
        return _enabled_override
    return os.environ.get(ENV_RECOVERY, "1").lower() not in (
        "0", "off", "false", "no"
    )


def set_enabled(on: Optional[bool]) -> None:
    """Override the env knob in-process (None restores it)."""
    global _enabled_override
    _enabled_override = on


@contextlib.contextmanager
def disabled():
    """``with recovery.disabled(): ...`` — typed errors instead of retries."""
    prev = _enabled_override
    set_enabled(False)
    try:
        yield
    finally:
        set_enabled(prev)


def regrow_capacities(e: ir.Expr, factor: int, bounds=None):
    """Re-stamp every dict/group builder capacity literal with
    ``capacity * factor``; returns ``(expr, n_stamped)``.

    ``bounds`` (``id(NewBuilder) -> (lb, ub)``, from
    ``analysis.bounds.BoundsReport.capacity_bounds``) clamps the ladder
    at what the interval analysis proved: a rung below the proven lower
    bound jumps straight to it (growing there would provably still
    poison), and no rung grows past the proven upper bound — a capacity
    already at/above it provably cannot be exceeded, so it is left
    unstamped (and an all-clamped program falls through to the generic
    lowering instead of burning rungs)."""
    n = 0
    bounds = bounds or {}

    def rec(x: ir.Expr) -> ir.Expr:
        nonlocal n
        orig = x
        x = x.map_children(rec)
        if (isinstance(x, ir.NewBuilder)
                and isinstance(x.ty, (wt.DictMerger, wt.GroupBuilder))
                and isinstance(x.arg, ir.Literal)):
            old = int(x.arg.value)
            new = old * factor
            lb, ub = bounds.get(id(orig), (0, None))
            if lb and new < lb:
                new = int(lb)
            if ub is not None and int(ub) > 0:
                # never shrink below the current rung's own value: the
                # differential WV404 check (and cache keys) rely on
                # regrow being monotone
                new = min(new, max(int(ub), old))
            if new <= old:
                return x  # provably can't overflow: nothing to regrow
            n += 1
            return ir.NewBuilder(
                x.ty,
                arg=ir.Literal(new, x.arg.ty),
                size_hint=x.size_hint,
            )
        return x

    return rec(e), n


def _capacity_bounds(prog):
    """Proven ``id(NewBuilder) -> (lb, ub)`` capacity bounds for the
    program's dict/group builders, from the weldbound interval analysis
    evaluated at the bound input shapes.  Best-effort: any failure (or
    the analysis being disabled) just leaves the ladder unclamped."""
    try:
        import numpy as np

        from .analysis import bounds as _bounds

        if not _bounds.enabled():
            return {}
        shapes = {}
        for name, bound in getattr(prog, "inputs", {}).items():
            try:
                shapes[name] = tuple(np.asarray(bound[-1]).shape)
            except Exception:
                continue
        return _bounds.analyze(prog.expr).capacity_bounds(shapes)
    except Exception:
        return {}


def _warn(msg: str) -> None:
    warnings.warn(msg, RuntimeWarning, stacklevel=4)


def run_with_recovery(runner, prog, *, optimize, memory_limit, passes,
                      mode, kernel_impl, root):
    """Drive ``runner`` (``runtime._compile_and_run``) up the ladder.

    Returns the runner's ``(value, compile_ms, from_cache, stats)``; on
    a recovered run the stats gain the ``recovery.*`` namespace.
    """
    events = []
    quarantined = []
    cur_prog = prog
    cur_mode = mode
    factor = 1
    regrows = 0
    attempt = 0
    while True:
        attempt += 1
        try:
            if attempt == 1:
                out = runner(cur_prog, optimize, memory_limit, passes,
                             cur_mode, cur_mode != "off", kernel_impl, root)
            else:
                with obs.span("recovery.retry", attempt=attempt,
                              mode=cur_mode, factor=factor):
                    out = runner(cur_prog, optimize, memory_limit, passes,
                                 cur_mode, cur_mode != "off", kernel_impl,
                                 root)
            value, compile_ms, from_cache, stats = out
            if events:
                stats["recovery.attempts"] = attempt
                stats["recovery.events"] = events
                stats["recovery.regrow_factor"] = factor
                stats["recovery.fallback"] = cur_mode != mode
                if quarantined:
                    stats["recovery.quarantined"] = quarantined
                root.set("recovery.attempts", attempt)
            return value, compile_ms, from_cache, stats
        except CapacityError as e:
            if not enabled():
                raise
            grown = None
            if regrows < MAX_REGROW:
                grown, n_stamped = regrow_capacities(
                    prog.expr, factor * GROWTH,
                    bounds=_capacity_bounds(prog))
                if n_stamped == 0:
                    # every capacity already sits at its proven upper bound,
                    # yet the runtime still observed a poison — the bound is
                    # contradicted (transient fault or unsound proof), so
                    # distrust the clamp and double unconditionally
                    grown, n_stamped = regrow_capacities(
                        prog.expr, factor * GROWTH)
                if n_stamped == 0:
                    grown = None  # nothing to regrow: skip to fallback
            if grown is not None:
                regrows += 1
                factor *= GROWTH
                # differential check: the regrown program must re-verify
                # clean AND every capacity must dominate its predecessor
                # (WV404) — a buggy rewrite here would loop the ladder
                from . import check

                check.verify_rewrite("recovery.regrow", prog.expr, grown)
                cur_prog = type(prog)(expr=grown, inputs=prog.inputs,
                                      out_ty=prog.out_ty)
                detail = (f"capacity poison; regrowing {n_stamped} "
                          f"builder capacit{'y' if n_stamped == 1 else 'ies'}"
                          f" x{factor}")
            elif cur_mode != "off":
                cur_mode = "off"
                detail = ("capacity poison persists; degrading to the "
                          "generic kernelize='off' lowering")
            else:
                raise CapacityError(
                    f"{e} [recovery exhausted after {attempt} attempts: "
                    f"capacity regrow x{factor}, generic fallback"
                ) from e
            events.append({"attempt": attempt, "action": "regrow"
                           if grown is not None else "fallback",
                           "detail": detail})
            _warn(f"weld recovery (attempt {attempt}): {detail}")
            obs.event("recovery.step", attempt=attempt, detail=detail)
        except KernelCompileError as e:
            if not enabled() or cur_mode == "off":
                raise
            from .kernelplan import quarantine

            qkey = quarantine.record(e.kernel or "?", impl=e.impl,
                                     dtype=e.dtype, n=e.n, error=str(e))
            quarantined.append(qkey)
            detail = (f"kernel {e.kernel!r} failed ({e}); quarantined "
                      f"[{qkey}] and degrading to the generic lowering")
            events.append({"attempt": attempt, "action": "quarantine",
                           "detail": detail})
            _warn(f"weld recovery (attempt {attempt}): {detail}")
            obs.event("recovery.step", attempt=attempt, kernel=e.kernel,
                      detail=detail)
            cur_mode = "off"
