"""Weld runtime API (paper §4, Table 2).

`WeldObject` represents either external in-memory data or a lazily
evaluated sub-computation; objects form a DAG across library boundaries.
`Evaluate` walks the DAG, stitches the IR fragments into a single program,
optimizes it, compiles it through the JAX backend and runs it on the
application's in-memory data (zero-copy for numpy/jax arrays).
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from . import ir
from . import wtypes as wt

_obj_ids = itertools.count()


# ---------------------------------------------------------------------------
# Encoders (paper §4.2): marshal native objects <-> Weld values.
# ---------------------------------------------------------------------------


class Encoder:
    """Bidirectional marshaller between a library's native format and Weld."""

    def encode(self, obj):  # native -> weld-usable (jax/numpy array)
        return obj

    def decode(self, value, ty: wt.WeldType):  # weld result -> native
        return value

    def weld_type(self, obj) -> wt.WeldType:
        raise NotImplementedError


class ArrayEncoder(Encoder):
    """Zero-copy encoder for numpy / jax arrays (the NumPy ndarray case from
    the paper: the buffer is already a packed array of primitives)."""

    def encode(self, obj):
        return obj  # jnp.asarray at execution is zero-copy for aligned numpy

    def decode(self, value, ty):
        return value

    def weld_type(self, obj) -> wt.WeldType:
        arr = np.asarray(obj) if not hasattr(obj, "dtype") else obj
        base: wt.WeldType = wt.dtype_to_weld(arr.dtype)
        for _ in range(arr.ndim):
            base = wt.Vec(base)
        return base


class ScalarEncoder(Encoder):
    def weld_type(self, obj) -> wt.WeldType:
        if isinstance(obj, bool):
            return wt.Bool
        if isinstance(obj, (int, np.integer)):
            return wt.I64
        return wt.F64

    def decode(self, value, ty):
        return np.asarray(value).item()


# ---------------------------------------------------------------------------
# WeldObject
# ---------------------------------------------------------------------------


@dataclass
class WeldResult:
    """Handle returned by Evaluate (paper Table 2)."""

    value: object
    ty: wt.WeldType
    compile_ms: float
    run_ms: float
    from_cache: bool = False
    _freed: bool = False

    def free(self) -> None:  # parity with FreeWeldResult; jax GC does the work
        self._freed = True
        self.value = None


class WeldObject:
    """A lazily-evaluated computation or a wrapped external value.

    Data objects:  `expr` is an Ident referring to themselves; `data` holds
    the native value.  Computation objects: `expr` is Weld IR whose free
    variables refer to entries of `deps`.
    """

    def __init__(
        self,
        expr: ir.Expr,
        deps: Dict[str, "WeldObject"],
        encoder: Encoder,
        data: object = None,
        ty: Optional[wt.WeldType] = None,
    ):
        self.obj_id = f"obj{next(_obj_ids):010d}"  # padded: lex == numeric
        self.expr = expr
        self.deps = dict(deps)
        self.encoder = encoder
        self.data = data
        self._ty = ty
        self._freed = False

    # -- paper API ---------------------------------------------------------

    @property
    def is_data(self) -> bool:
        return self.data is not None or not self.deps and isinstance(self.expr, ir.Ident)

    def weld_type(self) -> wt.WeldType:
        if self._ty is not None:
            return self._ty
        env = {name: dep.weld_type() for name, dep in self.deps.items()}
        self._ty = ir.typeof(self.expr, env)
        return self._ty

    def evaluate(self, memory_limit: Optional[int] = None,
                 **kw) -> WeldResult:
        return Evaluate(self, memory_limit=memory_limit, **kw)

    def free(self) -> None:
        """FreeWeldObject: drops internal state, not deps (paper §4.1)."""
        self._freed = True
        self.expr = None
        self.deps = {}
        self.data = None

    def __repr__(self) -> str:
        kind = "data" if self.is_data else "lazy"
        return f"<WeldObject {self.obj_id} {kind} : {self.weld_type()}>"


def NewWeldObject(
    deps_or_data,
    expr_or_type,
    encoder: Optional[Encoder] = None,
) -> WeldObject:
    """The two variants from Table 2.

    * ``NewWeldObject(data, type_or_none, encoder)`` — wrap external data.
    * ``NewWeldObject([deps], expr, encoder)`` — wrap a sub-computation.
    """
    if isinstance(deps_or_data, (list, tuple)) and all(
        isinstance(d, WeldObject) for d in deps_or_data
    ) and isinstance(expr_or_type, ir.Expr):
        deps_list: List[WeldObject] = list(deps_or_data)
        expr: ir.Expr = expr_or_type
        deps = {d.obj_id: d for d in deps_list}
        # free vars of expr must be declared deps (paper §4.1)
        fv = ir.free_vars(expr)
        for name in fv:
            if name not in deps:
                raise ValueError(
                    f"IR references {name} which is not among declared deps"
                )
        return WeldObject(expr, deps, encoder or ArrayEncoder())
    # data variant
    data = deps_or_data
    encoder = encoder or (
        ScalarEncoder() if np.isscalar(data) else ArrayEncoder()
    )
    ty = expr_or_type if isinstance(expr_or_type, wt.WeldType) else encoder.weld_type(data)
    obj = WeldObject(ir.Ident("<self>", ty), {}, encoder, data=data, ty=ty)
    obj.expr = ir.Ident(obj.obj_id, ty)
    return obj


def GetObjectType(o: WeldObject) -> wt.WeldType:
    return o.weld_type()


def FreeWeldObject(o: WeldObject) -> None:
    o.free()


def FreeWeldResult(r: WeldResult) -> None:
    r.free()


# ---------------------------------------------------------------------------
# DAG -> single program
# ---------------------------------------------------------------------------


@dataclass
class Program:
    """A stitched whole-workflow Weld program ready for the optimizer."""

    expr: ir.Expr
    #: name -> (weld type, encoder, native value)
    inputs: Dict[str, Tuple[wt.WeldType, Encoder, object]]
    out_ty: wt.WeldType = None  # type: ignore

    def evaluate(
        self,
        optimize: bool = True,
        memory_limit: Optional[int] = None,
        passes=None,
        kernelize=None,
        kernel_impl: Optional[str] = None,
    ):
        """Compile + run this program directly (no WeldObject wrapper).

        Returns ``(value, compile_ms, from_cache, stats)``;
        ``kernelize`` selects the planner mode — ``"auto"`` (default:
        cost-gated), ``"always"``/``True``, or ``"off"``/``False``
        (see ``repro.core.kernelplan``).
        """
        from .runtime import compile_and_run  # local import: needs jax

        return compile_and_run(
            self,
            optimize=optimize,
            memory_limit=memory_limit,
            passes=passes,
            kernelize=kernelize,
            kernel_impl=kernel_impl,
        )


def build_program(root: WeldObject) -> Program:
    """Topologically stitch the DAG below `root` into one IR expression.

    Data leaves become program inputs; every internal object's expr is
    let-bound under its obj_id so downstream fragments can reference it.
    Shared sub-computations are bound once (this is where cross-library
    common-subexpression sharing falls out of the DAG structure).
    """
    order: List[WeldObject] = []
    seen = set()

    def topo(o: WeldObject):
        if o.obj_id in seen:
            return
        seen.add(o.obj_id)
        for dep in o.deps.values():
            topo(dep)
        order.append(o)

    topo(root)

    inputs: Dict[str, Tuple[wt.WeldType, Encoder, object]] = {}
    bindings: List[Tuple[str, ir.Expr]] = []
    for o in order:
        if o._freed:
            raise RuntimeError(f"{o.obj_id} was freed before evaluation")
        if o.data is not None:
            inputs[o.obj_id] = (o.weld_type(), o.encoder, o.data)
        else:
            bindings.append((o.obj_id, o.expr))

    if root.data is not None:
        body: ir.Expr = ir.Ident(root.obj_id, root.weld_type())
    else:
        body = ir.Ident(root.obj_id, root.weld_type())
    # nest lets innermost-last so later bindings can see earlier ones
    for name, expr in reversed(bindings):
        body = ir.Let(name, expr, body)

    env = {k: v[0] for k, v in inputs.items()}
    out_ty = ir.typeof(body, env)
    return Program(expr=body, inputs=inputs, out_ty=out_ty)


# ---------------------------------------------------------------------------
# Evaluate
# ---------------------------------------------------------------------------

def Evaluate(
    o: WeldObject,
    memory_limit: Optional[int] = None,
    optimize: bool = True,
    passes=None,
    backend: str = "jax",
    collect_stats: Optional[dict] = None,
    kernelize=None,
    kernel_impl: Optional[str] = None,
) -> WeldResult:
    """Optimize + compile + run the whole DAG under `o` (paper Table 2).

    `memory_limit` bounds Weld-owned temporary allocation (estimated from
    size analysis, including kernel padding/scratch footprints); exceeded
    limits raise before execution.  `passes` selects a subset of optimizer
    passes (ablation benchmarks).  `kernelize` selects the kernel-planner
    mode: ``"auto"`` (the process default — matched loops route onto the
    Pallas kernel library only when the roofline cost model favors them),
    ``"always"``/``True`` (route every match), ``"off"``/``False``
    (bypass the planner; see ``repro.core.kernelplan``).  `kernel_impl`
    picks ref / interpret / pallas for the routed kernel calls.
    """
    from .runtime import compile_and_run  # local import: runtime needs jax

    # no global lock here: the runtime's compile cache is single-flight
    # (one thread compiles a key, peers wait) and compiles serialize on
    # the runtime's compile lock — concurrent Evaluates of already-
    # compiled programs execute in parallel
    prog = build_program(o)
    t0 = time.perf_counter()
    value, compile_ms, from_cache, stats = compile_and_run(
        prog,
        optimize=optimize,
        memory_limit=memory_limit,
        passes=passes,
        kernelize=kernelize,
        kernel_impl=kernel_impl,
    )
    run_ms = (time.perf_counter() - t0) * 1e3 - compile_ms
    if collect_stats is not None:
        collect_stats.update(stats)
    native = o.encoder.decode(value, prog.out_ty)
    return WeldResult(
        value=native,
        ty=prog.out_ty,
        compile_ms=compile_ms,
        run_ms=max(run_ms, 0.0),
        from_cache=from_cache,
    )
