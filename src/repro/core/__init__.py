# Weld core: the paper's primary contribution — a data-parallel IR
# (loops + builders), a lazy runtime API (WeldObject DAG), and an
# optimizer + JAX backend that fuse cross-library fragments into one
# XLA program per evaluation point.
from . import ir, macros, wtypes  # noqa: F401
from .cudf import register_cudf  # noqa: F401
from .lazy import (  # noqa: F401
    ArrayEncoder,
    Encoder,
    Evaluate,
    FreeWeldObject,
    FreeWeldResult,
    GetObjectType,
    NewWeldObject,
    WeldObject,
    WeldResult,
    ScalarEncoder,
)
