"""External function registry (paper: "Weld supports calling existing C
functions for complex non-data-parallel code").

Each registered name carries two implementations: a host (pure python /
numpy) version used by the reference interpreter, and a jax version used by
the backend.  This mirrors the paper's CUDF mechanism while staying inside
the JAX world.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

_REGISTRY: Dict[str, Tuple[Callable, Callable]] = {}


def register_cudf(name: str, host_fn: Callable, jax_fn: Callable) -> None:
    if name in _REGISTRY:
        raise ValueError(f"cudf {name!r} already registered")
    _REGISTRY[name] = (host_fn, jax_fn)


def lookup_cudf_host(name: str) -> Callable:
    return _REGISTRY[name][0]


def lookup_cudf_jax(name: str) -> Callable:
    return _REGISTRY[name][1]


def has_cudf(name: str) -> bool:
    return name in _REGISTRY
