"""Lower optimized Weld IR to a fused JAX program.

The emitter *interprets the IR while tracing*: running the emitted closure
under ``jax.jit`` stages one XLA program for the whole multi-library
workflow — the Weld evaluation point becomes exactly one compiled
executable, which is the paper's central mechanism.

Loop lowering ("vectorization", paper Table 3, adapted per DESIGN.md §2):

* A parallel ``for`` is evaluated in **vector form**: the element parameter
  is bound to the whole (tiled-by-XLA) array, builders become accumulator
  objects collecting masked contributions, and conditional control flow
  becomes predication masks.  This is the TPU-native analogue of the
  paper's AVX2 vectorization — the VPU consumes whole-array ops.
* Bodies that use their element as a *vector* (nested loops, e.g. a dot
  per row) fall back to ``jax.vmap`` over a scalar-world evaluation —
  the un-nesting transform the paper applies for its GPU backend.
* There is deliberately no sequential fallback: anything else raises
  ``WeldCompileError`` (see DESIGN.md §8.2 — SPMD hardware has no cheap
  dynamic parallelism, so we refuse rather than silently serialize).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import faults
from .. import ir
from .. import wtypes as wt
from ..cudf import has_cudf, lookup_cudf_jax
from ..errors import ResourceError, WeldError
from .values import WDict, WGroup, WVec


class WeldCompileError(WeldError):
    """The generic lowering refuses this program shape (not a kernel
    failure — see ``errors.KernelCompileError`` for those)."""


#: memory_limit breaches are typed ResourceError; the old name stays an
#: alias so existing imports/catch sites keep working.
WeldMemoryError = ResourceError


class _NeedsVmap(Exception):
    """Raised when a loop body needs its element as a vector."""


_NP_OF = {
    "bool": jnp.bool_, "i8": jnp.int8, "i32": jnp.int32,
    "i64": jnp.int64, "f32": jnp.float32, "f64": jnp.float64,
}


def _jdtype(ty: wt.Scalar):
    return _NP_OF[ty.kind]


# ---------------------------------------------------------------------------
# Builder accumulators
# ---------------------------------------------------------------------------


class _Acc:
    """Base accumulator.  Contributions are ('single', value) or
    ('batch', value, mask_or_None); struct values are tuples of arrays."""

    def __init__(self, bt: wt.BuilderType):
        self.bt = bt
        self.contribs: List[tuple] = []

    def add_single(self, value, mask=None):
        self.contribs.append(("single", value, mask))

    def add_batch(self, value, mask):
        self.contribs.append(("batch", value, mask))


class _MergerAcc(_Acc):
    def __init__(self, bt: wt.Merger, init=None):
        super().__init__(bt)
        self.init = init

    def finalize(self):
        acc = _identity_value(self.bt.elem, self.bt.op)
        if self.init is not None:
            acc = _combine(self.bt.op, acc, self.init)
        for kind, value, mask in self.contribs:
            if kind == "single":
                if mask is not None:
                    value = _select_struct(mask, value,
                                           _identity_value(self.bt.elem, self.bt.op))
                acc = _combine(self.bt.op, acc, value)
            else:
                red = _masked_reduce(self.bt, value, mask)
                acc = _combine(self.bt.op, acc, red)
        return acc


class _VecBuilderAcc(_Acc):
    def __init__(self, bt: wt.VecBuilder):
        super().__init__(bt)
        self.segments: List[tuple] = []  # sealed per enclosing loop

    def seal(self):
        """Called when an enclosing For finishes: fix the ordering of the
        contributions it produced (interleaved across merge sites)."""
        if not self.contribs:
            return
        batches = [(v, m) for k, v, m in self.contribs if k == "batch"]
        singles = [(v, m) for k, v, m in self.contribs if k == "single"]
        self.contribs = []
        if batches:
            vals = _interleave([b[0] for b in batches])
            masks = [
                b[1] if b[1] is not None
                else jnp.ones(_lead(b[0]), dtype=bool)
                for b in batches
            ]
            mask = _interleave(masks) if any(
                b[1] is not None for b in batches
            ) else None
            self.segments.append(("batch", vals, mask))
        for v, m in singles:
            self.segments.append(("single", v, m))

    def finalize(self):
        self.seal()
        if not self.segments:
            dt = _jdtype(self.bt.elem) if isinstance(self.bt.elem, wt.Scalar) else None
            if dt is None:
                raise WeldCompileError("empty struct vecbuilder")
            return WVec(jnp.zeros((0,), dtype=dt))
        if len(self.segments) == 1 and self.segments[0][0] == "batch":
            _, vals, mask = self.segments[0]
            if mask is None:
                return WVec(vals)
            return _compact(vals, mask)
        # general: concatenate segments (singles become length-1 batches)
        parts_v, parts_m = [], []
        for kind, v, m in self.segments:
            if kind == "single":
                v = jax.tree_util.tree_map(lambda a: jnp.asarray(a)[None], v)
                m = jnp.ones((1,), bool) if m is None else jnp.asarray(m)[None]
            else:
                m = jnp.ones(_lead(v), bool) if m is None else m
            parts_v.append(v)
            parts_m.append(m)
        vals = _concat_struct(parts_v)
        mask = jnp.concatenate(parts_m)
        return _compact(vals, mask)


class _VecMergerAcc(_Acc):
    def __init__(self, bt: wt.VecMerger, base):
        super().__init__(bt)
        if not isinstance(base, WVec):
            raise WeldCompileError("vecmerger needs a vector base")
        if not base.is_dense:
            raise WeldCompileError("vecmerger base must be dense")
        self.base = base

    def finalize(self):
        out = self.base.data
        ident = _identity_value(self.bt.elem, self.bt.op)
        for kind, value, mask in self.contribs:
            idx, v = value  # struct {i64, T}
            if kind == "single":
                idx = jnp.asarray(idx)[None]
                v = jax.tree_util.tree_map(lambda a: jnp.asarray(a)[None], v)
                mask = None if mask is None else jnp.asarray(mask)[None]
            if mask is not None:
                idx = jnp.where(mask, idx, 0)
                v = _select_struct(mask, v, ident)
            op = self.bt.op
            if op == "+":
                out = out.at[idx].add(v)
            elif op == "*":
                out = out.at[idx].multiply(v)
            elif op == "min":
                out = out.at[idx].min(v)
            elif op == "max":
                out = out.at[idx].max(v)
        return WVec(out)


class _DictMergerAcc(_Acc):
    def __init__(self, bt, capacity: int):
        super().__init__(bt)
        self.capacity = int(capacity)


class _GroupAcc(_Acc):
    def __init__(self, bt, capacity: int):
        super().__init__(bt)
        self.capacity = int(capacity)


def _finalize_keyed(acc, is_group: bool):
    """Shared finalize for dictmerger/groupbuilder: sort by packed key +
    segment-reduce (the TPU-native 'global builder' strategy — atomic-free)."""
    parts_k, parts_v, parts_m = [], [], []
    for kind, value, mask in acc.contribs:
        k, v = value
        if kind == "single":
            k = jax.tree_util.tree_map(lambda a: jnp.asarray(a)[None], k)
            v = jax.tree_util.tree_map(lambda a: jnp.asarray(a)[None], v)
            mask = None if mask is None else jnp.asarray(mask)[None]
        n = _lead(k)
        parts_k.append(k)
        parts_v.append(v)
        parts_m.append(jnp.ones(n, bool) if mask is None else mask)
    if not parts_k:
        raise WeldCompileError("empty dict builder")
    keys = _concat_struct(parts_k)
    vals = _concat_struct(parts_v)
    mask = jnp.concatenate(parts_m)

    packed = _pack_keys(keys)
    big = jnp.iinfo(jnp.int64).max
    packed = jnp.where(mask, packed, big)
    order = jnp.argsort(packed, stable=True)
    sp = packed[order]
    sk = _gather_struct(keys, order)
    sv = _gather_struct(vals, order)
    n = sp.shape[0]
    valid = sp != big
    is_new = jnp.concatenate([valid[:1], (sp[1:] != sp[:-1]) & valid[1:]])
    seg = jnp.cumsum(is_new.astype(jnp.int32)) - 1       # segment id per row
    seg = jnp.where(valid, seg, acc.capacity)            # park invalid rows
    count = is_new.sum()
    cap = acc.capacity
    # more distinct keys than capacity must POISON (negative count —
    # the same convention the kernel adapters use), never silently
    # truncate: the segment arrays below are only `cap` wide, so any
    # overflow would otherwise drop whole groups on the floor.  The
    # dict.build/group.build failpoints force the flag for tests.
    overflow = count > cap
    if faults.poisoned("group.build" if is_group else "dict.build"):
        overflow = True
    count = jnp.where(overflow, -count - 1, count)

    first_idx = jnp.where(is_new, jnp.arange(n), n)
    starts = jnp.sort(first_idx)[:cap]                   # first row per segment
    out_keys = _gather_struct(sk, jnp.clip(starts, 0, n - 1))

    if is_group:
        # values stay sorted-by-key; offsets via counts per segment
        ones = jnp.where(valid, 1, 0)
        sizes = jax.ops.segment_sum(ones, seg, num_segments=cap + 1)[:cap]
        offsets = jnp.concatenate(
            [jnp.zeros((1,), sizes.dtype), jnp.cumsum(sizes)]
        )
        return WGroup(out_keys, sv, offsets, count)

    opname = acc.bt.op
    segfn = {
        "+": jax.ops.segment_sum,
        "*": jax.ops.segment_prod,
        "min": jax.ops.segment_min,
        "max": jax.ops.segment_max,
    }[opname]

    def red(col):
        return segfn(col, seg, num_segments=cap + 1)[:cap]

    out_vals = jax.tree_util.tree_map(red, sv)
    return WDict(out_keys, out_vals, count)


# ---------------------------------------------------------------------------
# small helpers
# ---------------------------------------------------------------------------


def _lead(v) -> int:
    leaf = v[0] if isinstance(v, tuple) else v
    return leaf.shape[0]


def _interleave(vals: List):
    """[(n,...) x k] -> (n*k, ...) interleaved per-iteration."""
    if len(vals) == 1:
        return vals[0]
    if isinstance(vals[0], tuple):
        return tuple(
            _interleave([v[f] for v in vals]) for f in range(len(vals[0]))
        )
    stacked = jnp.stack(vals, axis=1)
    return stacked.reshape((-1,) + stacked.shape[2:])


def _concat_struct(parts: List):
    if isinstance(parts[0], tuple):
        return tuple(
            jnp.concatenate([p[f] for p in parts])
            for f in range(len(parts[0]))
        )
    return jnp.concatenate(parts)


def _gather_struct(v, idx):
    if isinstance(v, tuple):
        return tuple(f[idx] for f in v)
    return v[idx]


def _select_struct(mask, a, b):
    if isinstance(a, tuple):
        b = b if isinstance(b, tuple) else tuple(b for _ in a)
        return tuple(_select_struct(mask, x, y) for x, y in zip(a, b))
    return jnp.where(mask, a, b)


def _identity_value(ty, op):
    if isinstance(ty, wt.Struct):
        return tuple(_identity_value(f, op) for f in ty.fields)
    return jnp.asarray(wt.merge_identity(op, ty), dtype=_jdtype(ty))


def _combine(op, a, b):
    if isinstance(a, tuple):
        return tuple(_combine(op, x, y) for x, y in zip(a, b))
    return {
        "+": jnp.add, "*": jnp.multiply,
        "min": jnp.minimum, "max": jnp.maximum,
    }[op](a, b)


def _masked_reduce(bt: wt.Merger, value, mask):
    ident = _identity_value(bt.elem, bt.op)
    if mask is not None:
        value = _select_struct(mask, value, ident)
    fn = {
        "+": jnp.sum, "*": jnp.prod, "min": jnp.min, "max": jnp.max,
    }[bt.op]

    def red(x, iv):
        if hasattr(x, "shape") and x.ndim >= 1:
            if x.shape[0] == 0:  # empty loop: min/max have no jnp identity
                return jnp.asarray(iv)
            return fn(x, axis=0)
        return x

    return jax.tree_util.tree_map(red, value, ident)


def _compact(vals, mask) -> WVec:
    """Front-pack valid elements (stable) — TPU compaction via sort."""
    order = jnp.argsort(~mask, stable=True)
    packed = _gather_struct(vals, order)
    return WVec(packed, count=mask.sum())


def _pack_keys(keys):
    """Pack a (possibly struct) key into one i64 for sorting.  A single
    int column keeps its full 64-bit value (injective — join keys must
    not conflate); multi-field struct keys are bit-packed 32 bits per
    field and floats are bit-cast (order-preserving for the grouping
    use case — equality only matters, not order)."""
    cols = list(keys) if isinstance(keys, tuple) else [keys]
    if len(cols) == 1 and not jnp.issubdtype(cols[0].dtype, jnp.floating):
        return cols[0].astype(jnp.int64)
    packed = jnp.zeros(_lead(keys), dtype=jnp.int64)
    for c in cols:
        if jnp.issubdtype(c.dtype, jnp.floating):
            # normalize -0.0 to +0.0 BEFORE the bitcast: IEEE equality
            # says they match, the bit patterns do not (mirrored in
            # weldrel._pack_host — the two packings must stay
            # byte-identical)
            c = jnp.where(c == 0, jnp.zeros_like(c), c)
            c = jax.lax.bitcast_convert_type(
                c.astype(jnp.float32), jnp.int32
            ).astype(jnp.int64)
        else:
            c = c.astype(jnp.int64)
        packed = packed * jnp.int64(1 << 32) + (c & jnp.int64(0xFFFFFFFF))
    return packed


def _dict_find(d: WDict, key):
    """Locate `key` (scalar, (n,) column, or tuple thereof for struct
    keys) in a dict's sorted-front-packed key columns.

    Returns ``(pos, found, scalar)`` — clipped slot positions, a hit
    mask, and whether the input was a single key.  Works batched, which
    is what lets a probe loop (hash-join) lower as whole-column gathers
    instead of a per-element vmap.  Parked slots (>= count) are
    neutralized to +inf before the binary search: dicts produced under a
    filter mask carry arbitrary key bits there.  A poisoned dict
    (negative count, see the kernelized group-by overflow guard) matches
    nothing."""
    packed_keys = _pack_keys(d.keys)
    cap = packed_keys.shape[0]
    valid_n = jnp.maximum(jnp.asarray(d.count, jnp.int64), 0)
    big = jnp.iinfo(jnp.int64).max
    kt = (
        tuple(jnp.asarray(a) for a in key)
        if isinstance(key, tuple) else jnp.asarray(key)
    )
    lead = kt[0] if isinstance(kt, tuple) else kt
    scalar = lead.ndim == 0
    if scalar:
        kt = jax.tree_util.tree_map(lambda a: a[None], kt)
    q = _pack_keys(kt)
    if cap == 0:  # empty build side (static): nothing can match
        zeros = jnp.zeros(q.shape, jnp.int64)
        return zeros, zeros.astype(bool), scalar
    table = jnp.where(jnp.arange(cap) < valid_n, packed_keys, big)
    pos = jnp.clip(jnp.searchsorted(table, q), 0, cap - 1)
    found = (table[pos] == q) & (pos < valid_n)
    return pos, found, scalar


def _group_find(g: WGroup, key):
    """Locate batched probe keys in a groupbuilder result's sorted key
    columns.  Returns ``(pos, found, sizes)`` — clipped slot positions,
    a hit mask, and the per-query group size (0 on a miss).  Parked
    slots (>= count) are neutralized before the binary search; a
    poisoned group (negative count) matches nothing."""
    packed = _pack_keys(g.keys)
    cap = packed.shape[0]
    valid_n = jnp.maximum(jnp.asarray(g.count, jnp.int64), 0)
    kt = (
        tuple(jnp.asarray(a) for a in key)
        if isinstance(key, tuple) else jnp.asarray(key)
    )
    q = _pack_keys(kt)
    if cap == 0:  # statically empty build side: nothing can match
        z = jnp.zeros(q.shape, jnp.int64)
        return z.astype(jnp.int32), z.astype(bool), z
    big = jnp.iinfo(jnp.int64).max
    table = jnp.where(jnp.arange(cap) < valid_n, packed, big)
    pos = jnp.clip(jnp.searchsorted(table, q), 0, cap - 1).astype(jnp.int32)
    found = (table[pos] == q) & (pos < valid_n)
    offs = jnp.asarray(g.offsets, jnp.int64)
    sizes_all = offs[1:] - offs[:-1]
    sizes = jnp.where(found, sizes_all[pos], jnp.int64(0))
    return pos, found, sizes


def expand_rows(cnt, out_cap: int):
    """Two-phase variable-length expansion: per-row repeat counts ->
    ``(rows, ordinals, total)``.  ``rows[j]`` is the source row of output
    slot ``j`` (exclusive-scan offsets + binary search), ``ordinals[j]``
    its position within that row's run; ``total`` is the dynamic output
    length materialized into the static ``out_cap`` buffer."""
    n = cnt.shape[0]
    cnt = jnp.asarray(cnt, jnp.int64)
    total = cnt.sum() if n else jnp.int64(0)
    if out_cap == 0 or n == 0:
        z = jnp.zeros((out_cap,), jnp.int64)
        return z, z, total
    ends = jnp.cumsum(cnt)
    starts = ends - cnt
    j = jnp.arange(out_cap, dtype=jnp.int64)
    rows = jnp.clip(jnp.searchsorted(ends, j, side="right"), 0, n - 1)
    ordinals = j - starts[rows]
    return rows, ordinals, total


def group_expand(g: WGroup, pos, found, sizes, mask, how: str,
                 out_cap: int, col_specs):
    """Materialize an m:n probe's expanded output columns: match counts
    -> exclusive scan -> repeat/gather, all columns sharing ONE
    expansion index.  ``col_specs`` entries are ``("expr", col)`` (a
    whole probe-side column, repeated per match) or ``("gather", data,
    fill)`` (a build-side column gathered through the group's stored
    row payload; ``fill`` selects left-join miss rows).  Poison
    (negative group count, or a dynamic total exceeding the static
    capacity) propagates as a negative output count."""
    n = pos.shape[0]
    if how == "inner":
        cnt = jnp.where(found & mask, sizes, jnp.int64(0))
    elif how == "left":  # misses emit ONE fill row each
        cnt = jnp.where(mask, jnp.where(found, sizes, jnp.int64(1)),
                        jnp.int64(0))
    else:
        raise WeldCompileError(f"group expansion how={how!r}")
    rows, ordinals, total = expand_rows(cnt, out_cap)
    total = jnp.where(total > out_cap, -total - 1, total)
    total = jnp.where(jnp.asarray(g.count, jnp.int64) < 0,
                      jnp.int64(-1), total)
    vals = g.values
    if isinstance(vals, tuple):
        raise WeldCompileError("group expansion needs a scalar payload")
    nv = vals.shape[0]
    offs = jnp.asarray(g.offsets, jnp.int64)
    if n == 0 or out_cap == 0:
        frow = jnp.zeros((out_cap,), bool)
        payload = jnp.zeros((out_cap,), jnp.int64)
    else:
        frow = found[rows]
        grp = jnp.clip(pos[rows], 0, offs.shape[0] - 2)
        if nv == 0:
            payload = jnp.zeros((out_cap,), jnp.int64)
        else:
            bpos = jnp.clip(offs[grp] + ordinals, 0, nv - 1)
            payload = jnp.asarray(vals)[bpos]
    outs = []
    for spec in col_specs:
        if spec[0] == "expr":
            col = spec[1]
            out = col[rows] if (n and out_cap) else jnp.zeros(
                (out_cap,), col.dtype)
        else:
            rv, fill = spec[1], spec[2]
            if rv.shape[0] == 0 or out_cap == 0:
                out = jnp.zeros((out_cap,), rv.dtype)
                if fill is not None:
                    out = jnp.full((out_cap,), jnp.asarray(fill, rv.dtype))
            else:
                out = rv[jnp.clip(payload, 0, rv.shape[0] - 1)]
            if how == "left" and out_cap:
                out = jnp.where(frow, out, jnp.asarray(fill, rv.dtype))
        outs.append(out)
    return tuple(WVec(o, count=total) for o in outs)


@dataclass
class GroupProbeShape:
    """Destructured m:n probe loop (see :func:`match_group_probe`)."""

    d: "ir.Ident"                 # the groupbuilder dict
    key_parts: list               # per-probe-row key column exprs
    pred: Optional["ir.Expr"]     # optional elementwise row predicate
    how: str                      # "inner" | "left"
    cols: list                    # ("expr", e) | ("gather", rcol Ident)
    fills: list                   # per-column left-miss Literal (or None)
    builders: list                # the output NewBuilder(VecBuilder)s


def match_group_probe(loop: ir.For) -> Optional[GroupProbeShape]:
    """Structurally match weldrel's m:n join probe loop — the canonical
    variable-length-expansion form shared by the generic lowering and
    the kernel planner's ``group_probe`` route:

        for(V.., {vecbuilder..}, (b,i,x) =>
            [if(pred,]
              [if(keyexists(d, k),]                        # left only
                for(grouplookup(d, k), b, (b2,i2,r) =>
                    {merge(b2.$k, f(x) | lookup(RCOL, r))..})
              [, {merge(b.$k, f(x) | fill)..})]            # left misses
            [, b)])

    Returns ``None`` when the loop is anything else (the generic
    accumulator lowering then applies)."""
    nb = loop.builder
    if not (isinstance(nb, ir.MakeStruct) and nb.items and all(
            isinstance(p, ir.NewBuilder) and isinstance(p.ty, wt.VecBuilder)
            and isinstance(p.ty.elem, wt.Scalar) for p in nb.items)):
        return None
    if len(loop.func.params) != 3:
        return None
    b, i, x = loop.func.params
    body = loop.func.body
    pred: Optional[ir.Expr] = None
    if (isinstance(body, ir.If) and isinstance(body.on_false, ir.Ident)
            and body.on_false.name == b.name):
        pred, body = body.cond, body.on_true
    how, miss, ke = "inner", None, None
    if isinstance(body, ir.If) and isinstance(body.cond, ir.KeyExists):
        how, ke, miss, body = "left", body.cond, body.on_false, body.on_true
    if not (isinstance(body, ir.For) and len(body.iters) == 1
            and body.iters[0].is_plain
            and isinstance(body.iters[0].data, ir.GroupLookup)):
        return None
    gl = body.iters[0].data
    d = gl.expr
    if not (isinstance(d, ir.Ident) and isinstance(d.ty, wt.DictType)
            and isinstance(d.ty.val, wt.Vec)):
        return None
    if how == "left" and not (
            isinstance(ke.expr, ir.Ident) and ke.expr.name == d.name
            and ir.canon_key(ke.key) == ir.canon_key(gl.key)):
        return None
    if not (isinstance(body.builder, ir.Ident)
            and body.builder.name == b.name):
        return None
    if len(body.func.params) != 3:
        return None
    bi, ii, ri = body.func.params
    ibody = body.func.body
    if not (isinstance(ibody, ir.MakeStruct)
            and len(ibody.items) == len(nb.items)):
        return None

    def merge_into(item: ir.Expr, k: int, bname: str) -> Optional[ir.Expr]:
        if (isinstance(item, ir.Merge)
                and isinstance(item.builder, ir.GetField)
                and item.builder.index == k
                and isinstance(item.builder.expr, ir.Ident)
                and item.builder.expr.name == bname):
            return item.value
        return None

    cols: list = []
    fills: list = []
    for k, item in enumerate(ibody.items):
        v = merge_into(item, k, bi.name)
        if v is None:
            return None
        if (isinstance(v, ir.Lookup) and v.default is None
                and isinstance(v.expr, ir.Ident)
                and isinstance(v.expr.ty, wt.Vec)
                and isinstance(v.index, ir.Ident)
                and v.index.name == ri.name):
            cols.append(("gather", v.expr))
        else:
            if set(ir.free_vars(v)) & {ri.name, ii.name, bi.name, d.name}:
                return None
            cols.append(("expr", v))
        fills.append(None)
    if how == "left":
        if not (isinstance(miss, ir.MakeStruct)
                and len(miss.items) == len(nb.items)):
            return None
        for k, item in enumerate(miss.items):
            mv = merge_into(item, k, b.name)
            if mv is None:
                return None
            kind, payload = cols[k]
            if kind == "gather":
                if not isinstance(mv, ir.Literal):
                    return None
                fills[k] = mv
            elif ir.canon_key(mv) != ir.canon_key(payload):
                return None  # probe columns must fill with themselves
    key = gl.key
    key_parts = (
        list(key.items) if isinstance(key, ir.MakeStruct) else [key]
    )
    for e2 in key_parts + ([pred] if pred is not None else []):
        if d.name in ir.free_vars(e2):
            return None
    return GroupProbeShape(d=d, key_parts=key_parts, pred=pred, how=how,
                           cols=cols, fills=fills, builders=list(nb.items))


_UNARY_JAX = {
    "neg": jnp.negative,
    "not": jnp.logical_not,
    "exp": jnp.exp,
    "log": jnp.log,
    "sqrt": jnp.sqrt,
    "erf": jax.lax.erf,
    "sin": jnp.sin,
    "cos": jnp.cos,
    "tanh": jnp.tanh,
    "abs": jnp.abs,
    "sigmoid": jax.nn.sigmoid,
    "floor": jnp.floor,
    "rsqrt": jax.lax.rsqrt,
}


def _binop_jax(op, a, b):
    if op in ("+", "-", "*"):
        return {"+": jnp.add, "-": jnp.subtract, "*": jnp.multiply}[op](a, b)
    if op == "/":
        if jnp.issubdtype(jnp.result_type(a), jnp.integer):
            return jax.lax.div(jnp.asarray(a), jnp.asarray(b))  # C trunc-div
        return jnp.divide(a, b)
    if op == "%":
        if jnp.issubdtype(jnp.result_type(a), jnp.integer):
            return jax.lax.rem(jnp.asarray(a), jnp.asarray(b))
        return jnp.mod(a, b)
    if op == "pow":
        return jnp.power(a, b)
    if op in ("min", "max"):
        return (jnp.minimum if op == "min" else jnp.maximum)(a, b)
    if op in ir.CMP_OPS:
        return {
            "==": jnp.equal, "!=": jnp.not_equal, "<": jnp.less,
            "<=": jnp.less_equal, ">": jnp.greater, ">=": jnp.greater_equal,
        }[op](a, b)
    if op == "&&":
        return jnp.logical_and(a, b)
    if op == "||":
        return jnp.logical_or(a, b)
    raise WeldCompileError(f"binop {op}")


# ---------------------------------------------------------------------------
# static const-eval for iter bounds / capacities
# ---------------------------------------------------------------------------


def _static_eval(e: ir.Expr, shapes: Dict[str, tuple]) -> Optional[int]:
    if isinstance(e, ir.Literal):
        return int(e.value)
    if isinstance(e, ir.Len) and isinstance(e.expr, ir.Ident):
        shp = shapes.get(e.expr.name)
        return None if shp is None else int(shp[0])
    if isinstance(e, ir.BinOp):
        a = _static_eval(e.left, shapes)
        b = _static_eval(e.right, shapes)
        if a is None or b is None:
            return None
        return int({
            "+": a + b, "-": a - b, "*": a * b,
            "/": int(a / b) if b else 0,
            "min": min(a, b), "max": max(a, b),
        }.get(e.op, None)) if e.op in ("+", "-", "*", "/", "min", "max") else None
    return None


# ---------------------------------------------------------------------------
# The emitter
# ---------------------------------------------------------------------------


class _LoopCtx:
    def __init__(self, n: int, mask, per_elem: frozenset, parent=None):
        self.n = n
        self.mask = mask  # (n,) bool or None
        self.per_elem = per_elem
        self.parent = parent
        self.touched: List[_Acc] = []  # vecbuilders merged in this loop


class Emitter:
    def __init__(self, input_shapes: Dict[str, tuple],
                 memory_limit: Optional[int] = None,
                 kernel_impl: Optional[str] = None,
                 measure: bool = False):
        self.input_shapes = input_shapes
        self.memory_limit = memory_limit
        self.kernel_impl = kernel_impl
        self.measure = measure
        self.est_bytes = 0
        #: dynamic counts of every dict/group this program probed —
        #: emit_program ORs their signs into the output counts so a
        #: probe against a poisoned (overflowed) collection can never
        #: decode as a plausible empty/partial result
        self.taints: List[object] = []

    def _note_taint(self, coll) -> None:
        count = getattr(coll, "count", None)
        if count is not None:
            self.taints.append(count)

    @staticmethod
    def _ret_dtype(x: ir.KernelCall) -> str:
        from ..kernelplan.autotune import _np_dtype_of

        return str(np.dtype(_np_dtype_of(x.ret_ty)))

    # -- entry ---------------------------------------------------------------

    def run(self, expr: ir.Expr, env: Dict[str, object]):
        return self.ev(expr, dict(env), None)

    # -- main dispatch ---------------------------------------------------------

    def ev(self, x: ir.Expr, env, ctx: Optional[_LoopCtx]):
        m = getattr(self, "_ev_" + type(x).__name__, None)
        if m is None:
            raise WeldCompileError(f"cannot lower {type(x).__name__}")
        return m(x, env, ctx)

    # -- leaves ---------------------------------------------------------------

    def _ev_Literal(self, x: ir.Literal, env, ctx):
        return jnp.asarray(x.value, dtype=_jdtype(x.ty))

    def _ev_Ident(self, x: ir.Ident, env, ctx):
        if x.name not in env:
            raise WeldCompileError(f"unbound {x.name}")
        return env[x.name]

    def _ev_Let(self, x: ir.Let, env, ctx):
        v = self.ev(x.value, env, ctx)
        env2 = dict(env)
        env2[x.name] = v
        if ctx is not None and self._depends_per_elem(x.value, ctx):
            ctx2 = _LoopCtx(ctx.n, ctx.mask, ctx.per_elem | {x.name}, ctx.parent)
            ctx2.touched = ctx.touched  # share accumulator-seal tracking
            ctx = ctx2
        return self.ev(x.body, env2, ctx)

    def _ev_BinOp(self, x: ir.BinOp, env, ctx):
        return _binop_jax(x.op, self.ev(x.left, env, ctx),
                          self.ev(x.right, env, ctx))

    def _ev_UnaryOp(self, x: ir.UnaryOp, env, ctx):
        v = self.ev(x.expr, env, ctx)
        if x.op in ("exp", "log", "sqrt", "erf", "sin", "cos", "tanh",
                    "sigmoid", "rsqrt"):
            v = _to_float(v)
        return _UNARY_JAX[x.op](v)

    def _ev_Cast(self, x: ir.Cast, env, ctx):
        return jnp.asarray(self.ev(x.expr, env, ctx)).astype(_jdtype(x.ty))

    def _ev_Select(self, x: ir.Select, env, ctx):
        c = self.ev(x.cond, env, ctx)
        t = self.ev(x.on_true, env, ctx)
        f = self.ev(x.on_false, env, ctx)
        return _select_struct(c, t, f) if isinstance(t, tuple) else jnp.where(c, t, f)

    def _ev_If(self, x: ir.If, env, ctx):
        bty = self._is_builder_expr(x.on_true, env)
        if not bty:
            return self._ev_Select(ir.Select(x.cond, x.on_true, x.on_false),
                                   env, ctx)
        # control flow over builders -> predication masks
        c = self.ev(x.cond, env, ctx)
        if ctx is None:
            raise WeldCompileError("builder If outside a loop")
        c = jnp.broadcast_to(c, (ctx.n,))
        mask_t = c if ctx.mask is None else ctx.mask & c
        mask_f = ~c if ctx.mask is None else ctx.mask & ~c
        ctx_t = _LoopCtx(ctx.n, mask_t, ctx.per_elem, ctx.parent)
        ctx_t.touched = ctx.touched
        ctx_f = _LoopCtx(ctx.n, mask_f, ctx.per_elem, ctx.parent)
        ctx_f.touched = ctx.touched
        t = self.ev(x.on_true, env, ctx_t)
        self.ev(x.on_false, env, ctx_f)
        return t  # same accumulator objects on both paths

    def _ev_MakeStruct(self, x: ir.MakeStruct, env, ctx):
        return tuple(self.ev(i, env, ctx) for i in x.items)

    def _ev_GetField(self, x: ir.GetField, env, ctx):
        v = self.ev(x.expr, env, ctx)
        return v[x.index]

    def _ev_MakeVec(self, x: ir.MakeVec, env, ctx):
        items = [self.ev(i, env, ctx) for i in x.items]
        return WVec(jnp.stack([jnp.asarray(i) for i in items]))

    def _ev_Len(self, x: ir.Len, env, ctx):
        if ctx is not None and self._depends_per_elem(x.expr, ctx):
            raise _NeedsVmap()
        v = self.ev(x.expr, env, ctx)
        if isinstance(v, WVec):
            return jnp.asarray(v.length(), dtype=jnp.int64)
        raise WeldCompileError("len of non-vec")

    def _ev_Lookup(self, x: ir.Lookup, env, ctx):
        if ctx is not None and self._depends_per_elem(x.expr, ctx):
            raise _NeedsVmap()
        coll = self.ev(x.expr, env, ctx)
        idx = self.ev(x.index, env, ctx)
        if isinstance(coll, WVec):
            return _gather_struct(coll.data, idx)  # gather (vectorized ok)
        if isinstance(coll, WDict):
            # scalar OR whole-column probe (vectorized loop bodies bind
            # the key to a column).  With a `default` the miss mask from
            # the SAME find selects the fill — one probe pass, no second
            # search; without one, missing keys yield an arbitrary slot's
            # value — guard with KeyExists, as the frames do.
            self._note_taint(coll)
            pos, found, scalar = _dict_find(coll, idx)

            def gather(a):
                if a.shape[0] == 0:  # empty dict: type-correct zeros
                    return jnp.zeros(pos.shape, a.dtype)
                return a[pos]

            out = jax.tree_util.tree_map(gather, coll.vals)
            if x.default is not None:
                dflt = self.ev(x.default, env, ctx)
                out = _select_struct(found, out, dflt)
            if scalar:
                out = jax.tree_util.tree_map(lambda a: a[0], out)
            return out
        raise WeldCompileError("lookup on unsupported value")

    def _ev_KeyExists(self, x: ir.KeyExists, env, ctx):
        d = self.ev(x.expr, env, ctx)
        k = self.ev(x.key, env, ctx)
        self._note_taint(d)
        if isinstance(d, WGroup):
            pos, found, _ = _group_find(d, k)
            return found
        pos, found, scalar = _dict_find(d, k)
        return found[0] if scalar else found

    def _ev_GroupLookup(self, x: ir.GroupLookup, env, ctx):
        raise WeldCompileError(
            "grouplookup has data-dependent length and lowers only as "
            "the iteration source of an m:n probe loop (the shape "
            "match_group_probe recognizes); restructure the program "
            "around that canonical expansion form"
        )

    def _ev_CUDF(self, x: ir.CUDF, env, ctx):
        if ctx is not None and any(
            self._depends_per_elem(a, ctx) for a in x.args
        ):
            raise _NeedsVmap()
        if not has_cudf(x.name) and not x.name.startswith("linalg."):
            raise WeldCompileError(f"unknown cudf {x.name}")
        args = [self.ev(a, env, ctx) for a in x.args]
        uw = [a.data if isinstance(a, WVec) and a.is_dense else a for a in args]
        if any(isinstance(a, WVec) for a in uw):
            raise WeldCompileError(f"cudf {x.name} on padded vector")
        if x.name == "linalg.dot":
            out = jnp.dot(uw[0], uw[1])
        elif x.name == "linalg.matvec":
            out = uw[0] @ uw[1]
        elif x.name == "linalg.matmul":
            out = uw[0] @ uw[1]
        else:
            out = lookup_cudf_jax(x.name)(*uw)
        if isinstance(x.ret_ty, wt.Vec):
            return WVec(out)
        return out

    def _ev_KernelCall(self, x: ir.KernelCall, env, ctx):
        if ctx is not None and any(
            self._depends_per_elem(a, ctx) for a in x.args
        ):
            raise _NeedsVmap()
        from ..kernelplan import registry as kreg

        spec = kreg.get(x.kernel)
        args = [self.ev(a, env, ctx) for a in x.args]
        params = dict(x.params)
        if self.memory_limit is not None and spec.footprint is not None:
            # kernel calls pay padding + scratch out of the same budget
            # the vecbuilder size hints feed — a kernelized plan cannot
            # silently blow the evaluation's memory estimate
            self.est_bytes += self._kernel_footprint(spec, args, x, params)
            if self.est_bytes > self.memory_limit:
                raise WeldMemoryError(
                    f"estimated temp bytes {self.est_bytes} (incl. kernel "
                    f"{x.kernel} padding/scratch) exceed memory limit "
                    f"{self.memory_limit}"
                )
        fns = [self._stage_elem_fn(lam, env) for lam in x.fns]
        if self.measure:
            return self._measured_kernel_call(x, spec, args, params, fns)
        # per-launch label: device profiles (and jaxpr dumps) name each
        # kernel launch after the IR loop it was planned from
        from .. import obs

        obs.event("launch.stage", kernel=x.kernel,
                  n=params.get("n_rows"), impl=self.kernel_impl)
        with jax.named_scope(f"weld.{x.kernel}"):
            return kreg.execute_spec(args=args, params=params, fns=fns,
                                     impl=self.kernel_impl, spec=spec,
                                     dtype=self._ret_dtype(x))

    def _measured_kernel_call(self, x: ir.KernelCall, spec, args, params,
                              fns):
        """Eager-replay path: time this launch, record a span and a cost
        ledger entry carrying the planner's ``predicted_ns`` next to the
        measured wall time."""
        from .. import obs

        block = {k: v for k, v in params.items()
                 if k in ("block", "bm", "bn", "bk")}
        from ..kernelplan import registry as kreg

        with obs.span(f"kernel.{x.kernel}", n=params.get("n_rows"),
                      impl=self.kernel_impl, **block) as sp:
            out = kreg.execute_spec(args=args, params=params, fns=fns,
                                    impl=self.kernel_impl, spec=spec,
                                    dtype=self._ret_dtype(x))
            out = jax.block_until_ready(out)
        predicted = params.get("predicted_ns")
        sp.set("predicted_ns", predicted)
        sp.set("measured_ns", sp.dur_ns)
        from ..kernelplan.autotune import _np_dtype_of

        dtype = str(np.dtype(_np_dtype_of(x.ret_ty)))
        obs.ledger.record(
            kernel=x.kernel, dtype=dtype, n=params.get("n_rows") or 0,
            predicted_ns=predicted, measured_ns=sp.dur_ns or 0,
            impl=self.kernel_impl, params=block,
        )
        return out

    @staticmethod
    def _kernel_footprint(spec, args, x: ir.KernelCall, params) -> int:
        def shape_of(v):
            if isinstance(v, WVec):
                leaf = v.data[0] if isinstance(v.data, tuple) else v.data
                return tuple(leaf.shape)
            return getattr(v, "shape", None) and tuple(v.shape) or ()

        try:
            return int(spec.footprint(
                [shape_of(a) for a in args], wt.elem_bytes(x.ret_ty), params
            ))
        except Exception:
            return 0  # accounting must never break a valid plan

    def _stage_elem_fn(self, lam: ir.Lambda, env):
        """Per-element IR lambda -> jnp-traceable callable (whole-column
        evaluation via this emitter, closing over the current env)."""
        base_env = dict(env)

        def fn(*vals):
            env2 = dict(base_env)
            for p, v in zip(lam.params, vals):
                env2[p.name] = v
            return self.ev(lam.body, env2, None)

        return fn

    # -- builders -------------------------------------------------------------

    def _ev_NewBuilder(self, x: ir.NewBuilder, env, ctx):
        bt = x.ty
        if isinstance(bt, wt.Merger):
            init = self.ev(x.arg, env, ctx) if x.arg is not None else None
            return _MergerAcc(bt, init)
        if isinstance(bt, wt.VecBuilder):
            if x.size_hint is not None and self.memory_limit is not None:
                n = _static_eval(x.size_hint, self.input_shapes)
                if n is not None and isinstance(bt.elem, wt.Scalar):
                    self.est_bytes += n * np.dtype(bt.elem.np_dtype).itemsize
                    if self.est_bytes > self.memory_limit:
                        raise WeldMemoryError(
                            f"estimated temp bytes {self.est_bytes} exceed "
                            f"memory limit {self.memory_limit}"
                        )
            return _VecBuilderAcc(bt)
        if isinstance(bt, wt.VecMerger):
            base = self.ev(x.arg, env, ctx)
            return _VecMergerAcc(bt, base)
        if isinstance(bt, (wt.DictMerger, wt.GroupBuilder)):
            cap = 1024
            if x.arg is not None:
                c = _static_eval(x.arg, self.input_shapes)
                if c is not None:
                    cap = c
            cls = _DictMergerAcc if isinstance(bt, wt.DictMerger) else _GroupAcc
            return cls(bt, cap)
        raise WeldCompileError(f"cannot build {bt}")

    def _ev_Merge(self, x: ir.Merge, env, ctx):
        acc = self.ev(x.builder, env, ctx)
        if not isinstance(acc, _Acc):
            raise WeldCompileError("merge into non-builder value")
        val = self.ev(x.value, env, ctx)
        if ctx is None:
            acc.add_single(val)
        else:
            val = self._broadcast_elem(val, ctx)
            acc.add_batch(val, ctx.mask)
            if isinstance(acc, _VecBuilderAcc) and acc not in ctx.touched:
                ctx.touched.append(acc)
        return acc

    def _ev_Result(self, x: ir.Result, env, ctx):
        if ctx is None and isinstance(x.builder, ir.For):
            shape = match_group_probe(x.builder)
            if shape is not None:
                return self._lower_group_probe(x.builder, shape, env)
        acc = self.ev(x.builder, env, ctx)
        if isinstance(acc, tuple):
            return tuple(self._finalize(a) for a in acc)
        return self._finalize(acc)

    def _lower_group_probe(self, loop: ir.For, shape: GroupProbeShape, env):
        """Generic (kernel-free) lowering of the m:n join probe: one
        binary-search membership pass over the group's sorted keys, then
        the shared two-phase expansion (match counts -> exclusive scan ->
        repeat/gather) with every output column riding one expansion
        index.  Output length is data-dependent; the static buffer
        capacity comes from the vecbuilders' size hints."""
        g = self.ev(shape.d, env, None)
        if not isinstance(g, WGroup):
            raise WeldCompileError("group probe expects a groupbuilder dict")
        seqs = [self.ev(it, env, None) for it in loop.iters]
        n = min(s.capacity() for s in seqs)
        mask = None
        for s in seqs:
            if not s.is_dense:
                m = jnp.arange(n) < s.count
                mask = m if mask is None else mask & m
        b_p, i_p, x_p = loop.func.params
        env2 = dict(env)
        env2[i_p.name] = jnp.arange(n, dtype=jnp.int64)
        env2[x_p.name] = (
            _first_n(seqs[0].data, n) if len(seqs) == 1
            else tuple(_first_n(s.data, n) for s in seqs)
        )

        def col(v):
            a = jnp.asarray(v)
            return a if a.ndim >= 1 and a.shape[0] == n \
                else jnp.broadcast_to(a, (n,) + a.shape)

        key_cols = [col(self.ev(kp, env2, None)) for kp in shape.key_parts]
        key = tuple(key_cols) if len(key_cols) > 1 else key_cols[0]
        pos, found, sizes = _group_find(g, key)
        pm = mask
        if shape.pred is not None:
            pv = col(self.ev(shape.pred, env2, None)).astype(bool)
            pm = pv if pm is None else pm & pv
        if pm is None:
            pm = jnp.ones((n,), bool)
        hint = shape.builders[0].size_hint
        out_cap = (
            _static_eval(hint, self.input_shapes)
            if hint is not None else None
        )
        if out_cap is None:
            raise WeldCompileError(
                "m:n group probe needs a static output capacity "
                "(vecbuilder size hint)"
            )
        if self.memory_limit is not None:
            self.est_bytes += sum(
                int(out_cap) * np.dtype(p.ty.elem.np_dtype).itemsize
                for p in shape.builders
            )
            if self.est_bytes > self.memory_limit:
                raise WeldMemoryError(
                    f"estimated temp bytes {self.est_bytes} (incl. m:n "
                    f"join expansion) exceed memory limit "
                    f"{self.memory_limit}"
                )
        col_specs = []
        for (kind, payload), fill in zip(shape.cols, shape.fills):
            if kind == "expr":
                col_specs.append(("expr", col(self.ev(payload, env2, None))))
            else:
                rv = self.ev(payload, env, None)
                if not isinstance(rv, WVec) or not rv.is_dense:
                    raise WeldCompileError(
                        "group probe gathers need dense build columns"
                    )
                col_specs.append(
                    ("gather", rv.data,
                     None if fill is None else fill.value)
                )
        return group_expand(g, pos, found, sizes, pm, shape.how,
                            int(out_cap), col_specs)

    def _finalize(self, acc):
        if isinstance(acc, (_MergerAcc, _VecBuilderAcc, _VecMergerAcc)):
            return acc.finalize()
        if isinstance(acc, _DictMergerAcc):
            return _finalize_keyed(acc, is_group=False)
        if isinstance(acc, _GroupAcc):
            return _finalize_keyed(acc, is_group=True)
        raise WeldCompileError("result of non-builder")

    # -- loops ----------------------------------------------------------------

    def _ev_Iter(self, x: ir.Iter, env, ctx):
        data = self.ev(x.data, env, ctx)
        if not isinstance(data, WVec):
            raise WeldCompileError("iter over non-vec")
        start = _static_eval(x.start, self.input_shapes) if x.start is not None else 0
        end = (
            _static_eval(x.end, self.input_shapes)
            if x.end is not None else None
        )
        stride = (
            _static_eval(x.stride, self.input_shapes)
            if x.stride is not None else 1
        )
        if (x.start is not None and start is None) or \
           (x.end is not None and end is None) or \
           (x.stride is not None and stride is None):
            raise WeldCompileError("iter bounds must be statically evaluable")
        if start == 0 and end is None and stride == 1:
            return data
        if not data.is_dense:
            raise WeldCompileError("cannot slice a padded (filtered) vector")
        arr = data.data
        sl = (slice(start, end, stride),)
        arr = tuple(a[sl] for a in arr) if isinstance(arr, tuple) else arr[sl]
        return WVec(arr)

    def _ev_For(self, x: ir.For, env, ctx):
        # nested loop whose data depends on the enclosing element -> vmap
        if ctx is not None and any(
            self._depends_per_elem(it, ctx) for it in x.iters
        ):
            raise _NeedsVmap()

        acc_tree = self.ev(x.builder, env, ctx)
        seqs = [self.ev(it, env, ctx) for it in x.iters]
        lens = {s.capacity() for s in seqs}
        n = min(lens)
        mask = None
        for s in seqs:
            if not s.is_dense:
                m = jnp.arange(n) < s.count
                mask = m if mask is None else (mask & m)

        b_p, i_p, x_p = x.func.params
        idx = jnp.arange(n, dtype=jnp.int64)
        if len(seqs) == 1:
            elem = _first_n(seqs[0].data, n)
        else:
            elem = tuple(_first_n(s.data, n) for s in seqs)

        env2 = dict(env)
        env2[b_p.name] = acc_tree
        env2[i_p.name] = idx
        env2[x_p.name] = elem
        loop = _LoopCtx(n, mask, frozenset({i_p.name, x_p.name}), ctx)
        # Decide the lowering BEFORE evaluating: evaluation mutates the
        # accumulators, so a mid-body fallback would double-merge.
        if self._body_needs_vmap(x.func.body, {i_p.name, x_p.name}):
            out = self._for_via_vmap(x, acc_tree, idx, elem, mask, env, loop)
        else:
            try:
                out = self.ev(x.func.body, env2, loop)
            except _NeedsVmap as exc:  # pre-scan missed a case: hard error
                raise WeldCompileError(
                    "loop body unexpectedly needed per-element vector "
                    "evaluation"
                ) from exc
        # seal vecbuilder ordering for this loop
        for a in loop.touched:
            if isinstance(a, _VecBuilderAcc):
                a.seal()
        return out

    def _body_needs_vmap(self, body: ir.Expr, per_elem: set) -> bool:
        """Pre-scan: does the body use its element/index as a *vector*
        (inner For / Len / Lookup / CUDF over per-element data)?"""

        def dep(e: ir.Expr, pe: set) -> bool:
            return bool(set(ir.free_vars(e)) & pe)

        def scan(e: ir.Expr, pe: set) -> bool:
            if isinstance(e, ir.For):
                if any(dep(it, pe) for it in e.iters):
                    return True
                # the inner loop introduces its own element names; per-elem
                # names from this level may still leak into its body
                return scan(e.builder, pe) or scan(e.func.body, pe)
            if isinstance(e, (ir.Len, ir.Lookup)):
                tgt = e.expr
                if dep(tgt, pe):
                    return True
            if isinstance(e, ir.CUDF):
                if any(dep(a, pe) for a in e.args):
                    return True
            if isinstance(e, ir.Let):
                pe2 = pe | {e.name} if dep(e.value, pe) else pe
                return scan(e.value, pe) or scan(e.body, pe2)
            if isinstance(e, ir.Lambda):
                return scan(e.body, pe)
            return any(scan(c, pe) for c in e.children())

        return scan(body, set(per_elem))

    def _for_via_vmap(self, x: ir.For, acc_tree, idx, elem, mask, env, loop):
        """Un-nesting fallback: the body needs its element as a vector.
        Supports (lets*) [If(cond,] Merge(b, V) [, b)] bodies — V computed
        per element under jax.vmap."""
        b_p, i_p, x_p = x.func.params
        body = x.func.body
        lets: List[Tuple[str, ir.Expr]] = []
        while isinstance(body, ir.Let):
            lets.append((body.name, body.value))
            body = body.body
        cond_expr = None
        if isinstance(body, ir.If):
            merge_branch, other = body.on_true, body.on_false
            cond_expr = body.cond
            if not isinstance(merge_branch, ir.Merge):
                merge_branch, other = body.on_false, body.on_true
                cond_expr = ir.UnaryOp("not", body.cond)
            if not isinstance(merge_branch, ir.Merge):
                raise WeldCompileError(
                    "cannot lower nested loop body (no merge branch)"
                )
            body = merge_branch
        if not isinstance(body, ir.Merge):
            raise WeldCompileError(
                "unsupported nested-vector loop body; restructure with "
                "flat edge lists or weldnp 2-D ops (DESIGN.md §8.2)"
            )
        target = self.ev(body.builder, dict(env, **{b_p.name: acc_tree}), None)

        def per_elem(i_s, x_s):
            env_s = dict(env)
            env_s[i_p.name] = i_s
            env_s[x_p.name] = _wrap_rows(x_s, x.iters, self, env)
            for nm, val in lets:
                env_s[nm] = self.ev(val, env_s, None)
            v = self.ev(body.value, env_s, None)
            keep = (
                jnp.asarray(True)
                if cond_expr is None
                else self.ev(cond_expr, env_s, None)
            )
            return v, keep

        vals, keeps = jax.vmap(per_elem)(idx, elem)
        m = keeps if cond_expr is not None else None
        if mask is not None:
            m = mask if m is None else (m & mask)
        if not isinstance(target, _Acc):
            raise WeldCompileError("nested loop must merge into a builder")
        target.add_batch(vals, m)
        if isinstance(target, _VecBuilderAcc) and target not in loop.touched:
            loop.touched.append(target)
        return acc_tree

    # -- helpers --------------------------------------------------------------

    def _depends_per_elem(self, e: ir.Expr, ctx: _LoopCtx) -> bool:
        names = set(ir.free_vars(e))
        c = ctx
        while c is not None:
            if names & c.per_elem:
                return True
            c = c.parent
        return False

    def _broadcast_elem(self, val, ctx: _LoopCtx):
        def bc(a):
            a = jnp.asarray(a)
            if a.ndim >= 1 and a.shape[0] == ctx.n:
                return a
            return jnp.broadcast_to(a, (ctx.n,) + a.shape)

        return jax.tree_util.tree_map(bc, val)

    def _is_builder_expr(self, e: ir.Expr, env) -> bool:
        try:
            t = ir.typeof(e, {k: None for k in ()})
            return isinstance(t, wt.BuilderType)
        except Exception:
            pass
        # structural fallback: Merge / NewBuilder / structs thereof /
        # idents bound to accumulators
        if isinstance(e, (ir.Merge, ir.NewBuilder)):
            return True
        if isinstance(e, ir.MakeStruct):
            return any(self._is_builder_expr(i, env) for i in e.items)
        if isinstance(e, ir.Let):
            return self._is_builder_expr(e.body, env)
        if isinstance(e, ir.GetField):
            return self._is_builder_expr(e.expr, env)
        if isinstance(e, ir.Ident):
            v = env.get(e.name)
            if isinstance(v, _Acc):
                return True
            if isinstance(v, tuple):
                return all(isinstance(i, _Acc) for i in v)
        return False


def _to_float(v):
    v = jnp.asarray(v)
    if jnp.issubdtype(v.dtype, jnp.integer) or v.dtype == jnp.bool_:
        return v.astype(jnp.float64)
    return v


def _first_n(data, n):
    if isinstance(data, tuple):
        return tuple(a[:n] for a in data)
    return data[:n]


def _wrap_rows(x_s, iters, emitter, env):
    """Inside vmap, an element of vec[vec[T]] is a row — re-wrap as WVec so
    inner loops can iterate it."""

    def wrap(a):
        if hasattr(a, "ndim") and a.ndim >= 1:
            return WVec(a)
        return a

    if isinstance(x_s, tuple):
        return tuple(wrap(a) for a in x_s)
    return wrap(x_s)


# ---------------------------------------------------------------------------
# Program entry
# ---------------------------------------------------------------------------


def emit_program(expr: ir.Expr, input_names: List[str],
                 input_types: Dict[str, wt.WeldType],
                 input_shapes: Dict[str, tuple],
                 memory_limit: Optional[int] = None,
                 kernel_impl: Optional[str] = None,
                 measure: bool = False):
    """Returns fn(*arrays) evaluating the program; wrap in jax.jit.

    With ``measure=True`` the closure must be run *unjitted*: every
    ``KernelCall`` is individually timed (``block_until_ready``) and
    recorded as an obs span + cost-ledger entry.
    """

    def fn(*arrays):
        env = {}
        for name, arr in zip(input_names, arrays):
            ty = input_types[name]
            env[name] = _wrap_input(arr, ty)
        em = Emitter(input_shapes, memory_limit, kernel_impl=kernel_impl,
                     measure=measure)
        out = em.run(expr, env)
        if em.taints:
            # the program probed dynamic-count dicts/groups: a negative
            # count on ANY of them poisons every countable output, so a
            # probe against an overflowed build can never decode as a
            # plausible empty result (the kernel probe adapters already
            # guarantee this; here the generic lowering matches them)
            bad = jnp.asarray(False)
            for t in em.taints:
                bad = bad | (jnp.asarray(t) < 0)
            out = _apply_taint(out, bad)
        return out

    return fn


def _apply_taint(v, bad):
    """Poison the dynamic counts of ``v`` where ``bad`` (traced bool)."""
    if isinstance(v, WVec):
        if v.count is None:
            n = v.capacity()
            return WVec(v.data, jnp.where(bad, jnp.int64(-1), jnp.int64(n)))
        c = jnp.asarray(v.count)
        return WVec(v.data, jnp.where(bad, -abs(c) - 1, c))
    if isinstance(v, WDict):
        c = jnp.asarray(v.count)
        return WDict(v.keys, v.vals, jnp.where(bad, -abs(c) - 1, c))
    if isinstance(v, WGroup):
        c = jnp.asarray(v.count)
        return WGroup(v.keys, v.values, v.offsets, jnp.where(bad, -abs(c) - 1, c))
    if isinstance(v, tuple):
        return tuple(_apply_taint(x, bad) for x in v)
    return v


def _wrap_input(arr, ty: wt.WeldType):
    if isinstance(ty, wt.Vec):
        return WVec(arr)
    return arr
