"""JAX backend: lowers optimized Weld IR to fused jnp/lax programs."""
from .jaxgen import WeldCompileError, WeldMemoryError, emit_program  # noqa: F401
from .values import WVec, WDict, WGroup  # noqa: F401
