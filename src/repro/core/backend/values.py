"""Runtime value representations for the JAX backend.

XLA's static-shape world forces the one semantic adaptation documented in
DESIGN.md §2: variable-length results carry a static-capacity buffer plus a
dynamic count.  Dictionaries are (sorted-keys, vals, count) column arrays.
All classes are registered as pytrees so they flow through jax.jit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import numpy as np

from ..errors import CapacityError


@dataclass
class WVec:
    """A Weld vec[T].  `data` leading axis is the vector axis.  If `count`
    is None the vector is dense (every row valid); otherwise the first
    `count` rows are valid (front-packed) and the rest is padding."""

    data: object  # jnp array or tuple of arrays (vec of structs)
    count: Optional[object] = None  # traced scalar or None

    @property
    def is_dense(self) -> bool:
        return self.count is None

    def capacity(self) -> int:
        arr = self.data[0] if isinstance(self.data, tuple) else self.data
        return arr.shape[0]

    def length(self):
        return self.capacity() if self.count is None else self.count

    def to_numpy(self):
        """Host-side decode: slice off padding."""
        if self.count is not None and int(self.count) < 0:
            # producers flag unrepresentable inputs by negating the
            # count (same convention as WDict overflow); CapacityError
            # is the typed signal the recovery ladder retries on
            raise CapacityError(
                "kernelized producer flagged this vector as poisoned "
                "(e.g. a hash-join probe against an overflowed dict); "
                "rerun with kernelize=False or raise the builder capacity"
            )

        def cut(a):
            a = np.asarray(a)
            return a if self.count is None else a[: int(self.count)]

        if isinstance(self.data, tuple):
            return tuple(cut(a) for a in self.data)
        return cut(self.data)


@dataclass
class WDict:
    """A Weld dict[K,V]: parallel column arrays of static capacity with the
    first `count` slots valid.  Keys/vals may be tuples of arrays (struct
    keys/values, stored column-wise)."""

    keys: object
    vals: object
    count: object

    def to_numpy(self) -> dict:
        n = int(self.count)
        if n < 0:
            # group-by builds flag capacity violations by negating the
            # count (see kernelplan.registry._exec_dict_group_sum and
            # jaxgen._finalize_keyed); typed for the recovery ladder
            raise CapacityError(
                "kernelized group-by observed keys outside [0, capacity) — "
                "the dense-key kernel route cannot represent them; rerun "
                "with kernelize=False or raise the builder capacity"
            )

        def cols(x):
            return [np.asarray(a)[:n] for a in (x if isinstance(x, tuple) else (x,))]

        kcols, vcols = cols(self.keys), cols(self.vals)
        out = {}
        for i in range(n):
            k = tuple(c[i].item() for c in kcols)
            v = tuple(c[i].item() for c in vcols)
            out[k[0] if len(k) == 1 else k] = v[0] if len(v) == 1 else v
        return out


@dataclass
class WGroup:
    """groupbuilder result: dict[K, vec[V]] as sorted-values + offsets."""

    keys: object          # (cap,) or tuple of (cap,)
    values: object        # (n,) sorted by key; or tuple
    offsets: object       # (cap+1,) int32 group boundaries
    count: object         # number of distinct keys

    def to_numpy(self) -> dict:
        n = int(self.count)
        if n < 0:
            # group builds flag capacity overflow (more distinct keys
            # than the builder capacity) by negating the count,
            # mirroring the WDict convention; typed for recovery
            raise CapacityError(
                "kernelized group build observed more distinct keys than "
                "the builder capacity; rerun with kernelize=False or "
                "raise the builder capacity"
            )
        offs = np.asarray(self.offsets)
        kcols = [np.asarray(a) for a in
                 (self.keys if isinstance(self.keys, tuple) else (self.keys,))]
        vcols = [np.asarray(a) for a in
                 (self.values if isinstance(self.values, tuple) else (self.values,))]
        out = {}
        for i in range(n):
            k = tuple(c[i].item() for c in kcols)
            vs = [c[offs[i]: offs[i + 1]] for c in vcols]
            v = vs[0] if len(vs) == 1 else list(zip(*[x.tolist() for x in vs]))
            out[k[0] if len(k) == 1 else k] = (
                v.tolist() if hasattr(v, "tolist") else v
            )
    # NOTE: values within a group are in key-stable sorted order, which is
    # loop order for stable sorts — matching the reference interpreter.
        return out


def _flatten_wvec(v: WVec):
    leaves = list(v.data) if isinstance(v.data, tuple) else [v.data]
    is_tuple = isinstance(v.data, tuple)
    if v.count is None:
        return leaves, (is_tuple, len(leaves), False)
    return leaves + [v.count], (is_tuple, len(leaves), True)


def _unflatten_wvec(aux, leaves):
    is_tuple, n, has_count = aux
    data = tuple(leaves[:n]) if is_tuple else leaves[0]
    count = leaves[n] if has_count else None
    return WVec(data, count)


jax.tree_util.register_pytree_node(WVec, _flatten_wvec, _unflatten_wvec)


def _flatten_wdict(d: WDict):
    ks = list(d.keys) if isinstance(d.keys, tuple) else [d.keys]
    vs = list(d.vals) if isinstance(d.vals, tuple) else [d.vals]
    aux = (isinstance(d.keys, tuple), len(ks), isinstance(d.vals, tuple), len(vs))
    return ks + vs + [d.count], aux


def _unflatten_wdict(aux, leaves):
    kt, nk, vt, nv = aux
    keys = tuple(leaves[:nk]) if kt else leaves[0]
    vals = tuple(leaves[nk: nk + nv]) if vt else leaves[nk]
    return WDict(keys, vals, leaves[nk + nv])


jax.tree_util.register_pytree_node(WDict, _flatten_wdict, _unflatten_wdict)


def _flatten_wgroup(g: WGroup):
    ks = list(g.keys) if isinstance(g.keys, tuple) else [g.keys]
    vs = list(g.values) if isinstance(g.values, tuple) else [g.values]
    aux = (isinstance(g.keys, tuple), len(ks), isinstance(g.values, tuple), len(vs))
    return ks + vs + [g.offsets, g.count], aux


def _unflatten_wgroup(aux, leaves):
    kt, nk, vt, nv = aux
    keys = tuple(leaves[:nk]) if kt else leaves[0]
    values = tuple(leaves[nk: nk + nv]) if vt else leaves[nk]
    return WGroup(keys, values, leaves[nk + nv], leaves[nk + nv + 1])


jax.tree_util.register_pytree_node(WGroup, _flatten_wgroup, _unflatten_wgroup)
