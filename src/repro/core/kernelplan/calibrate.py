"""Ledger-calibrated cost overlay: measured medians over roofline math.

The roofline hooks in ``kernelplan.cost`` price every kernel candidate
from synthetic byte/flop constants.  Once the weldtrace cost ledger
(``core/obs/ledger.py``) has seen real traffic, those constants are the
weakest link — this module closes the ROADMAP's calibration loop by
reading the ledger's **median measured time per (kernel, dtype,
size-bucket)** and letting the cost gate substitute it for the analytic
kernel-side estimate.  The gate's ``why`` string then carries
``source=measured`` (vs ``source=roofline``), visible in
``Query.explain()``'s cost-gate decision table.

Precedence: a measured median wins over the roofline estimate iff the
ledger holds at least ``$WELD_CALIBRATE_MIN`` (default 3) records for
the exact ``(kernel, dtype, bucket)`` group — a single noisy launch
must not flip routing.  Disable entirely with ``WELD_CALIBRATE=0``.

Medians are cached in-process keyed on the ledger file's
``(mtime_ns, size)`` signature, so serving traffic that appends records
(measured replay) is picked up on the next *cold* compile without
re-parsing the JSONL on every estimate.  Note calibration state is
deliberately NOT part of the compile-cache key: a cached executable
keeps serving the plan it was compiled with (compile amortization wins
over calibration freshness); new medians take effect on the next cold
compile — ``runtime.clear_cache()`` forces the switchover.

Like :mod:`~repro.core.obs.ledger`, this module avoids the jax/kernel
stack so ``tools/cost_report.py --calibrate-dump`` can run in a bare
interpreter.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, List, Optional, Tuple

from ..obs import ledger

__all__ = [
    "enabled",
    "min_samples",
    "medians",
    "measured_ns",
    "invalidate",
]

ENV_CALIBRATE = "WELD_CALIBRATE"
ENV_MIN_SAMPLES = "WELD_CALIBRATE_MIN"
DEFAULT_MIN_SAMPLES = 3

#: (kernel, dtype, bucket) -> {"measured_ns": median, "calls": count}
Medians = Dict[Tuple[str, str, int], Dict[str, float]]

_lock = threading.Lock()
_cached: Optional[Tuple[str, Optional[Tuple[int, int]], Medians]] = None


def enabled() -> bool:
    return os.environ.get(ENV_CALIBRATE, "1").lower() not in (
        "0", "off", "false", "no"
    )


def min_samples() -> int:
    try:
        return max(1, int(os.environ.get(ENV_MIN_SAMPLES,
                                         DEFAULT_MIN_SAMPLES)))
    except ValueError:
        return DEFAULT_MIN_SAMPLES


def _sig(path: str) -> Optional[Tuple[int, int]]:
    try:
        st = os.stat(path)
        return (st.st_mtime_ns, st.st_size)
    except OSError:
        return None


def invalidate() -> None:
    """Drop the in-process medians cache (tests / explicit reload)."""
    global _cached
    with _lock:
        _cached = None


def _compute(records: List[dict]) -> Medians:
    groups: Dict[Tuple[str, str, int], List[float]] = {}
    for r in records:
        kernel = r.get("kernel")
        dtype = r.get("dtype")
        bucket = r.get("bucket")
        meas = r.get("measured_ns")
        if not kernel or not dtype or not bucket or not meas:
            continue
        groups.setdefault((str(kernel), str(dtype), int(bucket)),
                          []).append(float(meas))
    out: Medians = {}
    for key, xs in groups.items():
        xs.sort()
        m = len(xs) // 2
        med = xs[m] if len(xs) % 2 else (xs[m - 1] + xs[m]) / 2.0
        out[key] = {"measured_ns": med, "calls": len(xs)}
    return out


def medians(path: Optional[str] = None) -> Medians:
    """Median measured_ns per (kernel, dtype, bucket) — the exact table
    the cost gate consumes (all groups, including under-sampled ones;
    eligibility is applied in :func:`measured_ns`)."""
    global _cached
    p = path or ledger.ledger_path()
    sig = _sig(p)
    with _lock:
        if _cached is not None and _cached[0] == p and _cached[1] == sig:
            return _cached[2]
    if sig is None:
        table: Medians = {}
    else:
        table = _compute(ledger.read(p))
    with _lock:
        _cached = (p, sig, table)
    return table


def measured_ns(kernel: str, dtype: str, n: int,
                path: Optional[str] = None) -> Optional[Tuple[float, int]]:
    """``(median_measured_ns, calls)`` for the bucket covering ``n``,
    or None when the gate must stay on the roofline (calibration off,
    no ledger, or fewer than :func:`min_samples` records)."""
    if not enabled() or not kernel or not dtype or not n or n <= 0:
        return None
    entry = medians(path).get(
        (str(kernel), str(dtype), ledger.size_bucket(int(n))))
    if entry is None or entry["calls"] < min_samples():
        return None
    return entry["measured_ns"], entry["calls"]
