"""Empirical block-size autotuner for planned kernel calls.

The hand-picked ``BLOCK`` constants in ``repro.kernels.*`` are good
defaults for one tile regime; the best block is a function of dtype,
problem size, and platform.  On the *first encounter* of a
``(kernel, dtype, size-bucket, impl)`` key the tuner times the spec's
``tune_space`` grid on a synthetic workload of the same shape
(``spec.make_bench``), memoizes the winner in an on-disk JSON cache,
and every later compile reuses it for free.

The cache lives next to nothing volatile — default
``~/.cache/weld-repro/autotune.json``, overridable via
``$WELD_AUTOTUNE_CACHE`` — and its :func:`fingerprint` participates in
the runtime's compile-cache key, so a newly tuned plan can never be
served by a stale executable (the key changes, the program recompiles
with the tuned blocks baked in).

Timing only happens for real kernel paths (``impl`` "pallas" /
"interpret"); the pure-jnp ``"ref"`` oracle ignores block sizes, so the
tuner short-circuits to the module defaults without touching the cache.
Sizes are bucketed to the next power of two: one tuning run serves
every problem in the bucket.

``tune_plan`` is the planner-side entry: it walks a planned program and
bakes the chosen parameters into each ``KernelCall``'s static params
(where the registry adapters forward them to ``repro.kernels.ops`` and
``pretty.py`` displays them).
"""
from __future__ import annotations

import json
import os
import time
import warnings
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

from .. import ir
from .. import obs
from .. import wtypes as wt
from . import registry as reg

ENV_CACHE = "WELD_AUTOTUNE_CACHE"
ENV_DISABLE = "WELD_AUTOTUNE_DISABLE"

#: timing schedule per candidate: warmup (compile) + timed reps (min).
WARMUP = 1
REPS = 3

#: floor bucket so micro sizes don't fragment the cache.
MIN_BUCKET = 1024

_cache: Optional[Dict[str, dict]] = None  # lazily loaded from disk
_generation = 0  # bumps on every mutation (part of fingerprint)


def cache_path() -> str:
    return os.environ.get(ENV_CACHE) or os.path.join(
        os.path.expanduser("~"), ".cache", "weld-repro", "autotune.json"
    )


def _load() -> Dict[str, dict]:
    global _cache
    if _cache is None:
        path = cache_path()
        try:
            with open(path) as f:
                _cache = json.load(f)
            if not isinstance(_cache, dict):
                raise ValueError("cache root is not an object")
        except OSError:
            _cache = {}  # no cache yet: normal first run
        except ValueError as e:
            # corrupt/truncated JSON (e.g. a crashed writer before the
            # save became atomic) must not break the compile — start
            # empty and re-tune; the next _save overwrites the bad file.
            # Name the file and the parse error so the user can inspect
            # or delete it instead of silently re-tuning forever.
            warnings.warn(
                f"autotune cache {path} is corrupt ({e}); ignoring it "
                "and re-tuning from scratch — delete the file to silence "
                "this warning",
                RuntimeWarning, stacklevel=2,
            )
            _cache = {}
    return _cache


def _save() -> None:
    from .. import faults

    path = cache_path()
    tmp = f"{path}.{os.getpid()}.tmp"
    try:
        # the io.autotune_cache failpoint proves persistence really is
        # best-effort: an injected OSError must leave tuning in-process
        faults.maybe_raise("io.autotune_cache", exc=OSError)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(_cache, f, indent=1, sort_keys=True)
        os.replace(tmp, path)  # atomic: readers never see a partial file
    except OSError:
        try:
            os.remove(tmp)
        except OSError:
            pass
        # tuning still applies in-process; persistence is best-effort


def clear_cache(disk: bool = True) -> None:
    """Reset tunings (tests / after a platform change)."""
    global _cache, _generation
    _cache = {}
    _generation += 1
    if disk:
        try:
            os.remove(cache_path())
        except OSError:
            pass


def invalidate(kernel: Optional[str] = None) -> int:
    """Drop cached tunings for one kernel (or all); returns drop count."""
    global _generation
    c = _load()
    keys = [k for k in c if kernel is None or k.startswith(f"{kernel}|")]
    for k in keys:
        del c[k]
    if keys:
        _generation += 1
        _save()
    return len(keys)


def fingerprint() -> str:
    """Stable digest of the tuning state for the compile-cache key."""
    import zlib

    c = _load()
    items = ";".join(
        f"{k}={sorted(v.get('params', {}).items())}" for k, v in sorted(c.items())
    )
    return f"g{_generation}n{len(c)}h{zlib.crc32(items.encode()):08x}"


def size_bucket(n: int) -> int:
    b = MIN_BUCKET
    while b < n:
        b <<= 1
    return b


def _key(kernel: str, dtype, n: int, impl: str,
         k: Optional[int] = None, dims: Optional[tuple] = None) -> str:
    """Cache key.  K (segment width) and matmul dims shape the kernels'
    tile trade-offs as much as n does, so they are part of the key —
    a block tuned for K=256 must not be served to a K=4096 call."""
    extra = f"|k{size_bucket(int(k))}" if k else ""
    if dims:
        extra += "|d" + "x".join(str(size_bucket(int(d))) for d in dims)
    return f"{kernel}|{np.dtype(dtype).name}|{size_bucket(int(n))}{extra}|{impl}"


def _grid(space: Dict[str, tuple]) -> Iterable[Dict[str, int]]:
    names = sorted(space)
    points = [{}]
    for name in names:
        points = [dict(p, **{name: v}) for p in points for v in space[name]]
    return points


def _time_candidate(go) -> float:
    for _ in range(WARMUP):
        go()
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        go()
        best = min(best, time.perf_counter() - t0)
    return best


def lookup(kernel: str, dtype, n: int, impl: str,
           k: Optional[int] = None,
           dims: Optional[tuple] = None) -> Optional[Dict[str, int]]:
    ent = _load().get(_key(kernel, dtype, n, impl, k=k, dims=dims))
    return dict(ent["params"]) if ent else None


def tune(spec: "reg.KernelSpec", meta: dict, impl: str,
         force: bool = False) -> Tuple[Dict[str, int], bool]:
    """Resolve tuned params for one call site.

    Returns ``(params, from_cache)``.  Falls back to the spec's defaults
    (without timing or cache writes) when tuning cannot help: no tunable
    space, no bench, unknown size, the ref oracle, or tuning disabled.
    """
    global _generation
    n = meta.get("n") or 0
    k, dims = meta.get("k"), meta.get("dims")
    defaults = dict(spec.tune_defaults)
    if not spec.tune_space or n <= 0:
        return defaults, False
    if impl in (None, "ref") or os.environ.get(ENV_DISABLE):
        return defaults, False
    cached = None if force else lookup(spec.name, meta.get("dtype", "f8"),
                                       n, impl, k=k, dims=dims)
    if cached is not None:
        return cached, True
    if spec.make_bench is None:
        return defaults, False
    # time the grid on a synthetic same-bucket workload
    bench_meta = dict(meta, n=size_bucket(n))
    best_params, best_t = defaults, float("inf")
    with obs.span("autotune.tune", kernel=spec.name, n=size_bucket(n),
                  impl=impl) as tsp:
        for cand in _grid(spec.tune_space):
            try:
                from .. import faults

                faults.maybe_raise("autotune.time")
                go = spec.make_bench(bench_meta, cand, impl)
                t = _time_candidate(go)
            except Exception:
                obs.event("autotune.candidate", kernel=spec.name,
                          skipped=True, **cand)
                continue  # candidate invalid for this shape — skip
            obs.event("autotune.candidate", kernel=spec.name,
                      us=round(t * 1e6, 2), **cand)
            if t < best_t:
                best_params, best_t = cand, t
        tsp.set("best", dict(best_params))
        if best_t < float("inf"):
            tsp.set("us", round(best_t * 1e6, 2))
    c = _load()
    c[_key(spec.name, meta.get("dtype", "f8"), n, impl, k=k, dims=dims)] = {
        "params": best_params,
        "us": round(best_t * 1e6, 2) if best_t < float("inf") else None,
    }
    _generation += 1
    _save()
    return dict(best_params), False


# ---------------------------------------------------------------------------
# Plan-level entry: bake tuned params into KernelCall nodes
# ---------------------------------------------------------------------------


def _np_dtype_of(ty: wt.WeldType):
    if isinstance(ty, wt.Vec):
        return _np_dtype_of(ty.elem)
    if isinstance(ty, wt.Struct):
        return _np_dtype_of(ty.fields[0]) if ty.fields else np.float64
    if isinstance(ty, wt.DictType):
        return _np_dtype_of(ty.val)
    if isinstance(ty, wt.Scalar):
        return np.dtype(ty.np_dtype)
    return np.float64


def tune_plan(e: ir.Expr, impl: Optional[str],
              stats: Optional[dict] = None) -> ir.Expr:
    """Attach tuned (or default) block parameters to every planned
    ``KernelCall``.  Identity when the program has no kernel calls."""
    events = []

    def rec(x: ir.Expr) -> ir.Expr:
        x = x.map_children(rec)
        if not isinstance(x, ir.KernelCall):
            return x
        spec = reg.available(x.kernel)
        if spec is None or not spec.tune_space:
            return x
        params = dict(x.params)
        if any(k in params for k in spec.tune_space):
            return x  # already tuned (e.g. plan reuse)
        meta = {
            "kernel": x.kernel,
            "n": params.get("n_rows") if params.get("n_rows", -1) > 0 else None,
            "k": params.get("capacity") or params.get("k"),
            "dims": params.get("dims"),
            "dtype": _np_dtype_of(x.ret_ty),
        }
        chosen, from_cache = tune(spec, meta, impl)
        if not chosen:
            return x
        events.append({
            "kernel": x.kernel,
            "n": meta["n"],
            "params": dict(chosen),
            "cached": from_cache,
        })
        return ir.KernelCall(
            kernel=x.kernel,
            args=x.args,
            ret_ty=x.ret_ty,
            params=x.params + tuple(sorted(chosen.items())),
            fns=x.fns,
        )

    out = rec(e)
    if stats is not None and events:
        stats.setdefault("kernelplan", {})["autotune"] = events
    return out
