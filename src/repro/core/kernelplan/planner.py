"""Kernel planner: route optimized IR loops onto registered Pallas kernels.

Runs AFTER the optimizer (fusion/predication/CSE have already collapsed
library chains into single loops) and BEFORE the backend emitter.  It
pattern-matches the fused loop shapes the optimizer produces —

* ``result(for(V.., merger[+], .. merge(b, select(p, v, 0))))``  and the
  struct-of-mergers form weldrel's ``agg`` emits        → filter_reduce
* ``result(for(V.., vecmerger[+](base), merge(b, {i,v})))``
  (PageRank's edge scan)                                → segment_sum
* ``result(for([K,V], dictmerger[+](cap), merge(b,{k,v})))``
  with dense int keys                                   → segment_sum
* ``cudf[linalg.matmul] / cudf[linalg.matvec]``
  (the tiling pass raises dot loops to these)           → tiled_matmul
* ``result(for(V.., vecbuilder, merge(b, f(x))))`` with a nontrivial
  elementwise body                                      → map_elementwise
* ``result(for(V.., {vecbuilder..}, if(cond, {merge(b.$k, ..)..}, b)))``
  probing a let-bound dict (weldrel's horizontally fused join
  probe: inner/left/anti, scalar or struct keys)        → hash_probe

— and replaces each matched subtree with an ``ir.KernelCall`` node
carrying the iter sources as args and the per-element bodies as staged
lambdas.  Everything unmatched lowers exactly as before; a program with
no matches is returned unchanged (the planner is a no-op identity then).

Soundness rules (checked per match, conservative):

* every iter source must be *statically dense* — a program input, a
  let-bound map-like loop over dense sources, or a dense-producing
  kernel call — so staged bodies see unpadded columns;
* staged bodies must be elementwise-safe: no nested loops, builders,
  CUDF calls, or lookups into per-element collections (gathers from
  whole program inputs are fine);
* the planner never rewrites inside a ``for`` body — kernel calls are
  evaluation-point constructs, not loop-body ones.

Routing modes (``plan_kernels(mode=...)``):

* ``"always"`` — route every sound match (the PR-1 behavior; what
  ``kernelize=True`` requests);
* ``"auto"`` — price each match through :mod:`.cost` (roofline terms
  fed by ``Iter`` size hints and the staged bodies' op counts) and keep
  the jnp lowering when the kernel route cannot win.  Unknown sizes
  reject conservatively.  This is the process default.

Along the way the planner tracks *shapes*, not just density: the
``dense`` map carries the statically-known shape of every dense name
(program inputs from ``input_shapes``, let-bound map/scatter loops from
their iter sources), which is what prices the candidates and stamps
``n_rows`` onto emitted ``KernelCall`` nodes for the block-size
autotuner (:mod:`.autotune`).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .. import ir
from .. import obs as _obs
from .. import wtypes as wt
from ..backend.jaxgen import match_group_probe as _group_probe_shape
from . import cost as _cost
from . import registry as reg

#: minimum compute-node count for a map chain to be worth a kernel launch.
MIN_MAP_OPS = 2

#: shape map: dense name -> statically known shape tuple (or None when
#: the name is provably dense but its length is not statically known).
Shapes = Dict[str, Optional[tuple]]


# ---------------------------------------------------------------------------
# small predicates
# ---------------------------------------------------------------------------


def _is_ident(e: ir.Expr, name: str) -> bool:
    return isinstance(e, ir.Ident) and e.name == name


#: kernels whose vector result is padded (count-carrying), NOT dense.
_PADDED_RESULT_KERNELS = frozenset({"hash_probe", "group_probe"})


def _dense_expr(e: ir.Expr, dense: Shapes) -> bool:
    if isinstance(e, ir.Ident):
        return e.name in dense
    if isinstance(e, ir.KernelCall):
        return (isinstance(e.ret_ty, wt.Vec)
                and e.kernel not in _PADDED_RESULT_KERNELS)
    return False


def _iter_ok(it: ir.Iter, dense: Shapes) -> bool:
    return it.is_plain and _dense_expr(it.data, dense)


def _value_dense(e: ir.Expr, dense: Shapes) -> bool:
    """Is a let-bound value a dense vector (no padding/count)?"""
    if _dense_expr(e, dense):
        return True
    if isinstance(e, ir.CUDF):
        return isinstance(e.ret_ty, wt.Vec)
    if isinstance(e, ir.MakeVec):
        return True
    if isinstance(e, ir.Result) and isinstance(e.builder, ir.For):
        loop = e.builder
        nb = loop.builder
        if isinstance(nb, ir.NewBuilder) and isinstance(nb.ty, wt.VecMerger):
            return True
        if isinstance(nb, ir.NewBuilder) and isinstance(nb.ty, wt.VecBuilder):
            from ..passes.fusion import _merges_unconditionally_once

            pb = loop.func.params[0]
            return _merges_unconditionally_once(
                loop.func.body, pb.name
            ) and all(_iter_ok(it, dense) for it in loop.iters)
    return False


def _elementwise_ok(e: ir.Expr, banned: set, per_elem: set,
                    allow_lookup: bool = True) -> bool:
    """Can `e` be staged as a whole-column jnp evaluation of the element?"""

    def rec(x: ir.Expr) -> bool:
        if isinstance(x, (ir.For, ir.Lambda, ir.Merge, ir.NewBuilder,
                          ir.Result, ir.Iter, ir.MakeVec, ir.CUDF,
                          ir.KeyExists, ir.Len, ir.Let, ir.KernelCall)):
            return False
        if isinstance(x, ir.Ident):
            return x.name not in banned
        if isinstance(x, ir.Lookup):
            if not allow_lookup:
                return False
            if not isinstance(x.expr, ir.Ident):
                return False
            if x.expr.name in per_elem or x.expr.name in banned:
                return False
            return rec(x.index) and (x.default is None or rec(x.default))
        return all(rec(c) for c in x.children())

    return rec(e)


def _scalar_kind_ok(ty: wt.WeldType, spec: reg.KernelSpec) -> bool:
    return isinstance(ty, wt.Scalar) and ty.kind in spec.elem_kinds


def _static_cap(e: Optional[ir.Expr], dense: Shapes) -> Optional[int]:
    """Resolve a capacity / size-hint expression to a concrete int.
    Accepts anything the backend's static evaluator can resolve —
    literals AND symbolic forms over input lengths (``max(len(r), 1)``,
    ``len(l)*len(r)``) from the host-count-free join path — so kernel
    routing no longer requires a host pre-count."""
    from ..analysis.bounds import static_size

    return static_size(e, dense)


def _is_plus_identity(e: ir.Expr, elem: wt.Scalar) -> bool:
    return (
        isinstance(e, ir.Literal)
        and e.ty == elem
        and e.value == wt.merge_identity("+", elem)
    )


def _compute_ops(e: ir.Expr) -> int:
    return ir.count_nodes(
        e, lambda n: isinstance(n, (ir.BinOp, ir.UnaryOp, ir.Select, ir.Cast))
    )


def _destructure_pair(mval: ir.Expr) -> Tuple[ir.Expr, ir.Expr]:
    """Split a struct-producing merge value into its two fields."""
    if isinstance(mval, ir.MakeStruct) and len(mval.items) == 2:
        return mval.items[0], mval.items[1]
    return ir.GetField(mval, 0), ir.GetField(mval, 1)


# ---------------------------------------------------------------------------
# per-pattern matchers — each returns a KernelCall or None
# ---------------------------------------------------------------------------


def _match_filter_reduce(loop: ir.For, dense: Shapes) -> Optional[ir.KernelCall]:
    spec = reg.available("filter_reduce_sum")
    if spec is None:
        return None
    b, i, x = loop.func.params
    body = loop.func.body
    nb = loop.builder

    def merger_ok(nbx) -> bool:
        return (
            isinstance(nbx, ir.NewBuilder)
            and isinstance(nbx.ty, wt.Merger)
            and nbx.ty.op == "+"
            and nbx.arg is None
            and _scalar_kind_ok(nbx.ty.elem, spec)
        )

    vals: List[Tuple[wt.Scalar, ir.Expr]] = []
    cond: Optional[ir.Expr] = None
    struct = False

    if merger_ok(nb):
        elem = nb.ty.elem
        if isinstance(body, ir.Merge) and _is_ident(body.builder, b.name):
            v = body.value
            if isinstance(v, ir.Select) and _is_plus_identity(v.on_false, elem):
                cond, v = v.cond, v.on_true  # post-predication form
            vals.append((elem, v))
        elif (
            isinstance(body, ir.If)
            and isinstance(body.on_true, ir.Merge)
            and _is_ident(body.on_true.builder, b.name)
            and _is_ident(body.on_false, b.name)
        ):
            cond = body.cond  # pre-predication form
            vals.append((elem, body.on_true.value))
        else:
            return None
    elif isinstance(nb, ir.MakeStruct) and nb.items and all(
        merger_ok(p) for p in nb.items
    ):
        struct = True
        core = body
        if isinstance(body, ir.If):
            if not _is_ident(body.on_false, b.name):
                return None
            cond, core = body.cond, body.on_true
        if not (isinstance(core, ir.MakeStruct)
                and len(core.items) == len(nb.items)):
            return None
        for k, item in enumerate(core.items):
            if not (
                isinstance(item, ir.Merge)
                and isinstance(item.builder, ir.GetField)
                and item.builder.index == k
                and _is_ident(item.builder.expr, b.name)
            ):
                return None
            vals.append((nb.items[k].ty.elem, item.value))
    else:
        return None

    per_elem = {i.name, x.name}
    for _, v in vals:
        if not _elementwise_ok(v, {b.name}, per_elem):
            return None
    if cond is not None and not _elementwise_ok(cond, {b.name}, per_elem):
        return None

    fns = [ir.Lambda((i, x), v) for _, v in vals]
    if cond is not None:
        fns.append(ir.Lambda((i, x), cond))
    ret: wt.WeldType = (
        wt.Struct(tuple(e for e, _ in vals)) if struct else vals[0][0]
    )
    return ir.KernelCall(
        kernel=spec.name,
        args=tuple(it.data for it in loop.iters),
        ret_ty=ret,
        params=(("n_aggs", len(vals)), ("has_pred", cond is not None),
                ("struct", struct)),
        fns=tuple(fns),
    )


def _match_vecmerger(loop: ir.For, dense: Shapes) -> Optional[ir.KernelCall]:
    spec = reg.available("vecmerger_segment_sum")
    if spec is None:
        return None
    nb = loop.builder
    if not (
        isinstance(nb, ir.NewBuilder)
        and isinstance(nb.ty, wt.VecMerger)
        and nb.ty.op == "+"
        and nb.arg is not None
        and _scalar_kind_ok(nb.ty.elem, spec)
        and _value_dense(nb.arg, dense)
    ):
        return None
    b, i, x = loop.func.params
    body = loop.func.body
    if not (isinstance(body, ir.Merge) and _is_ident(body.builder, b.name)):
        return None
    idx_e, val_e = _destructure_pair(body.value)
    per_elem = {i.name, x.name}
    if not (_elementwise_ok(idx_e, {b.name}, per_elem)
            and _elementwise_ok(val_e, {b.name}, per_elem)):
        return None
    return ir.KernelCall(
        kernel=spec.name,
        args=(nb.arg,) + tuple(it.data for it in loop.iters),
        ret_ty=wt.Vec(nb.ty.elem),
        fns=(ir.Lambda((i, x), idx_e), ir.Lambda((i, x), val_e)),
    )


def _match_dict_group(loop: ir.For, dense: Shapes) -> Optional[ir.KernelCall]:
    spec = reg.available("dict_group_sum")
    if spec is None:
        return None
    nb = loop.builder
    if not (
        isinstance(nb, ir.NewBuilder)
        and isinstance(nb.ty, wt.DictMerger)
        and nb.ty.op == "+"
    ):
        return None
    kt, vt = nb.ty.key, nb.ty.val
    if not (isinstance(kt, wt.Scalar) and kt.is_int):
        return None
    if not _scalar_kind_ok(vt, spec):
        return None
    cap = _static_cap(nb.arg, dense)
    if cap is None:
        return None  # capacity must be statically resolvable
    if spec.max_segments is not None and cap > spec.max_segments:
        return None
    b, i, x = loop.func.params
    body = loop.func.body
    cond: Optional[ir.Expr] = None
    if (
        isinstance(body, ir.If)
        and isinstance(body.on_true, ir.Merge)
        and _is_ident(body.on_false, b.name)
    ):
        # filtered group-by: the predicate becomes the adapter's row mask
        cond, body = body.cond, body.on_true
    if not (isinstance(body, ir.Merge) and _is_ident(body.builder, b.name)):
        return None
    key_e, val_e = _destructure_pair(body.value)
    per_elem = {i.name, x.name}
    if not (_elementwise_ok(key_e, {b.name}, per_elem)
            and _elementwise_ok(val_e, {b.name}, per_elem)):
        return None
    if cond is not None and not _elementwise_ok(cond, {b.name}, per_elem):
        return None
    fns = [ir.Lambda((i, x), key_e), ir.Lambda((i, x), val_e)]
    if cond is not None:
        fns.append(ir.Lambda((i, x), cond))
    return ir.KernelCall(
        kernel=spec.name,
        args=tuple(it.data for it in loop.iters),
        ret_ty=wt.DictType(kt, vt),
        params=(("capacity", cap), ("key_np", str(kt.np_dtype.__name__)),
                ("has_pred", cond is not None)),
        fns=tuple(fns),
    )


def _match_hash_build(loop: ir.For, dense: Shapes) -> Optional[ir.KernelCall]:
    """Dictmerger build via the open-addressing hash route: int keys of
    ANY value (no dense [0, capacity) requirement) — scalar OR a struct
    of int columns (multi-column join keys, packed 32 bits per column
    into the shared 64-bit key space) — with scalar or struct-of-scalars
    values.  Matched for probed dicts (hash-join build side) and as the
    fallback when the dense segment route declines."""
    spec = reg.available("dict_hash_build")
    if spec is None:
        return None
    nb = loop.builder
    if not (
        isinstance(nb, ir.NewBuilder)
        and isinstance(nb.ty, wt.DictMerger)
        and nb.ty.op == "+"
    ):
        return None
    kt, vt = nb.ty.key, nb.ty.val
    key_tys = kt.fields if isinstance(kt, wt.Struct) else (kt,)
    if not all(isinstance(t, wt.Scalar) and t.is_int for t in key_tys):
        return None
    val_tys = vt.fields if isinstance(vt, wt.Struct) else (vt,)
    if not all(_scalar_kind_ok(t, spec) for t in val_tys):
        return None
    cap = _static_cap(nb.arg, dense)
    if cap is None:
        return None  # capacity must be statically resolvable
    if spec.max_segments is not None and cap > spec.max_segments:
        return None
    b, i, x = loop.func.params
    body = loop.func.body
    cond: Optional[ir.Expr] = None
    if (
        isinstance(body, ir.If)
        and isinstance(body.on_true, ir.Merge)
        and _is_ident(body.on_false, b.name)
    ):
        cond, body = body.cond, body.on_true
    if not (isinstance(body, ir.Merge) and _is_ident(body.builder, b.name)):
        return None
    key_e, val_e = _destructure_pair(body.value)
    if isinstance(kt, wt.Struct):
        if not (isinstance(key_e, ir.MakeStruct)
                and len(key_e.items) == len(key_tys)):
            return None
        key_exprs = list(key_e.items)
    else:
        key_exprs = [key_e]
    struct_val = isinstance(vt, wt.Struct)
    if struct_val:
        if not (isinstance(val_e, ir.MakeStruct)
                and len(val_e.items) == len(val_tys)):
            return None
        val_exprs = list(val_e.items)
    else:
        val_exprs = [val_e]
    per_elem = {i.name, x.name}
    for e2 in key_exprs + val_exprs:
        if not _elementwise_ok(e2, {b.name}, per_elem):
            return None
    if cond is not None and not _elementwise_ok(cond, {b.name}, per_elem):
        return None
    fns = [ir.Lambda((i, x), k) for k in key_exprs]
    fns += [ir.Lambda((i, x), v) for v in val_exprs]
    if cond is not None:
        fns.append(ir.Lambda((i, x), cond))
    return ir.KernelCall(
        kernel=spec.name,
        args=tuple(it.data for it in loop.iters),
        ret_ty=wt.DictType(kt, vt),
        params=(("capacity", cap), ("n_keys", len(key_exprs)),
                ("key_nps", tuple(
                    str(t.np_dtype.__name__) for t in key_tys)),
                ("n_vals", len(val_exprs)), ("struct_val", struct_val),
                ("has_pred", cond is not None)),
        fns=tuple(fns),
    )


def _split_probe_cond(cond: ir.Expr, dname_ok) -> Optional[Tuple[
        ir.KeyExists, Optional[ir.Expr], bool]]:
    """Split a probe loop's condition into (KeyExists(dict, k), pred?,
    negated).  Accepts `keyexists(d, k)`, its negation (anti joins), or
    a single `&&` with the (possibly negated) keyexists on either side
    (the shapes weldrel's filtered joins emit)."""

    def as_ke(e: ir.Expr):
        if isinstance(e, ir.KeyExists) and dname_ok(e.expr):
            return e, False
        if (isinstance(e, ir.UnaryOp) and e.op == "not"
                and isinstance(e.expr, ir.KeyExists)
                and dname_ok(e.expr.expr)):
            return e.expr, True
        return None

    hit = as_ke(cond)
    if hit is not None:
        return hit[0], None, hit[1]
    if isinstance(cond, ir.BinOp) and cond.op == "&&":
        for side, pred in ((cond.left, cond.right),
                           (cond.right, cond.left)):
            hit = as_ke(side)
            if hit is not None:
                return hit[0], pred, hit[1]
    return None


def _match_hash_probe(loop: ir.For, dense: Shapes) -> Optional[ir.KernelCall]:
    """Gather-style dict probe: filter rows to key matches and emit
    either a looked-up value (right/build column) or an elementwise
    expression over the probe row (left column).

        result(for(V.., vecbuilder,
                   (b,i,x) => if([p &&] keyexists(d, k),
                              merge(b, lookup(d,k)[.j] | f(x)), b)))

    The dict is a let-bound value (kernelized or generic — both arrive
    as a WDict at execution time)."""
    spec = reg.available("hash_probe")
    if spec is None:
        return None
    nb = loop.builder
    if not (
        isinstance(nb, ir.NewBuilder)
        and isinstance(nb.ty, wt.VecBuilder)
        and _scalar_kind_ok(nb.ty.elem, spec)
    ):
        return None
    b, i, x = loop.func.params
    body = loop.func.body
    if not (
        isinstance(body, ir.If)
        and isinstance(body.on_true, ir.Merge)
        and _is_ident(body.on_true.builder, b.name)
        and _is_ident(body.on_false, b.name)
    ):
        return None

    def dname_ok(e: ir.Expr) -> bool:
        return isinstance(e, ir.Ident) and isinstance(e.ty, wt.DictType)

    split = _split_probe_cond(body.cond, dname_ok)
    if split is None:
        return None
    ke, pred, negated = split
    if negated:
        return None  # anti probes arrive in the fused struct form only
    d_id = ke.expr
    key_e = ke.key
    kt = d_id.ty.key
    if not (isinstance(kt, wt.Scalar) and kt.is_int):
        return None
    per_elem = {i.name, x.name}
    banned = {b.name, d_id.name}
    if not _elementwise_ok(key_e, banned, per_elem):
        return None
    if pred is not None and not _elementwise_ok(pred, banned, per_elem):
        return None

    val = body.on_true.value
    field = -1
    gather = False
    if isinstance(val, ir.GetField) and isinstance(val.expr, ir.Lookup):
        lk, field = val.expr, val.index
        gather = True
    elif isinstance(val, ir.Lookup):
        lk = val
        gather = True
    if gather:
        if not (_is_ident(lk.expr, d_id.name)
                and ir.canon_key(lk.index) == ir.canon_key(key_e)):
            return None
        fns = [ir.Lambda((i, x), key_e)]
    else:
        if not _elementwise_ok(val, banned, per_elem):
            return None
        fns = [ir.Lambda((i, x), key_e), ir.Lambda((i, x), val)]
    if pred is not None:
        fns.append(ir.Lambda((i, x), pred))
    return ir.KernelCall(
        kernel=spec.name,
        args=(d_id,) + tuple(it.data for it in loop.iters),
        ret_ty=wt.Vec(nb.ty.elem),
        params=(("gather", gather), ("field", field),
                ("has_pred", pred is not None)),
        fns=tuple(fns),
    )


def _match_hash_probe_fused(loop: ir.For,
                            dense: Shapes) -> Optional[ir.KernelCall]:
    """Horizontally fused join probe: ONE loop merging every output
    column into a struct of vecbuilders (the form weldrel's join emits),
    so an N-column join takes ONE ``hash_probe`` launch instead of N.

        result(for(V.., {vecbuilder..},
               (b,i,x) => if(cond, {merge(b.$k, v_k)..}, b)))

    ``cond``/values encode the join flavor:

    * inner — cond carries ``keyexists(d, k)``; right columns gather
      ``lookup(d, k)[.j]``;
    * left  — no keyexists in cond (an optional elementwise predicate
      only); right columns gather ``lookup(d, k, fill)[.j]`` — the
      single-probe miss form;
    * anti  — cond carries ``not(keyexists(d, k))``; left columns only.

    Keys may be scalar ints or a struct of int columns (packed in the
    adapter exactly like the dict build side)."""
    spec = reg.available("hash_probe")
    if spec is None:
        return None
    nb = loop.builder
    if not (isinstance(nb, ir.MakeStruct) and nb.items and all(
            isinstance(p, ir.NewBuilder) and isinstance(p.ty, wt.VecBuilder)
            and _scalar_kind_ok(p.ty.elem, spec) for p in nb.items)):
        return None
    b, i, x = loop.func.params
    body = loop.func.body
    cond: Optional[ir.Expr] = None
    if isinstance(body, ir.If):
        if not _is_ident(body.on_false, b.name):
            return None
        cond, body = body.cond, body.on_true
    if not (isinstance(body, ir.MakeStruct)
            and len(body.items) == len(nb.items)):
        return None
    vals: List[ir.Expr] = []
    for k, item in enumerate(body.items):
        if not (
            isinstance(item, ir.Merge)
            and isinstance(item.builder, ir.GetField)
            and item.builder.index == k
            and _is_ident(item.builder.expr, b.name)
        ):
            return None
        vals.append(item.value)

    def dname_ok(e: ir.Expr) -> bool:
        return isinstance(e, ir.Ident) and isinstance(e.ty, wt.DictType)

    d_id: Optional[ir.Ident] = None
    key_e: Optional[ir.Expr] = None
    pred: Optional[ir.Expr] = None
    if cond is not None:
        split = _split_probe_cond(cond, dname_ok)
        if split is not None:
            ke, pred, negated = split
            d_id, key_e = ke.expr, ke.key
            how = "anti" if negated else "inner"
        else:
            pred, how = cond, "left"
    else:
        how = "left"

    # classify output columns; left joins discover the dict/key from the
    # gathers (their condition carries no keyexists)
    cols: List[Tuple[str, int]] = []
    fills: List[object] = []
    exprs: List[ir.Expr] = []
    for v in vals:
        lk, fld = v, -1
        if isinstance(lk, ir.GetField) and isinstance(lk.expr, ir.Lookup):
            lk, fld = lk.expr, lk.index
        if isinstance(lk, ir.Lookup) and dname_ok(lk.expr):
            if how == "anti":
                return None  # anti joins carry no build-side columns
            if d_id is None:
                d_id, key_e = lk.expr, lk.index
            if not (_is_ident(lk.expr, d_id.name)
                    and ir.canon_key(lk.index) == ir.canon_key(key_e)):
                return None  # every gather must share ONE dict + key
            if how == "left":
                dflt = lk.default
                if dflt is None:
                    return None
                f = dflt.items[fld] if isinstance(dflt, ir.MakeStruct) \
                    else dflt
                if not isinstance(f, ir.Literal):
                    return None
                fills.append(f.value)
            else:
                if lk.default is not None:
                    return None
                fills.append(None)
            cols.append(("gather", fld))
        else:
            cols.append(("expr", len(exprs)))
            exprs.append(v)
            fills.append(None)
    if d_id is None:
        return None  # no dict anywhere: a plain filter, not a probe
    kt = d_id.ty.key
    if isinstance(kt, wt.Struct):
        if not all(isinstance(f, wt.Scalar) and f.is_int
                   for f in kt.fields):
            return None
        if not (isinstance(key_e, ir.MakeStruct)
                and len(key_e.items) == len(kt.fields)):
            return None
        key_parts = list(key_e.items)
    elif isinstance(kt, wt.Scalar) and kt.is_int:
        key_parts = [key_e]
    else:
        return None
    per_elem = {i.name, x.name}
    banned = {b.name, d_id.name}
    for e2 in key_parts + exprs:
        if not _elementwise_ok(e2, banned, per_elem):
            return None
    if pred is not None and not _elementwise_ok(pred, banned, per_elem):
        return None
    fns = [ir.Lambda((i, x), p) for p in key_parts]
    fns += [ir.Lambda((i, x), v) for v in exprs]
    if pred is not None:
        fns.append(ir.Lambda((i, x), pred))
    return ir.KernelCall(
        kernel=spec.name,
        args=(d_id,) + tuple(it.data for it in loop.iters),
        ret_ty=wt.Struct(tuple(wt.Vec(p.ty.elem) for p in nb.items)),
        params=(("how", how), ("n_keys", len(key_parts)),
                ("cols", tuple(cols)), ("fills", tuple(fills)),
                ("has_pred", pred is not None)),
        fns=tuple(fns),
    )


def _match_group_build(loop: ir.For, dense: Shapes) -> Optional[ir.KernelCall]:
    """Groupbuilder build (key -> growing vector of row payloads) via the
    hash route: hash-to-slot + CSR slot-histogram compaction — the m:n
    hash-join build side.  Keys are scalar ints or a struct of int
    columns (packed like the dictmerger hash build); the payload is one
    scalar (the join stores the build-row index)."""
    spec = reg.available("group_build")
    if spec is None:
        return None
    nb = loop.builder
    if not (
        isinstance(nb, ir.NewBuilder)
        and isinstance(nb.ty, wt.GroupBuilder)
    ):
        return None
    kt, vt = nb.ty.key, nb.ty.val
    key_tys = kt.fields if isinstance(kt, wt.Struct) else (kt,)
    if not all(isinstance(t, wt.Scalar) and t.is_int for t in key_tys):
        return None
    if not _scalar_kind_ok(vt, spec):
        return None
    cap = _static_cap(nb.arg, dense)
    if cap is None:
        return None  # capacity must be statically resolvable
    if spec.max_segments is not None and cap > spec.max_segments:
        return None
    b, i, x = loop.func.params
    body = loop.func.body
    cond: Optional[ir.Expr] = None
    if (
        isinstance(body, ir.If)
        and isinstance(body.on_true, ir.Merge)
        and _is_ident(body.on_false, b.name)
    ):
        cond, body = body.cond, body.on_true
    if not (isinstance(body, ir.Merge) and _is_ident(body.builder, b.name)):
        return None
    key_e, val_e = _destructure_pair(body.value)
    if isinstance(kt, wt.Struct):
        if not (isinstance(key_e, ir.MakeStruct)
                and len(key_e.items) == len(key_tys)):
            return None
        key_exprs = list(key_e.items)
    else:
        key_exprs = [key_e]
    per_elem = {i.name, x.name}
    for e2 in key_exprs + [val_e]:
        if not _elementwise_ok(e2, {b.name}, per_elem):
            return None
    if cond is not None and not _elementwise_ok(cond, {b.name}, per_elem):
        return None
    fns = [ir.Lambda((i, x), k) for k in key_exprs]
    fns.append(ir.Lambda((i, x), val_e))
    if cond is not None:
        fns.append(ir.Lambda((i, x), cond))
    return ir.KernelCall(
        kernel=spec.name,
        args=tuple(it.data for it in loop.iters),
        ret_ty=wt.DictType(kt, wt.Vec(vt)),
        params=(("capacity", cap), ("n_keys", len(key_exprs)),
                ("key_nps", tuple(
                    str(t.np_dtype.__name__) for t in key_tys)),
                ("has_pred", cond is not None)),
        fns=tuple(fns),
    )


def _match_group_probe(loop: ir.For,
                       dense: Shapes) -> Optional[ir.KernelCall]:
    """The m:n join fan-out probe: the canonical variable-length
    expansion loop (see jaxgen ``match_group_probe`` for the exact
    shape) routed as ONE ``group_probe`` launch — membership and the
    per-row match-count pass fused into a single one-hot kernel, with
    every output column sharing the expansion index the adapter builds
    from it.  The static output capacity comes from the vecbuilders'
    size hints (weldrel stamps the exact unfiltered expansion size)."""
    spec = reg.available("group_probe")
    if spec is None:
        return None
    shape = _group_probe_shape(loop)
    if shape is None:
        return None
    if not all(p.ty.elem.kind in spec.elem_kinds for p in shape.builders):
        return None
    out_cap = _static_cap(shape.builders[0].size_hint, dense)
    if out_cap is None:
        return None  # output capacity must be static to size the buffers
    kt = shape.d.ty.key
    key_tys = kt.fields if isinstance(kt, wt.Struct) else (kt,)
    if not all(isinstance(t, wt.Scalar) and t.is_int for t in key_tys):
        return None
    if len(shape.key_parts) != len(key_tys):
        return None
    b, i, x = loop.func.params
    per_elem = {i.name, x.name}
    banned = {b.name, shape.d.name}
    for e2 in shape.key_parts:
        if not _elementwise_ok(e2, banned, per_elem):
            return None
    if shape.pred is not None and not _elementwise_ok(
            shape.pred, banned, per_elem):
        return None
    args: List[ir.Expr] = [shape.d] + [it.data for it in loop.iters]
    cols: List[Tuple[str, int]] = []
    exprs: List[ir.Expr] = []
    fills: List[object] = []
    for (kind, payload), fl in zip(shape.cols, shape.fills):
        if kind == "gather":
            # build columns are gathered outside the kernel: they must
            # be dense program inputs the adapter can index directly
            if not (isinstance(payload, ir.Ident)
                    and payload.name in dense):
                return None
            cols.append(("gather", len(args)))
            args.append(payload)
            fills.append(None if fl is None else fl.value)
        else:
            if not _elementwise_ok(payload, banned, per_elem):
                return None
            cols.append(("expr", len(exprs)))
            exprs.append(payload)
            fills.append(None)
    fns = [ir.Lambda((i, x), p) for p in shape.key_parts]
    fns += [ir.Lambda((i, x), v) for v in exprs]
    if shape.pred is not None:
        fns.append(ir.Lambda((i, x), shape.pred))
    return ir.KernelCall(
        kernel=spec.name,
        args=tuple(args),
        ret_ty=wt.Struct(tuple(wt.Vec(p.ty.elem) for p in shape.builders)),
        params=(("how", shape.how), ("n_keys", len(shape.key_parts)),
                ("n_iters", len(loop.iters)), ("cols", tuple(cols)),
                ("fills", tuple(fills)), ("out_cap", out_cap),
                ("has_pred", shape.pred is not None)),
        fns=tuple(fns),
    )


def _match_map_chain(loop: ir.For, dense: Shapes) -> Optional[ir.KernelCall]:
    spec = reg.available("map_elementwise")
    if spec is None:
        return None
    nb = loop.builder
    if not (
        isinstance(nb, ir.NewBuilder)
        and isinstance(nb.ty, wt.VecBuilder)
        and _scalar_kind_ok(nb.ty.elem, spec)
    ):
        return None
    b, i, x = loop.func.params
    body = loop.func.body
    if not (isinstance(body, ir.Merge) and _is_ident(body.builder, b.name)):
        return None
    val = body.value
    per_elem = {i.name, x.name}
    # the staged body runs INSIDE the Pallas kernel: gathers into whole
    # arrays (Lookup) and the loop index are unavailable there.
    if not _elementwise_ok(val, {b.name}, per_elem, allow_lookup=False):
        return None
    if i.name in ir.free_vars(val):
        return None
    if _compute_ops(val) < MIN_MAP_OPS:
        return None  # trivial map: XLA handles it; not worth a launch
    return ir.KernelCall(
        kernel=spec.name,
        args=tuple(it.data for it in loop.iters),
        ret_ty=wt.Vec(nb.ty.elem),
        fns=(ir.Lambda((i, x), val),),
    )


def _match_loop(e: ir.Result, dense: Shapes,
                probed: bool = False) -> Optional[ir.KernelCall]:
    loop = e.builder
    if not isinstance(loop, ir.For) or not loop.iters:
        return None
    if not all(_iter_ok(it, dense) for it in loop.iters):
        return None
    if len(loop.func.params) != 3:
        return None
    nb = loop.builder
    if isinstance(nb, ir.NewBuilder):
        if isinstance(nb.ty, wt.Merger):
            return _match_filter_reduce(loop, dense)
        if isinstance(nb.ty, wt.VecMerger):
            return _match_vecmerger(loop, dense)
        if isinstance(nb.ty, wt.DictMerger):
            if probed:
                # a probed dict (join build side) must preserve exact
                # keys: only the hash route is sound, never the dense
                # [0, capacity) segment route
                return _match_hash_build(loop, dense)
            return (_match_dict_group(loop, dense)
                    or _match_hash_build(loop, dense))
        if isinstance(nb.ty, wt.GroupBuilder):
            # group builds are only routed when probed (the m:n join
            # build side); a standalone groupbuilder result decodes on
            # the host and keeps the generic keyed finalize
            return _match_group_build(loop, dense) if probed else None
        if isinstance(nb.ty, wt.VecBuilder):
            return (_match_map_chain(loop, dense)
                    or _match_hash_probe(loop, dense))
    if isinstance(nb, ir.MakeStruct):
        return (_match_filter_reduce(loop, dense)
                or _match_hash_probe_fused(loop, dense)
                or _match_group_probe(loop, dense))
    return None


def _match_cudf(e: ir.CUDF) -> Optional[ir.KernelCall]:
    name = {"linalg.matmul": "matmul", "linalg.matvec": "matvec"}.get(e.name)
    if name is None:
        return None
    spec = reg.available(name)
    if spec is None:
        return None
    for a in e.args:
        try:
            ty = ir.typeof(a)
        except Exception:
            return None
        base = ty
        while isinstance(base, wt.Vec):
            base = base.elem
        if not _scalar_kind_ok(base, spec):
            return None
    return ir.KernelCall(kernel=name, args=e.args, ret_ty=e.ret_ty)


# ---------------------------------------------------------------------------
# static shape inference (feeds the cost model and the autotuner)
# ---------------------------------------------------------------------------


def _shape_of(e: ir.Expr, dense: Shapes) -> Optional[tuple]:
    """Statically-known shape of a dense expression, if any."""
    if isinstance(e, ir.Ident):
        return dense.get(e.name)
    if isinstance(e, ir.MakeVec):
        return (len(e.items),)
    if isinstance(e, ir.KernelCall):
        if e.kernel in ("vecmerger_segment_sum",):
            return _shape_of(e.args[0], dense)
        if e.kernel in ("map_elementwise",):
            return _shape_of(e.args[0], dense)
        if e.kernel == "matmul":
            a = _shape_of(e.args[0], dense)
            b = _shape_of(e.args[1], dense)
            if a and b and len(a) == 2 and len(b) == 2:
                return (a[0], b[1])
            return None
        if e.kernel == "matvec":
            a = _shape_of(e.args[0], dense)
            return (a[0],) if a else None
        return None
    if isinstance(e, ir.Result) and isinstance(e.builder, ir.For):
        loop = e.builder
        nb = loop.builder
        if isinstance(nb, ir.NewBuilder) and isinstance(nb.ty, wt.VecMerger):
            return _shape_of(nb.arg, dense)
        if loop.iters:  # map-like: output length == iter length
            src = _shape_of(loop.iters[0].data, dense)
            return (src[0],) if src else None
    return None


def _len_of(e: ir.Expr, dense: Shapes) -> Optional[int]:
    shp = _shape_of(e, dense)
    return int(shp[0]) if shp else None


_elem_bytes = wt.elem_bytes  # shared with jaxgen's memory accounting


def _min_block(spec: reg.KernelSpec, key: str) -> Optional[int]:
    """Best-case (smallest) tunable block: the padding the autotuner can
    shrink the kernel route down to, which is what the gate should price."""
    space = getattr(spec, "tune_space", None) or {}
    cands = space.get(key)
    return min(cands) if cands else None


def _call_meta(kc: ir.KernelCall, dense: Shapes,
               dict_caps: Optional[Dict[str, int]] = None) -> dict:
    """Static description of a matched call for cost.py / autotune.py."""
    spec = reg.available(kc.kernel)
    params = dict(kc.params)
    meta: dict = {"kernel": kc.kernel}
    if kc.kernel == "filter_reduce_sum":
        meta["n"] = next(
            (v for v in (_len_of(a, dense) for a in kc.args) if v), None
        )
        meta["cols"] = len(kc.args)
        meta["n_aggs"] = params.get("n_aggs", 1)
        meta["ops"] = sum(_compute_ops(f.body) for f in kc.fns) or 1
        meta["elem_bytes"] = _elem_bytes(kc.ret_ty)
    elif kc.kernel == "vecmerger_segment_sum":
        meta["n"] = next(
            (v for v in (_len_of(a, dense) for a in kc.args[1:]) if v), None
        )
        meta["k"] = _len_of(kc.args[0], dense)
        meta["elem_bytes"] = _elem_bytes(kc.ret_ty)
        meta["max_k"] = spec.max_segments if spec else None
    elif kc.kernel == "dict_group_sum":
        meta["n"] = next(
            (v for v in (_len_of(a, dense) for a in kc.args) if v), None
        )
        meta["k"] = params.get("capacity")
        meta["elem_bytes"] = _elem_bytes(kc.ret_ty)
    elif kc.kernel == "dict_hash_build":
        meta["n"] = next(
            (v for v in (_len_of(a, dense) for a in kc.args) if v), None
        )
        meta["k"] = params.get("capacity")
        meta["n_vals"] = params.get("n_vals", 1)
        meta["n_keys"] = params.get("n_keys", 1)
        meta["elem_bytes"] = _elem_bytes(kc.ret_ty)
    elif kc.kernel == "hash_probe":
        meta["n"] = next(
            (v for v in (_len_of(a, dense) for a in kc.args[1:]) if v), None
        )
        d = kc.args[0]
        meta["k"] = (dict_caps or {}).get(
            d.name if isinstance(d, ir.Ident) else "")
        # fused probes carry every output column through ONE launch; the
        # cost model prices the shared membership tile against them all
        meta["cols"] = max(len(params.get("cols", ())), 1)
        meta["elem_bytes"] = _elem_bytes(kc.ret_ty)
    elif kc.kernel == "group_build":
        meta["n"] = next(
            (v for v in (_len_of(a, dense) for a in kc.args) if v), None
        )
        meta["k"] = params.get("capacity")
        meta["n_keys"] = params.get("n_keys", 1)
        meta["elem_bytes"] = _elem_bytes(kc.ret_ty)
    elif kc.kernel == "group_probe":
        n_iters = params.get("n_iters", 1)
        meta["n"] = next(
            (v for v in (_len_of(a, dense)
                         for a in kc.args[1:1 + n_iters]) if v), None
        )
        d = kc.args[0]
        meta["k"] = (dict_caps or {}).get(
            d.name if isinstance(d, ir.Ident) else "")
        meta["cols"] = max(len(params.get("cols", ())), 1)
        # the expansion factor: both routes pay the repeated/gathered
        # output traffic, priced off the static expansion capacity
        meta["out"] = params.get("out_cap")
        meta["elem_bytes"] = _elem_bytes(kc.ret_ty)
    elif kc.kernel in ("matmul", "matvec"):
        a = _shape_of(kc.args[0], dense)
        b = _shape_of(kc.args[1], dense)
        if a and len(a) == 2:
            if kc.kernel == "matvec":
                # rhs is a vector: the output column count is 1 by shape
                meta["dims"] = (a[0], a[1], 1)
                meta["n"] = a[0]
            elif b and len(b) == 2:
                meta["dims"] = (a[0], a[1], b[1])
                meta["n"] = a[0]
            # else: rhs shape unknown — leave dims unset so the cost
            # model rejects conservatively instead of pricing a guess
        meta["elem_bytes"] = _elem_bytes(kc.ret_ty)
        for key in ("bm", "bn", "bk"):
            blk = _min_block(spec, key) if spec else None
            if blk:
                meta[key] = blk
    elif kc.kernel == "map_elementwise":
        meta["n"] = next(
            (v for v in (_len_of(a, dense) for a in kc.args) if v), None
        )
        meta["cols"] = len(kc.args)
        meta["ops"] = sum(_compute_ops(f.body) for f in kc.fns) or 1
        meta["elem_bytes"] = _elem_bytes(kc.ret_ty)
    if spec is not None and "block" not in meta:
        blk = _min_block(spec, "block")
        if blk:
            meta["block"] = blk
    # the call's ledger/calibration identity — the same dtype formula the
    # measured-replay recorder uses, so cost.estimate can match medians
    try:
        import numpy as _np

        from .autotune import _np_dtype_of

        meta["dtype"] = str(_np.dtype(_np_dtype_of(kc.ret_ty)))
    except Exception:
        pass
    return meta


# ---------------------------------------------------------------------------
# the pass
# ---------------------------------------------------------------------------


def plan_kernels(
    e: ir.Expr,
    input_shapes: Optional[Dict[str, tuple]] = None,
    stats: Optional[Dict[str, int]] = None,
    mode: str = "always",
    impl: Optional[str] = None,
) -> ir.Expr:
    """Annotate matched loops with KernelCall nodes.  Identity on programs
    with no matches; never rewrites inside ``for`` bodies.

    ``mode="always"`` routes every sound match; ``mode="auto"`` prices
    each candidate through the roofline cost model and keeps the jnp
    lowering when the kernel route loses.  Decisions (with both cost
    estimates) are recorded under ``stats["kernelplan"]``.
    """
    if mode not in ("always", "auto"):
        raise ValueError(f"plan_kernels mode must be always/auto, got {mode!r}")
    stats = stats if stats is not None else {}
    stats.setdefault("kernelize.matched", 0)
    kplan = stats.setdefault(
        "kernelplan",
        {"mode": mode, "routed": {}, "rejected": {}, "costs": []},
    )
    dense: Shapes = {
        k: tuple(v) if v is not None else None
        for k, v in (input_shapes or {}).items()
    }
    #: let-bound dict values (kernelized or generic) -> static capacity,
    #: which prices and autotunes the probe side of a hash join.
    dict_caps: Dict[str, int] = {}
    #: weldbound [lo, hi] row intervals for let-bound intermediates
    #: whose exact length is unknown — the roofline model prices those
    #: candidates at the interval midpoint instead of bailing
    nbounds: Dict[str, Tuple[int, Optional[int]]] = {}
    try:
        from ..analysis import bounds as _bounds

        if _bounds.enabled():
            nbounds = _bounds.analyze(e).name_bounds(input_shapes)
    except Exception:
        nbounds = {}

    def _quarantined(kc: ir.KernelCall, meta: dict) -> bool:
        from . import quarantine
        from .autotune import _np_dtype_of

        return quarantine.is_quarantined(
            kc.kernel, impl=impl, dtype=_np_dtype_of(kc.ret_ty),
            n=meta.get("n"),
        )

    def consider(kc: ir.KernelCall, orig: ir.Expr) -> ir.Expr:
        meta = _call_meta(kc, dense, dict_caps)
        if meta.get("n") is None and nbounds:
            mid = _midpoint_n(kc, nbounds)
            if mid is not None:
                meta["n"] = mid
                kplan["midpoint_priced"] = (
                    kplan.get("midpoint_priced", 0) + 1)
        if _quarantined(kc, meta):
            # a route that failed to stage/compile before is rejected up
            # front (even under "always") — re-paying a known failure
            # would just bounce off the recovery fallback again
            kplan["rejected"][kc.kernel] = (
                kplan["rejected"].get(kc.kernel, 0) + 1
            )
            kplan["costs"].append({
                "kernel": kc.kernel, "routed": False,
                "why": "quarantined", "kernel_us": 0.0, "jnp_us": 0.0,
            })
            _obs.event("kernelplan.candidate", kernel=kc.kernel,
                       n=meta.get("n"), routed=False, why="quarantined")
            return orig
        if kc.kernel in ("hash_probe", "group_probe"):
            # the one-hot tile is block x capacity: an unknown or
            # oversized dict cannot take the kernel even under "always"
            spec = reg.available(kc.kernel)
            k = meta.get("k")
            if k is None or (spec is not None
                             and spec.max_segments is not None
                             and k > spec.max_segments):
                return orig
        if mode == "auto":
            est = _cost.estimate(reg.get(kc.kernel), meta)
            kplan["costs"].append({"kernel": kc.kernel, **est.as_stats()})
            _obs.event("kernelplan.candidate", kernel=kc.kernel,
                       n=meta.get("n"), **est.as_stats())
            if not est.routed:
                kplan["rejected"][kc.kernel] = (
                    kplan["rejected"].get(kc.kernel, 0) + 1
                )
                return orig
        else:
            # "always" routes unconditionally, but the roofline price is
            # still worth stamping for the ledger — best-effort
            try:
                est = _cost.estimate(reg.get(kc.kernel), meta)
            except Exception:
                est = _cost.REJECT_UNKNOWN
            _obs.event("kernelplan.candidate", kernel=kc.kernel,
                       n=meta.get("n"), routed=True, why="mode=always")
        kplan["routed"][kc.kernel] = kplan["routed"].get(kc.kernel, 0) + 1
        stats["kernelize.matched"] += 1
        key = f"kernelize.{kc.kernel}"
        stats[key] = stats.get(key, 0) + 1
        n = meta.get("n")
        extra: Tuple[Tuple[str, object], ...] = (
            ("n_rows", int(n) if n else -1),
        )
        if est.kernel_s and est.kernel_s != float("inf"):
            # the roofline prediction rides along in the plan so the
            # measured replay / cost ledger can compare against it
            extra += (("predicted_ns", int(est.kernel_s * 1e9)),)
        if meta.get("dims"):
            extra += (("dims", tuple(int(d) for d in meta["dims"])),)
        if meta.get("k") and "capacity" not in dict(kc.params):
            # segment width for the autotuner (dict routes carry it as
            # "capacity" already; vecmerger needs it stamped explicitly)
            extra += (("k", int(meta["k"])),)
        return ir.KernelCall(
            kernel=kc.kernel,
            args=kc.args,
            ret_ty=kc.ret_ty,
            params=kc.params + extra,
            fns=kc.fns,
        )

    def rec_let_value(v: ir.Expr, probed: bool) -> ir.Expr:
        """Plan a let-bound value.  A dict build whose result is probed
        downstream (Lookup/KeyExists — the hash-join build side) may
        ONLY take the hash route: the dense segment route would poison
        sparse keys the generic lowering handles fine."""
        if probed and isinstance(v, ir.Result) \
                and isinstance(v.builder, ir.For) \
                and isinstance(v.builder.builder, ir.NewBuilder) \
                and isinstance(v.builder.builder.ty,
                               (wt.DictMerger, wt.GroupBuilder)):
            v2 = v.map_children(rec)  # plan nested subtrees only
            kc = _match_loop(v2, dense, probed=True)
            if kc is not None:
                return consider(kc, v2)
            return v2
        return rec(v)

    def rec(x: ir.Expr) -> ir.Expr:
        if isinstance(x, ir.Lambda):
            return x  # loop bodies are off-limits
        if isinstance(x, ir.Let):
            v = rec_let_value(x.value, _probed_as_dict(x.name, x.body))
            if _value_dense(v, dense):
                dense[x.name] = _shape_of(v, dense)
            cap = _dict_cap_of(v, dense)
            if cap is not None:
                dict_caps[x.name] = cap
            return ir.Let(x.name, v, rec(x.body))
        x = x.map_children(rec)
        if isinstance(x, ir.Result):
            kc = _match_loop(x, dense)
            if kc is not None:
                return consider(kc, x)
        if isinstance(x, ir.CUDF):
            kc = _match_cudf(x)
            if kc is not None:
                return consider(kc, x)
        return x

    planned = rec(e)
    # self-verify the planned program: a bad rewrite here (stale ident
    # type, unregistered kernel, capacity mismatch between build and
    # probe) would otherwise only surface as a cryptic staging error
    from .. import check

    check.checkpoint("kernelplan", planned, stats=stats,
                     shapes=input_shapes)
    return planned


def _midpoint_n(kc: ir.KernelCall,
                nbounds: Dict[str, Tuple[int, Optional[int]]]
                ) -> Optional[int]:
    """Midpoint of the derived [lo, hi] length interval of the call's
    driving argument — only consulted when the exact length is unknown,
    and only when the interval's upper bound is finite."""
    if kc.kernel in ("matmul", "matvec"):
        return None  # dims-driven: a guessed n would misprice the MXU
    args = (kc.args[1:] if kc.kernel in ("hash_probe", "group_probe")
            else kc.args)
    for a in args:
        if isinstance(a, ir.Ident) and a.name in nbounds:
            lo, hi = nbounds[a.name]
            if hi is None:
                continue
            return max(1, (int(lo) + int(hi) + 1) // 2)
    return None


def _probed_as_dict(name: str, body: ir.Expr) -> bool:
    """Does `body` consume `name` through dict probes (Lookup/KeyExists/
    GroupLookup)?"""
    return any(
        isinstance(n, (ir.Lookup, ir.KeyExists, ir.GroupLookup))
        and _is_ident(n.expr, name)
        for n in ir.walk(body)
    )


def _dict_cap_of(v: ir.Expr, dense: Shapes) -> Optional[int]:
    """Static capacity of a let-bound dict value, kernelized or not.
    Symbolic capacities (the host-count-free join path) resolve against
    the bound input shapes like any other static size."""
    if isinstance(v, ir.KernelCall) and v.kernel in (
            "dict_group_sum", "dict_hash_build", "group_build"):
        cap = dict(v.params).get("capacity")
        return int(cap) if cap is not None else None
    if isinstance(v, ir.Result) and isinstance(v.builder, ir.For):
        nb = v.builder.builder
        if isinstance(nb, ir.NewBuilder) \
                and isinstance(nb.ty, (wt.DictMerger, wt.GroupBuilder)):
            return _static_cap(nb.arg, dense)
    return None
