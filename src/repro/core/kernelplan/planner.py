"""Kernel planner: route optimized IR loops onto registered Pallas kernels.

Runs AFTER the optimizer (fusion/predication/CSE have already collapsed
library chains into single loops) and BEFORE the backend emitter.  It
pattern-matches the fused loop shapes the optimizer produces —

* ``result(for(V.., merger[+], .. merge(b, select(p, v, 0))))``  and the
  struct-of-mergers form weldrel's ``agg`` emits        → filter_reduce
* ``result(for(V.., vecmerger[+](base), merge(b, {i,v})))``
  (PageRank's edge scan)                                → segment_sum
* ``result(for([K,V], dictmerger[+](cap), merge(b,{k,v})))``
  with dense int keys                                   → segment_sum
* ``cudf[linalg.matmul] / cudf[linalg.matvec]``
  (the tiling pass raises dot loops to these)           → tiled_matmul
* ``result(for(V.., vecbuilder, merge(b, f(x))))`` with a nontrivial
  elementwise body                                      → map_elementwise

— and replaces each matched subtree with an ``ir.KernelCall`` node
carrying the iter sources as args and the per-element bodies as staged
lambdas.  Everything unmatched lowers exactly as before; a program with
no matches is returned unchanged (the planner is a no-op identity then).

Soundness rules (checked per match, conservative):

* every iter source must be *statically dense* — a program input, a
  let-bound map-like loop over dense sources, or a dense-producing
  kernel call — so staged bodies see unpadded columns;
* staged bodies must be elementwise-safe: no nested loops, builders,
  CUDF calls, or lookups into per-element collections (gathers from
  whole program inputs are fine);
* the planner never rewrites inside a ``for`` body — kernel calls are
  evaluation-point constructs, not loop-body ones.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

from .. import ir
from .. import wtypes as wt
from . import registry as reg

#: minimum compute-node count for a map chain to be worth a kernel launch.
MIN_MAP_OPS = 2


# ---------------------------------------------------------------------------
# small predicates
# ---------------------------------------------------------------------------


def _is_ident(e: ir.Expr, name: str) -> bool:
    return isinstance(e, ir.Ident) and e.name == name


def _dense_expr(e: ir.Expr, dense: Set[str]) -> bool:
    if isinstance(e, ir.Ident):
        return e.name in dense
    if isinstance(e, ir.KernelCall):
        return isinstance(e.ret_ty, wt.Vec)
    return False


def _iter_ok(it: ir.Iter, dense: Set[str]) -> bool:
    return it.is_plain and _dense_expr(it.data, dense)


def _value_dense(e: ir.Expr, dense: Set[str]) -> bool:
    """Is a let-bound value a dense vector (no padding/count)?"""
    if _dense_expr(e, dense):
        return True
    if isinstance(e, ir.CUDF):
        return isinstance(e.ret_ty, wt.Vec)
    if isinstance(e, ir.MakeVec):
        return True
    if isinstance(e, ir.Result) and isinstance(e.builder, ir.For):
        loop = e.builder
        nb = loop.builder
        if isinstance(nb, ir.NewBuilder) and isinstance(nb.ty, wt.VecMerger):
            return True
        if isinstance(nb, ir.NewBuilder) and isinstance(nb.ty, wt.VecBuilder):
            from ..passes.fusion import _merges_unconditionally_once

            pb = loop.func.params[0]
            return _merges_unconditionally_once(
                loop.func.body, pb.name
            ) and all(_iter_ok(it, dense) for it in loop.iters)
    return False


def _elementwise_ok(e: ir.Expr, banned: Set[str], per_elem: Set[str],
                    allow_lookup: bool = True) -> bool:
    """Can `e` be staged as a whole-column jnp evaluation of the element?"""

    def rec(x: ir.Expr) -> bool:
        if isinstance(x, (ir.For, ir.Lambda, ir.Merge, ir.NewBuilder,
                          ir.Result, ir.Iter, ir.MakeVec, ir.CUDF,
                          ir.KeyExists, ir.Len, ir.Let, ir.KernelCall)):
            return False
        if isinstance(x, ir.Ident):
            return x.name not in banned
        if isinstance(x, ir.Lookup):
            if not allow_lookup:
                return False
            if not isinstance(x.expr, ir.Ident):
                return False
            if x.expr.name in per_elem or x.expr.name in banned:
                return False
            return rec(x.index)
        return all(rec(c) for c in x.children())

    return rec(e)


def _scalar_kind_ok(ty: wt.WeldType, spec: reg.KernelSpec) -> bool:
    return isinstance(ty, wt.Scalar) and ty.kind in spec.elem_kinds


def _is_plus_identity(e: ir.Expr, elem: wt.Scalar) -> bool:
    return (
        isinstance(e, ir.Literal)
        and e.ty == elem
        and e.value == wt.merge_identity("+", elem)
    )


def _compute_ops(e: ir.Expr) -> int:
    return ir.count_nodes(
        e, lambda n: isinstance(n, (ir.BinOp, ir.UnaryOp, ir.Select, ir.Cast))
    )


def _destructure_pair(mval: ir.Expr) -> Tuple[ir.Expr, ir.Expr]:
    """Split a struct-producing merge value into its two fields."""
    if isinstance(mval, ir.MakeStruct) and len(mval.items) == 2:
        return mval.items[0], mval.items[1]
    return ir.GetField(mval, 0), ir.GetField(mval, 1)


# ---------------------------------------------------------------------------
# per-pattern matchers — each returns a KernelCall or None
# ---------------------------------------------------------------------------


def _match_filter_reduce(loop: ir.For, dense: Set[str]) -> Optional[ir.KernelCall]:
    spec = reg.available("filter_reduce_sum")
    if spec is None:
        return None
    b, i, x = loop.func.params
    body = loop.func.body
    nb = loop.builder

    def merger_ok(nbx) -> bool:
        return (
            isinstance(nbx, ir.NewBuilder)
            and isinstance(nbx.ty, wt.Merger)
            and nbx.ty.op == "+"
            and nbx.arg is None
            and _scalar_kind_ok(nbx.ty.elem, spec)
        )

    vals: List[Tuple[wt.Scalar, ir.Expr]] = []
    cond: Optional[ir.Expr] = None
    struct = False

    if merger_ok(nb):
        elem = nb.ty.elem
        if isinstance(body, ir.Merge) and _is_ident(body.builder, b.name):
            v = body.value
            if isinstance(v, ir.Select) and _is_plus_identity(v.on_false, elem):
                cond, v = v.cond, v.on_true  # post-predication form
            vals.append((elem, v))
        elif (
            isinstance(body, ir.If)
            and isinstance(body.on_true, ir.Merge)
            and _is_ident(body.on_true.builder, b.name)
            and _is_ident(body.on_false, b.name)
        ):
            cond = body.cond  # pre-predication form
            vals.append((elem, body.on_true.value))
        else:
            return None
    elif isinstance(nb, ir.MakeStruct) and nb.items and all(
        merger_ok(p) for p in nb.items
    ):
        struct = True
        core = body
        if isinstance(body, ir.If):
            if not _is_ident(body.on_false, b.name):
                return None
            cond, core = body.cond, body.on_true
        if not (isinstance(core, ir.MakeStruct)
                and len(core.items) == len(nb.items)):
            return None
        for k, item in enumerate(core.items):
            if not (
                isinstance(item, ir.Merge)
                and isinstance(item.builder, ir.GetField)
                and item.builder.index == k
                and _is_ident(item.builder.expr, b.name)
            ):
                return None
            vals.append((nb.items[k].ty.elem, item.value))
    else:
        return None

    per_elem = {i.name, x.name}
    for _, v in vals:
        if not _elementwise_ok(v, {b.name}, per_elem):
            return None
    if cond is not None and not _elementwise_ok(cond, {b.name}, per_elem):
        return None

    fns = [ir.Lambda((i, x), v) for _, v in vals]
    if cond is not None:
        fns.append(ir.Lambda((i, x), cond))
    ret: wt.WeldType = (
        wt.Struct(tuple(e for e, _ in vals)) if struct else vals[0][0]
    )
    return ir.KernelCall(
        kernel=spec.name,
        args=tuple(it.data for it in loop.iters),
        ret_ty=ret,
        params=(("n_aggs", len(vals)), ("has_pred", cond is not None),
                ("struct", struct)),
        fns=tuple(fns),
    )


def _match_vecmerger(loop: ir.For, dense: Set[str]) -> Optional[ir.KernelCall]:
    spec = reg.available("vecmerger_segment_sum")
    if spec is None:
        return None
    nb = loop.builder
    if not (
        isinstance(nb, ir.NewBuilder)
        and isinstance(nb.ty, wt.VecMerger)
        and nb.ty.op == "+"
        and nb.arg is not None
        and _scalar_kind_ok(nb.ty.elem, spec)
        and _value_dense(nb.arg, dense)
    ):
        return None
    b, i, x = loop.func.params
    body = loop.func.body
    if not (isinstance(body, ir.Merge) and _is_ident(body.builder, b.name)):
        return None
    idx_e, val_e = _destructure_pair(body.value)
    per_elem = {i.name, x.name}
    if not (_elementwise_ok(idx_e, {b.name}, per_elem)
            and _elementwise_ok(val_e, {b.name}, per_elem)):
        return None
    return ir.KernelCall(
        kernel=spec.name,
        args=(nb.arg,) + tuple(it.data for it in loop.iters),
        ret_ty=wt.Vec(nb.ty.elem),
        fns=(ir.Lambda((i, x), idx_e), ir.Lambda((i, x), val_e)),
    )


def _match_dict_group(loop: ir.For, dense: Set[str]) -> Optional[ir.KernelCall]:
    spec = reg.available("dict_group_sum")
    if spec is None:
        return None
    nb = loop.builder
    if not (
        isinstance(nb, ir.NewBuilder)
        and isinstance(nb.ty, wt.DictMerger)
        and nb.ty.op == "+"
    ):
        return None
    kt, vt = nb.ty.key, nb.ty.val
    if not (isinstance(kt, wt.Scalar) and kt.is_int):
        return None
    if not _scalar_kind_ok(vt, spec):
        return None
    if not (isinstance(nb.arg, ir.Literal)):
        return None  # capacity must be a static literal
    cap = int(nb.arg.value)
    if spec.max_segments is not None and cap > spec.max_segments:
        return None
    b, i, x = loop.func.params
    body = loop.func.body
    cond: Optional[ir.Expr] = None
    if (
        isinstance(body, ir.If)
        and isinstance(body.on_true, ir.Merge)
        and _is_ident(body.on_false, b.name)
    ):
        # filtered group-by: the predicate becomes the adapter's row mask
        cond, body = body.cond, body.on_true
    if not (isinstance(body, ir.Merge) and _is_ident(body.builder, b.name)):
        return None
    key_e, val_e = _destructure_pair(body.value)
    per_elem = {i.name, x.name}
    if not (_elementwise_ok(key_e, {b.name}, per_elem)
            and _elementwise_ok(val_e, {b.name}, per_elem)):
        return None
    if cond is not None and not _elementwise_ok(cond, {b.name}, per_elem):
        return None
    fns = [ir.Lambda((i, x), key_e), ir.Lambda((i, x), val_e)]
    if cond is not None:
        fns.append(ir.Lambda((i, x), cond))
    return ir.KernelCall(
        kernel=spec.name,
        args=tuple(it.data for it in loop.iters),
        ret_ty=wt.DictType(kt, vt),
        params=(("capacity", cap), ("key_np", str(kt.np_dtype.__name__)),
                ("has_pred", cond is not None)),
        fns=tuple(fns),
    )


def _match_map_chain(loop: ir.For, dense: Set[str]) -> Optional[ir.KernelCall]:
    spec = reg.available("map_elementwise")
    if spec is None:
        return None
    nb = loop.builder
    if not (
        isinstance(nb, ir.NewBuilder)
        and isinstance(nb.ty, wt.VecBuilder)
        and _scalar_kind_ok(nb.ty.elem, spec)
    ):
        return None
    b, i, x = loop.func.params
    body = loop.func.body
    if not (isinstance(body, ir.Merge) and _is_ident(body.builder, b.name)):
        return None
    val = body.value
    per_elem = {i.name, x.name}
    # the staged body runs INSIDE the Pallas kernel: gathers into whole
    # arrays (Lookup) and the loop index are unavailable there.
    if not _elementwise_ok(val, {b.name}, per_elem, allow_lookup=False):
        return None
    if i.name in ir.free_vars(val):
        return None
    if _compute_ops(val) < MIN_MAP_OPS:
        return None  # trivial map: XLA handles it; not worth a launch
    return ir.KernelCall(
        kernel=spec.name,
        args=tuple(it.data for it in loop.iters),
        ret_ty=wt.Vec(nb.ty.elem),
        fns=(ir.Lambda((i, x), val),),
    )


def _match_loop(e: ir.Result, dense: Set[str]) -> Optional[ir.KernelCall]:
    loop = e.builder
    if not isinstance(loop, ir.For) or not loop.iters:
        return None
    if not all(_iter_ok(it, dense) for it in loop.iters):
        return None
    if len(loop.func.params) != 3:
        return None
    nb = loop.builder
    if isinstance(nb, ir.NewBuilder):
        if isinstance(nb.ty, wt.Merger):
            return _match_filter_reduce(loop, dense)
        if isinstance(nb.ty, wt.VecMerger):
            return _match_vecmerger(loop, dense)
        if isinstance(nb.ty, wt.DictMerger):
            return _match_dict_group(loop, dense)
        if isinstance(nb.ty, wt.VecBuilder):
            return _match_map_chain(loop, dense)
    if isinstance(nb, ir.MakeStruct):
        return _match_filter_reduce(loop, dense)
    return None


def _match_cudf(e: ir.CUDF) -> Optional[ir.KernelCall]:
    name = {"linalg.matmul": "matmul", "linalg.matvec": "matvec"}.get(e.name)
    if name is None:
        return None
    spec = reg.available(name)
    if spec is None:
        return None
    for a in e.args:
        try:
            ty = ir.typeof(a)
        except Exception:
            return None
        base = ty
        while isinstance(base, wt.Vec):
            base = base.elem
        if not _scalar_kind_ok(base, spec):
            return None
    return ir.KernelCall(kernel=name, args=e.args, ret_ty=e.ret_ty)


# ---------------------------------------------------------------------------
# the pass
# ---------------------------------------------------------------------------


def plan_kernels(
    e: ir.Expr,
    input_shapes: Optional[Dict[str, tuple]] = None,
    stats: Optional[Dict[str, int]] = None,
) -> ir.Expr:
    """Annotate matched loops with KernelCall nodes.  Identity on programs
    with no matches; never rewrites inside ``for`` bodies."""
    stats = stats if stats is not None else {}
    stats.setdefault("kernelize.matched", 0)
    dense: Set[str] = set(input_shapes or ())

    def found(kc: ir.KernelCall) -> ir.KernelCall:
        stats["kernelize.matched"] += 1
        key = f"kernelize.{kc.kernel}"
        stats[key] = stats.get(key, 0) + 1
        return kc

    def rec(x: ir.Expr) -> ir.Expr:
        if isinstance(x, ir.Lambda):
            return x  # loop bodies are off-limits
        if isinstance(x, ir.Let):
            v = rec(x.value)
            if _value_dense(v, dense):
                dense.add(x.name)
            return ir.Let(x.name, v, rec(x.body))
        x = x.map_children(rec)
        if isinstance(x, ir.Result):
            kc = _match_loop(x, dense)
            if kc is not None:
                return found(kc)
        if isinstance(x, ir.CUDF):
            kc = _match_cudf(x)
            if kc is not None:
                return found(kc)
        return x

    return rec(e)
