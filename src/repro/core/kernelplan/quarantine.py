"""Kernel quarantine: an on-disk health file for failing kernel routes.

When a planned Pallas kernel fails to stage/compile/launch, the
recovery layer (``core.recovery``) falls the evaluation back to the
generic jnp lowering and records the offender here under the key
``(kernel, impl, dtype, size-bucket)``.  The planner's cost gate
consults :func:`is_quarantined` before routing, so a repeat offender is
rejected up front — the failure is paid once, not per query.

The file lives next to the autotune cache (default
``~/.cache/weld-repro/kernel_health.json``, overridable via
``$WELD_KERNEL_HEALTH``) and follows the same durability contract:
atomic tmp+rename writes, a corrupt file degrades to empty with a
``RuntimeWarning``, and :func:`fingerprint` participates in the
runtime's compile-cache key so quarantining (or clearing) a kernel can
never be served by a stale executable.

Reset with :func:`clear` (or delete the file) after fixing the kernel.
"""
from __future__ import annotations

import json
import os
import warnings
from typing import Dict, Optional

import numpy as np

ENV_FILE = "WELD_KERNEL_HEALTH"

_health: Optional[Dict[str, dict]] = None  # lazily loaded from disk
_generation = 0  # bumps on every mutation (part of fingerprint)


def path() -> str:
    return os.environ.get(ENV_FILE) or os.path.join(
        os.path.expanduser("~"), ".cache", "weld-repro", "kernel_health.json"
    )


def _load() -> Dict[str, dict]:
    global _health
    if _health is None:
        p = path()
        try:
            with open(p) as f:
                _health = json.load(f)
            if not isinstance(_health, dict):
                raise ValueError("health file root is not an object")
        except OSError:
            _health = {}  # no file yet: every kernel is healthy
        except ValueError as e:
            warnings.warn(
                f"kernel health file {p} is corrupt ({e}); ignoring it "
                "and starting with an empty quarantine — delete the file "
                "to silence this warning",
                RuntimeWarning, stacklevel=2,
            )
            _health = {}
    return _health


def _save() -> None:
    from .. import faults

    p = path()
    tmp = f"{p}.{os.getpid()}.tmp"
    try:
        faults.maybe_raise("io.quarantine", exc=OSError)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(_health, f, indent=1, sort_keys=True)
        os.replace(tmp, p)  # atomic: readers never see a partial file
    except OSError:
        try:
            os.remove(tmp)
        except OSError:
            pass
        # the quarantine still applies in-process; persistence is
        # best-effort, like the autotune cache


def clear(disk: bool = True) -> None:
    """Forget every quarantined kernel (after a fix / for tests)."""
    global _health, _generation
    _health = {}
    _generation += 1
    if disk:
        try:
            os.remove(path())
        except OSError:
            pass


def _key(kernel: str, impl: Optional[str], dtype, n: Optional[int]) -> str:
    from . import autotune

    bucket = autotune.size_bucket(int(n or 0))
    return f"{kernel}|{impl}|{np.dtype(dtype or 'f8').name}|{bucket}"


def record(kernel: str, impl: Optional[str] = None, dtype=None,
           n: Optional[int] = None, error: Optional[str] = None) -> str:
    """Quarantine one (kernel, impl, dtype, size-bucket); returns the key."""
    global _generation
    h = _load()
    k = _key(kernel, impl, dtype, n)
    ent = h.setdefault(k, {"kernel": kernel, "impl": impl, "count": 0})
    ent["count"] += 1
    if error:
        ent["last_error"] = error[:500]
    _generation += 1
    _save()
    return k


def is_quarantined(kernel: str, impl: Optional[str] = None, dtype=None,
                   n: Optional[int] = None) -> bool:
    return _key(kernel, impl, dtype, n) in _load()


def entries() -> Dict[str, dict]:
    """Copy of the current quarantine table (reporting/tests)."""
    return {k: dict(v) for k, v in _load().items()}


def fingerprint() -> str:
    """Stable digest of the quarantine state for the compile-cache key."""
    import zlib

    h = _load()
    items = ";".join(sorted(h))
    return f"g{_generation}n{len(h)}h{zlib.crc32(items.encode()):08x}"
