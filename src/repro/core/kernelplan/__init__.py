"""Kernel planning: lower fused Weld IR loops onto the Pallas kernel library.

The subsystem sits between the optimizer and the backend emitter:

    frames -> lazy DAG -> optimize (fusion/predication/CSE)
           -> **plan_kernels** (this package; cost-gated in "auto" mode)
           -> **tune_plan** (block-size autotuner bakes tuned params)
           -> jaxgen emitter (KernelCall nodes dispatch to repro.kernels.ops,
              everything else lowers through the generic vector emitter)

``kernelize`` accepts three modes (bools are accepted for
back-compatibility):

* ``"auto"`` (the default, ``None``) — route a matched loop only when
  the roofline cost model (:mod:`.cost`) prices the kernel route at
  least as fast as the generic jnp lowering;
* ``"always"`` (``True``) — route every sound match unconditionally
  (the pre-cost-model behavior; ablations and tests);
* ``"off"`` (``False``) — bypass the planner entirely.

``kernel_impl`` forwards the usual ref / interpret / pallas resolution
to the kernel entries.

This module stays import-light: the planner/registry/autotuner (and the
Pallas kernel library behind them) load lazily on first attribute
access.  With the default now "auto" they load at the first evaluation
rather than never; ``kernelize="off"`` evaluations still skip them.
"""
from __future__ import annotations

from typing import Optional, Union

KERNELIZE_MODES = ("always", "auto", "off")

#: process-wide default for evaluations that don't pass ``kernelize=``.
#: "auto" = cost-gated routing — safe to leave on everywhere because the
#: gate falls back to the jnp lowering whenever the kernel can't win.
DEFAULT_KERNELIZE: str = "auto"


def normalize_kernelize(kernelize: Union[None, bool, str]) -> str:
    """Map the public knob (None/bool/str) onto a mode string."""
    if kernelize is None:
        return DEFAULT_KERNELIZE
    if kernelize is True:
        return "always"
    if kernelize is False:
        return "off"
    if kernelize in KERNELIZE_MODES:
        return str(kernelize)
    raise ValueError(
        f"kernelize must be None, bool, or one of {KERNELIZE_MODES}; "
        f"got {kernelize!r}"
    )


def set_default_kernelize(mode: Union[bool, str]) -> None:
    global DEFAULT_KERNELIZE
    if mode is None:
        raise ValueError("default kernelize mode cannot be None")
    DEFAULT_KERNELIZE = normalize_kernelize(mode)


def resolve_kernelize(kernelize: Union[None, bool, str]) -> str:
    return normalize_kernelize(kernelize)


_PLANNER_ATTRS = {"plan_kernels"}
_REGISTRY_ATTRS = {
    "KernelPlanError", "KernelSpec", "all_specs", "available", "describe",
    "fingerprint", "get", "register", "unregister",
}
_AUTOTUNE_ATTRS = {"tune_plan"}


def __getattr__(name: str):  # PEP 562 lazy re-exports
    if name in _PLANNER_ATTRS:
        from . import planner

        return getattr(planner, name)
    if name in _REGISTRY_ATTRS:
        from . import registry

        return getattr(registry, name)
    if name in _AUTOTUNE_ATTRS:
        from . import autotune

        return getattr(autotune, name)
    if name in ("quarantine", "calibrate"):
        # importlib (not ``from . import``) — the fromlist lookup would
        # re-enter this __getattr__ before the submodule is bound
        import importlib

        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "plan_kernels",
    "tune_plan",
    "KernelPlanError",
    "KernelSpec",
    "register",
    "unregister",
    "get",
    "available",
    "all_specs",
    "describe",
    "fingerprint",
    "set_default_kernelize",
    "resolve_kernelize",
    "normalize_kernelize",
    "KERNELIZE_MODES",
    "DEFAULT_KERNELIZE",
    "quarantine",
    "calibrate",
]
