"""Kernel planning: lower fused Weld IR loops onto the Pallas kernel library.

The subsystem sits between the optimizer and the backend emitter:

    frames -> lazy DAG -> optimize (fusion/predication/CSE)
           -> **plan_kernels** (this package)
           -> jaxgen emitter (KernelCall nodes dispatch to repro.kernels.ops,
              everything else lowers through the generic vector emitter)

``kernelize`` is opt-in per evaluation (``Evaluate(obj, kernelize=True)``)
or globally via :func:`set_default_kernelize`; ``kernel_impl`` forwards
the usual ref / interpret / pallas resolution to the kernel entries.

This module stays import-light: the planner/registry (and the Pallas
kernel library behind them) load lazily on first attribute access, so
the default jnp-only evaluation path never pays their import cost.
"""
from __future__ import annotations

from typing import Optional

#: process-wide default for evaluations that don't pass ``kernelize=``.
#: stays False until kernel/jnp parity is proven on a deployment target.
DEFAULT_KERNELIZE: bool = False


def set_default_kernelize(flag: bool) -> None:
    global DEFAULT_KERNELIZE
    DEFAULT_KERNELIZE = bool(flag)


def resolve_kernelize(kernelize: Optional[bool]) -> bool:
    return DEFAULT_KERNELIZE if kernelize is None else bool(kernelize)


_PLANNER_ATTRS = {"plan_kernels"}
_REGISTRY_ATTRS = {
    "KernelPlanError", "KernelSpec", "all_specs", "available", "describe",
    "fingerprint", "get", "register", "unregister",
}


def __getattr__(name: str):  # PEP 562 lazy re-exports
    if name in _PLANNER_ATTRS:
        from . import planner

        return getattr(planner, name)
    if name in _REGISTRY_ATTRS:
        from . import registry

        return getattr(registry, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "plan_kernels",
    "KernelPlanError",
    "KernelSpec",
    "register",
    "unregister",
    "get",
    "available",
    "all_specs",
    "describe",
    "fingerprint",
    "set_default_kernelize",
    "resolve_kernelize",
    "DEFAULT_KERNELIZE",
]
