"""Roofline cost model for adaptive kernel routing (planner ``mode="auto"``).

The PR-1 planner routed *every* matched pattern onto a Pallas kernel;
that wins where the kernel restructures the computation (group-by as
one-hot MXU matmuls) but loses where it merely re-expresses an already
memory-bound jnp lowering plus launch/padding overhead (tiny inputs,
large-key vecmerger scatter).  Following Split Annotations' observation
that a cost-gated scheduler is what makes transparent acceleration safe
to enable by default, every candidate ``KernelCall`` is priced twice —
kernel route vs. generic jnp lowering — through the roofline constants
in :mod:`repro.roofline.analysis` and routed only when the kernel is
not meaningfully worse.

Each estimate is ``max(bytes/HBM_bw, flops/peak)`` plus route-specific
overheads:

* **padding** — kernels pad every column to a block multiple, so a tiny
  input pays for a whole tile of traffic;
* **launch** — a Pallas dispatch has fixed overhead the inlined jnp
  lowering does not pay;
* **scratch** — materialized helpers (one-hot tiles, stacked value
  matrices, compaction sorts) are charged to the kernel route;
* **structure factors** — the generic lowering pays for accumulator
  machinery (mask broadcasts, select chains, per-aggregate passes) and
  for sort-based keyed aggregation; scatter stores pay a random-access
  penalty.  These are calibrated against the PR-1 ablation
  (``benchmarks/bench_kernelplan.py``): segment-style group-by ~2.5-3.8x
  in favor of the kernel, vecmerger scatter in favor of jnp.

The absolute seconds are TPU-roofline numbers, not CPU wall clock; only
the *ordering* of the two estimates drives routing, and the overhead
terms are what flip it at the observed crossover points.

``estimate(spec, meta)`` returns a :class:`CostEstimate`; ``meta`` is
the planner-collected static description of the match (sizes from
``Iter`` hints, op counts from the staged bodies).  Unknown sizes
reject conservatively: a route we cannot price is a route we do not
take (the jnp lowering is always correct).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from math import ceil, log2
from typing import Optional

from ...roofline.analysis import HW_V5E

#: route when kernel_s <= jnp_s * (1 + ROUTE_MARGIN): prefer the kernel
#: on a near-tie (it strictly reduces HBM traffic on the real target).
ROUTE_MARGIN = 0.10

#: fixed per-launch overhead of a Pallas dispatch (grid setup + the
#: kernel's own jit boundary) that the inlined jnp lowering never pays.
LAUNCH_OVERHEAD_S = 1e-6

#: generic-emitter accumulator machinery (mask broadcast, select chain,
#: finalize combine) as a multiplicative tax on the jnp reduce lowering.
REDUCE_STRUCTURE_TAX = 1.15

#: random-access scatter stores achieve a fraction of streaming HBM
#: bandwidth; .at[].add is modelled as this many streaming passes.
SCATTER_PENALTY = 4.0

#: sort-based keyed aggregation (the generic dictmerger lowering) moves
#: roughly key+val+packed rows per comparison level; this scales the
#: n*log2(n) byte volume.
SORT_BYTES_PER_ROW = 24.0

#: deep elementwise chains risk XLA materializing intermediates between
#: fusion islands; per-op slack on the jnp map-chain estimate.
MAP_CHAIN_SLACK_PER_OP = 0.10

#: the one-hot segment kernels' VMEM accumulator bound (segment_reduce
#: MAX_K): keyed accumulation beyond it serves the ref scatter instead.
SEGMENT_TILE_K = 4096

#: a vectorized binary search (the generic dict-probe lowering) issues
#: log2(K) dependent random loads per row; each achieves this many
#: streaming-pass equivalents (gentler than full scatter: the upper tree
#: levels stay cache/VMEM resident).
BSEARCH_PENALTY = 2.0


@dataclass(frozen=True)
class CostEstimate:
    """Priced routing decision for one matched pattern."""

    kernel_s: float
    jnp_s: float
    routed: bool
    why: str
    #: where the kernel-side figure came from: "roofline" (analytic
    #: constants) or "measured" (cost-ledger median via kernelplan
    #: calibration).
    source: str = "roofline"

    def as_stats(self) -> dict:
        return {
            "kernel_us": round(self.kernel_s * 1e6, 3),
            "jnp_us": round(self.jnp_s * 1e6, 3),
            "routed": self.routed,
            "why": self.why,
            "source": self.source,
        }


REJECT_UNKNOWN = CostEstimate(
    float("inf"), 0.0, False,
    "unknown size: cannot price the kernel route, falling back to jnp",
)


def _roofline_s(bytes_moved: float, flops: float) -> float:
    return max(bytes_moved / HW_V5E["hbm_bw"],
               flops / HW_V5E["peak_flops_bf16"])


def _pad(n: int, block: int) -> int:
    return int(ceil(max(n, 1) / block)) * block


def _decide(kernel_s: float, jnp_s: float, why: str) -> CostEstimate:
    routed = kernel_s <= jnp_s * (1.0 + ROUTE_MARGIN)
    return CostEstimate(kernel_s, jnp_s, routed, why)


# ---------------------------------------------------------------------------
# Per-pattern cost hooks (wired onto KernelSpec.cost in registry.py).
# Every hook takes the planner's `meta` dict and returns a CostEstimate.
# ---------------------------------------------------------------------------


def cost_filter_reduce(meta: dict) -> CostEstimate:
    """Predicated multi-aggregate sum: one shared pass vs. the generic
    merger accumulators.  Gate is padding + launch vs. structure tax."""
    n = meta.get("n")
    if not n:
        return REJECT_UNKNOWN
    cols = max(meta.get("cols", 1), 1)
    ops = meta.get("ops", 1)
    aggs = max(meta.get("n_aggs", 1), 1)
    e = meta.get("elem_bytes", 8)
    block = meta.get("block", 8 * 1024)
    np_ = _pad(n, block)
    # kernel: every column + the predicate mask stream once, padded;
    # the multi-agg variant shares the mask/column loads across outputs.
    k_bytes = np_ * (cols * e + 1) + aggs * e
    k_flops = np_ * (ops + aggs)
    kernel_s = _roofline_s(k_bytes, k_flops) + LAUNCH_OVERHEAD_S
    j_bytes = (n * (cols * e + 1)) * REDUCE_STRUCTURE_TAX
    j_flops = n * (ops + aggs)
    jnp_s = _roofline_s(j_bytes, j_flops)
    return _decide(kernel_s, jnp_s,
                   f"n={n} cols={cols} aggs={aggs} pad={np_ - n}")


def cost_vecmerger(meta: dict) -> CostEstimate:
    """Scatter-add vs. one-hot MXU segment sum.  The kernel's 2*n*K
    matmul FLOPs cross the scatter's memory bound as K grows; beyond the
    VMEM tile bound the 'kernel' route degenerates to the same scatter
    plus overhead, so it can never win there."""
    n, k = meta.get("n"), meta.get("k")
    if not n or not k:
        return REJECT_UNKNOWN
    e = meta.get("elem_bytes", 8)
    block = meta.get("block", 512)
    max_k = meta.get("max_k")
    np_ = _pad(n, block)
    j_bytes = n * (8 + 2 * e) * SCATTER_PENALTY + k * e
    jnp_s = _roofline_s(j_bytes, n)
    if max_k is not None and k > max_k:
        # kops falls back to the ref segment-sum (itself a scatter):
        # strictly the jnp cost plus dispatch — never routable.
        return _decide(jnp_s * 1.2 + LAUNCH_OVERHEAD_S, jnp_s,
                       f"n={n} K={k} exceeds VMEM tile bound {max_k}")
    k_bytes = np_ * (4 + e) + k * e
    k_flops = 2.0 * np_ * k
    kernel_s = _roofline_s(k_bytes, k_flops) + LAUNCH_OVERHEAD_S
    return _decide(kernel_s, jnp_s, f"n={n} K={k} pad={np_ - n}")


def cost_dict_group(meta: dict) -> CostEstimate:
    """Dense-int-key group-by: one-hot segment sums + compaction vs. the
    generic sort-based dictmerger lowering."""
    n, k = meta.get("n"), meta.get("k")
    if not n or not k:
        return REJECT_UNKNOWN
    e = meta.get("elem_bytes", 8)
    block = meta.get("block", 256)
    np_ = _pad(n, block)
    # kernel: stacked (vals, ones) scratch + one-hot matmul + K-compaction
    k_bytes = np_ * (4 + 2 * e) + 2 * n * e + 4 * k * e
    k_flops = 2.0 * np_ * k * 2 + k * max(log2(max(k, 2)), 1.0)
    kernel_s = _roofline_s(k_bytes, k_flops) + 2 * LAUNCH_OVERHEAD_S
    j_bytes = n * SORT_BYTES_PER_ROW * max(log2(max(n, 2)), 1.0)
    jnp_s = _roofline_s(j_bytes, n)
    return _decide(kernel_s, jnp_s, f"n={n} K={k} pad={np_ - n}")


def cost_hash_build(meta: dict) -> CostEstimate:
    """Open-addressing dict build (hash-to-slot + one-hot accumulation +
    compaction) vs. the generic sort-based dictmerger lowering.  The
    serial insert chain is random-access bound; the sort pays
    n*log2(n) passes — the kernel wins once n clears the launch and
    probe-chain overheads."""
    n, k = meta.get("n"), meta.get("k")
    if not n or not k:
        return REJECT_UNKNOWN
    e = meta.get("elem_bytes", 8)
    nv = max(meta.get("n_vals", 1), 1)
    nk = max(meta.get("n_keys", 1), 1)
    block = meta.get("block", 256)
    np_ = _pad(n, block)
    # serial slot probes (key + slot traffic, random access) + table
    # init/sort + per-column staged values through the segment kernels;
    # multi-column keys stream one extra staged i64 column each beyond
    # the packed stream already charged
    k_bytes = (np_ * (8 + 4) * SCATTER_PENALTY + 4 * k * 8 + n * nv * e
               + n * (nk - 1) * 8)
    if k <= SEGMENT_TILE_K:
        k_flops = 2.0 * np_ * k * nv  # one-hot MXU accumulation
    else:
        k_flops = float(n)  # kops serves the ref scatter instead
        k_bytes += n * nv * e * SCATTER_PENALTY
    kernel_s = _roofline_s(k_bytes, k_flops) + 2 * LAUNCH_OVERHEAD_S
    j_bytes = n * SORT_BYTES_PER_ROW * max(log2(max(n, 2)), 1.0)
    jnp_s = _roofline_s(j_bytes, n)
    return _decide(kernel_s, jnp_s,
                   f"n={n} K={k} keys={nk} vals={nv} pad={np_ - n}")


def cost_hash_probe(meta: dict) -> CostEstimate:
    """One-hot MXU membership probe vs. the generic vectorized binary
    search: the kernel streams the query block against a VMEM key tile
    (n*K compares, ONCE for every output column of a fused probe), the
    jnp lowering pays log2(K) dependent random loads per row plus a
    per-column streaming pass."""
    n, k = meta.get("n"), meta.get("k")
    if not n or not k:
        return REJECT_UNKNOWN
    cols = max(meta.get("cols", 1), 1)
    e = meta.get("elem_bytes", 8)
    block = meta.get("block", 512)
    np_ = _pad(n, block)
    # one membership tile + per-column gather/compaction traffic
    k_bytes = np_ * (8 + 4 + 1 + cols * e) + k * 8
    k_flops = 1.0 * np_ * k
    kernel_s = _roofline_s(k_bytes, k_flops) + LAUNCH_OVERHEAD_S
    lgk = max(log2(max(k, 2)), 1.0)
    j_bytes = n * 8 * lgk * BSEARCH_PENALTY + n * cols * e
    jnp_s = _roofline_s(j_bytes, n * lgk)
    return _decide(kernel_s, jnp_s,
                   f"n={n} K={k} cols={cols} pad={np_ - n}")


def cost_group_build(meta: dict) -> CostEstimate:
    """CSR group build (hash-to-slot + slot histogram + payload
    ordering sort) vs. the generic sort-based groupbuilder finalize.
    Both routes order the payload rows; the kernel replaces the full
    keyed sort + segment machinery with the serial hash/histogram
    chains (random access) and a narrower ordering sort."""
    n, k = meta.get("n"), meta.get("k")
    if not n or not k:
        return REJECT_UNKNOWN
    e = meta.get("elem_bytes", 8)
    nk = max(meta.get("n_keys", 1), 1)
    block = meta.get("block", 256)
    np_ = _pad(n, block)
    lgn = max(log2(max(n, 2)), 1.0)
    # serial slot probes + histogram stores + the CSR payload ordering
    # sort + table/offsets traffic; extra staged key columns beyond the
    # packed stream cost one i64 pass each
    k_bytes = (np_ * (8 + 4) * SCATTER_PENALTY + n * 4 * SCATTER_PENALTY
               + n * 8 * lgn + 4 * k * 8 + n * (nk - 1) * 8 + n * e)
    kernel_s = _roofline_s(k_bytes, float(n)) + 2 * LAUNCH_OVERHEAD_S
    j_bytes = n * SORT_BYTES_PER_ROW * lgn
    jnp_s = _roofline_s(j_bytes, n)
    return _decide(kernel_s, jnp_s, f"n={n} K={k} keys={nk}")


def cost_group_probe(meta: dict) -> CostEstimate:
    """m:n fan-out probe: the fused one-hot membership + match-count
    tile vs. the generic vectorized binary search.  BOTH routes then
    pay the shared two-phase expansion (exclusive scan + repeat/gather
    into the static expansion buffer), priced by the expansion factor
    ``out``/``n`` the planner lifts off the vecbuilder size hints."""
    n, k = meta.get("n"), meta.get("k")
    if not n or not k:
        return REJECT_UNKNOWN
    out = meta.get("out") or n
    cols = max(meta.get("cols", 1), 1)
    e = meta.get("elem_bytes", 8)
    block = meta.get("block", 512)
    np_ = _pad(n, block)
    # scan + out-row binary search + per-column repeated/gathered output
    expand_bytes = n * 8.0 + out * (8 + cols * e)
    k_bytes = np_ * (8 + 4 + 1 + 4) + k * 8 + expand_bytes
    k_flops = 1.0 * np_ * k
    kernel_s = _roofline_s(k_bytes, k_flops) + LAUNCH_OVERHEAD_S
    lgk = max(log2(max(k, 2)), 1.0)
    j_bytes = n * 8 * lgk * BSEARCH_PENALTY + expand_bytes
    jnp_s = _roofline_s(j_bytes, n * lgk)
    return _decide(kernel_s, jnp_s,
                   f"n={n} K={k} cols={cols} out={out}")


def cost_matmul(meta: dict) -> CostEstimate:
    """Tiled VMEM matmul vs. XLA dot: identical arithmetic, so the gate
    is tile padding (XLA pads to 128 internally) plus launch overhead."""
    dims = meta.get("dims")
    if not dims or any(d is None for d in dims):
        return REJECT_UNKNOWN
    m, k, n = dims
    e = meta.get("elem_bytes", 8)
    bm = meta.get("bm", 256)
    bn = meta.get("bn", 256)
    bk = meta.get("bk", 512)
    mp, kp, np_ = _pad(m, bm), _pad(k, bk), _pad(n, bn)
    k_bytes = (mp * kp + kp * np_ + mp * np_) * e
    k_flops = 2.0 * mp * kp * np_
    kernel_s = _roofline_s(k_bytes, k_flops) + LAUNCH_OVERHEAD_S
    m1, k1, n1 = _pad(m, 128), _pad(k, 128), _pad(n, 128)
    j_bytes = (m1 * k1 + k1 * n1 + m1 * n1) * e
    jnp_s = _roofline_s(j_bytes, 2.0 * m1 * k1 * n1)
    return _decide(kernel_s, jnp_s, f"dims={m}x{k}x{n}")


def cost_map_chain(meta: dict) -> CostEstimate:
    """Fused elementwise chain: one guaranteed VMEM pass vs. XLA fusion
    with per-op materialization slack on deep chains."""
    n = meta.get("n")
    if not n:
        return REJECT_UNKNOWN
    cols = max(meta.get("cols", 1), 1)
    ops = meta.get("ops", 2)
    e = meta.get("elem_bytes", 8)
    block = meta.get("block", 8 * 1024)
    np_ = _pad(n, block)
    k_bytes = np_ * (cols + 1) * e
    kernel_s = _roofline_s(k_bytes, np_ * ops) + LAUNCH_OVERHEAD_S
    j_bytes = n * (cols + 1) * e * (1.0 + MAP_CHAIN_SLACK_PER_OP * min(ops, 8))
    jnp_s = _roofline_s(j_bytes, n * ops)
    return _decide(kernel_s, jnp_s, f"n={n} cols={cols} ops={ops}")


def _calibrated(spec, meta: dict, est: CostEstimate) -> CostEstimate:
    """Overlay the cost ledger's measured median over the roofline
    kernel-side estimate (see :mod:`.calibrate`).  The gate re-decides
    routing from the measured figure; ``why`` gains ``source=measured``
    vs ``source=roofline`` so ``Query.explain()`` shows which world the
    decision came from.  Best-effort: any calibration failure leaves the
    roofline estimate untouched."""
    kernel = meta.get("kernel") or getattr(spec, "name", None)
    dtype = meta.get("dtype")
    n = meta.get("n")
    hit = None
    try:
        if kernel and dtype is not None and n:
            from . import calibrate

            hit = calibrate.measured_ns(str(kernel), str(dtype), int(n))
    except Exception:
        hit = None
    if hit is None:
        if " source=" in est.why:
            return est
        return replace(est, why=f"{est.why} source=roofline")
    med_ns, calls = hit
    kernel_s = med_ns / 1e9
    routed = kernel_s <= est.jnp_s * (1.0 + ROUTE_MARGIN)
    return CostEstimate(
        kernel_s, est.jnp_s, routed,
        f"{est.why} source=measured calls={calls} "
        f"median={med_ns / 1e3:.1f}us",
        source="measured",
    )


def estimate(spec, meta: dict) -> CostEstimate:
    """Price one candidate through the spec's cost hook, then overlay
    any ledger-measured median (:func:`_calibrated`).  Specs without a
    hook route unconditionally (the pre-cost-model behavior)."""
    hook = getattr(spec, "cost", None)
    if hook is None:
        return CostEstimate(0.0, 0.0, True, "no cost hook: always route")
    return _calibrated(spec, meta, hook(meta))
