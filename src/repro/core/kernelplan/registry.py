"""Declarative registry of Pallas kernels reachable from the IR planner.

Each :class:`KernelSpec` describes one kernel in ``repro.kernels.ops``:
the IR pattern family it accelerates (loop shape + builder kind), the
scalar kinds it accepts, its static-shape constraints, and the backend
adapter that invokes the entry point on traced values.  The planner
(`repro.core.kernelplan.planner`) consults this table — patterns are
matched *by family*, so registering/unregistering a spec is the ablation
knob for a kernel, no planner change needed.

Adapters receive backend values (``WVec``/arrays), the static params
baked into the ``KernelCall`` node, the staged per-element callables, and
the ``impl`` knob (ref / interpret / pallas) which is forwarded to
``repro.kernels.ops`` so the existing resolution machinery applies.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ...kernels import ops as kops
from ...kernels import segment_reduce as _sr
from ..backend.values import WDict, WVec


class KernelPlanError(RuntimeError):
    """An annotated kernel call could not be executed (planner bug or a
    runtime-shape violation of a registry constraint)."""


# ---------------------------------------------------------------------------
# Spec + registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class KernelSpec:
    #: registry key; also the ``KernelCall.kernel`` tag and stats suffix.
    name: str
    #: entry point, dotted (module:function) — documentation + dispatch.
    entry: str
    #: IR pattern family the planner matches (see planner.py).
    pattern: str
    #: builder kind of the matched loop ("merger[+]", "vecmerger[+]",
    #: "dictmerger[+]", "vecbuilder", or "-" for non-loop patterns).
    builder: str
    #: scalar kinds accepted for the merged element / operands.
    elem_kinds: Tuple[str, ...]
    description: str
    #: static bound on segment count / dict capacity (None = unbounded).
    max_segments: Optional[int] = None
    #: backend adapter: (args, params, fns, impl) -> backend value.
    execute: Callable = None


_REGISTRY: Dict[str, KernelSpec] = {}


def register(spec: KernelSpec) -> KernelSpec:
    _REGISTRY[spec.name] = spec
    return spec


def unregister(name: str) -> None:
    _REGISTRY.pop(name, None)


def get(name: str) -> KernelSpec:
    if name not in _REGISTRY:
        raise KernelPlanError(f"no registered kernel {name!r}")
    return _REGISTRY[name]


def available(name: str) -> Optional[KernelSpec]:
    return _REGISTRY.get(name)


def all_specs() -> Tuple[KernelSpec, ...]:
    return tuple(_REGISTRY.values())


def fingerprint() -> str:
    """Stable key of the registered-kernel set — part of the compile-cache
    key, so register/unregister (the ablation knob) forces a recompile."""
    return ",".join(sorted(_REGISTRY))


def describe() -> str:
    """Human-readable registry dump (docs / debugging)."""
    lines = []
    for s in _REGISTRY.values():
        lines.append(
            f"{s.name:24s} {s.pattern:16s} {s.builder:14s} "
            f"[{','.join(s.elem_kinds)}] -> {s.entry}"
        )
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Adapter helpers
# ---------------------------------------------------------------------------


def _dense_data(v, what: str):
    if not isinstance(v, WVec):
        raise KernelPlanError(f"{what}: expected a vector value")
    if not v.is_dense:
        raise KernelPlanError(f"{what}: kernel path requires a dense vector")
    return v.data


def _elem_of(arrays):
    return arrays[0] if len(arrays) == 1 else tuple(arrays)


def _as_col(v, n):
    """Broadcast a staged per-element result to a full (n,) column."""
    v = jnp.asarray(v)
    if v.ndim >= 1 and v.shape[0] == n:
        return v
    return jnp.broadcast_to(v, (n,) + v.shape)


# ---------------------------------------------------------------------------
# Adapters
# ---------------------------------------------------------------------------


def _exec_filter_reduce(args, params, fns, impl):
    """(iters...) + staged val/pred bodies -> scalar (or struct of) sums."""
    arrays = [_dense_data(a, "filter_reduce") for a in args]
    n = arrays[0].shape[0]
    idx = jnp.arange(n, dtype=jnp.int64)
    elem = _elem_of(arrays)
    n_aggs = params["n_aggs"]
    if params["has_pred"]:
        pred = _as_col(fns[n_aggs](idx, elem), n).astype(bool)
    else:
        pred = jnp.ones((n,), dtype=bool)
    outs = []
    for k in range(n_aggs):
        val = _as_col(fns[k](idx, elem), n)
        outs.append(kops.filter_reduce_sum(val, pred, impl=impl))
    return tuple(outs) if params["struct"] else outs[0]


def _exec_vecmerger_segment_sum(args, params, fns, impl):
    """base + scatter-add of staged {index, value} pairs via segment_sum."""
    base = _dense_data(args[0], "vecmerger base")
    arrays = [_dense_data(a, "vecmerger") for a in args[1:]]
    n = arrays[0].shape[0]
    idx = jnp.arange(n, dtype=jnp.int64)
    elem = _elem_of(arrays)
    seg = _as_col(fns[0](idx, elem), n).astype(jnp.int32)
    vals = _as_col(fns[1](idx, elem), n).astype(base.dtype)
    k = base.shape[0]
    out = base + kops.segment_sum(seg, vals, num_segments=k, impl=impl)
    return WVec(out)


def _exec_dict_group_sum(args, params, fns, impl):
    """Dense-int-key group-by-sum: one-hot MXU accumulation + compaction.

    The route assumes keys in [0, capacity).  Rows failing the (optional)
    loop predicate are masked out; rows that PASS the predicate but carry
    an out-of-range key cannot be aggregated here — the generic sort path
    would have kept them — so the result is flagged (negative count) and
    decoding raises instead of returning a silently-short dict.
    """
    arrays = [_dense_data(a, "dict group") for a in args]
    n = arrays[0].shape[0]
    idx = jnp.arange(n, dtype=jnp.int64)
    elem = _elem_of(arrays)
    cap = int(params["capacity"])
    keys = _as_col(fns[0](idx, elem), n).astype(jnp.int64)
    vals = _as_col(fns[1](idx, elem), n)
    if params.get("has_pred"):
        mask = _as_col(fns[2](idx, elem), n).astype(bool)
    else:
        mask = jnp.ones((n,), dtype=bool)
    inrange = (keys >= 0) & (keys < cap)
    overflow = jnp.any(mask & ~inrange)
    valid = mask & inrange
    # invalid rows contribute zero to segment 0 (sum identity)
    seg = jnp.where(valid, keys, 0).astype(jnp.int32)
    vals_m = jnp.where(valid, vals, jnp.zeros((), vals.dtype))
    ones = jnp.where(valid, 1, 0).astype(vals.dtype)
    # one fused launch for sums + presence counts (shared seg-id loads)
    both = kops.segment_sum_vectors(seg, jnp.stack([vals_m, ones], axis=1),
                                    num_segments=cap, impl=impl)
    sums, counts = both[:, 0], both[:, 1]
    present = counts > 0
    order = jnp.argsort(~present, stable=True)  # front-pack, keys ascending
    key_dtype = np.dtype(params.get("key_np", "int64"))
    keys_out = jnp.arange(cap, dtype=key_dtype)[order]
    vals_out = sums[order]
    count = present.sum()
    # Overflow guards, layered: the negative count makes host decode raise
    # (WDict.to_numpy); poisoned keys/values cover traced consumers that
    # never decode — KeyExists sees no keys, Lookup yields NaN, so a wrong
    # aggregate cannot propagate as a plausible number.
    count = jnp.where(overflow, -count - 1, count)
    keys_out = jnp.where(overflow, jnp.full_like(keys_out, -1), keys_out)
    if jnp.issubdtype(vals_out.dtype, jnp.floating):
        vals_out = jnp.where(overflow, jnp.full_like(vals_out, jnp.nan),
                             vals_out)
    return WDict(keys_out, vals_out, count)


def _exec_matmul(args, params, fns, impl):
    a = _dense_data(args[0], "matmul lhs")
    b = _dense_data(args[1], "matmul rhs")
    ct = jnp.result_type(a, b)
    return WVec(kops.matmul(a.astype(ct), b.astype(ct), impl=impl))


def _exec_matvec(args, params, fns, impl):
    a = _dense_data(args[0], "matvec lhs")
    b = _dense_data(args[1], "matvec rhs")
    ct = jnp.result_type(a, b)
    out = kops.matmul(a.astype(ct), b[:, None].astype(ct), impl=impl)
    return WVec(out[:, 0])


def _exec_map_elementwise(args, params, fns, impl):
    arrays = [_dense_data(a, "map chain") for a in args]

    def body(*cols):
        # the staged lambda is (i, x); map-chain matching guarantees the
        # index is unused, so bind a dummy scalar.
        return fns[0](jnp.int64(0), _elem_of(list(cols)))

    return WVec(kops.map_elementwise(body, arrays, impl=impl))


# ---------------------------------------------------------------------------
# The shipped registry (one entry per reachable Pallas kernel)
# ---------------------------------------------------------------------------

register(KernelSpec(
    name="filter_reduce_sum",
    entry="repro.kernels.ops:filter_reduce_sum",
    pattern="filter_reduce",
    builder="merger[+]",
    elem_kinds=("f32", "f64", "i32", "i64"),
    description="predicated sum over a (possibly multi-column) loop; the "
                "fused form of Listing 10 / TPC-H Q6",
    execute=_exec_filter_reduce,
))

register(KernelSpec(
    name="vecmerger_segment_sum",
    entry="repro.kernels.ops:segment_sum",
    pattern="vecmerger_scatter",
    builder="vecmerger[+]",
    elem_kinds=("f32", "f64"),
    description="scatter-add into a dense base vector as one-hot MXU "
                "segment sums (PageRank's edge scan)",
    max_segments=None,  # kops falls back to the ref path above MAX_K
    execute=_exec_vecmerger_segment_sum,
))

register(KernelSpec(
    name="dict_group_sum",
    entry="repro.kernels.ops:segment_sum_vectors",
    pattern="dict_group",
    builder="dictmerger[+]",
    elem_kinds=("f32", "f64", "i32", "i64"),
    description="group-by-sum with dense int keys in [0, capacity) via "
                "segment_sum + presence compaction",
    max_segments=_sr.MAX_K,
    execute=_exec_dict_group_sum,
))

register(KernelSpec(
    name="matmul",
    entry="repro.kernels.ops:matmul",
    pattern="linalg.matmul",
    builder="-",
    elem_kinds=("f32", "f64"),
    description="tiled VMEM-blocked matmul for raised 2-D dot loops",
    execute=_exec_matmul,
))

register(KernelSpec(
    name="matvec",
    entry="repro.kernels.ops:matmul",
    pattern="linalg.matvec",
    builder="-",
    elem_kinds=("f32", "f64"),
    description="matrix-vector product through the tiled matmul kernel",
    execute=_exec_matvec,
))

register(KernelSpec(
    name="map_elementwise",
    entry="repro.kernels.ops:map_elementwise",
    pattern="map_chain",
    builder="vecbuilder",
    elem_kinds=("f32", "f64", "i32", "i64"),
    description="fused elementwise map chain staged into one Pallas pass "
                "(Black-Scholes-style operator chains)",
    execute=_exec_map_elementwise,
))
