"""Declarative registry of Pallas kernels reachable from the IR planner.

Each :class:`KernelSpec` describes one kernel in ``repro.kernels.ops``:
the IR pattern family it accelerates (loop shape + builder kind), the
scalar kinds it accepts, its static-shape constraints, and the backend
adapter that invokes the entry point on traced values.  The planner
(`repro.core.kernelplan.planner`) consults this table — patterns are
matched *by family*, so registering/unregistering a spec is the ablation
knob for a kernel, no planner change needed.

Adapters receive backend values (``WVec``/arrays), the static params
baked into the ``KernelCall`` node, the staged per-element callables, and
the ``impl`` knob (ref / interpret / pallas) which is forwarded to
``repro.kernels.ops`` so the existing resolution machinery applies.
Tuned block sizes arrive the same way: the autotuner appends ``block``
(or ``bm``/``bn``/``bk``) to the call's params and adapters forward them.

Beyond the adapter, each spec now carries the hooks the adaptive
planner needs:

* ``cost`` — roofline pricing of the match (see ``cost.py``); drives
  ``mode="auto"`` routing;
* ``tune_space`` / ``make_bench`` — the tunable-parameter grid and a
  synthetic-workload builder the autotuner times it with;
* ``footprint`` — padding + scratch bytes of one call, charged against
  the evaluation's ``memory_limit`` budget by the emitter (the same
  budget vecbuilder size hints feed).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...kernels import filter_reduce as _fr
from ...kernels import group_build as _gb
from ...kernels import hash_probe as _hp
from ...kernels import hash_table as _ht
from ...kernels import map_chain as _mc
from ...kernels import ops as kops
from ...kernels import segment_reduce as _sr
from ...kernels import tiled_matmul as _tm
from ..backend.jaxgen import _pack_keys, group_expand
from ..backend.values import WDict, WGroup, WVec
from . import cost as _cost


class KernelPlanError(RuntimeError):
    """An annotated kernel call could not be executed (planner bug or a
    runtime-shape violation of a registry constraint)."""


# ---------------------------------------------------------------------------
# Spec + registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class KernelSpec:
    #: registry key; also the ``KernelCall.kernel`` tag and stats suffix.
    name: str
    #: entry point, dotted (module:function) — documentation + dispatch.
    entry: str
    #: IR pattern family the planner matches (see planner.py).
    pattern: str
    #: builder kind of the matched loop ("merger[+]", "vecmerger[+]",
    #: "dictmerger[+]", "vecbuilder", or "-" for non-loop patterns).
    builder: str
    #: scalar kinds accepted for the merged element / operands.
    elem_kinds: Tuple[str, ...]
    description: str
    #: static bound on segment count / dict capacity (None = unbounded).
    max_segments: Optional[int] = None
    #: backend adapter: (args, params, fns, impl) -> backend value.
    execute: Callable = None
    #: roofline cost hook: (meta dict) -> cost.CostEstimate.  None means
    #: "always route" (no model; pre-cost-gate behavior).
    cost: Optional[Callable] = None
    #: tunable-parameter grid, e.g. {"block": (1024, 8192, 32768)}.
    #: Empty = nothing to tune.
    tune_space: Dict[str, tuple] = field(default_factory=dict)
    #: synthetic-workload builder for the autotuner:
    #: (meta, params, impl) -> zero-arg timed callable.
    make_bench: Optional[Callable] = None
    #: HBM overhead accounting: (arg_shapes, itemsize, params) -> bytes of
    #: padding + scratch this call adds beyond its natural inputs/outputs.
    footprint: Optional[Callable] = None
    #: module-default value per tunable (what runs untuned; also what the
    #: autotuner bakes into the plan when timing is unavailable).
    tune_defaults: Dict[str, int] = field(default_factory=dict)


_REGISTRY: Dict[str, KernelSpec] = {}


def register(spec: KernelSpec) -> KernelSpec:
    _REGISTRY[spec.name] = spec
    return spec


def unregister(name: str) -> None:
    _REGISTRY.pop(name, None)


def get(name: str) -> KernelSpec:
    if name not in _REGISTRY:
        raise KernelPlanError(f"no registered kernel {name!r}")
    return _REGISTRY[name]


def available(name: str) -> Optional[KernelSpec]:
    return _REGISTRY.get(name)


def all_specs() -> Tuple[KernelSpec, ...]:
    return tuple(_REGISTRY.values())


def fingerprint() -> str:
    """Stable key of the registered-kernel set — part of the compile-cache
    key, so register/unregister (the ablation knob) and default-block
    changes force a recompile rather than serving a stale executable."""
    return ",".join(sorted(
        f"{s.name}:{s.entry}:{sorted(s.tune_defaults.items())}"
        for s in _REGISTRY.values()
    ))


def describe() -> str:
    """Human-readable registry dump (docs / debugging)."""
    lines = []
    for s in _REGISTRY.values():
        lines.append(
            f"{s.name:24s} {s.pattern:16s} {s.builder:14s} "
            f"[{','.join(s.elem_kinds)}] -> {s.entry}"
        )
    return "\n".join(lines)


def _poison_value(out):
    """Negate every dynamic count in a kernel result (the ``poison``
    fault action): downstream probes and decode then see exactly what a
    real capacity overflow produces."""
    if isinstance(out, WVec):
        if out.count is None:
            return WVec(out.data, jnp.int64(-1))
        c = jnp.asarray(out.count)
        return WVec(out.data, -abs(c) - 1)
    if isinstance(out, WDict):
        c = jnp.asarray(out.count)
        return WDict(out.keys, out.vals, -abs(c) - 1)
    if isinstance(out, WGroup):
        c = jnp.asarray(out.count)
        return WGroup(out.keys, out.values, out.offsets, -abs(c) - 1)
    if isinstance(out, tuple):
        return tuple(_poison_value(v) for v in out)
    return out


def execute_spec(spec: KernelSpec, args, params, fns, impl,
                 dtype=None):
    """Every planned kernel launch funnels through here.

    Arms the ``kernel.<name>`` failpoints (``raise`` simulates a
    stage/compile failure, ``poison`` a capacity overflow) and wraps any
    backend failure into a typed
    :class:`~repro.core.errors.KernelCompileError` carrying the
    quarantine key ``(kernel, impl, dtype, n)`` — the recovery layer
    records the offender and degrades the evaluation to the generic
    lowering.
    """
    from .. import faults
    from ..errors import KernelCompileError, ResourceError

    site = f"kernel.{spec.name}"
    try:
        faults.maybe_raise(site)
        out = spec.execute(args, params, fns, impl)
    except (ResourceError, KernelCompileError):
        raise  # already typed; budget breaches are not kernel failures
    except Exception as e:
        raise KernelCompileError(
            f"kernel {spec.name!r} (impl={impl}) failed to stage/launch: "
            f"{type(e).__name__}: {e}",
            kernel=spec.name, impl=impl, dtype=dtype,
            n=dict(params).get("n_rows"),
        ) from e
    if faults.poisoned(site):
        out = _poison_value(out)
    return out


# ---------------------------------------------------------------------------
# Adapter helpers
# ---------------------------------------------------------------------------


def _dense_data(v, what: str):
    if not isinstance(v, WVec):
        raise KernelPlanError(f"{what}: expected a vector value")
    if not v.is_dense:
        raise KernelPlanError(f"{what}: kernel path requires a dense vector")
    return v.data


def _elem_of(arrays):
    return arrays[0] if len(arrays) == 1 else tuple(arrays)


def _as_col(v, n):
    """Broadcast a staged per-element result to a full (n,) column."""
    v = jnp.asarray(v)
    if v.ndim >= 1 and v.shape[0] == n:
        return v
    return jnp.broadcast_to(v, (n,) + v.shape)


# ---------------------------------------------------------------------------
# Adapters
# ---------------------------------------------------------------------------


def _exec_filter_reduce(args, params, fns, impl):
    """(iters...) + staged val/pred bodies -> scalar (or struct of) sums.

    Multi-aggregate calls (weldrel's struct-of-mergers ``agg``) stack
    the staged value columns and take the fused multi-output kernel, so
    the predicate mask and the column tiles are loaded once for ALL
    aggregates instead of once per aggregate.  ``multi=False`` in params
    forces the per-aggregate path (parity tests / ablation)."""
    arrays = [_dense_data(a, "filter_reduce") for a in args]
    n = arrays[0].shape[0]
    idx = jnp.arange(n, dtype=jnp.int64)
    elem = _elem_of(arrays)
    n_aggs = params["n_aggs"]
    block = params.get("block")
    if params["has_pred"]:
        pred = _as_col(fns[n_aggs](idx, elem), n).astype(bool)
    else:
        pred = jnp.ones((n,), dtype=bool)
    vals = [_as_col(fns[k](idx, elem), n) for k in range(n_aggs)]
    fuse = (
        params.get("multi", True)
        and n_aggs > 1
        and len({v.dtype for v in vals}) == 1
    )
    if fuse:
        fused = kops.filter_reduce_sum_multi(jnp.stack(vals), pred,
                                             impl=impl, block=block)
        outs = [fused[k] for k in range(n_aggs)]
    else:
        outs = [kops.filter_reduce_sum(v, pred, impl=impl, block=block)
                for v in vals]
    return tuple(outs) if params["struct"] else outs[0]


def _exec_vecmerger_segment_sum(args, params, fns, impl):
    """base + scatter-add of staged {index, value} pairs via segment_sum."""
    base = _dense_data(args[0], "vecmerger base")
    arrays = [_dense_data(a, "vecmerger") for a in args[1:]]
    n = arrays[0].shape[0]
    idx = jnp.arange(n, dtype=jnp.int64)
    elem = _elem_of(arrays)
    seg = _as_col(fns[0](idx, elem), n).astype(jnp.int32)
    vals = _as_col(fns[1](idx, elem), n).astype(base.dtype)
    k = base.shape[0]
    out = base + kops.segment_sum(seg, vals, num_segments=k, impl=impl,
                                  block=params.get("block"))
    return WVec(out)


def _exec_dict_group_sum(args, params, fns, impl):
    """Dense-int-key group-by-sum: one-hot MXU accumulation + compaction.

    The route assumes keys in [0, capacity).  Rows failing the (optional)
    loop predicate are masked out; rows that PASS the predicate but carry
    an out-of-range key cannot be aggregated here — the generic sort path
    would have kept them — so the result is flagged (negative count) and
    decoding raises instead of returning a silently-short dict.
    """
    arrays = [_dense_data(a, "dict group") for a in args]
    n = arrays[0].shape[0]
    idx = jnp.arange(n, dtype=jnp.int64)
    elem = _elem_of(arrays)
    cap = int(params["capacity"])
    keys = _as_col(fns[0](idx, elem), n).astype(jnp.int64)
    vals = _as_col(fns[1](idx, elem), n)
    if params.get("has_pred"):
        mask = _as_col(fns[2](idx, elem), n).astype(bool)
    else:
        mask = jnp.ones((n,), dtype=bool)
    inrange = (keys >= 0) & (keys < cap)
    overflow = jnp.any(mask & ~inrange)
    valid = mask & inrange
    # invalid rows contribute zero to segment 0 (sum identity)
    seg = jnp.where(valid, keys, 0).astype(jnp.int32)
    vals_m = jnp.where(valid, vals, jnp.zeros((), vals.dtype))
    ones = jnp.where(valid, 1, 0).astype(vals.dtype)
    # one fused launch for sums + presence counts (shared seg-id loads)
    both = kops.segment_sum_vectors(seg, jnp.stack([vals_m, ones], axis=1),
                                    num_segments=cap, impl=impl,
                                    block=params.get("block"))
    sums, counts = both[:, 0], both[:, 1]
    present = counts > 0
    order = jnp.argsort(~present, stable=True)  # front-pack, keys ascending
    key_dtype = np.dtype(params.get("key_np", "int64"))
    keys_out = jnp.arange(cap, dtype=key_dtype)[order]
    vals_out = sums[order]
    count = present.sum()
    # Overflow guards, layered: the negative count makes host decode raise
    # (WDict.to_numpy); poisoned keys/values cover traced consumers that
    # never decode — KeyExists sees no keys, Lookup yields NaN, so a wrong
    # aggregate cannot propagate as a plausible number.
    count = jnp.where(overflow, -count - 1, count)
    keys_out = jnp.where(overflow, jnp.full_like(keys_out, -1), keys_out)
    if jnp.issubdtype(vals_out.dtype, jnp.floating):
        vals_out = jnp.where(overflow, jnp.full_like(vals_out, jnp.nan),
                             vals_out)
    return WDict(keys_out, vals_out, count)


def _exec_dict_hash_build(args, params, fns, impl):
    """Dictmerger build with arbitrary (sparse) int keys: open-addressing
    hash-to-slot kernel, then per-column segment accumulation over the
    slot ids, then sort-based compaction into the backend's
    sorted-front-packed WDict layout.

    Key space is the same packed-i64 space the generic lowering compares
    in (jaxgen ``_pack_keys``), so probing a hash-built dict and a
    generic dict is indistinguishable.  Overflow (more distinct keys than
    the builder capacity, or a key colliding with the reserved EMPTY
    sentinel) poisons the result with the same negative-count convention
    as the dense group-by route."""
    arrays = [_dense_data(a, "hash build") for a in args]
    n = arrays[0].shape[0]
    idx = jnp.arange(n, dtype=jnp.int64)
    elem = _elem_of(arrays)
    cap = int(params["capacity"])
    nk = int(params.get("n_keys", 1))
    nv = int(params.get("n_vals", 1))
    block = params.get("block")
    key_cols = [
        _as_col(fns[j](idx, elem), n).astype(jnp.int64) for j in range(nk)
    ]
    vals = [_as_col(fns[nk + j](idx, elem), n) for j in range(nv)]
    if params.get("has_pred"):
        mask = _as_col(fns[nk + nv](idx, elem), n).astype(bool)
    else:
        mask = jnp.ones((n,), dtype=bool)
    packed = _pack_keys(tuple(key_cols) if nk > 1 else key_cols[0])
    sentinel_clash = jnp.any(mask & (packed == _ht.EMPTY))
    pk = jnp.where(mask, packed, _ht.EMPTY)
    ctab = _ht.table_size(cap)
    slots, table, used = kops.hash_to_slot(pk, ctab, impl=impl, block=block)
    overflow = (used > cap) | sentinel_clash
    # table slot -> compact position in ascending packed order (matches
    # the generic keyed finalize, so lookups/decodes are layout-identical)
    big = jnp.iinfo(jnp.int64).max
    tsort = jnp.where(table == _ht.EMPTY, big, table)
    order = jnp.argsort(tsort)
    rank = jnp.zeros((ctab,), jnp.int32).at[order].set(
        jnp.arange(ctab, dtype=jnp.int32))
    cslots = jnp.where(slots < ctab, rank[jnp.clip(slots, 0, ctab - 1)],
                       jnp.int32(cap))
    cslots = jnp.where(cslots < cap, cslots, jnp.int32(cap))  # parked/overflow
    key_nps = params.get("key_nps") or (params.get("key_np", "int64"),)
    keys_fin = _recover_key_cols(key_cols, mask, cslots, cap, key_nps,
                                 overflow)
    outs = []
    for v in vals:
        vm = jnp.where(mask, v, jnp.zeros((), v.dtype))
        outs.append(kops.segment_sum(cslots, vm, num_segments=cap,
                                     impl=impl))
    count = jnp.minimum(used.astype(jnp.int64), cap)
    count = jnp.where(overflow, -count - 1, count)
    keys_out = tuple(keys_fin) if nk > 1 else keys_fin[0]
    poisoned = []
    for v in outs:
        if jnp.issubdtype(v.dtype, jnp.floating):
            v = jnp.where(overflow, jnp.full_like(v, jnp.nan), v)
        poisoned.append(v)
    vals_out = tuple(poisoned) if params.get("struct_val") else poisoned[0]
    return WDict(keys_out, vals_out, count)


def _recover_key_cols(key_cols, mask, slots, cap, key_nps, overflow):
    """Per-slot raw key recovery shared by the keyed build adapters:
    every row in a slot holds one key, so a masked ``segment_max`` per
    field reads it back (packing may have dropped high bits); parked
    rows carry slot ``cap`` and fall off the ``[:cap]`` slice, and
    overflow poisons the columns to -1."""
    outs = []
    for kc, knp in zip(key_cols, key_nps):
        src = jnp.where(mask, kc, jnp.iinfo(jnp.int64).min)
        ko = jax.ops.segment_max(src, slots.astype(jnp.int32),
                                 num_segments=cap + 1)[:cap]
        ko = ko.astype(np.dtype(knp))
        outs.append(jnp.where(overflow, jnp.full_like(ko, -1), ko))
    return outs


def _probe_membership(args, params, fns, impl, nk, n_iters=None):
    """Shared prologue of the probe adapters: stage the probe-side
    columns, pack the (possibly multi-column) query keys into the i64
    key space, neutralize the table's parked slots, and run ONE
    membership kernel — ``dict_probe`` for dict tables, the fused
    membership + match-count ``group_probe`` for group (m:n) tables.
    Returns ``(n, idx, elem, pos, found, sizes, cap)`` with ``sizes``
    None for dicts."""
    d = args[0]
    if not isinstance(d, (WDict, WGroup)):
        raise KernelPlanError("probe: expected a dict/group value")
    is_group = isinstance(d, WGroup)
    tail = args[1:] if n_iters is None else args[1:1 + n_iters]
    arrays = [_dense_data(a, "hash probe") for a in tail]
    n = arrays[0].shape[0]
    idx = jnp.arange(n, dtype=jnp.int64)
    elem = _elem_of(arrays)
    key_cols = [
        _as_col(fns[j](idx, elem), n).astype(jnp.int64) for j in range(nk)
    ]
    keys_q = _pack_keys(tuple(key_cols) if nk > 1 else key_cols[0])
    packed_t = _pack_keys(d.keys)
    cap = packed_t.shape[0]
    cnt = jnp.maximum(jnp.asarray(d.count, jnp.int64), 0)
    sizes = jnp.zeros((n,), jnp.int64) if is_group else None
    if cap == 0:
        pos = jnp.zeros((n,), jnp.int32)
        found = jnp.zeros((n,), dtype=bool)
    else:
        big = jnp.iinfo(jnp.int64).max
        neut = jnp.where(jnp.arange(cap) < cnt, packed_t, big)
        if is_group:
            pos, found, sizes = kops.group_probe(
                neut, d.offsets, cnt, keys_q, impl=impl,
                block=params.get("block"))
            sizes = sizes.astype(jnp.int64)
        else:
            pos, found = kops.dict_probe(neut, cnt, keys_q, impl=impl,
                                         block=params.get("block"))
    return n, idx, elem, pos, found, sizes, cap


def _exec_hash_probe(args, params, fns, impl):
    """Probe a dict with per-row keys; keep matching rows (front-packed)
    and emit either the looked-up value column (``gather``) or a staged
    elementwise expression over the probe row.  The positional probe
    kernel serves every value dtype — the gather itself is a plain jnp
    indexing outside the kernel.

    Fused calls (``cols`` in params — weldrel's horizontally fused join
    probe) dispatch to :func:`_exec_hash_probe_fused`: ONE membership
    kernel launch shared by every output column."""
    if "cols" in params:
        return _exec_hash_probe_fused(args, params, fns, impl)
    d = args[0]
    n, idx, elem, pos, found, _, cap = _probe_membership(
        args, params, fns, impl, nk=1)
    gather = bool(params.get("gather"))
    if params.get("has_pred"):
        mask = _as_col(fns[1 if gather else 2](idx, elem), n).astype(bool)
        found = found & mask
    if gather:
        field = int(params.get("field", -1))
        vcol = d.vals[field] if isinstance(d.vals, tuple) else d.vals
        if cap == 0 or vcol.shape[0] == 0:
            out = jnp.zeros((n,), vcol.dtype)
        else:
            out = vcol[jnp.clip(pos, 0, vcol.shape[0] - 1)]
    else:
        out = _as_col(fns[1](idx, elem), n)
    order = jnp.argsort(~found, stable=True)  # front-pack kept rows
    count = jnp.where(jnp.asarray(d.count, jnp.int64) < 0,
                      jnp.int64(-1), found.sum().astype(jnp.int64))
    return WVec(out[order], count=count)


def _exec_hash_probe_fused(args, params, fns, impl):
    """Horizontally fused join probe: ONE ``dict_probe`` launch computes
    the found-mask/positions for the (possibly multi-column, packed)
    keys, then EVERY output column reuses them — build-side columns as
    plain gathers, probe-side columns as staged expressions, and all
    columns sharing a single front-pack sort.

    ``how`` selects the row semantics: ``inner`` keeps found rows,
    ``anti`` keeps misses (left columns only), and ``left`` keeps every
    row — misses in gathered columns fill from the per-column ``fills``
    (the planner lifts them off the ``lookup(d, k, fill)`` defaults)
    instead of front-packing, so no second probe pass exists anywhere."""
    d = args[0]
    how = params["how"]
    nk = int(params.get("n_keys", 1))
    n, idx, elem, pos, found, _, cap = _probe_membership(
        args, params, fns, impl, nk=nk)
    mask = None
    if params.get("has_pred"):
        mask = _as_col(fns[-1](idx, elem), n).astype(bool)
    outs = []
    for (kind, j), fill in zip(params["cols"], params["fills"]):
        if kind == "expr":
            col = _as_col(fns[nk + j](idx, elem), n)
        else:
            vcol = d.vals[j] if isinstance(d.vals, tuple) else d.vals
            if cap == 0 or vcol.shape[0] == 0:
                col = jnp.zeros((n,), vcol.dtype)
            else:
                col = vcol[jnp.clip(pos, 0, vcol.shape[0] - 1)]
            if how == "left":
                col = jnp.where(found, col, jnp.asarray(fill, vcol.dtype))
        outs.append(col)
    keep = {"inner": found, "anti": ~found, "left": None}[how]
    if mask is not None:
        keep = mask if keep is None else keep & mask
    poisoned = jnp.asarray(d.count, jnp.int64) < 0
    if keep is None:  # left join, no predicate: every row survives
        count = jnp.where(poisoned, jnp.int64(-1), jnp.int64(n))
        return tuple(WVec(c, count=count) for c in outs)
    order = jnp.argsort(~keep, stable=True)  # ONE shared front-pack
    count = jnp.where(poisoned, jnp.int64(-1),
                      keep.sum().astype(jnp.int64))
    return tuple(WVec(c[order], count=count) for c in outs)


def _exec_group_build(args, params, fns, impl):
    """Groupbuilder build (the m:n join build side): hash-to-slot over
    the packed keys, slot-histogram compaction into CSR offsets, and the
    payload column sorted by (ascending key, build-row order) — the
    layout the generic keyed finalize produces, so the probe side is
    indistinguishable.  Overflow (more distinct keys than the builder
    capacity, or a key hitting the reserved EMPTY sentinel) poisons via
    the shared negative-count convention."""
    arrays = [_dense_data(a, "group build") for a in args]
    n = arrays[0].shape[0]
    idx = jnp.arange(n, dtype=jnp.int64)
    elem = _elem_of(arrays)
    cap = int(params["capacity"])
    nk = int(params.get("n_keys", 1))
    block = params.get("block")
    key_cols = [
        _as_col(fns[j](idx, elem), n).astype(jnp.int64) for j in range(nk)
    ]
    val = _as_col(fns[nk](idx, elem), n)
    if params.get("has_pred"):
        mask = _as_col(fns[nk + 1](idx, elem), n).astype(bool)
    else:
        mask = jnp.ones((n,), dtype=bool)
    packed = _pack_keys(tuple(key_cols) if nk > 1 else key_cols[0])
    sentinel_clash = jnp.any(mask & (packed == _ht.EMPTY))
    pk = jnp.where(mask, packed, _ht.EMPTY)
    cslots, offsets, used = kops.group_build(pk, cap, impl=impl, block=block)
    overflow = (used > cap) | sentinel_clash
    # CSR payload ordering: ascending compact slot, stable — within a
    # group, build-row order (identical to the generic keyed finalize)
    order = jnp.argsort(cslots, stable=True)
    values = val[order]
    key_nps = params.get("key_nps") or ("int64",)
    keys_fin = _recover_key_cols(key_cols, mask, cslots, cap, key_nps,
                                 overflow)
    keys_out = tuple(keys_fin) if nk > 1 else keys_fin[0]
    count = jnp.minimum(used.astype(jnp.int64), cap)
    count = jnp.where(overflow, -count - 1, count)
    return WGroup(keys_out, values, offsets, count)


def _exec_group_probe(args, params, fns, impl):
    """The m:n join fan-out probe: ONE fused membership + match-count
    launch (``kops.group_probe``) for the packed keys, then the shared
    two-phase expansion (exclusive scan over the per-row counts, binary
    search back to source rows, repeat/gather) materializes EVERY output
    column through one expansion index — probe columns repeat, build
    columns gather through the group's stored row ids, left-join misses
    emit one fill row.  Poison propagates as a negative output count."""
    d = args[0]
    if not isinstance(d, WGroup):
        raise KernelPlanError("group_probe: expected a groupbuilder value")
    if isinstance(d.values, tuple):
        raise KernelPlanError("group_probe: scalar payloads only")
    how = params["how"]
    nk = int(params.get("n_keys", 1))
    n_iters = int(params.get("n_iters", 1))
    n, idx, elem, pos, found, sizes, cap = _probe_membership(
        args, params, fns, impl, nk=nk, n_iters=n_iters)
    if params.get("has_pred"):
        mask = _as_col(fns[-1](idx, elem), n).astype(bool)
    else:
        mask = jnp.ones((n,), dtype=bool)
    col_specs = []
    for (kind, j), fill in zip(params["cols"], params["fills"]):
        if kind == "expr":
            col_specs.append(("expr", _as_col(fns[nk + j](idx, elem), n)))
        else:
            rv = _dense_data(args[j], "group probe gather")
            col_specs.append(("gather", rv, fill))
    return group_expand(d, pos, found, sizes, mask, how,
                        int(params["out_cap"]), col_specs)


def _tiles(params) -> dict:
    return {k: params.get(k) for k in ("bm", "bn", "bk")}


def _exec_matmul(args, params, fns, impl):
    a = _dense_data(args[0], "matmul lhs")
    b = _dense_data(args[1], "matmul rhs")
    ct = jnp.result_type(a, b)
    return WVec(kops.matmul(a.astype(ct), b.astype(ct), impl=impl,
                            **_tiles(params)))


def _exec_matvec(args, params, fns, impl):
    a = _dense_data(args[0], "matvec lhs")
    b = _dense_data(args[1], "matvec rhs")
    ct = jnp.result_type(a, b)
    out = kops.matmul(a.astype(ct), b[:, None].astype(ct), impl=impl,
                      **_tiles(params))
    return WVec(out[:, 0])


def _exec_map_elementwise(args, params, fns, impl):
    arrays = [_dense_data(a, "map chain") for a in args]

    def body(*cols):
        # the staged lambda is (i, x); map-chain matching guarantees the
        # index is unused, so bind a dummy scalar.
        return fns[0](jnp.int64(0), _elem_of(list(cols)))

    return WVec(kops.map_elementwise(body, arrays, impl=impl,
                                     block=params.get("block")))


# ---------------------------------------------------------------------------
# Footprints: padding + scratch bytes one call adds to the HBM budget.
# (arg_shapes are the dense arg shapes at trace time; itemsize is the
# result element width.)  Charged by the emitter against memory_limit.
# ---------------------------------------------------------------------------


def _pad_of(n: int, block: int) -> int:
    return (-n) % max(block, 1)


def _fp_filter_reduce(arg_shapes, itemsize, params):
    n = arg_shapes[0][0] if arg_shapes and arg_shapes[0] else 0
    pad = _pad_of(n, params.get("block") or _fr.BLOCK)
    aggs = params.get("n_aggs", 1)
    # staged value columns (one per agg; stacked when fused) + pred mask
    scratch = aggs * (n + pad) * itemsize + (n + pad)
    return pad * len(arg_shapes) * itemsize + scratch


def _fp_vecmerger(arg_shapes, itemsize, params):
    n = arg_shapes[1][0] if len(arg_shapes) > 1 and arg_shapes[1] else 0
    pad = _pad_of(n, params.get("block") or _sr.BLOCK_N)
    # staged seg-id (i32) and value columns + the padded tails
    return (n + pad) * (4 + itemsize) + pad * itemsize * (len(arg_shapes) - 1)


def _fp_dict_group(arg_shapes, itemsize, params):
    n = arg_shapes[0][0] if arg_shapes and arg_shapes[0] else 0
    cap = int(params.get("capacity", 0))
    pad = _pad_of(n, params.get("block") or 256)
    # staged keys/mask + the stacked (n, 2) value matrix + K-compaction
    return (n + pad) * (4 + 2 * itemsize + 1) + cap * (3 * itemsize + 8)


def _fp_hash_build(arg_shapes, itemsize, params):
    n = arg_shapes[0][0] if arg_shapes and arg_shapes[0] else 0
    cap = int(params.get("capacity", 0))
    ctab = _ht.table_size(cap) if cap else 16
    pad = _pad_of(n, params.get("block") or _ht.BLOCK_N)
    nv = int(params.get("n_vals", 1))
    # staged packed keys + slots + per-column staged values, the VMEM
    # table + rank permutation, and the compacted key/value columns
    return ((n + pad) * (8 + 4 + nv * itemsize)
            + ctab * (8 + 8) + cap * (nv * itemsize + 8))


def _fp_hash_probe(arg_shapes, itemsize, params):
    n = arg_shapes[1][0] if len(arg_shapes) > 1 and arg_shapes[1] else 0
    block = params.get("block") or _hp.BLOCK_N
    pad = _pad_of(n, block)
    cap = int(params.get("k", 0))
    cols = max(len(params.get("cols", ())), 1)
    # staged packed queries + pos/found columns + the (per output
    # column) gathered/compacted outputs, plus the neutralized key
    # table and the block x cap one-hot tile — shared across columns
    return ((n + pad) * (8 + 4 + 1 + cols * itemsize) + n * cols * itemsize
            + cap * 8 + block * cap * 5)


def _fp_group_build(arg_shapes, itemsize, params):
    n = arg_shapes[0][0] if arg_shapes and arg_shapes[0] else 0
    cap = int(params.get("capacity", 0))
    ctab = _ht.table_size(cap) if cap else 16
    pad = _pad_of(n, params.get("block") or _gb.BLOCK_N)
    # staged packed keys + slots + payload column + the ordering sort,
    # the VMEM table + rank + counts, and the CSR offsets/key columns
    return ((n + pad) * (8 + 4 + itemsize + 8)
            + ctab * (8 + 8) + (cap + 1) * 4 + cap * 8)


def _fp_group_probe(arg_shapes, itemsize, params):
    n = arg_shapes[1][0] if len(arg_shapes) > 1 and arg_shapes[1] else 0
    block = params.get("block") or _hp.BLOCK_N
    pad = _pad_of(n, block)
    cap = int(params.get("k", 0))
    out = int(params.get("out_cap", 0))
    cols = max(len(params.get("cols", ())), 1)
    # staged packed queries + pos/found/size columns, the one-hot tile
    # (keys + sizes lanes), and the expanded output buffers every
    # column shares (the expansion-factor term of the memory budget)
    return ((n + pad) * (8 + 4 + 1 + 4) + out * (cols * itemsize + 8 + 8)
            + cap * (8 + 4) + block * cap * 6)


def _fp_matmul(arg_shapes, itemsize, params):
    if len(arg_shapes) < 2 or not arg_shapes[0] or not arg_shapes[1]:
        return 0
    m, k = arg_shapes[0][0], arg_shapes[0][1] if len(arg_shapes[0]) > 1 else 1
    n = arg_shapes[1][1] if len(arg_shapes[1]) > 1 else 1
    bm = params.get("bm") or 256
    bn = params.get("bn") or 256
    bk = params.get("bk") or 512
    mp, kp, np_ = m + _pad_of(m, bm), k + _pad_of(k, bk), n + _pad_of(n, bn)
    return (mp * kp - m * k + kp * np_ - k * n + mp * np_ - m * n) * itemsize


def _fp_map_chain(arg_shapes, itemsize, params):
    n = arg_shapes[0][0] if arg_shapes and arg_shapes[0] else 0
    pad = _pad_of(n, params.get("block") or _mc.BLOCK)
    return pad * (len(arg_shapes) + 1) * itemsize


# ---------------------------------------------------------------------------
# Autotune benches: synthetic workloads matching the tuned call's shape.
# (meta carries n / k / dims / dtype; params is one candidate point.)
# ---------------------------------------------------------------------------


def _bench_filter_reduce(meta, params, impl):
    n = int(meta["n"])
    x = jnp.ones((n,), meta.get("dtype", jnp.float64))
    p = jnp.ones((n,), bool)

    def go():
        jax.block_until_ready(kops.filter_reduce_sum(
            x, p, impl=impl, block=params.get("block")))

    return go


def _bench_vecmerger(meta, params, impl):
    n = int(meta["n"])
    k = int(meta.get("k") or 256)
    seg = (jnp.arange(n, dtype=jnp.int32) % max(min(k, _sr.MAX_K), 1))
    vals = jnp.ones((n,), meta.get("dtype", jnp.float64))

    def go():
        jax.block_until_ready(kops.segment_sum(
            seg, vals, num_segments=min(k, _sr.MAX_K), impl=impl,
            block=params.get("block")))

    return go


def _bench_dict_group(meta, params, impl):
    n = int(meta["n"])
    k = int(meta.get("k") or 256)
    seg = (jnp.arange(n, dtype=jnp.int32) % max(min(k, _sr.MAX_K), 1))
    vals = jnp.ones((n, 2), meta.get("dtype", jnp.float64))

    def go():
        jax.block_until_ready(kops.segment_sum_vectors(
            seg, vals, num_segments=min(k, _sr.MAX_K), impl=impl,
            block=params.get("block")))

    return go


def _bench_hash_build(meta, params, impl):
    # the insert chain is serial: cap the synthetic size so first-touch
    # tuning stays cheap (relative block ordering stabilizes well below
    # real workload sizes)
    n = min(int(meta["n"]), 8192)
    k = max(int(meta.get("k") or 256), 1)
    keys = (jnp.arange(n, dtype=jnp.int64) % k) * 7 + 3
    ctab = _ht.table_size(k)

    def go():
        jax.block_until_ready(kops.hash_to_slot(
            keys, ctab, impl=impl, block=params.get("block")))

    return go


def _bench_hash_probe(meta, params, impl):
    n = int(meta["n"])
    k = max(int(meta.get("k") or 256), 1)
    table = jnp.arange(k, dtype=jnp.int64) * 3
    queries = (jnp.arange(n, dtype=jnp.int64) % (2 * k)) * 3  # ~50% hits

    def go():
        jax.block_until_ready(kops.dict_probe(
            table, k, queries, impl=impl, block=params.get("block")))

    return go


def _bench_group_build(meta, params, impl):
    # the insert/histogram chains are serial: cap the synthetic size so
    # first-touch tuning stays cheap (same rationale as hash_build)
    n = min(int(meta["n"]), 8192)
    k = max(int(meta.get("k") or 256), 1)
    keys = (jnp.arange(n, dtype=jnp.int64) % k) * 7 + 3

    def go():
        jax.block_until_ready(kops.group_build(
            keys, k, impl=impl, block=params.get("block")))

    return go


def _bench_group_probe(meta, params, impl):
    n = int(meta["n"])
    k = max(int(meta.get("k") or 256), 1)
    table = jnp.arange(k, dtype=jnp.int64) * 3
    offsets = (jnp.arange(k + 1, dtype=jnp.int32) * 4)  # fan-out 4
    queries = (jnp.arange(n, dtype=jnp.int64) % (2 * k)) * 3  # ~50% hits

    def go():
        jax.block_until_ready(kops.group_probe(
            table, offsets, k, queries, impl=impl,
            block=params.get("block")))

    return go


def _bench_matmul(meta, params, impl):
    m, k, n = (int(d) for d in meta["dims"])
    a = jnp.ones((m, k), meta.get("dtype", jnp.float64))
    b = jnp.ones((k, n), meta.get("dtype", jnp.float64))

    def go():
        jax.block_until_ready(kops.matmul(
            a, b, impl=impl, bm=params.get("bm"), bn=params.get("bn"),
            bk=params.get("bk")))

    return go


def _bench_map_chain(meta, params, impl):
    n = int(meta["n"])
    x = jnp.ones((n,), meta.get("dtype", jnp.float64))

    def go():
        jax.block_until_ready(kops.map_elementwise(
            lambda c: c * 2.0 + 1.0, [x], impl=impl,
            block=params.get("block")))

    return go


# ---------------------------------------------------------------------------
# The shipped registry (one entry per reachable Pallas kernel)
# ---------------------------------------------------------------------------

register(KernelSpec(
    name="filter_reduce_sum",
    entry="repro.kernels.ops:filter_reduce_sum",
    pattern="filter_reduce",
    builder="merger[+]",
    elem_kinds=("f32", "f64", "i32", "i64"),
    description="predicated sum over a (possibly multi-column) loop; the "
                "fused form of Listing 10 / TPC-H Q6; multi-aggregate "
                "struct matches fuse into one multi-output launch",
    execute=_exec_filter_reduce,
    cost=_cost.cost_filter_reduce,
    tune_space={"block": _fr.BLOCK_CANDIDATES},
    tune_defaults={"block": _fr.BLOCK},
    make_bench=_bench_filter_reduce,
    footprint=_fp_filter_reduce,
))

register(KernelSpec(
    name="vecmerger_segment_sum",
    entry="repro.kernels.ops:segment_sum",
    pattern="vecmerger_scatter",
    builder="vecmerger[+]",
    elem_kinds=("f32", "f64"),
    description="scatter-add into a dense base vector as one-hot MXU "
                "segment sums (PageRank's edge scan)",
    max_segments=_sr.MAX_K,  # beyond this, kops serves the ref scatter:
                             # the cost gate prices that route as a loss
    execute=_exec_vecmerger_segment_sum,
    cost=_cost.cost_vecmerger,
    tune_space={"block": _sr.BLOCK_CANDIDATES},
    tune_defaults={"block": _sr.BLOCK_N},
    make_bench=_bench_vecmerger,
    footprint=_fp_vecmerger,
))

register(KernelSpec(
    name="dict_group_sum",
    entry="repro.kernels.ops:segment_sum_vectors",
    pattern="dict_group",
    builder="dictmerger[+]",
    elem_kinds=("f32", "f64", "i32", "i64"),
    description="group-by-sum with dense int keys in [0, capacity) via "
                "segment_sum + presence compaction",
    max_segments=_sr.MAX_K,
    execute=_exec_dict_group_sum,
    cost=_cost.cost_dict_group,
    tune_space={"block": (128, 256, 512)},
    tune_defaults={"block": 256},
    make_bench=_bench_dict_group,
    footprint=_fp_dict_group,
))

register(KernelSpec(
    name="dict_hash_build",
    entry="repro.kernels.ops:hash_to_slot",
    pattern="dict_hash_build",
    builder="dictmerger[+]",
    elem_kinds=("f32", "f64", "i32", "i64"),
    description="open-addressing hash build for sparse/non-dense int "
                "keys, scalar or multi-column struct (hash-join build "
                "side; also the group-by fallback beyond the dense "
                "segment route's capacity)",
    max_segments=_ht.MAX_CAP,
    execute=_exec_dict_hash_build,
    cost=_cost.cost_hash_build,
    tune_space={"block": _ht.BLOCK_CANDIDATES},
    tune_defaults={"block": _ht.BLOCK_N},
    make_bench=_bench_hash_build,
    footprint=_fp_hash_build,
))

register(KernelSpec(
    name="hash_probe",
    entry="repro.kernels.ops:dict_probe",
    pattern="hash_probe",
    builder="vecbuilder",
    elem_kinds=("f32", "f64", "i32", "i64"),
    description="one-hot MXU dict probe: one membership launch shared "
                "by every join output column (inner filter / left "
                "fill-on-miss / anti), gathers outside the kernel",
    max_segments=_ht.MAX_CAP,
    execute=_exec_hash_probe,
    cost=_cost.cost_hash_probe,
    tune_space={"block": _hp.BLOCK_CANDIDATES},
    tune_defaults={"block": _hp.BLOCK_N},
    make_bench=_bench_hash_probe,
    footprint=_fp_hash_probe,
))

register(KernelSpec(
    name="group_build",
    entry="repro.kernels.ops:group_build",
    pattern="group_build",
    builder="groupbuilder",
    elem_kinds=("i32", "i64"),
    description="CSR group build (key -> growing vector of build-row "
                "payloads) via hash-to-slot + slot-histogram compaction "
                "— the m:n hash-join build side",
    max_segments=_ht.MAX_CAP,
    execute=_exec_group_build,
    cost=_cost.cost_group_build,
    tune_space={"block": _gb.BLOCK_CANDIDATES},
    tune_defaults={"block": _gb.BLOCK_N},
    make_bench=_bench_group_build,
    footprint=_fp_group_build,
))

register(KernelSpec(
    name="group_probe",
    entry="repro.kernels.ops:group_probe",
    pattern="group_probe",
    builder="vecbuilder",
    elem_kinds=("bool", "i8", "i32", "i64", "f32", "f64"),
    description="m:n join fan-out probe: ONE fused membership + "
                "match-count launch shared by every output column, "
                "then the two-phase expansion (scan + repeat/gather) "
                "outside the kernel",
    max_segments=_ht.MAX_CAP,
    execute=_exec_group_probe,
    cost=_cost.cost_group_probe,
    tune_space={"block": _hp.BLOCK_CANDIDATES},
    tune_defaults={"block": _hp.BLOCK_N},
    make_bench=_bench_group_probe,
    footprint=_fp_group_probe,
))

register(KernelSpec(
    name="matmul",
    entry="repro.kernels.ops:matmul",
    pattern="linalg.matmul",
    builder="-",
    elem_kinds=("f32", "f64"),
    description="tiled VMEM-blocked matmul for raised 2-D dot loops",
    execute=_exec_matmul,
    cost=_cost.cost_matmul,
    tune_space={"bm": _tm.BM_CANDIDATES, "bn": _tm.BN_CANDIDATES,
                "bk": _tm.BK_CANDIDATES},
    tune_defaults={"bm": 256, "bn": 256, "bk": 512},
    make_bench=_bench_matmul,
    footprint=_fp_matmul,
))

register(KernelSpec(
    name="matvec",
    entry="repro.kernels.ops:matmul",
    pattern="linalg.matvec",
    builder="-",
    elem_kinds=("f32", "f64"),
    description="matrix-vector product through the tiled matmul kernel",
    execute=_exec_matvec,
    cost=_cost.cost_matmul,
    tune_space={"bm": _tm.BM_CANDIDATES, "bk": _tm.BK_CANDIDATES},
    tune_defaults={"bm": 256, "bk": 512},
    make_bench=None,  # shares the matmul entry; tuned via matmul dims
    footprint=_fp_matmul,
))

register(KernelSpec(
    name="map_elementwise",
    entry="repro.kernels.ops:map_elementwise",
    pattern="map_chain",
    builder="vecbuilder",
    elem_kinds=("f32", "f64", "i32", "i64"),
    description="fused elementwise map chain staged into one Pallas pass "
                "(Black-Scholes-style operator chains)",
    execute=_exec_map_elementwise,
    cost=_cost.cost_map_chain,
    tune_space={"block": _mc.BLOCK_CANDIDATES},
    tune_defaults={"block": _mc.BLOCK},
    make_bench=_bench_map_chain,
    footprint=_fp_map_chain,
))
