"""Logical-axis sharding rules (DESIGN.md §5).

Models annotate every parameter/input/cache leaf with *logical* axis
names; this module maps them to mesh axes with a priority-rule table,
respecting divisibility and never using a mesh axis twice in one spec.
The Megatron column/row TP pattern, EP for experts, and hierarchical DP
over (pod, data) all fall out of one rule table:

    heads/kv_heads/mlp/vocab/experts -> model   (TP / EP)
    head_dim -> model                           (fallback when the head
                                                 count doesn't divide)
    batch -> (pod, data)                        (hierarchical DP)

ZeRO-1: optimizer-moment leaves additionally shard their first
replicated-and-divisible dimension over 'data'.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisCandidates = Sequence[Union[str, Tuple[str, ...]]]

#: priority-ordered mesh-axis candidates per logical axis
DEFAULT_RULES: Dict[str, List] = {
    "mlp": ["model"],
    "heads": ["model"],
    "kv_heads": ["model"],
    "head_dim": ["model"],
    "vocab": ["model"],
    "experts": ["model"],
    "embed": [],            # replicated (activations gather over it anyway)
    "state": [],
    "layers": [],
    "batch": [("pod", "data"), "data"],
    "seq": ["model"],       # sequence parallelism for long-context decode
}


def _size(mesh: Mesh, axis: Union[str, Tuple[str, ...]]) -> int:
    if isinstance(axis, tuple):
        return int(np.prod([_size(mesh, a) for a in axis]))
    return mesh.shape.get(axis, 0)


def spec_for_leaf(shape: Tuple[int, ...], axes: Sequence[Optional[str]],
                  mesh: Mesh, rules: Optional[Dict] = None) -> P:
    """Build a PartitionSpec for one leaf: walk dims left→right, take the
    first unused, divisible candidate for each logical axis."""
    rules = dict(DEFAULT_RULES, **(rules or {}))
    used: set = set()
    parts = []
    assert len(axes) == len(shape), f"spec rank mismatch {axes} vs {shape}"
    for dim, name in zip(shape, axes):
        chosen = None
        if name is not None:
            for cand in rules.get(name, []):
                flat = cand if isinstance(cand, tuple) else (cand,)
                if any(a in used for a in flat):
                    continue
                if any(a not in mesh.shape for a in flat):
                    continue
                sz = _size(mesh, cand)
                if sz and dim % sz == 0:
                    chosen = cand
                    used.update(flat)
                    break
        parts.append(chosen)
    # trailing Nones can be dropped but keep explicit for readability
    return P(*parts)


def tree_shardings(spec_tree, shape_tree, mesh: Mesh,
                   rules: Optional[Dict] = None):
    """Map a tree of logical-axes tuples + a matching tree of
    shapes/ShapeDtypeStructs to NamedShardings."""

    def is_spec(x):
        return isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x
        )

    flat_specs = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: is_spec(x) or x == ())[0]
    flat_shapes, treedef = jax.tree_util.tree_flatten(shape_tree)
    assert len(flat_specs) == len(flat_shapes), (
        f"spec/shape tree mismatch: {len(flat_specs)} vs {len(flat_shapes)}"
    )
    out = []
    for spec, leaf in zip(flat_specs, flat_shapes):
        shape = leaf.shape if hasattr(leaf, "shape") else tuple(leaf)
        out.append(NamedSharding(
            mesh, spec_for_leaf(tuple(shape), spec, mesh, rules)))
    return jax.tree_util.tree_unflatten(treedef, out)


def zero1_moment_shardings(param_spec_tree, shape_tree, mesh: Mesh,
                           rules: Optional[Dict] = None):
    """ZeRO-1: like the param sharding but with the first replicated,
    divisible dim additionally sharded over 'data'."""

    def is_spec(x):
        return isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x
        )

    flat_specs = jax.tree_util.tree_flatten(
        param_spec_tree, is_leaf=lambda x: is_spec(x) or x == ())[0]
    flat_shapes, treedef = jax.tree_util.tree_flatten(shape_tree)
    dsz = mesh.shape.get("data", 1)
    out = []
    for spec, leaf in zip(flat_specs, flat_shapes):
        shape = tuple(leaf.shape if hasattr(leaf, "shape") else leaf)
        base = spec_for_leaf(shape, spec, mesh, rules)
        parts = list(base) + [None] * (len(shape) - len(base))
        used = {a for p in parts if p is not None
                for a in (p if isinstance(p, tuple) else (p,))}
        if "data" in mesh.shape and dsz > 1 and "data" not in used:
            for i, (dim, cur) in enumerate(zip(shape, parts)):
                if cur is None and dim % dsz == 0 and dim >= dsz:
                    parts[i] = "data"
                    break
        out.append(NamedSharding(mesh, P(*parts)))
    return jax.tree_util.tree_unflatten(treedef, out)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
