"""Distribution layer: logical-axis sharding rules, elastic re-sharding,
gradient compression, straggler monitoring."""
