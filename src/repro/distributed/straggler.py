"""Straggler mitigation (DESIGN.md §5).

On a synchronous SPMD mesh a slow host stalls every step, so detection +
policy lives on the host side:

  * `StepMonitor` — per-step wall-time tracker flagging outliers against
    a rolling median (the signal real fleets page on);
  * policy hooks — on sustained straggle the trainer (a) snapshots via the
    async checkpointer and (b) requests an elastic re-shard excluding the
    slow host (`elastic.remesh`), the standard large-fleet mitigation.
    Data-shard handoff is covered because the pipeline state is part of
    the checkpoint.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional


@dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float
    ratio: float


@dataclass
class StepMonitor:
    threshold: float = 2.0          # x median => straggler
    window: int = 32
    patience: int = 3               # consecutive flags before escalation
    on_escalate: Optional[Callable[[StragglerEvent], None]] = None
    _durations: List[float] = field(default_factory=list)
    _consecutive: int = 0
    events: List[StragglerEvent] = field(default_factory=list)
    escalations: int = 0
    _t0: float = 0.0
    _step: int = 0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> Optional[StragglerEvent]:
        dt = time.perf_counter() - self._t0
        self._step += 1
        hist = self._durations[-self.window:]
        self._durations.append(dt)
        if len(hist) < 5:
            return None
        med = statistics.median(hist)
        if dt > self.threshold * med:
            ev = StragglerEvent(self._step, dt, med, dt / med)
            self.events.append(ev)
            self._consecutive += 1
            if self._consecutive >= self.patience:
                self.escalations += 1
                self._consecutive = 0
                if self.on_escalate is not None:
                    self.on_escalate(ev)
            return ev
        self._consecutive = 0
        return None

    def summary(self) -> dict:
        d = self._durations
        return {
            "steps": len(d),
            "mean_s": statistics.mean(d) if d else 0.0,
            "median_s": statistics.median(d) if d else 0.0,
            "stragglers": len(self.events),
            "escalations": self.escalations,
        }
