"""Elastic scaling: restore a checkpoint onto a DIFFERENT mesh.

Checkpoints store unsharded host arrays (checkpoint/ckpt.py), so elastic
restart is: build the new mesh, derive shardings from the same logical
rules, `Checkpointer.restore(..., shardings=new)`.  The data pipeline's
shard-stable stream (data/pipeline.py) guarantees the global batch
sequence is unchanged across the re-shard, so training is a pure
continuation.  `remesh` covers the in-memory case (shrink/grow without
going through disk) — used by the straggler-escalation path.
"""
from __future__ import annotations

import jax

from .sharding import tree_shardings


def remesh(state_tree, spec_tree, new_mesh, rules=None):
    """Re-place a live state tree onto a new mesh (gathers to host views
    lazily via device_put; GSPMD moves only what must move)."""
    shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state_tree)
    new_sh = tree_shardings(spec_tree, shapes, new_mesh, rules)
    return jax.tree_util.tree_map(jax.device_put, state_tree, new_sh)
