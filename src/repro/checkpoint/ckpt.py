"""Checkpoint/restart (DESIGN.md §5).

Layout per step:  <dir>/step_<n>/
    manifest.json   — leaf paths, shapes, dtypes, sha256 per file,
                      data-pipeline state, user metadata
    <leaf>.npy      — one file per pytree leaf (unsharded host copy)

Design points for fleet use:
  * **async** — `save()` snapshots to host synchronously (cheap: device→
    host copy) then writes files on a background thread; training resumes
    immediately.  `wait()` joins before the next save or exit.
  * **atomic** — written under `.tmp_step_<n>`, fsync'd, then renamed;
    a crashed save never corrupts the latest-complete pointer.
  * **integrity** — every file carries its sha256 in the manifest and is
    verified on restore.
  * **elastic** — leaves are stored unsharded; restore() device_puts onto
    whatever mesh/sharding the new job supplies (different device count
    included).  See distributed/elastic.py + tests/test_distributed.py.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path).replace("/", "_")
        out[key] = leaf
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save -----------------------------------------------------------------

    def save(self, step: int, state_tree, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        # synchronous device->host snapshot (consistent view)
        host = {k: np.asarray(v) for k, v in _leaf_paths(state_tree).items()}
        meta = {"step": int(step), "extra": extra or {}}

        def write():
            try:
                tmp = os.path.join(self.dir, f".tmp_step_{step}")
                final = os.path.join(self.dir, f"step_{step}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                manifest = {"step": meta["step"], "extra": meta["extra"],
                            "leaves": {}}
                for key, arr in host.items():
                    fn = f"{_safe(key)}.npy"
                    fp = os.path.join(tmp, fn)
                    np.save(fp, arr)
                    with open(fp, "rb") as f:
                        digest = hashlib.sha256(f.read()).hexdigest()
                    manifest["leaves"][key] = {
                        "file": fn, "shape": list(arr.shape),
                        "dtype": str(arr.dtype), "sha256": digest,
                    }
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                    os.fsync(f.fileno())
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint failed: {err!r}") from err

    def _gc(self):
        steps = sorted(self.list_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def list_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template_tree, shardings=None,
                verify: bool = True) -> Tuple[Any, Dict]:
        """Rebuild `template_tree`'s structure from disk.  `shardings`
        (same structure, optional) places leaves onto the current mesh —
        any mesh: this is the elastic-restart path."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        keyed = _leaf_paths(template_tree)
        shard_map_ = _leaf_paths(shardings) if shardings is not None else {}
        out = {}
        for key, leaf in keyed.items():
            entry = manifest["leaves"][key]
            fp = os.path.join(d, entry["file"])
            if verify:
                with open(fp, "rb") as f:
                    digest = hashlib.sha256(f.read()).hexdigest()
                if digest != entry["sha256"]:
                    raise IOError(f"checkpoint corruption in {key}")
            arr = np.load(fp)
            want_dtype = (leaf.dtype if hasattr(leaf, "dtype")
                          else arr.dtype)
            arr = arr.astype(want_dtype, copy=False)
            if key in shard_map_:
                out[key] = jax.device_put(arr, shard_map_[key])
            else:
                out[key] = jax.numpy.asarray(arr)
        # reassemble in template order
        flat, treedef = jax.tree_util.tree_flatten(template_tree)
        paths = list(_leaf_paths(template_tree).keys())
        leaves = [out[k] for k in paths]
        return (jax.tree_util.tree_unflatten(treedef, leaves),
                manifest["extra"] | {"step": manifest["step"]})


def _safe(key: str) -> str:
    return "".join(c if c.isalnum() or c in "._-[]'" else "_" for c in key)
