"""Checkpoint substrate: async, integrity-checked save/restore of the
full training state (params, optimizer, data cursor, step)."""
from .ckpt import Checkpointer  # noqa: F401
