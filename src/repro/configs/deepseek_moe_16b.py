"""deepseek-moe-16b [arXiv:2401.06066; hf]: fine-grained MoE.
28L d_model=2048 16H (MHA kv=16) vocab=102400; 2 shared + 64 routed
experts top-6, expert d_ff=1408; first layer dense (d_ff=10944)."""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=10944, vocab=102_400, mlp_variant="swiglu",
        n_experts=64, n_shared_experts=2, top_k=6, expert_d_ff=1408,
        first_k_dense=1,
        dtype="bfloat16", param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128, mlp_variant="swiglu",
        n_experts=8, n_shared_experts=2, top_k=2, expert_d_ff=32,
        first_k_dense=1, remat=False,
    )


register(full, smoke)
