"""xlstm-350m [arXiv:2405.04517]: 24 blocks, d_model=1024 4H, d_ff=0
(blocks carry their own projections).  mLSTM (matrix memory, chunked)
with sLSTM (sequential scan) every 8th position — the paper's mixed
[m:s] stacking.  Simplification: sigmoid (not exponential) mLSTM gates;
see models/xlstm.py docstring."""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50_304, slstm_every=8, ssm_chunk=128,
        dtype="bfloat16", param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=0, vocab=128, slstm_every=4, ssm_chunk=16, remat=False,
    )


register(full, smoke)
