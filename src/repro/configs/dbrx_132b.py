"""dbrx-132b [hf:databricks/dbrx-base]: coarse MoE.
40L d_model=6144 48H (GQA kv=8) vocab=100352; 16 experts top-4,
expert d_ff=10752 (SwiGLU)."""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=10752, vocab=100_352, mlp_variant="swiglu",
        n_experts=16, n_shared_experts=0, top_k=4, expert_d_ff=10752,
        rope_theta=500_000.0,
        dtype="bfloat16", param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=128, mlp_variant="swiglu",
        n_experts=4, n_shared_experts=0, top_k=2, expert_d_ff=96,
        remat=False,
    )


register(full, smoke)
