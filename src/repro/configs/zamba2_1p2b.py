"""zamba2-1.2b [arXiv:2411.15242; hf]: hybrid — 38 Mamba2 blocks with a
SHARED attention+MLP block applied every 6 blocks (parameter reuse;
per-invocation LoRA deltas omitted — simplification noted here and in
DESIGN.md).  d_model=2048, shared block: 32H MHA + d_ff=8192,
ssm_state=64, vocab=32000."""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32_000, mlp_variant="gelu",
        ssm_state=64, ssm_head_dim=64, ssm_chunk=128, attn_every=6,
        dtype="bfloat16", param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128, mlp_variant="gelu",
        ssm_state=16, ssm_head_dim=16, ssm_chunk=16, attn_every=2,
        remat=False,
    )


register(full, smoke)
