"""llama3.2-3b [hf:meta-llama/Llama-3.2-3B]: small llama3, SwiGLU.
28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256."""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b", family="dense",
        n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab=128_256, mlp_variant="swiglu",
        rope_theta=500_000.0,
        dtype="bfloat16", param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=128, mlp_variant="swiglu", remat=False,
    )


register(full, smoke)
