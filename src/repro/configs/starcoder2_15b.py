"""starcoder2-15b [arXiv:2402.19173; hf]: dense GQA + RoPE code model.
40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152, GeLU MLP."""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b", family="dense",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
        d_ff=24576, vocab=49152, mlp_variant="gelu",
        rope_theta=100_000.0,
        dtype="bfloat16", param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=128, mlp_variant="gelu", remat=False,
    )


register(full, smoke)
