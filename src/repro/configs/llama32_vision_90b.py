"""llama-3.2-vision-90b [hf:meta-llama/Llama-3.2-90B-Vision]: VLM with
cross-attention image layers every 5th layer (100L total = 80 self + 20
cross).  d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
Vision tower is a STUB: input_specs provides precomputed patch
embeddings (1600 tokens x d_vision=1280)."""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=28672, vocab=128_256, mlp_variant="swiglu",
        rope_theta=500_000.0,
        cross_attn_every=5, n_image_tokens=1600, d_vision=1280,
        dtype="bfloat16", param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=128, mlp_variant="swiglu",
        cross_attn_every=2, n_image_tokens=8, d_vision=16, remat=False,
    )


register(full, smoke)
