"""Config schema + registry + the assigned input-shape suite."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


#: the assigned LM shape suite (seq_len × global_batch)
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 => d_model // n_heads
    mlp_variant: str = "gelu"         # gelu | swiglu | relu2
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    tie_embeddings: bool = True

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    attn_every: int = 0               # zamba2: shared attn period
    slstm_every: int = 0              # xlstm: sLSTM block period

    # enc-dec
    n_enc_layers: int = 0
    n_frames: int = 1500              # whisper stub frame count

    # vlm
    cross_attn_every: int = 0
    n_image_tokens: int = 0
    d_vision: int = 0

    # numerics / runtime
    dtype: str = "float32"            # activation/compute dtype
    param_dtype: str = "float32"
    attn_chunk: int = 1024
    remat: bool = True
    max_position: int = 1 << 20
    #: unroll layer/chunk scans.  Execution default is False (compact HLO,
    #: fast compiles); the dry-run lowers with True because XLA's cost
    #: analysis counts while-loop bodies ONCE — unrolled HLO makes the
    #: roofline terms exact (see launch/dryrun.py).
    scan_unroll: bool = False

    # sub-quadratic? (decides long_500k eligibility)
    @property
    def subquadratic(self) -> bool:
        return self.family in ("hybrid", "ssm")

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def p_dtype(self):
        return jnp.dtype(self.param_dtype)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    def shape_supported(self, shape: ShapeConfig) -> Tuple[bool, str]:
        """Assignment rules: long_500k only for sub-quadratic archs."""
        if shape.name == "long_500k" and not self.subquadratic:
            return False, (
                "long_500k requires sub-quadratic attention; "
                f"{self.name} is full-attention (skip per assignment rule)"
            )
        return True, ""


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: Dict[str, Callable[[], ModelConfig]] = {}


def register(full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]):
    cfg = full()
    _REGISTRY[cfg.name] = full
    _SMOKE[cfg.name] = smoke
    return cfg.name


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    table = _SMOKE if smoke else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]()


def list_configs() -> List[str]:
    return sorted(_REGISTRY)
