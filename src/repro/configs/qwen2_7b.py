"""qwen2-7b [arXiv:2407.10671; hf]: dense GQA with QKV bias, SwiGLU.
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064."""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b", family="dense",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
        d_ff=18944, vocab=152_064, mlp_variant="swiglu", qkv_bias=True,
        rope_theta=1_000_000.0,
        dtype="bfloat16", param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b", family="dense",
        n_layers=2, d_model=56, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=128, mlp_variant="swiglu", qkv_bias=True,
        remat=False,
    )


register(full, smoke)
