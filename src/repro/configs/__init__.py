"""Architecture configs: one module per assigned architecture, each with
the exact published configuration plus a reduced smoke variant."""
from .base import (  # noqa: F401
    ModelConfig,
    SHAPES,
    ShapeConfig,
    get_config,
    list_configs,
    register,
)
from . import (  # noqa: F401
    starcoder2_15b,
    nemotron4_15b,
    llama32_3b,
    qwen2_7b,
    llama32_vision_90b,
    whisper_large_v3,
    deepseek_moe_16b,
    dbrx_132b,
    zamba2_1p2b,
    xlstm_350m,
    weldbench,
)
