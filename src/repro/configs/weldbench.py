"""The paper's own workload suite config: not an LM — selects the Weld
benchmark battery (crime index, Black-Scholes, TPC-H, PageRank, logreg)
at the dataset scale used by benchmarks/.  Kept in the same registry so
`--arch weld-bench` drives the paper-native pipeline end to end."""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="weld-bench", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=128,
    )


def smoke() -> ModelConfig:
    return full()


register(full, smoke)
