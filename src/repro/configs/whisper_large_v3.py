"""whisper-large-v3 [arXiv:2212.04356]: encoder-decoder audio backbone.
32 enc + 32 dec layers, d_model=1280 20H (MHA kv=20) d_ff=5120
vocab=51866, LayerNorm + GELU, learned decoder positions.
Conv/audio frontend is a STUB (precomputed frame embeddings, 1500
frames).  Whisper's canonical decoder context is 448 tokens; the
decode_32k cell stresses the same backbone with a 32k cache
(max_position raised accordingly) — noted in DESIGN.md §7."""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="encdec",
        n_layers=32, n_enc_layers=32, d_model=1280, n_heads=20,
        n_kv_heads=20, d_ff=5120, vocab=51866,
        mlp_variant="gelu", norm="layernorm", rope_theta=0.0,
        n_frames=1500, max_position=32_768,
        dtype="bfloat16", param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="encdec",
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128, mlp_variant="gelu", norm="layernorm",
        rope_theta=0.0, n_frames=16, max_position=64, remat=False,
    )


register(full, smoke)
