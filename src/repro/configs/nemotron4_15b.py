"""nemotron-4-15b [arXiv:2402.16819]: dense GQA, squared-ReLU MLP.
32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000."""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense",
        n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=24576, vocab=256_000, mlp_variant="relu2",
        dtype="bfloat16", param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, mlp_variant="relu2", remat=False,
    )


register(full, smoke)
