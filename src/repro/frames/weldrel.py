"""weldrel — the Spark SQL integration (paper §6).

Column-store tables with relational operators (scan/filter/project/
aggregate/grouped-aggregate).  Mirrors the paper's port: *each operator
emits its own loop, independent of downstream operators* — no hand-written
operator-fusion logic as in HyPer-style code generators — and Weld's
optimizer fuses the chain into one pass.  Used for the TPC-H Q1/Q6
benchmarks and the UDF workload.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import ir, macros as M, wtypes as wt
from ..core.lazy import Evaluate, NewWeldObject, WeldObject
from . import weldnp


class Table:
    def __init__(self, columns: Dict[str, np.ndarray], eager: bool = False):
        self.eager = eager
        self.cols = {
            k: weldnp.array(np.asarray(v), eager=eager)
            for k, v in columns.items()
        }

    def col(self, name: str) -> weldnp.ndarray:
        return self.cols[name]


class Query:
    """A chain of relational operators over a table.  Each operator appends
    an independent IR fragment; `collect()` is the evaluation point."""

    def __init__(self, table: Table):
        self.table = table
        self.pred: Optional[weldnp.ndarray] = None

    def filter(self, pred: weldnp.ndarray) -> "Query":
        self.pred = pred if self.pred is None else (self.pred & pred)
        return self

    # -- ungrouped aggregate ---------------------------------------------------

    def agg(self, exprs: Dict[str, Tuple[weldnp.ndarray, str]],
            kernelize=None, kernel_impl=None,
            collect_stats: Optional[dict] = None):
        """exprs: name -> (value column expression, op).  Returns dict of
        scalars; single fused pass over the data.  Under the default
        ``kernelize="auto"`` the fused filter+reduce routes onto the
        Pallas kernel library when the cost gate favors it — all
        aggregates share one multi-output kernel launch; ``"always"``/
        True forces the route, ``"off"``/False disables it."""
        if self.table.eager:
            out = {}
            m = self.pred._eager if self.pred is not None else None
            for name, (col, op) in exprs.items():
                v = col._eager
                if m is not None:
                    v = v[m]
                # empty/fully-filtered input reduces to the merger
                # identity of the op, matching the lazy path (0 for "+",
                # 1 for "*", +/-inf-like extremes for min/max)
                out[name] = {
                    "+": np.sum, "min": np.min, "max": np.max, "*": np.prod,
                }[op](v) if v.size else wt.merge_identity(
                    op, wt.dtype_to_weld(v.dtype))
            return out

        names = list(exprs)
        deps: List[WeldObject] = []
        ids: List[ir.Expr] = []
        seen: Dict[str, int] = {}

        def slot(arr: weldnp.ndarray) -> int:
            if arr.obj.obj_id not in seen:
                seen[arr.obj.obj_id] = len(ids)
                deps.append(arr.obj)
                ids.append(ir.Ident(arr.obj.obj_id, arr.obj.weld_type()))
            return seen[arr.obj.obj_id]

        val_slots = [slot(exprs[n][0]) for n in names]
        pred_slot = slot(self.pred) if self.pred is not None else None

        builders = tuple(
            wt.Merger(exprs[n][0].weld_elem_ty, exprs[n][1]) for n in names
        )
        sbt = wt.StructBuilder(builders)
        elem_ty = (
            wt.Struct(tuple(_ety(i, ids) for i in range(len(ids))))
            if len(ids) > 1 else _ety(0, ids)
        )
        b = ir.Ident(ir.fresh("b"), sbt)
        i = ir.Ident(ir.fresh("i"), wt.I64)
        x = ir.Ident(ir.fresh("x"), elem_ty)

        def field(k: int) -> ir.Expr:
            return ir.GetField(x, k) if len(ids) > 1 else x

        cur: ir.Expr = b
        items = []
        for k, n in enumerate(names):
            items.append(ir.Merge(ir.GetField(b, k), field(val_slots[k])))
        merged = ir.MakeStruct(tuple(items))
        if pred_slot is not None:
            body: ir.Expr = ir.If(field(pred_slot), merged, b)
        else:
            body = merged
        loop = ir.For(
            tuple(ir.Iter(idn) for idn in ids),
            ir.MakeStruct(tuple(ir.NewBuilder(bt) for bt in builders)),
            ir.Lambda((b, i, x), body),
        )
        obj = NewWeldObject(deps, ir.Result(loop))
        res = Evaluate(obj, kernelize=kernelize, kernel_impl=kernel_impl,
                       collect_stats=collect_stats).value
        return {n: res[k] for k, n in enumerate(names)}

    # -- grouped aggregate -------------------------------------------------------

    def group_agg(
        self,
        keys: Sequence[weldnp.ndarray],
        vals: Dict[str, Tuple[weldnp.ndarray, str]],
        capacity: int = 4096,
        kernelize=None,
        kernel_impl=None,
    ):
        """GROUP BY keys; all aggregates share ONE dictmerger pass.
        Returns {key_tuple: (agg,...)} (+ implicit count as last value).

        NOTE: grouped multi-aggregates build a struct-valued dictmerger,
        which the kernel planner does not yet route (ROADMAP: multi-agg
        fusion) — ``kernelize=True`` is accepted for API symmetry but
        currently always falls back to the generic sort-based path."""
        if self.table.eager:
            # same contract as the lazy path below: anything but "+"
            # must fail loudly instead of silently summing
            ops = {vals[n][1] for n in vals} | {"+"}
            assert ops == {"+"}, "grouped aggregates support sum/count"
            m = self.pred._eager if self.pred is not None else slice(None)
            karrs = [k._eager[m] for k in keys]
            varrs = [vals[n][0]._eager[m] for n in vals]
            packed = list(zip(*karrs))
            out: dict = {}
            for row_idx, kt in enumerate(packed):
                # single-key groups use the bare scalar, like the lazy
                # path's dict decode — not a 1-tuple
                kt = tuple(x.item() for x in kt)
                kt = kt[0] if len(kt) == 1 else kt
                slotv = out.setdefault(kt, [0.0] * len(varrs) + [0])
                for j, v in enumerate(varrs):
                    slotv[j] += v[row_idx]
                slotv[-1] += 1
            return {k: tuple(v) for k, v in out.items()}

        names = list(vals)
        deps: List[WeldObject] = []
        ids: List[ir.Expr] = []
        seen: Dict[str, int] = {}

        def slot(arr: weldnp.ndarray) -> int:
            if arr.obj.obj_id not in seen:
                seen[arr.obj.obj_id] = len(ids)
                deps.append(arr.obj)
                ids.append(ir.Ident(arr.obj.obj_id, arr.obj.weld_type()))
            return seen[arr.obj.obj_id]

        key_slots = [slot(k) for k in keys]
        val_slots = [slot(vals[n][0]) for n in names]
        pred_slot = slot(self.pred) if self.pred is not None else None
        ops = {vals[n][1] for n in names} | {"+"}
        assert ops == {"+"}, "grouped aggregates support sum/count"

        key_ty = wt.Struct(tuple(_ety(s, ids) for s in key_slots)) \
            if len(key_slots) > 1 else _ety(key_slots[0], ids)
        val_ty = wt.Struct(
            tuple(_ety(s, ids) for s in val_slots) + (wt.I64,)
        )
        bt = wt.DictMerger(key_ty, val_ty, "+")
        elem_ty = (
            wt.Struct(tuple(_ety(i, ids) for i in range(len(ids))))
            if len(ids) > 1 else _ety(0, ids)
        )
        b = ir.Ident(ir.fresh("b"), bt)
        i = ir.Ident(ir.fresh("i"), wt.I64)
        x = ir.Ident(ir.fresh("x"), elem_ty)

        def field(k: int) -> ir.Expr:
            return ir.GetField(x, k) if len(ids) > 1 else x

        key_expr = (
            ir.MakeStruct(tuple(field(s) for s in key_slots))
            if len(key_slots) > 1 else field(key_slots[0])
        )
        val_expr = ir.MakeStruct(
            tuple(field(s) for s in val_slots) + (ir.Literal(1, wt.I64),)
        )
        merged = ir.Merge(b, ir.MakeStruct((key_expr, val_expr)))
        body: ir.Expr = merged if pred_slot is None else ir.If(
            field(pred_slot), merged, b
        )
        loop = ir.For(
            tuple(ir.Iter(idn) for idn in ids),
            ir.NewBuilder(bt, arg=ir.Literal(capacity, wt.I64)),
            ir.Lambda((b, i, x), body),
        )
        obj = NewWeldObject(deps, ir.Result(loop))
        return Evaluate(obj, kernelize=kernelize,
                        kernel_impl=kernel_impl).value

    # -- hash join ---------------------------------------------------------------

    def join(
        self,
        other: "Table",
        on: str,
        right_on: Optional[str] = None,
        how: str = "inner",
        suffix: str = "_r",
        capacity: Optional[int] = None,
        kernelize=None,
        kernel_impl=None,
        collect_stats: Optional[dict] = None,
    ) -> "Table":
        """Hash-join this query's (filtered) rows against `other` on an
        equality key; evaluation point returning a new materialized
        :class:`Table`.

        `other` is the BUILD side and must have unique keys (an m:1 /
        fact-to-dimension join, pandas ``validate="m:1"``); duplicate or
        missing keys on the probe side are fine — inner semantics drop
        unmatched probe rows.  Output columns are every left column plus
        every right column except the key (``suffix`` disambiguates
        collisions).

        Lazily the whole join is ONE fused program: a dictmerger build
        pass over the right side, then per output column a probe loop
        ``if(keyexists(d, k), merge(b, lookup(d, k) | left_col), b)``.
        Under ``kernelize`` the planner lowers it as a two-kernel plan —
        an open-addressing hash build (covering sparse/non-dense int
        keys) and a one-hot MXU gather probe (``repro.core.kernelplan``).
        """
        if how != "inner":
            raise NotImplementedError(f"join how={how!r} (inner only)")
        if not isinstance(other, Table):
            raise TypeError("join build side must be a weldrel.Table")
        rkey = right_on or on
        rk_host = np.asarray(_host(other.cols[rkey]))
        if np.unique(rk_host).size != rk_host.size:
            raise ValueError(
                "join requires unique build-side keys (m:1); aggregate "
                "the right side first"
            )
        names_l = list(self.table.cols)
        names_r = [c for c in other.cols if c != rkey]
        out_names = names_l + [
            c + suffix if c in names_l else c for c in names_r
        ]
        cap = int(capacity if capacity is not None else max(rk_host.size, 1))
        if cap < rk_host.size:
            # an undersized dict truncates (generic) or poisons (kernel)
            # the build — fail loudly before either can happen
            raise ValueError(
                f"join capacity {cap} < {rk_host.size} build-side keys"
            )

        if self.table.eager:
            m = (self.pred._eager if self.pred is not None
                 else np.ones(len(_host(self.table.col(on))), bool))
            lk = self.table.col(on)._eager
            if rk_host.size:
                order = np.argsort(rk_host, kind="stable")
                rks = rk_host[order]
                pos = np.clip(np.searchsorted(rks, lk), 0, rks.size - 1)
                found = rks[pos] == lk
            else:
                order = pos = np.zeros(lk.shape[0], dtype=np.int64)
                found = np.zeros(lk.shape[0], dtype=bool)
            mask = m & found
            out = {c: self.table.col(c)._eager[mask] for c in names_l}
            if names_r:
                gidx = order[pos[mask]] if rk_host.size else pos[:0]
                for c, name in zip(names_r, out_names[len(names_l):]):
                    out[name] = _host(other.cols[c])[gidx]
            return Table(out, eager=True)

        # -- lazy: one fused program (build + all probes) ----------------------
        lcols = {c: _as_lazy(self.table.cols[c]) for c in names_l}
        rcols = {c: _as_lazy(other.cols[c]) for c in [rkey] + names_r}
        kt = rcols[rkey].weld_elem_ty
        m = len(names_r)

        # build side: dict[key, {v1..vm}] (or dict[key, v] / dict[key, 1])
        r_objs = [rcols[rkey].obj] + [rcols[c].obj for c in names_r]
        r_ids = [ir.Ident(o.obj_id, o.weld_type()) for o in r_objs]
        b_elem = (
            wt.Struct(tuple(_ety(k, r_ids) for k in range(len(r_ids))))
            if len(r_ids) > 1 else _ety(0, r_ids)
        )
        vt: wt.WeldType = (
            wt.Struct(tuple(_ety(k, r_ids) for k in range(1, len(r_ids))))
            if m > 1 else (_ety(1, r_ids) if m == 1 else wt.I64)
        )
        bt = wt.DictMerger(kt, vt, "+")
        b = ir.Ident(ir.fresh("b"), bt)
        i = ir.Ident(ir.fresh("i"), wt.I64)
        x = ir.Ident(ir.fresh("x"), b_elem)
        kf = ir.GetField(x, 0) if len(r_ids) > 1 else x
        if m > 1:
            vf: ir.Expr = ir.MakeStruct(
                tuple(ir.GetField(x, k) for k in range(1, len(r_ids)))
            )
        elif m == 1:
            vf = ir.GetField(x, 1)
        else:
            vf = ir.Literal(1, wt.I64)
        build = ir.For(
            tuple(ir.Iter(idn) for idn in r_ids),
            ir.NewBuilder(bt, arg=ir.Literal(cap, wt.I64)),
            ir.Lambda((b, i, x), ir.Merge(b, ir.MakeStruct((kf, vf)))),
        )
        dict_obj = NewWeldObject(r_objs, ir.Result(build))
        d_id = ir.Ident(dict_obj.obj_id, dict_obj.weld_type())

        lk_obj = lcols[on].obj
        pred_obj = self.pred.obj if self.pred is not None else None

        def probe(val_of, elem_ty_of, iters_extra):
            """One output column: filter left rows to key matches and
            merge `val_of(x)` — the planner's hash_probe pattern."""
            ids2 = [ir.Ident(lk_obj.obj_id, lk_obj.weld_type())]
            ids2 += [ir.Ident(o.obj_id, o.weld_type()) for o in iters_extra]
            if pred_obj is not None:
                ids2.append(ir.Ident(pred_obj.obj_id, pred_obj.weld_type()))
            elem = (
                wt.Struct(tuple(_ety(k, ids2) for k in range(len(ids2))))
                if len(ids2) > 1 else _ety(0, ids2)
            )
            b2 = ir.Ident(ir.fresh("b"), wt.VecBuilder(elem_ty_of))
            i2 = ir.Ident(ir.fresh("i"), wt.I64)
            x2 = ir.Ident(ir.fresh("x"), elem)

            def field(k: int) -> ir.Expr:
                return ir.GetField(x2, k) if len(ids2) > 1 else x2

            cond: ir.Expr = ir.KeyExists(d_id, field(0))
            if pred_obj is not None:
                cond = ir.BinOp("&&", field(len(ids2) - 1), cond)
            body = ir.If(
                cond, ir.Merge(b2, val_of(field)), b2
            )
            return ir.Result(ir.For(
                tuple(ir.Iter(idn) for idn in ids2),
                ir.NewBuilder(b2.ty),
                ir.Lambda((b2, i2, x2), body),
            ))

        probes: List[ir.Expr] = []
        deps: List[WeldObject] = []
        seen_dep: Dict[str, WeldObject] = {}

        def dep(o: WeldObject) -> None:
            if o.obj_id not in seen_dep:
                seen_dep[o.obj_id] = o
                deps.append(o)

        dep(lk_obj)
        if pred_obj is not None:
            dep(pred_obj)
        dep(dict_obj)
        for c in names_l:
            col = lcols[c]
            if col.obj.obj_id == lk_obj.obj_id:
                probes.append(probe(
                    lambda f: f(0), col.weld_elem_ty, []))
            else:
                dep(col.obj)
                probes.append(probe(
                    lambda f: f(1), col.weld_elem_ty, [col.obj]))
        for j, c in enumerate(names_r):
            elem_ty = rcols[c].weld_elem_ty
            if m > 1:
                probes.append(probe(
                    lambda f, j=j: ir.GetField(
                        ir.Lookup(d_id, f(0)), j),
                    elem_ty, []))
            else:
                probes.append(probe(
                    lambda f: ir.Lookup(d_id, f(0)), elem_ty, []))

        obj = NewWeldObject(deps, ir.MakeStruct(tuple(probes)))
        res = Evaluate(obj, kernelize=kernelize, kernel_impl=kernel_impl,
                       collect_stats=collect_stats)
        arrays = [np.asarray(v) for v in res.value]
        return Table(dict(zip(out_names, arrays)), eager=False)


def _host(col: weldnp.ndarray) -> np.ndarray:
    """The numpy buffer behind a table column (eager or lazy)."""
    return col._eager if col.is_eager else np.asarray(col.obj.data)


def _as_lazy(col: weldnp.ndarray) -> weldnp.ndarray:
    return col if col.obj is not None else weldnp.array(col._eager)


def _ety(k: int, ids: List[ir.Expr]) -> wt.Scalar:
    t = ids[k].ty
    assert isinstance(t, wt.Vec)
    return t.elem
