"""weldrel — the Spark SQL integration (paper §6).

Column-store tables with relational operators (scan/filter/project/
aggregate/grouped-aggregate).  Mirrors the paper's port: *each operator
emits its own loop, independent of downstream operators* — no hand-written
operator-fusion logic as in HyPer-style code generators — and Weld's
optimizer fuses the chain into one pass.  Used for the TPC-H Q1/Q6
benchmarks and the UDF workload.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import faults, ir, macros as M, wtypes as wt
from ..core.errors import CapacityError
from ..core.lazy import Evaluate, NewWeldObject, WeldObject, build_program
from . import weldnp


class Table:
    def __init__(self, columns: Dict[str, np.ndarray], eager: bool = False):
        self.eager = eager
        self.cols = {
            k: weldnp.array(np.asarray(v), eager=eager)
            for k, v in columns.items()
        }

    def col(self, name: str) -> weldnp.ndarray:
        return self.cols[name]


class Query:
    """A chain of relational operators over a table.  Each operator appends
    an independent IR fragment; `collect()` is the evaluation point."""

    def __init__(self, table: Table):
        self.table = table
        self.pred: Optional[weldnp.ndarray] = None
        #: set by the stage()/compile() proxies: operator tails return a
        #: StagedQuery instead of evaluating
        self._staged = False

    def filter(self, pred: weldnp.ndarray) -> "Query":
        self.pred = pred if self.pred is None else (self.pred & pred)
        return self

    def stage(self) -> "_Stage":
        """Capture the *next* operator as a :class:`StagedQuery` instead
        of evaluating it::

            sq = Query(t).filter(p).stage().join(r, on="key")

        The staged query binds the operator's tables and IR but compiles
        nothing; hand it to ``core.serve.QueryServer.submit`` or call
        ``sq.compile()`` for the AOT handle.  Lazy tables only."""
        return _Stage(self)

    def compile(self, collect_stats: Optional[dict] = None) -> "_Compile":
        """AOT-compile the *next* operator::

            cq = Query(t).compile().join(r, on="key")   # CompiledQuery
            out1 = cq.run()                   # the staged tables
            out2 = cq.run(table=t2, right=r2)  # same shapes, 0 recompiles

        Returns a proxy; calling an operator on it yields a
        :class:`CompiledQuery` with ``.stats``, ``.explain()`` and
        ``.run(**tables)``.  Compilation goes through the runtime's
        bounded single-flight cache, so repeated compiles of the same
        (plan, shape) are free."""
        return _Compile(self, collect_stats)

    def _finish(self, obj: WeldObject, finalize: Callable, *, op: str,
                tables: Dict[str, Table], memory_limit=None, kernelize=None,
                kernel_impl=None, collect_stats=None):
        """Common tail of every lazy operator: evaluate now (the normal
        path) or, under stage()/compile(), capture the program plus the
        result finalizer as a :class:`StagedQuery`."""
        if self._staged:
            return StagedQuery(op=op, obj=obj, finalize=finalize,
                               tables=dict(tables),
                               memory_limit=memory_limit,
                               kernelize=kernelize,
                               kernel_impl=kernel_impl)
        res = Evaluate(obj, memory_limit=memory_limit, kernelize=kernelize,
                       kernel_impl=kernel_impl, collect_stats=collect_stats)
        return finalize(res.value)

    def explain(self, analyze: bool = False) -> "_Explain":
        """EXPLAIN [ANALYZE] the *next* operator instead of returning its
        result.  Call an operator on the returned proxy exactly as you
        would on the query::

            rep = Query(t).explain().join(r, on="key")
            rep = Query(t).explain(analyze=True).agg({...})
            print(rep)

        The report shows the fused IR after optimization, every routed
        kernel with its block parameters and roofline estimate, and the
        planner's route/reject decisions.  With ``analyze=True`` the
        query also runs with tracing enabled, adding per-span measured
        times and predicted-vs-measured ratios per kernel launch (the
        operator's result is still computed and available as
        ``rep.result``)."""
        return _Explain(self, analyze)

    # -- ungrouped aggregate ---------------------------------------------------

    def agg(self, exprs: Dict[str, Tuple[weldnp.ndarray, str]],
            kernelize=None, kernel_impl=None,
            collect_stats: Optional[dict] = None):
        """exprs: name -> (value column expression, op).  Returns dict of
        scalars; single fused pass over the data.  Under the default
        ``kernelize="auto"`` the fused filter+reduce routes onto the
        Pallas kernel library when the cost gate favors it — all
        aggregates share one multi-output kernel launch; ``"always"``/
        True forces the route, ``"off"``/False disables it."""
        if self.table.eager:
            out = {}
            m = self.pred._eager if self.pred is not None else None
            for name, (col, op) in exprs.items():
                v = col._eager
                if m is not None:
                    v = v[m]
                # empty/fully-filtered input reduces to the merger
                # identity of the op, matching the lazy path (0 for "+",
                # 1 for "*", +/-inf-like extremes for min/max)
                out[name] = {
                    "+": np.sum, "min": np.min, "max": np.max, "*": np.prod,
                }[op](v) if v.size else wt.merge_identity(
                    op, wt.dtype_to_weld(v.dtype))
            return out

        names = list(exprs)
        deps: List[WeldObject] = []
        ids: List[ir.Expr] = []
        seen: Dict[str, int] = {}

        def slot(arr: weldnp.ndarray) -> int:
            if arr.obj.obj_id not in seen:
                seen[arr.obj.obj_id] = len(ids)
                deps.append(arr.obj)
                ids.append(ir.Ident(arr.obj.obj_id, arr.obj.weld_type()))
            return seen[arr.obj.obj_id]

        val_slots = [slot(exprs[n][0]) for n in names]
        pred_slot = slot(self.pred) if self.pred is not None else None

        builders = tuple(
            wt.Merger(exprs[n][0].weld_elem_ty, exprs[n][1]) for n in names
        )
        sbt = wt.StructBuilder(builders)
        elem_ty = (
            wt.Struct(tuple(_ety(i, ids) for i in range(len(ids))))
            if len(ids) > 1 else _ety(0, ids)
        )
        b = ir.Ident(ir.fresh("b"), sbt)
        i = ir.Ident(ir.fresh("i"), wt.I64)
        x = ir.Ident(ir.fresh("x"), elem_ty)

        def field(k: int) -> ir.Expr:
            return ir.GetField(x, k) if len(ids) > 1 else x

        cur: ir.Expr = b
        items = []
        for k, n in enumerate(names):
            items.append(ir.Merge(ir.GetField(b, k), field(val_slots[k])))
        merged = ir.MakeStruct(tuple(items))
        if pred_slot is not None:
            body: ir.Expr = ir.If(field(pred_slot), merged, b)
        else:
            body = merged
        loop = ir.For(
            tuple(ir.Iter(idn) for idn in ids),
            ir.MakeStruct(tuple(ir.NewBuilder(bt) for bt in builders)),
            ir.Lambda((b, i, x), body),
        )
        obj = NewWeldObject(deps, ir.Result(loop))
        return self._finish(
            obj, lambda v: {n: v[k] for k, n in enumerate(names)},
            op="agg", tables={"table": self.table},
            kernelize=kernelize, kernel_impl=kernel_impl,
            collect_stats=collect_stats)

    # -- grouped aggregate -------------------------------------------------------

    def group_agg(
        self,
        keys: Sequence[weldnp.ndarray],
        vals: Dict[str, Tuple[weldnp.ndarray, str]],
        capacity: int = 4096,
        kernelize=None,
        kernel_impl=None,
        collect_stats: Optional[dict] = None,
    ):
        """GROUP BY keys; all aggregates share ONE dictmerger pass.
        Returns {key_tuple: (agg,...)} (+ implicit count as last value).

        NOTE: grouped multi-aggregates build a struct-valued dictmerger,
        which the kernel planner does not yet route (ROADMAP: multi-agg
        fusion) — ``kernelize=True`` is accepted for API symmetry but
        currently always falls back to the generic sort-based path."""
        if self.table.eager:
            # same contract as the lazy path below: anything but "+"
            # must fail loudly instead of silently summing
            ops = {vals[n][1] for n in vals} | {"+"}
            assert ops == {"+"}, "grouped aggregates support sum/count"
            m = self.pred._eager if self.pred is not None else slice(None)
            karrs = [k._eager[m] for k in keys]
            varrs = [vals[n][0]._eager[m] for n in vals]
            # per-dtype merger identities: an int value column accumulates
            # as ints and decodes as ints, exactly like the lazy dict path
            # (the old [0.0]*n seed floated every aggregate)
            idents = [
                wt.merge_identity("+", wt.dtype_to_weld(v.dtype))
                for v in varrs
            ]
            packed = list(zip(*karrs))
            out: dict = {}
            for row_idx, kt in enumerate(packed):
                # single-key groups use the bare scalar, like the lazy
                # path's dict decode — not a 1-tuple
                kt = tuple(x.item() for x in kt)
                kt = kt[0] if len(kt) == 1 else kt
                slotv = out.setdefault(kt, list(idents) + [0])
                for j, v in enumerate(varrs):
                    slotv[j] += v[row_idx]
                slotv[-1] += 1
            return {
                k: tuple(x.item() if isinstance(x, np.generic) else x
                         for x in v)
                for k, v in out.items()
            }

        names = list(vals)
        deps: List[WeldObject] = []
        ids: List[ir.Expr] = []
        seen: Dict[str, int] = {}

        def slot(arr: weldnp.ndarray) -> int:
            if arr.obj.obj_id not in seen:
                seen[arr.obj.obj_id] = len(ids)
                deps.append(arr.obj)
                ids.append(ir.Ident(arr.obj.obj_id, arr.obj.weld_type()))
            return seen[arr.obj.obj_id]

        key_slots = [slot(k) for k in keys]
        val_slots = [slot(vals[n][0]) for n in names]
        pred_slot = slot(self.pred) if self.pred is not None else None
        ops = {vals[n][1] for n in names} | {"+"}
        assert ops == {"+"}, "grouped aggregates support sum/count"

        key_ty = wt.Struct(tuple(_ety(s, ids) for s in key_slots)) \
            if len(key_slots) > 1 else _ety(key_slots[0], ids)
        val_ty = wt.Struct(
            tuple(_ety(s, ids) for s in val_slots) + (wt.I64,)
        )
        bt = wt.DictMerger(key_ty, val_ty, "+")
        elem_ty = (
            wt.Struct(tuple(_ety(i, ids) for i in range(len(ids))))
            if len(ids) > 1 else _ety(0, ids)
        )
        b = ir.Ident(ir.fresh("b"), bt)
        i = ir.Ident(ir.fresh("i"), wt.I64)
        x = ir.Ident(ir.fresh("x"), elem_ty)

        def field(k: int) -> ir.Expr:
            return ir.GetField(x, k) if len(ids) > 1 else x

        key_expr = (
            ir.MakeStruct(tuple(field(s) for s in key_slots))
            if len(key_slots) > 1 else field(key_slots[0])
        )
        val_expr = ir.MakeStruct(
            tuple(field(s) for s in val_slots) + (ir.Literal(1, wt.I64),)
        )
        merged = ir.Merge(b, ir.MakeStruct((key_expr, val_expr)))
        body: ir.Expr = merged if pred_slot is None else ir.If(
            field(pred_slot), merged, b
        )
        loop = ir.For(
            tuple(ir.Iter(idn) for idn in ids),
            ir.NewBuilder(bt, arg=ir.Literal(capacity, wt.I64)),
            ir.Lambda((b, i, x), body),
        )
        obj = NewWeldObject(deps, ir.Result(loop))
        return self._finish(
            obj, lambda v: v,
            op="group_agg", tables={"table": self.table},
            kernelize=kernelize, kernel_impl=kernel_impl,
            collect_stats=collect_stats)

    # -- hash join ---------------------------------------------------------------

    def join(
        self,
        other: "Table",
        on,
        right_on=None,
        how: str = "inner",
        suffix: str = "_r",
        capacity: Optional[int] = None,
        validate: Optional[str] = None,
        precount: bool = True,
        memory_limit: Optional[int] = None,
        kernelize=None,
        kernel_impl=None,
        collect_stats: Optional[dict] = None,
    ) -> "Table":
        """Hash-join this query's (filtered) rows against `other` on one
        or two equality keys; evaluation point returning a new
        materialized :class:`Table`.

        ``on`` (and optionally ``right_on``) is a column name or a list
        of up to two names — multi-column keys share the backend's packed
        64-bit key space (32 bits per column; out-of-range int keys
        raise).  ``how`` selects the join semantics:

        * ``"inner"`` — every (probe row, matching build row) pair; a
          probe row with k build matches expands to k output rows,
          unmatched rows drop;
        * ``"left"``  — matched probe rows expand like ``"inner"``;
          unmatched rows survive ONCE with right columns filled by a
          per-dtype default (NaN for floats, 0 for ints, False for
          bools — sentinel fills, NOT pandas' float upcast);
        * ``"anti"``  — keep probe rows whose key does NOT exist; the
          output has only left columns.

        `other` is the BUILD side.  Duplicate build-side keys are
        supported for ``"inner"``/``"left"`` (an m:n join: output rows
        are ordered probe-row-major, matches within a probe row in
        build-row order); pass ``validate="m:1"`` to instead raise on
        duplicates with a row-count diagnostic (the pandas knob — and
        the old default, which rejected every duplicate).  ``"anti"``
        still requires unique build keys (membership with duplicates is
        an aggregation question: aggregate the right side first).
        Duplicate or missing keys on the probe side are always fine.
        NaN join keys raise on every path (the one NaN semantics all
        three paths share).  Output columns are every left column plus
        every right column except the key; a post-``suffix`` name
        collision raises instead of silently overwriting.

        Lazily the whole join is ONE fused program.  With unique build
        keys (m:1): a dictmerger build pass over the right side, then
        ONE horizontally-fused probe loop merging every output column
        into a struct of vecbuilders — misses lower through
        ``lookup(d, k, default)`` (a single probe, no second pass).
        With duplicates (m:n): a groupbuilder build (key -> growing
        vector of build-row indices, CSR on the backend) and a probe
        loop iterating ``grouplookup(d, k)`` — lowered as a two-phase
        expansion (per-row match counts, exclusive scan, repeat/gather)
        whose data-dependent output length lives in a static buffer
        sized by the host-computed unfiltered match total.  Under
        ``kernelize`` the planner lowers build + probe as a two-kernel
        plan (``dict_hash_build``+``hash_probe``, or ``group_build``+
        ``group_probe`` for m:n) — ALL output columns share one probe
        launch regardless of width (``repro.core.kernelplan``).

        ``precount=False`` (lazy tables only) drops the host pre-count
        entirely: no distinct/duplicate scan, no match-total sum.
        Capacities and expansion buffers are instead *symbolic* IR
        expressions (``max(len(build), 1)`` for the group capacity,
        ``len(probe) * len(build)`` for the expansion buffer) that the
        weldbound interval analysis derives bounds for and the backend
        resolves against the bound shapes at trace time.  Every join
        lowers through the m:n group path (duplicates cannot be ruled
        out without counting), so ``how="anti"``, ``validate="m:1"``
        and packed (float or multi-column) keys — all of which *need* a
        host value scan — raise under ``precount=False``.

        ``memory_limit`` (bytes, lazy only) arms compile-time admission
        control: the plan's symbolic peak-memory certificate is
        evaluated against the bound input shapes and a provably
        over-budget plan raises a typed
        :class:`~repro.core.errors.ResourceError` *before* anything is
        traced or launched (see ``repro.core.analysis.bounds``).
        """
        if how not in ("inner", "left", "anti"):
            raise NotImplementedError(
                f"join how={how!r} (supported: inner, left, anti)"
            )
        if validate not in (None, "m:1"):
            raise ValueError(
                f"join validate={validate!r} (only 'm:1' is supported)"
            )
        if not isinstance(other, Table):
            raise TypeError("join build side must be a weldrel.Table")
        on_l = [on] if isinstance(on, str) else list(on)
        on_r = (
            ([right_on] if isinstance(right_on, str) else list(right_on))
            if right_on is not None else on_l
        )
        if not on_l or len(on_l) != len(on_r):
            raise ValueError(
                "join on/right_on must name the same number (>=1) of "
                "key columns"
            )
        if len(on_l) > 2:
            raise ValueError(
                "join supports at most 2 key columns (the packed-key "
                "space is 64-bit: 32 bits per column)"
            )
        nk = len(on_l)
        lk_host = [np.asarray(_host(self.table.cols[c])) for c in on_l]
        rk_host = [np.asarray(_host(other.cols[c])) for c in on_r]
        _check_join_keys(lk_host, rk_host, multi=nk > 1)
        # float keys compare through the f32 bitcast of the packed key
        # space on EVERY path (the dict paths have no alternative), so
        # the eager compare and the m:1 uniqueness check must use the
        # same packing — f64 build keys distinct only beyond f32
        # precision raise here instead of silently summing in the dict
        do_pack = nk > 1 or any(
            np.issubdtype(c.dtype, np.floating)
            for c in (lk_host[0], rk_host[0])
        )
        static_caps = (not precount) and not self.table.eager
        if static_caps:
            # weldbound static-capacity mode: no host counting at all.
            # Everything below that *requires* a value scan is rejected
            # up front; duplicates can't be ruled out, so every join
            # lowers through the m:n group path with symbolic sizes.
            if how == "anti":
                raise NotImplementedError(
                    "join precount=False cannot lower how='anti': anti "
                    "joins require host pre-counting (unique build "
                    "keys); pass precount=True"
                )
            if validate == "m:1":
                raise ValueError(
                    "join precount=False cannot honor validate='m:1': "
                    "duplicate detection is a host value scan; pass "
                    "precount=True"
                )
            if do_pack:
                raise ValueError(
                    "join precount=False supports single integer key "
                    "columns only: packed (float or multi-column) keys "
                    "need a host conflation scan; pass precount=True"
                )
            mn = True
            distinct = n_dup = 0  # never consulted on this path
        else:
            rk_packed = _pack_host(rk_host) if do_pack else rk_host[0]
            distinct = int(np.unique(rk_packed).size)
            n_dup = int(rk_packed.size) - distinct
            mn = n_dup > 0
        if not static_caps and do_pack and any(
            np.issubdtype(c.dtype, np.floating) for c in rk_host
        ):
            # m:n made duplicate build keys legal, so the uniqueness
            # guard no longer catches f64 keys that are distinct only
            # beyond the packed space's f32 precision — those would
            # silently fuse into one bogus group.  Keep that semantic
            # pinned: packed conflation of IEEE-distinct keys raises.
            # (np.unique matches the packed normalization: -0.0 == 0.0,
            # and NaN keys were already rejected above.)
            raw_distinct = int(np.unique(
                rk_host[0] if len(rk_host) == 1
                else np.rec.fromarrays(rk_host)
            ).size)
            if distinct < raw_distinct:
                raise ValueError(
                    "join build keys conflate in the packed (f32) key "
                    "space: keys distinct beyond f32 precision would "
                    "silently join as one key; cast or round the key "
                    "column before joining"
                )
        if n_dup and validate == "m:1":
            raise ValueError(
                f"join validate='m:1' violated: build side has {n_dup} "
                f"duplicate key rows ({rk_packed.size} rows, {distinct} "
                "distinct keys); aggregate the right side first"
            )
        if n_dup and how == "anti":
            raise NotImplementedError(
                "m:n anti joins pending (build side has duplicate "
                "keys); aggregate the right side first"
            )
        names_l = list(self.table.cols)
        names_r = (
            [] if how == "anti"
            else [c for c in other.cols if c not in on_r]
        )
        renamed_r = [c + suffix if c in names_l else c for c in names_r]
        out_names = names_l + renamed_r
        if len(set(out_names)) != len(out_names):
            seen: Dict[str, int] = {}
            for c in out_names:
                seen[c] = seen.get(c, 0) + 1
            dups = sorted(c for c, k in seen.items() if k > 1)
            raise ValueError(
                f"join output name collision after suffix {suffix!r}: "
                f"{dups}; rename columns or pick another suffix"
            )
        m = len(names_r)
        cap: Optional[int] = (
            int(capacity) if capacity is not None
            else (None if static_caps else max(distinct, 1))
        )
        injected_cap = faults.capacity_override("join.capacity")
        if injected_cap is not None:
            # fault injection: simulate a mis-estimated build capacity
            # (bypassing the guard below) so the runtime's poison ->
            # regrow -> fallback recovery ladder can be exercised
            cap = injected_cap
        elif static_caps:
            # no distinct count exists to guard against — an undersized
            # explicit capacity surfaces as runtime capacity poison and
            # rides the recovery regrow ladder instead
            pass
        elif cap < distinct:
            # an undersized dict poisons the build at decode time — on
            # an explicit user-passed capacity, fail loudly (and typed)
            # before compiling anything
            raise CapacityError(
                f"join capacity {cap} < {distinct} distinct build-side "
                "keys"
            )

        if self.table.eager:
            # the sort/searchsorted/repeat oracle, m:1 and m:n alike:
            # per-probe-row match counts via a left/right searchsorted
            # pair, then repeat/gather — matched build rows walk the
            # stable sort, so within a probe row output follows
            # build-row order (the ordering the lazy expansion shares)
            n_l = lk_host[0].shape[0]
            mrows = (self.pred._eager if self.pred is not None
                     else np.ones(n_l, bool))
            lk = _pack_host(lk_host) if do_pack else lk_host[0]
            rk = rk_packed
            if rk.size:
                order = np.argsort(rk, kind="stable")
                rks = rk[order]
                lo = np.searchsorted(rks, lk, side="left")
                hi = np.searchsorted(rks, lk, side="right")
                cnt = hi - lo
            else:
                order = lo = np.zeros(n_l, dtype=np.int64)
                cnt = np.zeros(n_l, dtype=np.int64)
            found = cnt > 0
            if how == "anti":
                mask = mrows & ~found
                return Table(
                    {c: self.table.col(c)._eager[mask] for c in names_l},
                    eager=True,
                )
            rep = np.where(
                mrows, cnt if how == "inner" else np.maximum(cnt, 1), 0
            )
            rows = np.repeat(np.arange(n_l), rep)
            offs = np.concatenate([[0], np.cumsum(rep)])
            t = np.arange(rows.size) - offs[rows]  # ordinal within a row
            frow = found[rows] if rows.size else np.zeros(0, bool)
            out = {c: self.table.col(c)._eager[rows] for c in names_l}
            if names_r:
                if rk.size:
                    gidx = order[np.where(frow, lo[rows] + t, 0)]
                for c, name in zip(names_r, renamed_r):
                    rcol = np.asarray(_host(other.cols[c]))
                    fill = rcol.dtype.type(_fill_of(rcol.dtype))
                    if rk.size:
                        v = rcol[gidx]
                        if how == "left":
                            v = np.where(frow, v, fill)
                    else:
                        v = np.full(rows.size, fill, rcol.dtype)
                    out[name] = v
            return Table(out, eager=True)

        # -- lazy: one fused program (build + ONE fused probe) -----------------
        lcols = {c: _as_lazy(self.table.cols[c]) for c in names_l}
        rkey_cols = [_as_lazy(other.cols[c]) for c in on_r]
        rcols = {c: _as_lazy(other.cols[c]) for c in names_r}
        kt: wt.WeldType = (
            wt.Struct(tuple(c.weld_elem_ty for c in rkey_cols))
            if nk > 1 else rkey_cols[0].weld_elem_ty
        )
        need_dict = m > 0 or how in ("inner", "anti")

        deps: List[WeldObject] = []
        seen_dep: Dict[str, WeldObject] = {}

        def dep(o: WeldObject) -> None:
            if o.obj_id not in seen_dep:
                seen_dep[o.obj_id] = o
                deps.append(o)

        if mn:
            # -- m:n: groupbuilder build (key -> growing vector of
            # build-row indices) + an expansion probe iterating
            # grouplookup(d, k) — ONE fused program whose output length
            # is data-dependent.  The static expansion buffer is sized
            # by the exact unfiltered match total (host-computed from
            # the same packed keys the dict compares); a predicate only
            # shrinks the in-program count.
            out_cap: Optional[int] = None
            if not static_caps:
                lk_packed = _pack_host(lk_host) if do_pack else lk_host[0]
                rks_h = np.sort(rk_packed)
                cnt_h = (np.searchsorted(rks_h, lk_packed, side="right")
                         - np.searchsorted(rks_h, lk_packed, side="left"))
                out_cap = int(cnt_h.sum() if how == "inner"
                              else np.maximum(cnt_h, 1).sum())

            r_objs = [c.obj for c in rkey_cols]
            r_ids = [ir.Ident(o.obj_id, o.weld_type()) for o in r_objs]
            # group capacity: the host distinct count when we have one,
            # else the proven-sufficient symbolic bound max(len(build),1)
            # — structurally >= the number of distinct keys, so the
            # symbolic path can never poison the build
            cap_node: ir.Expr = (
                ir.Literal(cap, wt.I64) if cap is not None
                else ir.BinOp("max", ir.Len(r_ids[0]),
                              ir.Literal(1, wt.I64))
            )
            b_elem = (
                wt.Struct(tuple(_ety(k, r_ids) for k in range(len(r_ids))))
                if len(r_ids) > 1 else _ety(0, r_ids)
            )
            bt = wt.GroupBuilder(kt, wt.I64)
            b = ir.Ident(ir.fresh("b"), bt)
            i = ir.Ident(ir.fresh("i"), wt.I64)
            x = ir.Ident(ir.fresh("x"), b_elem)

            def rfield(k: int) -> ir.Expr:
                return ir.GetField(x, k) if len(r_ids) > 1 else x

            kf: ir.Expr = (
                ir.MakeStruct(tuple(rfield(k) for k in range(nk)))
                if nk > 1 else rfield(0)
            )
            build = ir.For(
                tuple(ir.Iter(idn) for idn in r_ids),
                ir.NewBuilder(bt, arg=cap_node),
                ir.Lambda((b, i, x), ir.Merge(b, ir.MakeStruct((kf, i)))),
            )
            group_obj = NewWeldObject(r_objs, ir.Result(build))
            d_id = ir.Ident(group_obj.obj_id, group_obj.weld_type())
            dep(group_obj)
            rv_ids: Dict[str, ir.Ident] = {}
            for c in names_r:
                o = rcols[c].obj
                dep(o)
                rv_ids[c] = ir.Ident(o.obj_id, o.weld_type())

            pred_obj = self.pred.obj if self.pred is not None else None
            iter_objs: List[WeldObject] = []
            slots: Dict[str, int] = {}

            def slot(o: WeldObject) -> int:
                if o.obj_id not in slots:
                    slots[o.obj_id] = len(iter_objs)
                    iter_objs.append(o)
                return slots[o.obj_id]

            key_slots = [slot(lcols[c].obj) for c in on_l]
            col_slots = [slot(lcols[c].obj) for c in names_l]
            pred_slot = slot(pred_obj) if pred_obj is not None else None
            for o in iter_objs:
                dep(o)
            ids2 = [ir.Ident(o.obj_id, o.weld_type()) for o in iter_objs]
            elem = (
                wt.Struct(tuple(_ety(k, ids2) for k in range(len(ids2))))
                if len(ids2) > 1 else _ety(0, ids2)
            )
            out_tys = [lcols[c].weld_elem_ty for c in names_l] + \
                [rcols[c].weld_elem_ty for c in names_r]
            builders = tuple(wt.VecBuilder(t) for t in out_tys)
            sbt = wt.StructBuilder(builders)
            b2 = ir.Ident(ir.fresh("b"), sbt)
            i2 = ir.Ident(ir.fresh("i"), wt.I64)
            x2 = ir.Ident(ir.fresh("x"), elem)
            bi = ir.Ident(ir.fresh("b"), sbt)
            ii = ir.Ident(ir.fresh("i"), wt.I64)
            ri = ir.Ident(ir.fresh("r"), wt.I64)

            def field(k: int) -> ir.Expr:
                return ir.GetField(x2, k) if len(ids2) > 1 else x2

            key_expr: ir.Expr = (
                ir.MakeStruct(tuple(field(s) for s in key_slots))
                if nk > 1 else field(key_slots[0])
            )
            # the inner expansion loop: probe columns broadcast over the
            # group, build columns gather by the stored row index
            vals_in: List[ir.Expr] = [field(s) for s in col_slots]
            vals_in += [ir.Lookup(rv_ids[c], ri) for c in names_r]
            expand: ir.Expr = ir.For(
                (ir.Iter(ir.GroupLookup(d_id, key_expr)),),
                b2,
                ir.Lambda((bi, ii, ri), ir.MakeStruct(tuple(
                    ir.Merge(ir.GetField(bi, k), v)
                    for k, v in enumerate(vals_in)
                ))),
            )
            core: ir.Expr = expand
            if how == "left":
                miss_vals: List[ir.Expr] = [field(s) for s in col_slots]
                miss_vals += [
                    ir.Literal(
                        _fill_of(np.dtype(rcols[c].weld_elem_ty.np_dtype)),
                        rcols[c].weld_elem_ty,
                    )
                    for c in names_r
                ]
                miss = ir.MakeStruct(tuple(
                    ir.Merge(ir.GetField(b2, k), v)
                    for k, v in enumerate(miss_vals)
                ))
                core = ir.If(ir.KeyExists(d_id, key_expr), expand, miss)
            body2: ir.Expr = core if pred_slot is None else ir.If(
                field(pred_slot), core, b2
            )
            if out_cap is not None:
                hint_node: ir.Expr = ir.Literal(out_cap, wt.I64)
            else:
                # symbolic expansion bound: every probe row matches at
                # most len(build) rows (left joins emit at least one, so
                # max(len(build), 1) per row) — the weldbound interval
                # analysis tightens and certifies this, and the backend
                # resolves it against the bound shapes at trace time
                per_row: ir.Expr = ir.Len(r_ids[0])
                if how == "left":
                    per_row = ir.BinOp("max", per_row,
                                       ir.Literal(1, wt.I64))
                hint_node = ir.BinOp("*", ir.Len(ids2[0]), per_row)
                dep(r_objs[0])  # the hint reads len(build keys)
            loop = ir.For(
                tuple(ir.Iter(idn) for idn in ids2),
                ir.MakeStruct(tuple(
                    ir.NewBuilder(bt2, size_hint=hint_node)
                    for bt2 in builders
                )),
                ir.Lambda((b2, i2, x2), body2),
            )
            obj = NewWeldObject(deps, ir.Result(loop))
            return self._finish(
                obj,
                lambda v: Table(
                    dict(zip(out_names, [np.asarray(a) for a in v])),
                    eager=False),
                op="join", tables={"table": self.table, "right": other},
                memory_limit=memory_limit, kernelize=kernelize,
                kernel_impl=kernel_impl, collect_stats=collect_stats)

        # bool value columns cannot ride the "+"-dictmerger directly —
        # they build as i8 and cast back to bool at the probe (build
        # keys are unique, so the stored i8 is always 0/1)
        rval_tys = [rcols[c].weld_elem_ty for c in names_r]
        enc_tys = [wt.I8 if t == wt.Bool else t for t in rval_tys]

        d_id: Optional[ir.Ident] = None
        if need_dict:
            # build side: dict[key, {v1..vm}] (or dict[key, v] /
            # dict[key, 1]); multi-column keys merge a struct key
            r_objs = [c.obj for c in rkey_cols] + \
                [rcols[c].obj for c in names_r]
            r_ids = [ir.Ident(o.obj_id, o.weld_type()) for o in r_objs]
            b_elem = (
                wt.Struct(tuple(_ety(k, r_ids) for k in range(len(r_ids))))
                if len(r_ids) > 1 else _ety(0, r_ids)
            )
            vt: wt.WeldType = (
                wt.Struct(tuple(enc_tys))
                if m > 1 else (enc_tys[0] if m == 1 else wt.I64)
            )
            bt = wt.DictMerger(kt, vt, "+")
            b = ir.Ident(ir.fresh("b"), bt)
            i = ir.Ident(ir.fresh("i"), wt.I64)
            x = ir.Ident(ir.fresh("x"), b_elem)

            def rfield(k: int) -> ir.Expr:
                return ir.GetField(x, k) if len(r_ids) > 1 else x

            def renc(j: int) -> ir.Expr:
                f = rfield(nk + j)
                return ir.Cast(f, wt.I8) if rval_tys[j] == wt.Bool else f

            kf: ir.Expr = (
                ir.MakeStruct(tuple(rfield(k) for k in range(nk)))
                if nk > 1 else rfield(0)
            )
            if m > 1:
                vf: ir.Expr = ir.MakeStruct(
                    tuple(renc(j) for j in range(m))
                )
            elif m == 1:
                vf = renc(0)
            else:
                vf = ir.Literal(1, wt.I64)
            build = ir.For(
                tuple(ir.Iter(idn) for idn in r_ids),
                ir.NewBuilder(bt, arg=ir.Literal(cap, wt.I64)),
                ir.Lambda((b, i, x), ir.Merge(b, ir.MakeStruct((kf, vf)))),
            )
            dict_obj = NewWeldObject(r_objs, ir.Result(build))
            d_id = ir.Ident(dict_obj.obj_id, dict_obj.weld_type())
            dep(dict_obj)

        pred_obj = self.pred.obj if self.pred is not None else None

        # ONE probe pass: every output column merges into its own
        # vecbuilder inside a single loop over the probe side — the
        # horizontally-fused form the planner routes as one hash_probe
        iter_objs: List[WeldObject] = []
        slots: Dict[str, int] = {}

        def slot(o: WeldObject) -> int:
            if o.obj_id not in slots:
                slots[o.obj_id] = len(iter_objs)
                iter_objs.append(o)
            return slots[o.obj_id]

        key_slots = [slot(lcols[c].obj) for c in on_l]
        col_slots = [slot(lcols[c].obj) for c in names_l]
        pred_slot = slot(pred_obj) if pred_obj is not None else None
        for o in iter_objs:
            dep(o)
        ids2 = [ir.Ident(o.obj_id, o.weld_type()) for o in iter_objs]
        elem = (
            wt.Struct(tuple(_ety(k, ids2) for k in range(len(ids2))))
            if len(ids2) > 1 else _ety(0, ids2)
        )
        out_tys = [lcols[c].weld_elem_ty for c in names_l] + \
            [rcols[c].weld_elem_ty for c in names_r]
        builders = tuple(wt.VecBuilder(t) for t in out_tys)
        b2 = ir.Ident(ir.fresh("b"), wt.StructBuilder(builders))
        i2 = ir.Ident(ir.fresh("i"), wt.I64)
        x2 = ir.Ident(ir.fresh("x"), elem)

        def field(k: int) -> ir.Expr:
            return ir.GetField(x2, k) if len(ids2) > 1 else x2

        key_expr: ir.Expr = (
            ir.MakeStruct(tuple(field(s) for s in key_slots))
            if nk > 1 else field(key_slots[0])
        )
        vals: List[ir.Expr] = [field(s) for s in col_slots]
        if m:
            fill_dflt: Optional[ir.Expr] = None
            if how == "left":
                fills = tuple(
                    ir.Literal(_fill_of(np.dtype(t.np_dtype)), t)
                    for t in enc_tys
                )
                fill_dflt = ir.MakeStruct(fills) if m > 1 else fills[0]
            look = ir.Lookup(d_id, key_expr, fill_dflt)
            for j in range(m):
                v: ir.Expr = ir.GetField(look, j) if m > 1 else look
                if rval_tys[j] == wt.Bool:
                    v = ir.Cast(v, wt.Bool)
                vals.append(v)
        merged = ir.MakeStruct(tuple(
            ir.Merge(ir.GetField(b2, k), v) for k, v in enumerate(vals)
        ))
        cond: Optional[ir.Expr] = None
        if how == "inner":
            cond = ir.KeyExists(d_id, key_expr)
        elif how == "anti":
            cond = ir.UnaryOp("not", ir.KeyExists(d_id, key_expr))
        if pred_slot is not None:
            pf = field(pred_slot)
            cond = pf if cond is None else ir.BinOp("&&", pf, cond)
        body: ir.Expr = merged if cond is None else ir.If(cond, merged, b2)
        loop = ir.For(
            tuple(ir.Iter(idn) for idn in ids2),
            ir.MakeStruct(tuple(ir.NewBuilder(bt2) for bt2 in builders)),
            ir.Lambda((b2, i2, x2), body),
        )

        obj = NewWeldObject(deps, ir.Result(loop))
        return self._finish(
            obj,
            lambda v: Table(
                dict(zip(out_names, [np.asarray(a) for a in v])),
                eager=False),
            op="join", tables={"table": self.table, "right": other},
            memory_limit=memory_limit, kernelize=kernelize,
            kernel_impl=kernel_impl, collect_stats=collect_stats)


class _Explain:
    """Proxy returned by :meth:`Query.explain`: runs the next operator
    with stats collection (and, under ``analyze``, tracing) and wraps
    the outcome in a :class:`PlanReport` instead of returning it."""

    def __init__(self, query: Query, analyze: bool):
        self._q = query
        self._analyze = analyze

    def agg(self, *args, **kwargs) -> "PlanReport":
        return self._capture("agg", args, kwargs)

    def group_agg(self, *args, **kwargs) -> "PlanReport":
        return self._capture("group_agg", args, kwargs)

    def join(self, *args, **kwargs) -> "PlanReport":
        return self._capture("join", args, kwargs)

    def _capture(self, op: str, args, kwargs) -> "PlanReport":
        from ..core import obs

        if self._q.table.eager:
            raise ValueError(
                "explain() requires a lazy table — eager tables never "
                "build a Weld program to report on"
            )
        stats = kwargs.pop("collect_stats", None)
        stats = {} if stats is None else stats
        kwargs["collect_stats"] = stats
        was_on = obs.enabled()
        if self._analyze:
            obs.enable()
        pos = obs.mark()
        try:
            result = getattr(Query, op)(self._q, *args, **kwargs)
        finally:
            if self._analyze and not was_on:
                obs.disable()
        spans = obs.spans_since(pos) if self._analyze else []
        return PlanReport(op=op, stats=stats, spans=spans,
                          analyze=self._analyze, result=result)


class PlanReport:
    """Formatted EXPLAIN [ANALYZE] output for one weldrel operator."""

    def __init__(self, op: str, stats: dict, spans: list, analyze: bool,
                 result: object):
        self.op = op
        self.stats = stats
        self.spans = spans
        self.analyze = analyze
        self.result = result

    # -- structured accessors ------------------------------------------------

    def kernels(self) -> List[dict]:
        """One row per planned KernelCall in the program that ran."""
        plan = self.stats.get("plan.ir")
        if plan is None:
            return []
        rows = []
        for node in ir.walk(plan):
            if not isinstance(node, ir.KernelCall):
                continue
            params = dict(node.params)
            rows.append({
                "kernel": node.kernel,
                "n_rows": params.get("n_rows"),
                "block": {k: v for k, v in params.items()
                          if k in ("block", "bm", "bn", "bk")},
                "predicted_ns": params.get("predicted_ns"),
            })
        return rows

    def kernel_spans(self) -> List[dict]:
        """Measured per-launch rows (analyze=True only): predicted vs
        measured ns and their ratio."""
        rows = []
        for sp in self.spans:
            if not sp.name.startswith("kernel."):
                continue
            pred = sp.tags.get("predicted_ns")
            meas = sp.tags.get("measured_ns") or sp.dur_ns
            rows.append({
                "kernel": sp.name[len("kernel."):],
                "n_rows": sp.tags.get("n"),
                "predicted_ns": pred,
                "measured_ns": meas,
                "ratio": (meas / pred) if pred and meas else None,
            })
        return rows

    # -- rendering -----------------------------------------------------------

    def render(self) -> str:
        from ..core import obs
        from ..core.pretty import pretty

        st = self.stats
        kplan = st.get("kernelplan", {})
        lines = [
            f"== EXPLAIN{' ANALYZE' if self.analyze else ''} "
            f"weldrel.{self.op} ==",
            f"loops: {st.get('loops.before', '?')} -> "
            f"{st.get('loops.after', '?')} (after fusion)   "
            f"kernelize={kplan.get('mode', 'off')}   "
            f"matched={st.get('kernelize.matched', 0)}   "
            f"compile_ms={st.get('compile_ms', 0.0):.1f}",
        ]
        plan = st.get("plan.ir")
        if plan is not None:
            lines += ["", "-- fused IR (post-planning) --", pretty(plan)]
        krows = self.kernels()
        if krows:
            lines += ["", "-- routed kernels --"]
            for r in krows:
                blk = ",".join(f"{k}={v}" for k, v in r["block"].items())
                pred = (f"{r['predicted_ns'] / 1e3:.1f}us"
                        if r["predicted_ns"] else "-")
                lines.append(
                    f"  {r['kernel']:<24} n={r['n_rows']!s:<10} "
                    f"block[{blk}] predicted={pred}"
                )
        costs = kplan.get("costs") or []
        if costs:
            lines += ["", "-- cost-gate decisions --"]
            for c in costs:
                lines.append(
                    f"  {c.get('kernel'):<24} "
                    f"kernel={c.get('kernel_us', 0):.1f}us "
                    f"jnp={c.get('jnp_us', 0):.1f}us "
                    f"{'ROUTE' if c.get('routed') else 'reject'} "
                    f"({c.get('why', '')})"
                )
        if st.get("recovery.attempts"):
            lines += ["", "-- recovery --"]
            lines.append(
                f"  recovered after {st['recovery.attempts']} attempts "
                f"(capacity x{st.get('recovery.regrow_factor', 1)}"
                f"{', generic fallback' if st.get('recovery.fallback') else ''})"
            )
            for ev in st.get("recovery.events", []):
                lines.append(
                    f"  attempt {ev.get('attempt')}: {ev.get('action')} — "
                    f"{ev.get('detail')}"
                )
            for q in st.get("recovery.quarantined", []):
                lines.append(f"  quarantined: {q}")
        if st.get("verify.runs"):
            lines += ["", "-- verify --"]
            total = st.get("verify.ms", 0.0)
            lines.append(
                f"  weldcheck: {st['verify.runs']} checkpoints clean "
                f"(types, linearity, races, capacity) in {total:.1f}ms"
            )
            phases = st.get("verify.phases", [])
            by_phase: Dict[str, List[float]] = {}
            for name, ms in phases:
                by_phase.setdefault(name, []).append(ms)
            for name, times in by_phase.items():
                lines.append(
                    f"  {name:<24} x{len(times):<3} {sum(times):8.2f}ms"
                )
        if "bounds.certificate" in st:
            lines += ["", "-- bounds --"]
            lines.append(
                f"  peak-memory certificate: {st['bounds.certificate']}"
            )
            lines.append(
                f"  peak_bytes={st.get('bounds.peak_bytes')}   "
                f"admitted={st.get('bounds.admitted')}   "
                f"analysis_ms={st.get('bounds.ms', 0.0):.2f}"
            )
            out_rows = st.get("bounds.out_rows")
            if out_rows is not None:
                lo, hi = out_rows
                lines.append(
                    f"  out_rows in [{lo}, {'inf' if hi is None else hi}]"
                )
            for bl in st.get("bounds.builders") or []:
                lines.append(f"  {bl}")
        if self.analyze:
            mrows = self.kernel_spans()
            if mrows:
                lines += ["", "-- predicted vs measured (per launch) --"]
                for r in mrows:
                    pred = (f"{r['predicted_ns'] / 1e3:10.1f}"
                            if r["predicted_ns"] else f"{'-':>10}")
                    ratio = (f"{r['ratio']:.2f}x" if r["ratio"] else "-")
                    lines.append(
                        f"  {r['kernel']:<24} n={r['n_rows']!s:<10} "
                        f"pred_us={pred} meas_us="
                        f"{(r['measured_ns'] or 0) / 1e3:10.1f} "
                        f"ratio={ratio}"
                    )
            if self.spans:
                lines += ["", "-- span tree --",
                          obs.format_tree(self.spans)]
        return "\n".join(lines)

    __str__ = render

    def __repr__(self) -> str:
        return self.render()


class _Stage:
    """Proxy returned by :meth:`Query.stage`: the next operator call
    captures a :class:`StagedQuery` instead of evaluating."""

    def __init__(self, query: Query):
        self._q = query

    def agg(self, *args, **kwargs) -> "StagedQuery":
        return self._capture("agg", args, kwargs)

    def group_agg(self, *args, **kwargs) -> "StagedQuery":
        return self._capture("group_agg", args, kwargs)

    def join(self, *args, **kwargs) -> "StagedQuery":
        return self._capture("join", args, kwargs)

    def _capture(self, op: str, args, kwargs) -> "StagedQuery":
        if self._q.table.eager:
            raise ValueError(
                "stage()/compile() require a lazy table — eager tables "
                "evaluate immediately and never build a Weld program"
            )
        q = Query(self._q.table)
        q.pred = self._q.pred
        q._staged = True
        out = getattr(Query, op)(q, *args, **kwargs)
        if not isinstance(out, StagedQuery):  # pragma: no cover - guard
            raise ValueError(f"{op} did not reach the lazy tail; "
                             "cannot stage it")
        return out


class _Compile:
    """Proxy returned by :meth:`Query.compile`: the next operator call
    stages AND compiles, yielding a :class:`CompiledQuery`."""

    def __init__(self, query: Query, collect_stats: Optional[dict] = None):
        self._stage = _Stage(query)
        self._collect = collect_stats

    def agg(self, *args, **kwargs) -> "CompiledQuery":
        return self._stage._capture("agg", args, kwargs).compile(
            collect_stats=self._collect)

    def group_agg(self, *args, **kwargs) -> "CompiledQuery":
        return self._stage._capture("group_agg", args, kwargs).compile(
            collect_stats=self._collect)

    def join(self, *args, **kwargs) -> "CompiledQuery":
        return self._stage._capture("join", args, kwargs).compile(
            collect_stats=self._collect)


class StagedQuery:
    """One captured lazy operator: the stitched program, the bound
    tables, and the host-side result finalizer — nothing compiled yet.

    ``core.serve.QueryServer.submit`` accepts these directly (it reads
    ``program()``/``compile()``/``finalize`` by duck type);
    :meth:`compile` produces the reusable :class:`CompiledQuery`."""

    def __init__(self, op: str, obj: WeldObject, finalize: Callable,
                 tables: Dict[str, Table], memory_limit=None,
                 kernelize=None, kernel_impl=None):
        self.op = op
        self.obj = obj
        self.finalize = finalize
        self.tables = tables
        self.memory_limit = memory_limit
        self.kernelize = kernelize
        self.kernel_impl = kernel_impl
        self._prog = None

    def program(self):
        """The stitched :class:`~repro.core.lazy.Program` (cached)."""
        if self._prog is None:
            self._prog = build_program(self.obj)
        return self._prog

    def binding(self) -> Dict[str, Dict[str, str]]:
        """alias -> {column name -> program input name} for every bound
        table column that is actually a program input (filter predicates
        reach their columns through the same input objects, so
        re-binding a column re-binds the predicate too)."""
        prog = self.program()
        out: Dict[str, Dict[str, str]] = {}
        for alias, tbl in self.tables.items():
            cols = {}
            for cname, col in tbl.cols.items():
                oid = col.obj.obj_id
                if oid in prog.inputs:
                    cols[cname] = oid
            out[alias] = cols
        return out

    def compile(self, collect_stats: Optional[dict] = None
                ) -> "CompiledQuery":
        from ..core import runtime

        handle = runtime.compile_program(
            self.program(), memory_limit=self.memory_limit,
            kernelize=self.kernelize, kernel_impl=self.kernel_impl)
        if collect_stats is not None:
            collect_stats.update(handle.stats)
        return CompiledQuery(self, handle)


class CompiledQuery:
    """AOT handle for one weldrel operator: ``.stats``, ``.explain()``,
    and ``.run(**tables)`` re-binding same-shape tables against the
    cached executable with zero recompiles.

    ``run()`` with no arguments executes against the staged tables;
    ``run(table=t2)`` (and ``right=r2`` for joins) re-binds the named
    tables' columns by name.  Shapes and dtypes must match the compiled
    signature — anything else needs a fresh ``Query(...).compile()``."""

    def __init__(self, staged: StagedQuery, handle):
        self.staged = staged
        self.handle = handle
        self._binding = staged.binding()
        self._pos = {name: i
                     for i, name in enumerate(handle._low.input_names)}

    @property
    def stats(self) -> dict:
        return self.handle.stats

    @property
    def from_cache(self) -> bool:
        return self.handle.from_cache

    def explain(self) -> PlanReport:
        """The same EXPLAIN report ``Query.explain()`` renders, off the
        compiled plan's stats (cost-gate decisions included)."""
        return PlanReport(op=self.staged.op, stats=self.stats, spans=[],
                          analyze=False, result=None)

    def run(self, **tables):
        prog = self.staged.program()
        arrays = None
        if tables:
            arrays = list(self.handle._low.arrays)
            for alias, tbl in tables.items():
                mapping = self._binding.get(alias)
                if mapping is None:
                    raise KeyError(
                        f"unknown table alias {alias!r}; this "
                        f"{self.staged.op} binds {sorted(self._binding)}")
                for cname, iname in mapping.items():
                    if cname not in tbl.cols:
                        raise KeyError(
                            f"re-bound table {alias!r} is missing column "
                            f"{cname!r} required by the compiled plan")
                    enc = prog.inputs[iname][1]
                    arrays[self._pos[iname]] = enc.encode(
                        np.asarray(_host(tbl.cols[cname])))
        value = self.handle.run(arrays)
        return self.staged.finalize(value)


def _host(col: weldnp.ndarray) -> np.ndarray:
    """The numpy buffer behind a table column (eager or lazy)."""
    return col._eager if col.is_eager else np.asarray(col.obj.data)


def _fill_of(dt) -> object:
    """Per-dtype miss fill for left joins: NaN for floats, 0 for ints,
    False for bools (a sentinel fill, NOT pandas' float upcast)."""
    dt = np.dtype(dt)
    if np.issubdtype(dt, np.floating):
        return float("nan")
    if dt == np.dtype(np.bool_):
        return False
    return 0


def _check_join_keys(lcols: List[np.ndarray], rcols: List[np.ndarray],
                     multi: bool) -> None:
    """Pin the key semantics every path shares: mismatched key dtypes
    raise (the eager packed compare would silently conflate e.g. an int
    with a float's bitcast while the lazy dict raises a type error),
    NaN keys raise (eager NumPy would treat them as unmatchable while
    the packed-bits dict would equate identical payloads — neither
    silently), and multi-column int keys must fit the
    32-bit-per-column packed space."""
    for lc, rc in zip(lcols, rcols):
        if lc.dtype != rc.dtype:
            raise ValueError(
                f"join key dtype mismatch: {lc.dtype} vs {rc.dtype}; "
                "cast one side before joining"
            )
    for c in lcols + rcols:
        if np.issubdtype(c.dtype, np.floating) and np.isnan(c).any():
            raise ValueError(
                "join keys contain NaN; NaN join keys are unsupported "
                "(drop or fill them before joining)"
            )
        if multi and np.issubdtype(c.dtype, np.integer) and c.size:
            # strictly greater than INT32_MIN: -2^31 in a leading column
            # packs onto the hash table's EMPTY sentinel (INT64_MIN)
            if int(c.min()) <= -(2 ** 31) or int(c.max()) >= 2 ** 31:
                raise ValueError(
                    "multi-column join keys must fit in 32 bits per "
                    "column (the packed-key space is 64-bit; INT32_MIN "
                    "is reserved as the hash sentinel)"
                )


def _pack_host(cols: List[np.ndarray]) -> np.ndarray:
    """Host-side mirror of the backend's packed key space (jaxgen
    ``_pack_keys``): 32 bits per column, floats bit-cast through f32 —
    byte-identical packing, so the eager path and the dict paths agree
    on exactly which keys are equal (applied to multi-column keys AND
    single float key columns, which the jnp packing also bitcasts)."""
    packed = np.zeros(cols[0].shape[0], dtype=np.int64)
    for c in cols:
        if np.issubdtype(c.dtype, np.floating):
            c = np.where(c == 0, np.zeros_like(c), c)  # -0.0 == +0.0
            c = c.astype(np.float32).view(np.int32).astype(np.int64)
        else:
            c = c.astype(np.int64)
        packed = packed * np.int64(1 << 32) + (c & np.int64(0xFFFFFFFF))
    return packed


def _as_lazy(col: weldnp.ndarray) -> weldnp.ndarray:
    return col if col.obj is not None else weldnp.array(col._eager)


def _ety(k: int, ids: List[ir.Expr]) -> wt.Scalar:
    t = ids[k].ty
    assert isinstance(t, wt.Vec)
    return t.elem
