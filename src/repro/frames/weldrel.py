"""weldrel — the Spark SQL integration (paper §6).

Column-store tables with relational operators (scan/filter/project/
aggregate/grouped-aggregate).  Mirrors the paper's port: *each operator
emits its own loop, independent of downstream operators* — no hand-written
operator-fusion logic as in HyPer-style code generators — and Weld's
optimizer fuses the chain into one pass.  Used for the TPC-H Q1/Q6
benchmarks and the UDF workload.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import ir, macros as M, wtypes as wt
from ..core.lazy import Evaluate, NewWeldObject, WeldObject
from . import weldnp


class Table:
    def __init__(self, columns: Dict[str, np.ndarray], eager: bool = False):
        self.eager = eager
        self.cols = {
            k: weldnp.array(np.asarray(v), eager=eager)
            for k, v in columns.items()
        }

    def col(self, name: str) -> weldnp.ndarray:
        return self.cols[name]


class Query:
    """A chain of relational operators over a table.  Each operator appends
    an independent IR fragment; `collect()` is the evaluation point."""

    def __init__(self, table: Table):
        self.table = table
        self.pred: Optional[weldnp.ndarray] = None

    def filter(self, pred: weldnp.ndarray) -> "Query":
        self.pred = pred if self.pred is None else (self.pred & pred)
        return self

    # -- ungrouped aggregate ---------------------------------------------------

    def agg(self, exprs: Dict[str, Tuple[weldnp.ndarray, str]],
            kernelize=None, kernel_impl=None,
            collect_stats: Optional[dict] = None):
        """exprs: name -> (value column expression, op).  Returns dict of
        scalars; single fused pass over the data.  Under the default
        ``kernelize="auto"`` the fused filter+reduce routes onto the
        Pallas kernel library when the cost gate favors it — all
        aggregates share one multi-output kernel launch; ``"always"``/
        True forces the route, ``"off"``/False disables it."""
        if self.table.eager:
            out = {}
            m = self.pred._eager if self.pred is not None else None
            for name, (col, op) in exprs.items():
                v = col._eager
                if m is not None:
                    v = v[m]
                out[name] = {
                    "+": np.sum, "min": np.min, "max": np.max, "*": np.prod,
                }[op](v) if v.size else 0.0
            return out

        names = list(exprs)
        deps: List[WeldObject] = []
        ids: List[ir.Expr] = []
        seen: Dict[str, int] = {}

        def slot(arr: weldnp.ndarray) -> int:
            if arr.obj.obj_id not in seen:
                seen[arr.obj.obj_id] = len(ids)
                deps.append(arr.obj)
                ids.append(ir.Ident(arr.obj.obj_id, arr.obj.weld_type()))
            return seen[arr.obj.obj_id]

        val_slots = [slot(exprs[n][0]) for n in names]
        pred_slot = slot(self.pred) if self.pred is not None else None

        builders = tuple(
            wt.Merger(exprs[n][0].weld_elem_ty, exprs[n][1]) for n in names
        )
        sbt = wt.StructBuilder(builders)
        elem_ty = (
            wt.Struct(tuple(_ety(i, ids) for i in range(len(ids))))
            if len(ids) > 1 else _ety(0, ids)
        )
        b = ir.Ident(ir.fresh("b"), sbt)
        i = ir.Ident(ir.fresh("i"), wt.I64)
        x = ir.Ident(ir.fresh("x"), elem_ty)

        def field(k: int) -> ir.Expr:
            return ir.GetField(x, k) if len(ids) > 1 else x

        cur: ir.Expr = b
        items = []
        for k, n in enumerate(names):
            items.append(ir.Merge(ir.GetField(b, k), field(val_slots[k])))
        merged = ir.MakeStruct(tuple(items))
        if pred_slot is not None:
            body: ir.Expr = ir.If(field(pred_slot), merged, b)
        else:
            body = merged
        loop = ir.For(
            tuple(ir.Iter(idn) for idn in ids),
            ir.MakeStruct(tuple(ir.NewBuilder(bt) for bt in builders)),
            ir.Lambda((b, i, x), body),
        )
        obj = NewWeldObject(deps, ir.Result(loop))
        res = Evaluate(obj, kernelize=kernelize, kernel_impl=kernel_impl,
                       collect_stats=collect_stats).value
        return {n: res[k] for k, n in enumerate(names)}

    # -- grouped aggregate -------------------------------------------------------

    def group_agg(
        self,
        keys: Sequence[weldnp.ndarray],
        vals: Dict[str, Tuple[weldnp.ndarray, str]],
        capacity: int = 4096,
        kernelize=None,
        kernel_impl=None,
    ):
        """GROUP BY keys; all aggregates share ONE dictmerger pass.
        Returns {key_tuple: (agg,...)} (+ implicit count as last value).

        NOTE: grouped multi-aggregates build a struct-valued dictmerger,
        which the kernel planner does not yet route (ROADMAP: multi-agg
        fusion) — ``kernelize=True`` is accepted for API symmetry but
        currently always falls back to the generic sort-based path."""
        if self.table.eager:
            m = self.pred._eager if self.pred is not None else slice(None)
            karrs = [k._eager[m] for k in keys]
            varrs = [vals[n][0]._eager[m] for n in vals]
            packed = list(zip(*karrs))
            out: dict = {}
            for row_idx, kt in enumerate(packed):
                kt = tuple(x.item() for x in kt)
                slotv = out.setdefault(kt, [0.0] * len(varrs) + [0])
                for j, v in enumerate(varrs):
                    slotv[j] += v[row_idx]
                slotv[-1] += 1
            return {k: tuple(v) for k, v in out.items()}

        names = list(vals)
        deps: List[WeldObject] = []
        ids: List[ir.Expr] = []
        seen: Dict[str, int] = {}

        def slot(arr: weldnp.ndarray) -> int:
            if arr.obj.obj_id not in seen:
                seen[arr.obj.obj_id] = len(ids)
                deps.append(arr.obj)
                ids.append(ir.Ident(arr.obj.obj_id, arr.obj.weld_type()))
            return seen[arr.obj.obj_id]

        key_slots = [slot(k) for k in keys]
        val_slots = [slot(vals[n][0]) for n in names]
        pred_slot = slot(self.pred) if self.pred is not None else None
        ops = {vals[n][1] for n in names} | {"+"}
        assert ops == {"+"}, "grouped aggregates support sum/count"

        key_ty = wt.Struct(tuple(_ety(s, ids) for s in key_slots)) \
            if len(key_slots) > 1 else _ety(key_slots[0], ids)
        val_ty = wt.Struct(
            tuple(_ety(s, ids) for s in val_slots) + (wt.I64,)
        )
        bt = wt.DictMerger(key_ty, val_ty, "+")
        elem_ty = (
            wt.Struct(tuple(_ety(i, ids) for i in range(len(ids))))
            if len(ids) > 1 else _ety(0, ids)
        )
        b = ir.Ident(ir.fresh("b"), bt)
        i = ir.Ident(ir.fresh("i"), wt.I64)
        x = ir.Ident(ir.fresh("x"), elem_ty)

        def field(k: int) -> ir.Expr:
            return ir.GetField(x, k) if len(ids) > 1 else x

        key_expr = (
            ir.MakeStruct(tuple(field(s) for s in key_slots))
            if len(key_slots) > 1 else field(key_slots[0])
        )
        val_expr = ir.MakeStruct(
            tuple(field(s) for s in val_slots) + (ir.Literal(1, wt.I64),)
        )
        merged = ir.Merge(b, ir.MakeStruct((key_expr, val_expr)))
        body: ir.Expr = merged if pred_slot is None else ir.If(
            field(pred_slot), merged, b
        )
        loop = ir.For(
            tuple(ir.Iter(idn) for idn in ids),
            ir.NewBuilder(bt, arg=ir.Literal(capacity, wt.I64)),
            ir.Lambda((b, i, x), body),
        )
        obj = NewWeldObject(deps, ir.Result(loop))
        return Evaluate(obj, kernelize=kernelize,
                        kernel_impl=kernel_impl).value


def _ety(k: int, ids: List[ir.Expr]) -> wt.Scalar:
    t = ids[k].ty
    assert isinstance(t, wt.Vec)
    return t.elem
