"""welddf — the Pandas integration (paper §6).

DataFrames are named collections of columns; columns ARE weldnp arrays, so
dataframe operators and numpy operators compose into one Weld program (the
paper's crime-index workload is exactly this composition).

Ported operators (the set the paper ports): filtering / predicate masking,
column arithmetic, aggregation, groupby-aggregate, unique, and fixed-width
"slicing" of zip codes.  The paper slices zipcode *strings*; TPU-side we
adapt to fixed-width numeric codes (zip//10**k), as documented in
DESIGN.md §2 — same data movement, no variable-length strings.

A filtered dataframe is *lazy*: it carries the predicate column and only
materializes (filter+op fused) when an operator consumes it — this is what
lets Weld fuse the paper's Listing 7 into a single masked pass.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import numpy as np

from ..core import ir, macros as M, wtypes as wt
from ..core.lazy import Evaluate, NewWeldObject
from . import weldnp


class Series(weldnp.ndarray):
    """A named column — a weldnp array with an optional pending filter."""


def _as_col(arr) -> weldnp.ndarray:
    if isinstance(arr, weldnp.ndarray):
        return arr
    return weldnp.array(np.asarray(arr))


class DataFrame:
    def __init__(self, columns: Dict[str, object], mask: Optional[weldnp.ndarray] = None,
                 eager: bool = False):
        self.eager = eager
        self.columns: Dict[str, weldnp.ndarray] = {}
        for k, v in columns.items():
            if isinstance(v, weldnp.ndarray):
                self.columns[k] = v
            else:
                self.columns[k] = weldnp.array(np.asarray(v), eager=eager)
        #: pending row predicate (lazy filter), None = all rows
        self.mask = mask

    # -- basic access ---------------------------------------------------------

    def __getitem__(self, key):
        if isinstance(key, str):
            col = self.columns[key]
            if self.mask is None:
                return col
            return _apply_filter(col, self.mask)
        if isinstance(key, weldnp.ndarray):  # boolean predicate
            m = key if self.mask is None else _and(self.mask, key)
            return DataFrame(self.columns, mask=m, eager=self.eager)
        raise KeyError(key)

    def __setitem__(self, key: str, value):
        assert self.mask is None, "cannot assign into a filtered view"
        self.columns[key] = _as_col(value)

    def raw(self, key: str) -> weldnp.ndarray:
        """Column WITHOUT applying the pending filter."""
        return self.columns[key]

    # -- ported operators -------------------------------------------------------

    def filter(self, pred: weldnp.ndarray) -> "DataFrame":
        return self[pred]

    def agg_sum(self, key: str):
        col = self.columns[key]
        if self.eager:
            m = self.mask._eager if self.mask is not None else None
            d = col._eager
            out = np.sum(d[m] if m is not None else d)
            return weldnp.ndarray(None, (), out.dtype, eager_data=np.asarray(out))
        if self.mask is None:
            return col.sum()
        # fused filter+reduce — the paper's Listing 10
        expr = M.filter_reduce(
            _zip_pred_val(self.mask, col), _pred_fn, "+", _val_fn
        )
        obj = NewWeldObject([self.mask.obj, col.obj], expr)
        return weldnp.ndarray(obj, (), col.dtype)

    def count(self):
        if self.eager:
            if self.mask is not None:
                out = np.asarray(int(np.sum(self.mask._eager)))
            else:
                out = np.asarray(len(next(iter(self.columns.values()))._eager))
            return weldnp.ndarray(None, (), out.dtype, eager_data=out)
        if self.mask is None:
            any_col = next(iter(self.columns.values()))
            expr = ir.Len(ir.Ident(any_col.obj.obj_id, any_col.obj.weld_type()))
            return weldnp.ndarray(
                NewWeldObject([any_col.obj], expr), (), np.int64
            )
        ones = self.mask.astype(np.int64)
        return ones.sum()

    def groupby_sum(self, key: str, val: str, capacity: int = 4096,
                    kernelize=None, kernel_impl=None,
                    collect_stats: Optional[dict] = None) -> dict:
        """dict[key -> sum(val)] via a dictmerger; evaluation point.

        Under the default ``kernelize="auto"`` the group-by routes onto
        the segment-reduce Pallas kernel when the key column is
        int-typed, the capacity fits the kernel's VMEM tile, and the
        roofline cost gate favors it (see ``repro.core.kernelplan``);
        ``"always"``/True forces the route, ``"off"``/False disables."""
        kcol, vcol = self.columns[key], self.columns[val]
        if self.eager:
            k, v = kcol._eager, vcol._eager
            if self.mask is not None:
                m = self.mask._eager
                k, v = k[m], v[m]
            out: dict = {}
            # numpy-native groupby
            uk, inv = np.unique(k, return_inverse=True)
            sums = np.bincount(inv, weights=v.astype(np.float64))
            return {int(a): float(b) for a, b in zip(uk, sums)}
        kid = ir.Ident(kcol.obj.obj_id, kcol.obj.weld_type())
        vid = ir.Ident(vcol.obj.obj_id, vcol.obj.weld_type())
        deps = [kcol.obj, vcol.obj]
        if self.mask is None:
            expr = M.groupby_agg(kid, vid, "+", capacity=capacity)
        else:
            mid = ir.Ident(self.mask.obj.obj_id, self.mask.obj.weld_type())
            deps.append(self.mask.obj)
            bt = wt.DictMerger(kcol.weld_elem_ty, vcol.weld_elem_ty, "+")
            struct_ty = wt.Struct((kcol.weld_elem_ty, vcol.weld_elem_ty, wt.Bool))
            b = ir.Ident(ir.fresh("b"), bt)
            i = ir.Ident(ir.fresh("i"), wt.I64)
            x = ir.Ident(ir.fresh("x"), struct_ty)
            body = ir.If(
                ir.GetField(x, 2),
                ir.Merge(b, ir.MakeStruct((ir.GetField(x, 0), ir.GetField(x, 1)))),
                b,
            )
            expr = ir.Result(
                ir.For(
                    (ir.Iter(kid), ir.Iter(vid), ir.Iter(mid)),
                    ir.NewBuilder(bt, arg=ir.Literal(capacity, wt.I64)),
                    ir.Lambda((b, i, x), body),
                )
            )
        obj = NewWeldObject(deps, expr)
        return Evaluate(obj, kernelize=kernelize, kernel_impl=kernel_impl,
                        collect_stats=collect_stats).value

    def unique(self, key: str, capacity: int = 4096,
               kernelize=None, kernel_impl=None) -> np.ndarray:
        """Distinct values of a column (dictmerger keys)."""
        col = self.columns[key]
        if self.eager:
            v = col._eager
            if self.mask is not None:
                v = v[self.mask._eager]
            return np.unique(v)
        d = self.groupby_sum(key, key, capacity=capacity,
                             kernelize=kernelize, kernel_impl=kernel_impl)
        return np.sort(np.array(list(d.keys())))

    def slice_code(self, key: str, digits: int = 5) -> weldnp.ndarray:
        """Fixed-width code 'slice': keep the top `digits` digits
        (numeric adaptation of the paper's zipcode string slicing)."""
        col = self.columns[key]
        if self.eager:
            v = col._eager
            width = np.where(v > 0, np.floor(np.log10(np.maximum(v, 1))) + 1, 1)
            drop = np.maximum(width - digits, 0).astype(np.int64)
            out = (v // np.power(10, drop)).astype(v.dtype)
            return weldnp.ndarray(None, out.shape, out.dtype, eager_data=out)
        ty = col.weld_elem_ty

        def fn(x):
            fx = ir.Cast(x, wt.F64)
            width = ir.BinOp(
                "+",
                ir.UnaryOp(
                    "floor",
                    ir.BinOp(
                        "/",
                        ir.UnaryOp("log", ir.BinOp("max", fx, ir.Literal(1.0, wt.F64))),
                        ir.Literal(float(np.log(10.0)), wt.F64),
                    ),
                ),
                ir.Literal(1.0, wt.F64),
            )
            drop = ir.BinOp("max", ir.BinOp("-", width, ir.Literal(float(digits), wt.F64)),
                            ir.Literal(0.0, wt.F64))
            div = ir.BinOp("pow", ir.Literal(10.0, wt.F64), drop)
            return ir.Cast(ir.UnaryOp("floor", ir.BinOp("/", fx, div)), ty)

        expr = M.map_(ir.Ident(col.obj.obj_id, col.obj.weld_type()), fn)
        return weldnp.ndarray(NewWeldObject([col.obj], expr), col.shape, col.dtype)


# -- helpers ------------------------------------------------------------------


def _and(a: weldnp.ndarray, b: weldnp.ndarray) -> weldnp.ndarray:
    return a & b


def _apply_filter(col: weldnp.ndarray, mask: weldnp.ndarray) -> weldnp.ndarray:
    """Materializes filter(col, mask) — a conditional vecbuilder merge;
    usually fused away into whatever consumes it."""
    mid = ir.Ident(mask.obj.obj_id, mask.obj.weld_type())
    cid = ir.Ident(col.obj.obj_id, col.obj.weld_type())
    et = col.weld_elem_ty
    bt = wt.VecBuilder(et)
    b = ir.Ident(ir.fresh("b"), bt)
    i = ir.Ident(ir.fresh("i"), wt.I64)
    x = ir.Ident(ir.fresh("x"), wt.Struct((et, wt.Bool)))
    body = ir.If(ir.GetField(x, 1), ir.Merge(b, ir.GetField(x, 0)), b)
    expr = ir.Result(
        ir.For((ir.Iter(cid), ir.Iter(mid)), ir.NewBuilder(bt),
               ir.Lambda((b, i, x), body))
    )
    obj = NewWeldObject([col.obj, mask.obj], expr)
    out = weldnp.ndarray(obj, col.shape, col.dtype)
    return out


def _zip_pred_val(mask: weldnp.ndarray, col: weldnp.ndarray):
    """zip(col, mask) as a single vec-of-struct expression for macros."""
    cid = ir.Ident(col.obj.obj_id, col.obj.weld_type())
    mid = ir.Ident(mask.obj.obj_id, mask.obj.weld_type())
    return M.zip_map([cid, mid], lambda v, m: ir.MakeStruct((v, m)))


def _pred_fn(x):
    return ir.GetField(x, 1)


def _val_fn(x):
    return ir.GetField(x, 0)
