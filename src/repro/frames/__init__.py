"""Library integrations (paper §6): NumPy-, Pandas-, Spark SQL- and
TensorFlow-shaped libraries whose operators emit Weld IR fragments through
the lazy runtime API.  Operators interoperate across libraries — a welddf
column *is* a weldnp array — so the optimizer sees the whole workflow."""
