"""weldnp — the NumPy integration (paper §6).

A lazy ndarray wrapper: every operator returns a new `ndarray` holding a
WeldObject; printing / `.to_numpy()` / `.item()` force evaluation of the
whole accumulated workflow as ONE fused program.  Mirrors the paper's
integration style: ported operators accept either a plain numpy array or a
wrapper, and return wrappers with the inputs as dependencies.

`eager=True` arrays compute with real NumPy per call — the paper's
"native library" baseline (each operator is an optimized C kernel, results
materialize between calls).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Union

import numpy as np

from ..core import ir, macros as M, wtypes as wt
from ..core.lazy import Evaluate, NewWeldObject, WeldObject

Number = Union[int, float, bool]


def _scalar_lit(v: Number, like_ty: wt.Scalar) -> ir.Expr:
    if like_ty.is_float:
        return ir.Literal(float(v), like_ty)
    if like_ty == wt.Bool:
        return ir.Literal(bool(v), like_ty)
    return ir.Literal(int(v), like_ty)


class ndarray:
    """Lazily evaluated array.  1-D general; 2-D supported for linear
    algebra (dot/matvec/matmul) and row-wise maps."""

    def __init__(self, obj: WeldObject, shape: tuple, dtype, eager_data=None):
        self.obj = obj
        self.shape = tuple(shape)
        self.dtype = np.dtype(dtype)
        self._eager = eager_data  # numpy array when in eager mode

    # -- construction -------------------------------------------------------

    @staticmethod
    def from_numpy(arr: np.ndarray, eager: bool = False) -> "ndarray":
        arr = np.asarray(arr)
        if eager:
            return ndarray(None, arr.shape, arr.dtype, eager_data=arr)
        obj = NewWeldObject(arr, None)
        return ndarray(obj, arr.shape, arr.dtype)

    @property
    def is_eager(self) -> bool:
        return self._eager is not None

    @property
    def ndim(self) -> int:
        return len(self.shape)

    def _ident(self) -> ir.Expr:
        return ir.Ident(self.obj.obj_id, self.obj.weld_type())

    @property
    def weld_elem_ty(self) -> wt.Scalar:
        return wt.dtype_to_weld(self.dtype)

    # -- evaluation points ---------------------------------------------------

    def evaluate(self, kernelize=None, kernel_impl=None, **kw):
        """Force evaluation of the accumulated workflow as one program.

        ``kernelize`` selects the planner mode — the default ``"auto"``
        routes matched fused loops through the Pallas kernel library
        whenever the roofline cost model favors them, ``"always"``/True
        forces every match, ``"off"``/False bypasses the planner
        (``repro.core.kernelplan``); ``kernel_impl`` selects
        ref / interpret / pallas for the routed kernel calls.
        """
        if self.is_eager:
            return self._eager
        res = Evaluate(self.obj, kernelize=kernelize,
                       kernel_impl=kernel_impl, **kw)
        return res.value

    def to_numpy(self, **kw) -> np.ndarray:
        v = np.asarray(self.evaluate(**kw))
        if self.ndim == 2 and v.ndim == 1:
            v = v.reshape(self.shape)
        return v

    def item(self):
        return self.to_numpy().item()

    def __str__(self) -> str:  # print() is an evaluation point (paper §4)
        return str(self.to_numpy())

    def __len__(self) -> int:
        return self.shape[0]

    # -- elementwise operators ----------------------------------------------

    def _binop(self, other, op: str, reverse: bool = False) -> "ndarray":
        if self.is_eager:
            o = other._eager if isinstance(other, ndarray) else other
            a, b = (o, self._eager) if reverse else (self._eager, o)
            out = _np_result(op, a, b)
            return ndarray(None, out.shape, out.dtype, eager_data=out)
        if isinstance(other, ndarray):
            assert other.shape == self.shape, "weldnp: shape mismatch"
            out_dt = np.promote_types(self.dtype, other.dtype) \
                if op not in _CMP else np.dtype(bool)
            sid, oid = self._ident(), other._ident()
            l, r = (oid, sid) if reverse else (sid, oid)
            lt = other.weld_elem_ty if reverse else self.weld_elem_ty
            rt = self.weld_elem_ty if reverse else other.weld_elem_ty
            tgt = wt.dtype_to_weld(np.promote_types(self.dtype, other.dtype))
            expr = M.zip_map(
                [l, r],
                lambda x, y: ir.BinOp(op, _coerce(x, lt, tgt), _coerce(y, rt, tgt)),
            )
            obj = NewWeldObject([self.obj, other.obj], expr)
            return ndarray(obj, self.shape, out_dt)
        # scalar operand
        out_dt = np.promote_types(self.dtype, np.result_type(other)) \
            if op not in _CMP else np.dtype(bool)
        tgt = wt.dtype_to_weld(np.promote_types(self.dtype, np.result_type(other)))
        lit = _scalar_lit(other, tgt)
        me = self.weld_elem_ty
        fn = (lambda x: ir.BinOp(op, lit, _coerce(x, me, tgt))) if reverse \
            else (lambda x: ir.BinOp(op, _coerce(x, me, tgt), lit))
        expr = M.map_(self._ident(), fn)
        obj = NewWeldObject([self.obj], expr)
        return ndarray(obj, self.shape, out_dt)

    def __add__(self, o):
        return self._binop(o, "+")

    def __radd__(self, o):
        return self._binop(o, "+", reverse=True)

    def __sub__(self, o):
        return self._binop(o, "-")

    def __rsub__(self, o):
        return self._binop(o, "-", reverse=True)

    def __mul__(self, o):
        return self._binop(o, "*")

    def __rmul__(self, o):
        return self._binop(o, "*", reverse=True)

    def __truediv__(self, o):
        return self._binop(o, "/")

    def __rtruediv__(self, o):
        return self._binop(o, "/", reverse=True)

    def __gt__(self, o):
        return self._binop(o, ">")

    def __ge__(self, o):
        return self._binop(o, ">=")

    def __lt__(self, o):
        return self._binop(o, "<")

    def __le__(self, o):
        return self._binop(o, "<=")

    def __eq__(self, o):  # type: ignore[override]
        return self._binop(o, "==")

    def __ne__(self, o):  # type: ignore[override]
        return self._binop(o, "!=")

    def __and__(self, o):
        return self._binop(o, "&&")

    def __or__(self, o):
        return self._binop(o, "||")

    def __neg__(self):
        return self._unary("neg")

    def __hash__(self):
        return id(self)

    def _unary(self, op: str, out_float: bool = False) -> "ndarray":
        if self.is_eager:
            out = np.asarray(_np_unary(op, self._eager))
            return ndarray(None, out.shape, out.dtype, eager_data=out)
        expr = M.map_(self._ident(), lambda x: ir.UnaryOp(op, x))
        obj = NewWeldObject([self.obj], expr)
        dt = self.dtype
        if op in ("exp", "log", "sqrt", "erf", "sin", "cos", "tanh",
                  "sigmoid", "rsqrt"):
            dt = np.promote_types(self.dtype, np.float64) \
                if self.dtype.kind in "iub" else self.dtype
        return ndarray(obj, self.shape, dt)

    # -- reductions & linalg ---------------------------------------------------

    def sum(self) -> "ndarray":
        return self._reduce("+")

    def prod(self) -> "ndarray":
        return self._reduce("*")

    def min(self) -> "ndarray":
        return self._reduce("min")

    def max(self) -> "ndarray":
        return self._reduce("max")

    def _reduce(self, op: str) -> "ndarray":
        if self.is_eager:
            fn = {"+": np.sum, "*": np.prod, "min": np.min, "max": np.max}[op]
            out = np.asarray(fn(self._eager))
            return ndarray(None, (), out.dtype, eager_data=out)
        expr = M.reduce_(self._ident(), op)
        obj = NewWeldObject([self.obj], expr)
        return ndarray(obj, (), self.dtype)

    def dot(self, other: "ndarray") -> "ndarray":
        if self.is_eager:
            return ndarray(None, np.dot(self._eager, other._eager).shape, None,
                           eager_data=np.dot(self._eager, other._eager))
        if self.ndim == 1 and other.ndim == 1:
            expr = M.dot(self._ident(), other._ident())
            obj = NewWeldObject([self.obj, other.obj], expr)
            return ndarray(obj, (), np.promote_types(self.dtype, other.dtype))
        if self.ndim == 2 and other.ndim == 1:
            expr = ir.CUDF(
                "linalg.matvec", (self._ident(), other._ident()),
                wt.Vec(wt.dtype_to_weld(np.promote_types(self.dtype, other.dtype))),
            )
            obj = NewWeldObject([self.obj, other.obj], expr)
            return ndarray(obj, (self.shape[0],),
                           np.promote_types(self.dtype, other.dtype))
        if self.ndim == 2 and other.ndim == 2:
            expr = ir.CUDF(
                "linalg.matmul", (self._ident(), other._ident()),
                wt.Vec(wt.Vec(wt.dtype_to_weld(
                    np.promote_types(self.dtype, other.dtype)))),
            )
            obj = NewWeldObject([self.obj, other.obj], expr)
            return ndarray(obj, (self.shape[0], other.shape[1]),
                           np.promote_types(self.dtype, other.dtype))
        raise ValueError("unsupported dot shapes")

    def astype(self, dtype) -> "ndarray":
        dtype = np.dtype(dtype)
        if self.is_eager:
            return ndarray(None, self.shape, dtype,
                           eager_data=self._eager.astype(dtype))
        ty = wt.dtype_to_weld(dtype)
        expr = M.map_(self._ident(), lambda x: ir.Cast(x, ty))
        return ndarray(NewWeldObject([self.obj], expr), self.shape, dtype)


_CMP = {"==", "!=", "<", "<=", ">", ">="}


def _coerce(x: ir.Expr, have: wt.Scalar, want: wt.Scalar) -> ir.Expr:
    return x if have == want else ir.Cast(x, want)


def _np_result(op, a, b):
    return {
        "+": np.add, "-": np.subtract, "*": np.multiply, "/": np.divide,
        ">": np.greater, ">=": np.greater_equal, "<": np.less,
        "<=": np.less_equal, "==": np.equal, "!=": np.not_equal,
        "&&": np.logical_and, "||": np.logical_or,
    }[op](a, b)


def _np_unary(op, a):
    try:
        from scipy.special import erf as _erf  # pragma: no cover
    except Exception:
        _erf = np.vectorize(math.erf)
    return {
        "neg": np.negative, "not": np.logical_not, "exp": np.exp,
        "log": np.log, "sqrt": np.sqrt, "erf": _erf, "sin": np.sin,
        "cos": np.cos, "tanh": np.tanh, "abs": np.abs,
        "sigmoid": lambda x: 1 / (1 + np.exp(-x)), "floor": np.floor,
        "rsqrt": lambda x: 1 / np.sqrt(x),
    }[op](a)


# -- module-level API (numpy-like) ------------------------------------------


def array(data, dtype=None, eager: bool = False) -> ndarray:
    arr = np.asarray(data, dtype=dtype)
    return ndarray.from_numpy(arr, eager=eager)


def exp(a: ndarray) -> ndarray:
    return a._unary("exp")


def log(a: ndarray) -> ndarray:
    return a._unary("log")


def sqrt(a: ndarray) -> ndarray:
    return a._unary("sqrt")


def erf(a: ndarray) -> ndarray:
    return a._unary("erf")


def tanh(a: ndarray) -> ndarray:
    return a._unary("tanh")


def sigmoid(a: ndarray) -> ndarray:
    return a._unary("sigmoid")


def abs(a: ndarray) -> ndarray:  # noqa: A001
    return a._unary("abs")


def dot(a: ndarray, b: ndarray) -> ndarray:
    return a.dot(b)


def sum(a: ndarray) -> ndarray:  # noqa: A001
    return a.sum()


def minimum(a: ndarray, o: Number) -> ndarray:
    return a._binop(o, "min")


def maximum(a: ndarray, o: Number) -> ndarray:
    return a._binop(o, "max")


def where(cond: ndarray, a, b) -> ndarray:
    """Elementwise select (predicated — no branch)."""
    if cond.is_eager:
        av = a._eager if isinstance(a, ndarray) else a
        bv = b._eager if isinstance(b, ndarray) else b
        out = np.where(cond._eager, av, bv)
        return ndarray(None, out.shape, out.dtype, eager_data=out)
    deps = [cond.obj]
    ids = [ir.Ident(cond.obj.obj_id, cond.obj.weld_type())]
    sels = []
    for v in (a, b):
        if isinstance(v, ndarray):
            deps.append(v.obj)
            ids.append(v._ident())
            sels.append(None)
        else:
            sels.append(v)
    dt = np.promote_types(
        a.dtype if isinstance(a, ndarray) else np.result_type(a),
        b.dtype if isinstance(b, ndarray) else np.result_type(b),
    )
    tgt = wt.dtype_to_weld(dt)

    def body(*xs):
        c = xs[0]
        vals = list(xs[1:])
        out = []
        for v in (a, b):
            if isinstance(v, ndarray):
                out.append(_coerce(vals.pop(0), v.weld_elem_ty, tgt))
            else:
                out.append(_scalar_lit(v, tgt))
        return ir.Select(c, out[0], out[1])

    expr = M.zip_map(ids, body)
    return ndarray(NewWeldObject(deps, expr), cond.shape, dt)
