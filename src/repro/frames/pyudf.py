"""Python UDF → Weld IR translator (paper §4.4, Listing 6).

Walks the Python AST of a decorated function and emits a Weld lambda.
Supports the expression subset the paper's translator handles: arithmetic,
comparisons, boolean ops, conditional expressions, math calls, and names
from the closure (which become extra dependencies).

    @weld("(f64) => f64")
    def increment(x): return x + 1.0
"""
from __future__ import annotations

import ast
import inspect
import re
import textwrap
from typing import Callable, Dict, List

from ..core import ir, wtypes as wt

_TY = {
    "bool": wt.Bool, "i8": wt.I8, "i32": wt.I32, "i64": wt.I64,
    "f32": wt.F32, "f64": wt.F64,
}

_MATH_FNS = {"exp", "log", "sqrt", "erf", "sin", "cos", "tanh", "abs",
             "floor"}


def parse_signature(sig: str):
    m = re.match(r"\(([^)]*)\)\s*=>\s*(\w+)", sig.strip())
    if not m:
        raise ValueError(f"bad weld signature {sig!r}")
    params = [p.strip() for p in m.group(1).split(",") if p.strip()]
    return [_TY[p] for p in params], _TY[m.group(2)]


class WeldUDF:
    def __init__(self, fn: Callable, param_tys, ret_ty):
        self.fn = fn
        self.param_tys = param_tys
        self.ret_ty = ret_ty
        self._ast = _fn_body_ast(fn)
        self.__name__ = fn.__name__

    def __call__(self, *args):  # still a normal python function
        return self.fn(*args)

    def to_ir(self, args: List[ir.Expr]) -> ir.Expr:
        """Instantiate the UDF body with the given argument expressions."""
        names = list(inspect.signature(self.fn).parameters)
        env: Dict[str, ir.Expr] = dict(zip(names, args))
        closure = inspect.getclosurevars(self.fn)
        consts = {**closure.globals, **closure.nonlocals}
        return _emit(self._ast, env, consts, self.ret_ty)


def weld(signature: str):
    param_tys, ret_ty = parse_signature(signature)

    def deco(fn):
        return WeldUDF(fn, param_tys, ret_ty)

    return deco


def _fn_body_ast(fn) -> ast.expr:
    src = textwrap.dedent(inspect.getsource(fn))
    # strip decorators
    tree = ast.parse(src)
    fdef = tree.body[0]
    assert isinstance(fdef, ast.FunctionDef)
    if len(fdef.body) != 1 or not isinstance(fdef.body[0], ast.Return):
        raise ValueError("UDF must be a single return expression")
    return fdef.body[0].value


_BINOP = {
    ast.Add: "+", ast.Sub: "-", ast.Mult: "*", ast.Div: "/", ast.Mod: "%",
    ast.Pow: "pow",
}
_CMP = {
    ast.Gt: ">", ast.GtE: ">=", ast.Lt: "<", ast.LtE: "<=",
    ast.Eq: "==", ast.NotEq: "!=",
}


def _emit(node: ast.expr, env, consts, ret_ty) -> ir.Expr:
    def rec(n) -> ir.Expr:
        if isinstance(n, ast.BinOp):
            op = _BINOP.get(type(n.op))
            if op is None:
                raise ValueError(f"unsupported operator {ast.dump(n.op)}")
            return ir.BinOp(op, rec(n.left), rec(n.right))
        if isinstance(n, ast.Compare):
            if len(n.ops) != 1:
                raise ValueError("chained comparisons unsupported")
            return ir.BinOp(_CMP[type(n.ops[0])], rec(n.left),
                            rec(n.comparators[0]))
        if isinstance(n, ast.BoolOp):
            op = "&&" if isinstance(n.op, ast.And) else "||"
            out = rec(n.values[0])
            for v in n.values[1:]:
                out = ir.BinOp(op, out, rec(v))
            return out
        if isinstance(n, ast.UnaryOp):
            if isinstance(n.op, ast.USub):
                return ir.UnaryOp("neg", rec(n.operand))
            if isinstance(n.op, ast.Not):
                return ir.UnaryOp("not", rec(n.operand))
            raise ValueError("unsupported unary op")
        if isinstance(n, ast.IfExp):
            return ir.Select(rec(n.test), rec(n.body), rec(n.orelse))
        if isinstance(n, ast.Call):
            fname = None
            if isinstance(n.func, ast.Attribute):  # math.exp(...)
                fname = n.func.attr
            elif isinstance(n.func, ast.Name):
                fname = n.func.id
            if fname in _MATH_FNS:
                return ir.UnaryOp(fname, _as_float(rec(n.args[0])))
            if fname in ("min", "max"):
                return ir.BinOp(fname, rec(n.args[0]), rec(n.args[1]))
            raise ValueError(f"unsupported call {fname}")
        if isinstance(n, ast.Constant):
            v = n.value
            if isinstance(v, bool):
                return ir.Literal(v, wt.Bool)
            if isinstance(v, int):
                # match the UDF's float context when the constant mixes
                # with float math — emit f64 for float returns
                if ret_ty.is_float:
                    return ir.Literal(float(v), wt.F64)
                return ir.Literal(v, wt.I64)
            if isinstance(v, float):
                return ir.Literal(v, wt.F64)
            raise ValueError(f"unsupported constant {v!r}")
        if isinstance(n, ast.Name):
            if n.id in env:
                return env[n.id]
            if n.id in consts:
                v = consts[n.id]
                if isinstance(v, (int, float, bool)):
                    return rec(ast.Constant(v))
            raise ValueError(f"unbound name {n.id}")
        raise ValueError(f"unsupported syntax {ast.dump(n)[:60]}")

    return rec(node)


def _as_float(e: ir.Expr) -> ir.Expr:
    try:
        t = ir.typeof(e)
    except Exception:
        return e
    if isinstance(t, wt.Scalar) and not t.is_float:
        return ir.Cast(e, wt.F64)
    return e
