"""weldflow — the TensorFlow integration (paper §6).

A tiny lazily-evaluated dataflow-graph library: ops build a graph of
`Node`s; `Session.run` executes.  The Weld integration follows the paper:
(i) a `WeldOp` node runs an arbitrary Weld expression, and (ii) a *graph
transformer* replaces every maximal subgraph of Weld-portable operators
with one WeldOp (relying on Weld to fuse the merged expressions).  The
engine itself is untouched.

Three execution modes for benchmarks:
  * ``native``  — per-op execution, each op its own jit'd kernel with
    materialized results (TensorFlow-without-XLA analogue),
  * ``xla``     — whole graph in one ``jax.jit`` (TensorFlow-with-XLA:
    this IS XLA, so the comparison in Fig. 5d is exact),
  * ``weld``    — graph transformer + WeldOp + Weld optimizer.
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core import ir, macros as M, wtypes as wt
from ..core.lazy import Evaluate, NewWeldObject, WeldObject
from . import weldnp

_ids = itertools.count()


class Node:
    def __init__(self, op: str, inputs: List["Node"], payload=None):
        self.op = op
        self.inputs = inputs
        self.payload = payload  # constants: numpy array
        self.nid = next(_ids)

    # operator sugar
    def __add__(self, o):
        return Node("add", [self, _const(o)])

    def __sub__(self, o):
        return Node("sub", [self, _const(o)])

    def __mul__(self, o):
        return Node("mul", [self, _const(o)])


def _const(v) -> Node:
    if isinstance(v, Node):
        return v
    return Node("const", [], payload=np.asarray(v))


def placeholder() -> Node:
    return Node("placeholder", [])


def constant(v) -> Node:
    return _const(v)


def matvec(m: Node, v: Node) -> Node:
    return Node("matvec", [m, v])


def sigmoid(x: Node) -> Node:
    return Node("sigmoid", [x])


def log(x: Node) -> Node:
    return Node("log", [x])


def reduce_mean(x: Node) -> Node:
    return Node("mean", [x])


def reduce_sum(x: Node) -> Node:
    return Node("sum", [x])


#: ops our Weld port understands (the paper ports a subset; the rest run
#: natively and break WeldOp regions)
WELD_PORTABLE = {
    "add", "sub", "mul", "sigmoid", "log", "mean", "sum", "matvec", "const",
    "placeholder", "weldop",
}


class Session:
    def __init__(self, mode: str = "weld"):
        assert mode in ("native", "xla", "weld")
        self.mode = mode

    def run(self, node: Node, feed: Dict[Node, np.ndarray]):
        if self.mode == "native":
            return _run_native(node, feed)
        if self.mode == "xla":
            return _run_xla(node, feed)
        return _run_weld(node, feed)


# -- native per-op execution ---------------------------------------------------


def _run_native(node: Node, feed) -> np.ndarray:
    import jax
    import jax.numpy as jnp

    _ensure_ops()
    cache: Dict[int, object] = {}

    # each op dispatches its own jit'd kernel and materializes the result —
    # the function-call interface the paper's §1 describes.
    def ev(n: Node):
        if n.nid in cache:
            return cache[n.nid]
        if n.op == "placeholder":
            v = jnp.asarray(feed[n])
        elif n.op == "const":
            v = jnp.asarray(n.payload)
        else:
            args = [ev(i) for i in n.inputs]
            v = _JIT_OPS[n.op](*args)
            v.block_until_ready()
        cache[n.nid] = v
        return v

    return np.asarray(ev(node))


def _make_jit_ops():
    import jax
    import jax.numpy as jnp

    return {
        "add": jax.jit(jnp.add),
        "sub": jax.jit(jnp.subtract),
        "mul": jax.jit(jnp.multiply),
        "sigmoid": jax.jit(lambda x: 1 / (1 + jnp.exp(-x))),
        "log": jax.jit(jnp.log),
        "mean": jax.jit(jnp.mean),
        "sum": jax.jit(jnp.sum),
        "matvec": jax.jit(lambda m, v: m @ v),
    }


_JIT_OPS = None


def _ensure_ops():
    global _JIT_OPS
    if _JIT_OPS is None:
        _JIT_OPS = _make_jit_ops()


# -- whole-graph XLA -------------------------------------------------------------


_XLA_CACHE: Dict[int, object] = {}


def _run_xla(node: Node, feed) -> np.ndarray:
    import jax
    import jax.numpy as jnp

    order = sorted(feed.keys(), key=lambda n: n.nid)

    def fn(*arrays):
        env = {n.nid: a for n, a in zip(order, arrays)}

        def ev(n: Node):
            if n.nid in env:
                return env[n.nid]
            if n.op == "const":
                v = jnp.asarray(n.payload)
            else:
                args = [ev(i) for i in n.inputs]
                v = {
                    "add": jnp.add, "sub": jnp.subtract, "mul": jnp.multiply,
                    "sigmoid": lambda x: 1 / (1 + jnp.exp(-x)),
                    "log": jnp.log, "mean": jnp.mean, "sum": jnp.sum,
                    "matvec": lambda m, w: m @ w,
                }[n.op](*args)
            env[n.nid] = v
            return v

        return ev(node)

    jitted = _XLA_CACHE.get(node.nid)
    if jitted is None:
        jitted = jax.jit(fn)
        _XLA_CACHE[node.nid] = jitted
    out = jitted(*[feed[n] for n in order])
    return np.asarray(jax.block_until_ready(out))


# -- Weld graph transformer ------------------------------------------------------


def transform_graph(node: Node, feed) -> Tuple[WeldObject, int]:
    """Replace the maximal Weld-portable subgraph with one WeldOp.

    Returns the WeldObject for `node` and the number of graph nodes merged
    into the WeldOp region.  (All ops in this demo library are portable, so
    the whole graph merges — with a non-portable op the transformer would
    cut the region there, matching the paper's incremental-porting story.)
    """
    merged = 0
    cache: Dict[int, Tuple[WeldObject, ir.Expr, tuple]] = {}

    def ev(n: Node):
        nonlocal merged
        if n.nid in cache:
            return cache[n.nid]
        if n.op == "placeholder":
            obj = NewWeldObject(np.asarray(feed[n]), None)
            out = (obj, ir.Ident(obj.obj_id, obj.weld_type()),
                   np.asarray(feed[n]).shape)
        elif n.op == "const":
            obj = NewWeldObject(np.asarray(n.payload), None)
            out = (obj, ir.Ident(obj.obj_id, obj.weld_type()),
                   np.asarray(n.payload).shape)
        else:
            ins = [ev(i) for i in n.inputs]
            merged += 1
            out = _weld_op(n.op, ins)
        cache[n.nid] = out
        return out

    obj, expr, shape = ev(node)
    return NewWeldObject(_deps_of(expr, cache), expr), merged


def _deps_of(expr: ir.Expr, cache) -> List[WeldObject]:
    names = set(ir.free_vars(expr))
    out = []
    for obj, e, shape in cache.values():
        if obj.obj_id in names:
            out.append(obj)
    return out


def _weld_op(op: str, ins) -> Tuple[WeldObject, ir.Expr, tuple]:
    exprs = [e for _, e, _ in ins]
    shapes = [s for _, _, s in ins]
    deps = [o for o, _, _ in ins]

    def binop(o):
        a, b = exprs
        sa, sb = shapes
        if sa == sb and len(sa) >= 1:
            e = M.zip_map([a, b], lambda x, y: ir.BinOp(o, x, y))
            return e, sa
        if len(sa) >= 1 and len(sb) == 0:
            e = M.map_(a, lambda x: ir.BinOp(o, x, b))
            return e, sa
        if len(sb) >= 1 and len(sa) == 0:
            e = M.map_(b, lambda x: ir.BinOp(o, a, x))
            return e, sb
        return ir.BinOp(o, a, b), ()

    if op in ("add", "sub", "mul"):
        sym = {"add": "+", "sub": "-", "mul": "*"}[op]
        e, shape = binop(sym)
    elif op in ("sigmoid", "log"):
        (a,), (sa,) = exprs, shapes
        e = M.map_(a, lambda x: ir.UnaryOp(op, x)) if len(sa) >= 1 \
            else ir.UnaryOp(op, a)
        shape = sa
    elif op == "sum":
        e = M.reduce_(exprs[0], "+")
        shape = ()
    elif op == "mean":
        s = M.reduce_(exprs[0], "+")
        n = ir.Cast(ir.Len(exprs[0]), wt.F64)
        e = ir.BinOp("/", ir.Cast(s, wt.F64), n)
        shape = ()
    elif op == "matvec":
        m, v = exprs
        e = ir.CUDF("linalg.matvec", (m, v), wt.Vec(wt.F64))
        shape = (shapes[0][0],)
    else:
        raise ValueError(f"op {op} not weld-portable")

    obj = NewWeldObject(deps, e)
    return obj, ir.Ident(obj.obj_id, obj.weld_type()), shape


def _run_weld(node: Node, feed) -> np.ndarray:
    obj, merged = transform_graph(node, feed)
    res = Evaluate(obj)
    return np.asarray(res.value)
