#!/usr/bin/env python
"""CI explain/trace smoke (run from tools/ci.sh with WELD_TRACE=1).

Compiles a kernelized m:n hash join AND a group-by query with tracing
on, then asserts the whole observability surface end to end:

* the Chrome-trace export is valid JSON with the expected span names
  and monotonic nested spans (children inside their parents);
* ``Query.explain(analyze=True)`` shows ``group_build``/``group_probe``
  launches with BOTH predicted and measured times;
* the cost ledger received records and ``tools/cost_report.py``
  summarizes it without error.

State is confined to a temp directory (autotune cache + ledger) so the
smoke never pollutes — or depends on — the developer's caches.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

_TOOLS = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(_TOOLS, "..", "src"))

_td = tempfile.mkdtemp(prefix="weld-trace-smoke-")
os.environ["WELD_AUTOTUNE_CACHE"] = os.path.join(_td, "autotune.json")
os.environ["WELD_COST_LEDGER"] = os.path.join(_td, "cost_ledger.jsonl")
os.environ.setdefault("WELD_TRACE", "1")

import numpy as np  # noqa: E402

from repro import obs  # noqa: E402
from repro.frames import weldrel  # noqa: E402


def main() -> int:
    assert obs.enabled(), "WELD_TRACE=1 must enable tracing at import"

    n, k, fanout = 8192, 64, 4
    rng = np.random.RandomState(7)
    rkey = np.repeat(np.arange(k, dtype=np.int64), fanout)
    right = weldrel.Table({"key": rkey, "rate": rng.rand(rkey.size)})
    left = weldrel.Table({
        "key": rng.randint(0, 2 * k, n).astype(np.int64),
        "price": rng.rand(n),
    })

    # -- m:n join under EXPLAIN ANALYZE ---------------------------------
    rep = weldrel.Query(left).explain(analyze=True).join(
        right, on="key", kernelize="always")
    launches = {r["kernel"]: r for r in rep.kernel_spans()}
    for kern in ("group_build", "group_probe"):
        r = launches.get(kern)
        assert r, f"missing measured {kern} launch: {launches}"
        assert r["predicted_ns"] and r["measured_ns"], (kern, r)
    text = rep.render()
    for needle in ("EXPLAIN ANALYZE", "kernel[group_build]",
                   "kernel[group_probe]", "predicted vs measured"):
        assert needle in text, f"explain output missing {needle!r}"
    print("explain(analyze=True): group_build + group_probe measured OK")

    # -- group-by query, plain tracing ----------------------------------
    st: dict = {}
    grouped = weldrel.Query(left).group_agg(
        [left.col("key")], {"s": (left.col("price"), "+")},
        capacity=2 * k, kernelize="auto", collect_stats=st)
    assert grouped, "group-by returned nothing"

    # -- trace export: valid JSON, expected names, monotonic nesting ----
    trace_path = os.path.join(_td, "trace.json")
    obs.dump_chrome(trace_path)
    with open(trace_path) as f:
        trace = json.load(f)
    events = trace["traceEvents"]
    names = {e["name"] for e in events}
    for want in ("weld.evaluate", "optimize", "pass.fusion", "kernelplan",
                 "jit_compile", "execute", "decode", "cache.lookup",
                 "kernel.group_build", "kernel.group_probe"):
        assert want in names, f"trace missing span {want!r}: {sorted(names)}"
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in events)
    # nesting: every span must sit inside the evaluate span that opened
    # before it (spans are recorded in pre-order per thread)
    spans = obs.spans()
    stack: list = []
    for sp in spans:
        while stack and sp.depth <= stack[-1].depth:
            stack.pop()
        if stack:
            parent = stack[-1]
            end = parent.start_ns + (parent.dur_ns or 0)
            assert sp.start_ns >= parent.start_ns, (sp.name, parent.name)
            assert sp.start_ns + (sp.dur_ns or 0) <= end + 1_000_000, \
                (sp.name, parent.name)
        stack.append(sp)
    print(f"chrome trace OK: {len(events)} events, nesting monotonic")

    # -- ledger + report CLI --------------------------------------------
    ledger_path = os.environ["WELD_COST_LEDGER"]
    assert os.path.exists(ledger_path), "ledger not written"
    out = subprocess.run(
        [sys.executable, os.path.join(_TOOLS, "cost_report.py"),
         "--ledger", ledger_path],
        capture_output=True, text=True, check=True,
    )
    assert "group_build" in out.stdout and "group_probe" in out.stdout, \
        out.stdout
    print("cost_report.py OK:")
    print(out.stdout)
    return 0


if __name__ == "__main__":
    sys.exit(main())
