#!/usr/bin/env bash
# CI gate: byte-compile lint + the fast tier-1 slice (< a few minutes).
#
#   tools/ci.sh            # lint + fast tests
#   tools/ci.sh --full     # lint + the whole tier-1 suite (slow tests too)
#
# Extra args after the mode flag are forwarded to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."

MARK='not slow'
if [[ "${1:-}" == "--full" ]]; then
    MARK=''
    shift
fi

echo "== compileall lint =="
python -m compileall -q src benchmarks tests tools 2>/dev/null || \
python -m compileall -q src benchmarks tests

echo "== pytest (WELD_VERIFY=1: weldcheck on every compile) =="
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# every compile in the suite re-verifies its IR after each optimizer
# pass, after kernel planning, and after recovery rewrites — a pass
# that miscompiles fails here even when the numbers happen to agree
export WELD_VERIFY=1
if [[ -n "$MARK" ]]; then
    python -m pytest -x -q -m "$MARK" "$@"
else
    python -m pytest -x -q "$@"
fi

echo "== weldlint smoke (static verifier corpus + overhead gate) =="
# verifies the representative corpus (joins, group-by) compiles with
# every weldcheck checkpoint clean, gates verifier overhead at <10% of
# compile time, and gates mutation recall (seeded IR sabotage must be
# caught with the right code at the right node) at >=95%
python tools/weldlint.py --smoke
python tools/weldlint.py --mutate 3

echo "== weldbound smoke (size/memory-bounds certificate gate) =="
# asserts every corpus pipeline carries a peak-memory certificate in
# its stats, gates the bounds-analysis overhead at <10% of compile
# time, and prints the golden symbolic m:n certificate (an explain()
# with precount=False — no host pre-count anywhere in the plan)
python tools/weldlint.py --bounds-smoke

echo "== kernelplan smoke ablation (cost-gate regression check) =="
# asserts every auto-routed workload stays within tolerance of the jnp
# baseline (and that the group-by route still wins), so a cost-gate
# regression fails CI instead of landing silently
python -m benchmarks.bench_kernelplan --smoke

echo "== join smoke ablation (hash-build/probe routing check) =="
# asserts the hash-join build+probe kernels route under auto at the
# large config and are cost-gated at the tiny one, and that inner/
# left/anti/multi-key joins each take exactly ONE horizontally fused
# probe launch (N probes for an N-column join is a fusion regression)
python -m benchmarks.bench_join --smoke

echo "== recovery/faults smoke (adaptive recovery + quarantine check) =="
# injects deterministic faults (undersized join capacity, failing kernel
# launch) and asserts the recovery ladder regrows/falls back to oracle-
# correct rows, the offender lands in the quarantine health file, and
# the next compile rejects it at the cost gate
python tools/faults_smoke.py

echo "== explain/trace smoke (weldtrace observability check) =="
# compiles a kernelized m:n join + a group-by with WELD_TRACE=1,
# asserts the Chrome-trace export is valid and nested, that
# explain(analyze=True) shows predicted AND measured kernel times,
# and that tools/cost_report.py summarizes the produced ledger
WELD_TRACE=1 python tools/trace_smoke.py

echo "== serve smoke (AOT staging + concurrent serving check) =="
# drives QueryServer with 8 threads x 32 mixed staged queries and
# asserts byte-identical results vs the serial oracle, exactly one
# compile per distinct (plan, shape) key (single-flight), zero-compile
# same-shape rebinds, typed ResourceError shedding at admission, and
# that ledger-seeded medians reprice the cost gate (source=measured in
# explain) without flipping any routing decision
python tools/serve_smoke.py
