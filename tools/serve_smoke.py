#!/usr/bin/env python
"""CI serving smoke (run from tools/ci.sh).

Drives the weldserve stack end to end and asserts the §7.8 economics
actually hold under concurrency:

* 8 worker threads x 32 mixed staged queries (two join shapes, an m:n
  variant, a group-by): results byte-identical to the serial eager
  oracle, exactly ONE compile per distinct (plan, shape) key proven via
  the ``cache.*`` counters, ``cache_size()`` bounded by
  ``WELD_COMPILE_CACHE_MAX``;
* AOT re-binding: a ``CompiledQuery.run(**tables)`` against fresh
  same-shape tables spends zero additional compiles;
* admission: a provably over-budget query sheds with a typed
  ``ResourceError`` and never enters the compile cache;
* calibration: ledger medians seeded from an authentic traced run
  overlay the roofline estimates — the recompiled plan's ``explain()``
  shows ``source=measured`` provenance WITHOUT flipping any routing
  decision (the seeded medians equal the roofline predictions).

State is confined to a temp directory (autotune cache + ledger) so the
smoke never pollutes — or depends on — the developer's caches.
"""
from __future__ import annotations

import os
import sys
import tempfile

_TOOLS = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(_TOOLS, "..", "src"))

_td = tempfile.mkdtemp(prefix="weld-serve-smoke-")
os.environ["WELD_AUTOTUNE_CACHE"] = os.path.join(_td, "autotune.json")
os.environ["WELD_COST_LEDGER"] = os.path.join(_td, "cost_ledger.jsonl")
os.environ["WELD_COMPILE_CACHE_MAX"] = "8"
os.environ.setdefault("WELD_TRACE", "1")  # measured replay -> ledger

import numpy as np  # noqa: E402

from repro.core import runtime  # noqa: E402
from repro.core.errors import ResourceError  # noqa: E402
from repro.core.kernelplan import calibrate  # noqa: E402
from repro.core.obs import ledger  # noqa: E402
from repro.core.serve import QueryServer  # noqa: E402
from repro.frames.weldrel import Query, Table, _host  # noqa: E402


def _tables(n, k, seed):
    rng = np.random.RandomState(seed)
    probe = {"k": rng.randint(0, k, n).astype(np.int64),
             "x": rng.rand(n)}
    build = {"k": np.arange(k, dtype=np.int64), "w": rng.rand(k)}
    return probe, build


def _assert_tables_equal(got, want, label):
    assert sorted(got.cols) == sorted(want.cols), label
    for c in got.cols:
        np.testing.assert_array_equal(
            np.asarray(_host(got.cols[c])), np.asarray(_host(want.cols[c])),
            err_msg=f"{label}: column {c}")


def main() -> int:
    pa, ba = _tables(n=20000, k=100, seed=1)
    pb, bb = _tables(n=7000, k=50, seed=2)
    dup = {"k": np.concatenate([ba["k"], ba["k"]]),
           "w": np.concatenate([ba["w"], ba["w"] + 1.0])}

    makers = [
        lambda: Query(Table(dict(pa))).stage().join(
            Table(dict(ba)), on="k", validate="m:1"),
        lambda: Query(Table(dict(pb))).stage().join(
            Table(dict(bb)), on="k", validate="m:1"),
        lambda: Query(Table(dict(pa))).stage().join(
            Table(dict(dup)), on="k"),
        lambda: _staged_group(pa),
    ]

    def _staged_group(cols):
        t = Table(dict(cols))
        return Query(t).stage().group_agg(
            [t.col("k")], {"s": (t.col("x"), "+")})

    def _eager_join(probe, build, **kw):
        return Query(Table(dict(probe), eager=True)).join(
            Table(dict(build), eager=True), **kw)

    te = Table(dict(pa), eager=True)
    oracles = [
        _eager_join(pa, ba, on="k", validate="m:1"),
        _eager_join(pb, bb, on="k", validate="m:1"),
        _eager_join(pa, dup, on="k"),
        Query(te).group_agg([te.col("k")], {"s": (te.col("x"), "+")}),
    ]

    # -- admission shedding (first: cold cache, empty ledger) ------------
    runtime.clear_cache()
    with QueryServer(workers=2, memory_limit=64) as tiny:
        try:
            tiny.run(makers[0]())
            raise AssertionError("64-byte budget must shed the join")
        except ResourceError as e:
            assert "admission" in str(e), e
    assert tiny.stats()["serve.shed"] == 1
    assert runtime.cache_size() == 0, "a shed plan must never be cached"
    print("admission: over-budget query shed with typed ResourceError, "
          "nothing cached")

    # -- concurrent serving: 8 threads x 32 mixed queries ----------------
    runtime.clear_cache()
    n_req, distinct = 32, len(makers)
    reqs = [makers[i % distinct]() for i in range(n_req)]
    with QueryServer(workers=8) as srv:
        futs = [srv.submit(q) for q in reqs]
        results = [f.result() for f in futs]
    st = srv.stats()
    assert st["cache.misses"] == distinct, \
        f"single-flight broken: {distinct} plans, {st['cache.misses']} compiles"
    assert st["cache.hits"] + st["cache.waits"] == n_req - distinct, st
    assert runtime.cache_size() <= 8, st
    assert st["serve.completed"] == n_req and st["serve.shed"] == 0, st
    for i, got in enumerate(results):
        want = oracles[i % distinct]
        if isinstance(got, Table):
            _assert_tables_equal(got, want, f"request {i}")
        else:  # group-by dict: float sums may differ in the last ulp
            assert set(got) == set(want), f"request {i}"
            for key in want:
                np.testing.assert_allclose(
                    np.asarray(got[key], dtype=float),
                    np.asarray(want[key], dtype=float),
                    err_msg=f"request {i} group {key}")
    print(f"serve: {n_req} requests / 8 threads -> "
          f"{st['cache.misses']} compiles ({distinct} distinct plans), "
          f"{st['cache.hits']} hits, {st['cache.waits']} waits, "
          f"results byte-identical to serial oracle")

    # -- AOT re-binding: zero recompiles ---------------------------------
    cq = makers[0]().compile()
    misses0 = runtime.cache_stats()["cache.misses"]
    pa2, ba2 = _tables(n=20000, k=100, seed=9)
    out = cq.run(table=Table(dict(pa2)), right=Table(dict(ba2)))
    assert runtime.cache_stats()["cache.misses"] == misses0, \
        "same-shape rebind must not recompile"
    _assert_tables_equal(out, _eager_join(pa2, ba2, on="k", validate="m:1"),
                         "rebind")
    print("rebind: same-shape run(**tables) spent 0 recompiles")

    # -- calibration: measured medians overlay the roofline --------------
    # a fresh ledger: the traced runs above recorded AUTHENTIC (slow
    # CPU) medians for every routed kernel, which would calibrate — and
    # legitimately flip — the baseline compile we diff against below
    os.environ["WELD_COST_LEDGER"] = os.path.join(_td, "ledger_cal.jsonl")
    calibrate.invalidate()
    runtime.clear_cache()
    # authentic records: a traced always-routed m:n join writes one
    # ledger row per kernel launch (predicted AND measured)
    Query(Table(dict(pa))).join(Table(dict(dup)), on="k",
                                kernelize="always")
    recs = ledger.read()
    assert recs, "traced always-run must seed the cost ledger"
    # pre-calibration baseline under auto: routing decisions + provenance
    base = makers[2]().compile()
    base_costs = {c["kernel"]: bool(c["routed"])
                  for c in base.stats["kernelplan"]["costs"]}
    assert "source=roofline" in base.explain().render()
    # seed medians that EQUAL the roofline predictions so provenance
    # switches to measured while every routing decision stays put
    need = calibrate.min_samples() + 2
    for r in {(r["kernel"], r["dtype"], r["bucket"]): r
              for r in recs if r.get("predicted_ns")}.values():
        for _ in range(need):
            ledger.record(r["kernel"], r["dtype"], r["n"],
                          r["predicted_ns"], r["predicted_ns"])
    calibrate.invalidate()
    runtime.clear_cache()
    cal = makers[2]().compile()
    rendered = cal.explain().render()
    assert "source=measured" in rendered, rendered
    cal_costs = {c["kernel"]: bool(c["routed"])
                 for c in cal.stats["kernelplan"]["costs"]
                 if c.get("source") == "measured"}
    assert cal_costs, "no measured-provenance cost rows after seeding"
    for kern, routed in cal_costs.items():
        assert base_costs.get(kern) == routed, \
            (f"calibration flipped routing for {kern}: "
             f"{base_costs.get(kern)} -> {routed}")
    print(f"calibration: {len(cal_costs)} kernels repriced from ledger "
          f"medians (source=measured), routing decisions unchanged")
    return 0


if __name__ == "__main__":
    sys.exit(main())
