#!/usr/bin/env python
"""Summarize the weldtrace cost ledger: calibration error per kernel.

The ledger (``~/.cache/weld-repro/cost_ledger.jsonl`` by default, or
``$WELD_COST_LEDGER``) accumulates one record per measured kernel launch
— the planner's roofline ``predicted_ns`` next to the replay's
``measured_ns``.  This CLI groups records by (kernel, dtype,
size-bucket) and reports median predicted/measured times, their ratio,
and the mean |log2 ratio| calibration error.

    PYTHONPATH=src python tools/cost_report.py [--ledger PATH]
        [--kernel NAME] [--json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
)

from repro.core.obs import ledger  # noqa: E402


def _calibrate_dump(path: str, kernel: str | None) -> int:
    """Print the gate's own view of the ledger: one JSON row per
    (kernel, dtype, size-bucket) with the median it would overlay and
    whether the group clears the sample floor.  This goes through
    ``kernelplan.calibrate`` itself, so what it prints is BY
    CONSTRUCTION what ``cost.estimate`` would use."""
    from repro.core.kernelplan import calibrate  # noqa: E402

    floor = calibrate.min_samples()
    rows = []
    for (kern, dtype, bucket), g in sorted(calibrate.medians(path).items()):
        if kernel and kern != kernel:
            continue
        rows.append({
            "kernel": kern,
            "dtype": dtype,
            "bucket": bucket,
            "calls": g["calls"],
            "measured_ns_median": g["measured_ns"],
            "eligible": g["calls"] >= floor,
            "min_samples": floor,
        })
    print(json.dumps({"ledger": path, "enabled": calibrate.enabled(),
                      "groups": rows}, indent=1))
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ledger", default=None,
                    help="ledger path (default: $WELD_COST_LEDGER or "
                         "next to the autotune cache)")
    ap.add_argument("--kernel", default=None,
                    help="only report this kernel")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary rows as JSON")
    ap.add_argument("--calibrate-dump", action="store_true",
                    help="emit the EXACT per-(kernel, dtype, bucket) "
                         "medians the serving cost gate overlays on the "
                         "roofline estimates, as JSON rows")
    args = ap.parse_args()

    path = args.ledger or ledger.ledger_path()
    if args.calibrate_dump:
        return _calibrate_dump(path, args.kernel)
    records = ledger.read(path)
    if args.kernel:
        records = [r for r in records if r.get("kernel") == args.kernel]
    rows = ledger.summarize(records)
    if args.json:
        print(json.dumps({"ledger": path, "records": len(records),
                          "groups": rows}, indent=1))
    else:
        print(f"# ledger: {path} ({len(records)} records)")
        if rows:
            print(ledger.format_report(rows))
        else:
            print("# no records — run a kernelized query with WELD_TRACE=1")
    return 0


if __name__ == "__main__":
    sys.exit(main())
