#!/usr/bin/env python
"""CI recovery smoke (run from tools/ci.sh).

Drives the adaptive recovery runtime end to end with deterministic
fault injection — the degradation paths no healthy workload reaches:

* an m:n join forced onto an undersized build capacity
  (``join.capacity:cap=4``) must recover by regrowing and match the
  un-faulted rows, with the ladder visible as RuntimeWarnings and
  ``recovery.*`` stats;
* a group-by whose kernel launch is made to fail
  (``kernel.<name>:raise``) must degrade to the generic lowering,
  quarantine the offender in the on-disk health file, and the NEXT
  compile must reject the quarantined route at the cost gate without a
  cache clear — proving the quarantine fingerprint invalidates the
  compile cache;
* with recovery disabled the same capacity fault surfaces as the typed
  ``CapacityError``.

State is confined to a temp directory (health file + autotune cache +
ledger) so the smoke never pollutes — or depends on — the developer's
caches.
"""
from __future__ import annotations

import os
import sys
import tempfile
import warnings

_TOOLS = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(_TOOLS, "..", "src"))

_td = tempfile.mkdtemp(prefix="weld-faults-smoke-")
os.environ["WELD_KERNEL_HEALTH"] = os.path.join(_td, "kernel_health.json")
os.environ["WELD_AUTOTUNE_CACHE"] = os.path.join(_td, "autotune.json")
os.environ["WELD_COST_LEDGER"] = os.path.join(_td, "cost_ledger.jsonl")

import numpy as np  # noqa: E402

from repro import errors, faults  # noqa: E402
from repro.core import recovery, runtime  # noqa: E402
from repro.core.kernelplan import quarantine  # noqa: E402
from repro.frames import weldrel  # noqa: E402


def _rowset(t):
    cols = sorted(t.cols)
    arrs = [np.asarray(t.cols[c].to_numpy()) for c in cols]
    return sorted(zip(*[a.tolist() for a in arrs]))


def _tables(rng):
    k, n, fanout = 32, 2048, 3
    rkey = np.repeat(np.arange(k, dtype=np.int64), fanout)
    right = weldrel.Table({"key": rkey, "rate": rng.rand(rkey.size)})
    left = weldrel.Table({
        "key": rng.randint(0, 2 * k, n).astype(np.int64),
        "price": rng.rand(n),
    })
    return left, right


def main() -> int:
    rng = np.random.RandomState(11)
    left, right = _tables(rng)

    # -- 1. capacity fault on an m:n join: regrow to parity -------------
    want = _rowset(weldrel.Query(left).join(right, on="key",
                                            kernelize="always"))
    runtime.clear_cache()
    # cap=4 against 32 distinct build keys: x2/x4 still overflow, the
    # third rung (x8 = 32) fits — the deepest recoverable ladder
    faults.inject("join.capacity", "cap", times=1, value=4)
    st: dict = {}
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        got = weldrel.Query(left).join(right, on="key", kernelize="always",
                                       collect_stats=st)
    assert _rowset(got) == want, "recovered join differs from healthy run"
    assert st.get("recovery.attempts", 0) >= 2, st
    assert any("weld recovery" in str(x.message) for x in w), \
        "recovery must warn"
    assert faults.fired(), "the armed capacity fault never fired"
    faults.clear()
    print(f"join capacity fault: recovered after "
          f"{st['recovery.attempts']} attempts "
          f"(regrow x{st['recovery.regrow_factor']}), rows match")

    # -- 1b. group-by with an injected generic-build poison --------------
    runtime.clear_cache()
    want_gb = weldrel.Query(left).group_agg(
        [left.col("key")], {"s": (left.col("price"), "+")},
        capacity=128, kernelize="off")
    faults.inject("dict.build", "poison", times=1)
    stg: dict = {}
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        got_gb = weldrel.Query(left).group_agg(
            [left.col("key")], {"s": (left.col("price"), "+")},
            capacity=128, kernelize="off", collect_stats=stg)
    assert stg.get("recovery.attempts", 0) >= 2, stg
    assert set(got_gb) == set(want_gb) and all(
        abs(got_gb[k][0] - want_gb[k][0]) < 1e-9 for k in want_gb)
    faults.clear()
    print(f"group-by build poison: recovered after "
          f"{stg['recovery.attempts']} attempts, groups match")

    # -- 2. kernel fault: generic fallback + quarantine + cost gate -----
    runtime.clear_cache()
    quarantine.clear(disk=True)
    want_g = _rowset(weldrel.Query(left).join(right, on="key",
                                              kernelize="off"))
    qfp = quarantine.fingerprint()
    faults.inject("kernel.group_build", "raise", times=1)
    st2: dict = {}
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        got2 = weldrel.Query(left).join(right, on="key", kernelize="always",
                                        collect_stats=st2)
    assert _rowset(got2) == want_g, "fallback join differs from generic run"
    assert st2.get("recovery.fallback"), st2
    qkeys = st2.get("recovery.quarantined") or []
    assert qkeys and qkeys[0].startswith("group_build|"), st2
    assert os.path.exists(os.environ["WELD_KERNEL_HEALTH"]), \
        "health file not written"
    assert quarantine.fingerprint() != qfp, \
        "quarantine fingerprint must change (compile-cache invalidation)"
    faults.clear()
    # next compile, NO cache clear: the gate consults the quarantine
    st3: dict = {}
    got3 = weldrel.Query(left).join(right, on="key", kernelize="always",
                                    collect_stats=st3)
    assert _rowset(got3) == want_g
    kp = st3.get("kernelplan", {})
    assert kp.get("rejected", {}).get("group_build"), kp
    assert any(c.get("why") == "quarantined" for c in kp.get("costs", [])), \
        kp
    assert "recovery.attempts" not in st3, "healthy run touched the ladder"
    print(f"kernel fault: quarantined {qkeys[0]}; next compile rejected it "
          f"at the cost gate")

    # -- 3. recovery disabled: the typed error surfaces ------------------
    runtime.clear_cache()
    faults.inject("join.capacity", "cap", times=1, value=4)
    try:
        with recovery.disabled():
            try:
                weldrel.Query(left).join(right, on="key", kernelize="always")
            except errors.CapacityError:
                pass
            else:
                raise AssertionError(
                    "recovery.disabled() must surface CapacityError")
    finally:
        faults.clear()
    print("recovery disabled: typed CapacityError surfaced")
    return 0


if __name__ == "__main__":
    sys.exit(main())
