#!/usr/bin/env python
"""weldlint: run the weldcheck static verifier from the command line.

Modes:

* ``--smoke`` (the CI gate) — compile a representative corpus (hash
  join, m:n join, group-by) with verification on, assert every
  checkpoint ran clean, print the per-phase timing table, and gate the
  verifier's overhead at <10% of compile time;
* ``--mutate N`` — run the seeded mutation harness N rounds per
  mutator over the same corpus and report verifier recall (gated at
  >=95%);
* ``--bounds-smoke`` — the weldbound gate: every corpus pipeline must
  carry a peak-memory certificate in its stats, the analysis overhead
  must stay <10% of compile time, and the symbolic (no host pre-count)
  m:n certificate must render in ``explain()``;
* ``--demo`` — print a diagnostic rendered on a deliberately broken
  program (what a failing checkpoint looks like).

State is confined to a temp directory (autotune cache + ledger) so the
smoke never pollutes — or depends on — the developer's caches.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile

_TOOLS = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(_TOOLS, "..", "src"))

_td = tempfile.mkdtemp(prefix="weld-lint-")
os.environ["WELD_AUTOTUNE_CACHE"] = os.path.join(_td, "autotune.json")
os.environ["WELD_COST_LEDGER"] = os.path.join(_td, "cost_ledger.jsonl")
os.environ["WELD_VERIFY"] = "1"

import numpy as np  # noqa: E402

from repro.core import check, ir, wtypes as wt  # noqa: E402
from repro.core.check import mutate  # noqa: E402
from repro.frames import weldrel  # noqa: E402

OVERHEAD_GATE = 0.10  # verify time / compile time
RECALL_GATE = 0.95


def corpus():
    """(label, stats) per representative pipeline — the planned IR rides
    in stats['plan.ir'], verify counters in stats['verify.*']."""
    rng = np.random.RandomState(11)
    n = 512
    left = weldrel.Table({"k": rng.randint(0, 64, n).astype(np.int64),
                          "lv": rng.rand(n)})
    uniq = weldrel.Table({"k": np.arange(64, dtype=np.int64),
                          "rv": rng.rand(64)})
    mn = weldrel.Table({"k": rng.randint(0, 16, 128).astype(np.int64),
                        "rv": rng.rand(128)})
    out = []
    st = {}
    weldrel.Query(left).join(uniq, on="k", how="inner", collect_stats=st)
    out.append(("join.inner.1:1", st))
    st = {}
    weldrel.Query(left).join(mn, on="k", how="inner", collect_stats=st)
    out.append(("join.inner.m:n", st))
    st = {}
    weldrel.Query(left).join(uniq, on="k", how="left", collect_stats=st)
    out.append(("join.left", st))
    st = {}
    weldrel.Query(left).join(mn, on="k", how="left", collect_stats=st)
    out.append(("join.left.m:n", st))
    st = {}
    weldrel.Query(left).group_agg(
        [left.col("k")], {"s": (left.col("lv"), "+")}, collect_stats=st)
    out.append(("group_agg.sum", st))
    return out


def cmd_smoke() -> int:
    from repro.core import runtime

    runtime.clear_cache()
    print("== weldlint --smoke ==")
    total_verify = 0.0
    total_compile = 0.0
    runs = 0
    for label, st in corpus():
        vms = st.get("verify.ms", 0.0)
        cms = st.get("compile_ms", 0.0)
        vruns = st.get("verify.runs", 0)
        if vruns == 0:
            print(f"FAIL {label}: no verify checkpoints ran")
            return 1
        plan = st.get("plan.ir")
        resid = check.verify(plan) if plan is not None else []
        if resid:
            print(f"FAIL {label}: planned IR has diagnostics:")
            for d in resid:
                print("  " + d.render(plan))
            return 1
        total_verify += vms
        total_compile += cms
        runs += vruns
        print(f"  {label:<18} checkpoints={vruns:<3} "
              f"verify={vms:7.1f}ms compile={cms:8.1f}ms "
              f"({vms / cms:6.1%})")
    frac = total_verify / total_compile if total_compile else 0.0
    print(f"  {'TOTAL':<18} checkpoints={runs:<3} "
          f"verify={total_verify:7.1f}ms compile={total_compile:8.1f}ms "
          f"({frac:6.1%})")
    if frac >= OVERHEAD_GATE:
        print(f"FAIL: verifier overhead {frac:.1%} >= "
              f"{OVERHEAD_GATE:.0%} of compile time")
        return 1
    print(f"OK: corpus clean, overhead {frac:.1%} < {OVERHEAD_GATE:.0%}")
    return 0


def cmd_mutate(rounds: int, seed: int) -> int:
    print(f"== weldlint --mutate (rounds={rounds}, seed={seed}) ==")
    caught = [st for _, st in corpus() if "plan.ir" in st]
    progs = [st["plan.ir"] for st in caught]
    # bound input shapes per program: the WV501/WV502 bounds mutators
    # are only catchable when derived symbolic sizes evaluate to numbers
    shapes = [st.get("plan.inputs", (None, None, None))[2]
              for st in caught]
    score = mutate.run_mutations(progs, seed=seed, rounds=rounds,
                                 shapes=shapes)
    print(f"  mutants applied: {score.applied}")
    print(f"  caught (right code, right node): {score.caught} "
          f"({score.rate:.0%})")
    for name, seen in score.misses:
        print(f"  MISS {name}: diagnostics seen {seen}")
    if score.rate < RECALL_GATE:
        print(f"FAIL: recall {score.rate:.0%} < {RECALL_GATE:.0%}")
        return 1
    print(f"OK: recall {score.rate:.0%} >= {RECALL_GATE:.0%}")
    return 0


def cmd_bounds_smoke() -> int:
    """weldbound gate: every corpus pipeline gets a peak-memory
    certificate, analysis overhead stays <10% of compile time, and the
    symbolic m:n certificate (no host pre-count) renders in explain()."""
    from repro.core import runtime

    runtime.clear_cache()
    print("== weldlint --bounds-smoke ==")
    total_bounds = 0.0
    total_compile = 0.0
    for label, st in corpus():
        for key in ("bounds.certificate", "bounds.peak_bytes",
                    "bounds.admitted"):
            if key not in st:
                print(f"FAIL {label}: no {key} in stats (analysis "
                      f"failed or was skipped)")
                return 1
        if not st["bounds.admitted"]:
            print(f"FAIL {label}: rejected with no memory_limit set")
            return 1
        bms = st.get("bounds.ms", 0.0)
        cms = st.get("compile_ms", 0.0)
        total_bounds += bms
        total_compile += cms
        print(f"  {label:<18} peak={st['bounds.peak_bytes']:>12} "
              f"bounds={bms:6.2f}ms compile={cms:8.1f}ms  "
              f"cert: {st['bounds.certificate'][:60]}")
    frac = total_bounds / total_compile if total_compile else 0.0
    if frac >= OVERHEAD_GATE:
        print(f"FAIL: bounds-analysis overhead {frac:.1%} >= "
              f"{OVERHEAD_GATE:.0%} of compile time")
        return 1
    # golden: the symbolic certificate of an m:n join with NO host
    # pre-count must render in explain() in terms of the input lengths
    rng = np.random.RandomState(7)
    left = weldrel.Table({"k": rng.randint(0, 16, 256).astype(np.int64),
                          "lv": rng.rand(256)})
    mn = weldrel.Table({"k": rng.randint(0, 16, 64).astype(np.int64),
                        "rv": rng.rand(64)})
    rep = weldrel.Query(left).explain().join(mn, on="k", how="left",
                                             precount=False)
    txt = rep.render()
    if "-- bounds --" not in txt or "len(" not in txt:
        print("FAIL: precount=False explain() lacks a symbolic "
              "'-- bounds --' certificate:")
        print(txt)
        return 1
    i = txt.index("-- bounds --")
    print("  golden symbolic m:n certificate (precount=False):")
    for line in txt[i:].splitlines()[:4]:
        print("  " + line)
    print(f"OK: certificates on corpus, overhead {frac:.1%} < "
          f"{OVERHEAD_GATE:.0%}, symbolic certificate renders")
    return 0


def cmd_demo() -> int:
    bty = wt.DictMerger(wt.I64, wt.F64, "+")
    xs = ir.Ident("xs", wt.Vec(wt.F64))
    b, i, e = (ir.Ident("b", bty), ir.Ident("i", wt.I64),
               ir.Ident("e", wt.F64))
    prog = ir.Result(ir.For(
        (ir.Iter(xs),),
        ir.NewBuilder(bty, arg=ir.Literal(0, wt.I64)),
        ir.Lambda((b, i, e),
                  ir.Merge(b, ir.MakeStruct((ir.Cast(e, wt.I64), e))))))
    try:
        check.checkpoint("pass.demo", prog)
    except check.WeldVerifyError as err:
        print(str(err))
        return 0
    print("expected the demo program to fail verification")
    return 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="weldlint", description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: corpus clean + overhead < 10%%")
    ap.add_argument("--mutate", type=int, metavar="N", default=None,
                    help="mutation harness, N rounds per mutator")
    ap.add_argument("--seed", type=int, default=2026)
    ap.add_argument("--bounds-smoke", action="store_true",
                    help="weldbound gate: certificates + overhead < 10%%"
                         " + symbolic m:n golden")
    ap.add_argument("--demo", action="store_true",
                    help="show a rendered diagnostic")
    args = ap.parse_args(argv)
    if args.smoke:
        return cmd_smoke()
    if args.mutate is not None:
        return cmd_mutate(args.mutate, args.seed)
    if args.bounds_smoke:
        return cmd_bounds_smoke()
    if args.demo:
        return cmd_demo()
    ap.print_help()
    return 2


if __name__ == "__main__":
    sys.exit(main())
