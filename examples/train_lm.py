"""End-to-end training driver: train a ~10M-parameter llama-family model
for a few hundred steps on CPU with the full production stack —
sharding rules, AdamW + cosine schedule, grad clipping, deterministic
data pipeline, async checkpointing, straggler monitor.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/weld_lm_ckpt")
    args = ap.parse_args()

    out = train(
        "llama3.2-3b",          # smoke variant: 2L x 64d (~10M with vocab)
        smoke=True,
        steps=args.steps,
        global_batch=16,
        seq_len=128,
        accum=1,
        peak_lr=3e-3,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=100,
        log_every=20,
    )
    losses = out["losses"]
    print(f"\nfirst-10 mean loss: {sum(losses[:10]) / 10:.4f}")
    print(f"last-10  mean loss: {sum(losses[-10:]) / 10:.4f}")
    print(f"straggler monitor : {out['straggler']}")
    assert sum(losses[-10:]) < sum(losses[:10]), "loss did not decrease"
    print("loss decreased ✓  (resume with the same --ckpt-dir to continue)")


if __name__ == "__main__":
    main()
