"""Quickstart: Weld's cross-library optimization in 40 lines.

The paper's Listing 7: filter a dataframe with (weld)Pandas, total a
column with (weld)NumPy — two libraries, one fused loop at evaluation.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.lazy import Evaluate
from repro.frames import welddf, weldnp

rng = np.random.RandomState(0)
n = 2_000_000
data = {
    "population": rng.randint(0, 1_000_000, n).astype(np.float64),
    "crime": rng.rand(n),
}

# -- welddf: lazy dataframe; nothing computes yet ---------------------------
df = welddf.DataFrame(data)
big = df[df["population"] > 500_000]

# -- weldnp math on the *filtered* pandas columns (cross-library!) ----------
crime_index = big["population"] * 0.1 + big["crime"] * 2.0
total = crime_index.sum()

# -- print forces evaluation: the whole workflow compiles to ONE program ----
stats = {}
result = Evaluate(total.obj, collect_stats=stats)
print(f"total crime index      : {result.value:,.2f}")
print(f"loops before optimizer : {stats['loops.before']}")
print(f"loops after fusion     : {stats['loops.after']}")
print(f"vertical fusions       : {stats.get('fusion.vertical', 0)}")
print(f"horizontal fusions     : {stats.get('fusion.horizontal', 0)}")
print(f"predicated merges      : {stats.get('predication', 0)}")
print(f"compile time           : {result.compile_ms:.0f} ms "
      f"(cached on re-evaluation)")

# validate against native NumPy
m = data["population"] > 500_000
want = (data["population"][m] * 0.1 + data["crime"][m] * 2.0).sum()
assert abs(result.value - want) < 1e-6 * abs(want)
print("matches native NumPy   : True")
