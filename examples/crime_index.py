"""The paper's motivating workload (Fig. 3) end to end, with timings:
native NumPy/Pandas vs Weld without fusion vs Weld — reproducing the
"order of magnitude below hardware limits due to data movement" claim.

    PYTHONPATH=src python examples/crime_index.py [n_rows]
"""
import sys
import time

import numpy as np

sys.path.insert(0, ".")
from benchmarks.common import time_fn  # noqa: E402
from benchmarks.workloads import (  # noqa: E402
    crime_index_native, crime_index_weld, make_crime_data,
)
from repro.core.lazy import Evaluate  # noqa: E402
from benchmarks.bench_motivating import _weld_total  # noqa: E402

n = int(sys.argv[1]) if len(sys.argv) > 1 else 4_000_000
d = make_crime_data(n)
print(f"rows: {n:,}  (~{n * 24 / 1e6:.0f} MB across three columns)")

want = crime_index_native(d)
t_native = time_fn(lambda: crime_index_native(d)) / 1e3

got = Evaluate(_weld_total(d).obj, optimize=False).value
assert abs(got - want) < 1e-6 * abs(want)
t_nofuse = time_fn(
    lambda: Evaluate(_weld_total(d).obj, optimize=False).value) / 1e3

got = crime_index_weld(d)
assert abs(got - want) < 1e-6 * abs(want)
t_weld = time_fn(lambda: crime_index_weld(d)) / 1e3

print(f"{'native NumPy+Pandas':28s} {t_native:8.1f} ms   1.0x")
print(f"{'Weld (no optimization)':28s} {t_nofuse:8.1f} ms   "
      f"{t_native / t_nofuse:.1f}x")
print(f"{'Weld (fused, one pass)':28s} {t_weld:8.1f} ms   "
      f"{t_native / t_weld:.1f}x")
