"""MoE token→expert routing expressed as a Weld program.

The dispatch/combine pattern of a Mixture-of-Experts layer is exactly
Weld's builder vocabulary (DESIGN.md §3):

  * dispatch — group token ids by expert id: a `groupbuilder`;
  * combine  — scatter-add weighted expert outputs back into token
    slots: a `vecmerger`.

This example routes a batch of tokens through the Weld IR version and
checks it against the production MoE layer's sort-based dispatch
(models/moe.py), which is the static-shape lowering of the same program.

    PYTHONPATH=src python examples/moe_weld_routing.py
"""
import numpy as np

from repro.core import ir, macros as M, wtypes as wt
from repro.core.lazy import Evaluate, NewWeldObject

rng = np.random.RandomState(0)
N_TOKENS, N_EXPERTS = 64, 8

expert_ids = rng.randint(0, N_EXPERTS, N_TOKENS).astype(np.int64)
gates = rng.rand(N_TOKENS)
# "expert outputs": expert e scales its tokens by (e + 1)
token_vals = rng.rand(N_TOKENS)

# -- dispatch: group tokens by expert (groupbuilder) -------------------------
ids_o = NewWeldObject(expert_ids, None)
tok_o = NewWeldObject(np.arange(N_TOKENS, dtype=np.int64), None)
groups = M.group_vals(
    ir.Ident(ids_o.obj_id, ids_o.weld_type()),
    ir.Ident(tok_o.obj_id, tok_o.weld_type()),
    capacity=N_EXPERTS,
)
buckets = Evaluate(NewWeldObject([ids_o, tok_o], groups)).value
print("dispatch (groupbuilder) — tokens per expert:")
for e in sorted(buckets):
    print(f"  expert {e}: {len(buckets[e])} tokens")

# -- combine: weighted scatter-add back to token slots (vecmerger) -----------
expert_out = token_vals * (expert_ids + 1)            # simulated expert math
base_o = NewWeldObject(np.zeros(N_TOKENS), None)
idx_o = NewWeldObject(np.arange(N_TOKENS, dtype=np.int64), None)
val_o = NewWeldObject(expert_out * gates, None)
combined = M.scatter_add(
    ir.Ident(base_o.obj_id, base_o.weld_type()),
    ir.Ident(idx_o.obj_id, idx_o.weld_type()),
    ir.Ident(val_o.obj_id, val_o.weld_type()),
)
got = np.asarray(Evaluate(
    NewWeldObject([base_o, idx_o, val_o], combined)).value)
want = expert_out * gates
np.testing.assert_allclose(got, want, rtol=1e-12)
print("combine (vecmerger) matches direct computation ✓")

# -- the production layer runs the same algorithm, statically shaped --------
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models.moe import moe_apply, moe_init  # noqa: E402

cfg = get_config("deepseek-moe-16b", smoke=True)
params = moe_init(jax.random.PRNGKey(0), cfg)
x = jnp.asarray(rng.randn(2, 16, cfg.d_model), jnp.float32)
out, aux = moe_apply(params, x, cfg)
print(f"production MoE layer: out {out.shape}, aux load-balance "
      f"loss {float(aux):.4f}")
print("same groupbuilder/vecmerger algorithm, lowered with static "
      "capacities (sort + segment ops) for TPU")
