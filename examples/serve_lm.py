"""Batched serving: prefill a batch of prompts, then greedy-decode with
the static KV cache — the same `decode_step` the decode_32k/long_500k
dry-run cells lower.

    PYTHONPATH=src python examples/serve_lm.py --arch zamba2-1.2b
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()
    out = serve(args.arch, smoke=True, batch=args.batch,
                prompt_len=args.prompt_len, gen_len=args.gen_len)
    print(f"generated shape: {out['tokens'].shape}; "
          f"{out['tok_per_s']:.1f} tok/s decode")


if __name__ == "__main__":
    main()
